"""Shared fixtures.

``subprocess_env`` gives every subprocess integration test (dryrun
lower+compile, multidevice selftest, hlo analysis, train/serve drivers)
ONE session-scoped JAX persistent-compilation-cache directory, stable
across pytest sessions (it lives under ``.pytest_cache``): the first
full-tier run pays the XLA compiles, later runs load the compiled
artifacts from disk, keeping the slow tier fast.  Where the installed
JAX/backend does not support the persistent cache the env vars are
inert and the tests simply compile as before.
"""
import os

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="session")
def compiled_artifact_cache() -> str:
    """Session-scoped (and session-surviving) compiled-artifact cache
    directory shared by all subprocess tests."""
    cache = os.path.join(_ROOT, ".pytest_cache", "jax_persistent_cache")
    os.makedirs(cache, exist_ok=True)
    return cache


@pytest.fixture(scope="session", autouse=True)
def _inprocess_compiled_artifact_cache(compiled_artifact_cache):
    """Point the in-process JAX at the same persistent cache, so the
    compile-heavy in-process tests (arch smoke forward/train steps, the
    jitted cost-model evaluators) also skip recompiles on warm runs.
    Best-effort: older JAX/backends without persistent-cache support
    just compile as before."""
    try:
        import jax
        jax.config.update("jax_compilation_cache_dir",
                          compiled_artifact_cache)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)
    except Exception:
        pass
    yield


@pytest.fixture(scope="session")
def subprocess_env(compiled_artifact_cache):
    """Factory for the environment of a JAX subprocess: repo PYTHONPATH,
    no inherited XLA_FLAGS, and the shared persistent compilation cache
    (caching even fast compiles, so the many small programs of the
    drivers all hit it).  Pass ``cache=False`` for subprocesses that
    re-initialize JAX mid-run (the crash-recovery train driver segfaults
    on 0.4.x CPU when its restart path loads cached executables)."""
    def make(extra=None, cache=True):
        env = dict(os.environ)
        env["PYTHONPATH"] = "src"
        env.pop("XLA_FLAGS", None)
        if cache:
            env["JAX_COMPILATION_CACHE_DIR"] = compiled_artifact_cache
            env["JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS"] = "0"
            env["JAX_PERSISTENT_CACHE_MIN_ENTRY_SIZE_BYTES"] = "0"
        if extra:
            env.update(extra)
        return env
    return make
