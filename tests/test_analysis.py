"""Contract linter + jaxpr auditor (repro.analysis).

Layer 1: every rule R1-R4 is proven to fire on a violating fixture and
stay silent on a conforming twin (tests/analysis_fixtures/); R5 is
exercised over the live registries and over deliberately broken fakes.
Layer 2: the jaxpr audit must pass on a live kernel family, detect a
deliberately-baked-constant kernel as a family-sharing failure, and
flag host callbacks.  The `python -m repro.analysis` gate itself must
exit 0 on the repo.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.analysis import lint_source, run_report
from repro.analysis.lint import Violation, suppressions
from repro.analysis.rules import ALL_RULES
from repro.analysis.rules.r1_traced_bake import TracedBakeRule
from repro.analysis.rules.r2_rng import RngDeterminismRule
from repro.analysis.rules.r3_deferred_sync import DeferredSyncRule
from repro.analysis.rules.r4_counter_lock import CounterLockRule
from repro.analysis.rules.r5_registry import (check_archs,
                                              check_density_families,
                                              check_registries,
                                              check_request_methods)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(ROOT, "tests", "analysis_fixtures")


def _lint_fixture(name, rule_cls):
    path = os.path.join(FIXTURES, name)
    with open(path) as f:
        src = f.read()
    # force=True: fixtures live outside the rules' real target paths
    return lint_source(src, path, rules=[rule_cls()], force=True)


# ------------------------------------------------------------ rules R1-R4

@pytest.mark.parametrize("rule_cls,bad,ok,n_bad", [
    (TracedBakeRule, "r1_bad.py", "r1_ok.py", 4),
    (RngDeterminismRule, "r2_bad.py", "r2_ok.py", 4),
    (DeferredSyncRule, "r3_bad.py", "r3_ok.py", 3),
    (CounterLockRule, "r4_bad.py", "r4_ok.py", 3),
])
def test_rule_fires_on_bad_and_not_on_ok(rule_cls, bad, ok, n_bad):
    vs = _lint_fixture(bad, rule_cls)
    assert len(vs) == n_bad, [str(v) for v in vs]
    assert all(v.rule == rule_cls.rule_id for v in vs)
    assert _lint_fixture(ok, rule_cls) == []


def test_violation_render_and_sorting():
    vs = _lint_fixture("r4_bad.py", CounterLockRule)
    assert all(":" in str(v) and f"[{v.rule}]" in str(v) for v in vs)
    assert [v.line for v in vs] == sorted(v.line for v in vs)


def test_noqa_contract_suppression():
    path = os.path.join(FIXTURES, "noqa.py")
    with open(path) as f:
        src = f.read()
    sup = suppressions(src)
    assert any("R2" in rules for rules in sup.values())
    vs = lint_source(src, path, rules=[RngDeterminismRule()], force=True)
    # one of the two identical violations is suppressed, one remains
    assert len(vs) == 1
    assert "still_bad" in src.splitlines()[vs[0].line - 1] or \
        vs[0].line > min(sup)


def test_repo_is_lint_clean():
    rep = run_report(roots=[os.path.join(ROOT, "src"),
                            os.path.join(ROOT, "benchmarks"),
                            os.path.join(ROOT, "examples")],
                     include_jaxpr=False)
    assert rep["lint"]["violations"] == [], rep["lint"]["violations"]
    assert rep["ok"]


# ------------------------------------------------------------------- R5

def test_live_registries_conform():
    assert check_registries() == []


def test_r5_flags_bad_factory_and_orphan_segment_method():
    vs = check_request_methods(
        {"bad": (lambda spec: None), "notcallable": 3},
        segment_methods={"ghost", "bad"})
    msgs = "\n".join(str(v) for v in vs)
    assert "positional" in msgs
    assert "**kw" in msgs
    assert "not callable" in msgs
    assert "ghost" in msgs


def test_r5_flags_nonconforming_density_family():
    import dataclasses

    from repro.core.density import DensityModel

    @dataclasses.dataclass(frozen=True)
    class Mystery(DensityModel):
        family = "other_name"

    vs = check_density_families(
        {"mystery": (7, Mystery), "notamodel": (8, int)},
        jax_occ={}, base_cls=DensityModel)
    msgs = "\n".join(str(v) for v in vs)
    assert "does not match its registry key" in msgs
    assert "not overridden" in msgs
    assert "occupancy builder" in msgs
    assert "not a DensityModel subclass" in msgs


def test_r5_flags_param_vector_length_mismatch():
    from repro.core.arch import ARCH_SPARSEMAP

    class Truncated:
        topology = ARCH_SPARSEMAP.topology

        def param_vector(self):
            return ARCH_SPARSEMAP.param_vector()[:-1]

    vs = check_archs({"trunc": Truncated()})
    assert any("kernel layout" in v.message for v in vs)
    assert check_archs({"sparsemap": ARCH_SPARSEMAP}) == []


# ------------------------------------------------------- jaxpr audit

def _cloud_arch():
    from repro.core.arch import as_arch
    return as_arch("cloud")


def test_jaxpr_audit_one_family_clean_and_hashed():
    from repro.analysis.jaxpr_audit import audit_families
    findings, hashes = audit_families(archs={"cloud": _cloud_arch()},
                                      include_scan=False)
    assert findings == [], [str(v) for v in findings]
    assert set(hashes) == {"cloud/u/eval", "cloud/s/eval"}
    assert all(len(h) == 16 for h in hashes.values())


def test_baked_constant_kernel_fails_family_sharing():
    import jax
    import jax.numpy as jnp

    from repro.analysis.jaxpr_audit import canonical_hash

    def make_baked(scale):
        const = jnp.full((4,), scale, jnp.float32)   # closure const

        def f(x):
            return x * const + float(scale)          # baked literal
        return f

    x = np.zeros(4, np.float32)
    h1 = canonical_hash(jax.make_jaxpr(make_baked(1.5))(x))
    h2 = canonical_hash(jax.make_jaxpr(make_baked(2.5))(x))
    assert h1 != h2      # the bug class the audit exists to catch

    def traced(x, s):     # the conforming twin: number rides as input
        return x * s

    g1 = jax.make_jaxpr(traced)(x, np.float32(1.5))
    g2 = jax.make_jaxpr(traced)(x, np.float32(2.5))
    assert canonical_hash(g1) == canonical_hash(g2)


def test_audit_flags_host_callback():
    import jax

    from repro.analysis.jaxpr_audit import audit_program

    def f(x):
        return jax.pure_callback(
            lambda a: a, jax.ShapeDtypeStruct(x.shape, x.dtype), x)

    closed = jax.make_jaxpr(f)(np.ones(3, np.float32))
    vs = audit_program(closed, "fixture")
    assert any("callback" in v.message for v in vs)


def test_scan_alias_device_put_not_flagged():
    import jax
    import jax.numpy as jnp

    from repro.analysis.jaxpr_audit import audit_program

    def f(xs):
        def body(c, x):
            # jnp.asarray on a traced value emits the alias-semantics
            # device_put the audit must NOT flag
            return c + jnp.asarray(x, jnp.float32), ()
        c, _ = jax.lax.scan(body, jnp.float32(0.0), xs)
        return c

    closed = jax.make_jaxpr(f)(np.ones(4, np.float32))
    assert audit_program(closed, "fixture") == []


# ------------------------------------- compile-ahead key <-> struct audit

def _small_model():
    from repro.configs.paper_workloads import by_name
    from repro.core.encoding import GenomeSpec
    from repro.core.jax_cost import JaxCostModel
    arch = _cloud_arch()
    return JaxCostModel(GenomeSpec(by_name("mm1"), arch), arch)


def test_check_aot_jobs_accepts_real_builders():
    from repro.analysis.jaxpr_audit import check_aot_jobs
    from repro.core import jax_cost
    from repro.core.direct_encoding import DirectValueSpec
    m = _small_model()
    dspec = DirectValueSpec(m.spec)
    jobs = [
        jax_cost.stacked_compile_job(m, 64),
        jax_cost.bcast_compile_job(m, 128),
        jax_cost.scan_compile_job(m, B=8, k=2, n_parents=2, n_elite=1,
                                  genes_per=2, T=3),
        jax_cost.scan_compile_job(m, B=8, k=2, n_parents=2, n_elite=1,
                                  genes_per=2, T=1, restart=8),
        jax_cost.direct_scan_compile_job(
            m, B=8, k=2, n_parents=2, n_elite=1, genes_per=2, T=2,
            direct_len=dspec.length, n_perm_codes=dspec.n_perm_codes),
    ]
    vs = check_aot_jobs(jobs)
    assert vs == [], [str(v) for v in vs]


def test_check_aot_job_rejects_mismatched_key():
    from repro.analysis.jaxpr_audit import check_aot_job
    from repro.core import jax_cost
    m = _small_model()
    key, fn, structs = jax_cost.stacked_compile_job(m, 64)
    wrong = key[:5] + (128,)          # claims 128 rows, structs say 64
    assert check_aot_job(wrong, fn, structs)
    skey, sfn, sstructs = jax_cost.scan_compile_job(
        m, B=8, k=2, n_parents=2, n_elite=1, genes_per=2, T=1)
    wrong2 = skey[:5] + (2,) + skey[6:]   # claims T=2, structs say T=1
    assert check_aot_job(wrong2, sfn, sstructs)
    assert check_aot_job(key[:4] + ("mystery", 64), fn, structs)


# ------------------------------------------- steady-state shape predictor

def test_steady_rows_predictions():
    from repro.configs.paper_workloads import by_name
    from repro.core.baselines import steady_rows
    from repro.core.encoding import GenomeSpec
    spec = GenomeSpec(by_name("mm1"))
    # budget 300 -> pop 24, elite 2: init pop + per-generation children
    assert steady_rows("sparsemap", spec, 300, 0) == (24, 22)
    # random_mapper's single 300-row chunk exhausts the budget
    assert steady_rows("random_mapper", spec, 300, 0) == ()
    assert steady_rows("random_mapper", spec, 900, 0) == (388,)
    assert steady_rows("random_mapper", spec, 1600, 0) == (512,)
    assert steady_rows("pso", spec, 300, 0) == (50,)
    # the translatable subset is data-dependent -> unpredictable
    assert steady_rows("standard_es", spec, 300, 0) is None


def test_compile_ahead_jobs_include_steady_stacked_shape():
    """The fleet predictor must emit the decayed steady-state stacked
    shape (sum of survivors' per-round batches) and every predicted key
    must be consistent with its arg structs (the jaxpr-audit check)."""
    from repro.analysis.jaxpr_audit import check_aot_jobs
    from repro.configs.paper_workloads import by_name
    from repro.core import search

    wl = by_name("mm1")
    tasks = [
        search.SearchTask(wl, "cloud", budget=300, seed=0,
                          method="sparsemap"),
        search.SearchTask(wl, "cloud", budget=300, seed=0,
                          method="random_mapper"),
    ]
    ms = search.MultiSearch(tasks, stack_batches=True,
                            compile_ahead=False)
    jobs = ms._compile_ahead_jobs(ms._task_infos())
    assert check_aot_jobs(jobs) == []
    stacked = [j[0] for j in jobs if j[0][4] == "stacked"]
    # round-1: calib rows (predicted); steady: one sparsemap task's
    # init-pop/children rows -> pad bucket 64
    assert any(k[5] == 64 for k in stacked), stacked


# ----------------------------------------------------------- module gate

@pytest.mark.slow
def test_module_gate_exits_zero():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--skip-jaxpr"],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "lint 0 violation(s)" in proc.stderr


def test_all_rules_registered():
    ids = [r.rule_id for r in ALL_RULES]
    assert ids == ["R1", "R2", "R3", "R4"]
    assert Violation("R9", "x.py", 3, "m").rule == "R9"
