"""The declarative arch frontend (repro.core.arch_dsl): unit parsers,
exact lowering to hand-built ArchSpecs, the paper topology re-derived
through the DSL bit-identical to the pinned pre-refactor goldens, and
the error surface."""
import os

import numpy as np
import pytest

from repro.core import accel
from repro.core.arch import (ARCH_SPARSEMAP, ArchSpec, NoCSpec,
                             StorageLevel, arch_from_platform)
from repro.core.arch_dsl import (compile_arch, parse_bandwidth,
                                 parse_capacity, parse_frequency,
                                 sparsemap_desc)
from repro.core.encoding import GenomeSpec
from repro.core.jax_cost import JaxCostModel
from repro.core.workload import spmm

# ------------------------------------------------------------- parsers


def test_capacity_strings_are_binary():
    assert parse_capacity("512B") == 512
    assert parse_capacity("256KB") == 256 * 1024
    assert parse_capacity("64MB") == 64 * 1024 ** 2
    assert parse_capacity("2GB") == 2 * 1024 ** 3
    assert parse_capacity(108 * 1024) == 108 * 1024
    with pytest.raises(ValueError):
        parse_capacity("256 potatoes")
    with pytest.raises(ValueError):
        parse_capacity("KB")


def test_bandwidth_strings_are_decimal_rates_per_clock():
    # the configs' own spelling of Table II's starved edge DRAM:
    # 16 MB/s at a 1 GHz clock = 16e6 / 1e9 bytes per cycle, exactly
    assert parse_bandwidth("16MB/s", 1.0e9) == 16e6 / 1.0e9
    assert parse_bandwidth("128GB/s", 1.0e9) == 128e9 / 1.0e9
    assert parse_bandwidth("900GB/s", 2.0e9) == 900e9 / 2.0e9
    assert parse_bandwidth(0.016, 1.0e9) == 0.016   # already per-cycle
    with pytest.raises(ValueError):
        parse_bandwidth("16MB", 1.0e9)              # rate needs /s


def test_frequency_strings():
    assert parse_frequency("1GHz") == 1e9
    assert parse_frequency("200MHz") == 2e8
    assert parse_frequency(5e8) == 5e8


# ------------------------------------------------------------ lowering


def test_compiled_arch_equals_hand_built():
    """DSL lowering is exact: the declarative description of the 4-store
    clustered chip compares equal (content hash and all) to the
    hand-assembled ArchSpec."""
    hand = ArchSpec("dsl_twin", (
        StorageLevel("dram"),
        StorageLevel("glb", capacity_bytes=64 * 1024 * 1024,
                     fill_energy=(("dram", (100.0,)),), sg_site="L2",
                     fill_bandwidth_bytes_per_cycle=128e9 / 1.0e9),
        StorageLevel("cbuf", capacity_bytes=1024 * 1024,
                     fill_energy=(("glb", (15.0, 0.3)),),
                     fanout=16, sg_site="L3"),
        StorageLevel("reg",
                     fill_energy=(("cbuf", (0.5,)), ("reg", (0.05,))),
                     fanout=64),
    ), e_mac=0.8)
    dsl = compile_arch({
        "name": "dsl_twin",
        "levels": [
            {"name": "dram"},
            {"name": "glb", "capacity": "64MB",
             "energy": [["dram", [100.0]]],
             "sg_site": "L2", "bandwidth": "128GB/s"},
            {"name": "cbuf", "capacity": "1MB",
             "energy": [["glb", [15.0, 0.3]]],
             "fanout": 16, "sg_site": "L3"},
            {"name": "reg",
             "energy": [["cbuf", [0.5]], ["reg", [0.05]]],
             "fanout": 64},
        ],
    })
    assert dsl == hand
    assert hash(dsl) == hash(hand)
    np.testing.assert_array_equal(dsl.param_vector(),
                                  hand.param_vector())


def test_all_none_schemes_normalize_to_booleans():
    """'all'/'none' spellings lower to the plain boolean NoCSpec, so a
    desc-built arch is indistinguishable from a hand-built one."""
    dsl = compile_arch({
        "name": "norm", "levels": [
            {"name": "dram"},
            {"name": "glb", "energy": [["dram", [100.0]]],
             "fanout": 4,
             "noc": {"multicast": "none", "reduction": "all"}},
        ]})
    assert dsl.levels[1].noc == NoCSpec(multicast=False, reduction=True)


def test_mesh_fanout_resolves_row_col_discounts():
    """[rows, cols] mesh: total fanout rows*cols; a row-wise bus serves
    `cols` instances per copy, a column-wise one `rows`."""
    dsl = compile_arch({
        "name": "mesh", "levels": [
            {"name": "dram"},
            {"name": "pe", "energy": [["dram", [10.0]]],
             "fanout": [12, 14],
             "noc": {"multicast": "row", "reduction": "col"}},
        ]})
    lv = dsl.levels[1]
    assert lv.fanout == 12 * 14
    assert lv.noc == NoCSpec(multicast="row", reduction="col",
                             multicast_fanout=14.0, reduction_fanout=12.0)


def test_explicit_scheme_fanout_pair():
    dsl = compile_arch({
        "name": "pair", "levels": [
            {"name": "dram"},
            {"name": "pe", "energy": [["dram", [10.0]]],
             "fanout": 64,
             "noc": {"reduction": ["cluster", 8]}},
        ]})
    assert dsl.levels[1].noc == NoCSpec(
        reduction="cluster", reduction_fanout=8.0)


# ------------------------------------------------- the paper topology


def test_sparsemap_desc_equals_hand_built_on_all_platforms():
    for name, plat in accel.PLATFORMS.items():
        assert compile_arch(sparsemap_desc(name)) == \
            arch_from_platform(plat), name
    assert compile_arch(sparsemap_desc("cloud", name="sparsemap")) == \
        ARCH_SPARSEMAP


GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "arch_sparsemap_golden.npz")


def test_dsl_rebuilt_paper_arch_matches_goldens_bit_for_bit():
    """The acceptance pin: ARCH_SPARSEMAP rebuilt through the frontend
    reproduces the pre-refactor golden kernel outputs EXACTLY (captured
    genome batches, one workload x all platforms)."""
    g = np.load(GOLDEN)
    wl = spmm("mm_small", 32, 64, 48, 0.2, 0.5)
    for pname in accel.PLATFORMS:
        arch = compile_arch(sparsemap_desc(pname))
        key = f"{wl.name}:{pname}"
        res = JaxCostModel(GenomeSpec(wl, arch=arch), arch)(
            g[f"{key}:genomes"])
        np.testing.assert_array_equal(
            g[f"{key}:jax_valid"], np.asarray(res["valid"]),
            err_msg=f"{key}: valid drifted through the DSL")
        for fld, out_key in (("jax_edp", "edp"),
                             ("jax_energy", "energy_pj"),
                             ("jax_cycles", "cycles")):
            np.testing.assert_array_equal(
                g[f"{key}:{fld}"], np.asarray(res[out_key]),
                err_msg=f"{key}: {out_key} not bit-identical via DSL")


# --------------------------------------------------------------- errors


@pytest.mark.parametrize("desc, fragment", [
    ({"levels": []}, "needs a 'name'"),
    ({"name": "x"}, "needs a 'levels'"),
    ({"name": "x", "levels": [{"name": "d"}], "junk": 1},
     "unknown description keys"),
    ({"name": "x", "levels": [{"name": "d", "typo_key": 1},
                              {"name": "g",
                               "energy": [["d", [1.0]]]}]},
     "unknown keys"),
    ({"name": "x", "levels": [{"name": "d", "capacity": "1KB"},
                              {"name": "g",
                               "energy": [["d", [1.0]]]}]},
     "outermost"),
    ({"name": "x", "levels": [{"name": "d"},
                              {"name": "g", "energy": 3.0}]},
     "energy must be ordered"),
    ({"name": "x", "levels": [{"name": "d"},
                              {"name": "g", "energy": [["d", [1.0]]],
                               "fanout": [2, 3, 4]}]},
     "[rows, cols]"),
    ({"name": "x", "levels": [{"name": "d"},
                              {"name": "g", "energy": [["d", [1.0]]],
                               "noc": {"multicast": "row"}}]},
     "mesh"),
    ({"name": "x", "levels": [{"name": "d"},
                              {"name": "g", "energy": [["d", [1.0]]],
                               "noc": {"multicast": "cluster"}}]},
     "explicit discount"),
    ({"name": "x", "levels": [{"name": "d"},
                              {"name": "g", "energy": [["d", [1.0]]],
                               "noc": {"reduction": ["all", 4]}}]},
     "takes no fanout"),
    ({"name": "x", "levels": [{"name": "d"},
                              {"name": "g", "energy": [["d", [1.0]]],
                               "noc": {"wrong": True}}]},
     "unknown noc keys"),
])
def test_description_errors(desc, fragment):
    with pytest.raises(ValueError) as ei:
        compile_arch(desc)
    assert fragment in str(ei.value)
