"""Per-level word widths + NoC multicast/reduction (the ArchSpec axis
added on top of PR 3): numpy fills/cost semantics, topology fingerprints
and compilation sharing, the pinned CostReport goldens for the
non-default archs, and the end-to-end acceptance sweeps on the
systolic-mesh and quantized-edge topologies."""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.configs.archs import (CLUSTER_CLOUD, DSTC_LIKE, EYERISS_LIKE,
                                 MAPLE_EDGE, QUANT_EDGE, SIGMA_LIKE,
                                 SYSTOLIC_MESH)
from repro.core import search
from repro.core.arch import (ARCH_SPARSEMAP, ArchSpec, NoCSpec,
                             StorageLevel)
from repro.core.baselines import (fixed_mapping_genes_for_arch,
                                  manual_sparse_genes)
from repro.core.cost_model import evaluate
from repro.core.encoding import GenomeSpec
from repro.core.jax_cost import JaxCostModel
from repro.core.mapping import Mapping, balanced_mapping_for_arch
from repro.core.sparse import SG_GATE_BOTH
from repro.core.workload import spmm


def _three_store(noc: NoCSpec, name: str) -> ArchSpec:
    return ArchSpec(name, (
        StorageLevel("dram"),
        StorageLevel("glb", capacity_bytes=256 * 1024,
                     fill_energy=(("dram", (100.0,)),), sg_site="L2"),
        StorageLevel("reg", fill_energy=(("glb", (3.0,)),),
                     fanout=4, noc=noc),
    ))


def _mapping(arch: ArchSpec, wl, spatial_dim: str) -> Mapping:
    """All three dims are 4; ``spatial_dim`` unrolls on L2_S, the other
    two stay on L1_T."""
    rest = [d for d in wl.dim_order if d != spatial_dim]
    factors = ({d: 4 for d in rest}, {}, {spatial_dim: 4})
    perms = tuple(tuple(wl.dim_order) for _ in range(3))
    return Mapping(workload=wl, factors=factors, perms=perms, arch=arch)


# ------------------------------------------------------------- NoC fills


def test_unicast_noc_multiplies_irrelevant_spatial_read_traffic():
    """An irrelevant spatial loop is free under multicast (one copy
    serves all instances) and costs one copy per instance without it —
    wherever it sits in the nest, including the reuse suffix."""
    wl = spmm("noc_wl", 4, 4, 4, 0.5, 0.5)
    mcast = _three_store(NoCSpec(), "noc_mcast")
    ucast = _three_store(NoCSpec(multicast=False), "noc_ucast")
    # N unrolled spatially: irrelevant to P[M,K], relevant to Q and Z
    m_m = _mapping(mcast, wl, "N")
    m_u = _mapping(ucast, wl, "N")
    assert m_u.fills("reg", "P") == 4 * m_m.fills("reg", "P")
    assert m_u.fills("reg", "Q") == m_m.fills("reg", "Q")
    assert m_u.fills("reg", "Z") == m_m.fills("reg", "Z")


def test_no_reduction_noc_multiplies_partial_output_traffic():
    """Spatially-unrolled contraction (K on L2_S): with in-network
    reduction one reduced result crosses the edge; without it every
    instance's partials cross.  Reads are untouched by the reduction
    flag."""
    wl = spmm("noc_wl", 4, 4, 4, 0.5, 0.5)
    tree = _three_store(NoCSpec(), "noc_tree")
    flat = _three_store(NoCSpec(reduction=False), "noc_flat")
    m_t = _mapping(tree, wl, "K")
    m_f = _mapping(flat, wl, "K")
    assert m_f.fills("reg", "Z") == 4 * m_t.fills("reg", "Z")
    assert m_f.fills("reg", "P") == m_t.fills("reg", "P")
    assert m_f.fills("reg", "Q") == m_t.fills("reg", "Q")


def test_fractional_noc_interpolates_between_all_and_none():
    """A fractional scheme discounts irrelevant-spatial read traffic by
    ``max(S / fanout, 1)``: fanout 1 reproduces unicast, fanout >= S
    reproduces full multicast, and in between the edge carries S/fanout
    copies.  Same story for cluster-local reduction on the output."""
    wl = spmm("frac_wl", 4, 4, 4, 0.5, 0.5)
    mcast = _three_store(NoCSpec(), "frac_mc_all")
    ucast = _three_store(NoCSpec(multicast=False), "frac_mc_none")
    half = _three_store(NoCSpec(multicast="row", multicast_fanout=2.0),
                        "frac_mc_2")
    wide = _three_store(NoCSpec(multicast="row", multicast_fanout=8.0),
                        "frac_mc_8")
    base = _mapping(mcast, wl, "N").fills("reg", "P")
    assert _mapping(ucast, wl, "N").fills("reg", "P") == 4 * base
    assert _mapping(half, wl, "N").fills("reg", "P") == 2 * base
    assert _mapping(wide, wl, "N").fills("reg", "P") == base
    # relevant-tensor fills never see the discount
    assert _mapping(half, wl, "N").fills("reg", "Q") == \
        _mapping(mcast, wl, "N").fills("reg", "Q")
    tree = _three_store(NoCSpec(), "frac_red_all")
    cluster = _three_store(
        NoCSpec(reduction="cluster", reduction_fanout=2.0), "frac_red_2")
    assert _mapping(cluster, wl, "K").fills("reg", "Z") == \
        2 * _mapping(tree, wl, "K").fills("reg", "Z")


def test_fractional_noc_validation():
    """Fractional schemes need a positive fanout; all/none take none."""
    with pytest.raises(ValueError):
        NoCSpec(multicast="row")
    with pytest.raises(ValueError):
        NoCSpec(multicast="row", multicast_fanout=0.0)
    with pytest.raises(ValueError):
        NoCSpec(multicast=True, multicast_fanout=4.0)
    with pytest.raises(ValueError):
        NoCSpec(reduction="")


def test_fractional_noc_family_shares_one_compilation():
    """The scheme is structural, the discount fanout is traced: two
    same-scheme archs with different fanouts share the topology, the
    signature AND the compiled kernel object; labels don't split either.
    The discount rides in the param-vector tail."""
    a = _three_store(NoCSpec(multicast="row", multicast_fanout=2.0),
                     "frac_fam_a")
    b = _three_store(NoCSpec(multicast="bus", multicast_fanout=7.0),
                     "frac_fam_b")
    c = _three_store(NoCSpec(), "frac_fam_c")
    assert a.topology == b.topology
    assert a.topology != c.topology
    assert a.param_vector()[-1] == 2.0
    assert b.param_vector()[-1] == 7.0
    wl = spmm("frac_sig", 16, 16, 16, 0.5, 0.5)
    m_a = JaxCostModel(GenomeSpec(wl, arch=a), a)
    m_b = JaxCostModel(GenomeSpec(wl, arch=b), b)
    assert m_a.signature == m_b.signature
    assert m_a._fn is m_b._fn
    assert m_a.signature != JaxCostModel(GenomeSpec(wl, arch=c), c).signature


def test_default_noc_is_bitwise_neutral():
    """An explicitly-default NoCSpec leaves the topology and all numbers
    of the paper arch untouched."""
    spec = ArchSpec("explicit_noc", tuple(
        lv if k == 0 else dataclasses.replace(lv, noc=NoCSpec(True, True))
        for k, lv in enumerate(ARCH_SPARSEMAP.levels)))
    assert spec.topology == ARCH_SPARSEMAP.topology
    np.testing.assert_array_equal(spec.param_vector(),
                                  ARCH_SPARSEMAP.param_vector())


# ---------------------------------------------------------- word widths


def _quant_twin(word_bytes):
    lv = [dataclasses.replace(l, word_bytes=word_bytes) if k > 0 else l
          for k, l in enumerate(ARCH_SPARSEMAP.levels)]
    return ArchSpec(f"wb{word_bytes:g}", tuple(lv),
                    e_mac=ARCH_SPARSEMAP.e_mac,
                    clock_hz=ARCH_SPARSEMAP.clock_hz)


def test_halving_word_width_halves_uncompressed_bytes():
    """With uncompressed formats every byte count is linear in the word
    width: occupancies, traffic, DRAM cycles and edge energies all halve
    exactly at 1-byte words; MAC energy and compute cycles don't move."""
    wl = spmm("wb_wl", 32, 64, 48, 0.2, 0.5)
    wide, narrow = _quant_twin(2.0), _quant_twin(1.0)
    rep_w, rep_n = [], []
    for arch in (wide, narrow):
        spec = GenomeSpec(wl, arch=arch)
        g = np.zeros(spec.length, dtype=np.int64)
        for k, v in fixed_mapping_genes_for_arch(spec, arch).items():
            g[k] = v
        rep = evaluate(spec.decode(g), arch)
        assert rep.valid, rep.reason
        (rep_w if arch is wide else rep_n).append(rep)
    rw, rn = rep_w[0], rep_n[0]
    for store, occ in rw.occupancy_bytes.items():
        assert rn.occupancy_bytes[store] == pytest.approx(occ / 2)
    for key, b in rw.traffic_bytes.items():
        assert rn.traffic_bytes[key] == pytest.approx(b / 2)
    assert rn.dram_cycles == pytest.approx(rw.dram_cycles / 2)
    assert rn.compute_cycles == rw.compute_cycles
    assert rn.energy_breakdown["mac"] == rw.energy_breakdown["mac"]
    for grp in ("dram", "glb", "pebuf", "reg"):
        assert rn.energy_breakdown[grp] == \
            pytest.approx(rw.energy_breakdown[grp] / 2)


def test_metadata_bits_do_not_scale_with_word_width():
    """Compression metadata is width-independent, so at narrower words
    the compressed-to-dense byte ratio is WORSE (compression pays off
    later) — the quantized-edge design story."""
    from repro.core.sparse import TensorFormat, effective_bytes
    fmt = TensorFormat("P", formats=(1,), fiber_lens=(64,))   # bitmask
    dense2 = effective_bytes(fmt, 0.1, 64, 2.0) / (64 * 2.0)
    dense1 = effective_bytes(fmt, 0.1, 64, 1.0) / (64 * 1.0)
    assert dense1 > dense2


def test_word_width_topology_and_compilation_sharing():
    """Custom widths split the topology from the default-width kernel
    (the default stays bit-identical), but a FAMILY of custom-width
    specs shares one topology/compilation — widths are traced numbers."""
    assert ARCH_SPARSEMAP.topology.uniform_word_bytes
    q1, q2 = _quant_twin(1.0), _quant_twin(0.5)
    assert not q1.topology.uniform_word_bytes
    assert q1.topology != ARCH_SPARSEMAP.topology
    assert q1.topology == q2.topology
    wl = spmm("wb_sig", 16, 16, 16, 0.5, 0.5)
    m1 = JaxCostModel(GenomeSpec(wl, arch=q1), q1)
    m2 = JaxCostModel(GenomeSpec(wl, arch=q2), q2)
    assert m1.signature == m2.signature
    assert m1.signature != \
        JaxCostModel(GenomeSpec(wl), ARCH_SPARSEMAP).signature
    # param vector tail carries the per-edge widths
    np.testing.assert_allclose(q1.param_vector()[-q1.n_edges:],
                               [1.0] * q1.n_edges)


def test_word_bytes_validation():
    with pytest.raises(ValueError):
        ArchSpec("bad_wb", (
            StorageLevel("dram"),
            StorageLevel("glb", word_bytes=0.0,
                         fill_energy=(("dram", (100.0,)),)),
        ))


# ------------------------------------- new archs: oracle-kernel + e2e


@pytest.mark.parametrize("arch", [SYSTOLIC_MESH, QUANT_EDGE],
                         ids=lambda a: a.name)
def test_new_arch_default_design_oracle_matches_kernel(arch):
    """The engineer-default design is valid on both new topologies and
    the generic numpy oracle agrees with the generic kernel on it (the
    capacity-aware fallback makes this non-vacuous)."""
    wl = spmm("nw_probe", 32, 64, 48, 0.2, 0.5)
    spec = GenomeSpec(wl, arch=arch)
    g = np.zeros(spec.length, dtype=np.int64)
    for k, v in fixed_mapping_genes_for_arch(spec, arch).items():
        g[k] = v
    rep = evaluate(spec.decode(g), arch)
    assert rep.valid, f"{arch.name}: {rep.reason}"
    out = JaxCostModel(spec, arch)(g[None, :])
    assert bool(out["valid"][0]), arch.name
    lg = np.log10(rep.edp)
    assert abs(lg - out["log10_edp"][0]) <= 2e-3 * max(abs(lg), 1)


@pytest.mark.parametrize("archname", ["systolic_mesh", "quant_edge"])
def test_method_sweep_end_to_end_on_noc_word_archs(archname):
    """Acceptance criterion: the systolic-mesh and 1-byte-word
    topologies search end-to-end through the mega-batched sweep at 1.0
    dispatches/round per signature."""
    wls = [spmm(f"{archname}_a", 32, 64, 48, 0.2, 0.5),
           spmm(f"{archname}_b", 48, 32, 64, 0.4, 0.3)]
    stats: dict = {}
    grid = search.run_method_sweep(
        ["sparsemap", "random_mapper"], wls, archname,
        budget=200, seed=0, stats_out=stats)
    arch = search._platform(archname)
    for m in grid:
        for w, res in grid[m].items():
            assert res.evals >= 200
    assert len(stats["signatures"]) == 1
    assert stats["signatures"][0][2] == arch.topology.fingerprint
    assert stats["dispatches"] == stats["rounds"]


def test_sparsemap_finds_valid_designs_on_noc_word_archs():
    wl = spmm("nw_valid", 32, 64, 48, 0.2, 0.5)
    for archname in ("systolic_mesh", "quant_edge"):
        res = search.run("sparsemap", wl, archname, budget=800, seed=0)
        assert np.isfinite(res.best_edp), archname
        rep = search.report_best(wl, archname, res)
        assert rep is not None and rep.valid
        assert rep.edp == pytest.approx(res.best_edp, rel=1e-3)


def test_registered_topologies_are_distinct():
    fps = {a.topology.fingerprint
           for a in (ARCH_SPARSEMAP, MAPLE_EDGE, CLUSTER_CLOUD,
                     SYSTOLIC_MESH, QUANT_EDGE, EYERISS_LIKE,
                     SIGMA_LIKE, DSTC_LIKE)}
    assert len(fps) == 8


# ------------------------------------------- capacity-aware fallback


def test_fallback_mapping_is_valid_on_cluster_cloud_large_workload():
    """Regression: the fixed greedy caps (16/8/64) overflow
    cluster_cloud's 1 MB cluster buffer on large workloads (a 64-per-dim
    staging tile at L3_T alone holds multi-MB P tiles); capacity-aware
    sizing must keep the fallback ``evaluate``-valid."""
    wl = spmm("cc_big", 512, 4096, 512, 0.1, 0.1)
    for arch in (CLUSTER_CLOUD, ARCH_SPARSEMAP, MAPLE_EDGE):
        spec = GenomeSpec(wl, arch=arch)
        g = np.zeros(spec.length, dtype=np.int64)
        for k, v in fixed_mapping_genes_for_arch(spec, arch).items():
            g[k] = v
        rep = evaluate(spec.decode(g), arch)
        assert rep.valid, f"{arch.name}: {rep.reason}"


def test_fallback_mapping_is_valid_on_tiny_buffers():
    """A deliberately starved variant (4 KB GLB, 128 B PE buffers): every
    prime the greedy caps would place on-chip must flow outward
    instead."""
    tiny = ArchSpec("tiny_buffers", (
        StorageLevel("dram"),
        StorageLevel("glb", capacity_bytes=4 * 1024,
                     fill_energy=(("dram", (100.0,)),), sg_site="L2"),
        StorageLevel("pebuf", capacity_bytes=128,
                     fill_energy=(("glb", (3.0, 0.3)),),
                     fanout=16, sg_site="L3"),
        StorageLevel("reg", fill_energy=(("pebuf", (0.6,)),), fanout=4),
    ))
    wl = spmm("tiny_wl", 128, 256, 128, 0.3, 0.3)
    spec = GenomeSpec(wl, arch=tiny)
    g = np.zeros(spec.length, dtype=np.int64)
    for k, v in fixed_mapping_genes_for_arch(spec, tiny).items():
        g[k] = v
    rep = evaluate(spec.decode(g), tiny)
    assert rep.valid, rep.reason
    # ... and the mapping still parallelizes where capacity allows
    mp = balanced_mapping_for_arch(wl, tiny)
    assert any(mp.spatial_fanout(l) > 1 for l in tiny.spatial_levels)


def test_fallback_unchanged_where_capacity_never_binds():
    """On the paper platforms the capacity guard must be a no-op: the
    golden fixed-seed searches depend on these exact seed mappings."""
    from repro.core import accel
    from repro.core.arch import arch_from_platform
    wl = spmm("np_probe", 128, 1024, 128, 0.006, 0.006)
    arch = arch_from_platform(accel.CLOUD)
    mp = balanced_mapping_for_arch(wl, arch)
    # the documented greedy outcome: 16-wide contraction dot product,
    # 16x16 output parallelism, 8-per-dim local tiles
    assert mp.factors[4].get("K", 1) == 16
    assert mp.factors[2].get("M", 1) == 16
    assert mp.factors[2].get("N", 1) == 16
    assert mp.factors[3].get("M", 1) == 8


# ----------------------------------------------- pinned arch goldens


GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "arch_reports_golden.json")


def test_nondefault_arch_cost_reports_match_goldens():
    """CostReport energy_breakdown / occupancy_bytes / cycles for
    maple_edge, cluster_cloud and the zoo entries, pinned as float hex
    on deterministic designs (engineer default, the manual sparse
    strategy, gate-both)."""
    from repro.core.workload import spconv
    gold = json.load(open(GOLDEN))
    wls = {
        "mm_small": spmm("mm_small", 32, 64, 48, 0.2, 0.5),
        "mm_sparse": spmm("mm_sparse", 128, 1024, 128, 0.006, 0.006),
        "conv": spconv("conv", 64, 32, 32, 256, 1, 1, 0.45, 0.252),
    }
    seen = 0
    for arch in (MAPLE_EDGE, CLUSTER_CLOUD, EYERISS_LIKE, SIGMA_LIKE,
                 DSTC_LIKE):
        for wname, wl in wls.items():
            spec = GenomeSpec(wl, arch=arch)
            g0 = np.zeros(spec.length, dtype=np.int64)
            for k, v in fixed_mapping_genes_for_arch(spec, arch).items():
                g0[k] = v
            g1 = g0.copy()
            for k, v in manual_sparse_genes(spec).items():
                g1[k] = v
            g2 = g0.copy()
            g2[spec.segments["sg"].stop - 1] = SG_GATE_BOTH
            for gname, g in (("default", g0), ("manual_sparse", g1),
                             ("gate_both", g2)):
                exp = gold[f"{arch.name}:{wname}:{gname}"]
                rep = evaluate(spec.decode(g), arch)
                assert rep.valid == exp["valid"], \
                    f"{arch.name}:{wname}:{gname}: {rep.reason}"
                assert rep.reason == exp["reason"]
                for bkey, hexval in exp["energy_breakdown"].items():
                    assert rep.energy_breakdown[bkey].hex() == hexval, \
                        f"{arch.name}:{wname}:{gname}: {bkey} drifted"
                for skey, hexval in exp["occupancy_bytes"].items():
                    assert rep.occupancy_bytes[skey].hex() == hexval
                if rep.valid:
                    assert rep.cycles.hex() == exp["cycles"]
                    assert rep.energy_pj.hex() == exp["energy_pj"]
                    assert rep.edp.hex() == exp["edp"]
                seen += 1
    assert seen == len(gold) == 45
