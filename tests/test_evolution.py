"""ES engine tests: sensitivity, HSHI, operators, end-to-end improvement."""
import numpy as np
import pytest

from repro.core import search
from repro.core.evolution import (ESConfig, annealing_p_high, crossover,
                                  evolve, lhs_init, mutate)
from repro.core.sensitivity import calibrate
from repro.core.workload import spmm

WL = spmm("mm_es", 32, 64, 48, 0.2, 0.5)


@pytest.fixture(scope="module")
def env():
    spec, ev = search.get_evaluator(WL, "cloud")
    return spec, ev


def test_annealing_schedule():
    """Eq. (6): P_h decreasing over generations, 0.8 at g=0, 0 at g=G."""
    vals = [annealing_p_high(g, 100) for g in range(0, 101, 10)]
    assert vals[0] == pytest.approx(0.8)
    assert vals[-1] == pytest.approx(0.0)
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_sensitivity_calibration(env):
    spec, ev = env
    rng = np.random.default_rng(0)
    sens = calibrate(spec, ev, rng, n_contexts=3, n_samples=8)
    assert sens.scores.shape == (spec.length,)
    assert sens.high_mask.any()
    assert not sens.high_mask.all()
    assert (sens.scores >= 0).all()
    # threshold is the 3/4-range rule
    smax, smin = sens.scores.max(), sens.scores.min()
    assert sens.threshold == pytest.approx(0.75 * (smax - smin) + smin)


def test_high_segments_contiguous(env):
    spec, ev = env
    rng = np.random.default_rng(0)
    sens = calibrate(spec, ev, rng, n_contexts=2, n_samples=6)
    for a, b in sens.high_segments():
        assert b > a
        assert sens.high_mask[a:b].all()


def test_crossover_respects_high_segments(env):
    spec, ev = env
    rng = np.random.default_rng(0)
    sens = calibrate(spec, ev, rng, n_contexts=2, n_samples=6)
    parents = np.stack([np.zeros(spec.length, dtype=np.int64),
                        np.ones(spec.length, dtype=np.int64)])
    kids = crossover(parents, 64, spec, rng, sens)
    # no kid may switch parent INSIDE a high-sensitivity segment
    for kid in kids:
        for a, b in sens.high_segments():
            seg = kid[a:b]
            assert (seg == seg[0]).all(), "high-sens segment fragmented"


def test_mutation_stays_in_range(env):
    spec, ev = env
    rng = np.random.default_rng(0)
    g = spec.random_genomes(rng, 32)
    m = mutate(g, spec, rng, p_mut=1.0, genes_per=4, sens=None, p_high=0.5)
    assert (m >= 0).all() and (m < spec.gene_ub[None, :]).all()
    assert (m != g).any()


def test_lhs_init_covers_strata(env):
    spec, ev = env
    rng = np.random.default_rng(0)
    pop = lhs_init(spec, rng, 50)
    assert pop.shape == (50, spec.length)
    assert (pop >= 0).all() and (pop < spec.gene_ub[None, :]).all()
    # stratification: perm gene should hit most of its 6 values
    pg = pop[:, spec.segments["perm"].start]
    assert len(np.unique(pg)) >= 5


def test_sparsemap_beats_random_and_finds_valid(env):
    spec, ev = env
    res = evolve(spec, ev, ESConfig(budget=2500, seed=0))
    assert np.isfinite(res.best_edp)
    assert res.valid_evals > 0
    assert res.evals <= 2500
    assert len(res.history) == res.evals
    # best-so-far curve is monotonically non-increasing
    assert (res.history[1:] <= res.history[:-1]).all()
    # better than pure random sampling at the same budget
    rnd = search.run("random_mapper", WL, "cloud", budget=2500, seed=0)
    assert res.best_edp <= rnd.best_edp * 5     # same order or better


def test_fixed_genes_respected(env):
    spec, ev = env
    sg = spec.segments["sg"]
    fixed = {sg.start: 0, sg.start + 1: 0, sg.start + 2: 3}
    res = evolve(spec, ev, ESConfig(budget=600, seed=1, use_hshi=False,
                                    use_custom_ops=False),
                 fixed_genes=fixed)
    if res.best_genome is not None:
        for k, v in fixed.items():
            assert res.best_genome[k] == v


def test_seeds_injected(env):
    spec, ev = env
    seed_g = spec.random_genomes(np.random.default_rng(5), 1)
    res = evolve(spec, ev, ESConfig(budget=300, seed=2, use_hshi=False,
                                    use_custom_ops=False),
                 seeds=seed_g)
    assert res.evals <= 300
