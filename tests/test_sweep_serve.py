"""The sweep server tentpole, from the engine up:

* mid-run admission: a query admitted into a RUNNING fleet joins its
  signature group's mega-batch — 1.0 dispatches/round and NO extra XLA
  compilations vs the single-client fleet,
* checkpointed populations: save the in-flight fleet at round r, kill
  it, restore — the resumed run's final best-EDP / history is
  BIT-IDENTICAL to the uninterrupted run at fixed seeds,
* server crash recovery via the supervisor (injected step failure),
* warm-start library hit/miss semantics (and the methods that refuse
  runtime kwargs),
* slow tier: the full subprocess smoke — server CLI + two concurrent
  same-signature clients + one different-topology client, coalescing
  asserted via the stats op, clean shutdown with exit code 0.
"""
import json
import os
import socket
import subprocess
import sys
import tempfile
import threading
import time

import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt_lib
from repro.core import jax_cost
from repro.core.search import FleetConfig, MultiSearch, SearchTask
from repro.core.workload import spmm
from repro.launch import sweep_serve
from repro.launch.sweep_serve import (GenomeLibrary, SweepServer,
                                      library_key, pack_fleet,
                                      restore_fleet, submit)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BUDGET = 800
CFG = FleetConfig(stack_batches=True, device_rounds=1)


def task(name="wa", m=16, seed=5, budget=BUDGET, method="sparsemap",
         platform="cloud"):
    return SearchTask(spmm(name, m, 16, 8, 0.5, 0.5), platform,
                      budget=budget, seed=seed, method=method)


# ------------------------------------------------- mid-run admission


def test_admission_coalesces_into_shared_mega_batch():
    """Admit a same-signature task mid-run: from then on the fleet must
    keep issuing ONE device dispatch per round (the shared mega-batch),
    and the whole run must compile NO MORE XLA programs than a fleet
    that started with both tasks (admission itself is compile-free; the
    only new shape is the bigger mega-batch, which the from-start fleet
    pays for too)."""
    cfg = FleetConfig(stack_batches=True, device_rounds=1,
                      compile_ahead=False)   # deterministic counts

    jax_cost.clear_compile_cache()
    MultiSearch([task("a1", seed=5), task("a2", seed=6)], cfg).run()
    compiles_from_start = jax_cost.compilation_count()

    jax_cost.clear_compile_cache()
    ms = MultiSearch([task("a1", seed=5)], cfg)
    ms.start()
    for _ in range(3):
        ms.step()
    d0 = ms.stats_snapshot()["dispatches"]
    r0 = ms.stats_snapshot()["rounds"]
    name = ms.admit(task("a2", seed=6))
    assert name == "a2@cloud"
    while ms.step():
        pass
    results = ms.finish()
    st = ms.stats
    # every post-admission round is one shared dispatch
    assert (st["dispatches"] - d0) == (st["rounds"] - r0), st
    assert jax_cost.compilation_count() <= compiles_from_start
    assert len(st["signatures"]) == 1
    assert results["a2@cloud"].best_edp == \
        MultiSearch([task("a2", seed=6)], cfg).run()["a2@cloud"].best_edp


def test_admitted_task_result_matches_solo_run():
    """Coalescing must not perturb trajectories: a task admitted at
    round 3 finishes bit-identical to the same task run alone."""
    solo = MultiSearch([task("adm", seed=9)], CFG).run()["adm@cloud"]
    ms = MultiSearch([task("host_t", seed=5)], CFG)
    ms.start()
    for _ in range(3):
        ms.step()
    ms.admit(task("adm", seed=9))
    while ms.step():
        pass
    joined = ms.finish()["adm@cloud"]
    assert joined.best_edp == solo.best_edp
    assert np.array_equal(joined.history, solo.history)


def test_pop_done_and_result_of():
    ms = MultiSearch([task("pd1", budget=300)], CFG)
    ms.start()
    while ms.step():
        pass
    done = dict(ms.pop_done())
    assert "pd1@cloud" in done
    assert ms.pop_done() == []          # drained
    assert ms.result_of("pd1@cloud").best_edp == \
        done["pd1@cloud"].best_edp
    with pytest.raises(KeyError):
        ms.result_of("nope")


# --------------------------------------- checkpoint / crash recovery


def _run_tasks():
    out = []
    for nm, m in (("ck_a", 16), ("ck_b", 24)):
        t = task(nm, m=m, seed=5)
        t.runtime_kw["state_out"] = {}
        out.append(t)
    return out


def test_checkpoint_round_trip_is_bit_identical():
    """Save the in-flight fleet at round r, kill it, restore from disk:
    the resumed run's final results equal the uninterrupted run's
    bit-for-bit (best EDP, genome, full history, eval counts)."""
    ref = MultiSearch(_run_tasks(), CFG).run()

    ms = MultiSearch(_run_tasks(), CFG)
    ms.start()
    for _ in range(6):
        ms.step()
    arrays, meta = pack_fleet(ms)
    with tempfile.TemporaryDirectory() as d:
        ckpt_lib.save_flat(d, int(ms._rounds), arrays, extra_meta=meta)
        del ms                          # the "kill"
        arrays2, meta2 = ckpt_lib.load_flat(d, ckpt_lib.latest_step(d))
    res = restore_fleet(arrays2, meta2).run()

    for name in ref:
        a, b = ref[name], res[name]
        assert b.best_edp == a.best_edp, name
        assert np.array_equal(b.best_genome, a.best_genome), name
        assert np.array_equal(b.history, a.history), name
        assert (b.evals, b.valid_evals) == (a.evals, a.valid_evals)


def test_server_recovers_from_worker_crash(monkeypatch):
    """Kill the fleet mid-sweep (injected exception on step call 6,
    after the round-4 checkpoint): the supervisor restores from the
    latest checkpoint and the client still receives the bit-identical
    final best-EDP."""
    ref = MultiSearch([task("cr", seed=5)], CFG).run()["cr@cloud"]

    calls = {"n": 0}
    orig_step = MultiSearch.step

    def crashy(self):
        calls["n"] += 1
        if calls["n"] == 6:
            raise RuntimeError("injected worker crash")
        return orig_step(self)

    monkeypatch.setattr(MultiSearch, "step", crashy)
    with tempfile.TemporaryDirectory() as d:
        srv = SweepServer(port=0, config=CFG, ckpt_dir=d, ckpt_every=4)
        srv.start_background()
        try:
            evs = list(submit(srv.host, srv.port, task("cr", seed=5)))
            done = [e for e in evs if e.get("event") == "done"]
            st = next(iter(sweep_serve.request(
                srv.host, srv.port, {"op": "stats"})))["stats"]
            assert st["restarts"] == 1
            assert done[0]["best_edp"] == ref.best_edp
            assert done[0]["evals"] == ref.evals
            assert done[0]["best_genome"] == \
                np.asarray(ref.best_genome).tolist()
            # clean completion wipes the spent checkpoints
            assert not any(x.startswith("step_") for x in os.listdir(d))
        finally:
            srv.stop()


def test_checkpointing_requires_device_rounds_one():
    with pytest.raises(ValueError, match="device_rounds"):
        SweepServer(port=0, config=FleetConfig(device_rounds=4),
                    ckpt_dir="/tmp/nope")


# ------------------------------------------------- warm-start library


def test_library_hit_miss_and_keying():
    lib = GenomeLibrary()
    ta, tb = task("lw", seed=1), task("lw", seed=2)
    assert library_key(ta) == library_key(tb)       # content, not seed
    assert library_key(task("lw", m=24)) != library_key(ta)
    assert lib.lookup(ta) is None and lib.misses == 1

    res = MultiSearch([task("lw", seed=1)], CFG).run()["lw@cloud"]
    assert np.isfinite(res.best_edp)        # budget finds a valid genome
    lib.record(ta, res)
    rows = lib.lookup(tb)
    assert lib.hits == 1
    assert rows.shape == (1, len(res.best_genome))
    assert np.array_equal(rows[0], res.best_genome)
    # worse result does not displace the stored best
    worse = type(res)(best_edp=res.best_edp * 10,
                      best_genome=np.zeros_like(res.best_genome),
                      history=res.history, evals=1, valid_evals=1,
                      extras={})
    lib.record(ta, worse)
    assert np.array_equal(lib.lookup(ta)[0], res.best_genome)


def test_server_warm_starts_repeat_queries():
    srv = SweepServer(port=0, config=CFG)
    srv.start_background()
    try:
        list(submit(srv.host, srv.port, task("ws", budget=300)))
        list(submit(srv.host, srv.port, task("ws", budget=300)))
        st = next(iter(sweep_serve.request(
            srv.host, srv.port, {"op": "stats"})))["stats"]
        assert st["library"]["hits"] == 1
        assert st["library"]["misses"] == 1
        assert st["warm_started"] == 1
    finally:
        srv.stop()


def test_standard_es_rejects_runtime_kwargs():
    t = task("se", method="standard_es", budget=300)
    t.runtime_kw["warm_seeds"] = np.zeros((1, 4), dtype=np.int64)
    with pytest.raises(ValueError, match="standard_es"):
        MultiSearch([t], CFG).run()


# --------------------------------------------------- protocol errors


def test_unknown_arch_rejected_with_hint_server_survives():
    srv = SweepServer(port=0, config=CFG)
    srv.start_background()
    try:
        bad = task("ua").to_json_dict()
        bad["platform"] = "clodu"
        evs = list(sweep_serve.request(
            srv.host, srv.port, {"op": "submit", "task": bad}))
        assert not evs[0]["ok"] and evs[0]["unknown_arch"]
        assert "did you mean 'cloud'" in evs[0]["error"]
        # the server is still serving
        evs = list(submit(srv.host, srv.port, task("ua", budget=300)))
        assert any(e.get("event") == "done" for e in evs)
    finally:
        srv.stop()


def test_config_fragment_mismatch_rejected():
    srv = SweepServer(port=0, config=CFG)
    srv.start_background()
    try:
        msg = {"op": "submit", "task": task("cf").to_json_dict(),
               "config": FleetConfig(stack_batches=False).to_json_dict()}
        evs = list(sweep_serve.request(srv.host, srv.port, msg))
        assert not evs[0]["ok"] and "disagrees" in evs[0]["error"]
    finally:
        srv.stop()


# ------------------------------------------------- subprocess smoke


@pytest.mark.slow
def test_sweep_server_subprocess_smoke(subprocess_env):
    """The acceptance scenario end to end, over real sockets and
    processes: server CLI + two concurrent same-signature clients + one
    different-topology client.  The same-signature pair must coalesce
    (their shared signature group holds 2 tasks; dispatches/round stays
    1.0 while only that group runs) and shutdown must be clean."""
    env = subprocess_env()
    srv = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", "sweep",
         "--port", "0"],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env=env, cwd=ROOT)
    try:
        line = srv.stdout.readline()
        assert "listening on" in line, line
        port = int(line.rsplit(":", 1)[1])

        results = {}

        def client(tag, t, delay=0.0):
            time.sleep(delay)
            results[tag] = list(submit("127.0.0.1", port, t))

        threads = [
            threading.Thread(target=client,
                             args=("a", task("sub_a", seed=1))),
            threading.Thread(target=client,
                             args=("b", task("sub_b", seed=2))),
            # different topology => its own signature group
            threading.Thread(target=client,
                             args=("c", task("sub_c", seed=3,
                                             platform="edge"), 0.5)),
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=300)

        for tag in ("a", "b", "c"):
            evs = results[tag]
            assert evs[0]["ok"], (tag, evs[0])
            assert any(e.get("event") == "done" for e in evs), (tag, evs)

        st = next(iter(sweep_serve.request(
            "127.0.0.1", port, {"op": "stats"})))["stats"]
        assert st["queries"] == 3 and st["completed"] == 3
        # coalescing evidence: some epoch held the same-signature pair
        # in ONE signature group (server keeps per-epoch group history)
        assert any(max(g.values()) >= 2
                   for g in st["epoch_signature_groups"] if g), \
            f"same-signature queries never shared a group: " \
            f"{st['epoch_signature_groups']}"
        # per-round coalescing: one host sync per fleet round
        assert st["fleet"]["host_syncs_per_round"] == 1.0

        list(sweep_serve.request("127.0.0.1", port, {"op": "shutdown"}))
        assert srv.wait(timeout=60) == 0
        out = srv.stdout.read()
        assert "sweep serve stopped" in out
    finally:
        if srv.poll() is None:
            srv.kill()


@pytest.mark.slow
def test_serve_dispatch_help(subprocess_env):
    """Top-level serve --help names both modes; each mode's --help is
    accurate to its own flags."""
    env = subprocess_env()

    def run(args):
        return subprocess.run([sys.executable, "-m",
                               "repro.launch.serve"] + args,
                              capture_output=True, text=True, env=env,
                              cwd=ROOT, timeout=120)

    top = run(["--help"])
    assert top.returncode == 0
    assert "decode" in top.stdout and "sweep" in top.stdout
    sw = run(["sweep", "--help"])
    assert sw.returncode == 0
    assert "--checkpoint-dir" in sw.stdout
    assert "--batch" not in sw.stdout
    dec = run(["decode", "--help"])
    assert dec.returncode == 0
    assert "--prompt-len" in dec.stdout
    assert "--checkpoint-dir" not in dec.stdout
