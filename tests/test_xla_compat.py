"""Unit tests for the JAX version-compat shims (COMPAT.md): the
cost_analysis normalizer (dict / list-of-dicts / None returns) and the
shard_map compat import."""
import numpy as np
import pytest

from repro.launch.xla_compat import normalize_cost_analysis, \
    xla_cost_analysis


class _FakeCompiled:
    def __init__(self, ca):
        self._ca = ca

    def cost_analysis(self):
        if isinstance(self._ca, Exception):
            raise self._ca
        return self._ca


def test_dict_return_passes_through():
    ca = {"flops": 10.0, "bytes accessed": 4.0}
    out = xla_cost_analysis(_FakeCompiled(ca))
    assert out == ca
    assert out is not ca                       # defensive copy


def test_list_of_dicts_is_flattened():
    out = xla_cost_analysis(_FakeCompiled([{"flops": 10.0}]))
    assert out.get("flops") == 10.0


def test_list_of_dicts_sums_numeric_keys():
    out = normalize_cost_analysis(
        [{"flops": 10.0, "backend": "cpu"},
         {"flops": 5.0, "bytes accessed": 2.0, "backend": "cpu2"}])
    assert out["flops"] == 15.0
    assert out["bytes accessed"] == 2.0
    assert out["backend"] == "cpu"             # first occurrence kept


def test_none_and_errors_give_empty_dict():
    assert xla_cost_analysis(_FakeCompiled(None)) == {}
    assert xla_cost_analysis(
        _FakeCompiled(RuntimeError("unsupported"))) == {}
    assert normalize_cost_analysis([None, {"flops": 1.0}]) == {"flops": 1.0}


def test_real_compiled_artifact():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    c = jax.jit(lambda x: x @ x).lower(
        jax.ShapeDtypeStruct((16, 16), jnp.float32)).compile()
    out = xla_cost_analysis(c)
    assert isinstance(out, dict)
    assert out.get("flops", 0.0) > 0


def test_shard_map_compat_runs():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.distributed.compat import shard_map

    mesh = Mesh(np.asarray(jax.devices()[:1]), ("x",))
    with mesh:
        fn = shard_map(lambda a: a * 2.0, mesh=mesh,
                       in_specs=P(), out_specs=P(), check_vma=False)
        y = fn(jnp.arange(4.0))
    np.testing.assert_allclose(np.asarray(y), [0.0, 2.0, 4.0, 6.0])
