"""Per-topology pad-watermark policies (MultiSearch) and the CI
BENCH_sweep.json regression gate (benchmarks/compare_sweep.py)."""
import copy

import numpy as np

from benchmarks.compare_sweep import compare, stale_policy_warnings
from repro.core import search
from repro.core.arch import ARCH_SPARSEMAP
from repro.core.search import (MultiSearch, PadPolicy, SearchTask,
                               pad_policy_for, set_pad_policy)
from repro.core.workload import spmm

WL_A = spmm("pad_a", 32, 64, 48, 0.2, 0.5)
WL_B = spmm("pad_b", 48, 32, 64, 0.4, 0.3)


def _fleet(**kw):
    tasks = [SearchTask(WL_A, "cloud", budget=300, seed=0,
                        method="random_mapper"),
             SearchTask(WL_B, "cloud", budget=300, seed=0,
                        method="sparsemap")]
    return MultiSearch(tasks, stack_batches=True, **kw)


def test_pad_watermark_history_recorded_per_topology():
    ms = _fleet()
    ms.run()
    fp = ARCH_SPARSEMAP.topology.fingerprint
    assert list(ms.stats["pad_policies"]) == [fp]
    # the paper topology carries the measured policy derived from the
    # committed baseline trajectory (configs.archs), not the default:
    # earlier decay (2 quiet rounds), ratio tightened to the observed
    # post-spike plateau (256/2048)
    assert ms.stats["pad_policies"][fp] == \
        {"decay_rounds": 2, "decay_ratio": 0.125, "source": "measured"}
    wms = ms.stats["pad_watermarks"]
    assert len(wms) == 1
    (key, hist), = wms.items()
    assert key.endswith(fp)
    assert len(hist) == ms.stats["rounds"]
    assert all(h >= 64 for h in hist)       # the pad floor


def test_pad_policy_override_and_registry():
    aggressive = PadPolicy(decay_rounds=1, decay_ratio=1.0)
    fp = ARCH_SPARSEMAP.topology.fingerprint
    ms = _fleet(pad_policies={fp: aggressive})
    res_o = ms.run()
    assert ms.stats["pad_policies"][fp] == \
        {"decay_rounds": 1, "decay_ratio": 1.0, "source": "default"}
    (_, hist_o), = ms.stats["pad_watermarks"].items()
    ms_d = _fleet()
    res_d = ms_d.run()
    (_, hist_d), = ms_d.stats["pad_watermarks"].items()
    # an always-decay policy tracks each round's own shape, so its
    # watermark can only be at or below the sticky default's
    assert len(hist_o) == len(hist_d)
    assert all(o <= d for o, d in zip(hist_o, hist_d))
    # padding rows are inert: results are identical under either policy
    for name in res_d:
        assert res_d[name].best_edp == res_o[name].best_edp
        assert np.array_equal(res_d[name].history, res_o[name].history)
    # the global registry is consulted when no override is passed
    try:
        set_pad_policy("deadbeef", aggressive)
        assert pad_policy_for("deadbeef") == aggressive
        assert pad_policy_for("not_registered") == PadPolicy()
    finally:
        search._PAD_POLICIES.pop("deadbeef", None)


# ------------------------------------------------- compare_sweep gate


BASE = dict(
    budget=300,
    archs=[
        dict(arch="cloud", seconds=10.0, compiles=2,
             dispatches_per_round=1.0),
        dict(arch="maple_edge", seconds=5.0, compiles=2,
             dispatches_per_round=1.0),
    ])


def test_compare_sweep_passes_on_identical_runs():
    failures, warnings = compare(BASE, copy.deepcopy(BASE))
    assert failures == [] and warnings == []


def test_compare_sweep_fails_on_compile_and_dispatch_regressions():
    cur = copy.deepcopy(BASE)
    cur["archs"][0]["compiles"] = 3
    cur["archs"][1]["dispatches_per_round"] = 2.0
    failures, _ = compare(BASE, cur)
    assert len(failures) == 2
    assert "compiles regressed 2 -> 3" in failures[0]
    assert "dispatches/round regressed" in failures[1]


def test_compare_sweep_new_arch_and_timing_are_warn_only():
    cur = copy.deepcopy(BASE)
    cur["archs"].append(dict(arch="quant_edge", seconds=1.0, compiles=9,
                             dispatches_per_round=3.0))
    cur["archs"][0]["seconds"] = 100.0
    failures, warnings = compare(BASE, cur)
    assert failures == []
    assert any("new arch" in w for w in warnings)
    assert any("warn-only" in w for w in warnings)


def test_compare_sweep_budget_mismatch_downgrades_to_warnings():
    cur = copy.deepcopy(BASE)
    cur["budget"] = 1000
    cur["archs"][0]["compiles"] = 99
    del cur["archs"][1]                 # disappearance downgrades too
    failures, warnings = compare(BASE, cur)
    assert failures == []
    assert any("budgets differ" in w for w in warnings)
    assert any("compiles regressed" in w for w in warnings)
    assert any("disappeared" in w for w in warnings)


def test_committed_baseline_is_well_formed():
    import json
    import os
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "BENCH_sweep.baseline.json")
    base = json.load(open(path))
    failures, warnings = compare(base, base)
    assert failures == [] and warnings == []
    assert {a["arch"] for a in base["archs"]} >= \
        {"cloud", "maple_edge", "cluster_cloud", "systolic_mesh",
         "quant_edge"}
    for a in base["archs"]:
        # per-round fleets hold 1 dispatch/round; the device-resident
        # fleet (cloud_device_k4) folds k generations per dispatch
        assert a["dispatches_per_round"] <= 1.0
        assert a["host_syncs_per_round"] <= 1.0
        assert a["pad_watermarks"] and a["pad_policies"]
    k4 = {a["arch"]: a for a in base["archs"]}["cloud_device_k4"]
    assert k4["device_rounds"] == 4
    assert k4["host_syncs_per_round"] <= 1 / 4
    # no stale-policy warnings against the baseline itself: registered
    # policies must match what its own trajectories derive
    assert stale_policy_warnings(base) == []


def test_compare_sweep_fails_on_host_sync_regression():
    base = copy.deepcopy(BASE)
    base["archs"][0]["host_syncs_per_round"] = 0.25
    cur = copy.deepcopy(base)
    cur["archs"][0]["host_syncs_per_round"] = 1.0
    failures, _ = compare(base, cur)
    assert failures == ["cloud: host syncs/round regressed 0.25 -> 1.0"]
    # absent on either side (old baseline) -> not comparable, no failure
    failures, _ = compare(BASE, cur)
    assert failures == []


def test_stale_policy_warning_fires_on_mismatched_trajectory():
    rec = dict(archs=[dict(
        arch="cloud",
        # one-off spike, never re-grows -> derivation says decay_rounds=2
        pad_watermarks={"d3_p16_feedf00d": [2048, 2048, 2048, 256, 256]},
        pad_policies={"feedf00d": {"decay_rounds": 3,
                                   "decay_ratio": 0.5}})])
    warns = stale_policy_warnings(rec)
    assert len(warns) == 1 and "decay_rounds=2" in warns[0]
    # re-growing trajectory matches the conservative registered policy
    rec["archs"][0]["pad_watermarks"]["d3_p16_feedf00d"] = \
        [2048, 256, 2048, 256, 2048]
    assert stale_policy_warnings(rec) == []


def test_stale_policy_warning_promotes_seed_policies():
    """A policy still carrying source="seed" after a run that measured
    the topology's real trajectory asks for promotion to the baseline
    watermark table — even when decay_rounds already agrees."""
    rec = dict(archs=[dict(
        arch="sigma_like",
        pad_watermarks={"d3_p16_8b2430a8": [2048, 2048, 256, 256]},
        pad_policies={"8b2430a8": {"decay_rounds": 2,
                                   "decay_ratio": 0.125,
                                   "source": "seed"}})])
    warns = stale_policy_warnings(rec)
    assert len(warns) == 1
    assert "seed pad policy" in warns[0]
    assert "_SEED_PAD_WATERMARKS" in warns[0]
    # once promoted (source measured), the same record is quiet
    rec["archs"][0]["pad_policies"]["8b2430a8"]["source"] = "measured"
    assert stale_policy_warnings(rec) == []


def test_compare_sweep_fails_when_arch_disappears():
    cur = copy.deepcopy(BASE)
    cur["archs"] = cur["archs"][:1]
    failures, _ = compare(BASE, cur)
    assert failures == ["maple_edge: arch disappeared from the sweep"]
