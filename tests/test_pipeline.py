"""Pipelined fleet rounds (COMPAT.md "Pipelined dispatch contract"):

* pipeline=True vs pipeline=False must be BIT-IDENTICAL — the pipelined
  driver defers harvests/finalizes one round late, but dispatch shapes,
  registration order and values are the same by construction;
* the in-scan direct-genome translation (``standard_es`` segments) must
  match the numpy oracle (``DirectValueSpec.to_canonical``) row for row,
  including untranslatable rows;
* ``stagnation_restart > 0`` no longer forces the per-round path: the
  folded restart branch matches its host replay bit-for-bit and keeps
  the 1/k host-sync ratio;
* the compile-ahead AOT registry counts hits/misses correctly and the
  ``jax_cost`` module counters survive a two-thread hammer;
* the per-backend ``device_rounds`` chooser resolves and records its
  provenance.
"""
import threading

import numpy as np
import pytest

from repro.configs.paper_workloads import by_name, structured_workloads
from repro.core import es_ops, jax_cost, search
from repro.core.direct_encoding import DirectValueSpec
from repro.core.es_ops import DeviceSegment

BUDGET = 700
SEED = 3
K = 4


def _grid_equal(a, b):
    assert set(a) == set(b)
    for m in a:
        assert set(a[m]) == set(b[m])
        for w in a[m]:
            ra, rb = a[m][w], b[m][w]
            assert ra.best_edp == rb.best_edp, (m, w)
            assert np.array_equal(ra.history, rb.history), (m, w)
            assert ra.evals == rb.evals and \
                ra.valid_evals == rb.valid_evals, (m, w)


def _sweep(pipeline, compile_ahead=True, device_rounds=K, stats=None):
    """Mixed-method, mixed-density fleet: a segmented ES, the segmented
    direct-encoding ES, and a per-round baseline, over a uniform and a
    structured-density workload."""
    wls = [by_name("mm1"), structured_workloads()[0]]
    return search.run_method_sweep(
        ["sparsemap", "standard_es", "pso"], wls, "cloud",
        budget=BUDGET, seed=SEED, stack_batches=True,
        device_rounds=device_rounds, pipeline=pipeline,
        compile_ahead=compile_ahead, stats_out=stats)


def test_pipelined_equals_unpipelined_bitforbit():
    stats_on, stats_off = {}, {}
    on = _sweep(pipeline=True, stats=stats_on)
    off = _sweep(pipeline=False, stats=stats_off)
    _grid_equal(on, off)
    assert stats_on["pipeline"] and not stats_off["pipeline"]
    # both drivers issue the same device dispatches
    assert stats_on["dispatches"] == stats_off["dispatches"]


def test_pipeline_off_matches_no_compile_ahead():
    """Compile-ahead only changes WHERE compilation happens, never what
    is computed."""
    _grid_equal(_sweep(pipeline=True, compile_ahead=True),
                _sweep(pipeline=False, compile_ahead=False))


# ------------------------------------------------ direct translation


def _identity_segment(spec, dspec, pop, edp):
    """A 1-generation direct segment whose kids are exactly
    ``pop[:B-1]``: fitness is pre-sorted (stable order = identity),
    every child crosses parent i with itself, mutation inactive."""
    B = len(pop)
    C = B - 1
    d = es_ops.GenDraws(
        ab=np.stack([np.arange(C)] * 2, axis=1),
        cuts=np.ones(C, dtype=np.int64),
        active=np.zeros(C, dtype=bool),
        gene=np.zeros((C, 2), dtype=np.int64),
        vals=np.zeros((C, 2), dtype=np.int64))
    aux = dict(
        scramble=np.asarray(dspec.scramble, dtype=np.int32),
        dim_sizes=np.asarray(
            [dspec.workload.dim_sizes[k] for k in dspec.workload.dim_order],
            dtype=np.float32))
    return DeviceSegment(spec=spec, pop=pop, edp=edp, rounds=1, gen0=0,
                         n_parents=C, n_elite=1, genes_per=2, draws=
                         es_ops.stack_draws([d]), kind="direct", aux=aux)


def test_direct_translation_matches_numpy_oracle():
    wl = by_name("mm1")
    spec, ev = search.get_evaluator(wl, "cloud")
    dspec = DirectValueSpec(spec)
    rng = np.random.default_rng(7)
    pop = dspec.random_genomes(rng, 33)
    # guarantee translatable rows: trivial and two-way factor splits
    nl = dspec.n_levels
    for i, split in enumerate([(0,), (1,), (0, 1)]):
        row = pop[i]
        col = dspec.fact_sl.start
        for dim in dspec.workload.dim_order:
            size = dspec.workload.dim_sizes[dim]
            facs = [1] * nl
            if len(split) == 1 or len(dspec.div[dim]) < 3:
                facs[split[0] % nl] = size
            else:
                a = dspec.div[dim][1]       # smallest divisor > 1
                facs[0], facs[1] = a, size // a
            row[col:col + nl] = facs
            col += nl
    edp = np.arange(len(pop), dtype=np.float32)  # pre-sorted fitness
    seg = _identity_segment(spec, dspec, pop, edp)
    res = jax_cost.run_segments([ev], [seg])[0]
    kids_canon, out = res.gens[0]
    n_valid = 0
    for i in range(len(pop) - 1):
        oracle = dspec.to_canonical(pop[i])
        if oracle is None:
            assert not out["valid"][i], i
            assert np.array_equal(kids_canon[i],
                                  np.zeros(spec.length, np.int64)), i
            assert not np.isfinite(out["edp"][i]), i
        else:
            n_valid += 1
            assert np.array_equal(kids_canon[i], oracle), i
    assert n_valid >= 3      # the crafted rows did translate


def test_standard_es_segments_match_host_loop():
    """Device-executed direct segments == the host replay of the same
    plans, bit for bit (the ``standard_es`` exact-parity acceptance)."""
    wls = [by_name("mm1")]

    def go(device_execute):
        return search.run_method_sweep(
            ["standard_es"], wls, "cloud", budget=BUDGET, seed=SEED,
            stack_batches=True, device_rounds=K,
            device_execute=device_execute)

    _grid_equal(go(True), go(False))


# ------------------------------------------------ restart in-scan


def test_restart_segment_matches_host_replay():
    wls = [by_name("mm1")]
    kw = {"sparsemap": dict(stagnation_restart=2)}

    def go(device_execute, stats):
        return search.run_method_sweep(
            ["sparsemap"], wls, "cloud", budget=BUDGET, seed=SEED,
            stack_batches=True, device_rounds=K,
            device_execute=device_execute, method_kw=kw, stats_out=stats)

    sa, sb = {}, {}
    _grid_equal(go(True, sa), go(False, sb))
    # restart no longer forces the per-round path: the device fleet's
    # steady-state host-sync ratio is 1/k
    assert sa["host_syncs_per_round"] == pytest.approx(1.0 / K)


# ------------------------------------------------ compile-ahead


def test_compile_ahead_hits_and_misses():
    wl = by_name("mm2")
    jax_cost.clear_compile_cache()
    search._CACHE.clear()
    spec, ev = search.get_evaluator(wl, "cloud")
    jax_cost.reset_compile_ahead_counts()
    jax_cost.compile_ahead([jax_cost.bcast_compile_job(ev, 64)], wait=True)
    rng = np.random.default_rng(0)
    ev(spec.random_genomes(rng, 10))        # pads to 64 -> AOT hit
    assert jax_cost.compile_ahead_counts() == (1, 0)
    ev(spec.random_genomes(rng, 100))       # pads to 128 -> fresh trace
    assert jax_cost.compile_ahead_counts() == (1, 1)
    ev(spec.random_genomes(rng, 90))        # 128 again: warm jit, no miss
    assert jax_cost.compile_ahead_counts() == (1, 1)
    assert jax_cost.compilation_count() >= 2


def test_unclaimed_families_never_count_misses():
    wl = by_name("mm3")
    jax_cost.clear_compile_cache()
    search._CACHE.clear()
    spec, ev = search.get_evaluator(wl, "cloud")
    jax_cost.reset_compile_ahead_counts()
    # compile-ahead runs for an unrelated stacked family only
    jax_cost.compile_ahead([jax_cost.stacked_compile_job(ev, 256)],
                           wait=True)
    rng = np.random.default_rng(0)
    ev(spec.random_genomes(rng, 10))        # bcast family unclaimed
    assert jax_cost.compile_ahead_counts() == (0, 0)


def test_fleet_stats_record_compile_ahead_and_host_blocked():
    stats = {}
    _sweep(pipeline=True, stats=stats)
    assert stats["compile_ahead_hits"] >= 1
    assert stats["compile_ahead_misses"] >= 0
    assert stats["host_blocked_s"] >= 0.0
    assert stats["device_rounds_source"] == "explicit"


# ------------------------------------------------ counters under threads


def test_counters_thread_safe_under_hammer():
    jax_cost.reset_dispatch_count()
    n, threads = 20_000, []

    def hammer():
        for _ in range(n):
            jax_cost._count_dispatch()

    readers_ok = []

    def read():
        for _ in range(2_000):
            readers_ok.append(jax_cost.dispatch_count() >= 0)
            jax_cost.compilation_count()
            jax_cost.compile_ahead_counts()
            jax_cost.stack_prep_counts()
            jax_cost.host_blocked_s()

    for fn in (hammer, hammer, read):
        t = threading.Thread(target=fn)
        threads.append(t)
        t.start()
    for t in threads:
        t.join()
    assert jax_cost.dispatch_count() == 2 * n
    assert all(readers_ok)


# ------------------------------------------------ device_rounds chooser


def test_default_device_rounds_chooser():
    assert search.default_device_rounds("cpu") == 1
    assert search.default_device_rounds("gpu") == 4
    assert search.default_device_rounds("tpu") == 8
    assert search.default_device_rounds("metal") == 1   # unknown -> 1
    import jax
    assert search.default_device_rounds() == \
        search.default_device_rounds(jax.default_backend())


def test_device_rounds_resolution_and_provenance():
    import jax
    ms = search.MultiSearch([by_name("mm1")])
    assert ms.device_rounds == search.default_device_rounds()
    assert ms.device_rounds_source == f"default:{jax.default_backend()}"
    ms2 = search.MultiSearch([by_name("mm1")], device_rounds=2)
    assert ms2.device_rounds == 2
    assert ms2.device_rounds_source == "explicit"
    with pytest.raises(ValueError):
        search.MultiSearch([by_name("mm1")], device_rounds=0)
