"""autoshard (beyond-paper): ES over the distributed decision space."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import autoshard

MESH_1POD = {"data": 16, "model": 16}
MESH_2POD = {"pod": 2, "data": 16, "model": 16}


def test_decode_decisions_total_space():
    spec = autoshard.DecisionSpec()
    assert spec.length == len(autoshard.GENE_UB)
    g = spec.random_genomes(np.random.default_rng(0), 16)
    for row in g:
        d = autoshard.decode_decisions(row)
        assert d["remat"] in autoshard.REMAT_OPTS
        assert d["moments"] in autoshard.MOMENT_OPTS


def test_es_finds_exhaustive_optimum_dense():
    cfg = get_config("mistral-nemo-12b")
    dec, est, res = autoshard.search(cfg, 4096, 256, MESH_1POD,
                                     budget=2000, seed=0)
    _, best_t = autoshard.exhaustive_best(cfg, 4096, 256, MESH_1POD)
    assert dec is not None
    assert res.best_edp == pytest.approx(best_t, rel=1e-6)


def test_kimi_single_pod_infeasible_multi_pod_feasible():
    """The trillion-parameter config cannot train on one 256-chip pod
    (16 GB HBM); two pods with int8 moments + ZeRO-1 fit."""
    cfg = get_config("kimi-k2-1t-a32b")
    dec1, _ = autoshard.exhaustive_best(cfg, 4096, 256, MESH_1POD)
    assert dec1 is None
    dec2, est2, res2 = autoshard.search(cfg, 4096, 256, MESH_2POD,
                                        budget=2000, seed=0)
    assert dec2 is not None
    assert dec2["moments"] in ("int8", "bf16")
    assert est2.hbm_bytes_per_device < 16e9


def test_estimate_monotonic_in_remat():
    cfg = get_config("command-r-35b")
    base = dict(remat="none", microbatches=1, logits="vocab",
                embed="vocab", attn_chunk=0, mlp_shard="megatron",
                zero1=True, moe_ff="data", kv_seq="model", moments="bf16")
    e_none = autoshard.estimate(cfg, 4096, 256, MESH_1POD, base)
    e_full = autoshard.estimate(cfg, 4096, 256, MESH_1POD,
                                dict(base, remat="full"))
    assert e_full.t_compute > e_none.t_compute       # recompute costs flops
    assert e_full.hbm_bytes_per_device < e_none.hbm_bytes_per_device


def test_vocab_sharded_logits_beat_gather_on_collectives():
    cfg = get_config("gemma3-12b")       # 262k vocab: logits dominate
    base = dict(remat="full", microbatches=1, logits="vocab",
                embed="vocab", attn_chunk=0, mlp_shard="megatron",
                zero1=True, moe_ff="data", kv_seq="model", moments="bf16")
    e_v = autoshard.estimate(cfg, 4096, 256, MESH_1POD, base)
    e_g = autoshard.estimate(cfg, 4096, 256, MESH_1POD,
                             dict(base, logits="gather"))
    assert e_g.t_collective > e_v.t_collective
