"""The method-agnostic concurrent sweep engine + its satellite bugfixes:

* evaluator cache keyed on workload CONTENT (id-reuse aliasing regression),
* `_Budget.register` budget-truncation semantics (NaN tail, not inf),
* explicit duplicate-task-name handling in `MultiSearch`,
* mixed-method `MultiSearch` == sequential `search.run` at fixed seeds,
* `stack_batches=True` (mega-batch dispatch) == `stack_batches=False`
  bit-for-bit, with strictly fewer compilations AND dispatches than the
  sequential equivalent,
* `jax_cost.eval_stacked` == per-model calls, bit-for-bit.
"""
import numpy as np
import pytest

from repro.configs.paper_workloads import by_name
from repro.core import jax_cost, search
from repro.core.evolution import _Budget
from repro.core.workload import spmm

METHODS = ["sparsemap", "pso", "random_mapper"]
WLS = ("mm1", "mm3")        # same (3, 16) natural signature
BUDGET = 300


# ------------------------------------------------- evaluator cache


def test_evaluator_cache_is_content_keyed_not_id_keyed():
    """Regression: the cache used id(workload); after gc a recycled id
    could return the WRONG (GenomeSpec, JaxCostModel).  Construct/drop
    workloads in a loop to provoke id reuse and check the evaluator always
    matches the live workload's content."""
    for i in range(25):
        m = 8 + 4 * (i % 7)
        wl = spmm("alias_probe", m, 16, 8, 0.5, 0.5)
        spec, ev = search.get_evaluator(wl, "cloud")
        assert spec.workload.dim_sizes == wl.dim_sizes, \
            f"cache aliased a stale workload at iteration {i}"
        assert ev.spec is spec
        del wl, spec, ev        # free the id for reuse


def test_evaluator_cache_shares_content_equal_workloads():
    a = spmm("same_wl", 16, 16, 16, 0.5, 0.5)
    b = spmm("same_wl", 16, 16, 16, 0.5, 0.5)
    assert a is not b and a.cache_key() == b.cache_key()
    assert search.get_evaluator(a, "cloud")[1] is \
        search.get_evaluator(b, "cloud")[1]
    # different content (density) must NOT share
    c = spmm("same_wl", 16, 16, 16, 0.5, 0.25)
    assert search.get_evaluator(c, "cloud")[1] is not \
        search.get_evaluator(a, "cloud")[1]


# ------------------------------------------------- budget truncation


def test_budget_truncation_marks_tail_nan():
    """End-of-budget behavior: only the evaluated prefix is counted; the
    truncated tail comes back NaN (not inf), so selection can tell
    "not evaluated" from "evaluated and invalid"."""
    tr = _Budget(6)
    genomes = np.arange(20).reshape(10, 2)
    out = dict(edp=np.full(10, 2.0), valid=np.ones(10, bool))
    edp = tr.register(genomes, out)
    assert tr.last_n == 6 and tr.evals == 6 == len(tr.hist)
    assert tr.valid == 6
    np.testing.assert_array_equal(edp[:6], 2.0)
    assert np.isnan(edp[6:]).all()
    assert tr.exhausted
    # a post-exhaustion batch is all-NaN and counts nothing
    edp2 = tr.register(genomes, out)
    assert tr.last_n == 0 and tr.evals == 6
    assert np.isnan(edp2).all()
    # NaN rows sort after real rows and compare False, like inf rows
    order = np.argsort(edp)
    assert set(order[:6]) == set(range(6))


def test_budget_truncation_tail_never_becomes_best():
    tr = _Budget(2)
    genomes = np.zeros((4, 3), dtype=np.int64)
    out = dict(edp=np.array([9.0, 8.0, 1.0, 0.5]),
               valid=np.ones(4, bool))
    tr.register(genomes, out)
    assert tr.best == 8.0           # rows 2,3 were beyond the budget
    assert tr.evals == 2


# ------------------------------------------------- duplicate names


def test_multisearch_duplicate_names_all_suffixed():
    wl = by_name("mm1")
    ms = search.MultiSearch([
        search.SearchTask(wl, "cloud", budget=50, name="dup"),
        search.SearchTask(wl, "cloud", budget=50, name="dup"),
        search.SearchTask(wl, "cloud", budget=50, name="solo"),
        search.SearchTask(wl, "cloud", budget=50, name="dup"),
    ])
    assert ms.final_names == ["dup#0", "dup#1", "solo", "dup#2"]
    res = ms.run()
    assert set(res) == {"dup#0", "dup#1", "solo", "dup#2"}


def test_multisearch_suffixes_avoid_explicit_names():
    """An auto-suffix must never collide with a name another task chose
    explicitly — no two tasks ever share a results key."""
    wl = by_name("mm1")
    ms = search.MultiSearch([
        search.SearchTask(wl, "cloud", budget=50, name="dup"),
        search.SearchTask(wl, "cloud", budget=50, name="dup"),
        search.SearchTask(wl, "cloud", budget=50, name="dup#0"),
    ])
    assert ms.final_names == ["dup#1", "dup#2", "dup#0"]
    assert len(set(ms.final_names)) == len(ms.final_names)


def test_multisearch_default_names_include_method():
    wl = by_name("mm1")
    ms = search.MultiSearch([
        search.SearchTask(wl, "cloud", budget=50),
        search.SearchTask(wl, "cloud", budget=50, method="pso"),
    ])
    assert ms.final_names == ["mm1@cloud", "pso:mm1@cloud"]


def test_searchtask_rejects_method_without_request_generator():
    with pytest.raises(KeyError):
        search.SearchTask(by_name("mm1"), method="no_such_method")


def test_standard_es_joins_the_fleet():
    """standard_es (direct encoding) now has a request generator over
    canonical rows: a MultiSearch task with it matches the sequential
    closed-form run exactly at a fixed seed."""
    wl = by_name("mm1")
    seq = search.run("standard_es", wl, "cloud", budget=200, seed=5)
    ms = search.MultiSearch([search.SearchTask(
        wl, "cloud", budget=200, seed=5, method="standard_es")])
    (res,) = ms.run().values()
    assert res.evals == seq.evals == 200
    assert res.best_edp == seq.best_edp
    np.testing.assert_array_equal(res.history, seq.history)


def test_run_method_sweep_rejects_grid_collisions():
    """The {method: {workload_name: ...}} grid cannot represent duplicate
    methods or duplicate workload names — refuse instead of silently
    dropping one search's result."""
    a = spmm("twin", 16, 16, 16, 0.5, 0.5)
    b = spmm("twin", 32, 16, 16, 0.5, 0.5)
    with pytest.raises(ValueError):
        search.run_method_sweep(["pso"], [a, b], budget=50)
    with pytest.raises(ValueError):
        search.run_method_sweep(["pso", "pso"], [a], budget=50)


# ------------------------------------------------- stacked evaluator


def test_eval_stacked_bitexact_vs_per_model_calls():
    a = spmm("stk_a", 32, 64, 48, 0.2, 0.5)
    b = spmm("stk_b", 48, 32, 64, 0.4, 0.3)
    sa, eva = search.get_evaluator(a, "cloud")
    sb, evb = search.get_evaluator(b, "edge")
    assert eva.signature == evb.signature
    rng = np.random.default_rng(0)
    ga, gb = sa.random_genomes(rng, 37), sb.random_genomes(rng, 50)
    ra, rb = eva(ga), evb(gb)
    oa, ob = jax_cost.eval_stacked([eva, evb], [ga, gb])
    for k in ra:
        np.testing.assert_array_equal(np.asarray(ra[k]), np.asarray(oa[k]))
        np.testing.assert_array_equal(np.asarray(rb[k]), np.asarray(ob[k]))
    # pad_floor (the sticky mega-batch shape) must not change results
    (oa2,) = jax_cost.eval_stacked([eva], [ga], pad_floor=512)
    for k in ra:
        np.testing.assert_array_equal(np.asarray(ra[k]),
                                      np.asarray(oa2[k]))


def test_eval_stacked_caches_tiled_constants_per_fleet_epoch():
    """The per-row workload constants are rebuilt only when the (models,
    row-counts, padded shape) fleet epoch changes — repeated rounds of a
    steady fleet hit the prep cache, and cached rounds stay bit-identical
    to uncached ones."""
    a = spmm("prep_a", 32, 64, 48, 0.2, 0.5)
    b = spmm("prep_b", 48, 32, 64, 0.4, 0.3)
    sa, eva = search.get_evaluator(a, "cloud")
    sb, evb = search.get_evaluator(b, "edge")
    rng = np.random.default_rng(2)
    ga, gb = sa.random_genomes(rng, 37), sb.random_genomes(rng, 50)
    jax_cost.reset_stack_prep_counts()
    first = jax_cost.eval_stacked([eva, evb], [ga, gb])
    again = jax_cost.eval_stacked([eva, evb], [ga, gb])
    hits, misses = jax_cost.stack_prep_counts()
    assert (hits, misses) == (1, 1)
    for x, y in zip(first, again):
        for k in x:
            np.testing.assert_array_equal(np.asarray(x[k]),
                                          np.asarray(y[k]))
    # a different fleet shape is a new epoch: rebuild, then warm again
    jax_cost.eval_stacked([eva], [ga])
    jax_cost.eval_stacked([eva], [ga])
    hits, misses = jax_cost.stack_prep_counts()
    assert (hits, misses) == (2, 2)


def test_eval_stacked_rejects_mixed_signatures():
    a = spmm("sig_a", 32, 64, 48, 0.2, 0.5)        # bucket 16
    c = spmm("sig_c", 128, 256, 512, 0.1, 0.9)     # bucket 32
    sa, eva = search.get_evaluator(a, "cloud")
    sc, evc = search.get_evaluator(c, "cloud")
    assert eva.signature != evc.signature
    rng = np.random.default_rng(1)
    with pytest.raises(ValueError):
        jax_cost.eval_stacked([eva, evc],
                              [sa.random_genomes(rng, 8),
                               sc.random_genomes(rng, 8)])


# ------------------------------------------------- mixed-method fleet


@pytest.fixture(scope="module")
def sweep_runs():
    """One shared (sequential, stacked, unstacked) run triple: sequential
    `search.run` per (method, workload), then the same grid through
    `MultiSearch` with and without mega-batch stacking, each from a cold
    compile cache so compilation/dispatch counts are comparable."""
    wls = [by_name(n) for n in WLS]
    search.clear_cache()
    seq = {m: {w.name: search.run(m, w, "cloud", budget=BUDGET, seed=0)
               for w in wls} for m in METHODS}
    seq_counts = (jax_cost.compilation_count(), jax_cost.dispatch_count())

    search.clear_cache()
    stacked_stats: dict = {}
    stacked = search.run_method_sweep(METHODS, wls, "cloud", budget=BUDGET,
                                      seed=0, stack_batches=True,
                                      stats_out=stacked_stats)
    stacked_counts = (jax_cost.compilation_count(),
                      stacked_stats["dispatches"])

    search.clear_cache()
    unstacked_stats: dict = {}
    unstacked = search.run_method_sweep(METHODS, wls, "cloud",
                                        budget=BUDGET, seed=0,
                                        stack_batches=False,
                                        stats_out=unstacked_stats)
    return dict(seq=seq, stacked=stacked, unstacked=unstacked,
                seq_counts=seq_counts, stacked_counts=stacked_counts,
                stacked_stats=stacked_stats, unstacked_stats=unstacked_stats)


def test_mixed_method_fleet_matches_sequential_exactly(sweep_runs):
    for m in METHODS:
        for w in WLS:
            a = sweep_runs["seq"][m][w]
            b = sweep_runs["stacked"][m][w]
            assert a.best_edp == b.best_edp, (m, w)
            assert a.evals == b.evals == BUDGET, (m, w)
            assert a.valid_evals == b.valid_evals, (m, w)
            np.testing.assert_array_equal(a.history, b.history,
                                          err_msg=f"{m}/{w}")
            if a.best_genome is not None:
                np.testing.assert_array_equal(a.best_genome, b.best_genome)


def test_stacked_matches_unstacked_bit_for_bit(sweep_runs):
    for m in METHODS:
        for w in WLS:
            a = sweep_runs["unstacked"][m][w]
            b = sweep_runs["stacked"][m][w]
            assert a.best_edp == b.best_edp, (m, w)
            np.testing.assert_array_equal(a.history, b.history,
                                          err_msg=f"{m}/{w}")


def test_stacked_sweep_fewer_compiles_and_dispatches(sweep_runs):
    seq_compiles, seq_dispatches = sweep_runs["seq_counts"]
    st_compiles, st_dispatches = sweep_runs["stacked_counts"]
    assert st_compiles < seq_compiles
    assert st_dispatches < seq_dispatches
    # one shared signature (mm1/mm3 align; default topology), so one
    # dispatch per round
    from repro.core.arch import ARCH_SPARSEMAP
    stats = sweep_runs["stacked_stats"]
    assert stats["signatures"] == \
        [(3, 16, ARCH_SPARSEMAP.topology.fingerprint, "u")]
    assert stats["dispatches"] == stats["rounds"]
    # unstacked pays one dispatch per alive task per round
    assert stats["dispatches"] < sweep_runs["unstacked_stats"]["dispatches"]


def test_run_method_sweep_grid_shape(sweep_runs):
    grid = sweep_runs["stacked"]
    assert sorted(grid) == sorted(METHODS)
    for m in METHODS:
        assert sorted(grid[m]) == sorted(WLS)
        for w in WLS:
            assert grid[m][w].extras["method"] == m
