"""Property tests: the JAX batch evaluator agrees with the numpy oracle —
on the default paper topology AND on the non-default registered ArchSpecs
— plus the pinned pre-refactor golden regression for ARCH_SPARSEMAP."""
import os
import zlib

import numpy as np
import pytest

try:        # hypothesis is an optional test extra (pyproject.toml)
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, strategies as st

from repro.configs.archs import (CLUSTER_CLOUD, DSTC_LIKE, EYERISS_LIKE,
                                 MAPLE_EDGE, QUANT_EDGE, SIGMA_LIKE,
                                 SYSTOLIC_MESH)
from repro.core import accel
from repro.core.cost_model import evaluate
from repro.core.encoding import GenomeSpec
from repro.core.jax_cost import JaxCostModel
from repro.core.workload import batched_spmm, spconv, spmm

CASES = [
    spmm("mm_small", 32, 64, 48, 0.2, 0.5),
    spmm("mm_dense", 124, 124, 124, 0.785, 0.785),
    spmm("mm_sparse", 128, 1024, 128, 0.006, 0.006),
    spconv("conv", 64, 32, 32, 256, 1, 1, 0.45, 0.252),
    batched_spmm("bmm", 4, 16, 32, 16, 0.3, 0.7),
]
PLATS = [accel.EDGE, accel.MOBILE, accel.CLOUD]


@pytest.mark.parametrize("wl", CASES, ids=[w.name for w in CASES])
@pytest.mark.parametrize("plat", PLATS, ids=[p.name for p in PLATS])
def test_agreement(wl, plat):
    spec = GenomeSpec(wl)
    jm = JaxCostModel(spec, plat)
    rng = np.random.default_rng(
        zlib.crc32(f"{wl.name}:{plat.name}".encode()))
    G = spec.random_genomes(rng, 400)
    out = jm(G)
    n_valid = 0
    for i, g in enumerate(G):
        rep = evaluate(spec.decode(g), plat)
        jv = bool(out["valid"][i])
        # skip razor-thin capacity margins (float32 vs float64)
        if rep.valid != jv:
            margin = min(
                abs(rep.glb_occupancy_bytes - plat.glb_bytes) /
                plat.glb_bytes if rep.valid else 1,
                abs(rep.pebuf_occupancy_bytes - plat.pe_buffer_bytes) /
                plat.pe_buffer_bytes if rep.valid else 1)
            assert margin < 5e-3, (
                f"genome {i}: oracle valid={rep.valid} ({rep.reason}) "
                f"jax valid={jv}")
            continue
        if rep.valid:
            n_valid += 1
            lg = np.log10(rep.edp)
            assert abs(lg - out["log10_edp"][i]) <= 2e-3 * max(abs(lg), 1), \
                f"genome {i}: edp oracle={rep.edp:.4e} jax log mismatch"
    # make sure the comparison is not vacuous for at least some cases
    if wl.name == "mm_small" and plat.name == "cloud":
        assert n_valid > 0


# ---------------------------------------------- non-default topologies


def _check_agreement(wl, arch, seed, n=64, require_valid=0):
    """Numpy-oracle vs JAX-kernel agreement on one (workload, arch)."""
    spec = GenomeSpec(wl, arch=arch)
    jm = JaxCostModel(spec, arch)
    rng = np.random.default_rng(seed)
    G = spec.random_genomes(rng, n)
    out = jm(G)
    n_valid = 0
    for i, g in enumerate(G):
        rep = evaluate(spec.decode(g), arch)
        jv = bool(out["valid"][i])
        if rep.valid != jv:
            # tolerate razor-thin float32-vs-float64 capacity margins, in
            # BOTH directions (the oracle reports occupancies on a
            # capacity rejection too)
            margins = [1.0]
            for _, sname, cap in arch.capacity_stores:
                if sname in rep.occupancy_bytes:
                    margins.append(
                        abs(rep.occupancy_bytes[sname] - cap) / cap)
            assert min(margins) < 5e-3, (
                f"genome {i}: oracle valid={rep.valid} ({rep.reason}) "
                f"jax valid={jv}")
            continue
        if rep.valid:
            n_valid += 1
            lg = np.log10(rep.edp)
            assert abs(lg - out["log10_edp"][i]) <= 2e-3 * max(abs(lg), 1), \
                f"genome {i}: edp oracle={rep.edp:.4e} jax log mismatch"
    assert n_valid >= require_valid
    return n_valid


@st.composite
def small_workloads(draw):
    m = draw(st.integers(min_value=2, max_value=48))
    k = draw(st.integers(min_value=2, max_value=48))
    n = draw(st.integers(min_value=2, max_value=48))
    dp = draw(st.floats(min_value=0.01, max_value=1.0))
    dq = draw(st.floats(min_value=0.01, max_value=1.0))
    return spmm(f"mm_{m}x{k}x{n}", m, k, n, dp, dq)


@settings(max_examples=10, deadline=None)
@given(small_workloads(), st.integers(min_value=0, max_value=2**31 - 1))
def test_agreement_maple_edge(wl, seed):
    """2-store Maple-style arch (3 mapping levels, 2 S/G sites): the
    generic numpy model and the generic kernel must agree."""
    _check_agreement(wl, MAPLE_EDGE, seed)


@settings(max_examples=10, deadline=None)
@given(small_workloads(), st.integers(min_value=0, max_value=2**31 - 1))
def test_agreement_cluster_cloud(wl, seed):
    """4-store clustered arch (7 mapping levels, 4 S/G sites)."""
    _check_agreement(wl, CLUSTER_CLOUD, seed)


@settings(max_examples=10, deadline=None)
@given(small_workloads(), st.integers(min_value=0, max_value=2**31 - 1))
def test_agreement_systolic_mesh(wl, seed):
    """Mesh NoC (no read multicast, reduction-tree output collection):
    the NoC-aware fills accounting must agree numpy-vs-kernel."""
    _check_agreement(wl, SYSTOLIC_MESH, seed)


@settings(max_examples=10, deadline=None)
@given(small_workloads(), st.integers(min_value=0, max_value=2**31 - 1))
def test_agreement_quant_edge(wl, seed):
    """1-byte on-chip words: the traced per-edge width path of the
    kernel must agree with the width-parameterized numpy oracle."""
    _check_agreement(wl, QUANT_EDGE, seed)


@settings(max_examples=10, deadline=None)
@given(small_workloads(), st.integers(min_value=0, max_value=2**31 - 1))
def test_agreement_eyeriss_like(wl, seed):
    """Fractional NoC both ways (row multicast f=14, column reduction
    f=12 on the 12x14 mesh): the traced-discount kernel path must agree
    with the numpy oracle."""
    _check_agreement(wl, EYERISS_LIKE, seed)


@settings(max_examples=10, deadline=None)
@given(small_workloads(), st.integers(min_value=0, max_value=2**31 - 1))
def test_agreement_dstc_like(wl, seed):
    """Row multicast plus cluster-local reduction (both fractional) on a
    4-store hierarchy."""
    _check_agreement(wl, DSTC_LIKE, seed)


@settings(max_examples=10, deadline=None)
@given(small_workloads(), st.integers(min_value=0, max_value=2**31 - 1))
def test_agreement_sigma_like(wl, seed):
    """Full multicast with a fractional reduction tree over a 16384-wide
    spatial level."""
    _check_agreement(wl, SIGMA_LIKE, seed)


def test_new_archs_reach_valid_points():
    """The comparison on the new topologies must not be vacuous: the
    engineer-default design (balanced OS mapping, uncompressed formats,
    no S/G) is valid on both, and oracle == kernel on it."""
    from repro.core.baselines import fixed_mapping_genes_for_arch
    wl = spmm("mm_probe", 32, 64, 48, 0.2, 0.5)
    for arch in (MAPLE_EDGE, CLUSTER_CLOUD):
        spec = GenomeSpec(wl, arch=arch)
        g = np.zeros(spec.length, dtype=np.int64)
        for k, v in fixed_mapping_genes_for_arch(spec, arch).items():
            g[k] = v
        rep = evaluate(spec.decode(g), arch)
        assert rep.valid, f"{arch.name}: {rep.reason}"
        out = JaxCostModel(spec, arch)(g[None, :])
        assert bool(out["valid"][0]), arch.name
        lg = np.log10(rep.edp)
        assert abs(lg - out["log10_edp"][0]) <= 2e-3 * max(abs(lg), 1)


def test_genome_layout_scales_with_arch():
    wl = spmm("mm_layout", 32, 64, 48, 0.2, 0.5)
    base = GenomeSpec(wl)
    maple = GenomeSpec(wl, arch=MAPLE_EDGE)
    cluster = GenomeSpec(wl, arch=CLUSTER_CLOUD)
    assert len(base.segments["perm"]) == 5
    assert len(maple.segments["perm"]) == 3
    assert len(cluster.segments["perm"]) == 7
    assert len(base.segments["sg"]) == 3
    assert len(maple.segments["sg"]) == 2
    assert len(cluster.segments["sg"]) == 4
    assert int(base.gene_ub[base.segments["tiling"].start]) == 5
    assert int(maple.gene_ub[maple.segments["tiling"].start]) == 3
    assert int(cluster.gene_ub[cluster.segments["tiling"].start]) == 7


# ---------------------------------------------- pinned golden regression


GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "arch_sparsemap_golden.npz")
SEARCH_GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                             "search_golden.json")


def test_fixed_seed_searches_match_pre_refactor_goldens_bit_for_bit():
    """Fixed-seed end-to-end searches (engine RNG streams + kernel)
    reproduce the pre-refactor best-EDPs exactly (stored as float hex)."""
    import json

    from repro.configs.paper_workloads import by_name
    from repro.core import search
    gold = json.load(open(SEARCH_GOLDEN))
    r1 = search.run("sparsemap", by_name("mm1"), "cloud", budget=600,
                    seed=3)
    assert r1.best_edp.hex() == gold["sparsemap_mm1_cloud_b600_s3"]
    r2 = search.run("pso", by_name("mm3"), "cloud", budget=400, seed=1)
    assert r2.best_edp.hex() == gold["pso_mm3_cloud_b400_s1"]


def test_arch_sparsemap_matches_pre_refactor_goldens_bit_for_bit():
    """ARCH_SPARSEMAP (the default) must reproduce the pre-ArchSpec
    stack's numbers EXACTLY: the golden file holds seeded genome batches
    and their kernel outputs captured before the refactor."""
    g = np.load(GOLDEN)
    cases = [
        spmm("mm_small", 32, 64, 48, 0.2, 0.5),
        spmm("mm_sparse", 128, 1024, 128, 0.006, 0.006),
        spconv("conv", 64, 32, 32, 256, 1, 1, 0.45, 0.252),
        batched_spmm("bmm", 4, 16, 32, 16, 0.3, 0.7),
    ]
    for wl in cases:
        spec = GenomeSpec(wl)
        for plat in PLATS:
            key = f"{wl.name}:{plat.name}"
            G = g[f"{key}:genomes"]
            res = JaxCostModel(spec, plat)(G)
            np.testing.assert_array_equal(
                g[f"{key}:jax_valid"], np.asarray(res["valid"]),
                err_msg=f"{key}: valid drifted")
            for fld, out_key in (("jax_edp", "edp"),
                                 ("jax_energy", "energy_pj"),
                                 ("jax_cycles", "cycles")):
                np.testing.assert_array_equal(
                    g[f"{key}:{fld}"], np.asarray(res[out_key]),
                    err_msg=f"{key}: {out_key} not bit-identical")
            # numpy oracle (float64) on the captured prefix
            ov, oe = g[f"{key}:np_valid"], g[f"{key}:np_edp"]
            for i, row in enumerate(G[: len(ov)]):
                rep = evaluate(spec.decode(row), plat)
                assert rep.valid == ov[i], f"{key} row {i}"
                assert (rep.edp if rep.valid else np.inf) == oe[i], \
                    f"{key} row {i}: oracle EDP drifted"
