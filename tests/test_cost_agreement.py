"""Property test: the JAX batch evaluator agrees with the numpy oracle."""
import zlib

import numpy as np
import pytest

from repro.core import accel
from repro.core.cost_model import evaluate
from repro.core.encoding import GenomeSpec
from repro.core.jax_cost import JaxCostModel
from repro.core.workload import batched_spmm, spconv, spmm

CASES = [
    spmm("mm_small", 32, 64, 48, 0.2, 0.5),
    spmm("mm_dense", 124, 124, 124, 0.785, 0.785),
    spmm("mm_sparse", 128, 1024, 128, 0.006, 0.006),
    spconv("conv", 64, 32, 32, 256, 1, 1, 0.45, 0.252),
    batched_spmm("bmm", 4, 16, 32, 16, 0.3, 0.7),
]
PLATS = [accel.EDGE, accel.MOBILE, accel.CLOUD]


@pytest.mark.parametrize("wl", CASES, ids=[w.name for w in CASES])
@pytest.mark.parametrize("plat", PLATS, ids=[p.name for p in PLATS])
def test_agreement(wl, plat):
    spec = GenomeSpec(wl)
    jm = JaxCostModel(spec, plat)
    rng = np.random.default_rng(
        zlib.crc32(f"{wl.name}:{plat.name}".encode()))
    G = spec.random_genomes(rng, 400)
    out = jm(G)
    n_valid = 0
    for i, g in enumerate(G):
        rep = evaluate(spec.decode(g), plat)
        jv = bool(out["valid"][i])
        # skip razor-thin capacity margins (float32 vs float64)
        if rep.valid != jv:
            margin = min(
                abs(rep.glb_occupancy_bytes - plat.glb_bytes) /
                plat.glb_bytes if rep.valid else 1,
                abs(rep.pebuf_occupancy_bytes - plat.pe_buffer_bytes) /
                plat.pe_buffer_bytes if rep.valid else 1)
            assert margin < 5e-3, (
                f"genome {i}: oracle valid={rep.valid} ({rep.reason}) "
                f"jax valid={jv}")
            continue
        if rep.valid:
            n_valid += 1
            lg = np.log10(rep.edp)
            assert abs(lg - out["log10_edp"][i]) <= 2e-3 * max(abs(lg), 1), \
                f"genome {i}: edp oracle={rep.edp:.4e} jax log mismatch"
    # make sure the comparison is not vacuous for at least some cases
    if wl.name == "mm_small" and plat.name == "cloud":
        assert n_valid > 0
