"""Equivalence tests for the vectorized ES engine: the array-at-once
operators must match the seed (per-individual Python loop) implementations
— exactly for best-so-far tracking and sensitivity scoring, and in
per-gene marginal statistics / end-to-end search trajectories for the
stochastic operators (the RNG streams differ, the distributions must
not)."""
import numpy as np
import pytest

from repro.configs.paper_workloads import by_name
from repro.core import search
from repro.core.baselines import sparsemap_setup
from repro.core.encoding import GenomeSpec
from repro.core.evolution import (_Budget, annealing_p_high,
                                  crossover, evolve, hshi_init, lhs_init,
                                  mutate)
from repro.core.sensitivity import SensitivityResult, build_probes, \
    score_probes
from repro.core.workload import spmm

WL = spmm("mm_vec", 32, 64, 48, 0.2, 0.5)


def _make_sens(spec, seed=0):
    """Synthetic sensitivity: perm + sg genes high, a small valid pool."""
    rng = np.random.default_rng(seed)
    high = np.zeros(spec.length, dtype=bool)
    high[spec.segments["perm"].slice] = True
    high[spec.segments["sg"].slice] = True
    scores = high.astype(np.float64)
    return SensitivityResult(scores=scores, high_mask=high,
                             valid_pool=spec.random_genomes(rng, 64),
                             threshold=0.75, evals_used=0)


# ------------------------------------------------- seed reference ops


def ref_mutate(genomes, spec, rng, p_mut, genes_per, sens, p_high):
    out = genomes.copy()
    L = spec.length
    for i in range(len(out)):
        if rng.random() >= p_mut:
            continue
        if sens is not None:
            seg = sens.high_indices if rng.random() < p_high \
                else sens.low_indices
            if len(seg) == 0:
                seg = np.arange(L)
        else:
            seg = np.arange(L)
        for _ in range(genes_per):
            g = int(seg[rng.integers(0, len(seg))])
            out[i, g] = rng.integers(0, spec.gene_ub[g])
    return out


def ref_crossover(parents, n_children, spec, rng, sens):
    L = spec.length
    if sens is not None:
        pts = {0, L}
        for a, b in sens.high_segments():
            pts.add(a)
            pts.add(b)
        cut_points = sorted(pts - {0, L}) or [L // 2]
    else:
        cut_points = list(range(1, L))
    kids = np.empty((n_children, L), dtype=parents.dtype)
    for i in range(n_children):
        a, b = rng.integers(0, len(parents), 2)
        cut = cut_points[rng.integers(0, len(cut_points))]
        kids[i, :cut] = parents[a, :cut]
        kids[i, cut:] = parents[b, cut:]
    return kids


class RefBudget:
    """The seed's per-element best-so-far loop."""

    def __init__(self, budget):
        self.budget = budget
        self.evals = 0
        self.valid = 0
        self.best = np.inf
        self.best_genome = None
        self.hist = []

    def register(self, genomes, out):
        n = min(len(genomes), self.budget - self.evals)
        valid = np.asarray(out["valid"])[:n]
        edp = np.asarray(out["edp"], dtype=np.float64)[:n].copy()
        edp[~valid] = np.inf
        for i in range(n):
            if edp[i] < self.best:
                self.best = float(edp[i])
                self.best_genome = genomes[i].copy()
            self.hist.append(self.best)
        self.evals += n
        self.valid += int(valid.sum())
        full = np.full(len(genomes), np.nan)   # NaN = truncated, not counted
        full[:n] = edp
        return full

    @property
    def exhausted(self):
        return self.evals >= self.budget


def ref_evolve(spec, batch_eval, cfg, sens, seeds=None):
    """The seed main loop over the reference operators (sens given, so no
    calibration — exactly the operator-dependent part of the search)."""
    rng = np.random.default_rng(cfg.seed)
    tracker = RefBudget(cfg.budget)
    pop = hshi_init(spec, batch_eval, sens, rng, cfg.pop_size,
                    cfg.n_cubes or cfg.pop_size,
                    min(cfg.cube_budget,
                        max(2, int(0.15 * cfg.budget) //
                            max(cfg.n_cubes or cfg.pop_size, 1))),
                    tracker)
    if seeds is not None and len(seeds):
        pop[: len(seeds)] = seeds[: len(pop)]
    edp = tracker.register(pop, batch_eval(pop))
    n_parents = max(2, int(cfg.pop_size * cfg.parent_frac))
    n_elite = max(1, int(cfg.pop_size * cfg.elite_frac))
    total_gens = max(1, (cfg.budget - tracker.evals) // cfg.pop_size)
    gen = 0
    while not tracker.exhausted:
        order = np.argsort(edp)
        parents = pop[order[:n_parents]]
        elites = pop[order[:n_elite]].copy()
        elite_edp = edp[order[:n_elite]].copy()
        p_high = annealing_p_high(gen, total_gens)
        kids = ref_crossover(parents, cfg.pop_size - n_elite, spec, rng,
                             sens)
        kids = ref_mutate(kids, spec, rng, cfg.p_mutation,
                          cfg.genes_per_mutation, sens, p_high)
        kids = spec.clip(kids)
        kedp = tracker.register(kids, batch_eval(kids))
        pop = np.concatenate([elites, kids], axis=0)
        edp = np.concatenate([elite_edp, kedp])
        gen += 1
    return tracker


# ------------------------------------------------- budget tracking


def test_budget_register_matches_reference_exactly():
    rng = np.random.default_rng(3)
    a, b = _Budget(500), RefBudget(500)
    for _ in range(6):
        genomes = rng.integers(0, 50, size=(100, 7))
        edp = np.exp(rng.normal(20, 4, size=100))
        valid = rng.random(100) < 0.3
        out = dict(edp=np.where(valid, edp, np.inf), valid=valid)
        ea = a.register(genomes, out)
        eb = b.register(genomes, out)
        np.testing.assert_array_equal(ea, eb)
    assert a.best == b.best
    assert a.evals == b.evals == 500
    assert a.valid == b.valid
    assert a.hist == b.hist
    np.testing.assert_array_equal(a.best_genome, b.best_genome)


def test_budget_register_tie_keeps_first_genome():
    a, b = _Budget(10), RefBudget(10)
    genomes = np.arange(8).reshape(4, 2)
    out = dict(edp=np.array([5.0, 3.0, 3.0, 7.0]),
               valid=np.ones(4, bool))
    a.register(genomes, out)
    b.register(genomes, out)
    np.testing.assert_array_equal(a.best_genome, b.best_genome)


# ------------------------------------------------- operator marginals


def test_mutate_marginals_match_reference():
    spec = GenomeSpec(WL)
    sens = _make_sens(spec)
    n = 6000
    base = spec.random_genomes(np.random.default_rng(0), n)
    vec = mutate(base, spec, np.random.default_rng(1),
                 p_mut=0.7, genes_per=2, sens=sens, p_high=0.6)
    ref = ref_mutate(base, spec, np.random.default_rng(2),
                     p_mut=0.7, genes_per=2, sens=sens, p_high=0.6)
    for m in (vec, ref):
        assert (m >= 0).all() and (m < spec.gene_ub[None, :]).all()
    # fraction of mutated rows ~ p_mut * P(any drawn value differs)
    row_frac_v = (vec != base).any(axis=1).mean()
    row_frac_r = (ref != base).any(axis=1).mean()
    assert abs(row_frac_v - row_frac_r) < 0.03
    # per-gene mutation rate: high genes get more mass at p_high=0.6
    gene_rate_v = (vec != base).mean(axis=0)
    gene_rate_r = (ref != base).mean(axis=0)
    np.testing.assert_allclose(gene_rate_v, gene_rate_r, atol=0.02)
    hi = sens.high_indices
    lo = sens.low_indices
    assert gene_rate_v[hi].mean() > gene_rate_v[lo].mean()


def test_mutate_uniform_marginals_match_reference():
    spec = GenomeSpec(WL)
    n = 6000
    base = np.zeros((n, spec.length), dtype=np.int64)
    vec = mutate(base, spec, np.random.default_rng(1),
                 p_mut=1.0, genes_per=3, sens=None, p_high=0.0)
    ref = ref_mutate(base, spec, np.random.default_rng(2),
                     p_mut=1.0, genes_per=3, sens=None, p_high=0.0)
    np.testing.assert_allclose((vec != base).mean(axis=0),
                               (ref != base).mean(axis=0), atol=0.02)
    # replacement values uniform over [0, ub): compare per-gene means of
    # the touched entries
    for impl in (vec, ref):
        touched = impl != base
        j = int(np.argmax(touched.sum(axis=0)))
        vals = impl[touched[:, j], j]
        assert abs(vals.mean() - (spec.gene_ub[j] - 1) / 2.0) \
            < 0.1 * spec.gene_ub[j]


def test_crossover_marginals_match_reference():
    spec = GenomeSpec(WL)
    sens = _make_sens(spec)
    parents = np.stack([np.zeros(spec.length, dtype=np.int64),
                        np.ones(spec.length, dtype=np.int64)])
    n = 8000
    vec = crossover(parents, n, spec, np.random.default_rng(1), sens)
    ref = ref_crossover(parents, n, spec, np.random.default_rng(2), sens)
    # per-gene probability of inheriting parent 1 must agree
    np.testing.assert_allclose(vec.mean(axis=0), ref.mean(axis=0),
                               atol=0.025)
    # high-sensitivity runs never fragmented (both impls)
    for kids in (vec, ref):
        for a, b in sens.high_segments():
            seg = kids[:, a:b]
            assert (seg == seg[:, :1]).all()


def test_crossover_uniform_marginals_match_reference():
    spec = GenomeSpec(WL)
    parents = np.stack([np.zeros(spec.length, dtype=np.int64),
                        np.ones(spec.length, dtype=np.int64)])
    n = 8000
    vec = crossover(parents, n, spec, np.random.default_rng(1), None)
    ref = ref_crossover(parents, n, spec, np.random.default_rng(2), None)
    np.testing.assert_allclose(vec.mean(axis=0), ref.mean(axis=0),
                               atol=0.025)


def test_lhs_init_stratification_preserved():
    spec = GenomeSpec(WL)
    pop = lhs_init(spec, np.random.default_rng(0), 60)
    assert pop.shape == (60, spec.length)
    assert (pop >= 0).all() and (pop < spec.gene_ub[None, :]).all()
    # every gene with ub >= pop hits ~pop distinct strata; the 6-valued
    # perm gene must hit all 6
    pg = pop[:, spec.segments["perm"].start]
    assert len(np.unique(pg)) == 6


# ------------------------------------------------- sensitivity scoring


def ref_score_probes(spec, probes, gene_idx, sampled_vals, out, rng,
                     n_contexts, n_samples, max_pairs):
    """The seed's triple-loop scoring (pair subsampling disabled by a
    large max_pairs so both impls use every pair)."""
    L = spec.length
    valid = np.asarray(out["valid"])
    edp = np.asarray(out["edp"], dtype=np.float64)
    scores = np.zeros(L)
    counts = np.zeros(L)
    idx = 0
    for i in range(n_contexts):
        for v in range(L):
            sl = slice(idx, idx + n_samples)
            idx += n_samples
            vv = sampled_vals[sl]
            ok = valid[sl]
            if ok.sum() < 2:
                continue
            vals = vv[ok].astype(np.float64)
            es = edp[sl][ok]
            n = len(vals)
            pairs = [(a, b) for a in range(n) for b in range(a + 1, n)
                     if vals[a] != vals[b]]
            if not pairs:
                continue
            s = 0.0
            for a, b in pairs:
                s += (abs(es[a] - es[b]) /
                      (abs(vals[a] - vals[b]) *
                       max(min(es[a], es[b]), 1e-30)))
            scores[v] += s / len(pairs)
            counts[v] += 1
    return np.where(counts > 0, scores / np.maximum(counts, 1), 0.0)


def test_sensitivity_scores_match_reference():
    spec, ev = search.get_evaluator(WL, "cloud")
    rng = np.random.default_rng(0)
    n_ctx, n_smp = 3, 8
    probes, gene_idx, vals = build_probes(spec, rng, n_ctx, n_smp)
    out = ev(probes)
    big = 10_000        # use ALL pairs in both implementations
    sens = score_probes(spec, probes, gene_idx, vals, out,
                        np.random.default_rng(1), n_ctx, n_smp,
                        max_pairs=big)
    ref = ref_score_probes(spec, probes, gene_idx, vals, out,
                           np.random.default_rng(2), n_ctx, n_smp,
                           max_pairs=big)
    np.testing.assert_allclose(sens.scores, ref, rtol=1e-10, atol=1e-12)


# ------------------------------------------------- HSHI + trajectories


def _cheap_eval(spec):
    """Deterministic numpy evaluator: valid iff the first tiling gene is
    even; EDP = a smooth positive function of the genome."""
    til = spec.segments["tiling"].start

    def ev(genomes):
        g = np.asarray(genomes)
        valid = (g[:, til] % 2) == 0
        edp = 1e6 + (g * np.arange(1, spec.length + 1)[None, :]).sum(1)
        return dict(valid=valid,
                    edp=np.where(valid, edp.astype(np.float64), np.inf))
    return ev


def test_hshi_marginals_match_reference_seed_behavior():
    spec = GenomeSpec(WL)
    sens = _make_sens(spec)
    ev = _cheap_eval(spec)
    pops = []
    for seed in (1, 2):
        tracker = _Budget(4000)
        pop = hshi_init(spec, ev, sens, np.random.default_rng(seed),
                        pop_size=100, n_cubes=100, cube_budget=8,
                        tracker=tracker)
        assert pop.shape == (100, spec.length)
        assert (pop >= 0).all() and (pop < spec.gene_ub[None, :]).all()
        assert tracker.evals > 0
        pops.append(pop)
    # cube stratification: the high-sensitivity perm gene must spread
    # across its value range rather than collapse
    pg = pops[0][:, spec.segments["perm"].start]
    assert len(np.unique(pg)) >= 4
    # most cubes found a valid individual under the cheap validity rule
    til = spec.segments["tiling"].start
    assert (pops[0][:, til] % 2 == 0).mean() > 0.8


@pytest.mark.parametrize("wl_name", ["mm1", "mm3"])
def test_evolve_trajectory_matches_reference(wl_name):
    """End-to-end: vectorized evolve vs the seed loop w/ reference
    operators — same budget, same precomputed sensitivity, same seeds —
    must land within tolerance of each other on paper workloads."""
    wl = by_name(wl_name)
    spec, ev = search.get_evaluator(wl, "cloud")
    from repro.core.sensitivity import calibrate
    sens = calibrate(spec, ev, np.random.default_rng(0),
                     n_contexts=3, n_samples=8)
    cfg, seeds = sparsemap_setup(spec, search._platform("cloud"),
                                 budget=700, seed=0)
    res = evolve(spec, ev, cfg, sens=sens, seeds=seeds)
    ref = ref_evolve(spec, ev, cfg, sens, seeds=seeds)
    assert res.evals == ref.evals == 700
    assert np.isfinite(res.best_edp) and np.isfinite(ref.best)
    assert abs(res.valid_fraction - ref.valid / ref.evals) < 0.2
    assert abs(np.log10(res.best_edp) - np.log10(ref.best)) < 1.0


# ------------------------------------------------- MultiSearch


def test_multisearch_matches_sequential_and_aligns_signatures():
    mm1, mm4 = by_name("mm1"), by_name("mm4")
    seq = {w.name: search.run("sparsemap", w, "cloud", budget=400, seed=0)
           for w in (mm1, mm4)}
    ms = search.MultiSearch(
        [search.SearchTask(w, "cloud", budget=400, seed=0)
         for w in (mm1, mm4)])
    res = ms.run()
    assert set(res) == {"mm1@cloud", "mm4@cloud"}
    # aligned group collapses two natural signatures onto one
    assert len(ms.stats["signatures"]) < len(ms.stats["natural_signatures"])
    for w in (mm1, mm4):
        a = seq[w.name]
        b = res[f"{w.name}@cloud"]
        assert b.evals == a.evals
        if np.isfinite(a.best_edp):
            # same RNG streams; only the inert prime padding differs
            assert abs(np.log10(b.best_edp) - np.log10(a.best_edp)) < 1e-3
        assert b.extras["natural_signature"] != b.extras["signature"] or \
            w.name == "mm4"


def test_run_sweep_same_signature_is_exact():
    mm1, mm3 = by_name("mm1"), by_name("mm3")   # same (3, 16) signature
    seq = {w.name: search.run("sparsemap", w, "cloud", budget=300, seed=1)
           for w in (mm1, mm3)}
    res = search.run_sweep([mm1, mm3], "cloud", budget=300, seed=1)
    for w in (mm1, mm3):
        b = res[f"{w.name}@cloud"]
        assert b.best_edp == seq[w.name].best_edp
        np.testing.assert_array_equal(
            np.asarray(b.history), np.asarray(seq[w.name].history))
