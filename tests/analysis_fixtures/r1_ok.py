# R1 fixture — CONFORMING: traced values stay traced; host math only on
# structural (non-traced) quantities.
WORD_BYTES = 2.0


def eval_one(genes, plat, dens_params):
    e_mac = plat[3]
    occ = dens_params[0]
    return genes * e_mac + occ


def builder(topo):
    wb = float(WORD_BYTES)            # builder-level, not a kernel scope
    n = int(len(topo))
    return wb, n
