# R2 fixture — CONFORMING: explicitly seeded generators only.
import numpy as np


def draw(n, seed):
    rng = np.random.default_rng(seed)
    ss = np.random.SeedSequence([seed, n])
    child = np.random.Generator(np.random.PCG64(seed + 1))
    return rng.random(n), ss, child
