# R3 fixture — CONFORMING: materialization only inside the sanctioned
# late thunks (nested function / lambda), never in the immediate body.
import numpy as np


def dispatch(models, segs, _time_block):
    res = run_segments(models, segs, defer=True)   # noqa: F821

    def harvest():
        return np.asarray(res)          # late thunk: sanctioned

    out = _time_block(lambda: np.asarray(res))
    return harvest, out
