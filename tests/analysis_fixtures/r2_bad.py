# R2 fixture — VIOLATING: global-state / unseeded RNG.
import random

import numpy as np


def draw(n):
    vals = np.random.rand(n)          # module-global numpy RNG
    gen = np.random.default_rng()     # unseeded generator
    x = random.random()               # stdlib global RNG
    return vals, gen, x
