# R4 fixture — VIOLATING: counter/registry mutation outside the lock.
_DISPATCHES = 0          # module-level init is exempt
_JIT_FNS = {}


def record(key, fn):
    global _DISPATCHES
    _DISPATCHES += 1     # unlocked increment
    _JIT_FNS[key] = fn   # unlocked subscript store
    _JIT_FNS.clear()     # unlocked mutating method
