# R1 fixture — VIOLATING: host coercions of traced kernel values.
import numpy as np


def eval_one(genes, plat, dens_params):
    e_mac = float(plat[3])            # bakes a traced number
    row = np.asarray(dens_params)     # materializes a traced row
    scale = plat * 2.0
    k = int(scale[0])                 # coercion of a propagated value
    return genes * e_mac + row.sum() + k


def nested_builder(plat):
    def inner(x):
        return x * plat.item()        # method coercion in a kernel scope
    return inner
