# R3 fixture — VIOLATING: eager host sync on deferred dispatch handles.
import numpy as np


def dispatch(models, segs, rows):
    res = run_segments(models, segs, defer=True)   # noqa: F821
    ys = np.asarray(res)                # materializes in-flight work
    handle = eval_stacked(models, rows, defer=True)  # noqa: F821
    handle.block_until_ready()          # blocks the dispatch path
    val = float(res)
    return ys, val
