# Suppression fixture: the violating line carries a reviewed
# noqa-contract annotation naming the rule it waives.
import numpy as np


def jitter(n):
    return np.random.rand(n)  # repro: noqa-contract(R2)


def still_bad(n):
    return np.random.rand(n)  # a second, unsuppressed violation
