# R4 fixture — CONFORMING: every mutation under the lock.
import threading

_LOCK = threading.RLock()
_DISPATCHES = 0
_JIT_FNS = {}


def record(key, fn):
    global _DISPATCHES
    with _LOCK:
        _DISPATCHES += 1
        _JIT_FNS[key] = fn


def snapshot():
    with _LOCK:
        return dict(_JIT_FNS), _DISPATCHES   # reads are fine anywhere
