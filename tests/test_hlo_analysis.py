"""HLO analyzer: exact dot flops with while-loop trip-count correction,
validated against XLA cost_analysis on scan-free graphs."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P, NamedSharding
from repro.launch.hlo_analysis import analyze
from repro.launch.xla_compat import xla_cost_analysis

def check(name, got, want, tol=0.02):
    rel = abs(got - want) / max(abs(want), 1)
    assert rel <= tol, f"{name}: got {got:.4e} want {want:.4e}"
    print(f"{name} ok ({got:.4e})")

# 1. scan-free matmul chain: flops AND traffic match cost_analysis
def f1(x, w1, w2):
    return jnp.tanh(x @ w1) @ w2
c1 = jax.jit(f1).lower(
    jax.ShapeDtypeStruct((256,512), jnp.bfloat16),
    jax.ShapeDtypeStruct((512,512), jnp.bfloat16),
    jax.ShapeDtypeStruct((512,256), jnp.bfloat16)).compile()
a1 = analyze(c1.as_text())
check("flops1", a1["dot_flops"], 2*256*512*512 + 2*256*512*256)
check("traffic1", a1["traffic_bytes"],
      xla_cost_analysis(c1).get("bytes accessed"), tol=0.1)

# 2. scan x8: trip count corrected (XLA raw counts the body once)
def f2(x, w):
    def body(c, wi):
        return jnp.tanh(c @ wi), None
    return jax.lax.scan(body, x, w)[0]
c2 = jax.jit(f2).lower(
    jax.ShapeDtypeStruct((256,256), jnp.bfloat16),
    jax.ShapeDtypeStruct((8,256,256), jnp.bfloat16)).compile()
a2 = analyze(c2.as_text())
check("flops2", a2["dot_flops"], 8 * 2*256**3)
assert xla_cost_analysis(c2).get("flops") < 0.5 * a2["dot_flops"], \
    "XLA raw should undercount (this is the bug we correct)"
print("undercount confirmed")

# 3. nested scans multiply
def f3(x, w):
    def outer(c, wi):
        def inner(cc, _):
            return jnp.tanh(cc @ wi), None
        return jax.lax.scan(inner, c, None, length=4)[0], None
    return jax.lax.scan(outer, x, w)[0]
c3 = jax.jit(f3).lower(
    jax.ShapeDtypeStruct((128,128), jnp.bfloat16),
    jax.ShapeDtypeStruct((8,128,128), jnp.bfloat16)).compile()
a3 = analyze(c3.as_text())
check("flops3", a3["dot_flops"], 8*4*2*128**3)

# 4. sharded: per-device flops + collective bytes appear
mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2,4), ("data","model"))
def f4(x, w):
    return jnp.sum(x @ w)
c4 = jax.jit(f4, in_shardings=(NamedSharding(mesh, P("data", None)),
                               NamedSharding(mesh, P(None, "model"))),
             out_shardings=NamedSharding(mesh, P())).lower(
    jax.ShapeDtypeStruct((256,512), jnp.bfloat16),
    jax.ShapeDtypeStruct((512,512), jnp.bfloat16)).compile()
a4 = analyze(c4.as_text())
check("flops4", a4["dot_flops"], 2*256*512*512/8)
assert a4["coll_count"] >= 1
print("HLO_ANALYSIS OK")
"""


@pytest.mark.slow
def test_hlo_analysis_subprocess(subprocess_env):
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env=subprocess_env(), cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))))
    assert "HLO_ANALYSIS OK" in r.stdout, r.stdout + "\n" + r.stderr
