"""Tiny fallback for the subset of ``hypothesis`` this repo's tests use,
so the suite still COLLECTS AND RUNS where hypothesis is not installed
(it is an optional test extra — see pyproject.toml / COMPAT.md).

The shim is NOT hypothesis: no shrinking, no failure database, just
seeded pseudo-random example generation for ``@given`` with the
``integers`` / ``floats`` / ``composite`` strategies and a pass-through
``settings`` decorator.  Real hypothesis is preferred automatically when
importable:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_shim import given, settings, strategies as st
"""
from __future__ import annotations

import functools
import types
import zlib

import numpy as np

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw_fn):
        self._draw = draw_fn

    def example_from(self, rng: np.random.Generator):
        return self._draw(rng)


def _integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)))


def _floats(min_value: float, max_value: float, **_: object) -> _Strategy:
    return _Strategy(
        lambda rng: float(min_value + rng.random() *
                          (max_value - min_value)))


def _composite(fn):
    """``@st.composite`` — the wrapped function receives ``draw``."""
    @functools.wraps(fn)
    def make(*args, **kwargs):
        def draw_fn(rng):
            def draw(strategy):
                return strategy.example_from(rng)
            return fn(draw, *args, **kwargs)
        return _Strategy(draw_fn)
    return make


strategies = types.SimpleNamespace(
    integers=_integers, floats=_floats, composite=_composite)
st = strategies


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES,
             deadline=None, **_: object):
    def deco(test_fn):
        test_fn._shim_max_examples = max_examples
        return test_fn
    return deco


def given(*strats: _Strategy):
    def deco(test_fn):
        # NOTE: no functools.wraps — pytest would introspect the wrapped
        # signature and treat the drawn parameters as fixtures.
        def wrapper():
            n = getattr(wrapper, "_shim_max_examples",
                        _DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(test_fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for _ in range(n):
                drawn = [s.example_from(rng) for s in strats]
                test_fn(*drawn)
        wrapper.__name__ = test_fn.__name__
        wrapper.__qualname__ = test_fn.__qualname__
        wrapper.__doc__ = test_fn.__doc__
        wrapper.__module__ = test_fn.__module__
        wrapper._shim_max_examples = getattr(
            test_fn, "_shim_max_examples", _DEFAULT_MAX_EXAMPLES)
        return wrapper
    return deco
