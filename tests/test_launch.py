"""Launcher integration tests: one real dry-run cell (subprocess, 512
forced devices, lower+compile+roofline extraction), the training driver
end to end with checkpoint restart, and the serving driver.  All
subprocesses share the session-scoped compiled-artifact cache
(tests/conftest.py), so repeat full-tier runs skip the XLA compiles."""
import json
import os
import subprocess
import sys
import tempfile

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_cmd(args, env, timeout=900):
    return subprocess.run([sys.executable] + args, capture_output=True,
                          text=True, timeout=timeout, env=env, cwd=ROOT)


pytestmark = pytest.mark.slow      # subprocess lower+compile integration


def test_dryrun_single_cell(subprocess_env):
    """xlstm decode_32k: the fastest cell — full lower+compile on the
    256-chip production mesh with roofline extraction."""
    r = run_cmd(["-m", "repro.launch.dryrun", "--arch", "xlstm-350m",
                 "--shape", "decode_32k"], subprocess_env())
    assert r.returncode == 0, r.stderr[-2000:]
    rec = json.loads(r.stdout.strip().splitlines()[-1])
    assert rec["status"] == "ok"
    assert rec["mesh"] == "16x16"
    assert rec["flops_per_device"] > 0
    assert rec["bytes_per_device"] > 0
    assert rec["bottleneck"] in ("compute", "memory", "collective")
    assert rec["t_compute_s"] >= 0 and rec["t_memory_s"] > 0


def test_train_driver_with_crash_recovery(subprocess_env):
    with tempfile.TemporaryDirectory() as d:
        # cache=False: the restart path loading cached executables
        # segfaults on 0.4.x CPU (see conftest.subprocess_env)
        r = run_cmd(["-m", "repro.launch.train", "--arch", "xlstm-350m",
                     "--smoke", "--steps", "12", "--batch", "2",
                     "--seq", "32", "--ckpt-dir", d, "--ckpt-every", "4",
                     "--inject-failure-at", "6", "--log-every", "4"],
                    subprocess_env(cache=False))
        assert r.returncode == 0, r.stdout + r.stderr[-2000:]
        assert '"restarts": 1' in r.stdout
        # checkpoints exist
        assert any(x.startswith("step_") for x in os.listdir(d))


def test_serve_driver(subprocess_env):
    r = run_cmd(["-m", "repro.launch.serve", "--arch", "zamba2-2.7b",
                 "--smoke", "--batch", "2", "--prompt-len", "8",
                 "--gen", "4"], subprocess_env())
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    assert "serve ok" in r.stdout
