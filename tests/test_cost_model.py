"""Cost-model semantics: loop-nest reuse, sparsity effects, validity."""
import pytest

from repro.core import accel
from repro.core.cost_model import Design, evaluate, make_tensor_format
from repro.core.mapping import Mapping, balanced_mapping
from repro.core.sparse import (FMT_B, FMT_CP, FMT_U, FMT_UOP, SparseStrategy,
                               TensorFormat, fiber_tree_bytes)
from repro.core.workload import spmm


def tiny_mapping(wl, perm=("M", "N", "K")):
    """Everything tiled at L1_T (pure DRAM-streaming mapping)."""
    factors = [dict(wl.dim_sizes)] + [dict() for _ in range(4)]
    return Mapping(workload=wl, factors=tuple(factors),
                   perms=tuple(perm for _ in range(5)))


def strategy_uncompressed(mapping):
    wl = mapping.workload
    fmts = {t.name: make_tensor_format(mapping, t.name, (0, 0, 0, 0, 0))
            for t in wl.tensors}
    return SparseStrategy(formats=fmts, sg={"L2": 0, "L3": 0, "C": 0})


# ------------------------------------------------------- loop-nest reuse
def test_fills_output_stationary():
    """perm (M,N,K): K innermost -> Z written to DRAM exactly once; P
    refetched once per N iteration."""
    wl = spmm("t", 4, 2, 4, 1.0, 1.0)
    mp = tiny_mapping(wl, perm=("M", "N", "K"))
    assert mp.fills("glb", "Z") == 16                 # |Z| = 4*4
    assert mp.fills("glb", "P") == 4 * 2 * 4          # P refetched per n
    assert mp.fills("glb", "Q") == 4 * 2 * 4          # Q refetched per m


def test_fills_k_outermost_thrashes_z():
    """perm (K,M,N): Z tile revisited per K iteration."""
    wl = spmm("t", 4, 2, 4, 1.0, 1.0)
    mp = tiny_mapping(wl, perm=("K", "M", "N"))
    assert mp.fills("glb", "Z") == 2 * 16             # K thrash
    # P irrelevant to N; N is the innermost loop -> temporal reuse of the
    # P element across N: fills = K*M = 8
    assert mp.fills("glb", "P") == 8


def test_fills_suffix_reuse_exact():
    wl = spmm("t", 4, 2, 4, 1.0, 1.0)
    mp = tiny_mapping(wl, perm=("K", "M", "N"))
    # P relevant dims (M,K); outer nest = [K:2, M:4, N:4]; innermost N is
    # irrelevant -> suffix; fills = tile(1) * 2 * 4 = 8
    assert mp.fills("glb", "P") == 8
    # Q relevant (K,N): all of K,N relevant, M in middle thrashes
    # fills = 2 * 4 * 4 = 32
    assert mp.fills("glb", "Q") == 32


def test_spatial_multicast_does_not_multiply():
    """An irrelevant spatial loop multicasts: no extra upstream traffic."""
    wl = spmm("t", 4, 2, 4, 1.0, 1.0)
    factors = [dict(), dict(), {"M": 4}, dict(), dict()]
    factors[0] = {d: s for d, s in wl.dim_sizes.items()}
    factors[0]["M"] = 1
    mp = Mapping(workload=wl, factors=tuple(factors),
                 perms=tuple(("M", "N", "K") for _ in range(5)))
    # Q irrelevant to M; M is spatial at L2_S -> GLB reads of Q not scaled
    # by the M fanout
    fills_q = mp.fills("pebuf", "Q")
    assert fills_q == 2 * 4                      # |Q| once
    # P IS relevant to M (distribution, not multicast), and the temporal
    # N loop outside K thrashes P: fills = N(4) * K(2) * M3(4) = 32
    assert mp.fills("pebuf", "P") == 32


# ------------------------------------------------------- sparsity
def test_gate_saves_energy_not_cycles():
    wl = spmm("t", 16, 16, 16, 0.5, 0.5)
    mp = balanced_mapping(wl, 256, 4)
    base = strategy_uncompressed(mp)
    rep0 = evaluate(Design(mp, base), accel.MOBILE)
    gated = SparseStrategy(formats=base.formats,
                           sg={"L2": 0, "L3": 0, "C": 3})   # gate P<->Q
    rep1 = evaluate(Design(mp, gated), accel.MOBILE)
    assert rep0.valid and rep1.valid
    assert rep1.energy_pj < rep0.energy_pj
    assert rep1.cycles == rep0.cycles


def test_skip_saves_energy_and_cycles():
    wl = spmm("t", 16, 16, 16, 0.5, 0.5)
    mp = balanced_mapping(wl, 256, 4)
    base = strategy_uncompressed(mp)
    # compress Q (leader) on its innermost temporal sub-dim so skip is legal
    fmts = dict(base.formats)
    fmts["Q"] = make_tensor_format(mp, "Q", (0, 0, 0, 1, 1))
    ok, why = fmts["Q"].valid()
    assert ok, why
    skipped = SparseStrategy(formats=fmts, sg={"L2": 0, "L3": 0, "C": 4})
    rep0 = evaluate(Design(mp, base), accel.MOBILE)
    rep1 = evaluate(Design(mp, skipped), accel.MOBILE)
    if not rep1.valid:
        pytest.skip(f"mapping made skip invalid: {rep1.reason}")
    assert rep1.energy_pj < rep0.energy_pj
    assert rep1.compute_cycles < rep0.compute_cycles


def test_denser_tensors_cost_more():
    """With Gate P<->Q at compute, MAC energy scales with dP*dQ."""
    reps = []
    for dens in (0.1, 0.5, 1.0):
        wl = spmm("t", 32, 32, 32, dens, dens)
        mp = balanced_mapping(wl, 256, 4)
        st = strategy_uncompressed(mp)
        st = SparseStrategy(formats=st.formats,
                            sg={"L2": 0, "L3": 0, "C": 3})
        rep = evaluate(Design(mp, st), accel.MOBILE)
        assert rep.valid, rep.reason
        reps.append(rep.energy_pj)
    assert reps[0] < reps[1] < reps[2]


# ------------------------------------------------------- formats
def test_bitmask_metadata_is_one_bit_per_position():
    fmt = TensorFormat("P", (FMT_B,), (64,))
    data_b, meta_b = fiber_tree_bytes(fmt, density=0.25, word_bytes=2)
    assert meta_b == 64 / 8
    assert data_b == 64 * 0.25 * 2


def test_uncompressed_has_no_metadata():
    fmt = TensorFormat("P", (FMT_U, FMT_U), (8, 8))
    data_b, meta_b = fiber_tree_bytes(fmt, density=0.1)
    assert meta_b == 0.0
    assert data_b == 64 * 2


def test_uop_needs_partner():
    assert not TensorFormat("P", (FMT_UOP,), (8,)).valid()[0]
    assert not TensorFormat("P", (FMT_UOP, FMT_U), (8, 8)).valid()[0]
    assert TensorFormat("P", (FMT_UOP, FMT_CP), (8, 8)).valid()[0]


def test_csr_is_uop_cp():
    """UOP(dim M) - CP(dim K) == CSR (paper §III.A.2)."""
    fmt = TensorFormat("P", (FMT_UOP, FMT_CP), (32, 64))
    d = 0.1
    data_b, meta_b = fiber_tree_bytes(fmt, density=d)
    nnz = 32 * 64 * d
    # CP coords: ~log2(64) bits per nnz; UOP offsets: 33 * log2(2048) bits
    assert meta_b >= nnz * 6 / 8
    assert data_b == pytest.approx(nnz * 2)


# ------------------------------------------------------- validity
def test_fanout_overflow_invalid():
    wl = spmm("t", 64, 64, 64, 1.0, 1.0)
    factors = [dict(), dict(), {"M": 64, "N": 64}, dict(), {"K": 64}]
    mp = Mapping(workload=wl, factors=tuple(factors),
                 perms=tuple(("M", "N", "K") for _ in range(5)))
    st = strategy_uncompressed(mp)
    rep = evaluate(Design(mp, st), accel.EDGE)    # 256 PEs, 1 MAC
    assert not rep.valid
    assert "fanout" in rep.reason


def test_glb_overflow_invalid():
    wl = spmm("t", 512, 512, 512, 1.0, 1.0)
    # everything in GLB tile (all factors at L2_T)
    factors = [dict(), dict(wl.dim_sizes), dict(), dict(), dict()]
    mp = Mapping(workload=wl, factors=tuple(factors),
                 perms=tuple(("M", "N", "K") for _ in range(5)))
    st = strategy_uncompressed(mp)
    rep = evaluate(Design(mp, st), accel.EDGE)    # 128 KB GLB < 1.5 MB tiles
    assert not rep.valid
    assert "GLB overflow" in rep.reason


def test_skip_uncompressed_leader_invalid():
    wl = spmm("t", 16, 16, 16, 0.5, 0.5)
    mp = balanced_mapping(wl, 256, 4)
    base = strategy_uncompressed(mp)
    bad = SparseStrategy(formats=base.formats, sg={"L2": 4, "L3": 0, "C": 0})
    rep = evaluate(Design(mp, bad), accel.MOBILE)
    assert not rep.valid
    assert "uncompressed" in rep.reason


def test_edp_is_cycles_times_energy():
    wl = spmm("t", 16, 16, 16, 0.5, 0.5)
    mp = balanced_mapping(wl, 256, 4)
    rep = evaluate(Design(mp, strategy_uncompressed(mp)), accel.MOBILE)
    assert rep.valid
    assert rep.edp == pytest.approx(rep.cycles * rep.energy_pj)
