"""Substrate tests: data pipeline determinism, checkpoint/restore,
supervisor crash recovery, straggler monitor, gradient compression math,
and the multi-device selftest (subprocess with forced host devices)."""
import os
import subprocess
import sys
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.configs import smoke_config
from repro.configs.shapes import ShapeSpec
from repro.data.pipeline import DataConfig, SyntheticLM, make_data
from repro.optim.compression import (dequantize_int8, init_error_feedback,
                                     quantize_int8, topk_ef_step,
                                     topk_sparsify)
from repro.runtime.fault_tolerance import (ElasticPlan, StepMonitor,
                                           Supervisor)


# --------------------------------------------------------------- data
def test_data_deterministic_and_seekable():
    cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=8)
    d = SyntheticLM(cfg)
    a = d.batch_at(17)
    b = d.batch_at(17)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = d.batch_at(18)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_sharding_partitions_batch():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8)
    d = SyntheticLM(cfg)
    s0 = d.batch_at(5, shard=(0, 4))
    s1 = d.batch_at(5, shard=(1, 4))
    assert s0["tokens"].shape == (2, 32)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_data_token_range():
    cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=4)
    d = SyntheticLM(cfg)
    b = d.batch_at(0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 100


def test_make_data_matches_arch():
    mc = smoke_config("qwen2-vl-7b")
    sh = ShapeSpec("t", 64, 4, "train")
    d = make_data(mc, sh)
    b = d.batch_at(0)
    assert "frontend" in b
    assert b["frontend"].shape == (4, mc.n_frontend_tokens, mc.d_model)
    assert b["tokens"].shape[1] == 64 - mc.n_frontend_tokens


# --------------------------------------------------------------- ckpt
def test_checkpoint_roundtrip_and_prune():
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16)}}
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            ckpt.save(d, s, tree, keep_last=2)
        assert ckpt.latest_step(d) == 5
        steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
        assert len(steps) == 2          # pruned
        restored = ckpt.restore(d, 5, tree)
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]))
        assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_shape_mismatch_raises():
    tree = {"a": jnp.ones((3, 4))}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 0, tree)
        bad = {"a": jnp.ones((4, 4))}
        with pytest.raises(ValueError):
            ckpt.restore(d, 0, bad)


# --------------------------------------------------------------- FT
def test_supervisor_recovers_from_crash():
    with tempfile.TemporaryDirectory() as d:
        crashed = {"done": False}

        def step_fn(state, step):
            if step == 7 and not crashed["done"]:
                crashed["done"] = True
                raise RuntimeError("injected node failure")
            return {"x": state["x"] + 1.0}

        sup = Supervisor(d, ckpt_every=3, max_restarts=2)
        state, report = sup.run({"x": jnp.float32(0)}, step_fn, 10)
        assert report["restarts"] == 1
        assert float(state["x"]) == 10.0     # every step applied once


def test_supervisor_gives_up():
    with tempfile.TemporaryDirectory() as d:
        def step_fn(state, step):
            raise RuntimeError("permafail")
        sup = Supervisor(d, ckpt_every=1, max_restarts=2)
        with pytest.raises(RuntimeError):
            sup.run({"x": jnp.float32(0)}, step_fn, 3)


def test_straggler_monitor():
    m = StepMonitor(warmup_steps=2, straggler_factor=2.0)
    flags = [m.observe(i, 0.1) for i in range(5)]
    assert not any(flags)
    assert m.observe(5, 0.5)            # 5x slower -> straggler
    assert m.straggler_rate > 0


def test_elastic_plan():
    p = ElasticPlan.plan(n_devices=256, model_parallel=16)
    assert p.data_parallel == 16
    p2 = ElasticPlan.plan(n_devices=240, model_parallel=16)
    assert p2.data_parallel == 15       # shrink tolerated
    with pytest.raises(RuntimeError):
        ElasticPlan.plan(n_devices=8, model_parallel=16)
    assert p.host_shard(3) == (3, 16)


# --------------------------------------------------------------- comp
def test_int8_quantize_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((128,)) * 3, jnp.float32)
    q, s = quantize_int8(x)
    y = dequantize_int8(q, s)
    assert float(jnp.abs(y - x).max()) <= float(s) * 0.51


def test_topk_error_feedback_preserves_mass():
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
    ef = init_error_feedback(g)
    total_sent = jnp.zeros_like(g["w"])
    for _ in range(50):
        comp, ef = topk_ef_step(g, ef, frac=0.05)
        total_sent = total_sent + comp["w"]
    # with a CONSTANT gradient, sent mass converges to ~n * g
    np.testing.assert_allclose(np.asarray(total_sent) / 50,
                               np.asarray(g["w"]), atol=0.35)


def test_topk_sparsity_level():
    x = jnp.asarray(np.random.default_rng(2).standard_normal((1000,)),
                    jnp.float32)
    sx, mask = topk_sparsify(x, 0.01)
    assert 5 <= int(mask.sum()) <= 20


# --------------------------------------------------------------- multi-dev
@pytest.mark.slow
def test_multidevice_selftest_subprocess(subprocess_env):
    """pipeline PP + compressed psum + sharded-vs-single train step +
    elastic restore, on 8 forced host devices; shares the session
    compiled-artifact cache (tests/conftest.py)."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.distributed.selftest"],
        capture_output=True, text=True, timeout=900, env=subprocess_env(),
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert "SELFTEST OK" in r.stdout, r.stdout + "\n" + r.stderr
