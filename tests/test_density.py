"""DensityModel hierarchy: brute-force enumeration oracles for every
built-in model's tile occupancy, the Uniform bit-for-bit golden
regression (explicit Uniform(d) == seed float semantics against
tests/golden/arch_sparsemap_golden.npz), numpy-vs-JAX agreement on
structured workloads, and the compilation-sharing / mega-batching
contract (a BlockNM family shares one XLA compilation; a mixed
uniform/banded/N:M fleet runs at 1.0 dispatches/round)."""
import itertools
import math
import os

import numpy as np
import pytest

try:        # hypothesis is an optional test extra (pyproject.toml)
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import jax_cost, search
from repro.core.cost_model import evaluate
from repro.core.density import (Banded, BlockNM, DensityModel, Uniform,
                                as_density, param_row, param_width)
from repro.core.encoding import GenomeSpec
from repro.core.jax_cost import JaxCostModel, eval_stacked
from repro.core.sparse import FMT_B, FMT_CP, FMT_RLE, TensorFormat, \
    fiber_tree_bytes
from repro.core.workload import TensorSpec, spmm


# ------------------------------------------- brute-force occupancy oracles


def _enum_uniform_nonempty(d: float, e: int) -> float:
    """P(a block of e i.i.d. Bernoulli(d) elements has >= 1 nonzero), by
    exhaustive enumeration of all 2^e patterns."""
    p = 0.0
    for bits in itertools.product((0, 1), repeat=e):
        k = sum(bits)
        if k > 0:
            p += (d ** k) * ((1.0 - d) ** (e - k))
    return p


def _enum_block_nm_nonempty(n: int, m: int, e: int) -> float:
    """P(a fixed window of e of an m-block's positions intersects the n
    uniformly placed nonzeros), enumerating all C(m, n) placements."""
    window = set(range(e))
    total = hits = 0
    for placement in itertools.combinations(range(m), n):
        total += 1
        if window & set(placement):
            hits += 1
    return hits / total


@settings(max_examples=30, deadline=None)
@given(st.floats(min_value=0.05, max_value=1.0),
       st.integers(min_value=1, max_value=7))
def test_uniform_occupancy_matches_enumeration(d, e):
    assert Uniform(d).block_nonempty(e) == \
        pytest.approx(_enum_uniform_nonempty(d, e), rel=1e-9, abs=1e-12)


@settings(max_examples=30, deadline=None)
@given(st.floats(min_value=0.05, max_value=1.0),
       st.floats(min_value=0.05, max_value=1.0),
       st.integers(min_value=1, max_value=7))
def test_banded_occupancy_matches_enumeration(frac, cov, e):
    # two-phase model: block in band w.p. cov (uniform at d/cov inside),
    # exactly empty outside
    d = frac * cov
    model = Banded(d, cov)
    expect = cov * _enum_uniform_nonempty(d / cov, e)
    assert model.block_nonempty(e) == \
        pytest.approx(expect, rel=1e-9, abs=1e-12)


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=1, max_value=8),
       st.integers(min_value=1, max_value=8),
       st.integers(min_value=1, max_value=10))
def test_block_nm_occupancy_matches_enumeration(n, m, e):
    n = min(n, m)
    model = BlockNM(n, m)
    if e <= m:
        expect = _enum_block_nm_nonempty(n, m, e)
        assert model.block_nonempty(e) == pytest.approx(expect, rel=1e-9)
    else:
        assert model.block_nonempty(e) == 1.0


@settings(max_examples=20, deadline=None)
@given(st.floats(min_value=0.02, max_value=0.98),
       st.integers(min_value=1, max_value=6),
       st.integers(min_value=2, max_value=8))
def test_model_invariants(d, n, m):
    """block_nonempty(1) == mean density; occupancy is monotone in the
    block size and bounded by [density, 1]."""
    n = min(n, m)
    models = [Uniform(d), Banded(d * 0.5, max(d, 0.5)), BlockNM(n, m)]
    for model in models:
        assert model.block_nonempty(1) == pytest.approx(model.density,
                                                        rel=1e-12)
        prev = 0.0
        for e in range(1, 2 * m + 2):
            occ = model.block_nonempty(e)
            assert prev - 1e-12 <= occ <= 1.0 + 1e-12
            prev = occ
        assert model.hit_rate() == pytest.approx(model.density)


def test_model_validation():
    with pytest.raises(ValueError):
        Uniform(0.0)
    with pytest.raises(ValueError):
        Uniform(1.5)
    with pytest.raises(ValueError):
        Banded(0.5, 0.25)        # in-band density would exceed 1
    with pytest.raises(ValueError):
        Banded(0.1, 1.5)
    with pytest.raises(ValueError):
        BlockNM(5, 4)
    with pytest.raises(ValueError):
        BlockNM(0, 4)
    assert BlockNM(2, 4).density == 0.5
    assert as_density(0.25) == Uniform(0.25)
    assert as_density(Banded(0.1, 0.5)) == Banded(0.1, 0.5)
    assert isinstance(as_density(1), Uniform)


def test_param_rows():
    """The traced rows carry [family code, hit rate, params...]."""
    w = param_width()
    for model, code, tail in [(Uniform(0.3), 0.0, (0.3,)),
                              (Banded(0.1, 0.5), 1.0, (0.1, 0.5)),
                              (BlockNM(2, 4), 2.0, (2.0, 4.0))]:
        row = param_row(model)
        assert len(row) == w
        assert row[0] == code
        assert row[1] == pytest.approx(model.hit_rate())
        assert row[2:2 + len(tail)] == tail


def test_unregistered_family_rejected():
    class Weird(DensityModel):
        family = "weird_unregistered"
    with pytest.raises(KeyError):
        param_row(Weird())


# --------------------------------------------- byte-model structure effects


def test_structure_moves_the_byte_model():
    """Same mean density, different structure, different bytes: a banded
    operand's big empty regions shrink keep-based metadata (RLE/CP),
    while a 2:4 operand's occupancy saturates faster than uniform."""
    fmt = TensorFormat("P", (FMT_RLE, FMT_CP), (64, 64))
    d = 0.125
    _, meta_u = fiber_tree_bytes(fmt, d)
    _, meta_b = fiber_tree_bytes(fmt, Banded(d, 0.25))
    assert meta_b < meta_u
    fmt2 = TensorFormat("Q", (FMT_B, FMT_CP), (8, 2))
    _, meta_u2 = fiber_tree_bytes(fmt2, 0.5)
    _, meta_nm = fiber_tree_bytes(fmt2, BlockNM(2, 4))
    assert meta_nm > meta_u2          # small blocks: N:M hits more often


def test_fiber_tree_bytes_float_equals_uniform_bitwise():
    rng = np.random.default_rng(7)
    for _ in range(50):
        lens = tuple(int(rng.integers(2, 32))
                     for _ in range(int(rng.integers(1, 4))))
        fmts = tuple(int(rng.integers(0, 4)) for _ in lens)
        fmt = TensorFormat("P", fmts, lens)
        d = float(rng.uniform(0.01, 1.0))
        assert fiber_tree_bytes(fmt, d) == fiber_tree_bytes(fmt, Uniform(d))


# ------------------------------------------------ golden: Uniform == seed


GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "arch_sparsemap_golden.npz")


def test_explicit_uniform_matches_seed_goldens_bit_for_bit():
    """Workloads declared with explicit Uniform(d) models reproduce the
    pre-DensityModel kernel outputs EXACTLY (same baked uniform kernel,
    same constants) against the pinned golden captures."""
    g = np.load(GOLDEN)
    wl = spmm("mm_small", 32, 64, 48, Uniform(0.2), Uniform(0.5))
    assert not wl.structured_density
    spec = GenomeSpec(wl)
    jm = JaxCostModel(spec, "cloud")
    assert jm.signature[3] == "u"
    key = "mm_small:cloud"
    G = g[f"{key}:genomes"]
    res = jm(G)
    np.testing.assert_array_equal(g[f"{key}:jax_valid"],
                                  np.asarray(res["valid"]))
    for fld, out_key in (("jax_edp", "edp"), ("jax_energy", "energy_pj"),
                        ("jax_cycles", "cycles")):
        np.testing.assert_array_equal(
            g[f"{key}:{fld}"], np.asarray(res[out_key]),
            err_msg=f"{out_key} drifted under explicit Uniform models")
    # numpy oracle on the captured prefix, bit-for-bit too
    ov, oe = g[f"{key}:np_valid"], g[f"{key}:np_edp"]
    for i, row in enumerate(G[: len(ov)]):
        rep = evaluate(spec.decode(row), "cloud")
        assert rep.valid == ov[i], f"row {i}"
        assert (rep.edp if rep.valid else np.inf) == oe[i], f"row {i}"


# ------------------------------------------- numpy-vs-JAX on structured


@st.composite
def structured_workloads(draw):
    m = draw(st.integers(min_value=2, max_value=40))
    k = draw(st.integers(min_value=2, max_value=40))
    n = draw(st.integers(min_value=2, max_value=40))
    kind = draw(st.integers(min_value=0, max_value=2))
    if kind == 0:
        cov = draw(st.floats(min_value=0.1, max_value=1.0))
        frac = draw(st.floats(min_value=0.05, max_value=1.0))
        dp = Banded(frac * cov, cov)
    elif kind == 1:
        mm = draw(st.integers(min_value=2, max_value=8))
        nn = draw(st.integers(min_value=1, max_value=8))
        dp = BlockNM(min(nn, mm), mm)
    else:
        dp = draw(st.floats(min_value=0.05, max_value=1.0))
    qm = draw(st.integers(min_value=2, max_value=8))
    qn = draw(st.integers(min_value=1, max_value=8))
    dq = BlockNM(min(qn, qm), qm)
    return spmm(f"smm_{m}x{k}x{n}", m, k, n, dp, dq)


@settings(max_examples=10, deadline=None)
@given(structured_workloads(),
       st.integers(min_value=0, max_value=2**31 - 1))
def test_structured_agreement_numpy_vs_jax(wl, seed):
    """The structured kernel variant (traced family codes/params) must
    agree with the DensityModel-aware numpy oracle."""
    spec = GenomeSpec(wl)
    jm = JaxCostModel(spec, "cloud")
    assert jm.signature[3].startswith("s:")
    rng = np.random.default_rng(seed)
    G = spec.random_genomes(rng, 64)
    out = jm(G)
    for i, g in enumerate(G):
        rep = evaluate(spec.decode(g), "cloud")
        jv = bool(out["valid"][i])
        if rep.valid != jv:
            # tolerate razor-thin float32-vs-float64 capacity margins
            margins = [1.0]
            for _, sname, cap in spec.arch.capacity_stores:
                if sname in rep.occupancy_bytes:
                    margins.append(
                        abs(rep.occupancy_bytes[sname] - cap) / cap)
            assert min(margins) < 5e-3, (
                f"genome {i}: oracle valid={rep.valid} ({rep.reason}) "
                f"jax valid={jv}")
            continue
        if rep.valid:
            lg = np.log10(rep.edp)
            assert abs(lg - out["log10_edp"][i]) <= \
                2e-3 * max(abs(lg), 1), f"genome {i}"


# ---------------------------------------- compilation sharing / promotion


def test_block_nm_family_shares_one_compilation():
    """An N:M sweep (1:4, 2:4, 3:4, 2:8 ...) is ONE signature — n and m
    are traced numbers, not structural."""
    search.clear_cache()
    wls = [spmm(f"fam_{n}_{m}", 24, 36, 20, 0.4, BlockNM(n, m))
           for n, m in ((1, 4), (2, 4), (3, 4), (2, 8))]
    models = [JaxCostModel(GenomeSpec(w), "cloud") for w in wls]
    assert len({m.signature for m in models}) == 1
    rng = np.random.default_rng(0)
    batches = [GenomeSpec(w).random_genomes(rng, 32) for w in wls]
    for m, b in zip(models, batches):
        m(b)
    compiles = jax_cost.compilation_count()
    assert compiles == 1, f"family split compilations: {compiles}"
    # the mega-batch path shares too (one more compile for the stacked
    # kernel variant, then flat across the family)
    eval_stacked(models, batches)
    eval_stacked(list(reversed(models)), list(reversed(batches)))
    assert jax_cost.compilation_count() == compiles + 1


def test_uniform_promotion_agrees_with_baked_kernel():
    """A uniform workload promoted onto the structured kernel (so it can
    mega-batch with structured peers) evaluates to the same designs'
    costs as the baked uniform kernel."""
    wl = spmm("promo", 32, 64, 48, 0.2, 0.5)
    spec = GenomeSpec(wl)
    base = JaxCostModel(spec, "cloud")
    promo = JaxCostModel(spec, "cloud", structured=True)
    assert base.signature != promo.signature
    assert promo.signature[3].startswith("s:")
    G = spec.random_genomes(np.random.default_rng(3), 128)
    a, b = base(G), promo(G)
    np.testing.assert_array_equal(a["valid"], b["valid"])
    for k in ("cycles", "energy_pj"):
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                   rtol=1e-6)


def test_structured_workload_refuses_uniform_kernel():
    wl = spmm("refuse", 8, 8, 8, 0.5, BlockNM(2, 4))
    with pytest.raises(ValueError):
        JaxCostModel(GenomeSpec(wl), "cloud", structured=False)


def test_mixed_density_fleet_one_dispatch_per_round():
    """run_method_sweep over a mixed uniform/banded/N:M fleet:
    density-mode alignment promotes the group onto one structured
    signature — one mega-batch dispatch per round."""
    search.clear_cache()
    wls = [spmm("mix_u", 16, 24, 16, 0.5, 0.5),
           spmm("mix_b", 24, 16, 16, Banded(0.1, 0.25), 0.9),
           spmm("mix_nm", 16, 16, 24, 0.8, BlockNM(2, 4))]
    stats = {}
    grid = search.run_method_sweep(["sparsemap", "random_mapper"], wls,
                                   "cloud", budget=200, seed=0,
                                   stack_batches=True, stats_out=stats)
    assert len(stats["signatures"]) == 1
    assert stats["signatures"][0][3].startswith("s:")
    assert stats["dispatches"] == stats["rounds"]
    for m in grid:
        for w in grid[m]:
            assert grid[m][w].evals >= 200


def test_cache_key_distinguishes_density_models():
    """Two same-shape workloads differing only in density structure must
    not share an evaluator (same aliasing class as the PR 2 bug)."""
    a = spmm("twin_d", 16, 16, 16, 0.5, 0.5)
    b = spmm("twin_d", 16, 16, 16, 0.5, BlockNM(2, 4))
    sa, ea = search.get_evaluator(a, "cloud")
    sb, eb = search.get_evaluator(b, "cloud")
    assert ea is not eb
    assert a.cache_key() != b.cache_key()


def test_tensor_spec_density_views():
    t = TensorSpec("P", ("M", "K"), 0.25)
    assert t.density_model == Uniform(0.25)
    assert t.mean_density == 0.25
    t2 = TensorSpec("Q", ("K", "N"), BlockNM(2, 4))
    assert t2.mean_density == 0.5
    wl = spmm("views", 8, 8, 8, Banded(0.1, 0.5), 0.5)
    assert wl.density_of("P") == pytest.approx(0.1)
    assert wl.density_model_of("P") == Banded(0.1, 0.5)
    assert wl.density_model_of("Z").family == "uniform"
    assert wl.density_of("Z") == pytest.approx(wl.output_density())
    assert wl.structured_density


def test_block_nm_float_windows_interpolate():
    """The log-gamma form handles fractional window sizes (the kernel's
    tile extents are float products) and stays within the integer
    endpoints."""
    model = BlockNM(2, 6)
    lo, hi = model.block_nonempty(2), model.block_nonempty(3)
    mid = model.block_nonempty(2.5)
    assert lo < mid < hi
    assert math.isclose(model.block_nonempty(4.0),
                        1.0 - 1.0 / 15.0, rel_tol=1e-9)
