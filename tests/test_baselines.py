"""Every baseline runs under a small budget and respects the interface."""
import numpy as np
import pytest

from repro.core import search
from repro.core.baselines import METHODS
from repro.core.workload import spmm

WL = spmm("mm_bl", 32, 64, 48, 0.2, 0.5)
BUDGET = 400


@pytest.mark.parametrize("method", sorted(METHODS))
def test_method_contract(method):
    res = search.run(method, WL, "cloud", budget=BUDGET, seed=0)
    assert res.evals <= BUDGET
    assert len(res.history) == res.evals
    assert (res.history[1:] <= res.history[:-1]).all()  # monotone
    assert res.valid_evals <= res.evals
    if np.isfinite(res.best_edp):
        assert res.best_genome is not None
        rep = search.report_best(WL, "cloud", res)
        assert rep is not None and rep.valid
        assert rep.edp == pytest.approx(res.best_edp, rel=1e-3)


def test_same_seed_reproducible():
    a = search.run("sparsemap", WL, "cloud", budget=300, seed=7)
    b = search.run("sparsemap", WL, "cloud", budget=300, seed=7)
    assert a.best_edp == b.best_edp


def test_dqn_td_update_matches_sequential_reference():
    """The vectorized batched TD(0) update is EXACTLY the per-episode
    sequential loop over the same frozen Q snapshot: np.add.at applies
    duplicate-index increments unbuffered in element order, so the float
    accumulation order is identical (pins the ROADMAP DQN item).

    NOTE the snapshot semantics are themselves the change: the OLD
    engine bootstrapped each episode off the live, mid-round Q table
    (inherently sequential), so pre-PR fixed-seed DQN trajectories are
    not preserved — DQN has no pinned goldens, only this contract."""
    from repro.core.baselines import dqn_td_update
    rng = np.random.default_rng(0)
    L, V, n = 12, 9, 64
    ub = rng.integers(2, V + 1, L)
    q0 = rng.normal(size=(L, V))
    for j in range(L):
        q0[j, ub[j]:] = -1e9
    g = (rng.random((n, L)) * ub[None, :]).astype(np.int64)
    rew = rng.normal(size=n)
    gamma, lr = 0.98, 0.2

    q_vec = q0.copy()
    dqn_td_update(q_vec, g, rew, gamma, lr)

    q_seq, q_old = q0.copy(), q0.copy()
    for i in range(n):         # sequential form of the snapshot update
        for j in range(L):
            target = rew[i] if j == L - 1 else \
                gamma * np.max(q_old[j + 1, :ub[j + 1]])
            q_seq[j, g[i, j]] += lr * (target - q_old[j, g[i, j]])
    np.testing.assert_array_equal(q_vec, q_seq)


def test_dqn_same_seed_reproducible():
    a = search.run("dqn", WL, "cloud", budget=300, seed=11)
    b = search.run("dqn", WL, "cloud", budget=300, seed=11)
    assert a.best_edp == b.best_edp
    assert np.array_equal(a.history, b.history)


def test_sage_like_cannot_change_mapping():
    res = search.run("sage_like", WL, "cloud", budget=300, seed=0)
    if res.best_genome is None:
        pytest.skip("no valid point at tiny budget")
    spec, _ = search.get_evaluator(WL, "cloud")
    from repro.core import accel
    from repro.core.baselines import fixed_mapping_genes
    fixed = fixed_mapping_genes(spec, accel.CLOUD.n_pe,
                                accel.CLOUD.macs_per_pe)
    for k, v in fixed.items():
        assert res.best_genome[k] == v
