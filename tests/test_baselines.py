"""Every baseline runs under a small budget and respects the interface."""
import numpy as np
import pytest

from repro.core import search
from repro.core.baselines import METHODS
from repro.core.workload import spmm

WL = spmm("mm_bl", 32, 64, 48, 0.2, 0.5)
BUDGET = 400


@pytest.mark.parametrize("method", sorted(METHODS))
def test_method_contract(method):
    res = search.run(method, WL, "cloud", budget=BUDGET, seed=0)
    assert res.evals <= BUDGET
    assert len(res.history) == res.evals
    assert (res.history[1:] <= res.history[:-1]).all()  # monotone
    assert res.valid_evals <= res.evals
    if np.isfinite(res.best_edp):
        assert res.best_genome is not None
        rep = search.report_best(WL, "cloud", res)
        assert rep is not None and rep.valid
        assert rep.edp == pytest.approx(res.best_edp, rel=1e-3)


def test_same_seed_reproducible():
    a = search.run("sparsemap", WL, "cloud", budget=300, seed=7)
    b = search.run("sparsemap", WL, "cloud", budget=300, seed=7)
    assert a.best_edp == b.best_edp


def test_sage_like_cannot_change_mapping():
    res = search.run("sage_like", WL, "cloud", budget=300, seed=0)
    if res.best_genome is None:
        pytest.skip("no valid point at tiny budget")
    spec, _ = search.get_evaluator(WL, "cloud")
    from repro.core import accel
    from repro.core.baselines import fixed_mapping_genes
    fixed = fixed_mapping_genes(spec, accel.CLOUD.n_pe,
                                accel.CLOUD.macs_per_pe)
    for k, v in fixed.items():
        assert res.best_genome[k] == v
