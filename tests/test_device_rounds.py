"""Device-resident ES rounds (COMPAT.md "Device-resident round
protocol"): fixed-seed parity pins between the three execution paths of
a ``device_rounds=k`` fleet —

* **device**: k generations folded into one vmap-of-``lax.scan`` program
  (``jax_cost.run_segments``), host sync once per segment;
* **host-loop**: the same generator answered with ``None``, replaying
  the identical pre-drawn operator plan per-round on the host;
* **legacy k=1**: the original per-generation loop.

Device and host-loop consume the same ``DeviceSegment.draws``, so they
must match BIT-FOR-BIT (best EDP and the full history curve).  Legacy
k=1 differs from k>1 in exactly ONE seam — the legacy loop sorts fitness
with numpy's unstable introsort while segment selection is stable — and
that seam is pinned here explicitly: forcing unstable tie order into the
segment path reproduces the legacy trajectory bit-for-bit.  The
numpy-vs-threefry RNG seam is pinned the same way (deterministic, but a
different stream by construction).
"""
import numpy as np
import pytest

from repro.configs.paper_workloads import by_name
from repro.core import es_ops, search
from repro.core.es_ops import PaddedLayout

BUDGET = 700
SEED = 3
K = 4


def _grid_equal(a, b):
    """Bit-exact best-EDP + history equality over two result grids."""
    assert set(a) == set(b)
    for m in a:
        assert set(a[m]) == set(b[m])
        for w in a[m]:
            ra, rb = a[m][w], b[m][w]
            assert ra.best_edp == rb.best_edp, (m, w)
            assert np.array_equal(ra.history, rb.history), (m, w)
            assert ra.evals == rb.evals and \
                ra.valid_evals == rb.valid_evals, (m, w)


def _sweep(methods, wls, arch, device_execute, device_rounds=K,
           stats=None, method_kw=None):
    return search.run_method_sweep(
        methods, [by_name(w) for w in wls], arch, budget=BUDGET,
        seed=SEED, stack_batches=True, device_rounds=device_rounds,
        device_execute=device_execute,
        stats_out=stats if stats is not None else {},
        method_kw=method_kw)


# ------------------------------------------------ device == host-loop


@pytest.mark.parametrize("arch", ["cloud", "maple_edge"])
def test_device_segments_match_host_loop_bitwise(arch):
    stats_dev, stats_host = {}, {}
    dev = _sweep(["sparsemap"], ["mm1", "mm3"], arch, True,
                 stats=stats_dev)
    host = _sweep(["sparsemap"], ["mm1", "mm3"], arch, False,
                  stats=stats_host)
    _grid_equal(dev, host)
    # the device fleet folded k generations per host sync; the host-loop
    # reference paid one sync per generation
    assert stats_dev["host_syncs_per_round"] <= 1 / K
    assert stats_host["host_syncs_per_round"] >= 1.0
    assert stats_dev["host_syncs"] < stats_host["host_syncs"]


def test_mixed_density_mixed_method_fleet_parity():
    # uniform (mm1) + block-N:M structured (mm8) workloads promote the
    # fleet onto the structured kernel; standard_es has no device path
    # and must ride along unchanged
    methods = ["sparsemap", "standard_es"]
    wls = ["mm1", "mm8"]
    dev = _sweep(methods, wls, "cloud", True)
    host = _sweep(methods, wls, "cloud", False)
    _grid_equal(dev, host)
    # standard_es is per-round in ALL modes: identical to a k=1 fleet
    k1 = _sweep(methods, wls, "cloud", True, device_rounds=1)
    _grid_equal({"standard_es": dev["standard_es"]},
                {"standard_es": k1["standard_es"]})


# ------------------------------------------------ the k=1 <-> k>1 seam


def test_sort_stability_is_the_only_k1_seam(monkeypatch):
    """Legacy k=1 vs segmented k>1 differ ONLY in selection tie order
    (unstable introsort vs stable sort).  With unstable order forced
    into the segment path, the k>1 host-loop reproduces the legacy
    trajectory bit-for-bit."""
    wl = by_name("mm1")
    legacy = search.run("sparsemap", wl, "cloud", budget=BUDGET,
                        seed=SEED)
    from repro.core import evolution
    monkeypatch.setattr(evolution.es_ops, "stable_order",
                        lambda edp: np.argsort(edp))
    seg = _sweep(["sparsemap"], ["mm1"], "cloud", False)
    res = seg["sparsemap"]["mm1"]
    assert res.best_edp == legacy.best_edp
    assert np.array_equal(res.history, legacy.history)


def test_threefry_backend_deterministic_and_distinct():
    kw = {"sparsemap": dict(rng_backend="threefry")}
    dev = _sweep(["sparsemap"], ["mm1"], "cloud", True, method_kw=kw)
    host = _sweep(["sparsemap"], ["mm1"], "cloud", False, method_kw=kw)
    _grid_equal(dev, host)        # device RNG is driver-invariant too
    # ... but a different stream from the numpy oracle (the RNG seam):
    # same budget/seed, different draws -> different history
    numpy_dev = _sweep(["sparsemap"], ["mm1"], "cloud", True)
    assert not np.array_equal(dev["sparsemap"]["mm1"].history,
                              numpy_dev["sparsemap"]["mm1"].history)
    assert dev["sparsemap"]["mm1"].evals == \
        numpy_dev["sparsemap"]["mm1"].evals


# ------------------------------------------------ operator unit pins


def test_apply_ops_numpy_jnp_equal():
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    n, L, genes_per = 33, 17, 2
    gene_ub = rng.integers(2, 9, L)
    parents = rng.integers(0, 2, (7, L)).astype(np.int64)
    cut_arr = es_ops.crossover_cut_points(L)
    plan = es_ops.plan_generation(
        rng, n_children=n, n_parents=len(parents), cut_arr=cut_arr,
        gene_ub=gene_ub, genes_per=genes_per, p_mut=0.9, p_high=0.5,
        hi=None, lo=None)
    kids_np = es_ops.apply_crossover(parents, plan.ab, plan.cuts)
    kids_j = es_ops.apply_crossover(jnp.asarray(parents),
                                    jnp.asarray(plan.ab),
                                    jnp.asarray(plan.cuts))
    assert np.array_equal(kids_np, np.asarray(kids_j))
    mut_np = es_ops.apply_mutation(kids_np, plan.active, plan.gene,
                                   plan.vals)
    mut_j = es_ops.apply_mutation(jnp.asarray(kids_np),
                                  jnp.asarray(plan.active),
                                  jnp.asarray(plan.gene),
                                  jnp.asarray(plan.vals))
    assert np.array_equal(mut_np, np.asarray(mut_j))
    # duplicate-column overwrite order: force all draws onto one gene
    gene = np.zeros((n, genes_per), dtype=np.int64)
    dup_np = es_ops.apply_mutation(kids_np, plan.active, gene, plan.vals)
    dup_j = es_ops.apply_mutation(jnp.asarray(kids_np),
                                  jnp.asarray(plan.active),
                                  jnp.asarray(gene),
                                  jnp.asarray(plan.vals))
    assert np.array_equal(dup_np, np.asarray(dup_j))
    assert np.array_equal(
        dup_np[plan.active, 0], plan.vals[plan.active, -1])


def test_stable_order_and_best_so_far_backends_agree():
    import jax.numpy as jnp
    edp = np.array([3.0, 1.0, 1.0, np.inf, 2.0, 1.0, np.inf],
                   dtype=np.float32)
    assert np.array_equal(es_ops.stable_order(edp),
                          np.asarray(es_ops.stable_order(jnp.asarray(edp))))
    assert np.array_equal(es_ops.best_so_far(edp),
                          np.asarray(es_ops.best_so_far(jnp.asarray(edp))))


def test_padded_layout_roundtrip_and_index_maps():
    from repro.core.encoding import GenomeSpec
    spec = GenomeSpec(by_name("mm1"))
    lay = PaddedLayout(spec, spec.n_primes + 5)
    rng = np.random.default_rng(1)
    g = spec.random_genomes(rng, 8)
    gp = lay.pad_rows(g)
    assert gp.shape == (8, lay.Lp)
    assert np.array_equal(lay.unpad_rows(gp), g)
    # pad columns are inert zeros
    pad_cols = np.setdiff1d(np.arange(lay.Lp), lay.cols)
    assert (gp[:, pad_cols] == 0).all()
    idx = np.arange(spec.length)
    padded_idx = lay.pad_index(idx)
    # a padded gene index addresses the same gene the canonical one did
    assert np.array_equal(gp[:, padded_idx], g[:, idx])
    # cuts: the canonical prefix is preserved through the map
    for cut in range(1, spec.length):
        pc = int(lay.pad_cut(np.asarray(cut)))
        left = lay.unpad_rows(
            np.pad(gp[:, :pc], ((0, 0), (0, lay.Lp - pc))))
        assert np.array_equal(left[:, :cut], g[:, :cut])


# ------------------------------------------------ forced multi-device

SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
from repro.configs.paper_workloads import by_name
from repro.core import jax_cost, search
from repro.launch.mesh import make_search_mesh

mesh = make_search_mesh()
assert mesh is not None and int(np.asarray(mesh.devices).size) == 8

# 1. sharded mega-batch == single-device mega-batch, bit for bit
spec, ev = search.get_evaluator(by_name("mm1"), "cloud")
rng = np.random.default_rng(0)
batches = [spec.random_genomes(rng, n) for n in (48, 50, 64)]
models = [ev] * len(batches)
plain = jax_cost.eval_stacked(models, batches)
shard = jax_cost.eval_stacked(models, batches, mesh=mesh)
for p, s in zip(plain, shard):
    for k in p:
        assert np.array_equal(p[k], s[k]), k
print("EVAL_STACKED_SHARDED_OK")

# 2. an 8-task segment fleet (task axis divisible by 8 -> sharded scan)
# == the same fleet on one device, bit for bit
def fleet(mesh):
    tasks = [search.SearchTask(by_name("mm1"), "cloud", budget=700,
                               seed=s, name=f"t{s}") for s in range(8)]
    ms = search.MultiSearch(tasks, stack_batches=True, device_rounds=4,
                            mesh=mesh)
    return ms.run(), ms.stats

res1, st1 = fleet(None)
res8, st8 = fleet(mesh)
assert st8["devices"] == 8 and st1["devices"] == 1
assert st8["host_syncs_per_round"] <= 0.25
for name in res1:
    assert res1[name].best_edp == res8[name].best_edp, name
    assert np.array_equal(res1[name].history, res8[name].history), name
print("SEGMENT_FLEET_SHARDED_OK")
"""


@pytest.mark.slow
def test_forced_multi_device_sharding_matches_single_device(
        subprocess_env):
    import os
    import subprocess
    import sys
    r = subprocess.run(
        [sys.executable, "-c", SHARD_SCRIPT], capture_output=True,
        text=True, timeout=600, env=subprocess_env(),
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert "EVAL_STACKED_SHARDED_OK" in r.stdout, \
        r.stdout + "\n" + r.stderr
    assert "SEGMENT_FLEET_SHARDED_OK" in r.stdout, \
        r.stdout + "\n" + r.stderr
