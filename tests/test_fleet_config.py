"""FleetConfig / SearchTask wire-schema contract (COMPAT.md "FleetConfig
contract"):

* FleetConfig <-> JSON round-trip, unknown-field/version rejection, and
  the process-local mesh refusing to serialize,
* deprecated ``MultiSearch(**kwargs)`` aliases: warn, stay bit-identical
  to ``config=FleetConfig(...)``, and conflict loudly when both given,
* ``SearchTask.es_kw`` deprecation with merge semantics preserved,
* SearchTask <-> JSON round-trip: content-equal workload (cache_key),
  density models by registered family, platform by registry name,
  ``runtime_kw`` kept off the wire,
* property tests (hypothesis, shim fallback) over random spmm geometry
  and density families.
"""
import dataclasses
import json

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import search
from repro.core.density import (Banded, BlockNM, Uniform,
                                density_from_dict, density_to_dict)
from repro.core.search import FleetConfig, MultiSearch, SearchTask
from repro.core.workload import (spmm, workload_from_dict,
                                 workload_to_dict)

BUDGET = 300


def wl(name="fc_wl", m=16, k=16, n=8, dp=0.5, dq=0.5):
    return spmm(name, m, k, n, dp, dq)


# ------------------------------------------------- FleetConfig JSON


def test_fleet_config_json_round_trip():
    cfg = FleetConfig(align_signatures=False, stack_batches=True,
                      device_rounds=4, pipeline=False,
                      compile_ahead=False)
    back = FleetConfig.from_json(cfg.to_json())
    assert back == cfg
    # defaults round-trip too
    assert FleetConfig.from_json(FleetConfig().to_json()) == FleetConfig()


def test_fleet_config_rejects_unknown_fields_and_versions():
    d = FleetConfig().to_json_dict()
    d["warp_factor"] = 9
    with pytest.raises(ValueError, match="warp_factor"):
        FleetConfig.from_json(d)
    d2 = FleetConfig().to_json_dict()
    d2["version"] = 99
    with pytest.raises(ValueError):
        FleetConfig.from_json(d2)


def test_fleet_config_mesh_is_process_local():
    cfg = FleetConfig(mesh=object())
    with pytest.raises(ValueError, match="mesh"):
        cfg.to_json_dict()


def test_fleet_config_validates_device_rounds():
    with pytest.raises(ValueError):
        FleetConfig(device_rounds=0)
    v, src = FleetConfig(device_rounds=3).resolved_device_rounds()
    assert (v, src) == (3, "explicit")
    v, src = FleetConfig().resolved_device_rounds()
    assert v >= 1 and src.startswith("default:")


# ------------------------------------- deprecated MultiSearch kwargs


def test_legacy_kwargs_warn_and_match_config():
    """``MultiSearch(tasks, stack_batches=True)`` must warn AND give
    bit-identical results to the FleetConfig spelling."""
    def tasks():
        return [SearchTask(wl("lg1"), "cloud", budget=BUDGET, seed=7),
                SearchTask(wl("lg2", m=24), "cloud", budget=BUDGET,
                           seed=7)]
    with pytest.warns(DeprecationWarning, match="FleetConfig"):
        ms_old = MultiSearch(tasks(), stack_batches=True,
                             compile_ahead=False)
    assert ms_old.config == FleetConfig(stack_batches=True,
                                        compile_ahead=False)
    old = ms_old.run()
    new = MultiSearch(tasks(), FleetConfig(stack_batches=True,
                                           compile_ahead=False)).run()
    for name in old:
        assert old[name].best_edp == new[name].best_edp
        assert np.array_equal(old[name].history, new[name].history)


def test_legacy_kwargs_conflict_with_config_is_an_error():
    t = [SearchTask(wl(), "cloud", budget=BUDGET)]
    with pytest.raises(ValueError, match="config"):
        MultiSearch(t, FleetConfig(), stack_batches=True)


def test_config_spelling_does_not_warn():
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        MultiSearch([SearchTask(wl(), "cloud", budget=BUDGET)],
                    FleetConfig())
        search.run_method_sweep(
            ["random_mapper"], [wl()], "cloud", budget=BUDGET,
            config=FleetConfig(stack_batches=True))


# ------------------------------------------------ es_kw deprecation


def test_es_kw_warns_and_merges():
    with pytest.warns(DeprecationWarning, match="es_kw"):
        t = SearchTask(wl(), "cloud", budget=BUDGET,
                       es_kw={"pop": 32, "elite_frac": 0.5},
                       method_kw={"pop": 48})
    # explicit method_kw wins over the deprecated alias
    assert t.method_kw["pop"] == 48
    assert t.method_kw["elite_frac"] == 0.5


# -------------------------------------------- density / workload JSON


def test_density_dict_round_trip_all_families():
    for m in (Uniform(0.3), Banded(0.2, 0.5), BlockNM(2, 4)):
        d = density_to_dict(m)
        json.dumps(d)                       # wire-safe
        assert density_from_dict(d) == m
    # plain float normalizes to Uniform
    assert density_from_dict(density_to_dict(0.25)) == Uniform(0.25)


def test_density_from_dict_unknown_family_names_registry():
    with pytest.raises(ValueError, match="uniform"):
        density_from_dict({"family": "fractal", "fields": {}})


def test_unregistered_density_model_refuses_to_serialize():
    @dataclasses.dataclass(frozen=True)
    class Ghost(Uniform):
        family = "ghost_unregistered"
    with pytest.raises(ValueError, match="not registered"):
        density_to_dict(Ghost(0.5))


def test_workload_json_round_trip_is_cache_key_equal():
    w = spmm("wire", 100, 64, 48, Banded(0.2, 0.5), 0.6)
    back = workload_from_dict(workload_to_dict(w))
    assert back.cache_key() == w.cache_key()
    assert back.structured_density == w.structured_density


# ------------------------------------------------- SearchTask JSON


def test_search_task_json_round_trip():
    t = SearchTask(wl("stj", m=48), "edge", budget=1234, seed=9,
                   method="pso", method_kw={"swarm": 16})
    back = SearchTask.from_json(t.to_json())
    assert back.workload.cache_key() == t.workload.cache_key()
    assert (back.platform, back.budget, back.seed, back.method,
            back.method_kw) == ("edge", 1234, 9, "pso", {"swarm": 16})


def test_search_task_json_excludes_runtime_kw():
    t = SearchTask(wl(), "cloud", budget=BUDGET)
    t.runtime_kw["state_out"] = {}
    t.runtime_kw["warm_seeds"] = np.zeros((1, 4))
    d = t.to_json_dict()
    json.dumps(d)                           # must stay wire-safe
    assert "runtime_kw" not in d and "es_kw" not in d
    assert SearchTask.from_json(d).runtime_kw == {}


def test_search_task_json_rejects_unknown_fields():
    d = SearchTask(wl(), "cloud", budget=BUDGET).to_json_dict()
    d["favorite_color"] = "blue"
    with pytest.raises(ValueError, match="favorite_color"):
        SearchTask.from_json(d)


def test_search_task_json_round_trip_same_search_result():
    """The deserialized task must search identically: same evaluator
    (shared via cache_key), same trajectory at a fixed seed."""
    t = SearchTask(wl("same_res"), "cloud", budget=BUDGET, seed=11)
    t2 = SearchTask.from_json(t.to_json())
    a = MultiSearch([t], FleetConfig()).run()["same_res@cloud"]
    b = MultiSearch([t2], FleetConfig()).run()["same_res@cloud"]
    assert a.best_edp == b.best_edp
    assert np.array_equal(a.history, b.history)


# ---------------------------------------------------- property tests


@st.composite
def spmm_args(draw):
    return dict(m=draw(st.integers(4, 200)),
                k=draw(st.integers(4, 200)),
                n=draw(st.integers(4, 200)),
                dp=draw(st.floats(0.05, 1.0)),
                dq=draw(st.floats(0.05, 1.0)))


@settings(max_examples=25, deadline=None)
@given(spmm_args())
def test_property_search_task_round_trip(kw):
    t = SearchTask(spmm("prop", kw["m"], kw["k"], kw["n"],
                        kw["dp"], kw["dq"]),
                   "mobile", budget=500, seed=1)
    back = SearchTask.from_json(json.loads(t.to_json()))
    assert back.workload.cache_key() == t.workload.cache_key()
    assert back.to_json() == t.to_json()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 1), st.integers(0, 1), st.integers(0, 1),
       st.integers(1, 8))
def test_property_fleet_config_round_trip(align, stack, pipe, dr):
    cfg = FleetConfig(align_signatures=bool(align),
                      stack_batches=bool(stack), pipeline=bool(pipe),
                      device_rounds=dr)
    assert FleetConfig.from_json(cfg.to_json()) == cfg
