"""Unit + property tests for the genome encoding (prime factors, cantor)."""
import math

import numpy as np
import pytest

try:        # hypothesis is an optional test extra (pyproject.toml)
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.encoding import (GenomeSpec, all_permutations, cantor_decode,
                                 cantor_encode)
from repro.core.direct_encoding import DirectValueSpec
from repro.core.workload import (pad_to_composite, prime_factorize, spmm,
                                 batched_spmm)


# ---------------------------------------------------------------- cantor
@pytest.mark.parametrize("d", [2, 3, 4])
def test_cantor_roundtrip(d):
    for c in range(math.factorial(d)):
        assert cantor_encode(cantor_decode(c, d)) == c


def test_cantor_identity_is_zero():
    assert cantor_encode((0, 1, 2)) == 0          # MKN == code 0 (paper: 1)
    assert cantor_decode(0, 3) == (0, 1, 2)


def test_cantor_outer_loop_dominates():
    """Codes sharing the leading element are contiguous — the property that
    makes local search meaningful (paper Fig. 10)."""
    perms = all_permutations(3)
    # first 2 codes start with dim 0, next 2 with dim 1, last 2 with dim 2
    assert [p[0] for p in perms.tolist()] == [0, 0, 1, 1, 2, 2]


# ---------------------------------------------------------------- primes
@given(st.integers(min_value=1, max_value=10_000))
def test_prime_factorize(n):
    fs = prime_factorize(n)
    prod = 1
    for p in fs:
        prod *= p
    assert prod == n
    assert fs == sorted(fs)


@given(st.integers(min_value=2, max_value=100_000))
def test_pad_to_composite(n):
    m = pad_to_composite(n)
    assert m >= n
    assert max(prime_factorize(m)) <= 7


# ---------------------------------------------------------------- genome
@st.composite
def workloads(draw):
    m = draw(st.integers(min_value=2, max_value=64))
    k = draw(st.integers(min_value=2, max_value=64))
    n = draw(st.integers(min_value=2, max_value=64))
    dp = draw(st.floats(min_value=0.01, max_value=1.0))
    dq = draw(st.floats(min_value=0.01, max_value=1.0))
    return spmm(f"mm_{m}x{k}x{n}", m, k, n, dp, dq)


@settings(max_examples=25, deadline=None)
@given(workloads(), st.integers(min_value=0, max_value=2**31 - 1))
def test_decode_never_raises_and_tiling_constraint_holds(wl, seed):
    """Prime-factor encoding guarantees the tiling constraint by
    construction (paper §IV.B)."""
    spec = GenomeSpec(wl)
    rng = np.random.default_rng(seed)
    g = spec.random_genomes(rng, 4)
    for row in g:
        design = spec.decode(row)
        for d in wl.dim_order:
            prod = 1
            for lvl in range(5):
                prod *= design.mapping.factors[lvl].get(d, 1)
            assert prod == wl.dim_sizes[d]


@settings(max_examples=25, deadline=None)
@given(workloads(), st.integers(min_value=0, max_value=2**31 - 1))
def test_mapping_encode_decode_roundtrip(wl, seed):
    spec = GenomeSpec(wl)
    rng = np.random.default_rng(seed)
    g = spec.random_genomes(rng, 2)
    for row in g:
        mp = spec.decode(row).mapping
        g2 = spec.encode_mapping(mp)
        mp2 = spec.decode(g2).mapping
        assert mp2.factors == mp.factors
        assert mp2.perms == mp.perms


def test_genome_layout_matches_paper_fig13():
    wl = spmm("mm", 32, 64, 48, 0.2, 0.5)
    spec = GenomeSpec(wl)
    assert list(spec.segments) == ["perm", "tiling", "fmt_P", "fmt_Q",
                                   "fmt_Z", "sg"]
    assert len(spec.segments["perm"]) == 5
    assert len(spec.segments["tiling"]) == len(wl.prime_factors)
    assert len(spec.segments["sg"]) == 3
    assert spec.gene_ub[spec.segments["perm"].start] == 6      # 3! perms
    assert spec.gene_ub[spec.segments["sg"].start] == 7        # 7 S/G opts


def test_multidim_workload_widens_genome():
    """Paper §IV.G / Fig. 15: a 4-dim workload gets A_4^4 = 24 perm codes."""
    wl = batched_spmm("bmm", 4, 8, 8, 8, 0.5, 0.5)
    spec = GenomeSpec(wl)
    assert spec.gene_ub[spec.segments["perm"].start] == 24
    rng = np.random.default_rng(0)
    for row in spec.random_genomes(rng, 8):
        spec.decode(row)   # must not raise


def test_direct_encoding_mostly_invalid():
    """The paper's motivation for prime-factor encoding: direct value
    encoding leaves almost no valid tilings."""
    wl = spmm("mm", 32, 64, 48, 0.2, 0.5)
    spec = GenomeSpec(wl)
    dspec = DirectValueSpec(spec)
    rng = np.random.default_rng(0)
    g = dspec.random_genomes(rng, 500)
    n_ok = sum(dspec.to_canonical(row) is not None for row in g)
    assert n_ok < 25   # <5% valid even with divisor-based sampling


def test_direct_encoding_roundtrip_when_valid():
    """A hand-built tiling-satisfying direct genome converts and decodes."""
    wl = spmm("mm", 16, 16, 16, 0.5, 0.5)
    spec = GenomeSpec(wl)
    dspec = DirectValueSpec(spec)
    g = np.zeros(dspec.length, dtype=np.int64)
    g[dspec.perm_sl] = 0
    # factors per dim: (4, 4, 1, 1, 1) -> product 16
    facs = np.array([4, 4, 1, 1, 1] * 3, dtype=np.int64)
    g[dspec.fact_sl] = facs
    c = dspec.to_canonical(g)
    assert c is not None
    design = spec.decode(c)
    for d in wl.dim_order:
        assert design.mapping.factors[0].get(d, 1) == 4
        assert design.mapping.factors[1].get(d, 1) == 4
    # violating the product constraint -> None
    g[dspec.fact_sl.start] = 2
    assert dspec.to_canonical(g) is None
