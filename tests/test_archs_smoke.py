"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, shape + finiteness assertions; decode step for every arch (no
encoder-only archs are assigned, so decode applies everywhere)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, smoke_config
from repro.models.model import Model
from repro.optim import optimizer as opt

pytestmark = pytest.mark.slow      # jit-compiles every assigned arch

ALL = sorted(ARCHS)


def make_batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                              jnp.int32),
    }
    if cfg.frontend == "vision":
        batch["frontend"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_frontend_tokens, cfg.d_model)),
            jnp.bfloat16) * 0.02
    if cfg.frontend == "audio":
        batch["enc_embeds"] = jnp.asarray(
            rng.standard_normal((b, s, cfg.d_model)), jnp.bfloat16) * 0.02
    return batch


@pytest.mark.parametrize("arch", ALL)
def test_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)

    logits, aux = jax.jit(m.forward)(params, batch["tokens"],
                                     batch.get("frontend"),
                                     batch.get("enc_embeds"))
    s_total = batch["tokens"].shape[1] + (
        cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)
    assert logits.shape == (2, s_total, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    ocfg = opt.OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    ostate = opt.init(params, ocfg)

    @jax.jit
    def train_step(params, ostate, batch):
        (loss, aux), grads = jax.value_and_grad(
            m.loss_fn, has_aux=True)(params, batch)
        params, ostate, stats = opt.apply(params, grads, ostate, ocfg)
        return params, ostate, loss, stats

    params2, ostate2, loss, stats = train_step(params, ostate, batch)
    assert bool(jnp.isfinite(loss))
    assert bool(jnp.isfinite(stats["grad_norm"]))
    # params actually changed
    changed = jax.tree.map(
        lambda a, b: bool(jnp.any(a != b)), params, params2)
    assert any(jax.tree.leaves(changed))


@pytest.mark.parametrize("arch", ALL)
def test_decode_step(arch):
    cfg = smoke_config(arch)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, MAXLEN = 2, 64
    if cfg.n_enc_layers:
        enc = jnp.ones((B, 16, cfg.d_model), jnp.bfloat16) * 0.01
        cache = m.init_cache(B, MAXLEN, params=params, enc_embeds=enc)
    else:
        cache = m.init_cache(B, MAXLEN)
    step = jax.jit(m.decode_step)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits = None
    for pos in range(3):
        logits, cache = step(params, cache, tok, jnp.int32(pos))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


def test_loss_decreases_on_fixed_batch():
    cfg = smoke_config("mistral-nemo-12b")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    ocfg = opt.OptConfig(lr=3e-3, warmup_steps=1, total_steps=50)
    ostate = opt.init(params, ocfg)

    @jax.jit
    def train_step(params, ostate, batch):
        (loss, aux), grads = jax.value_and_grad(
            m.loss_fn, has_aux=True)(params, batch)
        params, ostate, _ = opt.apply(params, grads, ostate, ocfg)
        return params, ostate, loss

    losses = []
    for _ in range(8):
        params, ostate, loss = train_step(params, ostate, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.2, losses


def test_full_configs_match_advertised_scale():
    expect = {
        "xlstm-350m": (0.25, 0.6),
        "mistral-nemo-12b": (10, 14),
        "gemma3-12b": (10, 15),
        "starcoder2-7b": (6, 9),
        "command-r-35b": (30, 38),
        "kimi-k2-1t-a32b": (900, 1150),
        "arctic-480b": (430, 520),
        "qwen2-vl-7b": (6, 9),
        "seamless-m4t-large-v2": (0.8, 2.5),
        "zamba2-2.7b": (2.2, 3.5),
    }
    for name, (lo, hi) in expect.items():
        pc = get_config(name).param_count() / 1e9
        assert lo <= pc <= hi, f"{name}: {pc:.2f}B not in [{lo},{hi}]"
    # MoE active params
    assert 25 <= ARCHS["kimi-k2-1t-a32b"].active_param_count() / 1e9 <= 40
