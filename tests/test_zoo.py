"""The accelerator zoo: published-accelerator design points declared
through the DSL frontend — registry resolution and its error surface,
the pinned published-vs-modeled validation table, pad-policy seeds for
pre-baseline topologies, and the end-to-end CI-gated sweeps."""
import json
import os

import numpy as np
import pytest

from repro.configs.archs import (ACCEL_ARCHS, ZOO_ARCHS,
                                 zoo_validation_report)
from repro.core import search
from repro.core.arch import (UnknownArchError, as_arch, register_arch,
                             registered_archs)
from repro.core.arch_dsl import compile_arch
from repro.core.workload import spmm

VALIDATION = os.path.join(os.path.dirname(__file__), "golden",
                          "zoo_validation.json")


# ------------------------------------------------------------ registry


def test_zoo_entries_are_registered_and_resolvable():
    reg = registered_archs()
    for name, spec in ZOO_ARCHS.items():
        assert reg[name] is spec
        assert as_arch(name) is spec
        assert ACCEL_ARCHS[name] is spec


def test_as_arch_unknown_name_lists_registry():
    """Satellite bugfix pin: an unknown name raises a KeyError SUBCLASS
    (existing callers keep working) whose message enumerates the paper
    platforms and every registered arch, with a close-match hint."""
    with pytest.raises(UnknownArchError) as ei:
        as_arch("eyeris_like")          # sic: one 's'
    msg = str(ei.value)
    assert "eyeris_like" in msg
    assert "did you mean" in msg and "eyeriss_like" in msg
    for expected in ("edge", "mobile", "cloud", "maple_edge",
                     "sigma_like", "dstc_like", "register_arch",
                     "arch_dsl"):
        assert expected in msg, expected
    with pytest.raises(KeyError):       # subclass contract
        as_arch("definitely_not_an_arch")


# ----------------------------------------------------- validation table


def test_zoo_validation_table_matches_published_numbers():
    """Every zoo entry's modeled quantities — recomputed from the
    REGISTERED specs, never read back from the JSON — agree with the
    pinned table: exactly with the pinned 'modeled' column (the
    declarations did not drift) and within each check's tolerance of the
    'published' column (the declarations match the literature)."""
    table = json.load(open(VALIDATION))
    report = zoo_validation_report()
    assert set(table) == set(report) == set(ZOO_ARCHS)
    for arch_name, entry in table.items():
        assert entry["source"], arch_name       # citation is mandatory
        modeled = report[arch_name]
        assert set(entry["checks"]) == set(modeled), arch_name
        for check, pin in entry["checks"].items():
            got = modeled[check]
            assert got == pytest.approx(pin["modeled"], rel=1e-12), \
                f"{arch_name}.{check}: spec drifted from pinned table"
            tol = pin["rel_tol"]
            pub = pin["published"]
            assert abs(got - pub) <= tol * abs(pub) + 1e-9, \
                f"{arch_name}.{check}: modeled {got} vs published {pub}"


# ----------------------------------------------------------- pad policy


def test_zoo_pad_policies_are_registered_not_inherited():
    """Zoo topologies never silently inherit the default pad policy:
    each has a registered policy (measured from the committed baseline),
    while a genuinely unknown topology gets the documented explicit
    default."""
    for name, spec in ZOO_ARCHS.items():
        pol = search.pad_policy_for(spec.topology.fingerprint)
        assert pol.source == "measured", name
        assert pol == search.PadPolicy(decay_rounds=2, decay_ratio=0.125,
                                       source="measured")
    assert search.pad_policy_for("no_such_topology") \
        is search.DEFAULT_PAD_POLICY
    assert search.DEFAULT_PAD_POLICY.source == "default"


def test_seed_pad_policy_mechanism():
    """A brand-new topology declared ahead of its first baseline run:
    its seed trajectory registers with source="seed" (and would be
    flagged for promotion by compare_sweep once measured), and a
    measured registration overrides the seed."""
    probe = register_arch(compile_arch({
        "name": "zoo_seed_probe",
        "levels": [
            {"name": "dram"},
            {"name": "glb", "capacity": "32KB",
             "energy": [["dram", [100.0]]], "sg_site": "L2"},
            {"name": "reg", "energy": [["glb", [3.0]]],
             "fanout": [4, 8],
             "noc": {"multicast": "row", "reduction": ["cluster", 4]}},
        ],
    }), replace=True)
    fp = probe.topology.fingerprint
    seed = search.derive_pad_policy((2048, 2048, 256, 256), source="seed")
    assert seed.source == "seed"
    assert seed.decay_rounds == 2
    search.set_pad_policy(fp, seed)
    try:
        assert search.pad_policy_for(fp) == seed
        measured = search.derive_pad_policy((2048, 2048, 256, 256))
        assert measured.source == "measured"
        search.set_pad_policy(fp, measured)
        assert search.pad_policy_for(fp).source == "measured"
    finally:
        search._PAD_POLICIES.pop(fp, None)
        from repro.core import arch as arch_mod
        arch_mod._REGISTRY.pop("zoo_seed_probe", None)


# ------------------------------------------------------------------ e2e


@pytest.mark.parametrize("archname", sorted(ZOO_ARCHS))
def test_method_sweep_end_to_end_on_zoo_archs(archname):
    """Acceptance criterion: every zoo entry searches end-to-end through
    the mega-batched sweep at 1.0 dispatches/round per signature."""
    wls = [spmm(f"{archname}_a", 32, 64, 48, 0.2, 0.5),
           spmm(f"{archname}_b", 48, 32, 64, 0.4, 0.3)]
    stats: dict = {}
    grid = search.run_method_sweep(
        ["sparsemap", "random_mapper"], wls, archname,
        budget=200, seed=0, stats_out=stats)
    arch = as_arch(archname)
    for m in grid:
        for w, res in grid[m].items():
            assert res.evals >= 200
    assert len(stats["signatures"]) == 1
    assert stats["signatures"][0][2] == arch.topology.fingerprint
    assert stats["dispatches"] == stats["rounds"]


def test_sparsemap_finds_valid_designs_on_zoo_archs():
    wl = spmm("zoo_valid", 32, 64, 48, 0.2, 0.5)
    for archname in sorted(ZOO_ARCHS):
        res = search.run("sparsemap", wl, archname, budget=400, seed=0)
        assert np.isfinite(res.best_edp), archname
        rep = search.report_best(wl, archname, res)
        assert rep is not None and rep.valid, archname
        assert rep.edp == pytest.approx(res.best_edp, rel=1e-3)
