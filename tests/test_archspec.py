"""ArchSpec subsystem: structure derivation, registry resolution, and the
acceptance-criterion end-to-end sweeps on the non-default topologies."""
import numpy as np
import pytest

from repro.configs.archs import CLUSTER_CLOUD, MAPLE_EDGE
from repro.core import accel, search
from repro.core.arch import (ARCH_SPARSEMAP, ArchSpec, StorageLevel,
                             arch_from_platform, as_arch)
from repro.core.workload import spmm


# ------------------------------------------------------------ structure


def test_default_arch_matches_paper_structure():
    a = ARCH_SPARSEMAP
    assert a.level_names == ("L1_T", "L2_T", "L2_S", "L3_T", "L3_S")
    assert a.spatial_levels == (2, 4)
    assert a.temporal_levels == (0, 1, 3)
    assert a.outer_levels_for == {"glb": (0,), "pebuf": (0, 1, 2),
                                  "reg": (0, 1, 2, 3, 4)}
    assert a.inner_levels_for == {"glb": (1, 2, 3, 4), "pebuf": (3, 4),
                                  "reg": ()}
    assert a.sg_sites == ("L2", "L3", "C")
    assert [s for _, s, _ in a.capacity_stores] == ["glb", "pebuf"]


def test_platforms_share_topology_but_not_numbers():
    e, c = as_arch("edge"), as_arch("cloud")
    assert e.topology == c.topology == ARCH_SPARSEMAP.topology
    assert not np.array_equal(e.param_vector(), c.param_vector())
    # edge has 1 MAC/PE but keeps the 5-level structure (spatial=True)
    assert e.n_levels == 5 and e.spatial_caps() == (256, 1)


def test_new_archs_have_distinct_topologies():
    fps = {a.topology.fingerprint
           for a in (ARCH_SPARSEMAP, MAPLE_EDGE, CLUSTER_CLOUD)}
    assert len(fps) == 3
    assert MAPLE_EDGE.n_levels == 3 and MAPLE_EDGE.sg_sites == ("L2", "C")
    assert CLUSTER_CLOUD.n_levels == 7
    assert CLUSTER_CLOUD.sg_sites == ("L2", "L3", "L4", "C")
    assert [s for _, s, _ in CLUSTER_CLOUD.capacity_stores] == \
        ["glb", "cbuf", "pebuf"]


def test_as_arch_resolution():
    assert as_arch("maple_edge") is MAPLE_EDGE
    assert as_arch(MAPLE_EDGE) is MAPLE_EDGE
    assert as_arch(accel.CLOUD) is arch_from_platform(accel.CLOUD)
    with pytest.raises(KeyError):
        as_arch("no_such_arch")


def test_archspec_rejects_malformed_hierarchies():
    with pytest.raises(ValueError):        # one level only
        ArchSpec("x", (StorageLevel("dram"),))
    with pytest.raises(ValueError):        # spatial backing store
        ArchSpec("x", (StorageLevel("dram", fanout=4),
                       StorageLevel("glb")))
    with pytest.raises(ValueError):        # duplicate store name
        ArchSpec("x", (StorageLevel("dram"), StorageLevel("dram")))
    with pytest.raises(ValueError):        # site on innermost store
        ArchSpec("x", (StorageLevel("dram"),
                       StorageLevel("glb", sg_site="L2")))
    with pytest.raises(ValueError):        # reserved compute site name
        ArchSpec("x", (StorageLevel("dram", sg_site="C"),
                       StorageLevel("glb")))


# ---------------------------------------------------------- end-to-end


@pytest.mark.parametrize("archname", ["maple_edge", "cluster_cloud"])
def test_method_sweep_runs_end_to_end_on_new_arch(archname):
    """Acceptance criterion: non-default topologies run through the full
    concurrent mega-batched search stack, mixing methods — including the
    direct-encoding standard_es bridge."""
    wls = [spmm(f"{archname}_a", 32, 64, 48, 0.2, 0.5),
           spmm(f"{archname}_b", 48, 32, 64, 0.4, 0.3)]
    stats: dict = {}
    grid = search.run_method_sweep(
        ["sparsemap", "random_mapper", "standard_es"], wls, archname,
        budget=200, seed=0, stats_out=stats)
    arch = as_arch(archname)
    for m in grid:
        for w, res in grid[m].items():
            assert res.evals >= 200
            assert (np.asarray(res.history)[1:] <=
                    np.asarray(res.history)[:-1]).all()
    # the whole fleet mega-batches on the arch's single signature
    assert len(stats["signatures"]) == 1
    assert stats["signatures"][0][2] == arch.topology.fingerprint
    assert stats["dispatches"] == stats["rounds"]


def test_sparsemap_search_finds_valid_designs_on_new_archs():
    wl = spmm("arch_probe", 32, 64, 48, 0.2, 0.5)
    for archname in ("maple_edge", "cluster_cloud"):
        res = search.run("sparsemap", wl, archname, budget=800, seed=0)
        assert np.isfinite(res.best_edp), archname
        rep = search.report_best(wl, archname, res)
        assert rep is not None and rep.valid
        assert rep.edp == pytest.approx(res.best_edp, rel=1e-3)


def test_shared_energy_group_names_accumulate():
    """Two edges may reuse an energy-group name (e.g. "noc"); the numpy
    oracle must ACCUMULATE into the shared breakdown entry, matching the
    kernel, not overwrite the earlier edge's energy."""
    from repro.core.cost_model import evaluate
    from repro.core.encoding import GenomeSpec
    from repro.core.jax_cost import JaxCostModel

    arch = ArchSpec("dup_groups", (
        StorageLevel("dram"),
        StorageLevel("glb", capacity_bytes=256 * 1024,
                     fill_energy=(("noc", (100.0,)),), sg_site="L2"),
        StorageLevel("reg", fill_energy=(("noc", (3.5,)),),
                     fanout=256),
    ))
    wl = spmm("dupgrp", 16, 16, 16, 0.5, 0.5)
    spec = GenomeSpec(wl, arch=arch)
    from repro.core.baselines import fixed_mapping_genes_for_arch
    g = np.zeros(spec.length, dtype=np.int64)
    for k, v in fixed_mapping_genes_for_arch(spec, arch).items():
        g[k] = v
    rep = evaluate(spec.decode(g), arch)
    assert rep.valid, rep.reason
    assert set(rep.energy_breakdown) == {"noc", "mac"}
    out = JaxCostModel(spec, arch)(g[None, :])
    assert bool(out["valid"][0])
    lg = np.log10(rep.edp)
    assert abs(lg - out["log10_edp"][0]) <= 2e-3 * max(abs(lg), 1)


def test_evaluator_cache_is_arch_content_keyed_not_name_keyed():
    """Two content-different ArchSpecs sharing a NAME must not alias one
    cached evaluator (the arch analogue of PR 2's workload id-reuse
    fix)."""
    wl = spmm("name_clash", 16, 16, 16, 0.5, 0.5)
    s1, e1 = search.get_evaluator(wl, "cloud")
    impostor = ArchSpec("cloud", (
        StorageLevel("dram"),
        StorageLevel("glb", capacity_bytes=1024,
                     fill_energy=(("dram", (100.0,)),)),
        StorageLevel("reg", fill_energy=(("glb", (3.0,)),), fanout=4),
    ))
    s2, e2 = search.get_evaluator(wl, impostor)
    assert e1 is not e2
    assert s2.arch.n_levels == impostor.n_levels != s1.arch.n_levels


def test_same_workload_different_archs_do_not_alias():
    """The evaluator cache must key on the arch too: one workload
    searched on two topologies gets two evaluators with different genome
    lengths."""
    wl = spmm("alias_arch", 32, 64, 48, 0.2, 0.5)
    s1, e1 = search.get_evaluator(wl, "cloud")
    s2, e2 = search.get_evaluator(wl, "maple_edge")
    assert s1.length != s2.length
    assert e1.signature != e2.signature
