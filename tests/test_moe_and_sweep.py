"""MoE dispatch equivalence (flat vs grouped — the §Perf variant must be
numerically faithful), hypothesis property tests on the data pipeline,
and completeness of the dry-run sweep records."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

try:        # hypothesis is an optional test extra (pyproject.toml)
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, strategies as st

from repro.models.moe import moe_ffn, moe_ffn_grouped

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _params(rng, d, e, f):
    def mk(*s):
        return jnp.asarray(rng.standard_normal(s) * 0.2, jnp.float32)
    return dict(wg=mk(d, e), w1=mk(e, d, f), w3=mk(e, d, f),
                w2=mk(e, f, d))


def test_grouped_equals_flat_without_drops():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 64, 16)), jnp.float32)
    p = _params(rng, 16, 8, 32)
    y1, _ = moe_ffn(x, p, 2, capacity_factor=8.0)       # no drops
    y2, _ = moe_ffn_grouped(x, p, 2, capacity_factor=8.0, n_groups=8)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("groups", [1, 2, 16])
def test_grouped_group_count_invariance(groups):
    """Without drops the group count cannot change the math."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((1, 32, 8)), jnp.float32)
    p = _params(rng, 8, 4, 16)
    y_ref, _ = moe_ffn_grouped(x, p, 2, capacity_factor=16.0, n_groups=1)
    y, _ = moe_ffn_grouped(x, p, 2, capacity_factor=16.0, n_groups=groups)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)


def test_capacity_drops_tokens_gracefully():
    """With capacity_factor << 1, outputs shrink but stay finite (dropped
    tokens pass through the residual at the block level)."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((1, 64, 8)), jnp.float32)
    p = _params(rng, 8, 4, 16)
    y, _ = moe_ffn(x, p, 2, capacity_factor=0.1)
    assert bool(jnp.isfinite(y).all())
    y_full, _ = moe_ffn(x, p, 2, capacity_factor=8.0)
    assert float(jnp.abs(y).sum()) < float(jnp.abs(y_full).sum())


def test_router_aux_loss_positive():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((1, 32, 8)), jnp.float32)
    p = _params(rng, 8, 4, 16)
    _, aux = moe_ffn(x, p, 2)
    assert float(aux) >= 1.0 - 1e-3      # >= 1 by Switch-loss construction


# ------------------------------------------------------------ pipeline
@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=1, max_value=8))
def test_data_shards_partition_exactly(step, n_shards):
    from repro.data.pipeline import DataConfig, SyntheticLM
    cfg = DataConfig(vocab_size=256, seq_len=16, global_batch=8)
    d = SyntheticLM(cfg)
    if cfg.global_batch % n_shards != 0:
        return
    full_shapes = d.batch_at(step)["tokens"].shape
    shard_rows = sum(d.batch_at(step, (i, n_shards))["tokens"].shape[0]
                     for i in range(n_shards))
    assert shard_rows == full_shapes[0]


# ------------------------------------------------------------ sweep
def _dryrun_records():
    path = os.path.join(ROOT, "bench_out", "dryrun.jsonl")
    if not os.path.exists(path):
        pytest.skip("dry-run sweep has not been executed")
    recs = {}
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            key = (r.get("arch"), r.get("shape"), r.get("mesh"))
            if r.get("status") in ("ok", "skipped") or key not in recs:
                recs[key] = r
    return recs


def test_sweep_covers_all_cells_on_both_meshes():
    from repro.configs import ARCHS, SHAPES, applicable
    recs = _dryrun_records()
    missing, failed = [], []
    for a in ARCHS:
        for s in SHAPES:
            for mesh in ("16x16", "2x16x16"):
                r = recs.get((a, s, mesh))
                if r is None:
                    missing.append((a, s, mesh))
                    continue
                ok, _ = applicable(a, s)
                if ok and r.get("status") != "ok":
                    failed.append((a, s, mesh, r.get("error", "")[:80]))
                if not ok and r.get("status") != "skipped":
                    failed.append((a, s, mesh, "expected skip"))
    assert not missing, f"missing cells: {missing}"
    assert not failed, f"failed cells: {failed}"


def test_sweep_records_have_roofline_terms():
    recs = _dryrun_records()
    for key, r in recs.items():
        if r.get("status") != "ok":
            continue
        assert r["flops_per_device"] > 0, key
        assert r["bytes_per_device"] > 0, key
        assert r["bottleneck"] in ("compute", "memory", "collective"), key
        assert r["t_memory_s"] > 0, key
        # train cells must include optimizer state in the analytic bytes
        if r["kind"] == "train":
            assert r["state_bytes_per_device"] > 1e6, key
