"""Pallas kernel validation: shape/dtype sweeps, interpret mode vs the
pure-jnp oracles, and skipping-semantics checks."""
import zlib

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.bsr_spmm import bsr_spmm
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import (bsr_to_dense, dense_to_bsr,
                               flash_attention_ref)


def make_block_sparse(rng, m, k, bm, bk, density, dtype):
    p = rng.standard_normal((m, k)).astype(dtype)
    mask = rng.random((m // bm, k // bk)) < density
    for i in range(m // bm):
        for j in range(k // bk):
            if not mask[i, j]:
                p[i * bm:(i + 1) * bm, j * bk:(j + 1) * bk] = 0
    return p


# ------------------------------------------------------------- BSR SpMM
@pytest.mark.parametrize("m,k,n,bm,bk,bn", [
    (32, 256, 128, 8, 128, 128),
    (64, 128, 256, 16, 128, 128),
    (128, 512, 128, 8, 128, 128),
])
@pytest.mark.parametrize("density", [0.1, 0.5, 0.9])
@pytest.mark.parametrize("dtype", [np.float32, "bfloat16"])
def test_bsr_spmm_sweep(m, k, n, bm, bk, bn, density, dtype):
    dt = jnp.bfloat16 if dtype == "bfloat16" else jnp.float32
    rng = np.random.default_rng(
        zlib.crc32(f'{m}:{k}:{n}:{density}:{dtype}'.encode()))
    p = make_block_sparse(rng, m, k, bm, bk, density, np.float32)
    q = rng.standard_normal((k, n)).astype(np.float32)
    blocks, col_idx, row_ptr = dense_to_bsr(p, bm, bk)
    max_nnz = max(int(np.diff(row_ptr).max()), 1)
    z = bsr_spmm(jnp.asarray(blocks, dt), jnp.asarray(col_idx),
                 jnp.asarray(row_ptr), jnp.asarray(q, dt),
                 m_blocks=m // bm, max_row_nnz=max_nnz, bn=bn,
                 interpret=True)
    z_ref = p @ q
    tol = 1e-5 if dt == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(z, np.float32), z_ref,
        rtol=tol, atol=tol * max(1.0, np.abs(z_ref).max()))


def test_bsr_roundtrip():
    rng = np.random.default_rng(0)
    p = make_block_sparse(rng, 64, 256, 8, 128, 0.4, np.float32)
    blocks, col_idx, row_ptr = dense_to_bsr(p, 8, 128)
    back = bsr_to_dense(blocks, col_idx, row_ptr, 8, 2)
    np.testing.assert_array_equal(back, p)


def test_bsr_spmm_empty_rows():
    """Rows with zero stored blocks must produce zero output rows."""
    rng = np.random.default_rng(1)
    m, k, n, bm, bk = 32, 256, 128, 8, 128
    p = make_block_sparse(rng, m, k, bm, bk, 0.5, np.float32)
    p[0:bm] = 0             # first block row fully empty
    q = rng.standard_normal((k, n)).astype(np.float32)
    blocks, col_idx, row_ptr = dense_to_bsr(p, bm, bk)
    max_nnz = max(int(np.diff(row_ptr).max()), 1)
    z = bsr_spmm(jnp.asarray(blocks), jnp.asarray(col_idx),
                 jnp.asarray(row_ptr), jnp.asarray(q),
                 m_blocks=m // bm, max_row_nnz=max_nnz, interpret=True)
    assert np.abs(np.asarray(z)[0:bm]).max() == 0.0
    np.testing.assert_allclose(np.asarray(z), p @ q, rtol=1e-5, atol=1e-4)


def test_bsr_skip_saves_work():
    """The compacted representation stores only effectual blocks — the
    skip ratio equals the block density (energy AND cycles at tile
    granularity, paper Fig. 6)."""
    rng = np.random.default_rng(2)
    m, k, bm, bk = 64, 512, 8, 128
    p = make_block_sparse(rng, m, k, bm, bk, 0.25, np.float32)
    blocks, col_idx, row_ptr = dense_to_bsr(p, bm, bk)
    dense_blocks = (m // bm) * (k // bk)
    assert blocks.shape[0] < 0.5 * dense_blocks


# ------------------------------------------------------- flash attention
@pytest.mark.parametrize("s,bq,bk", [(256, 128, 128), (512, 128, 256)])
@pytest.mark.parametrize("hd", [128])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [np.float32, "bfloat16"])
def test_flash_attention_sweep(s, bq, bk, hd, causal, dtype):
    dt = jnp.bfloat16 if dtype == "bfloat16" else jnp.float32
    rng = np.random.default_rng(
        zlib.crc32(f'{s}:{bq}:{causal}:{dtype}'.encode()))
    q = jnp.asarray(rng.standard_normal((1, 2, s, hd)) * 0.3, dt)
    k = jnp.asarray(rng.standard_normal((1, 2, s, hd)) * 0.3, dt)
    v = jnp.asarray(rng.standard_normal((1, 2, s, hd)), dt)
    o = flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                        interpret=True)
    o_ref = flash_attention_ref(q, k, v, causal=causal)
    tol = 1e-5 if dt == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(o_ref, np.float32),
        rtol=tol, atol=tol)


def test_flash_matches_ref_first_row_causal():
    """Causal row 0 attends only to itself -> output == v[0]."""
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((1, 1, 256, 128)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 256, 128)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 1, 256, 128)), jnp.float32)
    o = flash_attention(q, k, v, causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(o)[0, 0, 0],
                               np.asarray(v)[0, 0, 0], rtol=1e-5)


# ------------------------------------------------------------- dispatch
def test_ops_dispatch_ref_on_cpu():
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.standard_normal((1, 1, 256, 128)) * 0.3)
    out = ops.flash_attention(q, q, q, causal=True)
    ref = flash_attention_ref(q, q, q, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
