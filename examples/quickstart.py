"""Quickstart: run SparseMap's joint mapping x sparse-strategy search on
one paper workload, print the winning accelerator design, then train a
reduced LM for a few steps with the surrounding framework.

    PYTHONPATH=src python examples/quickstart.py
"""
import time


def main():
    # ---------------- 1. the paper's DSE ----------------
    from repro.core import search
    from repro.configs.paper_workloads import by_name

    wl = by_name("conv4")       # pruned VGG16 layer (Table III)
    print(f"workload {wl.name}: dims={wl.orig_dim_sizes} "
          f"densities=({wl.density_of('P'):.2f}, "
          f"{wl.density_of('Q'):.2f})")

    t0 = time.time()
    res = search.run("sparsemap", wl, "cloud", budget=2000, seed=0)
    print(f"SparseMap: best EDP {res.best_edp:.3e} "
          f"(valid {100 * res.valid_fraction:.0f}% of "
          f"{res.evals} evals, {time.time() - t0:.1f}s)")

    base = search.run("random_mapper", wl, "cloud", budget=2000, seed=0)
    print(f"Sparseloop-Mapper-like baseline: {base.best_edp:.3e} "
          f"({base.best_edp / res.best_edp:.1f}x worse)")

    design = search.decode_best(wl, res)
    print("\nwinning mapping:")
    print(design.mapping.describe())
    print("sparse strategy:",
          {t: [f for f in fmt.formats] for t, fmt in
           design.strategy.formats.items()},
          "S/G:", design.strategy.sg)

    # ---------------- 2. train a small LM ----------------
    from repro.launch import train
    print("\ntraining xlstm-350m (smoke config) for 30 steps...")
    train.main(["--arch", "xlstm-350m", "--smoke", "--steps", "30",
                "--batch", "4", "--seq", "64", "--log-every", "10"])


if __name__ == "__main__":
    main()
