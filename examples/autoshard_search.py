"""Beyond-paper scenario: SparseMap's evolution strategy searching THIS
framework's distributed-mapping space (sharding / remat / microbatching /
optimizer precision) for the assigned architectures on the production
meshes — the paper's joint-space insight applied to multi-pod training.

    PYTHONPATH=src python examples/autoshard_search.py
"""


def main():
    from repro.configs import get_config
    from repro.core import autoshard

    meshes = {
        "1 pod (256 chips)": {"data": 16, "model": 16},
        "2 pods (512 chips)": {"pod": 2, "data": 16, "model": 16},
    }
    for arch in ("mistral-nemo-12b", "command-r-35b", "kimi-k2-1t-a32b",
                 "gemma3-12b"):
        cfg = get_config(arch)
        print(f"\n== {arch} (train_4k: 256 x 4096 tokens/step)")
        for mesh_name, mesh in meshes.items():
            dec, est, res = autoshard.search(cfg, 4096, 256, mesh,
                                             budget=2000, seed=0)
            if dec is None:
                print(f"  {mesh_name}: INFEASIBLE "
                      f"(no decision fits 16 GB HBM/chip)")
                continue
            print(f"  {mesh_name}: {est.t_total * 1e3:7.0f} ms/step "
                  f"[{est.bottleneck}-bound] "
                  f"hbm {est.hbm_bytes_per_device / 1e9:4.1f} GB/dev")
            keys = ("remat", "microbatches", "logits", "mlp_shard",
                    "zero1", "moments")
            print(f"     decisions: "
                  f"{{{', '.join(f'{k}={dec[k]}' for k in keys)}}}")
        # the joint-vs-marginal ablation: freeze everything except one
        # factor family and compare (the paper's Fig. 2 argument)
        mesh = meshes["1 pod (256 chips)"]
        dec, est, _ = autoshard.search(cfg, 4096, 256, mesh, budget=2000,
                                       seed=0)
        if dec is None:
            continue
        worst = 0.0
        for k, alt in (("remat", "full"), ("logits", "gather"),
                       ("moments", "fp32")):
            d2 = dict(dec)
            d2[k] = alt
            e2 = autoshard.estimate(cfg, 4096, 256, mesh, d2)
            if e2.valid:
                worst = max(worst, e2.t_total / est.t_total)
        print(f"     single bad factor costs up to {worst:.2f}x "
              f"(why joint search matters)")


if __name__ == "__main__":
    main()
