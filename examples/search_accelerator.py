"""Full accelerator DSE scenario: search sparse-accelerator designs for
the dominant GEMMs of an assigned LLM architecture across hardware
platforms, and compare against the prior-work baselines.

    PYTHONPATH=src python examples/search_accelerator.py \
        [--model kimi-k2-1t-a32b] [--budget 4000]

``--arch`` targets any single paper platform or registered accelerator
topology by name (``--list-archs`` prints the registry, including the
published-accelerator zoo from ``repro.configs.archs``); ``--platforms``
takes a comma-separated mix, e.g. ``--platforms cloud,eyeriss_like`` —
the whole stack is ArchSpec-driven, so non-default memory hierarchies
search end-to-end.

``--profile DIR`` wraps the whole sweep in ``jax.profiler`` and dumps a
TensorBoard-loadable trace directory — the tool for eyeballing the
pipelined round loop (device kernels should tile the timeline with the
host planning in the gaps; big host-blocked stalls mean a compile-ahead
miss or a lost overlap).
"""
import argparse
import time


def list_archs():
    from repro.core.accel import PLATFORMS
    from repro.core.arch import registered_archs
    print("paper platforms:")
    for name in sorted(PLATFORMS):
        print(f"  {name}")
    print("registered archs (repro.configs.archs):")
    for name, spec in sorted(registered_archs().items()):
        head = spec.describe().splitlines()[-1]
        print(f"  {name:>16s}  {head}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="kimi-k2-1t-a32b",
                    help="assigned LLM architecture to extract GEMMs from")
    ap.add_argument("--budget", type=int, default=4000)
    ap.add_argument("--arch", default=None, metavar="NAME",
                    help="single target platform/arch name (overrides "
                         "--platforms); see --list-archs")
    ap.add_argument("--platforms", default="edge,cloud",
                    help="comma-separated platform/arch names")
    ap.add_argument("--list-archs", action="store_true",
                    help="print every resolvable platform/arch and exit")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="dump a jax.profiler trace of the sweep to DIR "
                         "(view with TensorBoard)")
    args = ap.parse_args(argv)

    if args.list_archs:
        list_archs()
        return

    from repro.configs.paper_workloads import arch_gemms
    from repro.core import search
    from repro.core.arch import as_arch

    targets = [args.arch] if args.arch else args.platforms.split(",")
    for t in targets:
        as_arch(t)      # fail fast with the full registry listing

    workloads = arch_gemms(args.model, weight_density=0.5,
                           act_density=0.6)
    print(f"extracted {len(workloads)} GEMMs from {args.model} "
          f"(50% pruned weights, 60% dense activations)\n")

    if args.profile:
        import jax
        jax.profiler.start_trace(args.profile)

    methods = ("sparsemap", "sage_like", "random_mapper")
    for plat in targets:
        print(f"== platform: {plat}")
        # the whole (method x workload) grid runs as one concurrent
        # mega-batched fleet — same results as per-method search.run
        # at fixed seeds, one device dispatch per signature per round
        t0 = time.time()
        stats = {}
        grid = search.run_method_sweep(
            methods, workloads, plat, budget=args.budget, seed=0,
            stats_out=stats,
            config=search.FleetConfig(stack_batches=True))
        for wl in workloads:
            row = {m: grid[m][wl.name].best_edp for m in methods}
            ours = row["sparsemap"]
            print(f"  {wl.name:>28s}: ours {ours:10.3e}  "
                  f"SAGE-like {row['sage_like'] / ours:6.1f}x  "
                  f"Sparseloop-like {row['random_mapper'] / ours:6.1f}x")
        print(f"  [{len(workloads) * len(methods)} searches, "
              f"{stats['rounds']} rounds, {stats['dispatches']} device "
              f"dispatches, compile-ahead "
              f"{stats['compile_ahead_hits']}h/"
              f"{stats['compile_ahead_misses']}m, "
              f"host-blocked {stats['host_blocked_s']:.3f}s, "
              f"{time.time() - t0:.1f}s]")
    print("\n(EDP = cycles x pJ; larger ratio = larger our advantage)")

    if args.profile:
        jax.profiler.stop_trace()
        print(f"\nprofiler trace written to {args.profile}/ "
              f"(tensorboard --logdir {args.profile})")


if __name__ == "__main__":
    main()
