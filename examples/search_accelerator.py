"""Full accelerator DSE scenario: search sparse-accelerator designs for
the dominant GEMMs of an assigned LLM architecture across the three
hardware platforms, and compare against the prior-work baselines.

    PYTHONPATH=src python examples/search_accelerator.py \
        [--arch kimi-k2-1t-a32b] [--budget 4000]

``--platforms`` accepts any mix of the paper platforms (edge/mobile/
cloud) and registered accelerator topologies (repro.configs.archs),
e.g. ``--platforms cloud,maple_edge,cluster_cloud`` — the whole stack is
ArchSpec-driven, so non-default memory hierarchies search end-to-end.
"""
import argparse
import time



def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="kimi-k2-1t-a32b")
    ap.add_argument("--budget", type=int, default=4000)
    ap.add_argument("--platforms", default="edge,cloud")
    args = ap.parse_args(argv)

    from repro.configs.paper_workloads import arch_gemms
    from repro.core import search

    workloads = arch_gemms(args.arch, weight_density=0.5,
                           act_density=0.6)
    print(f"extracted {len(workloads)} GEMMs from {args.arch} "
          f"(50% pruned weights, 60% dense activations)\n")

    methods = ("sparsemap", "sage_like", "random_mapper")
    for plat in args.platforms.split(","):
        print(f"== platform: {plat}")
        # the whole (method x workload) grid runs as one concurrent
        # mega-batched fleet — same results as per-method search.run
        # at fixed seeds, one device dispatch per signature per round
        t0 = time.time()
        stats = {}
        grid = search.run_method_sweep(methods, workloads, plat,
                                       budget=args.budget, seed=0,
                                       stats_out=stats)
        for wl in workloads:
            row = {m: grid[m][wl.name].best_edp for m in methods}
            ours = row["sparsemap"]
            print(f"  {wl.name:>28s}: ours {ours:10.3e}  "
                  f"SAGE-like {row['sage_like'] / ours:6.1f}x  "
                  f"Sparseloop-like {row['random_mapper'] / ours:6.1f}x")
        print(f"  [{len(workloads) * len(methods)} searches, "
              f"{stats['rounds']} rounds, {stats['dispatches']} device "
              f"dispatches, {time.time() - t0:.1f}s]")
    print("\n(EDP = cycles x pJ; larger ratio = larger our advantage)")


if __name__ == "__main__":
    main()
