"""Client for the persistent sweep server: submit one accelerator-search
query and stream best-so-far results as the server's shared fleet runs.

Start the server in one terminal:

    PYTHONPATH=src python -m repro.launch.serve sweep --port 7333

then submit queries from others (concurrent same-signature queries
coalesce into one mega-batch round on the server — watch
``--stats`` report ~1.0 dispatches/round either way):

    PYTHONPATH=src python examples/sweep_client.py --port 7333 \
        --m 256 --k 256 --n 256 --density 0.3,0.4 --arch cloud \
        --method sparsemap --budget 4000
    PYTHONPATH=src python examples/sweep_client.py --port 7333 --stats
"""
import argparse
import json


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Submit a (workload, arch, density, method, budget) "
                    "query to a running sweep server and stream "
                    "best-so-far updates.")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--stats", action="store_true",
                    help="print server stats instead of submitting")
    ap.add_argument("--shutdown", action="store_true",
                    help="ask the server to stop")
    ap.add_argument("--name", default="client_query")
    ap.add_argument("--m", type=int, default=256)
    ap.add_argument("--k", type=int, default=256)
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--density", default="0.3,0.4",
                    help="comma pair: A density, B density")
    ap.add_argument("--arch", default="cloud",
                    help="platform or registered arch name")
    ap.add_argument("--method", default="sparsemap")
    ap.add_argument("--budget", type=int, default=4000)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.core.search import SearchTask
    from repro.core.workload import spmm
    from repro.launch import sweep_serve

    if args.stats:
        reply = next(iter(sweep_serve.request(
            args.host, args.port, {"op": "stats"})))
        print(json.dumps(reply["stats"], indent=2, default=str))
        return 0
    if args.shutdown:
        print(next(iter(sweep_serve.request(
            args.host, args.port, {"op": "shutdown"}))))
        return 0

    da, db = (float(x) for x in args.density.split(","))
    task = SearchTask(
        spmm(args.name, args.m, args.k, args.n, da, db),
        args.arch, budget=args.budget, seed=args.seed,
        method=args.method)
    for ev in sweep_serve.submit(args.host, args.port, task):
        if not ev.get("ok", True):
            print(f"rejected: {ev['error']}")
            return 1
        if "id" in ev and "event" not in ev:
            print(f"accepted as {ev['id']!r}")
        elif ev.get("event") == "update":
            print(f"  round {ev['round']:>4}  evals {ev['evals']:>6}  "
                  f"best EDP {ev['best_edp']:.4e}")
        elif ev.get("event") == "done":
            print(f"done: best EDP {ev['best_edp']:.4e} after "
                  f"{ev['evals']} evals ({ev['valid_evals']} valid)")
            print(f"best genome: {ev['best_genome']}")
        elif ev.get("event") == "failed":
            print(f"failed: {ev['error']}")
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
