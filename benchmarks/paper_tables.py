"""Benchmarks reproducing the paper's tables/figures.

Each function writes a CSV under bench_out/ and returns summary rows.
Budgets default to CI scale (the paper used 20 000 evals/workload; pass
--budget 20000 for the full setting — the jit-vectorized evaluator makes
that feasible too).
"""
from __future__ import annotations

import csv
import os
import time
from typing import Dict, List, Sequence

import numpy as np

from repro.configs.paper_workloads import all_workloads, by_name
from repro.core import accel, search
from repro.core.workload import spmm

OUT_DIR = os.environ.get("REPRO_BENCH_OUT", "bench_out")


def _write_csv(name: str, header: Sequence[str], rows: List[Sequence]):
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, name)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path


# ----------------------------------------------------------- Fig. 17


def fig17_baselines(budget: int = 1500, seeds: Sequence[int] = (0,),
                    workload_names: Sequence[str] = ("conv2", "conv4",
                                                     "conv5", "conv7"),
                    platform: str = "cloud",
                    concurrent: bool = True) -> List[Dict]:
    """Fig. 17(a)/(b): SparseMap vs classical optimizers on pruned-VGG16
    layers (EDP + valid-point fraction under the same budget).

    With ``concurrent=True`` (default) the whole grid runs as ONE
    mega-batched ``search.run_method_sweep`` fleet per seed — same results
    at fixed seeds, one device dispatch per signature per round instead of
    one per (method, workload)."""
    methods = ["sparsemap", "pso", "mcts", "tbpsa", "ppo", "dqn"]
    wls = [by_name(n) for n in workload_names]
    results: Dict[str, Dict[str, List]] = \
        {m: {w.name: [] for w in wls} for m in methods}
    t0 = time.time()
    for seed in seeds:
        if concurrent:
            grid = search.run_method_sweep(methods, wls, platform,
                                           budget=budget, seed=seed)
            for m in methods:
                for w in wls:
                    results[m][w.name].append(grid[m][w.name])
        else:
            for m in methods:
                for w in wls:
                    results[m][w.name].append(
                        search.run(m, w, platform, budget=budget,
                                   seed=seed))
    grid_seconds = round(time.time() - t0, 1)
    rows, out = [], []
    for wname in workload_names:
        for method in methods:
            rs = results[method][wname]
            rec = dict(workload=wname, method=method,
                       edp=float(np.min([r.best_edp for r in rs])),
                       valid_frac=float(np.mean([r.valid_fraction
                                                 for r in rs])),
                       budget=budget, grid_seconds=grid_seconds)
            out.append(rec)
            rows.append([wname, method, rec["edp"], rec["valid_frac"],
                         budget])
    _write_csv("fig17.csv",
               ["workload", "method", "best_edp", "valid_frac", "budget"],
               rows)
    return out


# ----------------------------------------------------------- Table IV


def table_iv(budget: int = 1500, seed: int = 0,
             platforms: Sequence[str] = ("edge", "mobile", "cloud"),
             workload_names: Sequence[str] = None) -> List[Dict]:
    """Table IV: ours vs Sparseloop-Mapper-like vs SAGE-like across the
    28 workloads x 3 platforms."""
    wls = all_workloads() if workload_names is None else \
        [by_name(n) for n in workload_names]
    methods = ["random_mapper", "sage_like", "sparsemap"]
    rows, out = [], []
    for wl in wls:
        for plat in platforms:
            rec = dict(workload=wl.name, platform=plat)
            for method in methods:
                res = search.run(method, wl, plat, budget=budget,
                                 seed=seed)
                rec[method] = res.best_edp
            rec["speedup_vs_sparseloop"] = (
                rec["random_mapper"] / rec["sparsemap"]
                if np.isfinite(rec["sparsemap"]) else float("nan"))
            rec["speedup_vs_sage"] = (
                rec["sage_like"] / rec["sparsemap"]
                if np.isfinite(rec["sparsemap"]) else float("nan"))
            out.append(rec)
            rows.append([wl.name, plat, rec["random_mapper"],
                         rec["sage_like"], rec["sparsemap"],
                         rec["speedup_vs_sparseloop"],
                         rec["speedup_vs_sage"]])
    _write_csv("table_iv.csv",
               ["workload", "platform", "sparseloop_like", "sage_like",
                "sparsemap", "speedup_vs_sparseloop", "speedup_vs_sage"],
               rows)
    return out


# ----------------------------------------------------------- Fig. 18


def fig18_ablation(budget: int = 3000, seed: int = 0,
                   workload_names: Sequence[str] = ("mm3", "conv4"),
                   platform: str = "cloud",
                   concurrent: bool = True) -> List[Dict]:
    """Fig. 18: standard ES (direct encoding) vs +PFCE vs full SparseMap
    (+CEOI); convergence curves to CSV.

    All three curves — including the direct-encoding ``standard_es``,
    whose generator yields canonical rows — now run as ONE mega-batched
    ``run_method_sweep`` fleet by default; results are identical to the
    sequential path at fixed seeds."""
    methods = ["standard_es", "pfce_es", "sparsemap"]
    wls = [by_name(n) for n in workload_names]
    if concurrent:
        grid = search.run_method_sweep(methods, wls, platform,
                                       budget=budget, seed=seed)
        results = {(m, w.name): grid[m][w.name]
                   for m in methods for w in wls}
    else:
        results = {(m, w.name): search.run(m, w, platform, budget=budget,
                                           seed=seed)
                   for m in methods for w in wls}
    rows, out = [], []
    for wname in workload_names:
        for method in methods:
            res = results[(method, wname)]
            # subsample history to 100 points
            h = res.history
            idx = np.linspace(0, len(h) - 1, 100).astype(int)
            for i in idx:
                rows.append([wname, method, int(i), h[i]])
            out.append(dict(workload=wname, method=method,
                            best_edp=res.best_edp,
                            valid_frac=res.valid_fraction))
    _write_csv("fig18.csv", ["workload", "method", "eval", "best_edp"],
               rows)
    return out


# ----------------------------------------------------------- Fig. 2


def fig2_interaction(platform: str = "mobile") -> List[Dict]:
    """Fig. 2: no single (mapping x format) wins across sparsity — we
    sweep OS/IS mappings x {CSR-like, RLE} formats over densities."""
    from repro.core.cost_model import Design, evaluate, make_tensor_format
    from repro.core.mapping import Mapping, balanced_mapping
    from repro.core.sparse import SparseStrategy

    plat = accel.PLATFORMS[platform]
    rows, out = [], []
    for dens in (0.05, 0.1, 0.2, 0.4, 0.8):
        wl = spmm(f"fig2_d{dens}", 256, 512, 256, dens, dens)
        for mapping_name in ("OS", "IS"):
            mp = balanced_mapping(wl, plat.n_pe, plat.macs_per_pe)
            if mapping_name == "IS":
                # input stationary: move contraction dims outermost
                perms = tuple(
                    tuple(reversed(p)) for p in mp.perms)
                mp = Mapping(workload=wl, factors=mp.factors, perms=perms)
            for fmt_name, genes in (("CSR", (0, 0, 0, 4, 3)),
                                    ("RLE", (0, 0, 0, 0, 2))):
                fmts = {t.name: make_tensor_format(mp, t.name, genes)
                        for t in wl.tensors}
                fmts["Z"] = make_tensor_format(mp, "Z", (0, 0, 0, 0, 0))
                st = SparseStrategy(formats=fmts,
                                    sg={"L2": 0, "L3": 0, "C": 3})
                rep = evaluate(Design(mp, st), plat)
                rec = dict(density=dens, mapping=mapping_name,
                           fmt=fmt_name, valid=rep.valid,
                           edp=rep.edp if rep.valid else float("inf"),
                           latency=rep.cycles if rep.valid else
                           float("inf"),
                           energy=rep.energy_pj if rep.valid else
                           float("inf"))
                out.append(rec)
                rows.append([dens, mapping_name, fmt_name, rep.valid,
                             rec["edp"], rec["latency"], rec["energy"]])
    _write_csv("fig2.csv", ["density", "mapping", "format", "valid",
                            "edp", "latency_cycles", "energy_pj"], rows)
    return out


# ----------------------------------------------------------- Fig. 7


def fig7_space(n_samples: int = 1000, platform: str = "cloud",
               seed: int = 0) -> Dict:
    """Fig. 7: random design points; valid points are a small colored
    island in a sea of invalid ones.  PCA over mapping/sparse gene
    blocks reproduces the scatter structure."""
    wl = by_name("mm3")
    spec, ev = search.get_evaluator(wl, platform)
    rng = np.random.default_rng(seed)
    G = spec.random_genomes(rng, n_samples)
    res = ev(G)
    valid = np.asarray(res["valid"])
    edp = np.asarray(res["edp"])

    def pca1(block: np.ndarray) -> np.ndarray:
        x = block.astype(np.float64)
        x = (x - x.mean(0)) / (x.std(0) + 1e-9)
        cov = x.T @ x / len(x)
        w, v = np.linalg.eigh(cov)
        return x @ v[:, -1]

    map_end = spec.segments["tiling"].stop
    xs = pca1(G[:, :map_end])
    ys = pca1(G[:, map_end:])
    rows = [[xs[i], ys[i], bool(valid[i]),
             edp[i] if valid[i] else ""] for i in range(n_samples)]
    _write_csv("fig7.csv", ["pca_mapping", "pca_sparse", "valid", "edp"],
               rows)
    return dict(n=n_samples, valid_frac=float(valid.mean()))
