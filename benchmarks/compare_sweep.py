"""Compare a freshly generated ``BENCH_sweep.json`` against the committed
baseline run (``benchmarks/BENCH_sweep.baseline.json``, regenerated with
``benchmarks.run --quick --only sweep_json`` whenever a PR intentionally
moves the counts) — the CI perf-regression gate.

    PYTHONPATH=src python -m benchmarks.compare_sweep \
        --baseline benchmarks/BENCH_sweep.baseline.json \
        --current BENCH_sweep.new.json

Hard failures (exit 1): a per-arch XLA compile-count increase, a
dispatches-per-round increase, a host-syncs-per-round increase, or a
compile-ahead-miss increase (the AOT predictor losing coverage of a
round-1 signature), compared arch-by-arch over the archs present in
BOTH files (a newly added arch has no baseline and is reported, not
failed).  Timing — seconds, seconds_per_round, host_blocked_s, and the
pipelined-vs-unpipelined host_blocked_s comparison — is warn-only: CI
machines are too noisy to gate on wall-clock.  When the two runs used
different budgets the counts are not comparable either, so everything
downgrades to warnings.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple

#: warn when an arch's wall-clock grows beyond this factor
TIME_WARN_RATIO = 1.5


def _derive_decay_rounds(trajectory):
    """Stdlib mirror of ``repro.core.search.derive_pad_policy`` (this
    gate must not import the package): one-off spike trajectories (step
    down from the peak, never re-grow) suggest ``decay_rounds=2``,
    re-growing ones the conservative default 3, and a trajectory that
    never decayed at all (e.g. a short device-resident fleet that holds
    one mega-batch size throughout) carries no evidence either way —
    ``None``, never warned against the registered policy."""
    traj = list(trajectory)
    peak = max(traj, default=0)
    if peak <= 0 or traj[-1] >= peak:
        return None
    first_down = next(i for i, v in enumerate(traj) if v < peak
                      and max(traj[:i], default=0) == peak)
    regrew = any(b > a for a, b in zip(traj[first_down:],
                                       traj[first_down + 1:]))
    return 3 if regrew else 2


def stale_policy_warnings(current: dict) -> List[str]:
    """Warn when a fresh run's watermark trajectory suggests the
    registered PadPolicy is stale (registration lives in
    ``repro.configs.archs._BASELINE_PAD_WATERMARKS``), or when a policy
    still carries ``source="seed"`` — the author-declared placeholder
    from ``_SEED_PAD_WATERMARKS`` — even though the run just MEASURED
    the topology's real trajectory and the seed should be promoted."""
    out: List[str] = []
    for arec in current.get("archs", []):
        policies = arec.get("pad_policies", {})
        for sig_key, traj in arec.get("pad_watermarks", {}).items():
            fp = sig_key.rsplit("_", 1)[-1]
            pol = policies.get(fp)
            if pol is None:
                continue
            if pol.get("source") == "seed":
                out.append(
                    f"{arec['arch']}: topology {fp} still runs on a "
                    f"seed pad policy but this run measured trajectory "
                    f"{traj} — promote the entry from "
                    f"repro.configs.archs._SEED_PAD_WATERMARKS to "
                    f"_BASELINE_PAD_WATERMARKS")
            want = _derive_decay_rounds(traj)
            if want is not None and want != pol.get("decay_rounds"):
                out.append(
                    f"{arec['arch']}: watermark trajectory {traj} for "
                    f"topology {fp} suggests decay_rounds={want} but the "
                    f"registered policy has "
                    f"decay_rounds={pol.get('decay_rounds')} — update "
                    f"repro.configs.archs._BASELINE_PAD_WATERMARKS")
    return out


def compare(baseline: dict, current: dict) -> Tuple[List[str], List[str]]:
    """(failures, warnings) between two bench_sweep_json records."""
    failures: List[str] = []
    warnings: List[str] = []
    comparable = baseline.get("budget") == current.get("budget")
    if not comparable:
        warnings.append(
            f"budgets differ (baseline {baseline.get('budget')} vs "
            f"current {current.get('budget')}): compile/dispatch counts "
            f"are not comparable, downgrading all checks to warnings")
    base_archs: Dict[str, dict] = {a["arch"]: a
                                   for a in baseline.get("archs", [])}
    cur_archs: Dict[str, dict] = {a["arch"]: a
                                  for a in current.get("archs", [])}
    for name in cur_archs:
        if name not in base_archs:
            warnings.append(f"{name}: new arch, no baseline to compare")
    for name, base in base_archs.items():
        sink = failures if comparable else warnings
        cur = cur_archs.get(name)
        if cur is None:
            sink.append(f"{name}: arch disappeared from the sweep")
            continue
        if cur["compiles"] > base["compiles"]:
            sink.append(
                f"{name}: compiles regressed "
                f"{base['compiles']} -> {cur['compiles']}")
        if cur["dispatches_per_round"] > base["dispatches_per_round"]:
            sink.append(
                f"{name}: dispatches/round regressed "
                f"{base['dispatches_per_round']} -> "
                f"{cur['dispatches_per_round']}")
        # host-sync regression: a device-resident fleet losing its k-round
        # segments (or a per-round fleet growing extra host round-trips)
        # shows up here even when dispatch counts stay flat
        base_hspr = base.get("host_syncs_per_round")
        cur_hspr = cur.get("host_syncs_per_round")
        if base_hspr is not None and cur_hspr is not None and \
                cur_hspr > base_hspr:
            sink.append(
                f"{name}: host syncs/round regressed "
                f"{base_hspr} -> {cur_hspr}")
        # compile-ahead coverage: the predictor failing to claim a
        # round-1 signature it used to cover is a hard regression (a
        # miss means a fresh jit trace landed on the fleet's critical
        # path); hit-count drift and all timing fields stay warn-only
        base_ca = base.get("compile_ahead_misses")
        cur_ca = cur.get("compile_ahead_misses")
        if base_ca is not None and cur_ca is not None and \
                cur_ca > base_ca:
            sink.append(
                f"{name}: compile-ahead misses regressed "
                f"{base_ca} -> {cur_ca}")
        if base.get("seconds") and cur.get("seconds", 0.0) > \
                TIME_WARN_RATIO * base["seconds"]:
            warnings.append(
                f"{name}: {cur['seconds']:.2f}s vs baseline "
                f"{base['seconds']:.2f}s (> {TIME_WARN_RATIO}x, "
                f"warn-only)")
        base_hb = base.get("host_blocked_s")
        cur_hb = cur.get("host_blocked_s", 0.0)
        if base_hb and cur_hb > TIME_WARN_RATIO * base_hb:
            warnings.append(
                f"{name}: host_blocked_s {cur_hb:.4f} vs baseline "
                f"{base_hb:.4f} (> {TIME_WARN_RATIO}x, warn-only)")
    # pipelining acceptance (warn-only, it is a timing measure): the
    # pipelined device fleet should spend strictly less host-blocked
    # wall-clock than its unpipelined twin in the SAME run
    # contract analysis: a violation recorded in the sweep is a hard
    # failure regardless of budget — `python -m repro.analysis` should
    # have caught it pre-merge, the sweep record carries it as artifact
    # provenance
    cur_an = current.get("analysis")
    if cur_an is not None and cur_an.get("violations", 0) > 0:
        failures.append(
            f"analysis: {cur_an['violations']} contract violation(s) "
            f"recorded in the sweep (run `python -m repro.analysis`)")
    # canonical kernel-family jaxpr hashes: drift is warn-only
    base_h = baseline.get("jaxpr_hashes") or {}
    cur_h = current.get("jaxpr_hashes") or {}
    for fam in sorted(set(base_h) & set(cur_h)):
        if base_h[fam] != cur_h[fam]:
            warnings.append(
                f"jaxpr hash drift for kernel family {fam}: "
                f"{base_h[fam]} -> {cur_h[fam]} (warn-only; expected "
                f"only when a PR intentionally changes the kernel)")
    for fam in sorted(set(base_h) - set(cur_h)):
        warnings.append(f"kernel family {fam} disappeared from the "
                        f"jaxpr_hashes record")
    pipe = cur_archs.get("cloud_device_k4")
    nopipe = cur_archs.get("cloud_device_k4_unpipelined")
    if pipe is not None and nopipe is not None and \
            pipe.get("host_blocked_s") is not None and \
            pipe["host_blocked_s"] >= nopipe.get("host_blocked_s", 0.0):
        warnings.append(
            f"cloud_device_k4: pipelined host_blocked_s "
            f"{pipe['host_blocked_s']} not below unpipelined "
            f"{nopipe.get('host_blocked_s')} (warn-only)")
    return failures, warnings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_sweep.json")
    ap.add_argument("--current", required=True,
                    help="freshly generated sweep record")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)
    failures, warnings = compare(baseline, current)
    warnings += stale_policy_warnings(current)
    for w in warnings:
        print(f"WARN: {w}")
    for x in failures:
        print(f"FAIL: {x}")
    if failures:
        return 1
    print(f"OK: {len(baseline.get('archs', []))} baseline archs compared, "
          f"no compile/dispatch regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
