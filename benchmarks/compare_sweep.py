"""Compare a freshly generated ``BENCH_sweep.json`` against the committed
baseline run (``benchmarks/BENCH_sweep.baseline.json``, regenerated with
``benchmarks.run --quick --only sweep_json`` whenever a PR intentionally
moves the counts) — the CI perf-regression gate.

    PYTHONPATH=src python -m benchmarks.compare_sweep \
        --baseline benchmarks/BENCH_sweep.baseline.json \
        --current BENCH_sweep.new.json

Hard failures (exit 1): a per-arch XLA compile-count increase or a
dispatches-per-round increase, compared arch-by-arch over the archs
present in BOTH files (a newly added arch has no baseline and is
reported, not failed).  Timing is warn-only — CI machines are too noisy
to gate on seconds.  When the two runs used different budgets the counts
are not comparable either, so everything downgrades to warnings.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple

#: warn when an arch's wall-clock grows beyond this factor
TIME_WARN_RATIO = 1.5


def compare(baseline: dict, current: dict) -> Tuple[List[str], List[str]]:
    """(failures, warnings) between two bench_sweep_json records."""
    failures: List[str] = []
    warnings: List[str] = []
    comparable = baseline.get("budget") == current.get("budget")
    if not comparable:
        warnings.append(
            f"budgets differ (baseline {baseline.get('budget')} vs "
            f"current {current.get('budget')}): compile/dispatch counts "
            f"are not comparable, downgrading all checks to warnings")
    base_archs: Dict[str, dict] = {a["arch"]: a
                                   for a in baseline.get("archs", [])}
    cur_archs: Dict[str, dict] = {a["arch"]: a
                                  for a in current.get("archs", [])}
    for name in cur_archs:
        if name not in base_archs:
            warnings.append(f"{name}: new arch, no baseline to compare")
    for name, base in base_archs.items():
        sink = failures if comparable else warnings
        cur = cur_archs.get(name)
        if cur is None:
            sink.append(f"{name}: arch disappeared from the sweep")
            continue
        if cur["compiles"] > base["compiles"]:
            sink.append(
                f"{name}: compiles regressed "
                f"{base['compiles']} -> {cur['compiles']}")
        if cur["dispatches_per_round"] > base["dispatches_per_round"]:
            sink.append(
                f"{name}: dispatches/round regressed "
                f"{base['dispatches_per_round']} -> "
                f"{cur['dispatches_per_round']}")
        if base.get("seconds") and cur.get("seconds", 0.0) > \
                TIME_WARN_RATIO * base["seconds"]:
            warnings.append(
                f"{name}: {cur['seconds']:.2f}s vs baseline "
                f"{base['seconds']:.2f}s (> {TIME_WARN_RATIO}x, "
                f"warn-only)")
    return failures, warnings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_sweep.json")
    ap.add_argument("--current", required=True,
                    help="freshly generated sweep record")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)
    failures, warnings = compare(baseline, current)
    for w in warnings:
        print(f"WARN: {w}")
    for x in failures:
        print(f"FAIL: {x}")
    if failures:
        return 1
    print(f"OK: {len(baseline.get('archs', []))} baseline archs compared, "
          f"no compile/dispatch regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
