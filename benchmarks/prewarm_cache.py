"""Pre-warm the JAX persistent compilation cache shared by the test
suite (tests/conftest.py points both the in-process tests and the slow
tier's subprocess fixture at ``.pytest_cache/jax_persistent_cache``).

CI restores that directory via ``actions/cache`` (keyed on JAX version +
kernel-source hash) and runs this script on a cache miss, so the first
test run of a fresh key already loads compiled executables from disk
instead of paying cold XLA compiles:

    PYTHONPATH=src python -m benchmarks.prewarm_cache [cache_dir]

Compiles the batch-evaluator kernels the suite leans on hardest: the
default paper topology plus every registered arch, on the common
(ndims=3, bucket=16) signature, both uniform and structured density
modes, broadcast and stacked variants, at the canonical padded batch
shapes.  Best-effort everywhere: backends without persistent-cache
support simply compile and discard.
"""
from __future__ import annotations

import os
import sys

_DEFAULT_DIR = os.path.join(".pytest_cache", "jax_persistent_cache")


def main(cache_dir: str = _DEFAULT_DIR) -> None:
    os.makedirs(cache_dir, exist_ok=True)
    # must land in the environment before jax initializes
    os.environ.setdefault("JAX_COMPILATION_CACHE_DIR", cache_dir)
    os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS",
                          "0")
    os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_ENTRY_SIZE_BYTES",
                          "0")

    import numpy as np

    from repro.configs.paper_workloads import (banded_attention_workloads,
                                               by_name)
    from repro.core import jax_cost, search
    from repro.core.arch import registered_archs

    rng = np.random.default_rng(0)
    wls = [by_name("mm1"), by_name("mm3")]
    archs = ["cloud"] + sorted(registered_archs())
    for arch in archs:
        for wl in wls:
            spec, ev = search.get_evaluator(wl, arch, n_pad=16)
            ev(spec.random_genomes(rng, 64))
        specs_evs = [search.get_evaluator(wl, arch, n_pad=16)
                     for wl in wls]
        jax_cost.eval_stacked(
            [ev for _, ev in specs_evs],
            [spec.random_genomes(rng, 64) for spec, _ in specs_evs])
    # structured-density kernels (the mixed fleet of the sweep guard)
    swls = [by_name("mm1"), banded_attention_workloads()[0]]
    models, batches = [], []
    for wl in swls:
        spec, ev = search.get_evaluator(wl, "cloud", n_pad=32,
                                        structured=True)
        g = spec.random_genomes(rng, 64)
        ev(g)
        models.append(ev)
        batches.append(g)
    jax_cost.eval_stacked(models, batches)
    print(f"prewarmed {jax_cost.compilation_count()} compilations into "
          f"{cache_dir}")


if __name__ == "__main__":
    main(*sys.argv[1:2])
