"""Roofline table from the dry-run JSONL (EXPERIMENTS.md §Roofline).

Reads bench_out/dryrun.jsonl (written by repro.launch.sweep / dryrun) and
emits the per-(arch x shape x mesh) three-term roofline with the dominant
bottleneck, MODEL_FLOPS/HLO ratio, and a markdown table.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

OUT_DIR = os.environ.get("REPRO_BENCH_OUT", "bench_out")


def load(path: Optional[str] = None) -> List[Dict]:
    path = path or os.path.join(OUT_DIR, "dryrun.jsonl")
    recs = {}
    if not os.path.exists(path):
        return []
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            key = (r.get("arch"), r.get("shape"), r.get("mesh"))
            # last record wins (reruns after fixes)
            if r.get("status") in ("ok", "skipped") or key not in recs:
                recs[key] = r
    return list(recs.values())


def table(recs: List[Dict], mesh: str = "16x16") -> List[Dict]:
    rows = []
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"])):
        if r.get("mesh") != mesh:
            continue
        if r.get("status") == "skipped":
            rows.append(dict(arch=r["arch"], shape=r["shape"],
                             status="skipped", reason=r.get("reason", "")))
            continue
        if r.get("status") != "ok":
            rows.append(dict(arch=r["arch"], shape=r["shape"],
                             status="error", reason=r.get("error", "")))
            continue
        terms = dict(compute=r["t_compute_s"], memory=r["t_memory_s"],
                     collective=r["t_collective_s"])
        dom = max(terms, key=terms.get)
        t_bound = max(terms.values())
        frac = terms["compute"] / t_bound if t_bound > 0 else 0.0
        rows.append(dict(
            arch=r["arch"], shape=r["shape"], status="ok",
            t_compute_s=r["t_compute_s"], t_memory_s=r["t_memory_s"],
            t_collective_s=r["t_collective_s"], bottleneck=dom,
            roofline_fraction=frac,
            useful_flops_ratio=r.get("useful_flops_ratio", 0.0),
            state_gb=r.get("state_bytes_per_device", 0) / 1e9,
            compile_s=r.get("compile_s", 0)))
    return rows


def markdown(rows: List[Dict]) -> str:
    out = ["| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | "
           "bottleneck | comp/roof | 6ND/HLO | state GB/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"{r['status']}: {r.get('reason','')[:60]} | | | |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3g} | "
            f"{r['t_memory_s']:.3g} | {r['t_collective_s']:.3g} | "
            f"**{r['bottleneck']}** | {r['roofline_fraction']:.2f} | "
            f"{r['useful_flops_ratio']:.2f} | {r['state_gb']:.1f} |")
    return "\n".join(out)


def main():
    recs = load()
    ok = [r for r in recs if r.get("status") == "ok"]
    sk = [r for r in recs if r.get("status") == "skipped"]
    print(f"dryrun records: {len(recs)} ({len(ok)} ok, {len(sk)} skipped)")
    for mesh in ("16x16", "2x16x16"):
        rows = table(recs, mesh)
        name = f"roofline_{mesh.replace('x','_')}.md"
        path = os.path.join(OUT_DIR, name)
        with open(path, "w") as f:
            f.write(markdown(rows) + "\n")
        print(f"wrote {path} ({len(rows)} rows)")
    return recs


if __name__ == "__main__":
    main()
