"""Benchmark harness: one benchmark per paper table/figure + the roofline
table from the dry-run.  Prints ``name,seconds,derived`` CSV lines.

    PYTHONPATH=src python -m benchmarks.run [--budget N] [--quick] [--full]
    PYTHONPATH=src python -m benchmarks.run --only fig18

``--only sweep_json`` (also run by default) additionally writes the
machine-readable ``BENCH_sweep.json`` perf-trajectory record — XLA
compilations, dispatches/round, per-topology pad-watermark
trajectories, and best-EDP per method x workload x arch — which CI
uploads as an artifact AND gates against the committed
``benchmarks/BENCH_sweep.baseline.json`` (compile-count or
dispatches-per-round regressions fail the build; see
``benchmarks.compare_sweep``).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

SWEEP_JSON = os.environ.get("REPRO_BENCH_SWEEP_JSON", "BENCH_sweep.json")


def bench_sweep_json(budget: int, out_path: str = SWEEP_JSON) -> dict:
    """One stacked ``run_method_sweep`` fleet per registered arch, plus
    one structured-density fleet (the 2:4 sparseGPT BlockNM family + a
    banded-attention workload + a uniform control as ONE mega-batched
    signature — density families/params are traced, so the compile count
    must stay flat across the family); the per-cell best-EDPs plus
    fleet-level compile/dispatch counts land in ``out_path`` as JSON."""
    from repro.configs.paper_workloads import (banded_attention_workloads,
                                               by_name)
    from repro.core import jax_cost, search

    methods = ["sparsemap", "random_mapper", "pso"]
    wls = [by_name(n) for n in ("mm1", "mm3")]
    archs = ["cloud", "maple_edge", "cluster_cloud", "systolic_mesh",
             "quant_edge", "eyeriss_like", "sigma_like", "dstc_like"]
    record = dict(budget=budget, methods=methods,
                  workloads=[w.name for w in wls], archs=[], cells=[])

    def run_fleet(entry_name, fleet_methods, fleet_wls, arch,
                  fleet_budget=None, **fleet_kw):
        search.clear_cache()
        stats: dict = {}
        t0 = time.time()
        config = search.FleetConfig(stack_batches=True, **fleet_kw)
        grid = search.run_method_sweep(fleet_methods, fleet_wls, arch,
                                       budget=fleet_budget or budget,
                                       seed=0, stats_out=stats,
                                       config=config)
        seconds = round(time.time() - t0, 2)
        arec = dict(
            arch=entry_name, seconds=seconds,
            budget=fleet_budget or budget,
            compiles=jax_cost.compilation_count(),
            rounds=stats["rounds"], dispatches=stats["dispatches"],
            dispatches_per_round=round(
                stats["dispatches"] / max(stats["rounds"], 1), 3),
            seconds_per_round=round(
                seconds / max(stats["rounds"], 1), 4),
            # host round-trips per search generation: 1.0 for per-round
            # fleets, ~1/k in the segment phase of device_rounds=k fleets
            host_syncs=stats["host_syncs"],
            host_syncs_per_round=round(stats["host_syncs_per_round"], 3),
            device_rounds=stats["device_rounds"],
            device_rounds_source=stats["device_rounds_source"],
            # pipelining record: wall-clock the host spent blocked in
            # device->numpy conversions, and the AOT compile-ahead
            # coverage of the fleet's round-1 dispatch signatures
            # (misses are gated by compare_sweep; timing is warn-only)
            host_blocked_s=round(stats["host_blocked_s"], 4),
            compile_ahead_hits=stats["compile_ahead_hits"],
            compile_ahead_misses=stats["compile_ahead_misses"],
            pipeline=stats["pipeline"],
            n_devices=stats["devices"],
            signatures=[list(s) for s in stats["signatures"]],
            # per-topology mega-batch watermark trajectory + the
            # grow/decay policy that produced it (PadPolicy, per
            # Topology.fingerprint) — the cross-PR record for tuning the
            # retrace-vs-padded-compute trade-off per topology
            pad_watermarks=stats.get("pad_watermarks", {}),
            pad_policies=stats.get("pad_policies", {}))
        record["archs"].append(arec)
        for m in fleet_methods:
            for w in fleet_wls:
                r = grid[m][w.name]
                record["cells"].append(dict(
                    arch=entry_name, method=m, workload=w.name,
                    best_edp=(float(r.best_edp)
                              if np.isfinite(r.best_edp) else None),
                    evals=int(r.evals), valid_evals=int(r.valid_evals)))

    for arch in archs:
        run_fleet(arch, methods, wls, arch)

    # structured-density mixed fleet on the paper arch: BlockNM(2,4)
    # family (mm8-mm10) + banded attention + uniform mm1 — density-mode
    # alignment promotes the whole group onto the structured kernel, so
    # the gate holds it at ONE signature (1.0 dispatches/round)
    struct_wls = ([by_name(n) for n in ("mm1", "mm8", "mm9", "mm10")] +
                  banded_attention_workloads()[:1])
    run_fleet("structured_cloud", ["sparsemap", "random_mapper"],
              struct_wls, "cloud")

    # device-resident fleet on the paper arch: the same ES searches fold
    # k=4 generations per device program (host_syncs_per_round tracks the
    # segment-phase sync ratio, gated at <= 1/k + prologue tolerance by
    # compare_sweep); sharded across every visible device when the host
    # exposes more than one (n_devices records it)
    from repro.launch.mesh import make_search_mesh
    # floor the budget so the run gets past the host-driven
    # calibration/HSHI prologue and into the segment phase (where
    # host_syncs_per_round is measured) even under --quick
    run_fleet("cloud_device_k4", ["sparsemap"], wls, "cloud",
              fleet_budget=max(budget, 2000),
              device_rounds=4, mesh=make_search_mesh())

    # the same fleet with the pipelined driver and compile-ahead both
    # disabled: the acceptance comparison for the pipelining PR —
    # cloud_device_k4's host_blocked_s must stay strictly below this
    # entry's, and its compile_ahead_misses must stay at the committed
    # baseline (0 = every round-1 signature predicted)
    run_fleet("cloud_device_k4_unpipelined", ["sparsemap"], wls, "cloud",
              fleet_budget=max(budget, 2000),
              device_rounds=4, mesh=make_search_mesh(),
              pipeline=False, compile_ahead=False)

    # search-as-a-service coalescing: one in-process sweep server serves
    # a single-client epoch, then TWO concurrent same-signature clients.
    # The pair epoch must hold 1.0 dispatches/round (both queries ride
    # one mega-batch), and its compile DELTA over the warm single-client
    # server is gated by compare_sweep like any arch entry (the honest
    # count: the pair's bigger stacked shape may cost one compile the
    # single-client fleet never needed; growing past the committed
    # baseline fails CI)
    import threading

    from repro.core import jax_cost as _jc
    from repro.launch import sweep_serve

    search.clear_cache()
    serve_budget = min(budget, 600)
    srv = sweep_serve.SweepServer(
        port=0, config=search.FleetConfig(stack_batches=True,
                                          device_rounds=1))
    srv.start_background()
    t0 = time.time()

    def serve_task(name, seed):
        return search.SearchTask(wls[0], "cloud", budget=serve_budget,
                                 seed=seed, name=name)

    try:
        list(sweep_serve.submit(srv.host, srv.port,
                                serve_task("serve_single", 0)))
        compiles_single = _jc.compilation_count()
        clients = [threading.Thread(
            target=lambda nm, sd: list(sweep_serve.submit(
                srv.host, srv.port, serve_task(nm, sd))),
            args=(f"serve_pair_{i}", i + 1)) for i in range(2)]
        for th in clients:
            th.start()
        for th in clients:
            th.join(timeout=600)
        st = next(iter(sweep_serve.request(srv.host, srv.port,
                                           {"op": "stats"})))["stats"]
    finally:
        srv.stop()
    fleet = st["fleet"]
    record["archs"].append(dict(
        arch="serve_coalesce", seconds=round(time.time() - t0, 2),
        budget=serve_budget,
        # compile DELTA of the concurrent-pair epoch over the warm
        # single-client server (0 = the pair rode existing programs)
        compiles=_jc.compilation_count() - compiles_single,
        rounds=fleet["rounds"], dispatches=fleet["dispatches"],
        dispatches_per_round=round(
            fleet["dispatches"] / max(fleet["rounds"], 1), 3),
        host_syncs_per_round=round(fleet["host_syncs_per_round"], 3),
        # largest same-signature group any epoch held (2 = the pair
        # provably coalesced; recorded, not gated — admission timing
        # can split the pair across epochs on a loaded machine)
        coalesced_group_size=max(
            (max(g.values()) for g in st["epoch_signature_groups"] if g),
            default=0),
        queries=st["queries"], completed=st["completed"],
        warm_started=st["warm_started"],
        pad_watermarks=fleet.get("pad_watermarks", {}),
        pad_policies=fleet.get("pad_policies", {})))

    # contract-analysis provenance: lint wall-time + per-rule violation
    # counts, and the canonical jaxpr hash of every registered kernel
    # family (compare_sweep hard-fails recorded violations and surfaces
    # hash drift warn-only — an intentional kernel change moves hashes,
    # silent drift in an unrelated PR deserves a review look)
    from repro.analysis import run_report
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    roots = [p for p in (os.path.join(root, d)
                         for d in ("src", "benchmarks", "examples"))
             if os.path.isdir(p)]
    rep = run_report(roots=roots, include_jaxpr=True, include_scan=False)
    record["analysis"] = dict(
        lint_seconds=rep["lint"]["seconds"],
        jaxpr_seconds=rep["jaxpr"]["seconds"],
        rule_counts=rep["lint"]["rule_counts"],
        violations=(len(rep["lint"]["violations"])
                    + len(rep["jaxpr"]["findings"])))
    record["jaxpr_hashes"] = rep["jaxpr"]["hashes"]

    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=int, default=None)
    ap.add_argument("--quick", action="store_true",
                    help="minimal budgets (CI)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale budgets (20k evals/workload)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig2,fig7,fig17,fig18,"
                         "table_iv,roofline,arch_dse,es_ops,stacked_prep,"
                         "multisearch,method_sweep,device_rounds,"
                         "sweep_json")
    args = ap.parse_args(argv)

    budget = args.budget or (300 if args.quick else
                             20000 if args.full else 10000)
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import paper_tables, roofline

    def want(name):
        return only is None or name in only

    print("name,seconds,derived")

    if want("es_ops"):
        from benchmarks import es_ops
        t0 = time.time()
        ops = es_ops.bench_operators(pop_size=100)
        print(f"es_ops,{time.time()-t0:.1f},"
              f"mutate_speedup={ops['mutate_speedup']:.1f}x;"
              f"crossover_speedup={ops['crossover_speedup']:.1f}x;"
              f"combined_speedup={ops['speedup']:.1f}x")

    if want("device_rounds"):
        from benchmarks import es_ops
        t0 = time.time()
        dr = es_ops.bench_device_rounds(
            budget=min(max(budget, 1200), 2000))
        print(f"device_rounds,{time.time()-t0:.1f},"
              f"k={dr['device_rounds']};"
              f"fused_vs_host_speedup={dr['speedup']:.2f}x;"
              f"syncs_per_round={dr['fused_syncs_per_round']:.3f}"
              f"_vs_{dr['host_syncs_per_round']:.3f};"
              f"edp_exact={dr['edp_exact']}")

    if want("stacked_prep"):
        from benchmarks import es_ops
        t0 = time.time()
        sp = es_ops.bench_stacked_prep()
        print(f"stacked_prep,{time.time()-t0:.1f},"
              f"prep_speedup={sp['prep_speedup']:.1f}x;"
              f"round_ms={sp['eval_round_seconds']*1e3:.2f}")

    if want("sweep_json"):
        t0 = time.time()
        rec = bench_sweep_json(budget=min(budget, 1000))
        dpr = ";".join(f"{a['arch']}={a['dispatches_per_round']}"
                       for a in rec["archs"])
        print(f"sweep_json,{time.time()-t0:.1f},"
              f"path={SWEEP_JSON};dispatches_per_round={dpr}")

    if want("multisearch"):
        from benchmarks import es_ops
        t0 = time.time()
        ms = es_ops.bench_multisearch(budget=min(budget, 2000))
        print(f"multisearch,{time.time()-t0:.1f},"
              f"compiles={ms['multi_compiles']}_vs_seq_"
              f"{ms['seq_compiles']};edp_match={ms['edp_match']}")

    if want("method_sweep"):
        from benchmarks import es_ops
        t0 = time.time()
        sw = es_ops.bench_method_sweep(budget=min(budget, 2000))
        print(f"method_sweep,{time.time()-t0:.1f},"
              f"compiles={sw['sweep_compiles']}_vs_seq_"
              f"{sw['seq_compiles']};"
              f"dispatches_per_round={sw['dispatches_per_round']:.1f}"
              f"_vs_seq_{sw['seq_dispatches_per_round']:.1f};"
              f"edp_exact={sw['edp_exact']}")

    if want("fig2"):
        t0 = time.time()
        rows = paper_tables.fig2_interaction()
        # derived: does the best (mapping,fmt) change across densities?
        best = {}
        for r in rows:
            if not r["valid"]:
                continue
            key = r["density"]
            if key not in best or r["edp"] < best[key][1]:
                best[key] = ((r["mapping"], r["fmt"]), r["edp"])
        winners = {v[0] for v in best.values()}
        print(f"fig2_interaction,{time.time()-t0:.1f},"
              f"distinct_winners={len(winners)}")

    if want("fig7"):
        t0 = time.time()
        info = paper_tables.fig7_space(n_samples=1000)
        print(f"fig7_space,{time.time()-t0:.1f},"
              f"valid_frac={info['valid_frac']:.4f}")

    if want("fig17"):
        t0 = time.time()
        wl_names = ("conv2", "conv4") if args.quick else \
            ("conv2", "conv4", "conv5", "conv7")
        out = paper_tables.fig17_baselines(budget=budget,
                                           workload_names=wl_names)
        ours = {r["workload"]: r["edp"] for r in out
                if r["method"] == "sparsemap"}
        ratios = []
        for w, o in ours.items():
            b = min(r["edp"] for r in out
                    if r["workload"] == w and r["method"] != "sparsemap")
            if np.isfinite(b) and np.isfinite(o) and o > 0:
                ratios.append(b / o)
        gm = float(np.exp(np.mean(np.log(ratios)))) if ratios else 0.0
        print(f"fig17_baselines,{time.time()-t0:.1f},"
              f"geomean_best_baseline_over_ours={gm:.2f}x")

    if want("fig18"):
        t0 = time.time()
        out = paper_tables.fig18_ablation(budget=max(budget, 2000))
        summary = {(r['workload'], r['method']): r['best_edp']
                   for r in out}
        ok = all(
            summary[(w, 'sparsemap')] <= summary[(w, 'pfce_es')] * 1.5
            for w in ('mm3', 'conv4'))
        print(f"fig18_ablation,{time.time()-t0:.1f},ordering_holds={ok}")

    if want("table_iv"):
        t0 = time.time()
        wl_names = None
        if args.quick:
            wl_names = ["mm1", "mm3", "conv2", "conv4"]
        out = paper_tables.table_iv(budget=budget,
                                    workload_names=wl_names)
        sp = [r["speedup_vs_sparseloop"] for r in out
              if np.isfinite(r.get("speedup_vs_sparseloop", np.nan))]
        sg = [r["speedup_vs_sage"] for r in out
              if np.isfinite(r.get("speedup_vs_sage", np.nan))]
        gm_sp = float(np.exp(np.mean(np.log(np.maximum(sp, 1e-9))))) \
            if sp else 0.0
        gm_sg = float(np.exp(np.mean(np.log(np.maximum(sg, 1e-9))))) \
            if sg else 0.0
        print(f"table_iv,{time.time()-t0:.1f},"
              f"geomean_edp_reduction_vs_sparseloop={gm_sp:.2f}x;"
              f"vs_sage={gm_sg:.2f}x")

    if want("arch_dse"):
        t0 = time.time()
        from repro.configs.paper_workloads import arch_gemms
        from repro.core import search as search_lib
        rows = []
        for arch in ("mistral-nemo-12b", "kimi-k2-1t-a32b"):
            for wl in arch_gemms(arch)[:2]:
                res = search_lib.run("sparsemap", wl, "cloud",
                                     budget=budget, seed=0)
                rows.append((wl.name, res.best_edp))
        print(f"arch_dse,{time.time()-t0:.1f},"
              f"searched={len(rows)}_arch_gemms")

    if want("roofline"):
        t0 = time.time()
        recs = roofline.main()
        ok = sum(1 for r in recs if r.get("status") == "ok")
        print(f"roofline,{time.time()-t0:.1f},cells_ok={ok}")


if __name__ == "__main__":
    main()
