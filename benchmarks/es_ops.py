"""ES operator micro-benchmark + MultiSearch compilation-sharing checks.

Benchmarks backing the vectorized/concurrent-engine claims:

* ``bench_operators`` — throughput (individuals/s) of the vectorized
  ``mutate`` + ``crossover`` (and HSHI round sampling / best-so-far
  tracking) vs the seed per-individual Python loops, at the paper's
  pop_size=100 on a paper workload genome.
* ``bench_multisearch`` — a 2-workload sweep through ``MultiSearch``
  must perform FEWER XLA compilations than sequential ``search.run``
  calls (signature alignment) while matching their best-EDP results.
* ``bench_method_sweep`` — a 2-workload x 3-method fig17-style grid via
  ``run_method_sweep(stack_batches=True)`` must perform strictly fewer
  XLA compilations AND fewer device dispatches per round (one padded
  mega-batch per signature) than the sequential equivalent, while
  matching sequential per-method best-EDP exactly at fixed seeds.
* ``bench_stacked_prep`` — dispatch-prep time of the mega-batch path:
  the per-(fleet, signature)-epoch constants cache vs rebuilding the
  tiled per-row constants (broadcast_to + concat) every round.

    PYTHONPATH=src python -m benchmarks.es_ops
    PYTHONPATH=src python -m benchmarks.run --only es_ops,multisearch,method_sweep
"""
from __future__ import annotations

import time
from typing import Dict

import numpy as np


# ---------------------------------------------------- seed reference ops


def _ref_mutate(genomes, spec, rng, p_mut, genes_per, sens, p_high):
    out = genomes.copy()
    L = spec.length
    for i in range(len(out)):
        if rng.random() >= p_mut:
            continue
        if sens is not None:
            seg = sens.high_indices if rng.random() < p_high \
                else sens.low_indices
            if len(seg) == 0:
                seg = np.arange(L)
        else:
            seg = np.arange(L)
        for _ in range(genes_per):
            g = int(seg[rng.integers(0, len(seg))])
            out[i, g] = rng.integers(0, spec.gene_ub[g])
    return out


def _ref_crossover(parents, n_children, spec, rng, sens):
    L = spec.length
    if sens is not None:
        pts = {0, L}
        for a, b in sens.high_segments():
            pts.add(a)
            pts.add(b)
        cut_points = sorted(pts - {0, L}) or [L // 2]
    else:
        cut_points = list(range(1, L))
    kids = np.empty((n_children, L), dtype=parents.dtype)
    for i in range(n_children):
        a, b = rng.integers(0, len(parents), 2)
        cut = cut_points[rng.integers(0, len(cut_points))]
        kids[i, :cut] = parents[a, :cut]
        kids[i, cut:] = parents[b, cut:]
    return kids


def _time(fn, min_seconds: float = 0.4) -> float:
    """Calls/second of fn()."""
    fn()                                    # warmup
    n = 0
    t0 = time.perf_counter()
    while True:
        fn()
        n += 1
        dt = time.perf_counter() - t0
        if dt >= min_seconds:
            return n / dt


def bench_operators(pop_size: int = 100, workload_name: str = "mm3"
                    ) -> Dict[str, float]:
    from repro.configs.paper_workloads import by_name
    from repro.core.encoding import GenomeSpec
    from repro.core.evolution import crossover, mutate
    from repro.core.sensitivity import SensitivityResult

    wl = by_name(workload_name)
    spec = GenomeSpec(wl)
    high = np.zeros(spec.length, dtype=bool)
    high[spec.segments["perm"].slice] = True
    high[spec.segments["sg"].slice] = True
    sens = SensitivityResult(
        scores=high.astype(np.float64), high_mask=high,
        valid_pool=spec.random_genomes(np.random.default_rng(0), 64),
        threshold=0.75, evals_used=0)

    rng = np.random.default_rng(1)
    pop = spec.random_genomes(rng, pop_size)
    parents = pop[:40]

    def vec_pair():
        kids = crossover(parents, pop_size, spec, rng, sens)
        mutate(kids, spec, rng, 0.9, 2, sens, 0.5)

    def ref_pair():
        kids = _ref_crossover(parents, pop_size, spec, rng, sens)
        _ref_mutate(kids, spec, rng, 0.9, 2, sens, 0.5)

    vec_cps = _time(vec_pair)
    ref_cps = _time(ref_pair)
    out = dict(
        workload=workload_name, pop_size=pop_size, genome_len=spec.length,
        vectorized_pairs_per_s=vec_cps * pop_size,
        reference_pairs_per_s=ref_cps * pop_size,
        speedup=vec_cps / ref_cps)

    # individual operators, for the breakdown
    out["mutate_speedup"] = (
        _time(lambda: mutate(pop, spec, rng, 0.9, 2, sens, 0.5)) /
        _time(lambda: _ref_mutate(pop, spec, rng, 0.9, 2, sens, 0.5)))
    out["crossover_speedup"] = (
        _time(lambda: crossover(parents, pop_size, spec, rng, sens)) /
        _time(lambda: _ref_crossover(parents, pop_size, spec, rng, sens)))
    return out


def bench_stacked_prep(n_tasks: int = 6, rows_per_task: int = 64,
                       rounds: int = 50) -> Dict[str, float]:
    """Dispatch-prep micro-benchmark for the mega-batch path: time per
    ``eval_stacked`` prep with the per-(fleet, signature)-epoch constants
    cache vs rebuilding the tiled constants every round (the pre-cache
    behavior: np.broadcast_to + concat per model per round)."""
    from repro.configs.paper_workloads import by_name
    from repro.core import jax_cost, search
    from repro.core.jax_cost import _pad_batch, _stacked_consts

    wls = [by_name(n) for n in ("mm1", "mm3")]
    models, batches = [], []
    rng = np.random.default_rng(0)
    for i in range(n_tasks):
        spec, ev = search.get_evaluator(wls[i % len(wls)], "cloud")
        models.append(ev)
        batches.append(spec.random_genomes(rng, rows_per_task))
    sizes = [len(b) for b in batches]
    padded = _pad_batch(sum(sizes))

    def cached():
        return _stacked_consts(models, sizes, padded)

    def uncached():
        jax_cost._STACK_CONSTS.clear()
        return _stacked_consts(models, sizes, padded)

    cached()                                    # warm the epoch entry
    cached_cps = _time(cached)
    uncached_cps = _time(uncached)

    # end-to-end: full eval_stacked rounds on a steady fleet
    t0 = time.perf_counter()
    for _ in range(rounds):
        jax_cost.eval_stacked(models, batches)
    per_round_s = (time.perf_counter() - t0) / rounds
    hits, misses = jax_cost.stack_prep_counts()
    return dict(
        n_tasks=n_tasks, rows_per_task=rows_per_task,
        cached_preps_per_s=cached_cps, uncached_preps_per_s=uncached_cps,
        prep_speedup=cached_cps / uncached_cps,
        eval_round_seconds=per_round_s,
        prep_hits=hits, prep_misses=misses)


def bench_device_rounds(budget: int = 2000, seed: int = 0,
                        device_rounds: int = 8) -> Dict[str, float]:
    """Fused-round vs host-loop throughput: the SAME pre-drawn operator
    plans executed as one vmap-of-``lax.scan`` device program every k
    generations (``device_execute=True``) vs replayed per-round on the
    host (``device_execute=False``, one dispatch + one sync per
    generation).  Results are bit-identical by construction, so the
    deltas are pure host-sync/dispatch overhead."""
    from repro.configs.paper_workloads import by_name
    from repro.core import jax_cost, search

    wls = [by_name("mm1"), by_name("mm3")]

    def fleet(execute: bool):
        search.clear_cache()
        stats: Dict = {}
        t0 = time.perf_counter()
        grid = search.run_method_sweep(
            ["sparsemap"], wls, "cloud", budget=budget, seed=seed,
            stack_batches=True, device_rounds=device_rounds,
            device_execute=execute, stats_out=stats)
        dt = time.perf_counter() - t0
        best = {w.name: grid["sparsemap"][w.name].best_edp for w in wls}
        return dt, stats, best

    fused_s, fused_stats, fused_best = fleet(True)
    host_s, host_stats, host_best = fleet(False)
    return dict(
        budget=budget, device_rounds=device_rounds,
        fused_seconds=fused_s, host_seconds=host_s,
        speedup=host_s / fused_s,
        fused_host_syncs=fused_stats["host_syncs"],
        host_host_syncs=host_stats["host_syncs"],
        fused_syncs_per_round=fused_stats["host_syncs_per_round"],
        host_syncs_per_round=host_stats["host_syncs_per_round"],
        fused_dispatches=fused_stats["dispatches"],
        host_dispatches=host_stats["dispatches"],
        edp_exact=all(fused_best[w] == host_best[w] for w in fused_best))


def bench_multisearch(budget: int = 1000, seed: int = 0
                      ) -> Dict[str, float]:
    from repro.configs.paper_workloads import by_name
    from repro.core import jax_cost, search

    # mm1 (prime bucket 16) and mm4 (bucket 32): two natural signatures
    wls = [by_name("mm1"), by_name("mm4")]

    search.clear_cache()
    t0 = time.perf_counter()
    seq = {w.name: search.run("sparsemap", w, "cloud", budget=budget,
                              seed=seed) for w in wls}
    seq_s = time.perf_counter() - t0
    seq_compiles = jax_cost.compilation_count()

    search.clear_cache()
    t0 = time.perf_counter()
    ms = search.MultiSearch(
        [search.SearchTask(w, "cloud", budget=budget, seed=seed)
         for w in wls])
    multi = ms.run()
    multi_s = time.perf_counter() - t0
    multi_compiles = jax_cost.compilation_count()

    match = all(
        (not np.isfinite(seq[w.name].best_edp)) or
        abs(np.log10(multi[f"{w.name}@cloud"].best_edp) -
            np.log10(seq[w.name].best_edp)) < 1e-3
        for w in wls)
    return dict(
        budget=budget, seq_compiles=seq_compiles,
        multi_compiles=multi_compiles, seq_seconds=seq_s,
        multi_seconds=multi_s, edp_match=match,
        signatures=ms.stats["signatures"],
        natural_signatures=ms.stats["natural_signatures"])


def bench_method_sweep(budget: int = 2000, seed: int = 0
                       ) -> Dict[str, float]:
    """Sequential fig17-style grid vs one stacked MultiSearch fleet:
    compilations, device dispatches, wall-clock, and exact result parity."""
    from repro.configs.paper_workloads import by_name
    from repro.core import jax_cost, search

    wls = [by_name("mm1"), by_name("mm3")]      # shared (3, 16) signature
    methods = ["sparsemap", "pso", "random_mapper"]

    search.clear_cache()
    t0 = time.perf_counter()
    seq = {m: {w.name: search.run(m, w, "cloud", budget=budget, seed=seed)
               for w in wls} for m in methods}
    seq_s = time.perf_counter() - t0
    seq_compiles = jax_cost.compilation_count()
    seq_dispatches = jax_cost.dispatch_count()

    search.clear_cache()
    stats: Dict = {}
    t0 = time.perf_counter()
    grid = search.run_method_sweep(methods, wls, "cloud", budget=budget,
                                   seed=seed, stack_batches=True,
                                   stats_out=stats)
    sweep_s = time.perf_counter() - t0
    sweep_compiles = jax_cost.compilation_count()

    exact = all(
        seq[m][w.name].best_edp == grid[m][w.name].best_edp and
        np.array_equal(seq[m][w.name].history, grid[m][w.name].history)
        for m in methods for w in wls)
    return dict(
        budget=budget, n_methods=len(methods), n_workloads=len(wls),
        seq_compiles=seq_compiles, sweep_compiles=sweep_compiles,
        seq_dispatches=seq_dispatches, sweep_dispatches=stats["dispatches"],
        rounds=stats["rounds"],
        dispatches_per_round=stats["dispatches"] / max(stats["rounds"], 1),
        seq_dispatches_per_round=seq_dispatches / max(stats["rounds"], 1),
        seq_seconds=seq_s, sweep_seconds=sweep_s, edp_exact=exact)


def main() -> None:
    ops = bench_operators()
    print(f"es_ops: pop={ops['pop_size']} L={ops['genome_len']} "
          f"({ops['workload']}) — mutate {ops['mutate_speedup']:.1f}x, "
          f"crossover {ops['crossover_speedup']:.1f}x, "
          f"mutate+crossover {ops['speedup']:.1f}x "
          f"({ops['vectorized_pairs_per_s']:.3g} vs "
          f"{ops['reference_pairs_per_s']:.3g} individuals/s)")
    sp = bench_stacked_prep()
    print(f"stacked_prep: {sp['n_tasks']} tasks x {sp['rows_per_task']} "
          f"rows — cached prep {sp['prep_speedup']:.1f}x faster "
          f"({sp['cached_preps_per_s']:.3g} vs "
          f"{sp['uncached_preps_per_s']:.3g} preps/s), steady round "
          f"{sp['eval_round_seconds'] * 1e3:.2f}ms, "
          f"hits/misses {sp['prep_hits']}/{sp['prep_misses']}")
    ms = bench_multisearch()
    print(f"multisearch: compiles {ms['multi_compiles']} vs sequential "
          f"{ms['seq_compiles']}, signatures {ms['signatures']} vs "
          f"{ms['natural_signatures']}, edp_match={ms['edp_match']}, "
          f"{ms['multi_seconds']:.1f}s vs {ms['seq_seconds']:.1f}s")
    dr = bench_device_rounds()
    print(f"device_rounds: k={dr['device_rounds']} — fused "
          f"{dr['fused_seconds']:.1f}s vs host-loop "
          f"{dr['host_seconds']:.1f}s ({dr['speedup']:.2f}x), syncs "
          f"{dr['fused_host_syncs']} vs {dr['host_host_syncs']} "
          f"({dr['fused_syncs_per_round']:.3f} vs "
          f"{dr['host_syncs_per_round']:.3f} per round), "
          f"edp_exact={dr['edp_exact']}")
    sw = bench_method_sweep()
    print(f"method_sweep: {sw['n_workloads']} workloads x "
          f"{sw['n_methods']} methods — compiles {sw['sweep_compiles']} vs "
          f"sequential {sw['seq_compiles']}, dispatches "
          f"{sw['sweep_dispatches']} vs {sw['seq_dispatches']} "
          f"({sw['dispatches_per_round']:.1f} vs "
          f"{sw['seq_dispatches_per_round']:.1f} per round), "
          f"edp_exact={sw['edp_exact']}, "
          f"{sw['sweep_seconds']:.1f}s vs {sw['seq_seconds']:.1f}s")


if __name__ == "__main__":
    main()
