from .archs import ARCHS, get_config, smoke_config
from .shapes import LONG_CONTEXT_ARCHS, SHAPES, all_cells, applicable
