"""SparseMap Table III workloads: mm1-mm15 (DeepBench + sparseGPT SpMM)
and conv1-conv13 (VGG16, 50% global pruning), plus structured-density
sets — the sparseGPT SpMMs (mm8-mm10) carry their real 2:4
block-pruning structure (``BlockNM(2, 4)``) rather than a uniform 50%
scalar, and ``banded_attention_workloads`` adds windowed-attention
score x value GEMMs with ``Banded`` operands — plus per-arch GEMM
extraction so the DSE can be run on this framework's own architectures.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.density import Banded, BlockNM, DensityModel
from repro.core.workload import Workload, spconv, spmm


def _k(x: float) -> int:
    return int(round(x * 1024))


# (name, M, K, N, density_P %, density_Q %) — operand1 = P, operand2 = Q
_MM = [
    ("mm1", 124, 124, 124, 78.5, 78.5),
    ("mm2", 171, _k(92), 171, 20.9, 20.9),
    ("mm3", 730, 730, 730, 11.8, 11.8),
    ("mm4", 7700, 2600, 7700, 5.0, 5.0),
    ("mm5", 9000, 9000, 9000, 4.1, 4.1),
    ("mm6", 2600, 2600, 2600, 1.1, 1.1),
    ("mm7", 1600, 4600, 1600, 0.3, 0.3),
    ("mm8", 2000, 12300, 128, 100.0, 50.0),
    ("mm9", 2000, 12300, 49200, 100.0, 50.0),
    ("mm10", 2000, 49200, 12300, 100.0, 50.0),
    ("mm11", 128, 1024, 128, 0.6, 0.6),
    ("mm12", 768, 64, 768, 5.9, 5.9),
    ("mm13", 12300, 24600, 12300, 1.0, 1.0),
    ("mm14", 256, 512, 2048, 32.8, 71.8),
    ("mm15", 1000, 16000, 16000, 60.0, 78.0),
]

# (name, C, H, W, Kout, R, S, density_input %, density_weight %)
_CONV = [
    ("conv1", 3, 32, 32, 64, 3, 3, 100.0, 54.6),
    ("conv2", 64, 32, 32, 256, 1, 1, 45.0, 25.2),
    ("conv3", 128, 16, 16, 512, 1, 1, 39.6, 36.6),
    ("conv4", 128, 16, 16, 128, 3, 3, 47.7, 64.7),
    ("conv5", 1024, 8, 8, 256, 1, 1, 40.2, 50.1),
    ("conv6", 256, 8, 8, 256, 3, 3, 43.0, 61.7),
    ("conv7", 512, 4, 4, 2048, 1, 1, 59.0, 11.8),
    ("conv8", 128, 64, 64, 512, 4, 4, 40.0, 30.0),
    ("conv9", 128, 64, 64, 64, 1, 1, 100.0, 20.0),
    ("conv10", 256, 64, 64, 512, 1, 1, 40.0, 25.0),
    ("conv11", 4, 32, 32, 64, 3, 3, 34.0, 14.6),
    ("conv12", 1024, 4, 4, 64, 1, 1, 79.0, 11.8),
    ("conv13", 256, 16, 16, 128, 1, 1, 90.2, 5.1),
]


# Structured-density overrides: the sparseGPT SpMMs (mm8-mm10) are 2:4
# block-pruned weight matrices (operand2 = Q), not uniform-random 50%.
# BlockNM(2, 4).density == 0.5, so the mean matches the Table III entry
# while the byte/intersection statistics carry the N:M structure.
_MM_STRUCTURED: Dict[str, Dict[str, DensityModel]] = {
    "mm8": {"Q": BlockNM(2, 4)},
    "mm9": {"Q": BlockNM(2, 4)},
    "mm10": {"Q": BlockNM(2, 4)},
}


def mm_workloads() -> List[Workload]:
    out = []
    for n, m, k, nn, dp, dq in _MM:
        over = _MM_STRUCTURED.get(n, {})
        out.append(spmm(n, m, k, nn,
                        over.get("P", dp / 100.0),
                        over.get("Q", dq / 100.0)))
    return out


def conv_workloads() -> List[Workload]:
    return [spconv(n, c, h, w, ko, r, s, di / 100.0, dw / 100.0)
            for n, c, h, w, ko, r, s, di, dw in _CONV]


# (name, tokens, d_head, band fraction, score density) — windowed/local
# attention score x value GEMMs: P = post-softmax scores S[M=tokens,
# K=tokens], banded with the attention window (nonzeros only inside the
# band, where dropout/thresholding leaves ~70% of entries), Q = the
# dense value matrix V[K=tokens, N=d_head].
_BANDED_ATTN = [
    ("battn1", 512, 64, 0.125, 0.0875),
    ("battn2", 1024, 64, 0.0625, 0.04375),
]


def banded_attention_workloads() -> List[Workload]:
    return [spmm(n, t, t, dh, Banded(d, band), 1.0)
            for n, t, dh, band, d in _BANDED_ATTN]


def structured_workloads() -> List[Workload]:
    """Every workload carrying a non-uniform density model: the 2:4
    sparseGPT family + the banded-attention set."""
    return [w for w in mm_workloads() if w.structured_density] + \
        banded_attention_workloads()


def all_workloads() -> List[Workload]:
    return mm_workloads() + conv_workloads()


def by_name(name: str) -> Workload:
    for wl in all_workloads() + banded_attention_workloads():
        if wl.name == name:
            return wl
    raise KeyError(name)


# ---------------------------------------------------------------- archs


def arch_gemms(arch_name: str, weight_density: float = 0.5,
               act_density: float = 0.6, tokens: int = 512
               ) -> List[Workload]:
    """Extract the dominant GEMMs of an assigned architecture as SpTA
    workloads (activations x pruned weights), so the paper's DSE runs on
    this framework's own models (DESIGN.md §4)."""
    from .archs import get_config
    c = get_config(arch_name)
    d, hd = c.d_model, c.hd
    out = [
        spmm(f"{arch_name}:qkv", tokens, d,
             (c.n_heads + 2 * c.n_kv_heads) * hd,
             act_density, weight_density),
        spmm(f"{arch_name}:attn_out", tokens, c.n_heads * hd, d,
             act_density, weight_density),
    ]
    ff = c.moe_d_ff if c.n_experts else c.d_ff
    if ff:
        out.append(spmm(f"{arch_name}:ffn_up", tokens, d, ff,
                        act_density, weight_density))
        out.append(spmm(f"{arch_name}:ffn_down", tokens, ff, d,
                        act_density, weight_density))
    return out
