"""Assigned architecture configs (exact hyperparameters from the
assignment table) + reduced smoke variants, plus the registered
ACCELERATOR topologies (repro.core.arch.ArchSpec) that extend the paper's
fixed DRAM/GLB/PE/MAC hierarchy.

Vocab sizes that do not divide the TP degree (16) are padded up to the
next multiple of 16 (noted per config) — embedding sharding needs even
shards; the pad rows are never addressed by the tokenizer stub.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.arch import ArchSpec, NoCSpec, StorageLevel, register_arch
from repro.models.config import BlockSpec, ModelConfig

# ----------------------------------------------------- accelerator archs
#
# Non-default searchable topologies.  Anything registered here resolves by
# name through the whole search stack, e.g.
#     search.run_method_sweep(methods, workloads, "maple_edge", ...)
# The numbers are 12nm-class pJ/byte figures in the spirit of Table II;
# the *structure* is what differs from the paper topology.

#: 2-store Maple-style edge chip: no per-PE buffer — a single shared GLB
#: feeds a 16x16 PE grid directly (each PE = 1 MAC + registers).  One
#: spatial mapping level, one store S/G site.  3 mapping levels total.
MAPLE_EDGE = register_arch(ArchSpec(
    name="maple_edge",
    levels=(
        StorageLevel("dram"),
        StorageLevel(
            "glb", capacity_bytes=256 * 1024,
            fill_energy=(("dram", (100.0,)),),
            sg_site="L2",
            # deliberately starved DRAM, matching Table II's edge
            # platform (16 MB/s): on-chip reuse dominates this design
            # point, which is the topology's story
            fill_bandwidth_bytes_per_cycle=16e6 / 1.0e9),
        StorageLevel(
            "reg",
            fill_energy=(("glb", (3.5, 0.3)), ("reg", (0.05,))),
            fanout=16 * 16),
    ),
    e_mac=0.8))

#: 4-store clustered cloud chip: a cluster buffer sits between the GLB
#: and the PE buffers (16 clusters x 64 PEs x 16 MACs).  Three spatial
#: mapping levels, three store S/G sites ("L2"/"L3"/"L4") — 7 mapping
#: levels and a 4-gene S/G segment.
CLUSTER_CLOUD = register_arch(ArchSpec(
    name="cluster_cloud",
    levels=(
        StorageLevel("dram"),
        StorageLevel(
            "glb", capacity_bytes=64 * 1024 * 1024,
            fill_energy=(("dram", (100.0,)),),
            sg_site="L2",
            fill_bandwidth_bytes_per_cycle=128e9 / 1.0e9),
        StorageLevel(
            "cbuf", capacity_bytes=1024 * 1024,
            fill_energy=(("glb", (15.0, 0.3)),),
            fanout=16, sg_site="L3"),
        StorageLevel(
            "pebuf", capacity_bytes=64 * 1024,
            fill_energy=(("cbuf", (1.8, 0.2)),),
            fanout=64, sg_site="L4"),
        StorageLevel(
            "reg",
            fill_energy=(("pebuf", (0.5,)), ("reg", (0.05,))),
            fanout=16),
    ),
    e_mac=0.8))

#: Systolic 16x16 mesh with reduction-tree output collection: operands
#: stream into the PE grid store-and-forward (mesh NoC, no multicast — an
#: irrelevant spatial loop costs one copy per PE), while partial outputs
#: collapse through an adder tree (reduction=True, one reduced result per
#: tile crosses the GLB edge).  Same S/G site count as the paper arch but
#: a distinct Topology (the NoC shape is structural).
SYSTOLIC_MESH = register_arch(ArchSpec(
    name="systolic_mesh",
    levels=(
        StorageLevel("dram"),
        StorageLevel(
            "glb", capacity_bytes=1024 * 1024,
            fill_energy=(("dram", (100.0,)),),
            sg_site="L2",
            fill_bandwidth_bytes_per_cycle=32e9 / 1.0e9),
        StorageLevel(
            "pebuf", capacity_bytes=1024,
            # per-hop mesh forwarding is pricier than the paper's
            # broadcast NoC hop — the reduction tree is the design's win
            fill_energy=(("glb", (6.0,)), ("mesh_hop", (0.6,))),
            fanout=16 * 16,
            noc=NoCSpec(multicast=False, reduction=True),
            sg_site="L3"),
        StorageLevel(
            "reg", fill_energy=(("pebuf", (0.6,)), ("reg", (0.05,))),
            fanout=4),
    ),
    e_mac=0.8))

#: Quantized 1-byte-word edge chip: the paper's exact 4-store topology
#: STRUCTURE, but every on-chip level stores 8-bit words (DRAM traffic,
#: occupancies and compression ratios all reprice; metadata bits do not
#: shrink with the datawidth, so compression pays off later than at
#: 16-bit).  Word widths are traced numbers: a family of quantized
#: variants shares one XLA compilation.
QUANT_EDGE = register_arch(ArchSpec(
    name="quant_edge",
    levels=(
        StorageLevel("dram"),
        StorageLevel(
            "glb", capacity_bytes=128 * 1024, word_bytes=1.0,
            fill_energy=(("dram", (100.0,)),),
            sg_site="L2",
            fill_bandwidth_bytes_per_cycle=16e6 / 1.0e9),
        StorageLevel(
            "pebuf", capacity_bytes=1024, word_bytes=1.0,
            fill_energy=(("glb", (3.0, 0.3)),),
            fanout=16 * 16, sg_site="L3"),
        StorageLevel(
            "reg", word_bytes=1.0,
            fill_energy=(("pebuf", (0.6,)), ("reg", (0.05,))),
            fanout=4),
    ),
    e_mac=0.4))    # 8-bit MACs are ~half the 16-bit energy

ACCEL_ARCHS: Dict[str, ArchSpec] = {
    a.name: a for a in (MAPLE_EDGE, CLUSTER_CLOUD, SYSTOLIC_MESH,
                        QUANT_EDGE)}

# ------------------------------------------- measured pad-watermark policies
#
# Per-round mega-batch pad-watermark trajectories from the committed
# benchmark baseline (benchmarks/BENCH_sweep.baseline.json, regenerated
# with ``python -m benchmarks.run --quick --only sweep_json``), keyed by
# arch name.  Every topology measured so far shows the same shape — a
# round-1 calibration/chunk spike that decays once and never re-grows —
# so ``search.derive_pad_policy`` tunes them all to the faster
# ``decay_rounds=2`` instead of the conservative CPU default.  When a
# regenerated baseline changes a trajectory, update the table; the
# ``benchmarks/compare_sweep.py`` staleness check warns when a fresh
# run's trajectory disagrees with the policy registered here.
_BASELINE_PAD_WATERMARKS: Dict[str, tuple] = {
    "cloud": (2048, 2048, 256, 256, 256, 256),
    "maple_edge": (2048, 2048, 256, 256, 256, 256),
    "cluster_cloud": (2048, 2048, 256, 256, 256, 256),
    "systolic_mesh": (2048, 2048, 256, 256, 256, 256),
    "quant_edge": (2048, 2048, 256, 256, 256, 256),
}


def register_measured_pad_policies() -> None:
    """Derive and register a tuned :class:`~repro.core.search.PadPolicy`
    per measured topology (idempotent; runs at import)."""
    from repro.core.arch import as_arch
    from repro.core.search import derive_pad_policy, set_pad_policy
    for name, traj in _BASELINE_PAD_WATERMARKS.items():
        spec = as_arch(name)
        set_pad_policy(spec.topology.fingerprint,
                       derive_pad_policy(traj))


try:
    register_measured_pad_policies()
except ImportError:             # pragma: no cover - jax-less install
    pass

# --------------------------------------------------------------- LM family

XLSTM_350M = ModelConfig(
    name="xlstm-350m", family="ssm",
    # 24L = (mLSTM + sLSTM) x 12, d_model=1024, 4 heads (GQA kv=4), d_ff=0
    # (xLSTM blocks carry their own up/down projections), vocab 50304
    # [arXiv:2405.04517]
    d_model=1024, n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=50304,
    pattern=(BlockSpec("mlstm"), BlockSpec("slstm")), n_super=12,
    tie_embeddings=True, subquadratic=True, remat="none",
)

MISTRAL_NEMO_12B = ModelConfig(
    name="mistral-nemo-12b", family="dense",
    # 40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, hd=128,
    # 128k ctx [hf:mistralai/Mistral-Nemo-Base-2407]
    d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131072,
    pattern=(BlockSpec("attn"),), n_super=40,
    rope_theta=1_000_000.0,
)

GEMMA3_12B = ModelConfig(
    name="gemma3-12b", family="dense",
    # 48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144 — 5:1
    # local:global, 128k ctx [hf:google/gemma-3 family]
    d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=15360, vocab_size=262144,
    pattern=(BlockSpec("attn_local", repeat=5), BlockSpec("attn")),
    n_super=8, sliding_window=1024, rope_theta=1_000_000.0,
    # long_500k runs: 5/6 of layers are O(window) in decode; global layers'
    # KV caches are sequence-sharded (DESIGN.md §4)
    subquadratic=True,
)

STARCODER2_7B = ModelConfig(
    name="starcoder2-7b", family="dense",
    # 32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152, RoPE
    # [arXiv:2402.19173]
    d_model=4608, n_heads=36, n_kv_heads=4, head_dim=128,
    d_ff=18432, vocab_size=49152,
    pattern=(BlockSpec("attn"),), n_super=32,
    mlp_kind="gelu",    # StarCoder2 uses a 2-matrix GELU MLP
)

COMMAND_R_35B = ModelConfig(
    name="command-r-35b", family="dense",
    # 40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000, no-bias
    # [hf:CohereForAI/c4ai-command-r-v01]
    d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22528, vocab_size=256000,
    pattern=(BlockSpec("attn"),), n_super=40,
)

KIMI_K2_1T = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    # 61L d_model=7168 64H (GQA kv=8) expert d_ff=2048 vocab=163840,
    # MoE 384 experts top-8 [arXiv:2501.* Kimi K2]
    d_model=7168, n_heads=64, n_kv_heads=8, head_dim=112,
    d_ff=2048, vocab_size=163840,
    pattern=(BlockSpec("moe"),), n_super=61,
    n_experts=384, top_k=8, moe_d_ff=2048,
)

ARCTIC_480B = ModelConfig(
    name="arctic-480b", family="moe",
    # 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
    # MoE 128e top-2 + dense residual [hf:Snowflake/snowflake-arctic-base]
    d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=4864, vocab_size=32000,
    pattern=(BlockSpec("moe"),), n_super=35,
    n_experts=128, top_k=2, moe_d_ff=4864, moe_dense_residual=True,
)

QWEN2_VL_7B = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    # 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064 — M-RoPE,
    # dynamic resolution [arXiv:2409.12191]; vision frontend is a STUB:
    # input_specs provides precomputed patch embeddings.
    d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab_size=152064,
    pattern=(BlockSpec("attn"),), n_super=28,
    m_rope=True, frontend="vision", n_frontend_tokens=256,
)

SEAMLESS_M4T_V2 = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    # enc-dec, 24 encoder + 24 decoder layers of d_model=1024 16H
    # (GQA kv=16) d_ff=8192 [arXiv:2308.11596]; vocab 256206 padded to
    # 256208 (divisibility by TP=16); audio frontend is a STUB
    # (precomputed frame embeddings via input_specs).
    d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab_size=256208,
    pattern=(BlockSpec("attn_cross"),), n_super=24, n_enc_layers=24,
    frontend="audio", remat="none",
)

ZAMBA2_2P7B = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    # 54L d_model=2560 32H (GQA kv=32) d_ff=10240, ssm_state=64 —
    # Mamba2 blocks + SHARED attention block [arXiv:2411.15242]
    d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab_size=32000,
    pattern=(BlockSpec("mamba2", repeat=5), BlockSpec("shared_attn")),
    n_super=9, ssm_state=64, subquadratic=True, remat="none",
)

ARCHS: Dict[str, ModelConfig] = {c.name: c for c in (
    XLSTM_350M, MISTRAL_NEMO_12B, GEMMA3_12B, STARCODER2_7B,
    COMMAND_R_35B, KIMI_K2_1T, ARCTIC_480B, QWEN2_VL_7B,
    SEAMLESS_M4T_V2, ZAMBA2_2P7B)}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: tiny widths, few
    layers/experts, tiny vocab.  Full configs are exercised only via the
    ShapeDtypeStruct dry-run."""
    c = get_config(name)
    kw = dict(
        name=c.name + "-smoke",
        d_model=64,
        n_heads=4,
        n_kv_heads=min(c.n_kv_heads, 4),
        head_dim=16,
        d_ff=128 if c.d_ff else 0,
        vocab_size=512,
        n_super=2,
        sliding_window=32,
        attention_chunk=0,
        ssm_chunk=16,
        remat="none",
    )
    if c.n_experts:
        kw.update(n_experts=8, top_k=min(c.top_k, 2), moe_d_ff=64)
    if c.n_enc_layers:
        kw.update(n_enc_layers=2)
    if c.frontend:
        kw.update(n_frontend_tokens=8)
    if c.family == "ssm":
        kw.update(head_dim=None)
    if c.family == "hybrid":
        kw.update(head_dim=None, n_kv_heads=4, ssm_state=16)
    return dataclasses.replace(c, **kw)
