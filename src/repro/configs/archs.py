"""Assigned architecture configs (exact hyperparameters from the
assignment table) + reduced smoke variants, plus the registered
ACCELERATOR topologies (repro.core.arch.ArchSpec) that extend the paper's
fixed DRAM/GLB/PE/MAC hierarchy.

Vocab sizes that do not divide the TP degree (16) are padded up to the
next multiple of 16 (noted per config) — embedding sharding needs even
shards; the pad rows are never addressed by the tokenizer stub.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.arch import ArchSpec, register_arch
from repro.core.arch_dsl import compile_arch
from repro.models.config import BlockSpec, ModelConfig

# ----------------------------------------------------- accelerator archs
#
# Non-default searchable topologies, all declared through the
# ``repro.core.arch_dsl`` frontend (see COMPAT.md "Declarative arch
# frontend" for the schema).  Anything registered here resolves by name
# through the whole search stack, e.g.
#     search.run_method_sweep(methods, workloads, "maple_edge", ...)
# The energy numbers are 12nm-class pJ/byte figures in the spirit of
# Table II unless a published figure is cited; the *structure* is what
# differs from the paper topology.  ``tests/golden/zoo_validation.json``
# pins the published-vs-modeled cross-checks for the zoo entries.

#: 2-store Maple-style edge chip: no per-PE buffer — a single shared GLB
#: feeds a 16x16 PE grid directly (each PE = 1 MAC + registers).  The
#: grid computes row-wise products: one operand copy is bussed along
#: each row (fractional multicast, discount fanout 16 = the row length),
#: partial outputs reduce in-network.  One spatial mapping level, one
#: store S/G site.  3 mapping levels total.
MAPLE_EDGE = register_arch(compile_arch({
    "name": "maple_edge",
    "levels": [
        {"name": "dram"},
        {"name": "glb", "capacity": "256KB",
         "energy": [["dram", [100.0]]],
         "sg_site": "L2",
         # deliberately starved DRAM, matching Table II's edge platform
         # (16 MB/s): on-chip reuse dominates this design point, which
         # is the topology's story
         "bandwidth": "16MB/s"},
        {"name": "reg",
         "energy": [["glb", [3.5, 0.3]], ["reg", [0.05]]],
         "fanout": [16, 16],
         "noc": {"multicast": "row"}},
    ],
}))

#: 4-store clustered cloud chip: a cluster buffer sits between the GLB
#: and the PE buffers (16 clusters x 64 PEs x 16 MACs).  Three spatial
#: mapping levels, three store S/G sites ("L2"/"L3"/"L4") — 7 mapping
#: levels and a 4-gene S/G segment.
CLUSTER_CLOUD = register_arch(compile_arch({
    "name": "cluster_cloud",
    "levels": [
        {"name": "dram"},
        {"name": "glb", "capacity": "64MB",
         "energy": [["dram", [100.0]]],
         "sg_site": "L2", "bandwidth": "128GB/s"},
        {"name": "cbuf", "capacity": "1MB",
         "energy": [["glb", [15.0, 0.3]]],
         "fanout": 16, "sg_site": "L3"},
        {"name": "pebuf", "capacity": "64KB",
         "energy": [["cbuf", [1.8, 0.2]]],
         "fanout": 64, "sg_site": "L4"},
        {"name": "reg",
         "energy": [["pebuf", [0.5]], ["reg", [0.05]]],
         "fanout": 16},
    ],
}))

#: Systolic 16x16 mesh with reduction-tree output collection: operands
#: stream into the PE grid store-and-forward (mesh NoC, no multicast — an
#: irrelevant spatial loop costs one copy per PE), while partial outputs
#: collapse through an adder tree (reduction "all", one reduced result
#: per tile crosses the GLB edge).  Same S/G site count as the paper arch
#: but a distinct Topology (the NoC shape is structural).
SYSTOLIC_MESH = register_arch(compile_arch({
    "name": "systolic_mesh",
    "levels": [
        {"name": "dram"},
        {"name": "glb", "capacity": "1MB",
         "energy": [["dram", [100.0]]],
         "sg_site": "L2", "bandwidth": "32GB/s"},
        {"name": "pebuf", "capacity": "1KB",
         # per-hop mesh forwarding is pricier than the paper's
         # broadcast NoC hop — the reduction tree is the design's win
         "energy": [["glb", [6.0]], ["mesh_hop", [0.6]]],
         "fanout": [16, 16],
         "noc": {"multicast": "none", "reduction": "all"},
         "sg_site": "L3"},
        {"name": "reg",
         "energy": [["pebuf", [0.6]], ["reg", [0.05]]],
         "fanout": 4},
    ],
}))

#: Quantized 1-byte-word edge chip: the paper's exact 4-store topology
#: STRUCTURE, but every on-chip level stores 8-bit words (DRAM traffic,
#: occupancies and compression ratios all reprice; metadata bits do not
#: shrink with the datawidth, so compression pays off later than at
#: 16-bit).  Word widths are traced numbers: a family of quantized
#: variants shares one XLA compilation.
QUANT_EDGE = register_arch(compile_arch({
    "name": "quant_edge",
    "mac_energy": 0.4,          # 8-bit MACs ~ half the 16-bit energy
    "levels": [
        {"name": "dram"},
        {"name": "glb", "capacity": "128KB", "word": 1.0,
         "energy": [["dram", [100.0]]],
         "sg_site": "L2", "bandwidth": "16MB/s"},
        {"name": "pebuf", "capacity": "1KB", "word": 1.0,
         "energy": [["glb", [3.0, 0.3]]],
         "fanout": 256, "sg_site": "L3"},
        {"name": "reg", "word": 1.0,
         "energy": [["pebuf", [0.6]], ["reg", [0.05]]],
         "fanout": 4},
    ],
}))

# ------------------------------------------------------------------ zoo
#
# Published-accelerator-shaped design points.  Each is "-like": the
# STRUCTURE (hierarchy, array geometry, NoC schemes) and every cited
# number follow the publication; uncited energies are the same
# 12nm-class figures the rest of the configs use.  The cross-check
# between these declarations and the published numbers is pinned in
# ``tests/golden/zoo_validation.json`` (tests/test_zoo.py).

#: Eyeriss-like row-stationary chip (Chen et al., ISCA 2016 / JSSC
#: 2017): 12x14 PE array at 200 MHz, 108 KB GLB, ~512 B scratchpads per
#: PE, 1 MAC per PE.  Operands ride a row-wise X-bus (one GLB read
#: serves the 14 PEs of a row — fractional multicast), partial sums hop
#: PE-to-PE down each column (fractional reduction, cluster = the 12-PE
#: column).  Access energies use the paper's published normalization
#: DRAM : GLB : spad = 200 : 6 : 1 relative to one MAC (e_mac = 1.0).
EYERISS_LIKE = register_arch(compile_arch({
    "name": "eyeriss_like",
    "clock": "200MHz",
    "mac_energy": 1.0,
    "levels": [
        {"name": "dram"},
        {"name": "glb", "capacity": "108KB",
         "energy": [["dram", [200.0]]],
         "sg_site": "L2", "bandwidth": "1GB/s"},
        {"name": "spad", "capacity": "512B",
         "energy": [["glb", [6.0]]],
         "fanout": [12, 14],
         "noc": {"multicast": "row", "reduction": "col"},
         "sg_site": "L3"},
        {"name": "reg",
         "energy": [["spad", [1.0]]],
         "fanout": 1, "spatial": True},
    ],
}))

#: SIGMA-like flexible sparse trainer (Qin et al., HPCA 2020): a 128x128
#: flex-DPE array (16384 multipliers) fed through a Benes distribution
#: network — any operand reaches ANY set of multipliers in one pass, so
#: the multicast scheme is the full "all" — with partial sums collapsed
#: by the FAN forest-of-adders reduction tree, modeled as cluster-local
#: reduction across a 128-wide DPE column.  3-store hierarchy: the big
#: banked SRAM feeds multiplier registers directly.
SIGMA_LIKE = register_arch(compile_arch({
    "name": "sigma_like",
    "clock": "500MHz",
    "mac_energy": 1.0,
    "levels": [
        {"name": "dram"},
        {"name": "glb", "capacity": "4MB",
         "energy": [["dram", [160.0]]],
         "sg_site": "L2", "bandwidth": "256GB/s"},
        {"name": "reg",
         "energy": [["glb", [1.2]], ["benes", [0.8]]],
         "fanout": [128, 128],
         "noc": {"multicast": "all", "reduction": ["fan_tree", 128]}},
    ],
}))

#: DSTC-like dual-side sparse tensor core (Wang et al., ISCA 2021),
#: V100-class substrate: 80 SMs x 8 tensor-core-like units (640 total),
#: 6 MB L2 as the GLB, 96 KB shared memory per SM, 900 GB/s HBM2.
#: Operands broadcast from shared memory to the 8 units of an SM (row
#: multicast over the [80, 8] mesh), partial sums accumulate SM-locally
#: (cluster reduction, fanout 8) before crossing back to L2.
DSTC_LIKE = register_arch(compile_arch({
    "name": "dstc_like",
    "mac_energy": 0.6,
    "levels": [
        {"name": "dram"},
        {"name": "glb", "capacity": "6MB",
         "energy": [["dram", [80.0]]],
         "sg_site": "L2", "bandwidth": "900GB/s"},
        {"name": "smem", "capacity": "96KB",
         "energy": [["glb", [2.4, 0.4]]],
         "fanout": [80, 8],
         "noc": {"multicast": "row", "reduction": ["cluster", 8]},
         "sg_site": "L3"},
        {"name": "reg",
         "energy": [["smem", [0.8]], ["reg", [0.1]]],
         "fanout": 4},
    ],
}))

ACCEL_ARCHS: Dict[str, ArchSpec] = {
    a.name: a for a in (MAPLE_EDGE, CLUSTER_CLOUD, SYSTOLIC_MESH,
                        QUANT_EDGE, EYERISS_LIKE, SIGMA_LIKE,
                        DSTC_LIKE)}

#: The published-accelerator subset of :data:`ACCEL_ARCHS` (the entries
#: cross-checked by ``tests/golden/zoo_validation.json``).
ZOO_ARCHS: Dict[str, ArchSpec] = {
    a.name: a for a in (EYERISS_LIKE, SIGMA_LIKE, DSTC_LIKE)}


def zoo_validation_report() -> Dict[str, Dict[str, float]]:
    """Modeled quantities for each zoo entry, recomputed from the
    REGISTERED specs (never from the JSON), in the units the pinned
    validation table uses.  ``tests/test_zoo.py`` asserts these agree
    with ``tests/golden/zoo_validation.json`` — both the pinned modeled
    values (exactly: the declarations did not drift) and the published
    column (within each check's tolerance)."""
    e, s, d = EYERISS_LIKE, SIGMA_LIKE, DSTC_LIKE

    def first_comp(spec, edge):
        return spec.edge_energy[edge][0][1][0]

    return {
        "eyeriss_like": {
            "dram_access_vs_mac": first_comp(e, 0) / e.e_mac,
            "glb_access_vs_mac": first_comp(e, 1) / e.e_mac,
            "spad_access_vs_mac": first_comp(e, 2) / e.e_mac,
            "pe_count": float(e.store("spad").fanout),
            "row_multicast_fanout": e.edge_noc[1].multicast_fanout,
            "col_reduction_fanout": e.edge_noc[1].reduction_fanout,
            "glb_bytes": e.store("glb").capacity_bytes,
            "clock_mhz": e.clock_hz / 1e6,
        },
        "sigma_like": {
            "multiplier_count": float(s.store("reg").fanout),
            "multicast_is_full": float(
                s.edge_noc[1].multicast_scheme == "all"),
            "reduction_cluster": s.edge_noc[1].reduction_fanout,
            "clock_mhz": s.clock_hz / 1e6,
        },
        "dstc_like": {
            "tensor_core_count": float(d.store("smem").fanout),
            "l2_bytes": d.store("glb").capacity_bytes,
            "smem_bytes": d.store("smem").capacity_bytes,
            "hbm_bytes_per_s":
                d.store("glb").fill_bandwidth_bytes_per_cycle
                * d.clock_hz,
            "sm_multicast_fanout": d.edge_noc[1].multicast_fanout,
            "sm_reduction_fanout": d.edge_noc[1].reduction_fanout,
        },
    }

# ------------------------------------------- measured pad-watermark policies
#
# Per-round mega-batch pad-watermark trajectories from the committed
# benchmark baseline (benchmarks/BENCH_sweep.baseline.json, regenerated
# with ``python -m benchmarks.run --quick --only sweep_json``), keyed by
# arch name.  Every topology measured so far shows the same shape — a
# round-1 calibration/chunk spike that decays once and never re-grows —
# so ``search.derive_pad_policy`` tunes them all to the faster
# ``decay_rounds=2`` instead of the conservative CPU default.  When a
# regenerated baseline changes a trajectory, update the table; the
# ``benchmarks/compare_sweep.py`` staleness check warns when a fresh
# run's trajectory disagrees with the policy registered here.
_BASELINE_PAD_WATERMARKS: Dict[str, tuple] = {
    "cloud": (2048, 2048, 256, 256, 256, 256),
    "maple_edge": (2048, 2048, 256, 256, 256, 256),
    "cluster_cloud": (2048, 2048, 256, 256, 256, 256),
    "systolic_mesh": (2048, 2048, 256, 256, 256, 256),
    "quant_edge": (2048, 2048, 256, 256, 256, 256),
    "eyeriss_like": (2048, 2048, 256, 256, 256, 256),
    "sigma_like": (2048, 2048, 256, 256, 256, 256),
    "dstc_like": (2048, 2048, 256, 256, 256, 256),
}

# Author-declared EXPECTED trajectories for topologies registered ahead
# of their first committed baseline run.  A new zoo entry lands here (so
# it never silently inherits the default pad policy); measured baseline
# entries above always shadow a seed, and
# ``benchmarks/compare_sweep.stale_policy_warnings`` flags a still-seeded
# policy once a fresh run has measured the real trajectory.  All zoo
# seeds so far matched the measured round-1-spike shape and were
# promoted; the mechanism (and its test) stays for the next entry.
_SEED_PAD_WATERMARKS: Dict[str, tuple] = {
    "eyeriss_like": (2048, 2048, 256, 256, 256, 256),
    "sigma_like": (2048, 2048, 256, 256, 256, 256),
    "dstc_like": (2048, 2048, 256, 256, 256, 256),
}


def measured_watermark_values(topology_fingerprint: str) -> list:
    """The DISTINCT pad-watermark values a topology's committed baseline
    trajectory visited (measured entry, else the author-declared seed),
    sorted descending — the steady-state mega-batch shapes
    ``search.MultiSearch`` AOT-compiles ahead of round 1.  Unknown
    topologies return ``[]`` (no shapes claimed, so their dispatches
    never count as compile-ahead misses)."""
    from repro.core.arch import as_arch
    for table in (_BASELINE_PAD_WATERMARKS, _SEED_PAD_WATERMARKS):
        for name, traj in table.items():
            try:
                fp = as_arch(name).topology.fingerprint
            except KeyError:        # pragma: no cover - stale entry
                continue
            if fp == topology_fingerprint:
                return sorted({int(v) for v in traj}, reverse=True)
    return []


def register_measured_pad_policies() -> None:
    """Derive and register a tuned :class:`~repro.core.search.PadPolicy`
    per known topology (idempotent; runs at import).  Seeds register
    first with ``source="seed"``; measured baseline trajectories follow
    and override, stamped ``source="measured"``."""
    from repro.core.arch import as_arch
    from repro.core.search import derive_pad_policy, set_pad_policy
    for name, traj in _SEED_PAD_WATERMARKS.items():
        if name in _BASELINE_PAD_WATERMARKS:
            continue                     # a measurement shadows the seed
        set_pad_policy(as_arch(name).topology.fingerprint,
                       derive_pad_policy(traj, source="seed"))
    for name, traj in _BASELINE_PAD_WATERMARKS.items():
        set_pad_policy(as_arch(name).topology.fingerprint,
                       derive_pad_policy(traj))


try:
    register_measured_pad_policies()
except ImportError:             # pragma: no cover - jax-less install
    pass

# --------------------------------------------------------------- LM family

XLSTM_350M = ModelConfig(
    name="xlstm-350m", family="ssm",
    # 24L = (mLSTM + sLSTM) x 12, d_model=1024, 4 heads (GQA kv=4), d_ff=0
    # (xLSTM blocks carry their own up/down projections), vocab 50304
    # [arXiv:2405.04517]
    d_model=1024, n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=50304,
    pattern=(BlockSpec("mlstm"), BlockSpec("slstm")), n_super=12,
    tie_embeddings=True, subquadratic=True, remat="none",
)

MISTRAL_NEMO_12B = ModelConfig(
    name="mistral-nemo-12b", family="dense",
    # 40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, hd=128,
    # 128k ctx [hf:mistralai/Mistral-Nemo-Base-2407]
    d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131072,
    pattern=(BlockSpec("attn"),), n_super=40,
    rope_theta=1_000_000.0,
)

GEMMA3_12B = ModelConfig(
    name="gemma3-12b", family="dense",
    # 48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144 — 5:1
    # local:global, 128k ctx [hf:google/gemma-3 family]
    d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=15360, vocab_size=262144,
    pattern=(BlockSpec("attn_local", repeat=5), BlockSpec("attn")),
    n_super=8, sliding_window=1024, rope_theta=1_000_000.0,
    # long_500k runs: 5/6 of layers are O(window) in decode; global layers'
    # KV caches are sequence-sharded (DESIGN.md §4)
    subquadratic=True,
)

STARCODER2_7B = ModelConfig(
    name="starcoder2-7b", family="dense",
    # 32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152, RoPE
    # [arXiv:2402.19173]
    d_model=4608, n_heads=36, n_kv_heads=4, head_dim=128,
    d_ff=18432, vocab_size=49152,
    pattern=(BlockSpec("attn"),), n_super=32,
    mlp_kind="gelu",    # StarCoder2 uses a 2-matrix GELU MLP
)

COMMAND_R_35B = ModelConfig(
    name="command-r-35b", family="dense",
    # 40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000, no-bias
    # [hf:CohereForAI/c4ai-command-r-v01]
    d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22528, vocab_size=256000,
    pattern=(BlockSpec("attn"),), n_super=40,
)

KIMI_K2_1T = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    # 61L d_model=7168 64H (GQA kv=8) expert d_ff=2048 vocab=163840,
    # MoE 384 experts top-8 [arXiv:2501.* Kimi K2]
    d_model=7168, n_heads=64, n_kv_heads=8, head_dim=112,
    d_ff=2048, vocab_size=163840,
    pattern=(BlockSpec("moe"),), n_super=61,
    n_experts=384, top_k=8, moe_d_ff=2048,
)

ARCTIC_480B = ModelConfig(
    name="arctic-480b", family="moe",
    # 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
    # MoE 128e top-2 + dense residual [hf:Snowflake/snowflake-arctic-base]
    d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=4864, vocab_size=32000,
    pattern=(BlockSpec("moe"),), n_super=35,
    n_experts=128, top_k=2, moe_d_ff=4864, moe_dense_residual=True,
)

QWEN2_VL_7B = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    # 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064 — M-RoPE,
    # dynamic resolution [arXiv:2409.12191]; vision frontend is a STUB:
    # input_specs provides precomputed patch embeddings.
    d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab_size=152064,
    pattern=(BlockSpec("attn"),), n_super=28,
    m_rope=True, frontend="vision", n_frontend_tokens=256,
)

SEAMLESS_M4T_V2 = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    # enc-dec, 24 encoder + 24 decoder layers of d_model=1024 16H
    # (GQA kv=16) d_ff=8192 [arXiv:2308.11596]; vocab 256206 padded to
    # 256208 (divisibility by TP=16); audio frontend is a STUB
    # (precomputed frame embeddings via input_specs).
    d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab_size=256208,
    pattern=(BlockSpec("attn_cross"),), n_super=24, n_enc_layers=24,
    frontend="audio", remat="none",
)

ZAMBA2_2P7B = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    # 54L d_model=2560 32H (GQA kv=32) d_ff=10240, ssm_state=64 —
    # Mamba2 blocks + SHARED attention block [arXiv:2411.15242]
    d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab_size=32000,
    pattern=(BlockSpec("mamba2", repeat=5), BlockSpec("shared_attn")),
    n_super=9, ssm_state=64, subquadratic=True, remat="none",
)

ARCHS: Dict[str, ModelConfig] = {c.name: c for c in (
    XLSTM_350M, MISTRAL_NEMO_12B, GEMMA3_12B, STARCODER2_7B,
    COMMAND_R_35B, KIMI_K2_1T, ARCTIC_480B, QWEN2_VL_7B,
    SEAMLESS_M4T_V2, ZAMBA2_2P7B)}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: tiny widths, few
    layers/experts, tiny vocab.  Full configs are exercised only via the
    ShapeDtypeStruct dry-run."""
    c = get_config(name)
    kw = dict(
        name=c.name + "-smoke",
        d_model=64,
        n_heads=4,
        n_kv_heads=min(c.n_kv_heads, 4),
        head_dim=16,
        d_ff=128 if c.d_ff else 0,
        vocab_size=512,
        n_super=2,
        sliding_window=32,
        attention_chunk=0,
        ssm_chunk=16,
        remat="none",
    )
    if c.n_experts:
        kw.update(n_experts=8, top_k=min(c.top_k, 2), moe_d_ff=64)
    if c.n_enc_layers:
        kw.update(n_enc_layers=2)
    if c.frontend:
        kw.update(n_frontend_tokens=8)
    if c.family == "ssm":
        kw.update(head_dim=None)
    if c.family == "hybrid":
        kw.update(head_dim=None, n_kv_heads=4, ssm_state=16)
    return dataclasses.replace(c, **kw)
