"""Assigned input shapes and (arch x shape) applicability.

    train_4k     seq_len=4,096   global_batch=256   lowers train_step
    prefill_32k  seq_len=32,768  global_batch=32    lowers prefill
    decode_32k   seq_len=32,768  global_batch=128   lowers serve_step
    long_500k    seq_len=524,288 global_batch=1     lowers serve_step

``long_500k`` requires sub-quadratic attention: run for SSM/hybrid/
local-global archs (xlstm, zamba2, gemma3), skipped for pure
full-attention archs (DESIGN.md §4).  No encoder-only archs are assigned,
so decode shapes apply everywhere.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from .archs import ARCHS, get_config


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode" | "long_decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "long_decode"),
}

LONG_CONTEXT_ARCHS = {"xlstm-350m", "gemma3-12b", "zamba2-2.7b"}


def applicable(arch: str, shape: str) -> Tuple[bool, str]:
    get_config(arch)            # validates the arch name
    sh = SHAPES[shape]
    if sh.kind == "long_decode" and arch not in LONG_CONTEXT_ARCHS:
        return False, ("long_500k skipped: pure full-attention arch "
                       "(needs sub-quadratic attention; DESIGN.md §4)")
    return True, ""


def all_cells() -> List[Tuple[str, str, bool, str]]:
    """Every (arch, shape) cell with its applicability verdict."""
    out = []
    for a in ARCHS:
        for s in SHAPES:
            ok, why = applicable(a, s)
            out.append((a, s, ok, why))
    return out
