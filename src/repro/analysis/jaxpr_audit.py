"""Layer 2 — jaxpr audit: trace every registered kernel family and
statically assert the contracts the AST linter cannot see.

For each registered topology (uniform and structured-density kernel
variants, fractional-NoC schemes included — they ride in the arch zoo's
registered specs) this module traces the un-jitted vmapped row kernel
and the device-resident ES scan programs with :func:`jax.make_jaxpr`
and walks the closed jaxpr recursively:

* **no host callbacks** anywhere (``pure_callback`` / ``io_callback`` /
  ``debug_callback`` / infeed/outfeed) — a callback inside a kernel
  re-inserts the host sync the pipelined dispatch path removed;
* **no float64** — no ``convert_element_type`` to f64 and no f64
  equation outputs (the contract dtype is float32 end-to-end);
* **no transfer ops inside ``lax.scan`` bodies** (``device_put`` in a
  scan body forces a per-generation transfer);
* **one compilation per family** — the same program traced from two
  same-structure / different-numbers specs (every numeric field of the
  arch perturbed) must produce byte-identical canonicalized jaxprs.  A
  number baked into the program surfaces as a differing literal/const
  and fails the diff.

Findings are reported as :class:`repro.analysis.lint.Violation` rows
with rule id ``JAXPR`` so both layers share one report format.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from .lint import Violation

RULE_ID = "JAXPR"

#: host-callback / transfer primitives forbidden anywhere in a kernel
DENY_GLOBAL = frozenset({
    "pure_callback", "io_callback", "callback", "debug_callback",
    "host_callback_call", "infeed", "outfeed",
})
#: additionally forbidden inside lax.scan/while bodies
DENY_SCAN = DENY_GLOBAL | {"device_put"}

#: primitives whose sub-jaxprs execute inside the device loop
_LOOP_PRIMS = frozenset({"scan", "while"})

#: batch size used for tracing (any power of two works; shapes only)
_TRACE_B = 8


# ------------------------------------------------------------ jaxpr walk

def _sub_jaxprs(val) -> Iterator:
    """Yield every Jaxpr/ClosedJaxpr nested in an eqn params value."""
    import jax.core as jcore
    Closed = getattr(jcore, "ClosedJaxpr", None)
    Jaxpr = getattr(jcore, "Jaxpr", None)
    if Closed is not None and isinstance(val, Closed):
        yield val.jaxpr
    elif Jaxpr is not None and isinstance(val, Jaxpr):
        yield val
    elif isinstance(val, (list, tuple)):
        for v in val:
            yield from _sub_jaxprs(v)


def iter_eqns(jaxpr, in_scan: bool = False) -> Iterator[Tuple[object, bool]]:
    """Depth-first (eqn, inside-device-loop) pairs over a jaxpr and all
    nested jaxprs (pjit bodies, vmap/scan/cond sub-programs)."""
    for eqn in jaxpr.eqns:
        yield eqn, in_scan
        child_scan = in_scan or eqn.primitive.name in _LOOP_PRIMS
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                yield from iter_eqns(sub, child_scan)


def _is_f64(dtype) -> bool:
    try:
        return np.dtype(dtype) == np.float64
    except TypeError:
        return False


def _is_real_transfer(eqn) -> bool:
    """``device_put`` with every target device/src ``None`` is the
    alias-semantics no-op ``jnp.asarray`` emits on traced values — XLA
    elides it.  Only placements naming an actual device/committed src
    move bytes."""
    devs = eqn.params.get("devices", ())
    srcs = eqn.params.get("srcs", ())
    return any(d is not None for d in devs) or \
        any(s is not None for s in srcs)


def audit_program(closed, family: str) -> List[Violation]:
    """Walk one ClosedJaxpr and report every contract breach."""
    out: List[Violation] = []
    where = f"jaxpr:{family}"
    for eqn, in_scan in iter_eqns(closed.jaxpr):
        name = eqn.primitive.name
        if name in DENY_GLOBAL:
            out.append(Violation(
                RULE_ID, where, 0,
                f"host callback primitive `{name}` in kernel program — "
                f"re-inserts a host sync into the device path"))
        elif in_scan and name in DENY_SCAN and \
                (name != "device_put" or _is_real_transfer(eqn)):
            out.append(Violation(
                RULE_ID, where, 0,
                f"transfer primitive `{name}` inside a lax.scan body — "
                f"forces a per-generation device<->host transfer"))
        if name == "convert_element_type" and \
                _is_f64(eqn.params.get("new_dtype")):
            out.append(Violation(
                RULE_ID, where, 0,
                "convert_element_type to float64 in kernel program — "
                "the contract dtype is float32 end-to-end"))
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            if aval is not None and _is_f64(getattr(aval, "dtype", None)):
                out.append(Violation(
                    RULE_ID, where, 0,
                    f"float64 output of `{name}` in kernel program"))
                break
    return out


def canonical_hash(closed) -> str:
    """Canonicalized program hash: the printed jaxpr (variable names are
    assigned deterministically by trace order) plus shape/dtype/VALUE of
    every closure constant.  Baked numbers live exactly there — as
    literals in the printed program or as consts — so same-structure /
    different-numbers traces collide iff nothing was baked."""
    h = hashlib.sha1()
    h.update(str(closed.jaxpr).encode())
    for c in closed.consts:
        a = np.asarray(c)
        h.update(repr((a.shape, str(a.dtype))).encode())
        h.update(a.tobytes())
    return h.hexdigest()[:16]


# ------------------------------------------------- family trace builders

def _base_workload():
    from repro.configs.paper_workloads import mm_workloads
    for w in mm_workloads():
        if not w.structured_density:
            return w
    raise RuntimeError("no uniform-density paper workload found")


def _model_for(arch, structured: bool):
    from repro.core.encoding import GenomeSpec
    from repro.core.jax_cost import JaxCostModel
    spec = GenomeSpec(_base_workload(), arch)
    return JaxCostModel(spec, arch, structured=True if structured
                        else None)


def _perturb(spec):
    """Same structure, different numbers: every numeric field of the
    arch scaled by a field-specific factor.  Structure (level count,
    spatial-ness, NoC schemes, energy-group layout, word-bytes
    uniformity) is preserved, so the topology fingerprint — and with it
    the compilation signature — must not change."""
    from repro.core.arch import ArchSpec

    levels = []
    for lv in spec.levels:
        noc = dataclasses.replace(
            lv.noc,
            multicast_fanout=(None if lv.noc.multicast_fanout is None
                              else lv.noc.multicast_fanout * 2),
            reduction_fanout=(None if lv.noc.reduction_fanout is None
                              else lv.noc.reduction_fanout * 2))
        levels.append(dataclasses.replace(
            lv,
            capacity_bytes=(None if lv.capacity_bytes is None
                            else lv.capacity_bytes * 2),
            fill_energy=tuple(
                (nm, tuple(e * 1.3 for e in comps))
                for nm, comps in lv.fill_energy),
            fill_bandwidth_bytes_per_cycle=(
                None if lv.fill_bandwidth_bytes_per_cycle is None
                else lv.fill_bandwidth_bytes_per_cycle * 1.5),
            word_bytes=(None if lv.word_bytes is None
                        else lv.word_bytes * 0.5),
            # fanout VALUE is traced; spatial-ness (>1) is structural
            fanout=lv.fanout * 2 if lv.fanout > 1 else lv.fanout,
            noc=noc))
    return ArchSpec(spec.name + "+perturbed", tuple(levels),
                    e_mac=spec.e_mac * 1.7, clock_hz=spec.clock_hz)


def _trace_eval(model):
    """ClosedJaxpr of the un-jitted vmapped row kernel, exactly the
    program every dispatch path compiles."""
    import jax

    from repro.core.jax_cost import _build_eval_one, _row_structs
    eval_one = _build_eval_one(model.d, model.n_pad, model.arch.topology,
                               model.dens_key)
    veval = jax.vmap(eval_one, in_axes=(0, 0, 0, 0) + (None,) * 9)
    rows = tuple(np.zeros(s.shape, s.dtype)
                 for s in _row_structs(model, _TRACE_B))
    return jax.make_jaxpr(veval)(*rows, *model._np_consts)


def _zeros_like_structs(tree):
    import jax
    return jax.tree_util.tree_map(
        lambda s: np.zeros(s.shape, s.dtype), tree)


def _trace_scan(model, restart: int = 0):
    """ClosedJaxpr of the device-resident ES scan program (the
    ``run_segments`` fold), un-jitted, one task, tiny shapes."""
    import jax

    from repro.core.jax_cost import _scan_task_fn, scan_compile_job
    _, _, structs = scan_compile_job(model, B=_TRACE_B, k=2, n_parents=2,
                                     n_elite=1, genes_per=2, T=1,
                                     restart=restart)
    fn = _scan_task_fn(model.d, model.n_pad, model.arch.topology,
                       model.dens_key, 2, 1, 2, restart)
    return jax.make_jaxpr(fn)(*_zeros_like_structs(structs))


def _trace_direct_scan(model):
    """ClosedJaxpr of the ``standard_es`` direct-coordinate scan."""
    import jax

    from repro.core.direct_encoding import DirectValueSpec
    from repro.core.jax_cost import (_direct_scan_task_fn,
                                     direct_scan_compile_job)
    dspec = DirectValueSpec(model.spec)
    _, _, structs = direct_scan_compile_job(
        model, B=_TRACE_B, k=2, n_parents=2, n_elite=1, genes_per=2,
        T=1, direct_len=dspec.length, n_perm_codes=dspec.n_perm_codes)
    fn = _direct_scan_task_fn(model.d, model.n_pad, model.arch.topology,
                              model.dens_key, 2, 1, 2)
    return jax.make_jaxpr(fn)(*_zeros_like_structs(structs))


# --------------------------------------------------------- family sweep

def _registered_archs() -> Dict[str, object]:
    from repro.core.arch import ARCH_SPARSEMAP, registered_archs
    archs = dict(registered_archs())
    archs.setdefault("sparsemap", ARCH_SPARSEMAP)
    # same-topology aliases (edge/mobile/cloud platforms, sparsemap vs
    # cloud) trace identical programs; audit one name per fingerprint
    seen = {}
    for name in sorted(archs):
        fp = archs[name].topology.fingerprint
        if fp not in seen:
            seen[fp] = name
    return {name: archs[name] for name in sorted(seen.values())}


def _family_pair(arch, structured: bool, tracer) -> Tuple[str, str, str]:
    """(hash_base, hash_perturbed, signature check message or '')."""
    base = _model_for(arch, structured)
    pert = _model_for(_perturb(arch), structured)
    msg = ""
    if base.signature != pert.signature:
        msg = (f"numeric perturbation changed the compilation signature "
               f"{base.signature} -> {pert.signature} — a number leaked "
               f"into the structural key")
    return canonical_hash(tracer(base)), canonical_hash(tracer(pert)), msg


def audit_families(archs: Optional[Dict[str, object]] = None,
                   include_scan: bool = True,
                   ) -> Tuple[List[Violation], Dict[str, str]]:
    """Trace every registered kernel family; return (findings, hashes).

    ``hashes`` maps family label -> canonical jaxpr hash of the base
    trace (recorded into ``BENCH_sweep.json`` so hash drift across PRs
    is visible in review).
    """
    if archs is None:
        archs = _registered_archs()
    findings: List[Violation] = []
    hashes: Dict[str, str] = {}

    def run(label: str, arch, structured: bool, tracer) -> None:
        base = _model_for(arch, structured)
        closed = tracer(base)
        findings.extend(audit_program(closed, label))
        h_base = canonical_hash(closed)
        hashes[label] = h_base
        pert = _model_for(_perturb(arch), structured)
        if base.signature != pert.signature:
            findings.append(Violation(
                RULE_ID, f"jaxpr:{label}", 0,
                f"numeric perturbation changed the compilation "
                f"signature {base.signature} -> {pert.signature} — a "
                f"number leaked into the structural key"))
            return
        h_pert = canonical_hash(tracer(pert))
        if h_base != h_pert:
            findings.append(Violation(
                RULE_ID, f"jaxpr:{label}", 0,
                f"family sharing violated: same-structure / "
                f"different-numbers traces hash {h_base} vs {h_pert} — "
                f"a spec number is baked into the XLA program instead "
                f"of riding in the traced param vector"))

    for name, arch in archs.items():
        run(f"{name}/u/eval", arch, False, _trace_eval)
        run(f"{name}/s/eval", arch, True, _trace_eval)
        if include_scan:
            run(f"{name}/u/scan", arch, False, _trace_scan)
    if include_scan and archs:
        # deeper scan variants on one representative topology: the
        # structured fold, the stagnation-restart carry, and the
        # standard_es direct-coordinate translate-in-scan program
        name = ("cloud" if "cloud" in archs else sorted(archs)[0])
        arch = archs[name]
        run(f"{name}/s/scan", arch, True, _trace_scan)
        run(f"{name}/u/scan_r8", arch, False,
            lambda m: _trace_scan(m, restart=8))
        run(f"{name}/u/dscan", arch, False, _trace_direct_scan)
    return findings, hashes


def family_hashes(include_scan: bool = False) -> Dict[str, str]:
    """Just the canonical hashes (benchmark provenance section)."""
    _, hashes = audit_families(include_scan=include_scan)
    return hashes


# ------------------------------------------- compile-ahead key validation

def check_aot_job(key: Tuple, fn, arg_structs) -> List[Violation]:
    """Validate one ``compile_ahead`` job triple: the AOT registry key
    must be consistent with the argument structs it will be compiled
    for, per dispatch-path tag — a mismatched key can never be *found*
    at dispatch (the lookup misses), so every prediction with a bad key
    is a silently wasted compile."""
    import jax

    out: List[Violation] = []
    where = "aot:" + "/".join(str(k) for k in key[:5])

    def bad(msg: str) -> None:
        out.append(Violation(RULE_ID, where, 0, msg))

    if len(key) < 6:
        bad(f"AOT key {key!r} too short — expected sig + tag + shape")
        return out
    d, n_pad, fp, dens_key, tag = key[0], key[1], key[2], key[3], key[4]
    if not (isinstance(d, int) and isinstance(n_pad, int)
            and isinstance(fp, str) and len(fp) == 8
            and isinstance(dens_key, str)):
        bad(f"AOT key {key!r} does not start with a "
            f"(ndims, n_pad, fingerprint, dens_key) signature")
        return out
    leaves = jax.tree_util.tree_leaves(arg_structs)
    if not callable(fn):
        bad("job fn is not callable")

    if tag in ("stacked", "bcast"):
        padded = key[5]
        if len(leaves) != 13:
            bad(f"{tag} job has {len(leaves)} arg leaves, kernel "
                f"takes 13")
            return out
        for i in range(4):
            if leaves[i].shape[0] != padded:
                bad(f"{tag} row arg {i} leading dim "
                    f"{leaves[i].shape[0]} != padded batch {padded} "
                    f"in the key")
                break
        if leaves[1].shape[-1] != n_pad:
            bad(f"{tag} tiling arg width {leaves[1].shape[-1]} != "
                f"prime bucket {n_pad} in the key")
        if tag == "stacked":
            if any(lv.shape[0] != padded for lv in leaves[4:]):
                bad("stacked consts are not batched to the padded "
                    "batch in the key")
        elif any(lv.shape[:1] == (padded,) and lv.ndim > 0
                 for lv in leaves[4:6]):
            # bcast primes/prime_dim are (n_pad,); a padded leading dim
            # means stacked consts were paired with a bcast key
            bad("bcast consts look batched — stacked structs under a "
                "bcast key")
    elif isinstance(tag, str) and (tag.startswith("scan:")
                                   or tag.startswith("dscan:")):
        if len(key) != 9:
            bad(f"scan-family key {key!r} must be sig + (tag, T, B, k, "
                f"n_children)")
            return out
        T, B, k, n_children = key[5], key[6], key[7], key[8]
        pop = leaves[0]
        if pop.shape[0] != T or pop.shape[1] != B:
            bad(f"{tag} population struct {pop.shape} != (T={T}, B={B}, "
                f"...) in the key")
        draws = arg_structs[5] if tag.startswith("scan:") else \
            arg_structs[4]
        if not isinstance(draws, dict) or "ab" not in draws:
            bad(f"{tag} job args missing the draws dict")
        elif draws["ab"].shape != (T, k, n_children, 2):
            bad(f"{tag} draws['ab'] struct {draws['ab'].shape} != "
                f"(T={T}, k={k}, n_children={n_children}, 2) in the key")
    else:
        bad(f"unknown AOT tag {tag!r}")
    return out


def check_aot_jobs(jobs) -> List[Violation]:
    out: List[Violation] = []
    for key, fn, structs in jobs:
        out.extend(check_aot_job(key, fn, structs))
    return out
