"""R3 — no host materialization of deferred handles in the pipelined
dispatch path.

The pipelined dispatch contract (COMPAT.md, PR 8): ``run_segments`` /
``eval_stacked`` with ``defer=True`` return handles (``SegmentResult``
with an unresolved ``harvest`` thunk, ``StackedPending``) whose device
work is still in flight; the ONLY sanctioned host conversions are the
nested harvest/materialize/finalize thunks, which run one round late
and charge their wall clock through ``_time_block``.  An eager
``np.asarray`` / ``.block_until_ready()`` / ``float()`` on a dispatch
output in the *immediate* body of a dispatch-path function re-inserts
the per-round host sync the pipeline removed.

Mechanics: names bound from a dispatch call (``_aot_call``,
``eval_stacked``, ``run_segments`` — tuple unpack included) are
*deferred*; materializing ops on expressions referencing a deferred
name flag, except inside nested functions/lambdas (those are the
sanctioned late thunks).
"""
from __future__ import annotations

import ast
from typing import List, Set

from ..lint import Rule, Violation, assign_target_names, dotted_name, names_in

#: calls whose results are in-flight device handles
DISPATCH_FNS = {"_aot_call", "eval_stacked", "run_segments",
                "_run_direct_segments"}

#: eager materializers
SYNC_CALLS = {"float", "int", "list"}
SYNC_DOTTED = {"np.asarray", "np.array", "numpy.asarray", "numpy.array",
               "np.stack", "np.concatenate"}
SYNC_METHODS = {"block_until_ready"}

FILES = ("repro/core/jax_cost.py", "repro/core/search.py")


def _immediate_nodes(fn: ast.AST):
    """Every node in the function's own body, descending into control
    flow and expressions but NOT into nested function/lambda bodies
    (those are the sanctioned deferred thunks)."""
    stack = list(fn.body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


class DeferredSyncRule(Rule):
    rule_id = "R3"
    title = "no host sync on deferred dispatch handles (pipeline path)"

    def applies(self, path: str) -> bool:
        return any(path.endswith(f) for f in FILES)

    def check(self, tree: ast.AST, src: str, path: str) -> List[Violation]:
        out: List[Violation] = []
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                out.extend(self._check_fn(node, path))
        return out

    def _check_fn(self, fn: ast.AST, path: str) -> List[Violation]:
        nodes = list(_immediate_nodes(fn))
        deferred: Set[str] = set()
        for node in nodes:
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Call):
                d = dotted_name(node.value.func)
                leaf = d.rsplit(".", 1)[-1] if d else None
                if leaf in DISPATCH_FNS:
                    for tgt in node.targets:
                        deferred |= assign_target_names(tgt)
        if not deferred:
            return []
        # propagate through plain reassignments in the immediate body
        for _ in range(4):
            grew = False
            for node in nodes:
                if isinstance(node, ast.Assign) and \
                        names_in(node.value) & deferred:
                    tgts: Set[str] = set()
                    for t in node.targets:
                        tgts |= assign_target_names(t)
                    if not tgts <= deferred:
                        deferred |= tgts
                        grew = True
            if not grew:
                break

        out: List[Violation] = []
        for call in nodes:
            if not isinstance(call, ast.Call):
                continue
            sync = None
            target = None
            d = dotted_name(call.func)
            if d in SYNC_CALLS or d in SYNC_DOTTED:
                if call.args:
                    sync, target = d, call.args[0]
            elif isinstance(call.func, ast.Attribute) and \
                    call.func.attr in SYNC_METHODS:
                sync = f".{call.func.attr}()"
                target = call.func.value
            if sync is None or target is None:
                continue
            hit = names_in(target) & deferred
            if hit:
                out.append(Violation(
                    self.rule_id, path, call.lineno,
                    f"{sync} blocks on deferred dispatch handle "
                    f"({', '.join(sorted(hit))}) in the immediate "
                    f"dispatch path — materialize only inside the "
                    f"harvest/finalize thunks via _time_block "
                    f"(COMPAT.md pipelined dispatch contract)"))
        return out
