"""R2 — RNG determinism in ``core/``: no global-state or time-seeded RNG.

The device<->host replay contract (COMPAT.md "Device-resident round
protocol"): every random draw a search consumes is pre-planned through
the ``es_ops`` plan/draw split from an explicitly seeded
``np.random.Generator`` (legacy call order) or ``jax.random.fold_in``
keys, so a device-folded segment replays bit-identically on the host.
Bare ``np.random.*`` calls (module-global state), stdlib ``random``
usage, and unseeded/time-seeded ``default_rng()`` all break that
bit-parity.  ``es_ops.py`` itself is the sanctioned plan/draw module
and is exempt.

Allowed: ``np.random.default_rng(<explicit seed>)``,
``np.random.SeedSequence(...)``, ``np.random.Generator`` (annotations
are not calls and never flag).
"""
from __future__ import annotations

import ast
from typing import List

from ..lint import Rule, Violation, dotted_name

#: np.random attributes that are fine to CALL
_ALLOWED = {"default_rng", "Generator", "SeedSequence", "PCG64",
            "Philox", "SFC64", "MT19937", "BitGenerator"}


def _mentions_time(node: ast.AST) -> bool:
    for n in ast.walk(node):
        d = dotted_name(n)
        if d in ("time.time", "time.time_ns", "time.perf_counter",
                 "time.monotonic"):
            return True
    return False


class RngDeterminismRule(Rule):
    rule_id = "R2"
    title = "no bare np.random.* / random.* / time-seeded RNG in core/"

    def applies(self, path: str) -> bool:
        return "repro/core/" in path and \
            not path.endswith("core/es_ops.py")

    def check(self, tree: ast.AST, src: str, path: str) -> List[Violation]:
        out: List[Violation] = []
        imports_random = False
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                if any(a.name == "random" for a in node.names):
                    imports_random = True
                    out.append(Violation(
                        self.rule_id, path, node.lineno,
                        "stdlib `random` (global hidden state) breaks "
                        "device<->host replay bit-parity; draw from a "
                        "seeded np.random.Generator via the es_ops "
                        "plan/draw split instead"))
            elif isinstance(node, ast.ImportFrom):
                if node.module == "random":
                    out.append(Violation(
                        self.rule_id, path, node.lineno,
                        "stdlib `random` (global hidden state) breaks "
                        "device<->host replay bit-parity; draw from a "
                        "seeded np.random.Generator via the es_ops "
                        "plan/draw split instead"))
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            d = dotted_name(node.func)
            if d is None:
                continue
            if d.startswith(("np.random.", "numpy.random.")):
                attr = d.rsplit(".", 1)[1]
                if attr not in _ALLOWED:
                    out.append(Violation(
                        self.rule_id, path, node.lineno,
                        f"bare global-state RNG `{d}(...)` in core/ "
                        f"breaks replay determinism; use an explicitly "
                        f"seeded np.random.default_rng through the "
                        f"es_ops plan/draw split"))
                elif attr == "default_rng" and (
                        not node.args or
                        isinstance(node.args[0], ast.Constant)
                        and node.args[0].value is None or
                        _mentions_time(node)):
                    out.append(Violation(
                        self.rule_id, path, node.lineno,
                        "unseeded/time-seeded default_rng() in core/ is "
                        "non-replayable; pass an explicit seed"))
            elif imports_random and d.startswith("random."):
                out.append(Violation(
                    self.rule_id, path, node.lineno,
                    f"stdlib `{d}(...)` uses hidden global state; use a "
                    f"seeded np.random.Generator via the es_ops "
                    f"plan/draw split"))
        return out
