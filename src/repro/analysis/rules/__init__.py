"""The repo-contract rule set.  ``ALL_RULES`` lists the AST rules the
lint engine runs per file; R5 (registry conformance) is runtime
reflection — see :func:`repro.analysis.rules.r5_registry.check_registries`.
"""
from .r1_traced_bake import TracedBakeRule
from .r2_rng import RngDeterminismRule
from .r3_deferred_sync import DeferredSyncRule
from .r4_counter_lock import CounterLockRule
from .r5_registry import check_registries

ALL_RULES = [TracedBakeRule, RngDeterminismRule, DeferredSyncRule,
             CounterLockRule]

__all__ = ["ALL_RULES", "TracedBakeRule", "RngDeterminismRule",
           "DeferredSyncRule", "CounterLockRule", "check_registries"]
