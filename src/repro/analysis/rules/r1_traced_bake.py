"""R1 — no host coercion of traced values inside kernel-builder code.

The structural-vs-traced contract (COMPAT.md, jax_cost module
docstring): arch/density *numbers* ride in traced arguments
(``ArchSpec.param_vector`` -> ``plat``, density ``param_row`` rows ->
``dens_params``, the workload constants), so one XLA compilation serves
a whole same-structure family.  ``float()``/``int()``/``.item()``/
``np.asarray`` applied to a traced value inside a kernel bakes the
number into the program — either a ConcretizationTypeError at trace
time or, worse, a silent per-arch recompile when the value happens to
be concrete (a closure constant).  This rule flags those coercions
inside kernel scopes of ``jax_cost.py`` / ``arch.py`` / ``density.py``.

A *kernel scope* is any function whose parameter list includes one of
the traced-argument sentinels (``plat``, ``dens_params``, ``consts``,
``draws``, ``pr`` — the names the kernel builders thread traced values
through), plus every function nested inside one.  Within a scope the
traced set seeds from all parameters and propagates through
assignments to a fixpoint.
"""
from __future__ import annotations

import ast
from typing import List, Set

from ..lint import Rule, Violation, assign_target_names, dotted_name, names_in

#: parameter names that mark a function as kernel code (traced inputs)
KERNEL_PARAMS = {"plat", "dens_params", "consts", "draws", "pr"}

#: bare-callable coercions that concretize a traced value
COERCE_CALLS = {"float", "int", "bool", "complex"}
#: dotted coercions that materialize a traced array on the host
COERCE_DOTTED = {"np.asarray", "np.array", "numpy.asarray", "numpy.array"}
#: method calls that concretize
COERCE_METHODS = {"item", "tolist"}

FILES = ("repro/core/jax_cost.py", "repro/core/arch.py",
         "repro/core/density.py")


def _func_params(fn: ast.AST) -> List[str]:
    a = fn.args
    params = [p.arg for p in
              getattr(a, "posonlyargs", []) + a.args + a.kwonlyargs]
    if a.vararg:
        params.append(a.vararg.arg)
    if a.kwarg:
        params.append(a.kwarg.arg)
    return params


class TracedBakeRule(Rule):
    rule_id = "R1"
    title = "no float()/int()/.item()/np.asarray on traced kernel values"

    def applies(self, path: str) -> bool:
        return any(path.endswith(f) for f in FILES)

    def check(self, tree: ast.AST, src: str, path: str) -> List[Violation]:
        out: List[Violation] = []
        for node in ast.walk(tree):
            if not isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            if not set(_func_params(node)) & KERNEL_PARAMS:
                continue
            out.extend(self._check_kernel(node, path))
        # a kernel root nested in another kernel root is visited twice;
        # dedupe by location
        seen = set()
        uniq = []
        for v in out:
            k = (v.line, v.message)
            if k not in seen:
                seen.add(k)
                uniq.append(v)
        return uniq

    def _check_kernel(self, fn: ast.AST, path: str) -> List[Violation]:
        # traced set: every parameter of the kernel function and of any
        # function nested inside it (closures over traced values), then
        # assignment propagation to a fixpoint
        traced: Set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                traced.update(_func_params(node))
        assigns = [n for n in ast.walk(fn)
                   if isinstance(n, (ast.Assign, ast.AugAssign,
                                     ast.AnnAssign))]
        for _ in range(8):          # fixpoint; depth is tiny in practice
            grew = False
            for a in assigns:
                if a.value is None:
                    continue
                if names_in(a.value) & traced:
                    tgts = (assign_target_names(a.targets[0])
                            if isinstance(a, ast.Assign)
                            else assign_target_names(a.target))
                    if not tgts <= traced:
                        traced |= tgts
                        grew = True
            if not grew:
                break

        out: List[Violation] = []
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            coerce = None
            target = None
            d = dotted_name(node.func)
            if d in COERCE_CALLS or d in COERCE_DOTTED:
                if node.args:
                    coerce, target = d, node.args[0]
            elif isinstance(node.func, ast.Attribute) and \
                    node.func.attr in COERCE_METHODS:
                coerce, target = f".{node.func.attr}()", node.func.value
            if coerce is None or target is None:
                continue
            hit = names_in(target) & traced
            if hit:
                out.append(Violation(
                    self.rule_id, path, node.lineno,
                    f"{coerce} applied to traced value "
                    f"({', '.join(sorted(hit))}) inside kernel code "
                    f"bakes a number into the XLA program — keep it in "
                    f"the traced param vector (COMPAT.md "
                    f"structural-vs-traced contract)"))
        return out
