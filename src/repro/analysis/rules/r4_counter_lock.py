"""R4 — jax_cost module counters/registries mutate only under ``_LOCK``.

The compile-ahead worker mutates the module-level counters and
registries from its background thread while the search thread
dispatches (jax_cost header comment), so every mutation — assignment,
augmented increment, subscript store, or mutating method call — must
sit lexically inside a ``with _LOCK:`` block.  Module-level
initializers (outside any function) are exempt; reads are not
restricted.
"""
from __future__ import annotations

import ast
import re
from typing import List

from ..lint import Rule, Violation, names_in

#: the lock-guarded module globals (jax_cost header comment)
COUNTER_RE = re.compile(
    r"^_(DISPATCHES|HOST_BLOCKED_S|CA_HITS|CA_MISSES|CA_ACTIVE|"
    r"CA_PREFIXES|CA_CANCEL|STACK_PREP_HITS|STACK_PREP_MISSES|"
    r"JIT_FNS|SHARD_FNS|STACK_CONSTS|AOT_FNS|AOT_PENDING)$")

MUTATORS = {"clear", "update", "pop", "popitem", "setdefault", "add",
            "append", "extend", "remove", "discard", "insert"}

FILES = ("repro/core/jax_cost.py",)


def _is_counter(name: str) -> bool:
    return bool(COUNTER_RE.match(name))


class CounterLockRule(Rule):
    rule_id = "R4"
    title = "jax_cost counter/registry mutations must hold _LOCK"

    def applies(self, path: str) -> bool:
        return any(path.endswith(f) for f in FILES)

    def check(self, tree: ast.AST, src: str, path: str) -> List[Violation]:
        out: List[Violation] = []
        self._visit(tree, path, fn_depth=0, lock_depth=0, out=out)
        return out

    def _visit(self, node: ast.AST, path: str, fn_depth: int,
               lock_depth: int, out: List[Violation]) -> None:
        for child in ast.iter_child_nodes(node):
            c_fn, c_lock = fn_depth, lock_depth
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                c_fn += 1
            elif isinstance(child, ast.With):
                if any("_LOCK" in names_in(item.context_expr)
                       for item in child.items):
                    c_lock += 1
            if fn_depth > 0 and lock_depth == 0:
                self._flag(child, path, out)
            self._visit(child, path, c_fn, c_lock, out)

    def _flag(self, node: ast.AST, path: str,
              out: List[Violation]) -> None:
        hits: List[str] = []
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                if isinstance(t, ast.Name) and _is_counter(t.id):
                    hits.append(t.id)
                elif isinstance(t, ast.Subscript) and \
                        isinstance(t.value, ast.Name) and \
                        _is_counter(t.value.id):
                    hits.append(t.value.id)
                elif isinstance(t, ast.Tuple):
                    for el in t.elts:
                        if isinstance(el, ast.Name) and \
                                _is_counter(el.id):
                            hits.append(el.id)
        elif isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr in MUTATORS and \
                isinstance(node.func.value, ast.Name) and \
                _is_counter(node.func.value.id):
            hits.append(f"{node.func.value.id}.{node.func.attr}()")
        for h in hits:
            out.append(Violation(
                self.rule_id, path, node.lineno,
                f"mutation of {h} outside `with _LOCK:` races the "
                f"compile-ahead worker thread — guard every module "
                f"counter/registry mutation with the lock"))
