"""R5 — registry conformance: every registered plugin implements the
full COMPAT.md protocol surface.

Runtime reflection over the three extension registries:

* ``baselines.REQUEST_METHODS`` — each factory must accept
  ``(spec, platform, budget, seed, **kw)``; ``SEGMENT_METHODS`` must be
  a subset of the registered methods.
* ``density`` families (``register_density_model``) — frozen hashable
  dataclass subclassing ``DensityModel`` with a ``family`` tag matching
  its registry key, overriding ``density``/``block_nonempty``/
  ``params``, and paired with a JAX occupancy builder
  (``jax_cost.register_density_occ``) so the structured kernel can
  trace it.
* registered topologies (``arch.register_arch`` + the paper platforms)
  — ``param_vector()`` must be a 1-D float32 vector whose length
  exactly matches the kernel's ``_topo_tables`` index layout (a
  mismatch silently misreads traced numbers).

All registries are injectable for testing; defaults reflect over the
live ones.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Dict, List, Optional

from ..lint import Violation

RULE_ID = "R5"


def _v(where: str, msg: str) -> Violation:
    return Violation(RULE_ID, where, 0, msg)


def check_request_methods(request_methods: Dict,
                          segment_methods=None) -> List[Violation]:
    out: List[Violation] = []
    where = "registry:REQUEST_METHODS"
    for name, factory in request_methods.items():
        if not callable(factory):
            out.append(_v(where, f"{name!r}: factory is not callable"))
            continue
        try:
            sig = inspect.signature(factory)
        except (TypeError, ValueError):
            continue                    # uninspectable; give it a pass
        params = list(sig.parameters.values())
        n_pos = len([p for p in params
                     if p.kind in (p.POSITIONAL_ONLY,
                                   p.POSITIONAL_OR_KEYWORD)])
        has_varkw = any(p.kind == p.VAR_KEYWORD for p in params)
        if n_pos < 4:
            out.append(_v(
                where,
                f"{name!r}: factory must accept (spec, platform, "
                f"budget, seed, **kw); it takes only {n_pos} "
                f"positional parameters"))
        if not has_varkw:
            out.append(_v(
                where,
                f"{name!r}: factory must accept **kw (method_kw "
                f"passthrough; COMPAT.md request-generator protocol)"))
    if segment_methods is not None:
        for name in sorted(segment_methods):
            if name not in request_methods:
                out.append(_v(
                    "registry:SEGMENT_METHODS",
                    f"{name!r} is declared segment-foldable but has no "
                    f"REQUEST_METHODS factory"))
    return out


def check_density_families(families: Dict, jax_occ: Dict,
                           base_cls=None) -> List[Violation]:
    out: List[Violation] = []
    for fam, entry in families.items():
        where = f"registry:density[{fam}]"
        cls = entry[1] if isinstance(entry, tuple) else entry
        if base_cls is not None and not (isinstance(cls, type) and
                                         issubclass(cls, base_cls)):
            out.append(_v(where, "not a DensityModel subclass"))
            continue
        if getattr(cls, "family", None) != fam:
            out.append(_v(
                where,
                f"class attr family={getattr(cls, 'family', None)!r} "
                f"does not match its registry key"))
        if not dataclasses.is_dataclass(cls):
            out.append(_v(where, "must be a (frozen) dataclass"))
        elif not cls.__dataclass_params__.frozen:
            out.append(_v(
                where, "dataclass must be frozen=True (models key "
                       "evaluator caches and live inside TensorSpec)"))
        if getattr(cls, "__hash__", None) is None:
            out.append(_v(where, "not hashable (frozen dataclass "
                                 "required)"))
        if not isinstance(getattr(cls, "density", None), property):
            out.append(_v(where, "missing `density` property"))
        for meth in ("block_nonempty", "params"):
            fn = getattr(cls, meth, None)
            if not callable(fn):
                out.append(_v(where, f"missing `{meth}` method"))
            elif base_cls is not None and \
                    fn is getattr(base_cls, meth, None):
                out.append(_v(
                    where,
                    f"`{meth}` not overridden (base raises "
                    f"NotImplementedError)"))
        if not callable(getattr(cls, "hit_rate", None)):
            out.append(_v(where, "missing `hit_rate` method"))
        if fam not in jax_occ:
            out.append(_v(
                where,
                "no JAX occupancy builder registered — call "
                "jax_cost.register_density_occ(family, fn) (COMPAT.md "
                "\"Defining a custom DensityModel\")"))
    return out


def check_archs(archs: Dict) -> List[Violation]:
    import numpy as np

    from repro.core.jax_cost import _topo_tables

    out: List[Violation] = []
    for name, spec in archs.items():
        where = f"registry:arch[{name}]"
        try:
            topo = spec.topology
            tt = _topo_tables(topo)
        except Exception as e:          # structurally broken spec
            out.append(_v(where, f"topology tables failed: {e!r}"))
            continue
        idxs = (list(tt.fanout_idx)
                + [i for _, i in tt.cap_checks]
                + [i for row in tt.energy_idx for i in row]
                + [i for _, i in tt.bw_checks]
                + [tt.mac_idx]
                + list(tt.word_idx)
                + [i for i in tt.noc_mc_idx if i is not None]
                + [i for i in tt.noc_red_idx if i is not None])
        expected = max(idxs) + 1
        try:
            vec = spec.param_vector()
        except Exception as e:
            out.append(_v(where, f"param_vector() failed: {e!r}"))
            continue
        if np.ndim(vec) != 1:
            out.append(_v(where, f"param_vector() must be 1-D, got "
                                 f"ndim={np.ndim(vec)}"))
        if np.asarray(vec).dtype != np.float32:
            out.append(_v(
                where,
                f"param_vector() must be float32 (traced row dtype), "
                f"got {np.asarray(vec).dtype}"))
        if len(vec) != expected:
            out.append(_v(
                where,
                f"param_vector() length {len(vec)} != kernel layout "
                f"length {expected} — traced numbers would be "
                f"misread (COMPAT.md \"Defining a custom ArchSpec\")"))
        fp = topo.fingerprint
        if not (isinstance(fp, str) and len(fp) == 8):
            out.append(_v(where, f"topology.fingerprint {fp!r} is not "
                                 f"an 8-hex tag"))
    return out


def check_registries(request_methods: Optional[Dict] = None,
                     segment_methods=None,
                     density_families: Optional[Dict] = None,
                     jax_occ: Optional[Dict] = None,
                     archs: Optional[Dict] = None) -> List[Violation]:
    """Run every registry-conformance check; any argument left ``None``
    reflects over the corresponding live registry."""
    from repro.core import baselines, density, jax_cost
    from repro.core.arch import ARCH_SPARSEMAP, registered_archs

    if request_methods is None:
        request_methods = baselines.REQUEST_METHODS
        if segment_methods is None:
            segment_methods = baselines.SEGMENT_METHODS
    if density_families is None:
        density_families = density._FAMILIES
    if jax_occ is None:
        jax_occ = jax_cost._JAX_OCC
    if archs is None:
        archs = dict(registered_archs())
        archs.setdefault("sparsemap", ARCH_SPARSEMAP)

    out = check_request_methods(request_methods, segment_methods)
    out += check_density_families(density_families, jax_occ,
                                  base_cls=density.DensityModel)
    out += check_archs(archs)
    return out
