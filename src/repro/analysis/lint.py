"""AST contract linter: the rule engine behind ``python -m repro.analysis``.

Layer 1 of the static-analysis gate.  Each rule is a small class over the
stdlib ``ast`` module encoding ONE repo contract from COMPAT.md — the
structural-vs-traced split (R1), the RNG plan/draw determinism contract
(R2), the pipelined-dispatch no-host-sync contract (R3), the jax_cost
counter lock discipline (R4).  Registry conformance (R5) is runtime
reflection and lives in :mod:`repro.analysis.rules.r5_registry`; the
jaxpr layer is :mod:`repro.analysis.jaxpr_audit`.

Suppression: a violation whose source line carries
``# repro: noqa-contract(RULE)`` (or ``(RULE1,RULE2)``) is dropped —
the escape hatch for a reviewed, intentional exception.  There is no
``--fix``; violations are fixed by hand or suppressed explicitly.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set


@dataclasses.dataclass(frozen=True)
class Violation:
    """One contract violation: rule id, location, human message."""

    rule: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


class Rule:
    """Base class for AST contract rules.  ``applies`` selects files by
    path (repo-relative, '/'-separated); ``check`` returns raw
    violations (suppressions are handled by the engine)."""

    rule_id = "R?"
    title = ""

    def applies(self, path: str) -> bool:
        raise NotImplementedError

    def check(self, tree: ast.AST, src: str, path: str) -> List[Violation]:
        raise NotImplementedError


# ------------------------------------------------------------ AST helpers


def dotted_name(node: ast.AST) -> Optional[str]:
    """'np.random.rand' for nested Attribute/Name chains, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted_name(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def names_in(node: ast.AST) -> Set[str]:
    """All bare identifier names referenced anywhere under ``node``."""
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def assign_target_names(node: ast.AST) -> Set[str]:
    """Plain names bound by an assignment target (tuple unpack included;
    subscript/attribute stores bind no new name)."""
    out: Set[str] = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store):
            out.add(n.id)
    return out


_NOQA_RE = re.compile(r"#\s*repro:\s*noqa-contract\(([^)]*)\)")


def suppressions(src: str) -> Dict[int, Set[str]]:
    """line number -> set of suppressed rule ids (from
    ``# repro: noqa-contract(R1)`` / ``(R1,R2)`` comments)."""
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(src.splitlines(), start=1):
        m = _NOQA_RE.search(line)
        if m:
            out[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
    return out


# ---------------------------------------------------------------- engine


def default_rules() -> List[Rule]:
    from .rules import ALL_RULES
    return [cls() for cls in ALL_RULES]


def lint_source(src: str, path: str, rules: Sequence[Rule],
                force: bool = False) -> List[Violation]:
    """Run ``rules`` over one file's source.  ``force=True`` skips the
    per-rule path filter (fixture tests)."""
    norm = path.replace(os.sep, "/")
    active = [r for r in rules if force or r.applies(norm)]
    if not active:
        return []
    tree = ast.parse(src, filename=path)
    sup = suppressions(src)
    out: List[Violation] = []
    for rule in active:
        for v in rule.check(tree, src, norm):
            if rule.rule_id in sup.get(v.line, ()):
                continue
            out.append(v)
    return sorted(out, key=lambda v: (v.path, v.line, v.rule))


def lint_file(path: str, rules: Optional[Sequence[Rule]] = None,
              force: bool = False) -> List[Violation]:
    with open(path, "r", encoding="utf-8") as f:
        src = f.read()
    return lint_source(src, path, rules or default_rules(), force=force)


def iter_py_files(roots: Iterable[str]) -> List[str]:
    out: List[str] = []
    for root in roots:
        if os.path.isfile(root):
            out.append(root)
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__", ".git",
                                        "bench_out", ".ruff_cache")]
            out.extend(os.path.join(dirpath, f)
                       for f in sorted(filenames) if f.endswith(".py"))
    return sorted(out)


def lint_paths(roots: Iterable[str],
               rules: Optional[Sequence[Rule]] = None
               ) -> List[Violation]:
    """Lint every .py file under ``roots`` with the applicable rules."""
    rules = list(rules or default_rules())
    out: List[Violation] = []
    for path in iter_py_files(roots):
        out.extend(lint_file(path, rules))
    return out
