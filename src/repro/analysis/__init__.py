"""Static-analysis subsystem: machine-checks for the repo's three load-
bearing contracts (COMPAT.md).

Layer 1 — AST contract lint (:mod:`.lint`, :mod:`.rules`): rules R1-R4
walk Python source, R5 reflects over the live plugin registries.

Layer 2 — jaxpr audit (:mod:`.jaxpr_audit`): traces every registered
kernel family and asserts no host callbacks, no float64, no transfers
in scan bodies, and one-compilation-per-family.

Run both as a gate with ``python -m repro.analysis`` (exit code 1 on
any violation).  Suppress a single lint line with
``# repro: noqa-contract(R2)`` — suppressions carry the rule id and are
reviewable in the diff.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

from .lint import (Rule, Violation, default_rules, iter_py_files,
                   lint_file, lint_paths, lint_source)

__all__ = [
    "Rule", "Violation", "default_rules", "iter_py_files", "lint_file",
    "lint_paths", "lint_source", "run_report",
]

#: source roots the gate sweeps, relative to the repo root
DEFAULT_ROOTS = ("src", "benchmarks", "examples")


def run_report(roots: Optional[List[str]] = None,
               include_jaxpr: bool = True,
               include_scan: bool = True) -> Dict:
    """Run both analysis layers and return a JSON-ready report:
    ``{"lint": {...}, "jaxpr": {...}, "ok": bool}``.  Shared by the
    ``python -m repro.analysis`` gate and ``benchmarks/run.py`` (which
    records the timings and rule counts into ``BENCH_sweep.json``)."""
    import os

    from .rules import ALL_RULES
    from .rules.r5_registry import check_registries

    if roots is None:
        roots = [r for r in DEFAULT_ROOTS if os.path.isdir(r)]

    t0 = time.perf_counter()
    violations = lint_paths(roots)
    violations += check_registries()
    lint_s = time.perf_counter() - t0

    rule_counts = {r.rule_id: 0 for r in ALL_RULES}
    rule_counts["R5"] = 0
    for v in violations:
        rule_counts[v.rule] = rule_counts.get(v.rule, 0) + 1

    report: Dict = {
        "lint": {
            "roots": list(roots),
            "violations": [str(v) for v in violations],
            "rule_counts": rule_counts,
            "seconds": round(lint_s, 3),
        },
    }

    jaxpr_viol: List[Violation] = []
    if include_jaxpr:
        from .jaxpr_audit import audit_families
        t1 = time.perf_counter()
        jaxpr_viol, hashes = audit_families(include_scan=include_scan)
        report["jaxpr"] = {
            "findings": [str(v) for v in jaxpr_viol],
            "hashes": hashes,
            "families": len(hashes),
            "seconds": round(time.perf_counter() - t1, 3),
        }

    report["ok"] = not violations and not jaxpr_viol
    return report
