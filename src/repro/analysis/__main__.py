"""``python -m repro.analysis`` — the contract gate.

Runs the AST contract lint (rules R1-R5) over the source roots and the
jaxpr audit over every registered kernel family, prints each violation
as ``path:line: [RULE] message``, and exits non-zero if anything fired.
There is deliberately no ``--fix``: every violation is either a real
contract breach (fix the code) or a reviewed exception (annotate the
line with ``# repro: noqa-contract(RULE)``).
"""
from __future__ import annotations

import argparse
import sys

from . import DEFAULT_ROOTS, run_report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="SparseMap contract linter + jaxpr auditor")
    ap.add_argument("roots", nargs="*", default=None,
                    help=f"source roots to lint (default: "
                         f"{' '.join(DEFAULT_ROOTS)}, existing only)")
    ap.add_argument("--skip-jaxpr", action="store_true",
                    help="lint layer only (no kernel tracing; fast)")
    ap.add_argument("--no-scan", action="store_true",
                    help="jaxpr-audit the row kernels but skip the ES "
                         "scan programs (quicker trace)")
    args = ap.parse_args(argv)

    report = run_report(roots=args.roots or None,
                        include_jaxpr=not args.skip_jaxpr,
                        include_scan=not args.no_scan)

    for line in report["lint"]["violations"]:
        print(line)
    jx = report.get("jaxpr")
    if jx:
        for line in jx["findings"]:
            print(line)

    n_lint = len(report["lint"]["violations"])
    n_jax = len(jx["findings"]) if jx else 0
    counts = ", ".join(f"{k}={v}" for k, v in
                       sorted(report["lint"]["rule_counts"].items()))
    print(f"analysis: lint {n_lint} violation(s) [{counts}] in "
          f"{report['lint']['seconds']}s", file=sys.stderr)
    if jx:
        print(f"analysis: jaxpr {n_jax} finding(s) across "
              f"{jx['families']} kernel families in {jx['seconds']}s",
              file=sys.stderr)
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
