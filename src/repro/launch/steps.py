"""Step builders shared by the trainer, the server and the dry-run."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim import optimizer as opt_lib


def build_train_step(model: Model, ocfg: opt_lib.OptConfig,
                     n_microbatches: int = 1) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    With ``n_microbatches`` > 1 the global batch is split along axis 0 and
    gradients are accumulated with a ``lax.scan`` (sequential microbatches
    — the standard remat-friendly pattern)."""

    def loss_fn(params, batch):
        return model.loss_fn(params, batch)

    def train_step(params, opt_state, batch):
        if n_microbatches == 1:
            (loss, aux), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def split(x):
                return x.reshape((n_microbatches,
                                  x.shape[0] // n_microbatches) +
                                 x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc_body(carry, mbatch):
                gsum, lsum = carry
                (l, aux), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mbatch)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + l), aux

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), aux = jax.lax.scan(
                acc_body, (zeros, jnp.float32(0.0)), mb)
            grads = jax.tree.map(lambda g: g / n_microbatches, gsum)
            loss = lsum / n_microbatches
            aux = jax.tree.map(lambda a: a[-1], aux)
        params, opt_state, stats = opt_lib.apply(params, grads,
                                                 opt_state, ocfg)
        metrics = dict(loss=loss, **stats)
        return params, opt_state, metrics

    return train_step


def build_serve_step(model: Model) -> Callable:
    """(params, cache, tokens [B,1], pos scalar) -> (logits, cache)."""

    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    return serve_step


def build_prefill_step(model: Model) -> Callable:
    """Prefill lowers the forward pass (logits over the whole prompt)."""

    def prefill_step(params, batch):
        logits, _ = model.forward(params, batch["tokens"],
                                  frontend=batch.get("frontend"),
                                  enc_embeds=batch.get("enc_embeds"))
        return logits

    return prefill_step
