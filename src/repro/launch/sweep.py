"""Dry-run sweep driver: every (arch x shape x mesh) cell in its own
subprocess (sequential — the container has one core; isolation means one
pathological cell cannot take down the sweep), appending JSONL records.
Resumable: cells already present in the output file are skipped.

    PYTHONPATH=src python -m repro.launch.sweep --jsonl bench_out/dryrun.jsonl
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time


def existing_keys(path):
    keys = set()
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("status") in ("ok", "skipped"):
                        keys.add((r["arch"], r["shape"], r["mesh"]))
                except json.JSONDecodeError:
                    pass
    return keys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", required=True)
    ap.add_argument("--timeout", type=int, default=2400)
    ap.add_argument("--only-mesh", choices=["16x16", "2x16x16"], default=None)
    args = ap.parse_args(argv)

    from repro.configs import ARCHS, SHAPES     # no jax import here
    done = existing_keys(args.jsonl)
    cells = []
    for a in sorted(ARCHS):
        for s in SHAPES:                         # keep canonical order
            for mesh, flag in (("16x16", []), ("2x16x16", ["--multi-pod"])):
                if args.only_mesh and mesh != args.only_mesh:
                    continue
                if (a, s, mesh) in done:
                    continue
                cells.append((a, s, mesh, flag))

    print(f"{len(cells)} cells to run ({len(done)} already done)",
          flush=True)
    for i, (a, s, mesh, flag) in enumerate(cells):
        t0 = time.time()
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", a, "--shape", s, "--jsonl", args.jsonl] + flag
        try:
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
            status = "ok" if r.returncode == 0 else "err"
            if r.returncode != 0:
                with open(args.jsonl, "a") as f:
                    f.write(json.dumps(dict(
                        arch=a, shape=s, mesh=mesh, status="error",
                        error=f"rc={r.returncode}",
                        stderr=r.stderr[-1500:])) + "\n")
        except subprocess.TimeoutExpired:
            status = "timeout"
            with open(args.jsonl, "a") as f:
                f.write(json.dumps(dict(
                    arch=a, shape=s, mesh=mesh, status="error",
                    error=f"timeout {args.timeout}s")) + "\n")
        print(f"[{i+1}/{len(cells)}] {a} {s} {mesh}: {status} "
              f"({time.time()-t0:.0f}s)", flush=True)


if __name__ == "__main__":
    main()
