import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh)
cell lowers AND compiles under the production meshes, and extract the
roofline terms from the compiled artifact.

The two lines above MUST stay the first statements in this module — jax
locks the device count on first init, and the dry-run needs 512 host
placeholder devices (single-pod cells use the first 256).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch mistral-nemo-12b \
        --shape train_4k [--multi-pod] [--jsonl out.jsonl]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--jsonl out.jsonl]

Per cell this records: lower+compile success, XLA cost_analysis (FLOPs /
bytes), memory_analysis, per-collective byte totals parsed from the
post-optimization HLO, analytic per-device state bytes, and the roofline
terms vs TPU v5e constants (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI).
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, SHAPES, applicable, get_config
from repro.core.accel import TPU_V5E
from repro.launch import specs as specs_lib
from repro.launch.mesh import batch_axes_of, make_production_mesh
from repro.launch.xla_compat import xla_cost_analysis
from repro.launch.steps import (build_prefill_step, build_serve_step,
                                build_train_step)
from repro.models import sharding as shard_ctx
from repro.models.model import Model
from repro.optim import optimizer as opt_lib

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2,
                "s16": 2, "u16": 2, "f32": 4, "s32": 4, "u32": 4,
                "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
                "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo: str) -> Dict[str, float]:
    """Sum operand bytes of every collective op in the post-optimization
    (per-device) HLO.  `-start` variants are counted; `-done` are not."""
    out = {k: 0.0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo.splitlines():
        m = re.search(r"=\s+\S+\s+([a-z-]+)\(", line)
        if not m:
            continue
        op = m.group(1)
        base = op[:-6] if op.endswith("-start") else op
        if base not in _COLLECTIVES:
            continue
        if op.endswith("-done"):
            continue
        # operand types appear inline inside the call parens
        inside = line[line.index(op) + len(op):]
        total = 0
        for dt, dims in _SHAPE_RE.findall(inside):
            if dt in _DTYPE_BYTES:
                total += _shape_bytes(dt, dims)
        out[base] += float(total)
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def _tree_device_bytes(shapes, shardings, mesh) -> float:
    """Analytic per-device bytes of a sharded pytree."""
    total = 0.0
    for leaf, sh in zip(jax.tree.leaves(shapes), jax.tree.leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec"))):
        n = np.prod(leaf.shape) * jnp.dtype(leaf.dtype).itemsize
        div = 1
        spec = getattr(sh, "spec", None)
        if spec is not None:
            for part in spec:
                if part is None:
                    continue
                for ax in (part if isinstance(part, tuple) else (part,)):
                    div *= mesh.shape[ax]
        total += n / div
    return float(total)


def run_cell(arch: str, shape: str, multi_pod: bool,
             arch_cfg=None, tag: str = "") -> Dict[str, Any]:
    cfg = arch_cfg or get_config(arch)
    sh = SHAPES[shape]
    rec: Dict[str, Any] = dict(
        arch=arch, shape=shape, mesh="2x16x16" if multi_pod else "16x16",
        kind=sh.kind, seq_len=sh.seq_len, global_batch=sh.global_batch,
        tag=tag)
    ok, why = applicable(arch, shape)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    shard_ctx.set_batch_axes(batch_axes_of(mesh))
    model = Model(cfg)
    try:
        with mesh:
            if sh.kind == "train":
                ocfg = opt_lib.OptConfig(
                    moment_dtype="bfloat16" if cfg.param_count() > 1e11
                    else "float32")
                step = build_train_step(model, ocfg)
                pshapes, psh, oshapes, osh = \
                    specs_lib.param_and_opt_shardings(model, mesh, ocfg)
                bshapes = specs_lib.batch_spec(cfg, sh)
                bsh = specs_lib.batch_shardings(cfg, sh, mesh)
                lowered = jax.jit(
                    step, in_shardings=(psh, osh, bsh),
                    out_shardings=(psh, osh, None),
                    donate_argnums=(0, 1)).lower(pshapes, oshapes, bshapes)
                state_bytes = (_tree_device_bytes(pshapes, psh, mesh) +
                               _tree_device_bytes(oshapes, osh, mesh))
            elif sh.kind == "prefill":
                step = build_prefill_step(model)
                pshapes, psh, _, _ = specs_lib.param_and_opt_shardings(
                    model, mesh)
                bshapes = specs_lib.batch_spec(cfg, sh)
                bsh = specs_lib.batch_shardings(cfg, sh, mesh)
                from jax.sharding import NamedSharding, PartitionSpec as P
                ba = batch_axes_of(mesh)
                lead = ba if len(ba) > 1 else ba[0]
                out_sh = NamedSharding(mesh, P(lead, None, "model"))
                lowered = jax.jit(
                    step, in_shardings=(psh, bsh),
                    out_shardings=out_sh).lower(pshapes, bshapes)
                state_bytes = _tree_device_bytes(pshapes, psh, mesh)
            else:   # decode / long_decode
                step = build_serve_step(model)
                pshapes, psh, _, _ = specs_lib.param_and_opt_shardings(
                    model, mesh)
                cshapes, tok_shape, pos_shape = specs_lib.decode_inputs(
                    cfg, sh, model)
                csh, tok_sh, pos_sh = specs_lib.decode_shardings(
                    cfg, sh, mesh, model)
                lowered = jax.jit(
                    step, in_shardings=(psh, csh, tok_sh, pos_sh),
                    out_shardings=(None, csh),
                    donate_argnums=(1,)).lower(
                        pshapes, cshapes, tok_shape, pos_shape)
                state_bytes = (_tree_device_bytes(pshapes, psh, mesh) +
                               _tree_device_bytes(cshapes, csh, mesh))
            rec["lower_s"] = round(time.time() - t0, 1)

            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 1)

            ca = xla_cost_analysis(compiled)
            # raw XLA numbers (NOTE: while-loop bodies counted ONCE —
            # see hlo_analysis docstring; kept for reference)
            rec["xla_flops_raw"] = float(ca.get("flops", 0.0))
            rec["xla_bytes_raw"] = float(ca.get("bytes accessed", 0.0))
            try:
                ma = compiled.memory_analysis()
                if ma is not None:
                    for f in ("argument_size_in_bytes",
                              "output_size_in_bytes",
                              "temp_size_in_bytes",
                              "generated_code_size_in_bytes"):
                        v = getattr(ma, f, None)
                        if v is not None:
                            rec[f] = int(v)
            except Exception as e:          # CPU backend may not support
                rec["memory_analysis_error"] = str(e)
            hlo = compiled.as_text()
            rec["hlo_bytes"] = len(hlo)
            # trip-count-corrected per-device analysis
            from repro.launch.hlo_analysis import analyze
            ha = analyze(hlo)
            rec["flops_per_device"] = ha["dot_flops"]
            rec["bytes_per_device"] = ha["traffic_bytes"]
            for k in _COLLECTIVES:
                rec[f"coll_{k}"] = ha[f"coll_{k}"]
            rec["coll_count"] = ha["coll_count"]
            rec["coll_total"] = ha["coll_total"]
            rec["state_bytes_per_device"] = state_bytes

            # roofline terms (per-chip program vs per-chip peaks)
            rec["t_compute_s"] = rec["flops_per_device"] / \
                TPU_V5E["peak_bf16_flops"]
            rec["t_memory_s"] = rec["bytes_per_device"] / \
                TPU_V5E["hbm_bw_bytes_per_s"]
            rec["t_collective_s"] = rec["coll_total"] / \
                TPU_V5E["ici_link_bw_bytes_per_s"]
            terms = dict(compute=rec["t_compute_s"],
                         memory=rec["t_memory_s"],
                         collective=rec["t_collective_s"])
            rec["bottleneck"] = max(terms, key=terms.get)

            # model flops (6*N*D) for the useful-compute ratio
            n_chips = int(np.prod(list(mesh.shape.values())))
            n_act = cfg.active_param_count()
            if sh.kind == "train":
                tokens = sh.global_batch * sh.seq_len
                mf = 6.0 * n_act * tokens
            elif sh.kind == "prefill":
                tokens = sh.global_batch * sh.seq_len
                mf = 2.0 * n_act * tokens
            else:
                tokens = sh.global_batch
                mf = 2.0 * n_act * tokens
            rec["model_flops_total"] = mf
            hlo_total = rec["flops_per_device"] * n_chips
            rec["useful_flops_ratio"] = (mf / hlo_total) if hlo_total else 0.0
            rec["status"] = "ok"
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    finally:
        shard_ctx.set_batch_axes(None)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every applicable cell on both meshes")
    ap.add_argument("--jsonl", default=None, help="append records here")
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for a in sorted(ARCHS):
            for s in sorted(SHAPES):
                cells.append((a, s, False))
                cells.append((a, s, True))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells.append((args.arch, args.shape, args.multi_pod))

    rc = 0
    for a, s, mp in cells:
        rec = run_cell(a, s, mp)
        line = json.dumps({k: v for k, v in rec.items()
                           if k != "traceback"})
        print(line, flush=True)
        if rec["status"] == "error":
            print(rec.get("traceback", ""), file=sys.stderr)
            rc = 1
        if args.jsonl:
            with open(args.jsonl, "a") as f:
                f.write(json.dumps(rec) + "\n")
    return rc


if __name__ == "__main__":
    sys.exit(main())
