"""JAX version-compat shims for compiled-artifact introspection.

``Compiled.cost_analysis()`` has changed return type across JAX releases:

* some versions return a single ``dict`` of metric -> value,
* JAX 0.4.x returns a ``list`` of per-program dicts (usually length 1),
* backends without cost-analysis support return ``None`` (or raise).

:func:`xla_cost_analysis` normalizes all three to one flat dict so
callers can do ``xla_cost_analysis(compiled).get("flops", 0.0)``
unconditionally.  See COMPAT.md for the repo-wide version policy.
"""
from __future__ import annotations

from typing import Any, Dict


def normalize_cost_analysis(ca: Any) -> Dict[str, float]:
    """Normalize a raw ``cost_analysis()`` return value (dict /
    list-of-dicts / None) to one dict.  Numeric values appearing in
    several per-program dicts are summed (program costs are additive);
    non-numeric values keep the first occurrence."""
    if ca is None:
        return {}
    if isinstance(ca, dict):
        return dict(ca)
    out: Dict[str, float] = {}
    for entry in ca:
        if not isinstance(entry, dict):
            continue
        for k, v in entry.items():
            if k in out and isinstance(v, (int, float)) \
                    and isinstance(out[k], (int, float)):
                out[k] += v
            elif k not in out:
                out[k] = v
    return out


def xla_cost_analysis(compiled: Any) -> Dict[str, float]:
    """``compiled.cost_analysis()`` across JAX versions -> one flat dict.
    Returns {} when the backend offers no cost analysis."""
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    return normalize_cost_analysis(ca)
