"""Serving entry point: decode-serving and sweep-serving behind one CLI.

Two modes, dispatched on the first argument:

* ``decode`` — the batched LLM serving driver: prefill + greedy decode
  loop with KV cache over synthetic prompts; reports tokens/s and
  validates the cache path end to end.

      PYTHONPATH=src python -m repro.launch.serve decode \
          --arch gemma3-12b --smoke --batch 4 --prompt-len 64 --gen 32

* ``sweep`` — the persistent sweep server
  (:mod:`repro.launch.sweep_serve`): accepts streaming (workload, arch,
  density, method, budget) queries over a local socket, coalesces
  same-signature queries into shared mega-batch rounds, streams
  best-so-far results, checkpoints populations and survives crashes.

      PYTHONPATH=src python -m repro.launch.serve sweep \
          --port 7333 --checkpoint-dir /tmp/sweeps

Bare legacy flags (no mode word) keep selecting decode — existing
scripts and tests predate the sweep mode.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

MODES = ("decode", "sweep")

_USAGE = """\
usage: python -m repro.launch.serve <mode> [mode options]

modes:
  decode   batched LLM serving driver (prefill + greedy decode loop);
           options: --arch --smoke --batch --prompt-len --gen
  sweep    persistent accelerator-search sweep server (query coalescing,
           checkpointed populations, crash recovery); options: --host
           --port --checkpoint-dir --checkpoint-every --max-restarts
           --no-warm-start --device-rounds --no-stack

`<mode> --help` shows that mode's full options.  Legacy invocations with
bare flags (no mode word) run decode.
"""


def main(argv=None):
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] in MODES:
        if argv[0] == "sweep":
            from . import sweep_serve
            return sweep_serve.main(argv[1:])
        return decode_main(argv[1:])
    if argv[:1] in (["-h"], ["--help"]):
        print(_USAGE)
        return 0
    return decode_main(argv)        # legacy: bare flags mean decode


def decode_main(argv=None):
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.serve decode",
        description="Batched serving driver: prefill + greedy decode "
                    "loop with KV cache over synthetic prompts; reports "
                    "tokens/s.")
    ap.add_argument("--arch", default="gemma3-12b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, smoke_config
    from repro.models.model import Model

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b, pl_, g = args.batch, args.prompt_len, args.gen
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, pl_)),
                          jnp.int32)

    max_len = pl_ + g + 1
    if cfg.n_enc_layers:
        enc = jnp.asarray(rng.standard_normal((b, pl_, cfg.d_model)),
                          jnp.bfloat16) * 0.02
        cache = model.init_cache(b, max_len, params=params,
                                 enc_embeds=enc)
    else:
        cache = model.init_cache(b, max_len)

    decode = jax.jit(model.decode_step, donate_argnums=(1,))

    # prefill by stepping the prompt through decode (correct though not
    # the fast path; prefill_32k lowers the batched forward instead)
    t0 = time.time()
    tok = prompts[:, 0:1]
    for i in range(pl_):
        logits, cache = decode(params, cache, tok, jnp.int32(i))
        tok = prompts[:, i + 1:i + 2] if i + 1 < pl_ else \
            jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    prefill_s = time.time() - t0

    t0 = time.time()
    out_tokens = []
    for i in range(g):
        logits, cache = decode(params, cache, tok, jnp.int32(pl_ + i))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out_tokens.append(np.asarray(tok)[:, 0])
    decode_s = time.time() - t0
    gen = np.stack(out_tokens, axis=1)

    print(f"arch={cfg.name} batch={b} prompt={pl_} gen={g}")
    print(f"prefill: {pl_ * b / max(prefill_s, 1e-9):.1f} tok/s   "
          f"decode: {g * b / max(decode_s, 1e-9):.1f} tok/s")
    print(f"first generated rows: {gen[:2, :8].tolist()}")
    assert gen.shape == (b, g)
    assert (gen >= 0).all() and (gen < cfg.vocab_size).all()
    print("serve ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
