"""Production mesh construction.

Single pod: (16, 16) over ("data", "model") — 256 chips (TPU v5e pod).
Multi-pod:  (2, 16, 16) over ("pod", "data", "model") — 512 chips.

Defined as FUNCTIONS so importing this module never touches jax device
state; `launch/dryrun.py` sets ``XLA_FLAGS=--xla_force_host_platform_
device_count=...`` before any jax import.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"before importing jax (see launch/dryrun.py)")
    # more devices than needed (e.g. 512 forced, single-pod mesh): subset
    from jax.sharding import Mesh
    arr = np.asarray(devices[:n]).reshape(shape)
    return Mesh(arr, axes)


def make_test_mesh(shape: Tuple[int, ...] = (2, 2),
                   axes: Tuple[str, ...] = ("data", "model")):
    """Small mesh for CI sharding tests (requires forced host devices)."""
    import jax
    from jax.sharding import Mesh
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_search_mesh(n_devices: Optional[int] = None, axis: str = "rows"):
    """1-D mesh for sharding search mega-batches / segment fleets
    (``jax_cost.eval_stacked`` shards batch rows, ``run_segments`` shards
    the task axis).  Uses every visible device by default; returns None
    on a single device so callers can pass the result straight to
    ``MultiSearch(mesh=...)`` and keep the bit-identical fast path."""
    import jax
    from jax.sharding import Mesh
    devices = jax.devices()
    n = len(devices) if n_devices is None else int(n_devices)
    if n > len(devices):
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    if n <= 1:
        return None
    return Mesh(np.asarray(devices[:n]).reshape(n), (axis,))


_BATCH_AXES_OVERRIDE: Optional[Tuple[str, ...]] = None


def set_batch_axes_override(axes: Optional[Tuple[str, ...]]) -> None:
    """Perf variant hook: e.g. ("data", "model") = pure data parallelism
    over the whole mesh (TP disabled) for small models."""
    global _BATCH_AXES_OVERRIDE
    _BATCH_AXES_OVERRIDE = tuple(axes) if axes else None


def batch_axes_of(mesh) -> Tuple[str, ...]:
    if _BATCH_AXES_OVERRIDE is not None:
        return tuple(a for a in _BATCH_AXES_OVERRIDE
                     if a in mesh.axis_names)
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
