"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

``input_specs`` builds weak-type-correct, shardable abstract values for
every model input — no device allocation ever happens in the dry-run.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.shapes import ShapeSpec
from repro.models.config import ModelConfig
from repro.models.model import Model
from repro.optim import optimizer as opt_lib

from .mesh import batch_axes_of


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_spec(cfg: ModelConfig, sh: ShapeSpec) -> Dict[str, Any]:
    """Abstract training/prefill batch for one shape cell."""
    b, s = sh.global_batch, sh.seq_len
    s_text = s - (cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)
    out = dict(tokens=_sds((b, s_text), jnp.int32),
               labels=_sds((b, s_text), jnp.int32))
    if cfg.frontend == "vision":
        out["frontend"] = _sds((b, cfg.n_frontend_tokens, cfg.d_model),
                               jnp.bfloat16)
    if cfg.frontend == "audio":
        out["enc_embeds"] = _sds((b, s, cfg.d_model), jnp.bfloat16)
    return out


def batch_shardings(cfg: ModelConfig, sh: ShapeSpec, mesh
                    ) -> Dict[str, Any]:
    ba = batch_axes_of(mesh)
    b = sh.global_batch
    n_b = 1
    for a in ba:
        n_b *= mesh.shape[a]
    lead = (ba if len(ba) > 1 else ba[0]) if b % n_b == 0 else None
    out = dict(tokens=NamedSharding(mesh, P(lead, None)),
               labels=NamedSharding(mesh, P(lead, None)))
    if cfg.frontend == "vision":
        out["frontend"] = NamedSharding(mesh, P(lead, None, None))
    if cfg.frontend == "audio":
        out["enc_embeds"] = NamedSharding(mesh, P(lead, None, None))
    return out


def decode_inputs(cfg: ModelConfig, sh: ShapeSpec, model: Model
                  ) -> Tuple[Any, Any, Any]:
    """(cache_shapes, tokens_shape, pos_shape) abstract values."""
    b, s = sh.global_batch, sh.seq_len
    if cfg.n_enc_layers:
        params_sh = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        enc = _sds((b, min(s, 4096), cfg.d_model), jnp.bfloat16)
        cache = jax.eval_shape(
            lambda p, e: model.init_cache(b, s, params=p, enc_embeds=e),
            params_sh, enc)
    else:
        cache = jax.eval_shape(lambda: model.init_cache(b, s))
    return cache, _sds((b, 1), jnp.int32), _sds((), jnp.int32)


def decode_shardings(cfg: ModelConfig, sh: ShapeSpec, mesh, model: Model):
    """(cache_shardings, token_sharding, pos_sharding)."""
    ba = batch_axes_of(mesh)
    b = sh.global_batch
    n_b = 1
    for a in ba:
        n_b *= mesh.shape[a]
    batch_shard = ba if b % n_b == 0 else ()
    # sequence axis of KV caches: long-context decode spreads the cache
    # over every axis not used by the batch
    if sh.kind == "long_decode":
        seq_shard = tuple(ba) + ("model",) if not batch_shard else ("model",)
    else:
        seq_shard = ("model",) if sh.seq_len % mesh.shape["model"] == 0 \
            else ()
    cache_specs = model.cache_specs(batch_shard=batch_shard,
                                    seq_shard=seq_shard)
    cache_sh = jax.tree.map(
        lambda spec: NamedSharding(mesh, spec), cache_specs,
        is_leaf=lambda x: isinstance(x, P))
    lead = (tuple(ba) if len(ba) > 1 else ba[0]) if batch_shard else None
    tok = NamedSharding(mesh, P(lead, None))
    pos = NamedSharding(mesh, P())
    return cache_sh, tok, pos


def param_and_opt_shardings(model: Model, mesh, ocfg=None):
    """(param_shapes, param_shardings, opt_shapes, opt_shardings)."""
    pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = model.param_specs()
    psh = jax.tree.map(lambda spec: NamedSharding(mesh, spec), pspecs,
                       is_leaf=lambda x: isinstance(x, P))
    oshapes = osh = None
    if ocfg is not None:
        oshapes = jax.eval_shape(lambda p: opt_lib.init(p, ocfg), pshapes)
        shape_tree = jax.tree.map(lambda s: s.shape, pshapes)
        ospecs = opt_lib.opt_state_specs(pspecs, shape_tree,
                                         data_size=mesh.shape["data"])
        osh = opt_lib.OptState(
            step=NamedSharding(mesh, P()),
            mu=jax.tree.map(lambda spec: NamedSharding(mesh, spec),
                            ospecs.mu, is_leaf=lambda x: isinstance(x, P)),
            nu=jax.tree.map(lambda spec: NamedSharding(mesh, spec),
                            ospecs.nu, is_leaf=lambda x: isinstance(x, P)))
    return pshapes, psh, oshapes, osh
