"""Post-optimization HLO analysis with while-loop trip-count correction.

``compiled.cost_analysis()`` counts each while-loop body ONCE regardless
of trip count (verified experimentally — see EXPERIMENTS.md §Dry-run), so
scan-over-layers models are undercounted by ~n_layers and sequential
recurrences by ~seq_len.  This module re-derives the roofline quantities
from ``compiled.as_text()`` directly:

* **dot FLOPs** — every ``dot`` op contributes 2 * prod(result_shape) *
  prod(contracting dims of the lhs operand); operand shapes come from a
  per-computation symbol table.  Dots inside fusion computations count.
* **traffic bytes** — an HBM-traffic estimate per *executed* op:
  result + operand bytes, with slice-aware rules — dynamic-slice /
  gather / slice count the slice (result), dynamic-update-slice /
  scatter count the update, and a fusion's operand counts only what the
  fused computation actually reads of it (a parameter consumed only by
  slicing ops counts its slices, not the whole array).  Ops inside
  fusion computations contribute NO traffic (they are fused); ops inside
  while bodies contribute traffic x trip_count.
* **collective bytes** — operand bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute (+ ``-start`` forms).

The call graph is expanded from ENTRY with multipliers: while bodies x
known_trip_count (parsed from backend_config), conditional branches and
fusions x 1.  Validated against cost_analysis on scan-free graphs in
tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
                "f32": 4, "s32": 4, "u32": 4,
                "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
                "f8e4m3fn": 1, "f8e5m2": 1, "token": 0}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OPLINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")
_TRIP_RE = re.compile(r'known_trip_count[\\":{ n]+(\d+)')

# ops that read only a slice of their (first) operand
_SLICING = {"dynamic-slice", "slice", "gather"}
# ops that write only the update portion
_UPDATING = {"dynamic-update-slice", "scatter"}
# pure plumbing: no executed traffic.  ``copy`` is excluded too: XLA-CPU
# inserts full copies of while-carried buffers that are aliased in-place
# on real hardware.
_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "partition-id", "replica-id", "iota",
             "while", "conditional", "copy", "copy-start", "copy-done"}
_FUSED_CALLERS = {"fusion", "map", "reduce", "reduce-window", "scatter",
                  "sort", "select-and-scatter", "custom-call"}


def _shape_list(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _bytes_of(shapes) -> float:
    total = 0.0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    result: List[Tuple[str, List[int]]]
    operands: List[str]
    line: str


@dataclasses.dataclass
class Comp:
    name: str
    ops: List[Op] = dataclasses.field(default_factory=list)
    syms: Dict[str, List[Tuple[str, List[int]]]] = \
        dataclasses.field(default_factory=dict)


def _parse(hlo: str) -> Tuple[Dict[str, Comp], Optional[str]]:
    comps: Dict[str, Comp] = {}
    cur: Optional[Comp] = None
    entry = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line or line.startswith("HloModule"):
            continue
        hdr = _COMP_HDR_RE.match(line)
        if hdr and "{" in line:
            cur = comps.setdefault(hdr.group(1), Comp(hdr.group(1)))
            if line.startswith("ENTRY"):
                entry = hdr.group(1)
            continue
        if cur is None:
            continue
        m = _OPLINE_RE.match(line)
        if not m:
            continue
        opname, result_txt, opcode, rest = m.groups()
        result = _shape_list(result_txt)
        operands = re.findall(r"%([\w.\-]+)", rest.split("),")[0])
        op = Op(opname, opcode, result, operands, line)
        cur.ops.append(op)
        cur.syms[opname] = result
    return comps, entry


def _fusion_input_bytes(comp: Comp) -> float:
    """Effective bytes a fused computation reads from its parameters:
    a parameter consumed only by slicing ops counts its slices' bytes; a
    parameter that is only the in-place TARGET of dynamic-update-slice
    ops is written through, not read."""
    total = 0.0
    params = [op for op in comp.ops if op.opcode == "parameter"]
    for p in params:
        consumers = [op for op in comp.ops if p.name in op.operands]
        if consumers and all(
                c.opcode in _SLICING and c.operands and
                c.operands[0] == p.name for c in consumers):
            total += sum(_bytes_of(c.result) for c in consumers)
        elif consumers and all(
                c.opcode in _UPDATING and c.operands and
                c.operands[0] == p.name for c in consumers):
            pass        # pure in-place update target: no read traffic
        else:
            total += _bytes_of(comp.syms.get(p.name, []))
    return total


def _fusion_output_bytes(comp: Comp, result_bytes: float) -> float:
    """A fusion that updates a parameter in place (dynamic-update-slice on
    a parameter) writes only the update slice, not the whole buffer."""
    params = {op.name for op in comp.ops if op.opcode == "parameter"}
    for op in comp.ops:
        if op.opcode in _UPDATING and op.operands and \
                op.operands[0] in params:
            upd = comp.syms.get(op.operands[1], []) \
                if len(op.operands) > 1 else []
            return _bytes_of(upd)
    return result_bytes


def _op_traffic(op: Op, comp: Comp, comps: Dict[str, Comp]) -> float:
    res_b = _bytes_of(op.result)
    if op.opcode in _SLICING:
        return 2.0 * res_b
    if op.opcode in _UPDATING:
        # update operand is the 2nd for DUS, updates last for scatter
        upd = comp.syms.get(op.operands[1], []) if len(op.operands) > 1 \
            else op.result
        return 2.0 * _bytes_of(upd)
    if op.opcode in _FUSED_CALLERS:
        cm = re.search(r"calls=%?([\w.\-]+)", op.line)
        if cm and cm.group(1) in comps:
            called = comps[cm.group(1)]
            return _fusion_output_bytes(called, res_b) + \
                _fusion_input_bytes(called)
    opnd_b = sum(_bytes_of(comp.syms.get(o, [])) for o in op.operands)
    return res_b + opnd_b


def analyze(hlo: str) -> Dict[str, float]:
    comps, entry = _parse(hlo)
    zero = dict(dot_flops=0.0, traffic_bytes=0.0, coll_total=0.0,
                coll_count=0.0,
                **{f"coll_{k}": 0.0 for k in _COLLECTIVES})
    if entry is None:
        return dict(zero)

    memo_flops: Dict[str, Dict[str, float]] = {}

    def flops_of(name: str, depth=0) -> Dict[str, float]:
        """dot flops + collectives, counting nested control flow."""
        if name in memo_flops:
            return memo_flops[name]
        comp = comps.get(name)
        out = dict(zero)
        if comp is None or depth > 64:
            return out
        memo_flops[name] = out
        for op in comp.ops:
            if op.opcode == "dot":
                lhs_dims = []
                cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}",
                               op.line)
                lhs_shape = comp.syms.get(op.operands[0], [])
                if cm and lhs_shape:
                    dims = lhs_shape[0][1]
                    for idx in cm.group(1).split(","):
                        if idx and int(idx) < len(dims):
                            lhs_dims.append(dims[int(idx)])
                k = 1
                for d in lhs_dims:
                    k *= d
                n_out = 1
                for dt, dims in op.result:
                    for d in dims:
                        n_out *= d
                out["dot_flops"] += 2.0 * n_out * k
            base = op.opcode[:-6] if op.opcode.endswith("-start") \
                else op.opcode
            if base in _COLLECTIVES:
                b = sum(_bytes_of(comp.syms.get(o, []))
                        for o in op.operands)
                out[f"coll_{base}"] += b
                out["coll_total"] += b
                out["coll_count"] += 1
            mult, subs = _sub_computations(op)
            for cn in subs:
                sub = flops_of(cn, depth + 1)
                for kk in out:
                    out[kk] += mult * sub[kk]
        return out

    memo_traffic: Dict[str, Tuple[float, float]] = {}

    def traffic_of(name: str, depth=0) -> Tuple[float, float]:
        """(variant_bytes, invariant_bytes) HBM traffic of one execution
        of the computation.  Loop-invariant operands inside while bodies
        (weights pinned in VMEM across a sequential scan) are separated so
        the caller charges them ONCE, not x trip_count."""
        if name in memo_traffic:
            return memo_traffic[name]
        comp = comps.get(name)
        if comp is None or depth > 64:
            return (0.0, 0.0)
        memo_traffic[name] = (0.0, 0.0)
        invariant_gtes = _invariant_gtes(comp)
        var_b = 0.0
        inv_b = 0.0
        for op in comp.ops:
            if op.opcode in _SKIP_OPS and op.opcode not in (
                    "while", "conditional"):
                continue
            if op.opcode in ("while", "conditional"):
                mult, subs = _sub_computations(op)
                for cn in subs:
                    v, i = traffic_of(cn, depth + 1)
                    var_b += mult * v + i         # invariants charged once
                continue
            t = _op_traffic(op, comp, comps)
            # split out reads of loop-invariant tuple elements
            inv_here = sum(
                _bytes_of(comp.syms.get(o, []))
                for o in op.operands if o in invariant_gtes)
            inv_here = min(inv_here, t)
            var_b += t - inv_here
            inv_b += inv_here
        memo_traffic[name] = (var_b, inv_b)
        return (var_b, inv_b)

    out = flops_of(entry)
    v, i = traffic_of(entry)
    out["traffic_bytes"] = v + i
    return out


def _invariant_gtes(comp: Comp) -> set:
    """Names of get-tuple-element ops on the computation's parameter whose
    tuple slot is passed through unchanged to the ROOT tuple — i.e.
    loop-invariant state of a while body."""
    root = None
    for op in comp.ops:
        if op.opcode == "tuple" and "ROOT" in op.line:
            root = op
    if root is None:
        return set()
    params = {op.name for op in comp.ops if op.opcode == "parameter"}
    gte_idx = {}
    for op in comp.ops:
        if op.opcode == "get-tuple-element" and op.operands and \
                op.operands[0] in params:
            m = re.search(r"index=(\d+)", op.line)
            if m:
                gte_idx[op.name] = int(m.group(1))
    out = set()
    for pos, oname in enumerate(root.operands):
        if gte_idx.get(oname) == pos:
            out.add(oname)
    return out


def _sub_computations(op: Op) -> Tuple[float, List[str]]:
    """(multiplier, called computations) for control-flow ops only."""
    if op.opcode == "while":
        tm = _TRIP_RE.search(op.line)
        mult = float(tm.group(1)) if tm else 1.0
        subs = []
        bm = re.search(r"body=%?([\w.\-]+)", op.line)
        if bm:
            subs.append(bm.group(1))
        return mult, subs
    if op.opcode == "conditional":
        bm = re.search(r"branch_computations=\{([^}]*)\}", op.line)
        if bm:
            return 1.0, [c.strip().lstrip("%")
                         for c in bm.group(1).split(",")]
        return 1.0, []
    if op.opcode in _FUSED_CALLERS or op.opcode == "call":
        cm = re.search(r"(?:calls=|to_apply=)%?([\w.\-]+)", op.line)
        if cm:
            return 1.0, [cm.group(1)]
    return 1.0, []
