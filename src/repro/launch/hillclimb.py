import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimb for the three selected cells (EXPERIMENTS.md §Perf).

Each variant re-lowers + recompiles the cell with one change and records
the roofline terms next to the baseline (tag field distinguishes them).

    PYTHONPATH=src python -m repro.launch.hillclimb --jsonl bench_out/perf.jsonl

Cells and hypotheses (napkin math in EXPERIMENTS.md):
  kimi-k2-1t-a32b x train_4k    collective-bound (232 s)
      v1 moe_grouped: group-local dispatch -> a2a instead of full-buffer
         materialization; predicted ~20-70x collective reduction
      v2 moe_grouped + remat dots: trade recompute for stored dots
  command-r-35b x train_4k      memory-bound (22.8 s)
      v1 remat dots: stop recomputing matmuls (traffic + flops down)
      v2 dots + dmodel-sharded embedding (kill gather resharding)
  xlstm-350m x train_4k         worst roofline fraction (0.03)
      v1 pure-DP mapping: TP=16 for a 350M model is the wrong mapping —
         replicate params, shard batch over all 256 chips
"""
import argparse
import dataclasses
import json
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", default="bench_out/perf.jsonl")
    ap.add_argument("--only", default=None,
                    help="comma list: kimi,commandr,xlstm")
    args = ap.parse_args(argv)

    from repro.configs import get_config
    from repro.launch import dryrun
    from repro.launch.mesh import set_batch_axes_override
    from repro.models import blocks

    only = set(args.only.split(",")) if args.only else None

    def emit(rec):
        keep = {k: v for k, v in rec.items() if k != "traceback"}
        print(json.dumps(keep), flush=True)
        with open(args.jsonl, "a") as f:
            f.write(json.dumps(keep) + "\n")
        if rec.get("status") != "ok":
            print(rec.get("traceback", "")[-1500:], file=sys.stderr)

    def run(arch, shape, tag, cfg=None, pure_dp=False):
        if pure_dp:
            blocks.set_tp_enabled(False)
            set_batch_axes_override(("data", "model"))
        try:
            rec = dryrun.run_cell(arch, shape, multi_pod=False,
                                  arch_cfg=cfg, tag=tag)
        finally:
            blocks.set_tp_enabled(True)
            set_batch_axes_override(None)
        emit(rec)
        return rec

    if only is None or "kimi" in only:
        base = get_config("kimi-k2-1t-a32b")
        run("kimi-k2-1t-a32b", "train_4k", "v1_moe_grouped",
            cfg=dataclasses.replace(base, moe_grouped=True,
                                    moe_n_groups=256))
        run("kimi-k2-1t-a32b", "train_4k", "v2_grouped_dots",
            cfg=dataclasses.replace(base, moe_grouped=True,
                                    moe_n_groups=256, remat="dots"))

    if only is None or "commandr" in only:
        base = get_config("command-r-35b")
        run("command-r-35b", "train_4k", "v1_remat_dots",
            cfg=dataclasses.replace(base, remat="dots"))
        run("command-r-35b", "train_4k", "v2_dots_embed_dmodel",
            cfg=dataclasses.replace(base, remat="dots",
                                    embed_shard="dmodel"))

    if only is None or "xlstm" in only:
        run("xlstm-350m", "train_4k", "v1_pure_dp", pure_dp=True)


if __name__ == "__main__":
    main()
