"""Search-as-a-service: the persistent sweep server.

A long-lived process that accepts streaming (workload, arch, density,
method, budget) queries over a local TCP socket, admits them MID-FLIGHT
into one running ``MultiSearch`` fleet — same-signature queries from
different clients coalesce into one mega-batch round, so the marginal
cost of one more query is rows in an already-dispatched batch — and
streams best-so-far (genome, EDP, round) updates back per client.

    PYTHONPATH=src python -m repro.launch.serve sweep --port 7333 \
        --checkpoint-dir /tmp/sweeps
    PYTHONPATH=src python examples/sweep_client.py --port 7333 \
        --arch cloud --m 256 --k 256 --n 256 --density 0.3,0.4

Wire protocol (JSON lines; COMPAT.md "Sweep server protocol"): a query
is exactly a serialized ``SearchTask`` (``SearchTask.to_json_dict``)
plus an optional ``FleetConfig`` fragment that must agree with the
server's; replies are ``{"ok": ...}`` then ``{"event": "update"|"done",
...}`` lines.  Bad arch names come back with ``UnknownArchError``'s
close-match hints instead of killing the server.

Durability: with ``--checkpoint-dir``, live populations checkpoint every
k fleet rounds (``checkpoint.save_flat`` — atomic staging-dir commit)
from the ``state_out`` captures the ES generators refresh at the top of
every generation, and a crashed worker (or a fresh server process
pointed at the same directory) restores from the latest checkpoint with
BIT-IDENTICAL resume at fixed seeds: the resumed trajectory equals the
uninterrupted one (pinned in tests/test_sweep_serve.py).  Checkpointing
requires the fleet to resolve ``device_rounds == 1`` — scan segments
keep populations device-resident with no generation-boundary capture.

Completed queries feed a content-keyed :class:`GenomeLibrary` of best
genomes keyed on (workload cache-key, topology fingerprint, density
mode); a later query with the same key warm-starts from the library
winner as seeded initial-population rows.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import socket
import socketserver
import threading
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint import checkpoint as ckpt_lib
from repro.core import jax_cost
from repro.core.arch import UnknownArchError, as_arch
from repro.core.baselines import RESUMABLE_METHODS, WARM_START_METHODS
from repro.core.evolution import snapshot_tracker_hist
from repro.core.search import (FleetConfig, MultiSearch, SearchTask,
                               SearchResult)
from repro.core.sensitivity import SensitivityResult
from repro.runtime.fault_tolerance import Supervisor


# ---------------------------------------------------------------- library


def library_key(task: SearchTask) -> Tuple:
    """The warm-start content key: (workload cache-key, topology
    fingerprint, density mode).  Content-derived — two clients that
    serialize the same query land on the same key — and alignment-free,
    so a library entry recorded under one fleet composition warm-starts
    the same query under any other (genome length depends only on
    (workload, topology), never on fleet padding)."""
    arch = as_arch(task.platform)
    mode = "structured" if task.workload.structured_density else "uniform"
    return (task.workload.cache_key(), arch.topology.fingerprint, mode)


class GenomeLibrary:
    """Content-keyed best-genome store feeding warm starts.  Thread-safe;
    keeps the single lowest-EDP genome per key."""

    def __init__(self):
        self._best: Dict[Tuple, Tuple[float, np.ndarray]] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def record(self, task: SearchTask, result: SearchResult) -> None:
        if result.best_genome is None or not np.isfinite(result.best_edp):
            return
        key = library_key(task)
        with self._lock:
            prev = self._best.get(key)
            if prev is None or result.best_edp < prev[0]:
                self._best[key] = (float(result.best_edp),
                                   np.asarray(result.best_genome,
                                              dtype=np.int64).copy())

    def lookup(self, task: SearchTask) -> Optional[np.ndarray]:
        """Warm rows for a query, or None.  Counts hit/miss (only called
        for warm-eligible methods, so the ratio is meaningful)."""
        key = library_key(task)
        with self._lock:
            entry = self._best.get(key)
            if entry is None:
                self.misses += 1
                return None
            self.hits += 1
            return entry[1][None, :].copy()

    def snapshot(self) -> Dict:
        with self._lock:
            return dict(hits=self.hits, misses=self.misses,
                        size=len(self._best))


# ------------------------------------------------- fleet state packing


def pack_fleet(ms: MultiSearch) -> Tuple[Dict[str, np.ndarray], Dict]:
    """Flatten a running fleet into (arrays, meta) for
    ``checkpoint.save_flat``.  Live resumable tasks (ES family,
    ``state_out`` captured) pack their pre-draw generation state;
    everything else is recorded as task JSON only and restarts from
    scratch on restore — still bit-identical at fixed seeds, since every
    task's trajectory is row-deterministic regardless of fleet
    composition (mega-batch stacking is bit-exact per row)."""
    name2task = dict(zip(ms.final_names, ms.tasks))
    arrays: Dict[str, np.ndarray] = {}
    entries: List[Dict] = []
    for st in ms._states:
        if st.extras is not None:
            continue                    # retired: already streamed out
        task = name2task[st.name]
        entry = task.to_json_dict()
        entry["_name"] = st.name
        cap = None
        if task.method in RESUMABLE_METHODS:
            cap = task.runtime_kw.get("state_out", {}).get("resume")
        entry["_resumable"] = cap is not None
        if cap is not None:
            cap = snapshot_tracker_hist(st.tracker, cap)
            t = cap["tracker"]
            pfx = f"t{len(entries):03d}/"
            arrays[pfx + "pop"] = cap["pop"]
            arrays[pfx + "edp"] = cap["edp"]
            arrays[pfx + "ints"] = np.array(
                [cap["gen"], cap["since_improve"], cap["total_gens"],
                 t["evals"], t["valid"]], dtype=np.int64)
            arrays[pfx + "floats"] = np.array(
                [cap["last_best"], t["best"]], dtype=np.float64)
            arrays[pfx + "rng"] = np.frombuffer(
                json.dumps(cap["rng_state"]).encode(), dtype=np.uint8)
            arrays[pfx + "hist"] = np.asarray(t["hist"], dtype=np.float64)
            if t["best_genome"] is not None:
                arrays[pfx + "best_genome"] = t["best_genome"]
            sens = cap["sens"]
            entry["_sens"] = sens is not None
            if sens is not None:
                arrays[pfx + "sens_scores"] = np.asarray(sens.scores)
                arrays[pfx + "sens_mask"] = np.asarray(sens.high_mask)
                arrays[pfx + "sens_pool"] = np.asarray(sens.valid_pool)
                arrays[pfx + "sens_scalars"] = np.array(
                    [float(sens.threshold), float(sens.evals_used)],
                    dtype=np.float64)
        entries.append(entry)
    meta = {"config": ms.config.to_json_dict(), "tasks": entries,
            "round": ms._rounds}
    return arrays, meta


def restore_fleet(arrays: Dict[str, np.ndarray],
                  meta: Dict) -> Optional[MultiSearch]:
    """Rebuild a fleet from a ``pack_fleet`` checkpoint.  Returns None
    when every task had already retired (nothing to resume)."""
    if not meta["tasks"]:
        return None
    tasks = []
    for i, entry in enumerate(meta["tasks"]):
        entry = dict(entry)
        name = entry.pop("_name")
        resumable = entry.pop("_resumable", False)
        has_sens = entry.pop("_sens", False)
        task = SearchTask.from_json(entry)
        task.name = name                 # preserve collision suffixes
        task.runtime_kw["state_out"] = {}
        if resumable:
            pfx = f"t{i:03d}/"
            ints = arrays[pfx + "ints"]
            floats = arrays[pfx + "floats"]
            sens = None
            if has_sens:
                ss = arrays[pfx + "sens_scalars"]
                sens = SensitivityResult(
                    scores=arrays[pfx + "sens_scores"],
                    high_mask=arrays[pfx + "sens_mask"],
                    valid_pool=arrays[pfx + "sens_pool"],
                    threshold=float(ss[0]), evals_used=int(ss[1]))
            bg = arrays.get(pfx + "best_genome")
            task.runtime_kw["resume_state"] = dict(
                rng_state=json.loads(
                    arrays[pfx + "rng"].tobytes().decode()),
                pop=arrays[pfx + "pop"], edp=arrays[pfx + "edp"],
                gen=int(ints[0]), since_improve=int(ints[1]),
                total_gens=int(ints[2]), last_best=float(floats[0]),
                sens=sens,
                tracker=dict(
                    evals=int(ints[3]), valid=int(ints[4]),
                    best=float(floats[1]),
                    best_genome=None if bg is None else bg,
                    hist=arrays[pfx + "hist"].tolist()))
        tasks.append(task)
    config = FleetConfig.from_json(meta["config"])
    return MultiSearch(tasks, config)


# ---------------------------------------------------------------- server


class _Pending:
    """One admitted-but-unstarted query: the task plus its client's
    event queue (None for orphans resumed from a checkpoint)."""

    def __init__(self, task: SearchTask, events: Optional["deque"]):
        self.task = task
        self.events = events
        self.name: Optional[str] = None


class SweepServer:
    """The persistent sweep service: a worker thread owns the fleet and
    a ThreadingTCPServer feeds it queries.  See the module docstring for
    the protocol and durability contract."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 config: Optional[FleetConfig] = None,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 8,
                 max_restarts: int = 3, warm_start: bool = True):
        self.config = config if config is not None else \
            FleetConfig(stack_batches=True, device_rounds=1)
        if ckpt_dir is not None:
            resolved, _ = self.config.resolved_device_rounds()
            if resolved != 1:
                raise ValueError(
                    "checkpointing requires device_rounds == 1 (scan "
                    "segments keep populations device-resident with no "
                    "generation-boundary capture); pass device_rounds=1 "
                    "or disable --checkpoint-dir")
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = int(ckpt_every)
        self.max_restarts = int(max_restarts)
        self.warm_start = bool(warm_start)
        self.library = GenomeLibrary()
        self._pending: deque = deque()
        self._cond = threading.Condition()
        self._shutdown = threading.Event()
        self._fleet_lock = threading.Lock()
        self._ms: Optional[MultiSearch] = None
        self._events: Dict[str, deque] = {}
        self._events_lock = threading.Lock()
        self._last_best: Dict[str, float] = {}
        self._stats = dict(queries=0, completed=0, rejected=0, epochs=0,
                           restarts=0, warm_started=0)
        self._last_fleet_stats: Dict = {}
        self._last_groups: Dict[str, int] = {}
        self._epoch_groups: List[Dict[str, int]] = []

        srv = self

        class _Handler(socketserver.StreamRequestHandler):
            def handle(self):
                srv._handle(self)

        class _Server(socketserver.ThreadingTCPServer):
            allow_reuse_address = True
            daemon_threads = True

        self._tcp = _Server((host, port), _Handler)
        self.host, self.port = self._tcp.server_address[:2]
        self._worker = threading.Thread(target=self._worker_loop,
                                        name="sweep-worker", daemon=True)

    # ------------------------------------------------------------ lifecycle

    def serve_forever(self) -> None:
        self._worker.start()
        try:
            self._tcp.serve_forever(poll_interval=0.1)
        finally:
            self.stop()

    def start_background(self) -> None:
        """Start worker + acceptor threads and return (tests)."""
        self._worker.start()
        threading.Thread(target=self._tcp.serve_forever,
                         kwargs=dict(poll_interval=0.1),
                         daemon=True).start()

    def stop(self) -> None:
        if self._shutdown.is_set():
            return
        self._shutdown.set()
        with self._cond:
            self._cond.notify_all()
        self._tcp.shutdown()
        self._tcp.server_close()
        self._worker.join(timeout=30)

    # ------------------------------------------------------------- protocol

    def _handle(self, h: socketserver.StreamRequestHandler) -> None:
        line = h.rfile.readline()
        if not line:
            return
        try:
            msg = json.loads(line.decode())
        except ValueError:
            self._reply(h, {"ok": False, "error": "malformed JSON line"})
            return
        op = msg.get("op")
        if op == "stats":
            self._reply(h, {"ok": True, "stats": self.stats()})
        elif op == "shutdown":
            self._reply(h, {"ok": True, "stopping": True})
            threading.Thread(target=self.stop, daemon=True).start()
        elif op == "submit":
            self._handle_submit(h, msg)
        else:
            self._reply(h, {"ok": False,
                            "error": f"unknown op {op!r}; have "
                                     f"submit / stats / shutdown"})

    def _handle_submit(self, h, msg: Dict) -> None:
        try:
            if "config" in msg and msg["config"] is not None:
                frag = FleetConfig.from_json(msg["config"])
                if frag != self.config:
                    raise ValueError(
                        f"query FleetConfig fragment disagrees with the "
                        f"server's: {frag.to_json()} != "
                        f"{self.config.to_json()}")
            task = SearchTask.from_json(msg["task"])
            as_arch(task.platform)      # validate NOW, not mid-fleet
        except UnknownArchError as e:
            # close-match hints travel to the client; the server lives on
            self._stats["rejected"] += 1
            self._reply(h, {"ok": False, "error": str(e),
                            "unknown_arch": True})
            return
        except (KeyError, ValueError, TypeError) as e:
            self._stats["rejected"] += 1
            self._reply(h, {"ok": False, "error": f"{e}"})
            return
        events: deque = deque()
        pend = _Pending(task, events)
        ready = threading.Event()
        pend.ready = ready
        with self._cond:
            self._stats["queries"] += 1
            self._pending.append(pend)
            self._cond.notify_all()
        ready.wait(timeout=300)
        self._reply(h, {"ok": True, "id": pend.name})
        # stream events until done
        while not self._shutdown.is_set():
            if events:
                ev = events.popleft()
                self._reply(h, ev)
                if ev.get("event") in ("done", "failed"):
                    return
            else:
                with self._cond:
                    self._cond.wait(timeout=0.05)

    @staticmethod
    def _reply(h, obj: Dict) -> None:
        try:
            h.wfile.write((json.dumps(obj) + "\n").encode())
            h.wfile.flush()
        except (BrokenPipeError, ConnectionResetError, OSError):
            pass                        # client went away; fleet lives on

    # --------------------------------------------------------------- worker

    def _worker_loop(self) -> None:
        # orphan recovery: a fresh server process pointed at the
        # checkpoint dir of a crashed one resumes its in-flight fleet
        # (results feed the library; the dead clients' streams are gone)
        if self.ckpt_dir is not None and \
                ckpt_lib.latest_step(self.ckpt_dir) is not None:
            self._run_epoch([])
        while not self._shutdown.is_set():
            with self._cond:
                while not self._pending and not self._shutdown.is_set():
                    self._cond.wait(timeout=0.1)
                if self._shutdown.is_set():
                    return
                batch = [self._pending.popleft()
                         for _ in range(len(self._pending))]
            self._run_epoch(batch)

    def _prepare(self, pend: _Pending) -> SearchTask:
        """Runtime-kw plumbing for one query, idempotent across crash
        re-admissions (the warm-start lookup is counted once; the
        state_out capture dict is always fresh)."""
        task = pend.task
        task.runtime_kw = dict(task.runtime_kw)
        task.runtime_kw.pop("resume_state", None)   # stale after crash
        if task.method in RESUMABLE_METHODS and self.ckpt_dir is not None:
            task.runtime_kw["state_out"] = {}
        if self.warm_start and task.method in WARM_START_METHODS and \
                not getattr(pend, "prepared", False):
            rows = self.library.lookup(task)
            if rows is not None:
                task.runtime_kw["warm_seeds"] = rows
                self._stats["warm_started"] += 1
        pend.prepared = True
        return task

    def _wipe_checkpoints(self) -> None:
        """A cleanly-finished epoch's checkpoints are spent — remove
        them so the next epoch (and the next server process) starts
        fresh instead of resuming ghosts."""
        if self.ckpt_dir is None or not os.path.isdir(self.ckpt_dir):
            return
        for d in os.listdir(self.ckpt_dir):
            if d.startswith("step_"):
                shutil.rmtree(os.path.join(self.ckpt_dir, d),
                              ignore_errors=True)

    def _run_epoch(self, batch: List[_Pending]) -> None:
        """One fleet lifetime: build (or restore) the fleet, drive it to
        completion under a crash supervisor, stream per-client events,
        checkpoint every k rounds."""
        self._stats["epochs"] += 1
        by_name: Dict[str, _Pending] = {}
        epoch_pends: List[_Pending] = list(batch)
        sup = Supervisor(self.ckpt_dir or "", ckpt_every=self.ckpt_every,
                         max_restarts=self.max_restarts)

        def wire(p: _Pending, name: str) -> None:
            p.name = name
            by_name[name] = p
            self._last_best.setdefault(name, float("inf"))
            if p.events is not None:
                with self._events_lock:
                    self._events[name] = p.events
            if getattr(p, "ready", None) is not None:
                p.ready.set()

        def admit_all(ms: Optional[MultiSearch],
                      pends: List[_Pending]) -> Optional[MultiSearch]:
            if ms is None and pends:
                # fresh epoch: one fleet from the whole batch — names
                # resolve at construction, so every client learns its id
                # BEFORE start()'s calibration compiles (minutes on a
                # cold process)
                tasks = [self._prepare(p) for p in pends]
                ms = MultiSearch(tasks, self.config)
                for p, name in zip(pends, ms.final_names):
                    wire(p, name)
                ms.start()
            elif ms is not None:
                for p in pends:
                    wire(p, ms.admit(self._prepare(p)))
            return ms

        def make_state(step: Optional[int]) -> MultiSearch:
            ms = None
            if step is not None and self.ckpt_dir is not None:
                arrays, meta = ckpt_lib.load_flat(self.ckpt_dir, step)
                ms = restore_fleet(arrays, meta)
                if ms is not None:
                    ms.start()
            with self._fleet_lock:
                # re-admit every epoch query the checkpoint doesn't
                # carry: on first build that is all of them; after a
                # crash, only those admitted since the last save (they
                # restart from scratch — deterministic, so the epoch's
                # final results are unchanged)
                have = set(ms.final_names) if ms is not None else set()
                missing = [p for p in epoch_pends
                           if p.name is None or p.name not in have]
                ms = admit_all(ms, missing)
                if ms is None:
                    raise RuntimeError("no tasks to run")
                self._ms = ms
            return ms

        def step_fn(ms: MultiSearch, s: int) -> bool:
            with self._cond:
                newcomers = [self._pending.popleft()
                             for _ in range(len(self._pending))]
            epoch_pends.extend(newcomers)
            with self._fleet_lock:
                admit_all(ms, newcomers)
                alive = ms.step()
                self._emit_updates(ms)
            return not alive

        def save_fn(ms: MultiSearch, s: int) -> None:
            if self.ckpt_dir is None or ms.done:
                return
            with self._fleet_lock:
                arrays, meta = pack_fleet(ms)
            ckpt_lib.save_flat(self.ckpt_dir, int(ms._rounds), arrays,
                               extra_meta=meta)

        try:
            # first-build happens inside run_loop's make_state; on a
            # crash mid-epoch the supervisor rebuilds from the latest
            # checkpoint (bit-identical resume) up to max_restarts times
            ms, report = sup.run_loop(make_state, step_fn, save_fn)
            self._stats["restarts"] += report["restarts"]
        except Exception as e:          # noqa: BLE001 — surface to clients
            self._stats["restarts"] += sup.restarts
            for name, p in by_name.items():
                if p.events is not None:
                    p.events.append({"event": "failed", "id": name,
                                     "error": f"{e}"})
            with self._cond:
                self._cond.notify_all()
            self._ms = None
            return
        with self._fleet_lock:
            ms.finish()
            self._last_fleet_stats = dict(ms.stats)
            self._last_groups = self._signature_groups(ms)
            self._epoch_groups.append(dict(self._last_groups))
            # wipe BEFORE streaming the final events: a client that acts
            # on "done" (or a test that lists the directory) must never
            # see spent checkpoints from an epoch that completed cleanly
            self._wipe_checkpoints()
            self._emit_updates(ms)
            self._ms = None

    def _emit_updates(self, ms: MultiSearch) -> None:
        for name, res in ms.pop_done():
            self._stats["completed"] += 1
            task = dict(zip(ms.final_names, ms.tasks))[name]
            self.library.record(task, res)
            with self._events_lock:
                q = self._events.pop(name, None)
            if q is not None:
                q.append({
                    "event": "done", "id": name,
                    "best_edp": float(res.best_edp),
                    "best_genome": None if res.best_genome is None
                    else np.asarray(res.best_genome).tolist(),
                    "evals": int(res.evals),
                    "valid_evals": int(res.valid_evals),
                    "round": int(ms._rounds)})
        for st in ms._alive:
            best = float(st.tracker.best)
            if best < self._last_best.get(st.name, float("inf")):
                self._last_best[st.name] = best
                with self._events_lock:
                    q = self._events.get(st.name)
                if q is not None:
                    bg = st.tracker.best_genome
                    q.append({
                        "event": "update", "id": st.name,
                        "best_edp": best,
                        "best_genome": None if bg is None
                        else np.asarray(bg).tolist(),
                        "evals": int(st.tracker.evals),
                        "round": int(ms._rounds)})
        with self._cond:
            self._cond.notify_all()

    # ---------------------------------------------------------------- stats

    @staticmethod
    def _signature_groups(ms: MultiSearch) -> Dict[str, int]:
        groups: Dict[str, int] = {}
        for st in ms._states:
            sig = "_".join(str(x) for x in st.signature)
            groups[sig] = groups.get(sig, 0) + 1
        return groups

    def stats(self) -> Dict:
        out = dict(self._stats)
        out["library"] = self.library.snapshot()
        out["compilations"] = jax_cost.compilation_count()
        with self._fleet_lock:
            ms = self._ms
            if ms is not None and ms._started:
                out["fleet"] = ms.stats_snapshot()
                out["signature_groups"] = self._signature_groups(ms)
            elif self._last_fleet_stats:
                # the most recent completed epoch's evidence: its stats
                # and how its tasks grouped by compilation signature
                out["fleet"] = dict(self._last_fleet_stats)
                out["signature_groups"] = dict(self._last_groups)
        out["epoch_signature_groups"] = [dict(g)
                                         for g in self._epoch_groups]
        fleet = out.get("fleet")
        if fleet and fleet.get("rounds"):
            out["dispatches_per_round"] = \
                fleet["dispatches"] / fleet["rounds"]
        return out


# ---------------------------------------------------------------- client


def request(host: str, port: int, msg: Dict, timeout: float = 600.0):
    """Send one op and yield reply lines until the stream closes (a
    submit yields update events then the done event; stats/shutdown
    yield one line).  The examples client and the tests both drive the
    server through this."""
    with socket.create_connection((host, port), timeout=timeout) as sk:
        f = sk.makefile("rwb")
        f.write((json.dumps(msg) + "\n").encode())
        f.flush()
        for line in f:
            yield json.loads(line.decode())


def submit(host: str, port: int, task: SearchTask,
           config: Optional[FleetConfig] = None, timeout: float = 600.0):
    """Submit one query; yields its event stream."""
    msg = {"op": "submit", "task": task.to_json_dict()}
    if config is not None:
        msg["config"] = config.to_json_dict()
    return request(host, port, msg, timeout=timeout)


# ------------------------------------------------------------------- CLI


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.serve sweep",
        description="Persistent sweep server: coalesces concurrent "
                    "(workload, arch, density, method, budget) queries "
                    "into one mega-batched MultiSearch fleet, streams "
                    "best-so-far results, checkpoints populations and "
                    "survives worker crashes.")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 picks an ephemeral port (printed on start)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="enable population checkpoints + crash "
                         "recovery (requires device_rounds=1)")
    ap.add_argument("--checkpoint-every", type=int, default=8,
                    help="fleet rounds between checkpoints")
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("--no-warm-start", action="store_true",
                    help="disable the best-genome warm-start library")
    ap.add_argument("--device-rounds", type=int, default=1)
    ap.add_argument("--no-stack", action="store_true",
                    help="disable mega-batch stacking (debug)")
    args = ap.parse_args(argv)

    config = FleetConfig(stack_batches=not args.no_stack,
                         device_rounds=args.device_rounds)
    server = SweepServer(args.host, args.port, config=config,
                         ckpt_dir=args.checkpoint_dir,
                         ckpt_every=args.checkpoint_every,
                         max_restarts=args.max_restarts,
                         warm_start=not args.no_warm_start)
    print(f"sweep serve listening on {server.host}:{server.port}",
          flush=True)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        server.stop()
    print("sweep serve stopped", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
