"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-350m \
        --steps 200 --batch 8 --seq 256 [--smoke] [--ckpt-dir ckpt/] \
        [--mesh 1x1] [--grad-compression none|int8|topk]

Composes every substrate: synthetic data pipeline (deterministic,
seekable), scanned model, AdamW with schedule/clipping, optional gradient
compression, checkpoint/restart via the fault-tolerant Supervisor, and a
step-time straggler monitor.  On this CPU container it trains the smoke
configs (examples/quickstart.py trains ~100M-class xlstm for a few
hundred steps); on a TPU pod the same driver runs the full configs under
``make_production_mesh()``.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-350m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-feasible)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default=None,
                    help="e.g. 2x4 -> (data=2, model=4) host-device mesh")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--inject-failure-at", type=int, default=None,
                    help="testing: raise at this step once")
    args = ap.parse_args(argv)

    import os
    if args.mesh:
        n = int(np.prod([int(x) for x in args.mesh.split("x")]))
        os.environ.setdefault(
            "XLA_FLAGS", f"--xla_force_host_platform_device_count={n}")

    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_config, smoke_config
    from repro.configs.shapes import ShapeSpec
    from repro.data.pipeline import make_data
    from repro.launch.steps import build_train_step
    from repro.models import sharding as shard_ctx
    from repro.models.model import Model
    from repro.optim import optimizer as opt
    from repro.runtime.fault_tolerance import StepMonitor, Supervisor

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeSpec("cli", args.seq, args.batch, "train")
    model = Model(cfg)
    data = make_data(cfg, shape)
    ocfg = opt.OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                         total_steps=args.steps)
    step_fn_raw = build_train_step(model, ocfg,
                                   n_microbatches=args.microbatches)

    mesh = None
    if args.mesh:
        dims = [int(x) for x in args.mesh.split("x")]
        axes = ("data", "model")[:len(dims)]
        mesh = Mesh(np.asarray(jax.devices()[:int(np.prod(dims))])
                    .reshape(dims), axes)
        shard_ctx.set_batch_axes(("data",))

    params = model.init(jax.random.PRNGKey(0))
    ostate = opt.init(params, ocfg)
    if mesh is not None:
        pspecs = model.param_specs()
        psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                           is_leaf=lambda x: isinstance(x, P))
        params = jax.device_put(params, psh)

    train_step = jax.jit(step_fn_raw, donate_argnums=(0, 1))
    monitor = StepMonitor()
    t_start = time.time()
    losses = []

    def one_step(state, step):
        params, ostate = state
        if args.inject_failure_at is not None and \
                step == args.inject_failure_at and \
                not getattr(one_step, "_crashed", False):
            one_step._crashed = True
            raise RuntimeError("injected failure")
        batch = {k: jnp.asarray(v) for k, v in
                 data.batch_at(step).items()}
        ctx = mesh if mesh is not None else _nullcontext()
        with ctx:
            params, ostate, metrics = train_step(params, ostate, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t_start
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):8.3f} "
                  f"lr {float(metrics['lr']):.2e} ({dt:6.1f}s)",
                  flush=True)
        return (params, ostate)

    state = (params, ostate)
    if args.ckpt_dir:
        sup = Supervisor(args.ckpt_dir, ckpt_every=args.ckpt_every)
        state, report = sup.run(state, one_step, args.steps)
        print(f"supervisor report: {json.dumps(report)}")
    else:
        for s in range(args.steps):
            t0 = time.time()
            state = one_step(state, s)
            monitor.observe(s, time.time() - t0)

    if len(losses) >= 20:
        first = np.mean(losses[:10])
        last = np.mean(losses[-10:])
        print(f"loss {first:.4f} -> {last:.4f} "
              f"({'improved' if last < first else 'NOT improved'})")
    return 0


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    raise SystemExit(main())
