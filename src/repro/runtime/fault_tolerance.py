"""Fault tolerance + straggler mitigation for the training runtime.

Designed for the 1000+-node regime; everything here is exercised by
tests on a single host (failure injection via exceptions):

* **StepMonitor** — per-step wall-time EWMA; flags stragglers when a step
  exceeds ``straggler_factor`` x the EWMA, and records slow-step history
  (the controller escalates: log -> re-shard data feed -> evict host).
* **Supervisor.run** — the crash-safe outer loop: catches step failures,
  restores the latest checkpoint, rebuilds the data iterator at the
  restored step (the deterministic pipeline makes this exact) and
  continues; gives up after ``max_restarts``.
* **ElasticPlan** — given a shrunken/grown device set, recompute the mesh
  shape and per-host data shards; restore-on-new-mesh is plain
  checkpoint.restore with new shardings (leaves are stored unsharded).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.checkpoint import checkpoint as ckpt_lib


@dataclasses.dataclass
class StepMonitor:
    ewma_alpha: float = 0.1
    straggler_factor: float = 2.5
    warmup_steps: int = 3
    ewma_s: float = 0.0
    n: int = 0
    stragglers: List[Tuple[int, float]] = dataclasses.field(
        default_factory=list)

    def observe(self, step: int, dt_s: float) -> bool:
        """Record a step duration; True if it was a straggler step."""
        self.n += 1
        if self.n <= self.warmup_steps:
            self.ewma_s = dt_s if self.ewma_s == 0.0 else \
                0.5 * (self.ewma_s + dt_s)
            return False
        is_slow = dt_s > self.straggler_factor * self.ewma_s
        if is_slow:
            self.stragglers.append((step, dt_s))
        else:
            self.ewma_s = (1 - self.ewma_alpha) * self.ewma_s + \
                self.ewma_alpha * dt_s
        return is_slow

    @property
    def straggler_rate(self) -> float:
        return len(self.stragglers) / max(self.n - self.warmup_steps, 1)


@dataclasses.dataclass
class ElasticPlan:
    """Mesh + data-shard plan for a given healthy-host count."""
    n_hosts: int
    data_parallel: int
    model_parallel: int

    @classmethod
    def plan(cls, n_devices: int, model_parallel: int = 16
             ) -> "ElasticPlan":
        """Largest (data x model) mesh fitting the healthy devices; model
        parallel degree is fixed by the model's sharding, data shrinks."""
        dp = n_devices // model_parallel
        if dp < 1:
            raise RuntimeError(
                f"{n_devices} devices cannot host model_parallel="
                f"{model_parallel}")
        return cls(n_hosts=dp * model_parallel, data_parallel=dp,
                   model_parallel=model_parallel)

    def host_shard(self, host_idx: int) -> Tuple[int, int]:
        return (host_idx % self.data_parallel, self.data_parallel)


class Supervisor:
    """Crash-safe training loop: checkpoint/restore + bounded restarts."""

    def __init__(self, ckpt_dir: str, ckpt_every: int = 100,
                 max_restarts: int = 3, keep_last: int = 3):
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.keep_last = keep_last
        self.monitor = StepMonitor()
        self.restarts = 0

    def run(self, state: Any, step_fn: Callable[[Any, int], Any],
            n_steps: int,
            restore_fn: Optional[Callable[[int, Any], Any]] = None
            ) -> Tuple[Any, Dict]:
        """Run ``n_steps`` of ``step_fn(state, step) -> state``.

        On exception: restore the latest checkpoint (via ``restore_fn``
        or checkpoint.restore into the current state structure) and
        continue from there.  Returns (final_state, report).
        """
        step = ckpt_lib.latest_step(self.ckpt_dir)
        if step is not None:
            state = (restore_fn or self._default_restore)(step, state)
            start = step + 1
        else:
            start = 0

        s = start
        while s < n_steps:
            try:
                t0 = time.time()
                state = step_fn(state, s)
                self.monitor.observe(s, time.time() - t0)
                if (s + 1) % self.ckpt_every == 0 or s == n_steps - 1:
                    ckpt_lib.save(self.ckpt_dir, s, state,
                                  keep_last=self.keep_last)
                s += 1
            except Exception as e:      # noqa: BLE001 — supervised retry
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise RuntimeError(
                        f"giving up after {self.max_restarts} restarts"
                    ) from e
                last = ckpt_lib.latest_step(self.ckpt_dir)
                if last is None:
                    s = 0               # restart from scratch
                    continue
                state = (restore_fn or self._default_restore)(last, state)
                s = last + 1
        report = dict(restarts=self.restarts,
                      straggler_rate=self.monitor.straggler_rate,
                      mean_step_s=self.monitor.ewma_s)
        return state, report

    def _default_restore(self, step: int, state: Any) -> Any:
        return ckpt_lib.restore(self.ckpt_dir, step, state)

    def run_loop(self, make_state: Callable[[Optional[int]], Any],
                 step_fn: Callable[[Any, int], bool],
                 save_fn: Callable[[Any, int], None]) -> Tuple[Any, Dict]:
        """The :meth:`run` shape generalized for open-ended supervised
        loops whose state is NOT a fixed-shape jax tree — the sweep
        server's fleet, for example, whose populations/histories change
        shape every round and which finishes by its own predicate rather
        than a step count.

        ``make_state(step)`` builds (or rebuilds) the loop state — from
        scratch when ``step`` is None, else from that checkpoint;
        ``step_fn(state, step) -> done`` advances one step;
        ``save_fn(state, step)`` checkpoints (called every
        ``ckpt_every`` steps and once at completion).  On exception the
        state is REBUILT via ``make_state(latest_step)`` — bounded by
        ``max_restarts`` like :meth:`run`."""
        state = make_state(ckpt_lib.latest_step(self.ckpt_dir))
        s = 0
        while True:
            try:
                t0 = time.time()
                done = step_fn(state, s)
                self.monitor.observe(s, time.time() - t0)
                if done or (s + 1) % self.ckpt_every == 0:
                    save_fn(state, s)
                if done:
                    break
                s += 1
            except Exception as e:  # noqa: BLE001 — supervised retry
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise RuntimeError(
                        f"giving up after {self.max_restarts} restarts"
                    ) from e
                state = make_state(ckpt_lib.latest_step(self.ckpt_dir))
        report = dict(restarts=self.restarts,
                      straggler_rate=self.monitor.straggler_rate,
                      mean_step_s=self.monitor.ewma_s)
        return state, report
