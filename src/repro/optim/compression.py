"""Gradient compression for cross-pod reduction (distributed-optimization
tricks for the 1000+-node regime).

Two composable schemes, both shard_map/pjit-friendly:

* **int8 quantized all-reduce** — per-tensor symmetric scale, quantize to
  int8, sum in int32, dequantize.  8x less ICI traffic on the data/pod
  axes; unbiased up to rounding (stochastic rounding optional).
* **top-k sparsification with error feedback** — keep the k largest-
  magnitude entries per tensor, accumulate the residual locally and add
  it back next step (Stich et al., 2018) — the standard convergence-
  preserving trick.

Tested in tests/test_compression.py on a forced multi-device host mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


# ------------------------------------------------------------- int8 AR


def quantize_int8(x: jnp.ndarray, stochastic: bool = False,
                  key=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    y = x.astype(jnp.float32) / scale
    if stochastic and key is not None:
        y = y + jax.random.uniform(key, y.shape, minval=-0.5, maxval=0.5)
    q = jnp.clip(jnp.round(y), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """int8-quantized all-reduce over ``axis_name`` (use inside
    shard_map).  Scales are all-reduced at fp32 (tiny); payload moves as
    int8 — ~4x traffic reduction vs fp32 psum."""
    q, scale = quantize_int8(x)
    scale_max = jax.lax.pmax(scale, axis_name)
    # re-quantize against the shared scale so the sum is exact in int32
    q2 = jnp.clip(jnp.round(x.astype(jnp.float32) / scale_max),
                  -127, 127).astype(jnp.int32)
    s = jax.lax.psum(q2, axis_name)
    return (s.astype(jnp.float32) * scale_max).astype(x.dtype)


# ------------------------------------------------------------- top-k EF


@dataclasses.dataclass
class ErrorFeedbackState:
    residual: Any          # pytree like grads


def init_error_feedback(grads_like: Any) -> ErrorFeedbackState:
    return ErrorFeedbackState(residual=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def topk_sparsify(x: jnp.ndarray, frac: float
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Keep the ``frac`` largest-|.| entries; returns (sparse_x, mask)."""
    flat = x.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.size * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = (jnp.abs(flat) >= thresh).astype(jnp.float32)
    return (flat * mask).reshape(x.shape), mask.reshape(x.shape)


def topk_ef_step(grads: Any, ef: ErrorFeedbackState, frac: float = 0.01
                 ) -> Tuple[Any, ErrorFeedbackState]:
    """Apply error-feedback top-k compression to a gradient pytree.
    Returns (compressed grads to all-reduce, new residual state)."""
    def one(g, r):
        acc = g.astype(jnp.float32) + r
        sparse, mask = topk_sparsify(acc, frac)
        new_r = acc - sparse
        return sparse.astype(g.dtype), new_r

    outs = jax.tree.map(one, grads, ef.residual)
    comp = jax.tree.map(lambda t: t[0], outs,
                        is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[1], outs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return comp, ErrorFeedbackState(residual=res)
