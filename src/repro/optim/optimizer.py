"""AdamW + schedule + clipping, pytree-native (no optax dependency).

Optimizer state sharding: each moment tensor inherits the parameter's
PartitionSpec, with the largest still-unsharded axis additionally sharded
over "data" when divisible (ZeRO-1); master/moment dtype is configurable
(fp32 default; bf16 "low_mem" for the trillion-parameter configs, and the
int8 quantized option lives in repro.optim.compression).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    moment_dtype: str = "float32"      # "bfloat16" => low-memory mode


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def init(params, cfg: OptConfig) -> OptState:
    dt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32

    def z(p):
        return jnp.zeros(p.shape, dt)
    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(z, params),
                    nu=jax.tree.map(z, params))


def schedule(step, cfg: OptConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def apply(params, grads, state: OptState, cfg: OptConfig
          ) -> Tuple[Any, OptState, Dict[str, jnp.ndarray]]:
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = schedule(step, cfg)
    b1, b2 = cfg.betas

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v2 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m2 / (1 - b1 ** step.astype(jnp.float32))
        vhat = v2 / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2.astype(m.dtype), v2.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(step, new_mu, new_nu), \
        dict(grad_norm=gnorm, lr=lr)


# ---------------------------------------------------------------- sharding


def zero1_spec(spec: P, shape: Tuple[int, ...], data_axis: str = "data",
               data_size: int = 16) -> P:
    """ZeRO-1: shard the largest unsharded axis of an optimizer-state
    tensor over the data axis (if divisible and not already used)."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for pt in parts:
        for ax in (pt if isinstance(pt, tuple) else (pt,)):
            if ax is not None:
                used.add(ax)
    if data_axis in used:
        return P(*parts)
    best, best_size = None, 0
    for i, (pt, sz) in enumerate(zip(parts, shape)):
        if pt is None and sz % data_size == 0 and sz > best_size:
            best, best_size = i, sz
    if best is not None:
        parts[best] = data_axis
    return P(*parts)


def opt_state_specs(param_specs, param_shapes, data_size: int = 16):
    """Specs for OptState given the param spec/shape trees."""
    mu = jax.tree.map(
        lambda s, shp: zero1_spec(s, shp, data_size=data_size),
        param_specs, param_shapes,
        is_leaf=lambda x: isinstance(x, P))
    return OptState(step=P(), mu=mu, nu=mu)
