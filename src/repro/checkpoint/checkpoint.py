"""Fault-tolerant checkpointing: per-leaf npz shards, atomic commit,
elastic re-sharding on restore.

Layout:
    <dir>/step_000123.tmp-<nonce>/   (staging)
        meta.json                    (step, tree structure, shapes, dtypes)
        leaf_00000.npy ...
    <dir>/step_000123/               (atomic rename = commit)

Restore is shape-checked against the target tree; because every leaf is
stored UNSHARDED (gathered) and re-sharding happens at device_put time,
the same checkpoint restores onto ANY mesh — elastic shrink/grow is a
restore with different shardings (tests/test_checkpoint.py).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    paths = ["/".join(str(k) for k in path)
             for path, _ in leaves_with_paths]
    leaves = [leaf for _, leaf in leaves_with_paths]
    return paths, leaves


def save(directory: str, step: int, tree: Any,
         keep_last: int = 3) -> str:
    """Write a checkpoint atomically; prune old ones; return its path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    if os.path.exists(final):
        shutil.rmtree(final)
    staging = tempfile.mkdtemp(prefix=f"step_{step:08d}.tmp-",
                               dir=directory)
    paths, leaves = _flatten_with_paths(tree)
    meta = {"step": step, "paths": paths,
            "shapes": [list(np.shape(x)) for x in leaves],
            "dtypes": [str(jnp.asarray(x).dtype) for x in leaves],
            "time": time.time()}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind not in "fiub":        # ml_dtypes (bf16, fp8...)
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2
                           else np.uint8)
        np.save(os.path.join(staging, f"leaf_{i:05d}.npy"), arr)
    with open(os.path.join(staging, "meta.json"), "w") as f:
        json.dump(meta, f)
    os.rename(staging, final)           # atomic commit
    _prune(directory, keep_last)
    return final


def _prune(directory: str, keep_last: int):
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and ".tmp-" not in d)
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)
    # remove stale staging dirs (crashed writers)
    for d in os.listdir(directory):
        if ".tmp-" in d:
            full = os.path.join(directory, d)
            if time.time() - os.path.getmtime(full) > 3600:
                shutil.rmtree(full, ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and ".tmp-" not in d]
    return max(steps) if steps else None


def save_flat(directory: str, step: int, arrays: dict,
              extra_meta: Optional[dict] = None,
              keep_last: int = 3) -> str:
    """Write a flat ``{key: np.ndarray}`` checkpoint — same staging-dir +
    atomic-rename + prune machinery as :func:`save`, but restorable
    WITHOUT a shape-matched target tree (:func:`load_flat`).  The sweep
    server uses this: fleet state (populations, rng blobs, histories) is
    variable-shape across rounds and across restarts, so a structural
    template cannot exist before the read.  ``extra_meta`` lands in
    ``meta.json`` under ``"extra"`` (JSON-able values only)."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    if os.path.exists(final):
        shutil.rmtree(final)
    staging = tempfile.mkdtemp(prefix=f"step_{step:08d}.tmp-",
                               dir=directory)
    keys = sorted(arrays)
    meta = {"step": step, "flat": True, "keys": keys,
            "extra": extra_meta or {}, "time": time.time()}
    for i, k in enumerate(keys):
        np.save(os.path.join(staging, f"leaf_{i:05d}.npy"),
                np.asarray(arrays[k]))
    with open(os.path.join(staging, "meta.json"), "w") as f:
        json.dump(meta, f)
    os.rename(staging, final)           # atomic commit
    _prune(directory, keep_last)
    return final


def load_flat(directory: str, step: int) -> tuple:
    """Read a :func:`save_flat` checkpoint: ``(arrays, extra_meta)``."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    if not meta.get("flat"):
        raise ValueError(f"{path} is a tree checkpoint; use restore()")
    arrays = {k: np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
              for i, k in enumerate(meta["keys"])}
    return arrays, meta.get("extra", {})


def restore(directory: str, step: int, target_tree: Any,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``target_tree``; if ``shardings``
    (a matching tree of jax.sharding.Sharding) is given, leaves are
    device_put with those shardings — restoring onto a different mesh
    than the one that saved is exactly this path."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    t_paths, t_leaves = _flatten_with_paths(target_tree)
    by_path = {p: i for i, p in enumerate(meta["paths"])}
    out_leaves = []
    sh_leaves = jax.tree.leaves(
        shardings, is_leaf=lambda x: hasattr(x, "device_set")) \
        if shardings is not None else [None] * len(t_leaves)
    for tp, tl, sh in zip(t_paths, t_leaves, sh_leaves):
        if tp not in by_path:
            raise KeyError(f"checkpoint missing leaf {tp}")
        i = by_path[tp]
        arr = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
        want = tuple(np.shape(tl))
        if tuple(arr.shape) != want:
            raise ValueError(f"{tp}: checkpoint shape {arr.shape} != "
                             f"target {want}")
        saved_dtype = meta["dtypes"][i]
        if arr.dtype.kind == "u" and saved_dtype in (
                "bfloat16", "float8_e4m3fn", "float8_e5m2"):
            arr = arr.view(jnp.dtype(saved_dtype))   # restore raw bits
        tgt = tl.dtype if hasattr(tl, "dtype") else np.asarray(tl).dtype
        if arr.dtype != tgt:
            arr = np.asarray(jnp.asarray(arr).astype(tgt))
        if sh is not None:
            out_leaves.append(jax.device_put(arr, sh))
        else:
            out_leaves.append(jnp.asarray(arr))
    treedef = jax.tree_util.tree_structure(target_tree)
    return jax.tree_util.tree_unflatten(treedef, out_leaves)
