"""Monte-Carlo high-sensitivity gene calibration (SparseMap §IV.D,
Eqs. 2-5).

For each gene v: fix all other genes to a random combination, Monte-Carlo
sample v, evaluate EDP with the batch cost model, drop invalid points, and
average the pairwise EDP-variation ratio

    S_i(v) = (1/N_i) * sum_{v1,v2} |EDP(v1)-EDP(v2)|
                       / (|v1-v2| * min(EDP(v1), EDP(v2)))

over I independent context combinations (Eq. 3).  Genes with

    S(v) > 3/4 * (S_max - S_min) + S_min          (Eq. 4)

are *high-sensitivity*; the rest are low-sensitivity (Eq. 5).  Valid
genomes discovered during calibration are pooled and reused by the
high-sensitivity hypercube initialization to seed low-sensitivity genes.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from .encoding import GenomeSpec


@dataclasses.dataclass
class SensitivityResult:
    scores: np.ndarray            # (L,) S(v)
    high_mask: np.ndarray         # (L,) bool
    valid_pool: np.ndarray        # (n_valid, L) valid genomes found
    threshold: float
    evals_used: int

    @property
    def high_indices(self) -> np.ndarray:
        return np.nonzero(self.high_mask)[0]

    @property
    def low_indices(self) -> np.ndarray:
        return np.nonzero(~self.high_mask)[0]

    def high_segments(self) -> List[tuple]:
        """Contiguous runs of high-sensitivity genes [(start, stop), ...] —
        the natural crossover boundaries for sensitivity-aware crossover."""
        segs = []
        in_run = False
        start = 0
        for i, h in enumerate(self.high_mask):
            if h and not in_run:
                in_run, start = True, i
            elif not h and in_run:
                segs.append((start, i))
                in_run = False
        if in_run:
            segs.append((start, len(self.high_mask)))
        return segs


def calibrate(spec: GenomeSpec, batch_eval, rng: np.random.Generator,
              n_contexts: int = 6, n_samples: int = 12,
              max_pairs: int = 32) -> SensitivityResult:
    """Run the calibration.

    ``batch_eval(genomes) -> dict with 'valid' (bool) and 'edp'`` — normally
    a :class:`repro.core.jax_cost.JaxCostModel`.

    One batched evaluation covers all genes x contexts x samples.
    """
    L = spec.length
    ub = spec.gene_ub

    # Build the full probe batch: for each context i and gene v, n_samples
    # genomes identical to context i except gene v.
    contexts = spec.random_genomes(rng, n_contexts)            # (I, L)
    probes = np.repeat(contexts, L * n_samples, axis=0)        # (I*L*S, L)
    gene_idx = np.tile(np.repeat(np.arange(L), n_samples), n_contexts)
    sampled_vals = (rng.random(len(probes)) *
                    ub[gene_idx]).astype(np.int64)
    probes[np.arange(len(probes)), gene_idx] = sampled_vals

    out = batch_eval(probes)
    valid = np.asarray(out["valid"])
    edp = np.asarray(out["edp"], dtype=np.float64)

    scores = np.zeros(L)
    counts = np.zeros(L)
    idx = 0
    for i in range(n_contexts):
        for v in range(L):
            sl = slice(idx, idx + n_samples)
            idx += n_samples
            vv = sampled_vals[sl]
            ok = valid[sl]
            if ok.sum() < 2:
                continue
            vals = vv[ok].astype(np.float64)
            es = edp[sl][ok]
            # pairwise ratio (subsample pairs if large)
            n = len(vals)
            pairs = [(a, b) for a in range(n) for b in range(a + 1, n)
                     if vals[a] != vals[b]]
            if len(pairs) > max_pairs:
                sel = rng.choice(len(pairs), max_pairs, replace=False)
                pairs = [pairs[j] for j in sel]
            if not pairs:
                continue
            s = 0.0
            for a, b in pairs:
                s += (abs(es[a] - es[b]) /
                      (abs(vals[a] - vals[b]) * max(min(es[a], es[b]), 1e-30)))
            scores[v] += s / len(pairs)
            counts[v] += 1

    with np.errstate(invalid="ignore"):
        scores = np.where(counts > 0, scores / np.maximum(counts, 1), 0.0)

    smax, smin = scores.max(), scores.min()
    threshold = 0.75 * (smax - smin) + smin
    high = scores > threshold
    if not high.any():         # degenerate: everything equal
        high = scores >= smax

    pool = probes[valid]
    return SensitivityResult(scores=scores, high_mask=high,
                             valid_pool=pool, threshold=float(threshold),
                             evals_used=len(probes))
