"""Monte-Carlo high-sensitivity gene calibration (SparseMap §IV.D,
Eqs. 2-5).

For each gene v: fix all other genes to a random combination, Monte-Carlo
sample v, evaluate EDP with the batch cost model, drop invalid points, and
average the pairwise EDP-variation ratio

    S_i(v) = (1/N_i) * sum_{v1,v2} |EDP(v1)-EDP(v2)|
                       / (|v1-v2| * min(EDP(v1), EDP(v2)))

over I independent context combinations (Eq. 3).  Genes with

    S(v) > 3/4 * (S_max - S_min) + S_min          (Eq. 4)

are *high-sensitivity*; the rest are low-sensitivity (Eq. 5).  Valid
genomes discovered during calibration are pooled and reused by the
high-sensitivity hypercube initialization to seed low-sensitivity genes.

Split into :func:`build_probes` / :func:`score_probes` so the evaluation
can be routed through a shared batch evaluator by an external driver
(``search.MultiSearch``); :func:`calibrate` composes the two around a
direct ``batch_eval`` call.  Scoring is fully vectorized: all pairwise
ratios for every (context, gene) cell are computed in one broadcasted
pass over the (I, L, S, S) pair lattice.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from .encoding import GenomeSpec


@dataclasses.dataclass
class SensitivityResult:
    scores: np.ndarray            # (L,) S(v)
    high_mask: np.ndarray         # (L,) bool
    valid_pool: np.ndarray        # (n_valid, L) valid genomes found
    threshold: float
    evals_used: int

    @property
    def high_indices(self) -> np.ndarray:
        return np.nonzero(self.high_mask)[0]

    @property
    def low_indices(self) -> np.ndarray:
        return np.nonzero(~self.high_mask)[0]

    def high_segments(self) -> List[tuple]:
        """Contiguous runs of high-sensitivity genes [(start, stop), ...] —
        the natural crossover boundaries for sensitivity-aware crossover."""
        segs = []
        in_run = False
        start = 0
        for i, h in enumerate(self.high_mask):
            if h and not in_run:
                in_run, start = True, i
            elif not h and in_run:
                segs.append((start, i))
                in_run = False
        if in_run:
            segs.append((start, len(self.high_mask)))
        return segs


def build_probes(spec: GenomeSpec, rng: np.random.Generator,
                 n_contexts: int = 6, n_samples: int = 12
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Build the full probe batch: for each context i and gene v,
    ``n_samples`` genomes identical to context i except gene v.  Returns
    (probes, gene_idx, sampled_vals); probe row i*L*S + v*S + s is context
    i with gene v resampled."""
    L = spec.length
    contexts = spec.random_genomes(rng, n_contexts)            # (I, L)
    probes = np.repeat(contexts, L * n_samples, axis=0)        # (I*L*S, L)
    gene_idx = np.tile(np.repeat(np.arange(L), n_samples), n_contexts)
    sampled_vals = (rng.random(len(probes)) *
                    spec.gene_ub[gene_idx]).astype(np.int64)
    probes[np.arange(len(probes)), gene_idx] = sampled_vals
    return probes, gene_idx, sampled_vals


def score_probes(spec: GenomeSpec, probes: np.ndarray, gene_idx: np.ndarray,
                 sampled_vals: np.ndarray, out: dict,
                 rng: np.random.Generator, n_contexts: int, n_samples: int,
                 max_pairs: int = 32) -> SensitivityResult:
    """Compute sensitivity scores from the evaluated probe batch."""
    L = spec.length
    S = n_samples
    valid = np.asarray(out["valid"]).reshape(n_contexts, L, S)
    edp = np.asarray(out["edp"], dtype=np.float64).reshape(n_contexts, L, S)
    vals = sampled_vals.astype(np.float64).reshape(n_contexts, L, S)

    # The seed implementation subsampled pairs per cell purely to bound
    # the Python-loop cost; vectorized, every eligible pair of a normal
    # calibration (S <= ~32) is cheap, and using them all avoids biasing
    # against cells with few valid samples.  Only truly huge lattices get
    # a (shared) subsample, scaled so ~max_pairs pairs survive per cell.
    iu, ju = np.triu_indices(S, k=1)
    if len(iu) > max(max_pairs * 16, 512):
        sel = rng.choice(len(iu), max(max_pairs * 16, 512), replace=False)
        iu, ju = iu[sel], ju[sel]

    ok_a = valid[..., iu]
    ok_b = valid[..., ju]
    va = vals[..., iu]
    vb = vals[..., ju]
    pair_ok = ok_a & ok_b & (va != vb)
    # neutralize invalid entries (inf EDP) before arithmetic
    ea = np.where(ok_a, edp[..., iu], 0.0)
    eb = np.where(ok_b, edp[..., ju], 0.0)
    num = np.abs(ea - eb)
    den = np.abs(va - vb) * np.maximum(np.minimum(ea, eb), 1e-30)
    ratio = np.where(pair_ok, num / np.where(pair_ok, den, 1.0), 0.0)

    n_pairs = pair_ok.sum(axis=-1)                  # (I, L)
    cell_ok = (valid.sum(axis=-1) >= 2) & (n_pairs > 0)
    cell_score = np.where(
        cell_ok, ratio.sum(axis=-1) / np.maximum(n_pairs, 1), 0.0)
    scores = cell_score.sum(axis=0)                 # (L,)
    counts = cell_ok.sum(axis=0)
    with np.errstate(invalid="ignore"):
        scores = np.where(counts > 0, scores / np.maximum(counts, 1), 0.0)

    smax, smin = scores.max(), scores.min()
    threshold = 0.75 * (smax - smin) + smin
    high = scores > threshold
    if not high.any():         # degenerate: everything equal
        high = scores >= smax

    pool = probes[np.asarray(out["valid"])]
    return SensitivityResult(scores=scores, high_mask=high,
                             valid_pool=pool, threshold=float(threshold),
                             evals_used=len(probes))


def calibrate(spec: GenomeSpec, batch_eval, rng: np.random.Generator,
              n_contexts: int = 6, n_samples: int = 12,
              max_pairs: int = 32) -> SensitivityResult:
    """Run the calibration.

    ``batch_eval(genomes) -> dict with 'valid' (bool) and 'edp'`` — normally
    a :class:`repro.core.jax_cost.JaxCostModel`.

    One batched evaluation covers all genes x contexts x samples.
    """
    probes, gene_idx, sampled_vals = build_probes(
        spec, rng, n_contexts=n_contexts, n_samples=n_samples)
    out = batch_eval(probes)
    return score_probes(spec, probes, gene_idx, sampled_vals, out, rng,
                        n_contexts=n_contexts, n_samples=n_samples,
                        max_pairs=max_pairs)
