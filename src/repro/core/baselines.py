"""Baseline optimizers (SparseMap §III.C, §V) + prior-work proxies.

Every method consumes the same genome representation (`GenomeSpec`), the
same batch evaluator and the same evaluation budget, and returns a
`SearchResult` so convergence curves are directly comparable (Fig. 17/18).

Each optimizer is written as a *request generator* (``*_requests``)
conforming to the :data:`repro.core.evolution.Requests` protocol: it
``yield``s every (B, L) genome batch that needs evaluating, is ``send``-ed
the evaluator's output dict, and returns an extras dict via
``StopIteration``.  The closed-form functions (``pso``, ``tbpsa``, ...)
simply drive their generator against one evaluator; ``search.MultiSearch``
instead round-robins a heterogeneous fleet of generators over shared
jitted evaluators — optionally concatenating all same-signature pending
batches into one mega-batch dispatch per round.  ``make_requests`` is the
registry entry point for drivers.

Prior-work proxies (§V):
* ``random_mapper``  — Sparseloop-Mapper-like: random mapping sampling under
  a fixed, manually chosen sparse strategy.
* ``sage_like``      — SAGE-like: sparse-strategy search under a fixed
  (balanced output-stationary) mapping.

Classical baselines (Fig. 17): PSO, MCTS, TBPSA, PPO, DQN — compact but
faithful implementations; they are *expected* to drown in invalid points,
which is the paper's point.  ``standard_es`` runs on the DIRECT value
encoding; its generator (``direct_encoding.direct_requests``) translates
valid direct genomes to canonical rows before yielding them, so even the
direct-encoding ablation joins a mega-batched fleet.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .arch import ArchSpec, as_arch
from .encoding import GenomeSpec
from .evolution import (ESConfig, Requests, SearchResult, _Budget, _drive,
                        evolve_requests)
from .mapping import balanced_mapping_for_arch
from .sparse import MAX_FMT_GENES


# ---------------------------------------------------------------- helpers


def _finish(tracker: _Budget, **extras) -> SearchResult:
    return SearchResult(best_edp=tracker.best,
                        best_genome=tracker.best_genome,
                        history=np.asarray(tracker.hist),
                        evals=tracker.evals, valid_evals=tracker.valid,
                        extras=extras)


def _run_closed(method: str, spec: GenomeSpec, batch_eval, budget: int,
                seed: int, platform=None, **kw) -> SearchResult:
    """Drive a registered request generator to completion against one
    evaluator — the closed-form path every ``METHODS`` entry uses, so a
    sequential ``search.run`` and a concurrent ``search.MultiSearch`` task
    execute literally the same code."""
    gen, tracker = make_requests(method, spec, platform, budget, seed, **kw)
    extras = _drive(gen, batch_eval) or {}
    return _finish(tracker, **extras)


def manual_sparse_genes(spec: GenomeSpec) -> Dict[int, int]:
    """A sensible hand-picked sparse strategy (the 'manually specified
    sparse strategy' a Sparseloop-Mapper user would fix): bitmask on the two
    innermost sub-dims of P and Q, uncompressed Z, no store-site S/G,
    skip P<->Q at compute (the last S/G site of any arch)."""
    fixed: Dict[int, int] = {}
    for tn in spec.tensor_names:
        seg = spec.segments[f"fmt_{tn}"]
        genes = [0, 0, 0, 1, 1] if tn != "Z" else [0] * MAX_FMT_GENES
        for i, v in enumerate(genes):
            fixed[seg.start + i] = v
    sg = spec.segments["sg"]
    for i in range(sg.start, sg.stop - 1):
        fixed[i] = 0             # store sites: none
    fixed[sg.stop - 1] = 6       # C: skip P<->Q
    return fixed


def _freeze_mapping_genes(spec: GenomeSpec, mapping) -> Dict[int, int]:
    g = spec.encode_mapping(mapping)
    fixed: Dict[int, int] = {}
    for seg_name in ("perm", "tiling"):
        seg = spec.segments[seg_name]
        for i in range(seg.start, seg.stop):
            fixed[i] = int(g[i])
    return fixed


def fixed_mapping_genes_for_arch(spec: GenomeSpec, arch: ArchSpec
                                 ) -> Dict[int, int]:
    """Freeze the mapping segment to the balanced OS mapping on ``arch``
    (SAGE-like).  ``arch`` must share the spec's topology (it supplies
    the fanout numbers; e.g. the resolved edge/mobile/cloud platform)."""
    return _freeze_mapping_genes(
        spec, balanced_mapping_for_arch(spec.workload, arch))


def fixed_mapping_genes(spec: GenomeSpec, n_pe: int, macs_per_pe: int
                        ) -> Dict[int, int]:
    """Paper-topology convenience variant taking explicit fanout caps."""
    from .mapping import balanced_mapping
    return _freeze_mapping_genes(
        spec, balanced_mapping(spec.workload, n_pe, macs_per_pe))


# ---------------------------------------------------------------- proxies


def random_mapper_requests(spec: GenomeSpec, tracker: _Budget, seed: int,
                           platform=None) -> Requests:
    """Sparseloop-Mapper-like: uniform random mapping search, sparse
    strategy fixed manually.  (The paper incorporates the manual settings
    into its random sampling space.)"""
    rng = np.random.default_rng(seed)
    fixed = manual_sparse_genes(spec)
    chunk = 512
    while not tracker.exhausted:
        g = spec.random_genomes(
            rng, min(chunk, tracker.budget - tracker.evals))
        for k, v in fixed.items():
            g[:, k] = v
        out = yield g
        tracker.register(g, out)
    return dict(method="random_mapper")


def random_mapper(spec: GenomeSpec, batch_eval, budget: int, seed: int,
                  platform=None) -> SearchResult:
    return _run_closed("random_mapper", spec, batch_eval, budget, seed,
                       platform)


def _sage_like_setup(spec: GenomeSpec, platform, budget: int, seed: int,
                     **kw) -> Tuple[ESConfig, Dict[int, int], np.ndarray]:
    """SAGE-like search space: fixed balanced-OS mapping, format genes of
    spatially-unrolled sub-dimensions pinned uncompressed, started from the
    engineer's uncompressed default."""
    from .cost_model import spatial_subdim_indices, tiled_subdims
    fixed = fixed_mapping_genes_for_arch(spec, as_arch(platform))
    # pin format genes of spatially-unrolled sub-dimensions to U
    genome0 = np.zeros(spec.length, dtype=np.int64)
    for k, v in fixed.items():
        genome0[k] = v
    mapping = spec.decode(genome0).mapping
    for tn in spec.tensor_names:
        seg = spec.segments[f"fmt_{tn}"]
        k = len(tiled_subdims(mapping, tn))
        for i in spatial_subdim_indices(mapping, tn):
            gidx = i + max(MAX_FMT_GENES - k, 0)
            if 0 <= gidx < MAX_FMT_GENES:
                fixed[seg.start + gidx] = 0
    params = dict(use_hshi=False, use_custom_ops=False, pop_size=64)
    params.update(kw)
    cfg = ESConfig(budget=budget, seed=seed, **params)
    return cfg, fixed, genome0


def sage_like(spec: GenomeSpec, batch_eval, budget: int, seed: int,
              platform, **kw) -> SearchResult:
    """SAGE-like: sparse-strategy search under a FIXED mapping (the
    balanced output-stationary mapping).

    SAGE knows its accelerator template, so the search space excludes
    format choices that are structurally impossible under the fixed
    mapping (formats on spatially-unrolled sub-dimensions stay
    uncompressed), and it starts from the engineer's uncompressed default.
    What it cannot do — the paper's point — is adapt the mapping itself.
    """
    return _run_closed("sage_like", spec, batch_eval, budget, seed,
                       platform, **kw)


# ---------------------------------------------------------------- PSO


def pso_requests(spec: GenomeSpec, tracker: _Budget, seed: int,
                 platform=None, n_particles: int = 50, w: float = 0.72,
                 c1: float = 1.49, c2: float = 1.49) -> Requests:
    rng = np.random.default_rng(seed)
    L = spec.length
    ub = spec.gene_ub.astype(np.float64)
    x = rng.random((n_particles, L)) * ub
    v = (rng.random((n_particles, L)) - 0.5) * ub * 0.2
    pbest_x = x.copy()
    pbest_f = np.full(n_particles, np.inf)
    gbest_x = x[0].copy()
    gbest_f = np.inf
    while not tracker.exhausted:
        g = spec.clip(x.astype(np.int64))
        out = yield g
        edp = tracker.register(g, out)
        improved = edp < pbest_f            # NaN tail compares False
        pbest_f = np.where(improved, edp, pbest_f)
        pbest_x[improved] = x[improved]
        i = int(np.argmin(pbest_f))
        if pbest_f[i] < gbest_f:
            gbest_f, gbest_x = pbest_f[i], pbest_x[i].copy()
        r1, r2 = rng.random((2, n_particles, L))
        v = w * v + c1 * r1 * (pbest_x - x) + c2 * r2 * (gbest_x[None] - x)
        x = np.clip(x + v, 0, ub - 1e-6)
    return dict(method="pso")


def pso(spec: GenomeSpec, batch_eval, budget: int, seed: int,
        platform=None, **kw) -> SearchResult:
    return _run_closed("pso", spec, batch_eval, budget, seed, platform,
                       **kw)


# ---------------------------------------------------------------- MCTS


def mcts_requests(spec: GenomeSpec, tracker: _Budget, seed: int,
                  platform=None, max_children: int = 8, c_ucb: float = 1.4,
                  rollout_batch: int = 16) -> Requests:
    """Gene-by-gene tree search with UCB1 selection and random rollouts.
    Large per-gene ranges are subsampled to ``max_children`` branches
    (standard progressive-widening practice)."""
    rng = np.random.default_rng(seed)
    L = spec.length

    class Node:
        __slots__ = ("depth", "children", "visits", "value", "vals")

        def __init__(self, depth: int):
            self.depth = depth
            self.children: Dict[int, Node] = {}
            self.visits = 0
            self.value = 0.0
            self.vals: Optional[np.ndarray] = None

    root = Node(0)

    def reward(edp: float) -> float:
        if not np.isfinite(edp):
            return 0.0
        return 1.0 / (1.0 + math.log10(max(edp, 1.0)))

    while not tracker.exhausted:
        node = root
        prefix: List[int] = []
        # selection / expansion
        while node.depth < L:
            if node.vals is None:
                k = min(max_children, int(spec.gene_ub[node.depth]))
                node.vals = rng.choice(spec.gene_ub[node.depth], size=k,
                                       replace=False)
            unvisited = [v for v in node.vals if v not in node.children]
            if unvisited:
                v = int(unvisited[0])
                node.children[v] = Node(node.depth + 1)
                prefix.append(v)
                node = node.children[v]
                break
            # UCB1
            best_v, best_u = None, -np.inf
            for v, ch in node.children.items():
                u = (ch.value / max(ch.visits, 1) +
                     c_ucb * math.sqrt(math.log(max(node.visits, 1) + 1) /
                                       max(ch.visits, 1)))
                if u > best_u:
                    best_u, best_v = u, v
            prefix.append(int(best_v))
            node = node.children[int(best_v)]
        # rollout: complete randomly (batched)
        n = min(rollout_batch, tracker.budget - tracker.evals)
        g = spec.random_genomes(rng, n)
        g[:, :len(prefix)] = np.asarray(prefix, dtype=np.int64)[None, :]
        out = yield g
        edp = tracker.register(g, out)
        r = max(reward(float(e)) for e in edp)
        # backprop along path
        node = root
        node.visits += 1
        node.value += r
        for v in prefix:
            if v in node.children:
                node = node.children[v]
                node.visits += 1
                node.value += r
            else:
                break
    return dict(method="mcts")


def mcts(spec: GenomeSpec, batch_eval, budget: int, seed: int,
         platform=None, **kw) -> SearchResult:
    return _run_closed("mcts", spec, batch_eval, budget, seed, platform,
                       **kw)


# ---------------------------------------------------------------- TBPSA


def tbpsa_requests(spec: GenomeSpec, tracker: _Budget, seed: int,
                   platform=None, mu: int = 12, llambda: int = 48
                   ) -> Requests:
    """Test-based population-size-adaptation ES (nevergrad's TBPSA family):
    gaussian search distribution in the continuous relaxation, mean/state
    updated from the mu best of each lambda batch."""
    rng = np.random.default_rng(seed)
    L = spec.length
    ub = spec.gene_ub.astype(np.float64)
    mean = ub / 2.0
    sigma = ub / 4.0
    while not tracker.exhausted:
        n = min(llambda, tracker.budget - tracker.evals)
        x = mean[None] + rng.standard_normal((n, L)) * sigma[None]
        g = spec.clip(np.clip(x, 0, ub - 1e-6).astype(np.int64))
        out = yield g
        edp = tracker.register(g, out)
        order = np.argsort(edp)[:mu]
        sel = x[order]
        new_mean = sel.mean(axis=0)
        sigma = 0.9 * sigma + 0.1 * (sel.std(axis=0) + 1e-3)
        mean = np.clip(new_mean, 0, ub - 1e-6)
    return dict(method="tbpsa")


def tbpsa(spec: GenomeSpec, batch_eval, budget: int, seed: int,
          platform=None, **kw) -> SearchResult:
    return _run_closed("tbpsa", spec, batch_eval, budget, seed, platform,
                       **kw)


# ---------------------------------------------------------------- PPO-lite


def ppo_requests(spec: GenomeSpec, tracker: _Budget, seed: int,
                 platform=None, batch: int = 64, lr: float = 0.15,
                 clip_eps: float = 0.2, epochs: int = 3) -> Requests:
    """Factorized-categorical policy over genes, trained with the clipped
    PPO objective on a normalized -log10(EDP) reward; invalid designs give
    reward -1 (the sparse-reward regime the paper §I points at)."""
    rng = np.random.default_rng(seed)
    L = spec.length
    maxv = int(spec.gene_ub.max())
    logits = np.zeros((L, maxv))
    for j in range(L):
        logits[j, spec.gene_ub[j]:] = -1e9
    r_mean, r_std = 0.0, 1.0

    def softmax(z):
        z = z - z.max(axis=-1, keepdims=True)
        e = np.exp(z)
        return e / e.sum(axis=-1, keepdims=True)

    while not tracker.exhausted:
        n = min(batch, tracker.budget - tracker.evals)
        pi = softmax(logits)                       # (L, V)
        # vectorized inverse-CDF sampling: one uniform matrix, all genes
        cdf = np.cumsum(pi, axis=-1)               # (L, V)
        u = rng.random((n, L))
        g = (u[:, :, None] > cdf[None, :, :]).sum(axis=-1)
        g = np.minimum(g, spec.gene_ub[None, :] - 1).astype(np.int64)
        out = yield g
        edp = tracker.register(g, out)
        rew = np.where(np.isfinite(edp), 0.0, -1.0)
        ok = np.isfinite(edp)
        if ok.any():
            rew[ok] = -np.log10(edp[ok])
            r_mean = 0.9 * r_mean + 0.1 * rew[ok].mean()
            r_std = 0.9 * r_std + 0.1 * (rew[ok].std() + 1e-6)
            rew[ok] = (rew[ok] - r_mean) / max(r_std, 1e-6)
        adv = rew - rew.mean()
        old_pi = pi.copy()
        onehot = np.zeros((n, L, maxv))
        onehot[np.arange(n)[:, None], np.arange(L)[None, :], g] = 1.0
        for _ in range(epochs):
            pi = softmax(logits)
            ratio = (pi[None, :, :] * onehot).sum(-1) / \
                np.maximum((old_pi[None, :, :] * onehot).sum(-1), 1e-9)
            clipped = np.clip(ratio, 1 - clip_eps, 1 + clip_eps)
            use = (np.minimum(ratio * adv[:, None], clipped * adv[:, None])
                   == ratio * adv[:, None])
            w_adv = adv[:, None] * use                     # (n, L)
            grad = (onehot - pi[None, :, :]) * w_adv[:, :, None]
            logits += lr * grad.mean(axis=0)
            for j in range(L):
                logits[j, spec.gene_ub[j]:] = -1e9
    return dict(method="ppo")


def ppo(spec: GenomeSpec, batch_eval, budget: int, seed: int,
        platform=None, **kw) -> SearchResult:
    return _run_closed("ppo", spec, batch_eval, budget, seed, platform,
                       **kw)


# ---------------------------------------------------------------- DQN-lite


def dqn_td_update(q: np.ndarray, g: np.ndarray, rew: np.ndarray,
                  gamma: float, lr: float) -> None:
    """One batched TD(0) update of the factored Q table, in place.

    All targets come from the round's FROZEN Q snapshot: position j
    bootstraps ``gamma * max(q_old[j+1])`` (the terminal position takes
    the episode reward), and the per-(position, value) increments of the
    whole episode batch are accumulated with one ``np.add.at`` — the
    batch analogue of PPO's vectorized sampling.  This deliberately
    replaces the old LIVE-table episode loop (each episode bootstrapped
    off the previous episode's in-round updates, sequential by
    construction and unvectorizable); the frozen-snapshot semantics ARE
    order-free, and ``np.add.at``'s unbuffered in-element-order
    duplicate accumulation makes this bit-exactly the per-episode
    sequential loop over the same snapshot (parity pinned by
    tests/test_baselines.py)."""
    n, L = g.shape
    q_old = q.copy()
    # masked (out-of-range) cells hold -1e9 and are never selected, so
    # the full-row max IS the masked max
    boot = gamma * np.max(q_old[1:], axis=1)              # (L-1,)
    targets = np.concatenate(
        [np.broadcast_to(boot, (n, L - 1)), rew[:, None]], axis=1)
    pos = np.broadcast_to(np.arange(L), (n, L))
    np.add.at(q, (pos, g), lr * (targets - q_old[pos, g]))


def dqn_requests(spec: GenomeSpec, tracker: _Budget, seed: int,
                 platform=None, batch: int = 32, lr: float = 0.2,
                 eps_start: float = 0.9, eps_end: float = 0.05,
                 gamma: float = 0.98) -> Requests:
    """Sequential gene-picking MDP with a factored Q table (gene position x
    value), epsilon-greedy, batched TD(0) bootstrapping
    (:func:`dqn_td_update`)."""
    rng = np.random.default_rng(seed)
    L = spec.length
    maxv = int(spec.gene_ub.max())
    q = np.zeros((L, maxv))
    for j in range(L):
        q[j, spec.gene_ub[j]:] = -1e9
    step = 0
    total_steps = max(tracker.budget // batch, 1)
    while not tracker.exhausted:
        eps = eps_start + (eps_end - eps_start) * min(step / total_steps, 1)
        n = min(batch, tracker.budget - tracker.evals)
        # vectorized epsilon-greedy: out-of-range q is -1e9, so the full-
        # row argmax is the masked argmax
        explore = rng.random((n, L)) < eps
        rand_vals = rng.integers(0, spec.gene_ub, size=(n, L),
                                 dtype=np.int64)
        greedy = np.argmax(q, axis=1).astype(np.int64)
        g = np.where(explore, rand_vals, greedy[None, :])
        out = yield g
        edp = tracker.register(g, out)
        rew = np.where(np.isfinite(edp), 0.0, -1.0)
        ok = np.isfinite(edp)
        rew[ok] = -np.log10(np.maximum(edp[ok], 1.0)) / 10.0
        # NaN tail rows (budget-truncated, never evaluated) must not
        # train the Q table
        counted = tracker.last_n
        dqn_td_update(q, g[:counted], rew[:counted], gamma, lr)
        step += 1
    return dict(method="dqn")


def dqn(spec: GenomeSpec, batch_eval, budget: int, seed: int,
        platform=None, **kw) -> SearchResult:
    return _run_closed("dqn", spec, batch_eval, budget, seed, platform,
                       **kw)


# ---------------------------------------------------------------- registry


def sparsemap_setup(spec: GenomeSpec, platform, budget: int, seed: int,
                    **kw) -> Tuple[ESConfig, Optional[np.ndarray]]:
    """Shared SparseMap search setup: the ESConfig (population scaled with
    the budget so calibration + HSHI never starve the evolutionary phase
    at CI-scale budgets) and the engineer-default seed genomes.  Used by
    both :func:`sparsemap` and ``search.MultiSearch`` so single and
    concurrent searches are configured identically."""
    if "pop_size" not in kw:
        kw["pop_size"] = int(min(100, max(24, budget // 20)))
    cfg = ESConfig(budget=budget, seed=seed, **kw)
    # seed the initial population with the engineer-default designs that
    # the prior-work baselines also start from (balanced OS mapping with
    # uncompressed / manual sparse strategies) — the joint search then
    # explores outward from them.  Implementation enhancement over the
    # paper, documented in DESIGN.md §6.
    seeds = None
    if platform is not None:
        g0 = np.zeros(spec.length, dtype=np.int64)
        for k, v in fixed_mapping_genes_for_arch(
                spec, as_arch(platform)).items():
            g0[k] = v
        g1 = g0.copy()
        for k, v in manual_sparse_genes(spec).items():
            g1[k] = v
        seeds = np.stack([g0, g1])
    return cfg, seeds


def sparsemap(spec: GenomeSpec, batch_eval, budget: int, seed: int,
              platform=None, **kw) -> SearchResult:
    return _run_closed("sparsemap", spec, batch_eval, budget, seed,
                       platform, **kw)


def standard_es(spec: GenomeSpec, batch_eval, budget: int, seed: int,
                platform=None, **kw) -> SearchResult:
    """Fig. 18 curve 'ES': standard ES with LHS init on the DIRECT value
    encoding (no prime-factor/cantor encoding), uniform operators.  Its
    engine is the ``direct_requests`` generator over canonical genome
    rows, so it also runs inside a concurrent ``MultiSearch`` fleet."""
    from .direct_encoding import direct_standard_es
    return direct_standard_es(spec, batch_eval, budget, seed, platform,
                              **kw)


def pfce_es(spec: GenomeSpec, batch_eval, budget: int, seed: int,
            platform=None) -> SearchResult:
    """Fig. 18 curve 'PFCE': prime-factor + cantor encoding only (the
    encoding is intrinsic to GenomeSpec; custom operators + HSHI off)."""
    return _run_closed("pfce_es", spec, batch_eval, budget, seed, platform)


# -------- request-generator factories (the MultiSearch entry points)


def _pop_runtime_kw(kw: Dict) -> Tuple:
    """Split the process-local runtime extras out of a factory's kwargs
    (``SearchTask.runtime_kw``, merged in by MultiSearch): warm-start
    rows, a resume-state dict, and a live state-capture sink.  Popped
    here so they never reach ESConfig."""
    return (kw.pop("warm_seeds", None), kw.pop("resume_state", None),
            kw.pop("state_out", None))


def _with_warm_seeds(seeds: Optional[np.ndarray], warm,
                     length: int) -> Optional[np.ndarray]:
    """Stack library warm-start rows AHEAD of the engineer-default
    seeds: warm rows are prior search winners for a similar query, the
    strongest prior available, so they must survive the
    ``pop[:len(seeds)]`` injection even when the population is tiny."""
    if warm is None or len(warm) == 0:
        return seeds
    warm = np.asarray(warm, dtype=np.int64).reshape(-1, length)
    return warm if seeds is None else np.concatenate([warm, seeds])


def _factory_sparsemap(spec: GenomeSpec, platform, budget: int, seed: int,
                       **kw) -> Tuple[Requests, _Budget]:
    warm, resume, state_out = _pop_runtime_kw(kw)
    cfg, seeds = sparsemap_setup(spec, platform, budget, seed, **kw)
    tracker = _Budget(cfg.budget)
    return evolve_requests(spec, cfg, tracker,
                           seeds=_with_warm_seeds(seeds, warm,
                                                  spec.length),
                           resume=resume, state_out=state_out), tracker


def _factory_pfce_es(spec: GenomeSpec, platform, budget: int, seed: int,
                     **kw) -> Tuple[Requests, _Budget]:
    warm, resume, state_out = _pop_runtime_kw(kw)
    cfg = ESConfig(budget=budget, seed=seed, use_hshi=False,
                   use_custom_ops=False, **kw)
    tracker = _Budget(cfg.budget)
    return evolve_requests(spec, cfg, tracker,
                           seeds=_with_warm_seeds(None, warm,
                                                  spec.length),
                           resume=resume, state_out=state_out), tracker


def _factory_sage_like(spec: GenomeSpec, platform, budget: int, seed: int,
                       **kw) -> Tuple[Requests, _Budget]:
    warm, resume, state_out = _pop_runtime_kw(kw)
    cfg, fixed, genome0 = _sage_like_setup(spec, platform, budget, seed,
                                           **kw)
    tracker = _Budget(cfg.budget)
    return evolve_requests(spec, cfg, tracker, fixed_genes=fixed,
                           seeds=_with_warm_seeds(genome0[None, :], warm,
                                                  spec.length),
                           resume=resume, state_out=state_out), tracker


def _gen_factory(gen_fn: Callable) -> Callable:
    def factory(spec: GenomeSpec, platform, budget: int, seed: int,
                **kw) -> Tuple[Requests, _Budget]:
        tracker = _Budget(budget)
        return gen_fn(spec, tracker, seed, platform=platform, **kw), tracker
    return factory


def _factory_standard_es(spec: GenomeSpec, platform, budget: int,
                         seed: int, **kw) -> Tuple[Requests, _Budget]:
    from .direct_encoding import direct_requests
    warm, resume, state_out = _pop_runtime_kw(kw)
    if warm is not None or resume is not None or state_out is not None:
        # direct-encoding genomes live in a different space than the
        # canonical rows the warm-start library stores, and the direct
        # generator has no generation-boundary capture — refuse rather
        # than silently drop the caller's durability expectation
        raise ValueError(
            "standard_es supports neither warm_seeds nor checkpoint "
            "resume (direct encoding; see baselines.RESUMABLE_METHODS)")
    tracker = _Budget(budget)
    return direct_requests(spec, tracker, seed, platform=platform,
                           **kw), tracker


#: methods whose request generators can fold generations into
#: device-resident segments (COMPAT.md "Device-resident round protocol"):
#: the ``evolve_requests`` family accepts ``device_rounds``/``rng_backend``
#: through its ESConfig, and ``standard_es`` accepts ``device_rounds``
#: directly — its direct-to-canonical translation now runs in-scan
#: (``kind="direct"`` segments; COMPAT.md "standard_es segment protocol
#: addendum").  The non-ES baselines (PSO/MCTS/TBPSA/PPO/DQN,
#: random_mapper) keep their per-round host paths; in a
#: ``device_rounds=k`` fleet they run unchanged alongside segmented ES
#: tasks.
SEGMENT_METHODS = frozenset({"sparsemap", "pfce_es", "sage_like",
                             "standard_es"})

#: methods whose factories accept library ``warm_seeds`` rows (canonical
#: genome space) and the ``resume_state``/``state_out`` checkpoint hooks
#: (``evolve_requests`` family).  The sweep server gates warm-start
#: injection and checkpointing on this set; other methods run fine but
#: restart from scratch after a crash.
WARM_START_METHODS = frozenset({"sparsemap", "pfce_es", "sage_like"})
RESUMABLE_METHODS = WARM_START_METHODS


# ------------------- compile-ahead shape predictors (search.MultiSearch)


def _es_cfg_for(method: str, budget: int, seed: int, kw: Dict) -> ESConfig:
    """The ESConfig the method's factory would build — the factories'
    default arithmetic, re-expressed for shape prediction."""
    params = dict(kw)
    for k in ("warm_seeds", "resume_state", "state_out"):
        params.pop(k, None)       # runtime extras never reach ESConfig
    if method == "sparsemap":
        params.setdefault("pop_size", int(min(100, max(24, budget // 20))))
    elif method == "sage_like":
        base = dict(use_hshi=False, use_custom_ops=False, pop_size=64)
        base.update(params)
        params = base
    elif method == "pfce_es":
        base = dict(use_hshi=False, use_custom_ops=False)
        base.update(params)
        params = base
    return ESConfig(budget=budget, seed=seed, **params)


def round1_rows(method: str, spec: GenomeSpec, budget: int, seed: int,
                **kw) -> Optional[int]:
    """Row count of the FIRST batch ``method``'s request generator will
    yield — the signature ``MultiSearch`` AOT-compiles ahead of round 1
    while the host runs the prologue.  ``None`` means the first round is
    not predictable (no job is scheduled; the dispatch falls back to
    ordinary jit and does NOT count as a compile-ahead miss unless the
    method's family was claimed)."""
    from .evolution import calib_plan
    if method in ("sparsemap", "pfce_es", "sage_like"):
        cfg = _es_cfg_for(method, budget, seed, kw)
        if cfg.use_hshi or cfg.use_custom_ops:
            n_ctx, n_smp = calib_plan(spec.length, cfg)
            return n_ctx * n_smp * spec.length
        return cfg.pop_size
    if method == "standard_es":
        # the first yield is the TRANSLATABLE subset of the seeded random
        # population — data-dependent, so simulate it exactly (cheap
        # numpy work on <= pop_size rows, same seed => same subset)
        from .direct_encoding import DirectValueSpec
        dspec = DirectValueSpec(spec)
        rng = np.random.default_rng(seed)
        pop = dspec.random_genomes(rng, int(kw.get("pop_size", 100)))
        _, index = dspec.translate_batch(pop)
        return len(index) or None
    if method == "random_mapper":
        return min(512, budget)
    if method == "pso":
        return int(kw.get("n_particles", 50))
    if method == "mcts":
        return min(int(kw.get("rollout_batch", 16)), budget)
    if method == "tbpsa":
        return min(int(kw.get("llambda", 48)), budget)
    if method == "ppo":
        return min(int(kw.get("batch", 64)), budget)
    if method == "dqn":
        return min(int(kw.get("batch", 32)), budget)
    return None


def steady_rows(method: str, spec: GenomeSpec, budget: int, seed: int,
                **kw) -> Optional[Tuple[int, ...]]:
    """Candidate per-round batch sizes ``method`` submits AFTER round 1
    — the decayed steady-state shapes the pad-watermark eventually
    settles on.  ``()`` means the task exhausts its budget in round 1
    and contributes nothing to later mega-batches; ``None`` means the
    steady shape is not predictable (the signature group then gets no
    steady-state job).  ES methods return (init-pop, children-per-gen):
    the post-calibration population round and the elitist per-generation
    child batch — the two shapes every later round is built from."""
    r1 = round1_rows(method, spec, budget, seed, **kw)
    if r1 is None:
        return None
    if method in ("sparsemap", "pfce_es", "sage_like"):
        # the ES generators always seed a population and run generations
        # once started, even when calibration consumed the paper budget
        cfg = _es_cfg_for(method, budget, seed, kw)
        n_elite = max(1, int(cfg.pop_size * cfg.elite_frac))
        return (cfg.pop_size, cfg.pop_size - n_elite)
    if method == "standard_es":
        return None     # translatable-subset row counts are data-dependent
    remaining = budget - r1
    if remaining <= 0:
        return ()
    if method == "random_mapper":
        return (min(512, remaining),)
    if method == "pso":
        return (int(kw.get("n_particles", 50)),)
    if method == "mcts":
        return (min(int(kw.get("rollout_batch", 16)), remaining),)
    if method == "tbpsa":
        return (min(int(kw.get("llambda", 48)), remaining),)
    if method == "ppo":
        return (min(int(kw.get("batch", 64)), remaining),)
    if method == "dqn":
        return (min(int(kw.get("batch", 32)), remaining),)
    return None


def segment_plan(method: str, spec: GenomeSpec, budget: int, seed: int,
                 **kw) -> Optional[Dict]:
    """Predicted :func:`es_ops.segment_shape_key` fields for a segmented
    task (``device_rounds > 1``), or ``None`` when the method will not
    yield DeviceSegments.  Feeds ``jax_cost.scan_compile_job`` /
    ``direct_scan_compile_job``."""
    rounds = int(kw.get("device_rounds", 1) or 1)
    if rounds <= 1 or method not in SEGMENT_METHODS:
        return None
    if method == "standard_es":
        B = int(kw.get("pop_size", 100))
        return dict(B=B, rounds=rounds,
                    n_parents=max(2, int(B * kw.get("parent_frac", 0.4))),
                    n_elite=max(1, int(B * kw.get("elite_frac", 0.1))),
                    genes_per=2, kind="direct", restart=0)
    cfg = _es_cfg_for(method, budget, seed, kw)
    B = cfg.pop_size
    return dict(B=B, rounds=rounds,
                n_parents=max(2, int(B * cfg.parent_frac)),
                n_elite=max(1, int(B * cfg.elite_frac)),
                genes_per=cfg.genes_per_mutation, kind="es",
                restart=int(cfg.stagnation_restart or 0))

#: method name -> (spec, platform, budget, seed, **kw) -> (Requests, _Budget)
REQUEST_METHODS: Dict[str, Callable] = {
    "sparsemap": _factory_sparsemap,
    "standard_es": _factory_standard_es,   # direct encoding (Fig. 18 "ES")
    "pfce_es": _factory_pfce_es,
    "sage_like": _factory_sage_like,
    "random_mapper": _gen_factory(random_mapper_requests),
    "pso": _gen_factory(pso_requests),
    "mcts": _gen_factory(mcts_requests),
    "tbpsa": _gen_factory(tbpsa_requests),
    "ppo": _gen_factory(ppo_requests),
    "dqn": _gen_factory(dqn_requests),
}


def make_requests(method: str, spec: GenomeSpec, platform, budget: int,
                  seed: int, **kw) -> Tuple[Requests, _Budget]:
    """Build the (request generator, budget tracker) pair for ``method``.
    Every method here can be driven sequentially (``_drive``) or as part
    of a concurrent ``search.MultiSearch`` fleet."""
    if method not in REQUEST_METHODS:
        raise KeyError(f"method {method!r} has no request generator; "
                       f"have {sorted(REQUEST_METHODS)}")
    return REQUEST_METHODS[method](spec, platform, budget, seed, **kw)


METHODS: Dict[str, Callable] = {
    "sparsemap": sparsemap,
    "standard_es": standard_es,     # direct encoding (Fig. 18 "ES")
    "pfce_es": pfce_es,             # Fig. 18 "PFCE"
    "pso": pso,
    "mcts": mcts,
    "tbpsa": tbpsa,
    "ppo": ppo,
    "dqn": dqn,
    "random_mapper": random_mapper,
    "sage_like": sage_like,
}
