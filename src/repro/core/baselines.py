"""Baseline optimizers (SparseMap §III.C, §V) + prior-work proxies.

Every method consumes the same genome representation (`GenomeSpec`), the
same batch evaluator and the same evaluation budget, and returns a
`SearchResult` so convergence curves are directly comparable (Fig. 17/18).

Prior-work proxies (§V):
* ``random_mapper``  — Sparseloop-Mapper-like: random mapping sampling under
  a fixed, manually chosen sparse strategy.
* ``sage_like``      — SAGE-like: sparse-strategy search under a fixed
  (balanced output-stationary) mapping.

Classical baselines (Fig. 17): PSO, MCTS, TBPSA, PPO, DQN — compact but
faithful implementations; they are *expected* to drown in invalid points,
which is the paper's point.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .encoding import GenomeSpec, all_permutations, cantor_encode
from .evolution import ESConfig, SearchResult, _Budget, evolve, lhs_init
from .mapping import N_LEVELS, balanced_mapping
from .sparse import MAX_FMT_GENES
from .workload import Workload


# ---------------------------------------------------------------- helpers


def _finish(tracker: _Budget, **extras) -> SearchResult:
    return SearchResult(best_edp=tracker.best,
                        best_genome=tracker.best_genome,
                        history=np.asarray(tracker.hist),
                        evals=tracker.evals, valid_evals=tracker.valid,
                        extras=extras)


def manual_sparse_genes(spec: GenomeSpec) -> Dict[int, int]:
    """A sensible hand-picked sparse strategy (the 'manually specified
    sparse strategy' a Sparseloop-Mapper user would fix): bitmask on the two
    innermost sub-dims of P and Q, uncompressed Z, skip P<->Q at compute."""
    fixed: Dict[int, int] = {}
    for tn in spec.tensor_names:
        seg = spec.segments[f"fmt_{tn}"]
        genes = [0, 0, 0, 1, 1] if tn != "Z" else [0] * MAX_FMT_GENES
        for i, v in enumerate(genes):
            fixed[seg.start + i] = v
    sg = spec.segments["sg"]
    fixed[sg.start + 0] = 0      # L2: none
    fixed[sg.start + 1] = 0      # L3: none
    fixed[sg.start + 2] = 6      # C: skip P<->Q
    return fixed


def fixed_mapping_genes(spec: GenomeSpec, n_pe: int, macs_per_pe: int
                        ) -> Dict[int, int]:
    """Freeze the mapping segment to the balanced OS mapping (SAGE-like)."""
    mp = balanced_mapping(spec.workload, n_pe, macs_per_pe)
    g = spec.encode_mapping(mp)
    fixed: Dict[int, int] = {}
    for seg_name in ("perm", "tiling"):
        seg = spec.segments[seg_name]
        for i in range(seg.start, seg.stop):
            fixed[i] = int(g[i])
    return fixed


# ---------------------------------------------------------------- proxies


def random_mapper(spec: GenomeSpec, batch_eval, budget: int, seed: int,
                  platform=None) -> SearchResult:
    """Sparseloop-Mapper-like: uniform random mapping search, sparse
    strategy fixed manually.  (The paper incorporates the manual settings
    into its random sampling space.)"""
    rng = np.random.default_rng(seed)
    tracker = _Budget(budget)
    fixed = manual_sparse_genes(spec)
    chunk = 512
    while not tracker.exhausted:
        g = spec.random_genomes(rng, min(chunk, budget - tracker.evals))
        for k, v in fixed.items():
            g[:, k] = v
        tracker.register(g, batch_eval(g))
    return _finish(tracker, method="random_mapper")


def sage_like(spec: GenomeSpec, batch_eval, budget: int, seed: int,
              platform) -> SearchResult:
    """SAGE-like: sparse-strategy search under a FIXED mapping (the
    balanced output-stationary mapping).

    SAGE knows its accelerator template, so the search space excludes
    format choices that are structurally impossible under the fixed
    mapping (formats on spatially-unrolled sub-dimensions stay
    uncompressed), and it starts from the engineer's uncompressed default.
    What it cannot do — the paper's point — is adapt the mapping itself.
    """
    from .cost_model import spatial_subdim_indices, tiled_subdims
    fixed = fixed_mapping_genes(spec, platform.n_pe, platform.macs_per_pe)
    # pin format genes of spatially-unrolled sub-dimensions to U
    genome0 = np.zeros(spec.length, dtype=np.int64)
    for k, v in fixed.items():
        genome0[k] = v
    mapping = spec.decode(genome0).mapping
    for tn in spec.tensor_names:
        seg = spec.segments[f"fmt_{tn}"]
        k = len(tiled_subdims(mapping, tn))
        for i in spatial_subdim_indices(mapping, tn):
            gidx = i + max(MAX_FMT_GENES - k, 0)
            if 0 <= gidx < MAX_FMT_GENES:
                fixed[seg.start + gidx] = 0
    cfg = ESConfig(budget=budget, seed=seed, use_hshi=False,
                   use_custom_ops=False, pop_size=64)
    return evolve(spec, batch_eval, cfg, fixed_genes=fixed,
                  seeds=genome0[None, :])


# ---------------------------------------------------------------- PSO


def pso(spec: GenomeSpec, batch_eval, budget: int, seed: int,
        platform=None, n_particles: int = 50,
        w: float = 0.72, c1: float = 1.49, c2: float = 1.49) -> SearchResult:
    rng = np.random.default_rng(seed)
    tracker = _Budget(budget)
    L = spec.length
    ub = spec.gene_ub.astype(np.float64)
    x = rng.random((n_particles, L)) * ub
    v = (rng.random((n_particles, L)) - 0.5) * ub * 0.2
    pbest_x = x.copy()
    pbest_f = np.full(n_particles, np.inf)
    gbest_x = x[0].copy()
    gbest_f = np.inf
    while not tracker.exhausted:
        g = spec.clip(x.astype(np.int64))
        edp = tracker.register(g, batch_eval(g))
        improved = edp < pbest_f
        pbest_f = np.where(improved, edp, pbest_f)
        pbest_x[improved] = x[improved]
        i = int(np.argmin(pbest_f))
        if pbest_f[i] < gbest_f:
            gbest_f, gbest_x = pbest_f[i], pbest_x[i].copy()
        r1, r2 = rng.random((2, n_particles, L))
        v = w * v + c1 * r1 * (pbest_x - x) + c2 * r2 * (gbest_x[None] - x)
        x = np.clip(x + v, 0, ub - 1e-6)
    return _finish(tracker, method="pso")


# ---------------------------------------------------------------- MCTS


def mcts(spec: GenomeSpec, batch_eval, budget: int, seed: int,
         platform=None, max_children: int = 8, c_ucb: float = 1.4,
         rollout_batch: int = 16) -> SearchResult:
    """Gene-by-gene tree search with UCB1 selection and random rollouts.
    Large per-gene ranges are subsampled to ``max_children`` branches
    (standard progressive-widening practice)."""
    rng = np.random.default_rng(seed)
    tracker = _Budget(budget)
    L = spec.length

    class Node:
        __slots__ = ("depth", "children", "visits", "value", "vals")

        def __init__(self, depth: int):
            self.depth = depth
            self.children: Dict[int, Node] = {}
            self.visits = 0
            self.value = 0.0
            self.vals: Optional[np.ndarray] = None

    root = Node(0)

    def reward(edp: float) -> float:
        if not np.isfinite(edp):
            return 0.0
        return 1.0 / (1.0 + math.log10(max(edp, 1.0)))

    while not tracker.exhausted:
        node = root
        prefix: List[int] = []
        # selection / expansion
        while node.depth < L:
            if node.vals is None:
                k = min(max_children, int(spec.gene_ub[node.depth]))
                node.vals = rng.choice(spec.gene_ub[node.depth], size=k,
                                       replace=False)
            unvisited = [v for v in node.vals if v not in node.children]
            if unvisited:
                v = int(unvisited[0])
                node.children[v] = Node(node.depth + 1)
                prefix.append(v)
                node = node.children[v]
                break
            # UCB1
            best_v, best_u = None, -np.inf
            for v, ch in node.children.items():
                u = (ch.value / max(ch.visits, 1) +
                     c_ucb * math.sqrt(math.log(max(node.visits, 1) + 1) /
                                       max(ch.visits, 1)))
                if u > best_u:
                    best_u, best_v = u, v
            prefix.append(int(best_v))
            node = node.children[int(best_v)]
        # rollout: complete randomly (batched)
        n = min(rollout_batch, budget - tracker.evals)
        g = spec.random_genomes(rng, n)
        g[:, :len(prefix)] = np.asarray(prefix, dtype=np.int64)[None, :]
        edp = tracker.register(g, batch_eval(g))
        r = max(reward(float(e)) for e in edp)
        # backprop along path
        node = root
        node.visits += 1
        node.value += r
        for v in prefix:
            if v in node.children:
                node = node.children[v]
                node.visits += 1
                node.value += r
            else:
                break
    return _finish(tracker, method="mcts")


# ---------------------------------------------------------------- TBPSA


def tbpsa(spec: GenomeSpec, batch_eval, budget: int, seed: int,
          platform=None, mu: int = 12, llambda: int = 48) -> SearchResult:
    """Test-based population-size-adaptation ES (nevergrad's TBPSA family):
    gaussian search distribution in the continuous relaxation, mean/state
    updated from the mu best of each lambda batch."""
    rng = np.random.default_rng(seed)
    tracker = _Budget(budget)
    L = spec.length
    ub = spec.gene_ub.astype(np.float64)
    mean = ub / 2.0
    sigma = ub / 4.0
    while not tracker.exhausted:
        n = min(llambda, budget - tracker.evals)
        x = mean[None] + rng.standard_normal((n, L)) * sigma[None]
        g = spec.clip(np.clip(x, 0, ub - 1e-6).astype(np.int64))
        edp = tracker.register(g, batch_eval(g))
        order = np.argsort(edp)[:mu]
        sel = x[order]
        new_mean = sel.mean(axis=0)
        sigma = 0.9 * sigma + 0.1 * (sel.std(axis=0) + 1e-3)
        mean = np.clip(new_mean, 0, ub - 1e-6)
    return _finish(tracker, method="tbpsa")


# ---------------------------------------------------------------- PPO-lite


def ppo(spec: GenomeSpec, batch_eval, budget: int, seed: int,
        platform=None, batch: int = 64, lr: float = 0.15,
        clip_eps: float = 0.2, epochs: int = 3) -> SearchResult:
    """Factorized-categorical policy over genes, trained with the clipped
    PPO objective on a normalized -log10(EDP) reward; invalid designs give
    reward -1 (the sparse-reward regime the paper §I points at)."""
    rng = np.random.default_rng(seed)
    tracker = _Budget(budget)
    L = spec.length
    maxv = int(spec.gene_ub.max())
    logits = np.zeros((L, maxv))
    for j in range(L):
        logits[j, spec.gene_ub[j]:] = -1e9
    r_mean, r_std = 0.0, 1.0

    def softmax(z):
        z = z - z.max(axis=-1, keepdims=True)
        e = np.exp(z)
        return e / e.sum(axis=-1, keepdims=True)

    while not tracker.exhausted:
        n = min(batch, budget - tracker.evals)
        pi = softmax(logits)                       # (L, V)
        # vectorized inverse-CDF sampling: one uniform matrix, all genes
        cdf = np.cumsum(pi, axis=-1)               # (L, V)
        u = rng.random((n, L))
        g = (u[:, :, None] > cdf[None, :, :]).sum(axis=-1)
        g = np.minimum(g, spec.gene_ub[None, :] - 1).astype(np.int64)
        edp = tracker.register(g, batch_eval(g))
        rew = np.where(np.isfinite(edp), 0.0, -1.0)
        ok = np.isfinite(edp)
        if ok.any():
            rew[ok] = -np.log10(edp[ok])
            r_mean = 0.9 * r_mean + 0.1 * rew[ok].mean()
            r_std = 0.9 * r_std + 0.1 * (rew[ok].std() + 1e-6)
            rew[ok] = (rew[ok] - r_mean) / max(r_std, 1e-6)
        adv = rew - rew.mean()
        old_pi = pi.copy()
        onehot = np.zeros((n, L, maxv))
        onehot[np.arange(n)[:, None], np.arange(L)[None, :], g] = 1.0
        for _ in range(epochs):
            pi = softmax(logits)
            ratio = (pi[None, :, :] * onehot).sum(-1) / \
                np.maximum((old_pi[None, :, :] * onehot).sum(-1), 1e-9)
            clipped = np.clip(ratio, 1 - clip_eps, 1 + clip_eps)
            use = (np.minimum(ratio * adv[:, None], clipped * adv[:, None])
                   == ratio * adv[:, None])
            w_adv = adv[:, None] * use                     # (n, L)
            grad = (onehot - pi[None, :, :]) * w_adv[:, :, None]
            logits += lr * grad.mean(axis=0)
            for j in range(L):
                logits[j, spec.gene_ub[j]:] = -1e9
    return _finish(tracker, method="ppo")


# ---------------------------------------------------------------- DQN-lite


def dqn(spec: GenomeSpec, batch_eval, budget: int, seed: int,
        platform=None, batch: int = 32, lr: float = 0.2,
        eps_start: float = 0.9, eps_end: float = 0.05,
        gamma: float = 0.98) -> SearchResult:
    """Sequential gene-picking MDP with a factored Q table (gene position x
    value), epsilon-greedy, TD(0) bootstrapping along the episode."""
    rng = np.random.default_rng(seed)
    tracker = _Budget(budget)
    L = spec.length
    maxv = int(spec.gene_ub.max())
    q = np.zeros((L, maxv))
    for j in range(L):
        q[j, spec.gene_ub[j]:] = -1e9
    step = 0
    total_steps = max(budget // batch, 1)
    while not tracker.exhausted:
        eps = eps_start + (eps_end - eps_start) * min(step / total_steps, 1)
        n = min(batch, budget - tracker.evals)
        # vectorized epsilon-greedy: out-of-range q is -1e9, so the full-
        # row argmax is the masked argmax
        explore = rng.random((n, L)) < eps
        rand_vals = rng.integers(0, spec.gene_ub, size=(n, L),
                                 dtype=np.int64)
        greedy = np.argmax(q, axis=1).astype(np.int64)
        g = np.where(explore, rand_vals, greedy[None, :])
        edp = tracker.register(g, batch_eval(g))
        rew = np.where(np.isfinite(edp), 0.0, -1.0)
        ok = np.isfinite(edp)
        rew[ok] = -np.log10(np.maximum(edp[ok], 1.0)) / 10.0
        for i in range(n):
            for j in reversed(range(L)):
                target = rew[i] if j == L - 1 else \
                    gamma * np.max(q[j + 1, :spec.gene_ub[j + 1]])
                q[j, g[i, j]] += lr * (target - q[j, g[i, j]])
        step += 1
    return _finish(tracker, method="dqn")


# ---------------------------------------------------------------- registry


def sparsemap_setup(spec: GenomeSpec, platform, budget: int, seed: int,
                    **kw) -> Tuple[ESConfig, Optional[np.ndarray]]:
    """Shared SparseMap search setup: the ESConfig (population scaled with
    the budget so calibration + HSHI never starve the evolutionary phase
    at CI-scale budgets) and the engineer-default seed genomes.  Used by
    both :func:`sparsemap` and ``search.MultiSearch`` so single and
    concurrent searches are configured identically."""
    if "pop_size" not in kw:
        kw["pop_size"] = int(min(100, max(24, budget // 20)))
    cfg = ESConfig(budget=budget, seed=seed, **kw)
    # seed the initial population with the engineer-default designs that
    # the prior-work baselines also start from (balanced OS mapping with
    # uncompressed / manual sparse strategies) — the joint search then
    # explores outward from them.  Implementation enhancement over the
    # paper, documented in DESIGN.md §6.
    seeds = None
    if platform is not None:
        g0 = np.zeros(spec.length, dtype=np.int64)
        for k, v in fixed_mapping_genes(spec, platform.n_pe,
                                        platform.macs_per_pe).items():
            g0[k] = v
        g1 = g0.copy()
        for k, v in manual_sparse_genes(spec).items():
            g1[k] = v
        seeds = np.stack([g0, g1])
    return cfg, seeds


def sparsemap(spec: GenomeSpec, batch_eval, budget: int, seed: int,
              platform=None, **kw) -> SearchResult:
    cfg, seeds = sparsemap_setup(spec, platform, budget, seed, **kw)
    return evolve(spec, batch_eval, cfg, seeds=seeds)


def standard_es(spec: GenomeSpec, batch_eval, budget: int, seed: int,
                platform=None) -> SearchResult:
    """Fig. 18 curve 'ES': standard ES with LHS init on the DIRECT value
    encoding (no prime-factor/cantor encoding), uniform operators."""
    from .direct_encoding import direct_standard_es
    return direct_standard_es(spec, batch_eval, budget, seed, platform)


def pfce_es(spec: GenomeSpec, batch_eval, budget: int, seed: int,
            platform=None) -> SearchResult:
    """Fig. 18 curve 'PFCE': prime-factor + cantor encoding only (the
    encoding is intrinsic to GenomeSpec; custom operators + HSHI off)."""
    cfg = ESConfig(budget=budget, seed=seed, use_hshi=False,
                   use_custom_ops=False)
    return evolve(spec, batch_eval, cfg)


METHODS: Dict[str, Callable] = {
    "sparsemap": sparsemap,
    "standard_es": standard_es,     # direct encoding (Fig. 18 "ES")
    "pfce_es": pfce_es,             # Fig. 18 "PFCE"
    "pso": pso,
    "mcts": mcts,
    "tbpsa": tbpsa,
    "ppo": ppo,
    "dqn": dqn,
    "random_mapper": random_mapper,
    "sage_like": sage_like,
}
