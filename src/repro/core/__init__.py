"""SparseMap core: joint mapping x sparse-strategy DSE for sparse tensor
accelerators via an enhanced evolution strategy (Zhao et al., 2025).

Public entry points:
    repro.core.workload   — SpMM/SpConv workload definitions (Table III)
    repro.core.accel      — platform models (Table II) + TPU constants
    repro.core.arch       — ArchSpec: declared memory hierarchies; the
                            whole mapping/cost/genome/search stack derives
                            its structure from one (register_arch/as_arch;
                            non-default topologies in repro.configs.archs)
    repro.core.search     — run("sparsemap"| baselines, workload, platform)
                            + MultiSearch / run_sweep for concurrent
                            multi-workload searches on shared compilations
    repro.core.evolution  — the ES engine (HSHI, annealing mutation, SAC)
    repro.core.autoshard  — beyond-paper: the same ES over the distributed
                            sharding space of this framework
"""
from . import accel, workload
from .arch import ARCH_SPARSEMAP, ArchSpec, StorageLevel, as_arch
from .cost_model import CostReport, Design, evaluate
from .encoding import GenomeSpec
from .evolution import ESConfig, SearchResult, evolve
from .jax_cost import JaxCostModel
from .search import MultiSearch, SearchTask, run_sweep
from .workload import Workload, batched_spmm, spconv, spmm
