"""First-class accelerator architecture specs (the ArchSpec subsystem).

SparseMap (§II.B, Fig. 3/4) fixes one topology — DRAM -> GLB -> PE array
-> MACs — and the seed stack hardwired it as module constants spread over
``mapping`` / ``jax_cost`` / ``sparse`` / ``accel``.  This module lifts the
memory hierarchy into data: an :class:`ArchSpec` is an ordered list of
:class:`StorageLevel`\\ s, each carrying capacity / fill-energy / bandwidth
numbers plus the mapping levels it owns (one temporal level per store, and
an optional spatial level directly above it when the store is replicated
``fanout`` times under its parent).  Everything the stack used to hardcode
is *derived* here:

* loop-slot count (``n_levels``) and level names,
* temporal / spatial level index sets,
* outer / inner mapping-level sets per store (the loop-nest reuse rule),
* S/G sites (one per store that declares one, plus compute ``"C"``),
* genome segment widths (``n_levels`` perm genes, tiling genes in
  ``[0, n_levels)``, ``len(sg_sites)`` S/G genes),
* per-level word widths (:attr:`StorageLevel.word_bytes`, default the
  global 16-bit operand width) and per-edge NoC shape
  (:class:`NoCSpec`: multicast for reads, in-network reduction for the
  output — the knobs that open systolic-mesh and quantized-edge
  accelerator classes),
* the JAX kernel's constant tables and traced parameter vector.

Two ArchSpecs with the same :class:`Topology` (structure) but different
numbers — e.g. the paper's edge/mobile/cloud platforms — share one XLA
compilation: the structure is baked into the kernel, the numbers are
traced arguments.

The paper topology ships as :data:`ARCH_SPARSEMAP` (the default
everywhere; numerically bit-identical to the pre-ArchSpec code).  New
accelerator classes are config, not code: build an ArchSpec, register it
with :func:`register_arch`, and the whole mapping/cost/genome/search stack
runs on it (see ``repro.configs.archs`` for a 2-store Maple-style edge
chip and a 4-store clustered cloud chip, and COMPAT.md for the contract).
"""
from __future__ import annotations

import dataclasses
import hashlib
from functools import cached_property, lru_cache
from typing import Dict, Optional, Tuple, Union

from .accel import Platform
from .workload import WORD_BYTES

# Energy groups: ((name, (component, ...)), ...).  A group becomes one
# named entry of the numpy cost model's energy breakdown (its components
# summed first); the JAX kernel flattens all components of an edge and
# sums them left-to-right in float32 — both reproduce the seed
# implementation's exact arithmetic order for the paper topology.
EnergyGroups = Tuple[Tuple[str, Tuple[float, ...]], ...]


def _noc_scheme(flag: Union[bool, str]) -> str:
    """Normalize a NoC scheme declaration to "all" / "none" / "frac".

    ``True`` and ``"all"`` mean full multicast (or full in-network
    reduction); ``False`` and ``"none"`` mean pure unicast (or
    all-partials).  Any OTHER non-empty string — ``"row"``, ``"col"``,
    ``"cluster"``, ... — declares a *fractional* scheme: the label is
    kept for display, but structurally every fractional scheme is the
    same kernel shape ("frac"); its numeric discount fanout rides in the
    traced param vector so a family of same-scheme archs shares one XLA
    compilation."""
    if flag is True or flag == "all":
        return "all"
    if flag is False or flag == "none":
        return "none"
    if isinstance(flag, str) and flag:
        return "frac"
    raise ValueError(
        f"NoC scheme must be True/'all', False/'none', or a fractional "
        f"scheme label ('row', 'col', 'cluster', ...); got {flag!r}")


def _noc_topo_code(flag: Union[bool, str]) -> Union[bool, str]:
    """The Topology-tuple encoding of a scheme: the legacy booleans for
    all/none (existing fingerprints are unchanged) and the literal string
    ``"frac"`` for every fractional scheme (labels never split
    compilation)."""
    s = _noc_scheme(flag)
    return True if s == "all" else False if s == "none" else "frac"


@dataclasses.dataclass(frozen=True)
class NoCSpec:
    """Network-on-chip shape of the fill edge into a storage level: how
    traffic crossing the edge scales with the spatial fanout unrolled
    beneath it.

    ``multicast=True`` (tree/bus-style distribution, the paper topology's
    implicit NoC) means an irrelevant spatial loop below the edge sends
    ONE copy of a read tile to all instances; ``False`` (mesh-style
    store-and-forward unicast, the systolic-array model) means every
    instance's copy crosses the edge, multiplying read traffic by the
    loop bound.  ``reduction`` is the same choice for the OUTPUT tensor:
    ``True`` reduces spatially-partitioned partial sums in-network (one
    reduced result crosses the edge per tile), ``False`` sends every
    instance's partial sums across.

    Between the two extremes sit *fractional* schemes, declared with a
    string label and a numeric ``*_fanout``: ``multicast="row",
    multicast_fanout=14`` models a row-wise bus on a 2-D mesh (one copy
    serves each row of 14 instances), ``reduction="cluster",
    reduction_fanout=8`` a cluster-local adder tree (partials reduce
    within clusters of 8, one partial per cluster crosses the edge).
    With ``S`` spatial instances needing a tile the edge carries
    ``max(S / fanout, 1)`` copies — ``"all"`` is the ``fanout -> inf``
    limit, ``"none"`` is ``fanout = 1``.

    The *scheme* is structural: it shapes the compiled kernel and is part
    of the Topology fingerprint (as the normalized code, so different
    labels and fanouts never split compilation).  The *fanout* is a
    number riding in ``ArchSpec.param_vector`` — a family of same-scheme
    archs differing only in discount factors shares one XLA compilation.
    """

    multicast: Union[bool, str] = True
    reduction: Union[bool, str] = True
    multicast_fanout: Optional[float] = None
    reduction_fanout: Optional[float] = None

    def __post_init__(self):
        for kind, flag, fan in (
                ("multicast", self.multicast, self.multicast_fanout),
                ("reduction", self.reduction, self.reduction_fanout)):
            scheme = _noc_scheme(flag)      # raises on junk values
            if scheme == "frac":
                if fan is None or not fan > 0:
                    raise ValueError(
                        f"NoCSpec {kind}={flag!r} is a fractional scheme "
                        f"and needs {kind}_fanout > 0, got {fan!r}")
            elif fan is not None:
                raise ValueError(
                    f"NoCSpec {kind}={flag!r} takes no {kind}_fanout "
                    f"(only fractional schemes carry a numeric discount)")

    @property
    def multicast_scheme(self) -> str:
        return _noc_scheme(self.multicast)

    @property
    def reduction_scheme(self) -> str:
        return _noc_scheme(self.reduction)


#: The default edge NoC: full multicast + in-network reduction (exactly
#: the pre-NoC accounting, so existing topologies are unchanged).
NOC_DEFAULT = NoCSpec()


@dataclasses.dataclass(frozen=True)
class StorageLevel:
    """One storage level of the hierarchy, outermost (DRAM-like) first.

    The *edge* that fills this level from its parent owns one temporal
    mapping level; if ``fanout > 1`` the edge additionally owns a spatial
    mapping level directly below the temporal one (``fanout`` parallel
    instances of this level and everything beneath it).  The outermost
    level has no fill edge; its energy/bandwidth fields are ignored.
    """

    name: str
    capacity_bytes: Optional[float] = None       # None = unbounded
    fill_energy: EnergyGroups = ()               # pJ/byte into this level
    fanout: int = 1                              # spatial instances
    sg_site: Optional[str] = None                # S/G site filtering the
    #                                              edge OUT of this level
    fill_bandwidth_bytes_per_cycle: Optional[float] = None  # None = inf
    # datawidth of one element held in this level, in bytes.  None = the
    # global default (workload.WORD_BYTES, the paper's 16-bit operands).
    # Fills INTO this level and this level's occupancy are accounted at
    # this width (a quantized edge chip stores 1-byte words on-chip while
    # keeping the same topology otherwise).  Ignored on the outermost
    # level, like the energy/NoC fields: every edge is priced at its
    # DESTINATION store's width and the backing store is never filled or
    # capacity-checked.
    word_bytes: Optional[float] = None
    # NoC shape of the fill edge into this level (multicast/reduction);
    # ignored on the outermost level, which has no fill edge.
    noc: NoCSpec = NOC_DEFAULT
    # whether this store owns a spatial mapping level.  None derives it
    # from ``fanout > 1``; pass True to keep the level in the genome even
    # when the cap is 1 (e.g. the paper's edge platform has 1 MAC/PE but
    # the SAME 5-level mapping structure as mobile/cloud — an L3_S factor
    # > 1 is simply invalid there).
    spatial: Optional[bool] = None

    @property
    def is_spatial(self) -> bool:
        return self.fanout > 1 if self.spatial is None else self.spatial

    def flat_energy(self) -> Tuple[float, ...]:
        return tuple(c for _, comps in self.fill_energy for c in comps)


@dataclasses.dataclass(frozen=True)
class Topology:
    """The structural fingerprint of an ArchSpec: everything that shapes
    the compiled kernel (loop slots, site wiring, which parameters exist)
    but none of the numbers.  ArchSpecs sharing a Topology share genome
    layouts and XLA compilations."""

    store_names: Tuple[str, ...]
    has_capacity: Tuple[bool, ...]               # per store
    has_spatial: Tuple[bool, ...]                # per EDGE (stores[1:])
    n_energy_comps: Tuple[int, ...]              # per edge
    edge_site: Tuple[Optional[int], ...]         # per edge: site idx | None
    has_bandwidth: Tuple[bool, ...]              # per edge
    sg_sites: Tuple[str, ...]                    # store sites + "C"
    # NoC scheme per edge (structural: changes the fills accounting).
    # Entries are the legacy booleans for the all/none schemes (existing
    # fingerprints unchanged) or the literal "frac" for any fractional
    # scheme — the numeric fanout is traced, never part of the topology.
    noc_multicast: Tuple[Union[bool, str], ...] = ()
    noc_reduction: Tuple[Union[bool, str], ...] = ()
    # True when every level stores the global default word width; the
    # kernel then bakes the width as a constant (the pre-word-width code
    # path, bit-identical for existing topologies).  Custom-width specs
    # trace per-edge widths from the param vector instead, so e.g. a
    # family of 1-byte-word chips still shares one compilation.
    uniform_word_bytes: bool = True

    @cached_property
    def fingerprint(self) -> str:
        """Short stable tag used in compilation signatures."""
        h = hashlib.sha1(repr(dataclasses.astuple(self)).encode())
        return h.hexdigest()[:8]


class ArchSpec:
    """An ordered memory hierarchy plus compute, with all derived
    mapping/genome/kernel structure cached.  Hashable by identity-free
    content, so it can key jit caches directly."""

    def __init__(self, name: str, levels: Tuple[StorageLevel, ...],
                 e_mac: float = 0.8, clock_hz: float = 1.0e9):
        if len(levels) < 2:
            raise ValueError("ArchSpec needs >= 2 storage levels "
                             "(a backing store and at least one buffer)")
        if levels[0].is_spatial:
            raise ValueError("the outermost (backing) store cannot be "
                             "spatially replicated")
        if levels[0].capacity_bytes is not None:
            raise ValueError(
                "the outermost (backing) store is never capacity-checked;"
                " leave capacity_bytes=None (a value would only split "
                "compilation signatures for identical kernels)")
        names = [lv.name for lv in levels]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate storage level names: {names}")
        sites = [lv.sg_site for lv in levels if lv.sg_site is not None]
        if len(set(sites)) != len(sites):
            raise ValueError(f"duplicate S/G site names: {sites}")
        if "C" in sites:
            raise ValueError('"C" is reserved for the compute S/G site')
        if levels[-1].sg_site is not None:
            raise ValueError("the innermost store's outgoing edge IS "
                             "compute; give it sg_site=None (site 'C' "
                             "is implicit)")
        for lv in levels:
            if lv.word_bytes is not None and not lv.word_bytes > 0:
                raise ValueError(
                    f"store {lv.name!r}: word_bytes must be > 0, got "
                    f"{lv.word_bytes}")
        self.name = name
        self.levels = tuple(levels)
        self.e_mac = float(e_mac)
        self.clock_hz = float(clock_hz)
        self._build()

    # ------------------------------------------------------------ build
    def _build(self) -> None:
        lv = self.levels
        self.n_stores = len(lv)
        self.store_names = tuple(l.name for l in lv)
        self.store_index: Dict[str, int] = {
            l.name: k for k, l in enumerate(lv)}

        # mapping levels: per edge k (into store k, k >= 1) a temporal
        # level L{k}_T, then a spatial level L{k}_S when fanout > 1
        names = []
        level_edge = []          # mapping level -> edge index (store k - 1)
        spatial = []
        spatial_store = []       # spatial level -> store index it replicates
        for k in range(1, self.n_stores):
            names.append(f"L{k}_T")
            level_edge.append(k - 1)
            spatial.append(False)
            if lv[k].is_spatial:
                names.append(f"L{k}_S")
                level_edge.append(k - 1)
                spatial.append(True)
                spatial_store.append(k)
        self.level_names = tuple(names)
        self.n_levels = len(names)
        self.is_spatial = tuple(spatial)
        self.spatial_levels = tuple(
            i for i, s in enumerate(spatial) if s)
        self.temporal_levels = tuple(
            i for i, s in enumerate(spatial) if not s)
        self.level_edge = tuple(level_edge)
        self.spatial_store = tuple(spatial_store)

        self.n_edges = self.n_stores - 1
        # fills INTO store k see the loops of edges 1..k as the outer
        # nest; the tile held inside spans the levels below
        self.outer_levels_for: Dict[str, Tuple[int, ...]] = {}
        self.inner_levels_for: Dict[str, Tuple[int, ...]] = {}
        for k in range(1, self.n_stores):
            self.outer_levels_for[lv[k].name] = tuple(
                i for i, e in enumerate(level_edge) if e <= k - 1)
            self.inner_levels_for[lv[k].name] = tuple(
                i for i, e in enumerate(level_edge) if e > k - 1)

        # S/G sites: per-store declared sites in store order, then "C"
        store_sites = [l.sg_site for l in lv if l.sg_site is not None]
        self.sg_sites: Tuple[str, ...] = tuple(store_sites) + ("C",)
        site_idx = {s: i for i, s in enumerate(store_sites)}
        # edge k (into store k) is filtered by the site of store k-1
        self.edge_site: Tuple[Optional[int], ...] = tuple(
            site_idx.get(lv[k - 1].sg_site)
            for k in range(1, self.n_stores))

        # capacity-checked stores (store index, name, capacity)
        self.capacity_stores: Tuple[Tuple[int, str, float], ...] = tuple(
            (k, lv[k].name, float(lv[k].capacity_bytes))
            for k in range(1, self.n_stores)
            if lv[k].capacity_bytes is not None)
        # bandwidth-limited edges (edge index, bytes/cycle)
        self.bw_edges: Tuple[Tuple[int, float], ...] = tuple(
            (k - 1, float(lv[k].fill_bandwidth_bytes_per_cycle))
            for k in range(1, self.n_stores)
            if lv[k].fill_bandwidth_bytes_per_cycle is not None)
        self.edge_energy: Tuple[EnergyGroups, ...] = tuple(
            lv[k].fill_energy for k in range(1, self.n_stores))

        # per-store word widths (None -> the global default) and the
        # per-edge view: edge k-1 fills store k, so its traffic and the
        # store's occupancy are both accounted at store k's width
        self.store_word_bytes: Tuple[float, ...] = tuple(
            float(l.word_bytes) if l.word_bytes is not None
            else float(WORD_BYTES) for l in lv)
        self.edge_word_bytes: Tuple[float, ...] = self.store_word_bytes[1:]
        # NoC descriptor per edge (the filled store's declared NoC)
        self.edge_noc: Tuple[NoCSpec, ...] = tuple(
            lv[k].noc for k in range(1, self.n_stores))

        self.topology = Topology(
            store_names=self.store_names,
            has_capacity=tuple(l.capacity_bytes is not None for l in lv),
            has_spatial=tuple(l.is_spatial for l in lv[1:]),
            n_energy_comps=tuple(len(lv[k].flat_energy())
                                 for k in range(1, self.n_stores)),
            edge_site=self.edge_site,
            has_bandwidth=tuple(
                l.fill_bandwidth_bytes_per_cycle is not None
                for l in lv[1:]),
            sg_sites=self.sg_sites,
            noc_multicast=tuple(_noc_topo_code(n.multicast)
                                for n in self.edge_noc),
            noc_reduction=tuple(_noc_topo_code(n.reduction)
                                for n in self.edge_noc),
            uniform_word_bytes=all(
                w == float(WORD_BYTES) for w in self.edge_word_bytes),
        )

    # ------------------------------------------------------ conveniences
    def spatial_caps(self) -> Tuple[int, ...]:
        """Fanout cap per spatial mapping level, in level order."""
        return tuple(self.levels[k].fanout for k in self.spatial_store)

    def store(self, name: str) -> StorageLevel:
        return self.levels[self.store_index[name]]

    def word_bytes_of(self, store_name: str) -> float:
        """Resolved datawidth of one element held in ``store_name``."""
        return self.store_word_bytes[self.store_index[store_name]]

    def param_vector(self):
        """The traced parameter vector the JAX kernel consumes:
        [spatial caps | capacities | flat edge-energy components |
        edge bandwidths | e_mac | per-edge word widths | fractional NoC
        fanouts], float32.  Two same-topology specs differ only here, so
        they share compilations (uniform-default-width topologies bake
        the width as a kernel constant and simply never read the width
        tail; the NoC tail only exists for edges declaring a fractional
        scheme, in edge order, multicast fanout before reduction
        fanout)."""
        import numpy as np
        vals = (list(self.spatial_caps()) +
                [c for _, _, c in self.capacity_stores] +
                [c for groups in self.edge_energy
                 for _, comps in groups for c in comps] +
                [bw for _, bw in self.bw_edges] +
                [self.e_mac] +
                list(self.edge_word_bytes))
        for n in self.edge_noc:
            if n.multicast_scheme == "frac":
                vals.append(n.multicast_fanout)
            if n.reduction_scheme == "frac":
                vals.append(n.reduction_fanout)
        return np.asarray(vals, dtype=np.float32)

    def describe(self) -> str:
        rows = []
        for k, l in enumerate(self.levels):
            bits = [f"store {l.name}"]
            if l.capacity_bytes is not None:
                bits.append(f"{l.capacity_bytes / 1024:.0f}KB")
            if k > 0 and l.fanout > 1:
                bits.append(f"x{l.fanout}")
            if l.sg_site:
                bits.append(f"S/G {l.sg_site}")
            if l.word_bytes is not None:
                bits.append(f"{l.word_bytes:g}B-word")
            if k > 0 and l.noc != NOC_DEFAULT:
                def _bit(scheme, label, fanout, full, empty):
                    if scheme == "all":
                        return full
                    if scheme == "none":
                        return empty
                    return f"{full}:{label}/{fanout:g}"
                bits.append(
                    "noc["
                    + _bit(l.noc.multicast_scheme, l.noc.multicast,
                           l.noc.multicast_fanout, "mc", "ucast") + "/"
                    + _bit(l.noc.reduction_scheme, l.noc.reduction,
                           l.noc.reduction_fanout, "red", "all-partials")
                    + "]")
            rows.append(" ".join(bits))
        rows.append(f"levels: {' '.join(self.level_names)}; "
                    f"sites: {'/'.join(self.sg_sites)}")
        return "\n".join(rows)

    # hashability: by content, so lru_cache can key on the spec
    def _key(self) -> Tuple:
        return (self.name, self.levels, self.e_mac, self.clock_hz)

    def __hash__(self) -> int:
        return hash(self._key())

    def __eq__(self, other) -> bool:
        return isinstance(other, ArchSpec) and self._key() == other._key()

    def __repr__(self) -> str:
        return (f"ArchSpec({self.name!r}, {self.n_stores} stores, "
                f"{self.n_levels} mapping levels, "
                f"sites={self.sg_sites})")


# ---------------------------------------------------------------- paper


@lru_cache(maxsize=None)
def arch_from_platform(p: Platform) -> ArchSpec:
    """The paper topology (Fig. 3a: DRAM -> GLB -> PE array -> MACs)
    populated with a :class:`repro.core.accel.Platform`'s Table II
    numbers.  All platforms share one Topology, hence one compilation."""
    return ArchSpec(
        name=p.name,
        levels=(
            StorageLevel("dram"),
            StorageLevel(
                "glb", capacity_bytes=p.glb_bytes,
                fill_energy=(("dram", (p.e_dram_per_byte,)),),
                sg_site="L2",
                fill_bandwidth_bytes_per_cycle=p.dram_bytes_per_cycle),
            StorageLevel(
                "pebuf", capacity_bytes=p.pe_buffer_bytes,
                fill_energy=(("glb", (p.scaled_glb_energy(),
                                      p.e_noc_per_byte)),),
                fanout=p.n_pe, sg_site="L3", spatial=True),
            StorageLevel(
                "reg",
                fill_energy=(("pebuf", (p.scaled_pebuf_energy(),)),
                             ("reg", (p.e_reg_per_byte,))),
                fanout=p.macs_per_pe, spatial=True),
        ),
        e_mac=p.e_mac, clock_hz=p.clock_hz)


def _sparsemap_default() -> ArchSpec:
    from .accel import CLOUD
    spec = arch_from_platform(CLOUD)
    return ArchSpec(name="sparsemap", levels=spec.levels,
                    e_mac=spec.e_mac, clock_hz=spec.clock_hz)


#: The paper topology (cloud-class numbers) — the default arch everywhere.
ARCH_SPARSEMAP = _sparsemap_default()


# ---------------------------------------------------------------- registry

_REGISTRY: Dict[str, ArchSpec] = {}


def register_arch(spec: ArchSpec, replace: bool = False) -> ArchSpec:
    from .accel import PLATFORMS
    if spec.name in PLATFORMS:
        # as_arch resolves platform names FIRST; a same-named arch would
        # register fine but silently never be found
        raise ValueError(
            f"arch name {spec.name!r} shadows a paper platform; pick a "
            f"name outside {sorted(PLATFORMS)}")
    if spec.name in _REGISTRY and not replace \
            and _REGISTRY[spec.name] != spec:
        raise ValueError(f"arch {spec.name!r} already registered with "
                         f"different content")
    _REGISTRY[spec.name] = spec
    return spec


def registered_archs() -> Dict[str, ArchSpec]:
    _load_config_archs()
    return dict(_REGISTRY)


def _load_config_archs() -> None:
    """Import the config-level arch definitions so string lookups see
    them (they register themselves on import).  Only a genuinely absent
    configs package is tolerated; any OTHER import failure (e.g. a broken
    transitive dependency) surfaces instead of silently emptying the
    registry."""
    try:
        import repro.configs.archs  # noqa: F401  (side effect: register)
    except ModuleNotFoundError as e:
        if e.name not in ("repro.configs", "repro.configs.archs"):
            raise


class UnknownArchError(KeyError):
    """Raised by :func:`as_arch` for an unresolvable name.  A KeyError
    subclass (callers catching KeyError keep working) whose message is
    not repr-quoted, so the full platform/arch listing stays readable."""

    def __str__(self) -> str:
        return self.args[0]


def as_arch(platform: Union[str, Platform, ArchSpec]) -> ArchSpec:
    """Resolve any accepted hardware description to an ArchSpec:
    a Platform name ("edge"/"mobile"/"cloud"), a registered arch name,
    a Platform object, or an ArchSpec (passed through).  Unknown names
    raise :class:`UnknownArchError` listing every resolvable name (the
    paper platforms plus :func:`registered_archs`)."""
    if isinstance(platform, ArchSpec):
        return platform
    if isinstance(platform, Platform):
        return arch_from_platform(platform)
    if isinstance(platform, str):
        from .accel import PLATFORMS
        if platform in PLATFORMS:
            return arch_from_platform(PLATFORMS[platform])
        if platform not in _REGISTRY:
            _load_config_archs()
        if platform in _REGISTRY:
            return _REGISTRY[platform]
        import difflib
        known = sorted(PLATFORMS) + sorted(_REGISTRY)
        close = difflib.get_close_matches(platform, known, n=3)
        hint = f"; did you mean {' / '.join(map(repr, close))}?" \
            if close else ""
        raise UnknownArchError(
            f"unknown platform/arch {platform!r}{hint}\n"
            f"  paper platforms: {', '.join(sorted(PLATFORMS))}\n"
            f"  registered archs: {', '.join(sorted(_REGISTRY))}\n"
            f"  (register new topologies with repro.core.arch."
            f"register_arch or declare them via repro.core.arch_dsl; "
            f"see repro.configs.archs and COMPAT.md)")
    raise TypeError(f"cannot resolve {type(platform).__name__} to an "
                    f"ArchSpec")


register_arch(ARCH_SPARSEMAP)
