"""Sparse strategy (SparseMap §II.C, §III.A.2, Figs. 5/6/13).

Two components:

* **Compression format** — a hierarchical combination of per-dimension 1-D
  formats over the *tiled sub-dimensions* of a tensor (Fig. 5).  Gene values:

      0 = U    uncompressed (dense positions)
      1 = B    bitmask: 1 bit per position
      2 = RLE  run length encoding: log2(L) bits per kept entry
      3 = CP   coordinate payload: log2(L) bits per kept entry
      4 = UOP  uncompressed offset pair: (L+1) offsets per fiber; must be
               combined with a compressed format below it (paper: "UOP needs
               to be used with other format")

* **Skipping/Gating (S/G)** — per storage/compute site (GLB=L2, PE buffer=L3,
  compute=C), one of 7 options (Fig. 6/13):

      0 = none
      1 = Gate P<-Q   (P processed only where Q nonzero; energy only)
      2 = Gate Q<-P
      3 = Gate P<->Q  (double-sided)
      4 = Skip P<-Q   (cycles AND energy)
      5 = Skip Q<-P
      6 = Skip P<->Q

The byte-accounting model follows Sparseloop's format taxonomy: a tensor
tile with dims (outer..inner per the mapping's tiled sub-dimensions) is a
fiber tree; level i has ``n_fibers(i)`` fibers of length ``L_i``; how
occupancy decays down the tree is supplied by the tensor's
:class:`~repro.core.density.DensityModel` (``block_nonempty``): a plain
float density means uniform random nonzeros (the seed semantics,
bit-identical), while banded / block-N:M operands keep/drop coordinates
with their own statistics — which is exactly what moves the best
format choice on structured workloads.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

from .density import DensityLike, as_density
from .workload import WORD_BYTES

FMT_U, FMT_B, FMT_RLE, FMT_CP, FMT_UOP = range(5)
FORMAT_NAMES = ("U", "B", "RLE", "CP", "UOP")

SG_NONE = 0
SG_GATE_P_Q = 1     # Gate P<-Q : leader Q
SG_GATE_Q_P = 2     # Gate Q<-P : leader P
SG_GATE_BOTH = 3
SG_SKIP_P_Q = 4
SG_SKIP_Q_P = 5
SG_SKIP_BOTH = 6
SG_NAMES = ("none", "gate P<-Q", "gate Q<-P", "gate P<->Q",
            "skip P<-Q", "skip Q<-P", "skip P<->Q")
N_SG = 7
MAX_FMT_GENES = 5               # fixed sub-segment length (paper §IV.F)

# The DEFAULT (paper) arch's S/G sites: GLB, PE buffer, compute.  The
# authoritative per-arch site list is ``ArchSpec.sg_sites`` — any store
# may declare a site, and "C" (compute) is always last.
SG_SITES = ("L2", "L3", "C")


def is_gate(sg: int) -> bool:
    return sg in (SG_GATE_P_Q, SG_GATE_Q_P, SG_GATE_BOTH)


def is_skip(sg: int) -> bool:
    return sg in (SG_SKIP_P_Q, SG_SKIP_Q_P, SG_SKIP_BOTH)


def leaders(sg: int) -> Tuple[str, ...]:
    """Tensors whose metadata drives the intersection at this site."""
    if sg in (SG_GATE_P_Q, SG_SKIP_P_Q):
        return ("Q",)
    if sg in (SG_GATE_Q_P, SG_SKIP_Q_P):
        return ("P",)
    if sg in (SG_GATE_BOTH, SG_SKIP_BOTH):
        return ("P", "Q")
    return ()


def followers(sg: int) -> Tuple[str, ...]:
    """Tensors whose accesses are filtered by the mechanism."""
    if sg in (SG_GATE_P_Q, SG_SKIP_P_Q):
        return ("P",)
    if sg in (SG_GATE_Q_P, SG_SKIP_Q_P):
        return ("Q",)
    if sg in (SG_GATE_BOTH, SG_SKIP_BOTH):
        return ("P", "Q")
    return ()


@dataclasses.dataclass(frozen=True)
class TensorFormat:
    """Per-dimension formats for one tensor's tiled sub-dimensions,
    outermost first.  ``formats[i]`` applies to sub-dimension i whose fiber
    length is ``fiber_lens[i]``."""

    tensor: str
    formats: Tuple[int, ...]
    fiber_lens: Tuple[int, ...]

    @property
    def compressed(self) -> bool:
        return any(f != FMT_U for f in self.formats)

    def valid(self) -> Tuple[bool, str]:
        if len(self.formats) != len(self.fiber_lens):
            return False, "format/fiber length mismatch"
        if self.formats and self.formats[-1] == FMT_UOP:
            return False, "UOP on innermost sub-dimension"
        for i, f in enumerate(self.formats):
            if f == FMT_UOP and all(g == FMT_U for g in self.formats[i + 1:]):
                return False, "UOP without a compressed format below it"
        return True, ""


def fiber_tree_bytes(fmt: TensorFormat, density: DensityLike,
                     word_bytes: float = WORD_BYTES
                     ) -> Tuple[float, float]:
    """(data_bytes, metadata_bytes) for one *full tensor* tile whose tiled
    sub-dimension lengths are ``fmt.fiber_lens`` (product = element count).

    ``word_bytes`` is the datawidth of the level holding the tile
    (``ArchSpec.store_word_bytes``); metadata bits are width-independent,
    so the effective compression ratio varies with the level's width.

    ``density`` is a :class:`~repro.core.density.DensityModel` (a float
    means :class:`~repro.core.density.Uniform`, the seed semantics): the
    probability that a position at tree level i contains any nonzero
    below it is ``occ_i = model.block_nonempty(elements under the
    position)`` — for uniform random nonzeros that is
    ``1 - (1 - d) ** elems``, bit-identical to the pre-model code.
    """
    model = as_density(density)
    lens = fmt.fiber_lens
    n_elems = 1
    for L in lens:
        n_elems *= L
    if not fmt.compressed:
        return float(n_elems * word_bytes), 0.0

    data_bytes = n_elems * model.density * word_bytes
    meta_bits = 0.0
    n_fibers = 1.0          # fibers at current level
    elems_below = n_elems
    for i, L in enumerate(lens):
        elems_below //= max(L, 1)
        # probability that a coordinate at this level is "kept"
        occ = model.block_nonempty(max(elems_below, 1))
        kept = L * occ
        f = fmt.formats[i]
        if f == FMT_B:
            meta_bits += n_fibers * L                       # 1 bit/pos
        elif f == FMT_RLE:
            meta_bits += n_fibers * kept * _clog2(L)        # runlen/entry
        elif f == FMT_CP:
            meta_bits += n_fibers * kept * _clog2(L)        # coord/entry
        elif f == FMT_UOP:
            meta_bits += n_fibers * (L + 1) * _clog2(max(n_elems, 2))
        # U: no metadata, positions stay dense
        if f == FMT_U:
            n_fibers *= L
        else:
            n_fibers *= kept
    return float(data_bytes), float(meta_bits / 8.0)


def _clog2(x: float) -> float:
    return max(1.0, math.ceil(math.log2(max(x, 2))))


def effective_bytes(fmt: TensorFormat, density: DensityLike,
                    n_elems_tile: int,
                    word_bytes: float = WORD_BYTES) -> float:
    """Bytes occupied by a tile of ``n_elems_tile`` elements under this
    format, scaling the full-tensor fiber-tree accounting proportionally."""
    full_elems = 1
    for L in fmt.fiber_lens:
        full_elems *= L
    data_b, meta_b = fiber_tree_bytes(fmt, density, word_bytes)
    frac = n_elems_tile / max(full_elems, 1)
    return (data_b + meta_b) * frac


@dataclasses.dataclass(frozen=True)
class SparseStrategy:
    """Complete sparse strategy: formats for P/Q/Z + S/G per site."""

    formats: Dict[str, TensorFormat]          # keyed "P","Q","Z"
    sg: Dict[str, int]                        # keyed "L2","L3","C"

    def valid(self, spatial_subdims: Dict[str, Tuple[int, ...]]
              ) -> Tuple[bool, str]:
        """``spatial_subdims[t]`` = indices of t's tiled sub-dimensions that
        are spatially unrolled (need random parallel access -> must stay
        uncompressed)."""
        for t, fmt in self.formats.items():
            ok, why = fmt.valid()
            if not ok:
                return False, f"{t}: {why}"
            for i in spatial_subdims.get(t, ()):
                if i < len(fmt.formats) and fmt.formats[i] != FMT_U:
                    return False, (f"{t}: compressed format "
                                   f"{FORMAT_NAMES[fmt.formats[i]]} on "
                                   f"spatially unrolled sub-dimension")
        for site, sg in self.sg.items():
            if is_skip(sg):
                for ld in leaders(sg):
                    if not self.formats[ld].compressed:
                        return False, (f"{site}: skip with uncompressed "
                                       f"leader {ld} (no metadata to "
                                       f"locate nonzeros)")
        return True, ""
