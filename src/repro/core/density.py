"""Per-tensor statistical density models (the DensityModel hierarchy).

The seed's byte accounting and S/G intersection math assumed *uniform
random* nonzeros — one scalar density per tensor.  Real Table III
operands are anything but uniform: sparseGPT weights are N:M
block-pruned, windowed-attention scores are banded, pruned-VGG
activations are spatially clustered.  Following Sparseloop's statistical
density models and TeAAL's per-tensor occupancy specs, density is a
per-tensor *model*, not a scalar: anywhere a ``TensorSpec`` used to
carry ``density: float`` it now carries a :class:`DensityModel` (floats
are still accepted everywhere and mean :class:`Uniform`).

A model supplies three quantities the sparse stack consumes:

* ``density`` — the mean fraction of nonzero elements.  Prices data
  bytes (``sparse.fiber_tree_bytes``) and the dense->effectual MAC
  scaling.
* ``block_nonempty(e)`` — the probability that an (aligned) block of
  ``e`` elements contains at least one nonzero.  This is the fiber-fill
  distribution driving the format byte model: the expected number of
  kept coordinates of a fiber of length ``L`` whose positions each
  cover ``e`` elements is ``L * block_nonempty(e)``.
* ``hit_rate()`` — the expected fraction of a follower tensor's
  accesses that survive an element-granularity leader/follower
  intersection when this model's tensor leads a gate/skip mechanism
  (``cost_model.evaluate``).  For every built-in model this equals the
  mean density (element-level intersections see the mean); correlated
  custom models may override it.

Built-ins:

* :class:`Uniform` — i.i.d. Bernoulli nonzeros, the seed semantics.
  ``block_nonempty(e) = 1 - (1 - d)**e``, bit-identical to the
  pre-model code (pinned by the goldens).
* :class:`Banded` — a two-phase clustered model for diagonal / windowed
  operands: a fraction ``bandwidth`` of each tensor block lies inside
  the band (where nonzeros are uniform at density ``d / bandwidth``);
  the rest is exactly empty.  ``block_nonempty(e) =
  bandwidth * (1 - (1 - d/bandwidth)**e)`` — large out-of-band blocks
  are certainly empty, which is what makes RLE/CP-style formats (and
  coarse skipping) win on banded operands.
* :class:`BlockNM` — fixed-structured N:M pruning (e.g. sparseGPT 2:4):
  every aligned block of ``m`` elements keeps exactly ``n`` nonzeros,
  uniformly placed within the block.  ``block_nonempty(e)`` is the
  hypergeometric miss probability ``1 - C(m-n, e) / C(m, e)`` for
  ``e <= m - n`` and exactly 1 beyond (any window wider than the zero
  budget must hit a nonzero) — evaluated via log-gamma so the JAX
  kernel's float tile extents use the same formula.  Elements of a
  block are modeled as drawn from a single aligned m-block (the
  conservative case; windows straddling blocks hit at least as often).

Structural-vs-traced contract (mirrors ``ArchSpec.word_bytes``): the
density-model *mode* is structural in the JAX compilation signature —
all-:class:`Uniform` workloads compile the literal pre-model kernel
(bit-identical to the goldens), while any structured operand selects the
structured kernel variant, in which the per-tensor family code and its
numeric parameters (``params()``) are *traced*.  A whole family of N:M
workloads — or a mixed uniform/banded/N:M fleet — therefore shares ONE
XLA compilation.  Custom models must register here (numpy side,
:func:`register_density_model`) and in ``jax_cost``
(``register_density_occ``) — see COMPAT.md "Defining a custom
DensityModel".
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple, Type, Union

#: anything that describes a tensor's nonzero statistics: a plain float
#: (mean density, meaning Uniform) or a DensityModel
DensityLike = Union[float, "DensityModel"]

#: traced per-tensor parameter row width: [family code, hit rate,
#: family params...] padded to the widest registered family
_N_FAMILY_PARAMS = 2


@dataclasses.dataclass(frozen=True)
class DensityModel:
    """Base class: one tensor's nonzero statistics.  Frozen/hashable so
    it can live inside ``TensorSpec`` and key evaluator caches."""

    #: family tag; structural on the JAX side (selects the occupancy
    #: formula), unique per registered subclass
    family = "abstract"

    @property
    def density(self) -> float:
        """Mean fraction of nonzero elements, in (0, 1]."""
        raise NotImplementedError

    def block_nonempty(self, elems) -> float:
        """P(an aligned block of ``elems`` elements holds a nonzero)."""
        raise NotImplementedError

    def hit_rate(self) -> float:
        """Expected fraction of follower accesses surviving an
        element-granularity intersection led by this tensor."""
        return self.density

    def params(self) -> Tuple[float, ...]:
        """Numeric family parameters, traced by the JAX kernel (at most
        ``param_width() - 2`` values; the row is zero-padded)."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Uniform(DensityModel):
    """i.i.d. uniform-random nonzeros at mean density ``d`` — the seed
    semantics, bit-identical to the pre-model byte accounting."""

    d: float
    family = "uniform"

    def __post_init__(self):
        if not 0.0 < self.d <= 1.0:
            raise ValueError(f"Uniform density must be in (0, 1], "
                             f"got {self.d}")

    @property
    def density(self) -> float:
        return self.d

    def block_nonempty(self, elems) -> float:
        return 1.0 - (1.0 - self.d) ** elems

    def params(self) -> Tuple[float, ...]:
        return (self.d,)


@dataclasses.dataclass(frozen=True)
class Banded(DensityModel):
    """Band/window-clustered nonzeros: fraction ``bandwidth`` of every
    block lies inside the band, where nonzeros are uniform at density
    ``d / bandwidth``; outside the band the tensor is exactly zero.
    Mean density is ``d``."""

    d: float
    bandwidth: float
    family = "banded"

    def __post_init__(self):
        if not 0.0 < self.bandwidth <= 1.0:
            raise ValueError(f"Banded bandwidth must be in (0, 1], got "
                             f"{self.bandwidth}")
        if not 0.0 < self.d <= self.bandwidth:
            raise ValueError(
                f"Banded density must be in (0, bandwidth={self.bandwidth}]"
                f" (in-band density d/bandwidth must be <= 1), got {self.d}")

    @property
    def density(self) -> float:
        return self.d

    def block_nonempty(self, elems) -> float:
        d_in = self.d / self.bandwidth
        return self.bandwidth * (1.0 - (1.0 - d_in) ** elems)

    def params(self) -> Tuple[float, ...]:
        return (self.d, self.bandwidth)


@dataclasses.dataclass(frozen=True)
class BlockNM(DensityModel):
    """Structured N:M pruning: every aligned block of ``m`` elements
    keeps exactly ``n`` nonzeros, uniformly placed within the block
    (sparseGPT 2:4 -> ``BlockNM(2, 4)``).  Mean density is ``n / m``
    exactly, with zero variance — the intersection hit rate of an N:M
    leader is deterministic."""

    n: int
    m: int
    family = "block_nm"

    def __post_init__(self):
        if not (isinstance(self.n, int) and isinstance(self.m, int)):
            raise ValueError("BlockNM n and m must be ints")
        if not 1 <= self.n <= self.m:
            raise ValueError(f"BlockNM needs 1 <= n <= m, got "
                             f"{self.n}:{self.m}")

    @property
    def density(self) -> float:
        return self.n / self.m

    def block_nonempty(self, elems) -> float:
        # P(miss) for a window of e elements of one aligned m-block is
        # hypergeometric: C(m-n, e) / C(m, e); via log-gamma so float
        # (tile-extent) windows use the same formula as the JAX kernel
        free = self.m - self.n
        e = min(float(elems), float(free))
        if float(elems) > free:
            return 1.0
        p_miss = math.exp(
            math.lgamma(free + 1.0) + math.lgamma(self.m - e + 1.0)
            - math.lgamma(free - e + 1.0) - math.lgamma(self.m + 1.0))
        return 1.0 - p_miss

    def params(self) -> Tuple[float, ...]:
        return (float(self.n), float(self.m))


# ---------------------------------------------------------------- registry

#: family name -> (traced family code, model class), in registration
#: order.  The JAX structured kernel bakes the registered family SET at
#: trace time and selects per tensor by the traced code — register
#: custom families before building evaluators (COMPAT.md).
_FAMILIES: Dict[str, Tuple[int, Type[DensityModel]]] = {}


def register_density_model(cls: Type[DensityModel]) -> Type[DensityModel]:
    """Register a DensityModel subclass (numpy side).  The JAX kernel
    additionally needs ``jax_cost.register_density_occ(family, fn)``."""
    global _N_FAMILY_PARAMS
    fam = cls.family
    if fam in _FAMILIES and _FAMILIES[fam][1] is not cls:
        raise ValueError(f"density family {fam!r} already registered by "
                         f"{_FAMILIES[fam][1].__name__}")
    if fam not in _FAMILIES:
        _FAMILIES[fam] = (len(_FAMILIES), cls)
    probe_params = getattr(cls, "_n_params", None)
    if probe_params is not None:
        _N_FAMILY_PARAMS = max(_N_FAMILY_PARAMS, int(probe_params))
    return cls


register_density_model(Uniform)
register_density_model(Banded)
register_density_model(BlockNM)


def family_code(family: str) -> int:
    """The traced integer code of a registered family."""
    return _FAMILIES[family][0]


def density_to_dict(d: DensityLike) -> Dict:
    """Wire form of a density description: the registered family name
    plus the model's dataclass fields (a plain float normalizes to
    :class:`Uniform` first).  Only registered families serialize — an
    unregistered custom model has no code the receiving side could
    rebuild a kernel row from."""
    m = as_density(d)
    if m.family not in _FAMILIES or _FAMILIES[m.family][1] is not type(m):
        raise ValueError(
            f"density model {type(m).__name__!r} (family {m.family!r}) is "
            f"not registered; registered families: {sorted(_FAMILIES)}")
    return {"family": m.family, "fields": dataclasses.asdict(m)}


def density_from_dict(d: Dict) -> DensityModel:
    """Inverse of :func:`density_to_dict`.  Unknown families raise
    ``ValueError`` naming the registered ones (a server surfaces this to
    the client instead of dying)."""
    fam = d["family"]
    if fam not in _FAMILIES:
        raise ValueError(f"unknown density family {fam!r}; registered "
                         f"families: {sorted(_FAMILIES)}")
    return _FAMILIES[fam][1](**d.get("fields", {}))


def registered_families() -> Tuple[str, ...]:
    """Registered family names in code order."""
    return tuple(_FAMILIES)


def registry_fingerprint() -> str:
    """Joined registered family names — part of the structured
    compilation signature, so registering a new family can never alias a
    stale structured kernel."""
    return "+".join(_FAMILIES)


def param_width() -> int:
    """Width of the traced per-tensor parameter row:
    ``[code, hit_rate, family params..., 0 pad]``."""
    return 2 + _N_FAMILY_PARAMS


def as_density(d: DensityLike) -> DensityModel:
    """Normalize a density description: floats/ints become
    :class:`Uniform`, models pass through."""
    if isinstance(d, DensityModel):
        return d
    return Uniform(float(d))


def param_row(model: DensityModel) -> Tuple[float, ...]:
    """The traced parameter row of one tensor's model."""
    if model.family not in _FAMILIES:
        raise KeyError(
            f"density family {model.family!r} is not registered; call "
            f"density.register_density_model first (COMPAT.md)")
    p = model.params()
    if len(p) > _N_FAMILY_PARAMS:
        raise ValueError(
            f"{model.family}: {len(p)} params exceed the registered "
            f"width {_N_FAMILY_PARAMS}; set a _n_params class attr and "
            f"re-register")
    pad = (0.0,) * (_N_FAMILY_PARAMS - len(p))
    return (float(family_code(model.family)), float(model.hit_rate())) \
        + tuple(float(x) for x in p) + pad
