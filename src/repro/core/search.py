"""Public search facade: run any optimization method on (workload,
platform) under an evaluation budget.

    from repro.core import search
    res = search.run("sparsemap", workload, "cloud", budget=20_000, seed=0)
    print(res.best_edp, res.valid_fraction)
    design = search.decode_best(workload, res)

Evaluator instances are cached per (workload content, platform) because
jit compilation of the batch cost model dominates small searches; the key
is :meth:`Workload.cache_key`, so content-equal workloads share one
evaluator and a recycled object id can never alias a stale entry.

Concurrent sweeps use :class:`MultiSearch`, the repo's method-agnostic
search runtime: every task — any (method, workload, platform) triple whose
method has a request generator in ``baselines.REQUEST_METHODS`` — is a
generator that yields genome batches, and each round every pending task's
batch is evaluated and its generator advanced.  Tasks are ordered by
(ndims, prime-bucket, topology) compilation signature; with
``align_signatures=True``
each workload's prime axis is padded up to the largest bucket among its
same-ndims peers so the whole group shares ONE XLA compilation, and with
``stack_batches=True`` all same-signature pending batches are concatenated
into one padded mega-batch per round — a single device dispatch per
signature instead of one per task:

    results = search.run_sweep([wl_a, wl_b], "cloud", budget=20_000)
    grid = search.run_method_sweep(["sparsemap", "pso", "random_mapper"],
                                   [wl_a, wl_b], "cloud", budget=20_000)
"""
from __future__ import annotations

import dataclasses
import json
import warnings
from collections import Counter
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from . import accel, es_ops, jax_cost
from .arch import ArchSpec, as_arch
from .baselines import (METHODS, REQUEST_METHODS, SEGMENT_METHODS,
                        make_requests)
from .es_ops import DeviceSegment
from .cost_model import CostReport, Design, evaluate
from .encoding import GenomeSpec
from .evolution import SearchResult, _Budget
from .jax_cost import JaxCostModel, _bucket
from .workload import Workload, workload_from_dict, workload_to_dict

#: anything that names hardware: a Platform/arch name, a Platform, or an
#: ArchSpec (see repro.core.arch.as_arch)
PlatformLike = Union[str, accel.Platform, ArchSpec]

_CACHE: Dict[Tuple[Tuple, ArchSpec, Optional[int], bool],
             Tuple[GenomeSpec, JaxCostModel]] = {}


def _platform(platform: PlatformLike) -> ArchSpec:
    """Resolve any hardware description to its ArchSpec."""
    return as_arch(platform)


def get_evaluator(workload: Workload, platform: PlatformLike,
                  n_pad: Optional[int] = None,
                  structured: bool = False
                  ) -> Tuple[GenomeSpec, JaxCostModel]:
    plat = _platform(platform)
    # ``structured=True`` promotes an all-uniform workload onto the
    # structured-density kernel so it can mega-batch with banded/N:M
    # peers (MultiSearch alignment); a naturally structured workload is
    # normalized to its natural key so sequential and fleet runs share
    # one evaluator
    structured = bool(structured) and not workload.structured_density
    # the ArchSpec itself (content-hashable) keys the cache: two specs
    # that merely share a NAME must not alias one evaluator (same
    # aliasing class as the id(workload) bug fixed in PR 2)
    key = (workload.cache_key(), plat, n_pad, structured)
    if key not in _CACHE:
        spec = GenomeSpec(workload, arch=plat)
        _CACHE[key] = (spec, JaxCostModel(spec, plat, n_pad=n_pad,
                                          structured=structured or None))
    return _CACHE[key]


def clear_cache() -> None:
    """Drop cached evaluators AND the shared jitted kernels (benchmark
    hook for counting compilations from a cold start)."""
    _CACHE.clear()
    jax_cost.clear_compile_cache()


def run(method: str, workload: Workload,
        platform: PlatformLike, budget: int = 20_000,
        seed: int = 0, **kw) -> SearchResult:
    if method not in METHODS:
        raise KeyError(f"unknown method {method!r}; have {list(METHODS)}")
    plat = _platform(platform)
    spec, ev = get_evaluator(workload, plat)
    res = METHODS[method](spec, ev, budget, seed, plat, **kw)
    res.extras.setdefault("arch", plat)
    return res


def decode_best(workload: Workload, result: SearchResult,
                platform: Optional[PlatformLike] = None) -> Optional[Design]:
    """Decode a result's best genome.  ``platform`` selects the arch the
    search ran on; when omitted, the arch recorded in the result's extras
    is used (falling back to the paper topology for results that predate
    the recording).  Any same-topology description works."""
    if result.best_genome is None:
        return None
    if platform is None:
        platform = result.extras.get("arch")
    spec = GenomeSpec(workload) if platform is None else \
        GenomeSpec(workload, arch=_platform(platform))
    return spec.decode(result.best_genome)


def report_best(workload: Workload, platform: PlatformLike,
                result: SearchResult) -> Optional[CostReport]:
    plat = _platform(platform)
    d = decode_best(workload, result, platform=plat)
    if d is None:
        return None
    return evaluate(d, plat)


# ---------------------------------------------------------------- multi


@dataclasses.dataclass(frozen=True)
class PadPolicy:
    """Mega-batch pad-watermark grow/decay constants for ONE topology.

    The watermark grows to the largest padded round immediately;
    it decays after ``decay_rounds`` consecutive rounds each needing at
    most ``decay_ratio`` of the current shape.  The defaults are
    CPU-tuned; each registered topology compiles its own kernel family,
    so the retrace-vs-padded-compute sweet spot is a per-topology number
    — register a measured policy with :func:`set_pad_policy` (keyed by
    ``Topology.fingerprint``) or pass ``pad_policies`` to
    :class:`MultiSearch` for a one-off override.

    ``source`` records where the constants came from: ``"default"`` (the
    CPU-tuned fallback), ``"measured"`` (derived from a committed
    benchmark trajectory) or ``"seed"`` (declared by a topology's author
    ahead of its first committed baseline run — a zoo entry lands with a
    seed so it never *silently* inherits the default, and
    ``benchmarks/compare_sweep.stale_policy_warnings`` flags the seed for
    promotion once a baseline run has measured the real trajectory)."""

    decay_rounds: int = 3
    decay_ratio: float = 0.5
    source: str = "default"


#: The explicit policy :func:`pad_policy_for` returns for topologies with
#: no registered entry: the conservative CPU-tuned constants.
DEFAULT_PAD_POLICY = PadPolicy()


def derive_pad_policy(trajectory: Sequence[int],
                      source: str = "measured") -> PadPolicy:
    """Derive a per-topology :class:`PadPolicy` from a pad-watermark
    trajectory (``stats["pad_watermarks"]`` of a committed benchmark
    run, e.g. ``BENCH_sweep.baseline.json``; pass ``source="seed"`` when
    the trajectory is an author-declared expectation rather than a
    committed measurement).

    Heuristic: a trajectory that steps down from its peak and never
    re-grows afterwards is a one-off spike (round-1 calibration probes /
    random_mapper chunks).  Such topologies decay earlier
    (``decay_rounds=2``) — one fewer round of mostly-padding kernel
    compute — with ``decay_ratio`` tightened to the observed post-spike
    plateau, so the earlier decay does NOT buy extra re-traces later
    (marginal follow-up decays, e.g. 256 -> 128, stay suppressed).  A
    trajectory that re-grows after decaying (oscillating fleet demand)
    keeps the conservative default, where an extra quiet round must pass
    before paying the re-trace.  ``benchmarks/compare_sweep.py`` mirrors
    the decay_rounds rule (stdlib-only) to warn when a fresh trajectory
    disagrees with the registered policy."""
    traj = list(trajectory)
    peak = max(traj, default=0)
    if peak <= 0 or traj[-1] >= peak:
        # never decayed: no evidence either way — default constants, but
        # stamped with the source so the registry records it was derived
        return PadPolicy(source=source)
    first_down = next(i for i, v in enumerate(traj) if v < peak
                      and max(traj[:i], default=0) == peak)
    regrew = any(b > a for a, b in zip(traj[first_down:],
                                       traj[first_down + 1:]))
    if regrew:
        return PadPolicy(source=source)
    plateau_ratio = max(traj[first_down:]) / peak
    return PadPolicy(decay_rounds=2,
                     decay_ratio=min(max(plateau_ratio, 1 / 32), 0.5),
                     source=source)


#: topology fingerprint -> tuned PadPolicy (default policy when absent)
_PAD_POLICIES: Dict[str, PadPolicy] = {}


def set_pad_policy(topology_fingerprint: str, policy: PadPolicy) -> None:
    """Register the tuned pad-watermark policy for a topology."""
    _PAD_POLICIES[topology_fingerprint] = policy


def pad_policy_for(topology_fingerprint: str) -> PadPolicy:
    """The registered policy for a topology, or — documented, not an
    accident — :data:`DEFAULT_PAD_POLICY` when none is registered (new
    topologies start on the conservative CPU-tuned constants until a
    seed or measured policy lands in ``repro.configs.archs``)."""
    _load_measured_policies()
    return _PAD_POLICIES.get(topology_fingerprint, DEFAULT_PAD_POLICY)


def _load_measured_policies() -> None:
    """Importing ``repro.configs.archs`` registers the PadPolicies
    derived from the committed benchmark baseline; built-in topologies
    (e.g. the paper arch) never trigger ``as_arch``'s lazy configs
    import, so the policy lookup triggers it itself."""
    try:
        import repro.configs.archs  # noqa: F401  (side effect: register)
    except ImportError:             # pragma: no cover - jax-less install
        pass


#: per-backend default for ``MultiSearch(device_rounds=None)``.  CPU stays
#: at 1 — measured in the PR 6 baseline: folded scans win on host syncs
#: but XLA:CPU's scan program loses wall-clock to the per-round path, so
#: folding is opt-in there.  Accelerator backends amortize the scan
#: compile over k dispatch-free generations; 4 (gpu) / 8 (tpu) follow the
#: ROADMAP sizing note (larger k = fewer host syncs but longer-horizon
#: stale budgets, so segments overshoot budget boundaries by up to k-1
#: generations of padding work).
_DEFAULT_DEVICE_ROUNDS = {"cpu": 1, "gpu": 4, "tpu": 8}


def default_device_rounds(backend: Optional[str] = None) -> int:
    """The fleet ``device_rounds`` default for a JAX backend (the running
    ``jax.default_backend()`` when not given).  Unknown backends fall
    back to 1 — the always-correct per-round path."""
    if backend is None:
        import jax
        backend = jax.default_backend()
    return _DEFAULT_DEVICE_ROUNDS.get(backend, 1)


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """The fleet runtime configuration — every knob
    :class:`MultiSearch` accepts, in one validated, frozen, serializable
    object (``MultiSearch(tasks, FleetConfig(...))``).  This replaces the
    eight accreted ``MultiSearch.__init__`` kwargs (still accepted as
    deprecated aliases) and doubles as the sweep server's wire schema:
    ``to_json()``/``from_json()`` round-trip everything except ``mesh``,
    which is a process-local ``jax.sharding.Mesh`` and must be rebuilt on
    the serving side.

    ``device_rounds=None`` defers to the per-backend default
    (:func:`default_device_rounds`); :meth:`resolved_device_rounds`
    resolves it in exactly one place and reports the provenance string
    the fleet ``stats`` record."""

    align_signatures: bool = True
    stack_batches: bool = False
    pad_policies: Dict[str, PadPolicy] = \
        dataclasses.field(default_factory=dict)
    device_rounds: Optional[int] = None
    mesh: object = None
    device_execute: bool = True
    pipeline: bool = True
    compile_ahead: bool = True

    def __post_init__(self):
        for flag in ("align_signatures", "stack_batches",
                     "device_execute", "pipeline", "compile_ahead"):
            object.__setattr__(self, flag, bool(getattr(self, flag)))
        if self.device_rounds is not None:
            if int(self.device_rounds) < 1:
                raise ValueError("device_rounds must be >= 1")
            object.__setattr__(self, "device_rounds",
                               int(self.device_rounds))
        pols = {}
        for fp, pol in (self.pad_policies or {}).items():
            if isinstance(pol, dict):
                pol = PadPolicy(**pol)
            if not isinstance(pol, PadPolicy):
                raise TypeError(f"pad_policies[{fp!r}] must be a "
                                f"PadPolicy or dict, got {type(pol)}")
            pols[str(fp)] = pol
        object.__setattr__(self, "pad_policies", pols)

    def resolved_device_rounds(self) -> Tuple[int, str]:
        """``(value, provenance)``: the explicit value, or the
        per-backend default (CPU=1, documented at
        ``_DEFAULT_DEVICE_ROUNDS``) tagged ``"default:<backend>"``."""
        if self.device_rounds is None:
            import jax
            backend = jax.default_backend()
            return default_device_rounds(backend), f"default:{backend}"
        return self.device_rounds, "explicit"

    def to_json_dict(self) -> Dict:
        if self.mesh is not None:
            raise ValueError(
                "FleetConfig.mesh is process-local (a jax Mesh) and "
                "cannot be serialized; rebuild the mesh on the serving "
                "side and attach it there")
        return dict(
            version=1,
            align_signatures=self.align_signatures,
            stack_batches=self.stack_batches,
            pad_policies={fp: dataclasses.asdict(pol)
                          for fp, pol in sorted(self.pad_policies.items())},
            device_rounds=self.device_rounds,
            device_execute=self.device_execute,
            pipeline=self.pipeline,
            compile_ahead=self.compile_ahead)

    def to_json(self) -> str:
        return json.dumps(self.to_json_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, data: Union[str, Dict]) -> "FleetConfig":
        d = dict(json.loads(data) if isinstance(data, str) else data)
        version = d.pop("version", 1)
        if version != 1:
            raise ValueError(f"unknown FleetConfig schema version "
                             f"{version!r}")
        unknown = set(d) - {f.name for f in dataclasses.fields(cls)}
        if unknown:
            raise ValueError(f"unknown FleetConfig fields: "
                             f"{sorted(unknown)}")
        return cls(**d)


#: sentinel distinguishing "kwarg not passed" from any real value in the
#: deprecated MultiSearch keyword aliases
_UNSET = object()


@dataclasses.dataclass
class SearchTask:
    """One (method, workload, platform) search in a :class:`MultiSearch`
    fleet.  ``method`` must have a request generator
    (``baselines.REQUEST_METHODS``); ``method_kw`` is forwarded to its
    factory.  ``es_kw`` is the deprecated pre-method-agnostic alias —
    still merged (``method_kw`` wins on conflicts) but it warns.

    ``runtime_kw`` carries process-local factory extras the wire schema
    must not see — warm-start ``seeds`` rows, ``resume_state`` /
    ``state_out`` checkpoint hooks (the sweep server's durability path).
    It is excluded from ``to_json()`` and from the compile-ahead
    predictors.
    """
    workload: Workload
    platform: PlatformLike = "cloud"
    budget: int = 20_000
    seed: int = 0
    name: Optional[str] = None
    method: str = "sparsemap"
    method_kw: Dict = dataclasses.field(default_factory=dict)
    es_kw: Dict = dataclasses.field(default_factory=dict)
    runtime_kw: Dict = dataclasses.field(default_factory=dict,
                                         repr=False, compare=False)

    def __post_init__(self):
        if self.method not in REQUEST_METHODS:
            raise KeyError(
                f"method {self.method!r} has no request generator; "
                f"have {sorted(REQUEST_METHODS)}")
        if self.es_kw:
            warnings.warn(
                "SearchTask.es_kw is deprecated; pass method_kw=... "
                "(merge semantics preserved: method_kw wins)",
                DeprecationWarning, stacklevel=3)
            self.method_kw = {**self.es_kw, **self.method_kw}

    def resolved_name(self) -> str:
        if self.name:
            return self.name
        base = f"{self.workload.name}@{_platform(self.platform).name}"
        return base if self.method == "sparsemap" else \
            f"{self.method}:{base}"

    def to_json_dict(self) -> Dict:
        """JSON-able wire form: the workload by its ``cache_key`` fields
        (density models via registered family names), the platform by
        registry name, and the method's factory kwargs.  ``runtime_kw``
        (process-local) and ``es_kw`` (already merged) are excluded —
        a server query is exactly this dict plus a FleetConfig
        fragment."""
        return dict(
            version=1,
            workload=workload_to_dict(self.workload),
            platform=_platform(self.platform).name,
            budget=int(self.budget),
            seed=int(self.seed),
            name=self.name,
            method=self.method,
            method_kw=dict(self.method_kw))

    def to_json(self) -> str:
        return json.dumps(self.to_json_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, data: Union[str, Dict]) -> "SearchTask":
        d = dict(json.loads(data) if isinstance(data, str) else data)
        version = d.pop("version", 1)
        if version != 1:
            raise ValueError(f"unknown SearchTask schema version "
                             f"{version!r}")
        unknown = set(d) - {"workload", "platform", "budget", "seed",
                            "name", "method", "method_kw"}
        if unknown:
            raise ValueError(f"unknown SearchTask fields: "
                             f"{sorted(unknown)}")
        return cls(
            workload=workload_from_dict(d["workload"]),
            platform=d.get("platform", "cloud"),
            budget=int(d.get("budget", 20_000)),
            seed=int(d.get("seed", 0)),
            name=d.get("name"),
            method=d.get("method", "sparsemap"),
            method_kw=dict(d.get("method_kw") or {}))


@dataclasses.dataclass
class _TaskState:
    name: str
    gen: object                      # the method's request generator
    tracker: _Budget
    ev: JaxCostModel
    natural: Tuple[int, int]         # (ndims, natural prime bucket)
    method: str
    req: Optional[np.ndarray] = None
    extras: Optional[Dict] = None

    @property
    def signature(self) -> Tuple[int, int, str]:
        return self.ev.signature


class MultiSearch:
    """Run a fleet of (method, workload, platform) searches concurrently.

    Each task's engine is a request generator (``evolve_requests`` for
    SparseMap populations, ``baselines.*_requests`` for the baseline
    optimizers); every round, each pending task's next batch is evaluated
    and the generator advanced, with tasks ordered by compilation
    signature so same-signature tasks hit the shared jitted evaluator
    back-to-back.

    With ``align_signatures=True`` (default), each workload's prime axis
    is padded up to the largest bucket among its same-ndims peers,
    collapsing the group onto one (ndims, bucket) signature — a sweep over
    the paper's workload table then reuses compilations instead of paying
    XLA tracing per workload (the padding primes are 1.0 and numerically
    inert).

    With ``stack_batches=True``, every round concatenates all
    same-signature pending batches into ONE padded mega-batch and issues a
    single device dispatch per signature (``jax_cost.eval_stacked``),
    slicing the results back per task.  Rows run through the same per-row
    kernel math either way, so stacked and per-task dispatch give
    bit-identical results; the baselines' odd native batch sizes (48, 50,
    64) simply become rows of the shared power-of-two-padded mega-batch.

    With ``device_rounds=k > 1``, tasks whose method is scan-foldable
    (``baselines.SEGMENT_METHODS``) advance in k-generation device
    segments: the generator yields a :class:`~repro.core.es_ops.
    DeviceSegment` carrying the pre-drawn per-generation operator plans,
    the driver runs {select -> crossover -> mutate -> cost} for all k
    generations as ONE ``lax.scan`` program (``jax_cost.run_segments``,
    same-signature same-shape segments stacked and, with ``mesh``,
    sharded across devices), and the host syncs only once per segment for
    ``_Budget`` accounting and history.  ``standard_es`` folds too — its
    direct-to-canonical translation runs in-scan (``kind="direct"``
    segments) — as does ``stagnation_restart > 0`` (a re-init branch on
    the carried best-so-far).  Methods without a device path
    (PSO/MCTS/PPO/DQN, ``random_mapper``) keep the per-round path
    transparently, and mixed fleets interleave both.
    ``device_rounds=None`` (the default) resolves per backend via
    :func:`default_device_rounds` (CPU=1); ``stats`` record the resolved
    value and its provenance (``device_rounds_source``).
    ``device_execute=False`` forces the host-loop reference path: the
    driver answers each segment with ``None`` and the generator replays
    the identical operator plan per-round on the host (bit-identical
    trajectories; see COMPAT.md "Device-resident round protocol").

    With ``pipeline=True`` (default) the round loop is software-
    pipelined: segment results come back deferred and are resolved one
    round late by the request generators, and stacked mega-batches are
    dispatched for ALL signature groups before any is finalized — JAX
    async dispatch overlaps the host's numpy conversions with device
    execution.  ``pipeline=False`` is the escape hatch and is
    bit-identical by construction (same dispatches, same registration
    order, merely blocking earlier); ``stats["host_blocked_s"]`` records
    the host time actually spent blocked on conversions either way.

    With ``compile_ahead=True`` (default) the fleet's round-1 dispatch
    shapes (plus each topology's committed pad-watermark shapes and the
    segment scan programs) are predicted from the task list and AOT-
    compiled on a background thread while the host runs the HSHI/LHS
    prologue; ``stats["compile_ahead_hits"/"compile_ahead_misses"]``
    report registry coverage next to ``jax_cost.compilation_count()``.

    After :meth:`run`, ``stats`` holds the weighted round count, host
    sync count, device-dispatch count, and the aligned and natural
    signature sets.  Duplicate resolved task names are made explicit:
    every colliding name gets a ``#k`` suffix (``name#0``, ``name#1``,
    ...), so no two tasks ever silently share a results key.
    """

    def __init__(self, tasks: Iterable,
                 config: Optional[FleetConfig] = None, *,
                 align_signatures=_UNSET, stack_batches=_UNSET,
                 pad_policies=_UNSET, device_rounds=_UNSET, mesh=_UNSET,
                 device_execute=_UNSET, pipeline=_UNSET,
                 compile_ahead=_UNSET):
        norm: List[SearchTask] = []
        for t in tasks:
            norm.append(self._as_task(t))
        if not norm:
            raise ValueError("MultiSearch needs at least one task")
        legacy = {k: v for k, v in dict(
            align_signatures=align_signatures,
            stack_batches=stack_batches, pad_policies=pad_policies,
            device_rounds=device_rounds, mesh=mesh,
            device_execute=device_execute, pipeline=pipeline,
            compile_ahead=compile_ahead).items() if v is not _UNSET}
        if legacy:
            if config is not None:
                raise ValueError(
                    f"pass config=FleetConfig(...) OR the legacy "
                    f"kwargs, not both (got config and "
                    f"{sorted(legacy)})")
            warnings.warn(
                f"MultiSearch({', '.join(sorted(legacy))}=...) keyword "
                f"configuration is deprecated; pass "
                f"config=FleetConfig(...)", DeprecationWarning,
                stacklevel=2)
            if legacy.get("pad_policies") is None:
                legacy["pad_policies"] = {}
            config = FleetConfig(**legacy)
        if config is None:
            config = FleetConfig()
        self.tasks = norm
        self.config = config
        # resolved views (one resolution point: FleetConfig)
        self.align_signatures = config.align_signatures
        self.stack_batches = config.stack_batches
        self.pad_policies = dict(config.pad_policies)
        self.device_rounds, self.device_rounds_source = \
            config.resolved_device_rounds()
        self.mesh = config.mesh
        self.device_execute = config.device_execute
        self.pipeline = config.pipeline
        self.compile_ahead = config.compile_ahead
        self.final_names: List[str] = self._resolve_names(norm)
        self.stats: Dict = {}
        self._started = False

    @staticmethod
    def _as_task(t) -> SearchTask:
        if isinstance(t, SearchTask):
            return t
        if isinstance(t, Workload):
            return SearchTask(t)
        return SearchTask(*t)

    def _pad_policy(self, topology_fingerprint: str) -> PadPolicy:
        if topology_fingerprint in self.pad_policies:
            return self.pad_policies[topology_fingerprint]
        return pad_policy_for(topology_fingerprint)

    @staticmethod
    def _resolve_names(tasks: Sequence[SearchTask]) -> List[str]:
        base = [t.resolved_name() for t in tasks]
        dup = {n for n, c in Counter(base).items() if c > 1}
        taken = set(base)       # every base name reserves its spot
        next_k: Dict[str, int] = {}
        names = []
        for n in base:
            if n not in dup:
                names.append(n)
                continue
            k = next_k.get(n, 0)
            while f"{n}#{k}" in taken:  # don't collide with explicit names
                k += 1
            next_k[n] = k + 1
            taken.add(f"{n}#{k}")
            names.append(f"{n}#{k}")
        return names

    def _compile_ahead_jobs(self, infos: List[Tuple]) -> List[Tuple]:
        """The AOT (key, jit_fn, arg_structs) jobs predicted from the
        fleet's tasks: round-1 eval shapes (stacked mega-batch per
        signature group, or per-task broadcast), the registered
        pad-watermark shapes of each topology (the steady-state
        mega-batch sizes a committed baseline measured), and the scan /
        direct-scan programs of segment-foldable tasks.  Predictions are
        conservative: a signature group whose round-1 rows cannot all be
        predicted contributes NO job (its family stays unclaimed, so jit
        fallbacks there never count as compile-ahead misses)."""
        from .baselines import round1_rows, segment_plan, steady_rows
        # the worker compiles in list order and a racing dispatch WAITS
        # for its queued key, so order jobs by when the fleet needs
        # them: round-1 shapes first, segment scans next (needed right
        # after the prologue), steady-state watermark extras last
        jobs: List[Tuple] = []
        late: List[Tuple] = []
        seen: set = set()

        def add(job: Tuple, when: List[Tuple] = jobs) -> None:
            if job[0] not in seen:
                seen.add(job[0])
                when.append(job)

        def watermarks(topology_fingerprint: str) -> List[int]:
            try:
                from repro.configs.archs import measured_watermark_values
            except ImportError:         # pragma: no cover - jax-less
                return []
            return measured_watermark_values(topology_fingerprint)

        rows: List[Optional[int]] = []
        for task, kw, spec, ev in infos:
            try:
                rows.append(round1_rows(task.method, spec, task.budget,
                                        task.seed, **kw))
            except (TypeError, ValueError):
                rows.append(None)
        if self.stack_batches:
            by_sig: Dict[Tuple, List[int]] = {}
            for i, (task, kw, spec, ev) in enumerate(infos):
                by_sig.setdefault(ev.signature, []).append(i)
            for sig in sorted(by_sig):
                idx = by_sig[sig]
                model = infos[idx[0]][3]
                if all(rows[i] is not None for i in idx):
                    total = sum(rows[i] for i in idx)
                    add(jax_cost.stacked_compile_job(
                        model, jax_cost._pad_batch(total)))
                    # decayed steady-state shapes: once round-1 shapes
                    # (calibration / first chunks) age out of the pad
                    # watermark, the mega-batch settles on the sum of
                    # the survivors' per-round batches
                    steads = []
                    for i in idx:
                        task, kw = infos[i][0], infos[i][1]
                        try:
                            steads.append(steady_rows(
                                task.method, infos[i][2], task.budget,
                                task.seed, **kw))
                        except (TypeError, ValueError):
                            steads.append(None)
                    if all(s is not None for s in steads):
                        alive = [s for s in steads if s]
                        for tot in sorted({sum(s[0] for s in alive),
                                           sum(s[-1] for s in alive)}):
                            if tot > 0:
                                add(jax_cost.stacked_compile_job(
                                    model, jax_cost._pad_batch(tot)),
                                    when=late)
                    for v in watermarks(sig[2]):
                        add(jax_cost.stacked_compile_job(model, int(v)),
                            when=late)
        else:
            for (task, kw, spec, ev), r in zip(infos, rows):
                if r is not None:
                    add(jax_cost.bcast_compile_job(
                        ev, jax_cost._pad_batch(r)))
        if self.device_execute:
            seg_groups: Dict[Tuple, List[Tuple]] = {}
            for task, kw, spec, ev in infos:
                plan = segment_plan(task.method, spec, task.budget,
                                    task.seed, **kw)
                if plan is not None:
                    key = ev.signature + tuple(sorted(plan.items()))
                    seg_groups.setdefault(key, []).append(
                        (plan, spec, ev))
            for key in sorted(seg_groups, key=repr):
                grp = seg_groups[key]
                plan, spec, ev = grp[0]
                T = len(grp)
                if plan["kind"] == "direct":
                    from .direct_encoding import DirectValueSpec
                    dspec = DirectValueSpec(spec)
                    add(jax_cost.direct_scan_compile_job(
                        ev, plan["B"], plan["rounds"], plan["n_parents"],
                        plan["n_elite"], plan["genes_per"], T,
                        dspec.length, dspec.n_perm_codes))
                else:
                    add(jax_cost.scan_compile_job(
                        ev, plan["B"], plan["rounds"], plan["n_parents"],
                        plan["n_elite"], plan["genes_per"], T,
                        restart=plan["restart"]))
        return jobs + late

    @staticmethod
    def _advance(st: _TaskState, out: Dict) -> bool:
        """Send an evaluation to a task's generator; False when done."""
        try:
            st.req = st.gen.send(out)
            return True
        except StopIteration as stop:
            st.extras = stop.value or {}
            return False

    def _task_infos(self) -> List[Tuple]:
        """One signature-aligned (task, method_kw, spec, evaluator)
        tuple per task — the prediction inputs
        :meth:`_compile_ahead_jobs` consumes.  Builds evaluators but
        starts no request generator, so tests and tooling can inspect
        the fleet's predicted AOT jobs without running a round."""
        naturals = [(t.workload.ndims,
                     _bucket(max(len(t.workload.prime_factors), 1)))
                    for t in self.tasks]
        pad_for: Dict[int, int] = {}
        # density-mode alignment, same spirit as prime-axis padding: if
        # any same-ndims peer declares a structured density model, the
        # whole group runs on the structured kernel (uniform members'
        # models become traced family rows), so a mixed
        # uniform/banded/N:M fleet still shares one signature — one
        # mega-batch dispatch per round
        structured_for: Dict[int, bool] = {}
        if self.align_signatures:
            for (d, bucket), t in zip(naturals, self.tasks):
                pad_for[d] = max(pad_for.get(d, 0), bucket)
                structured_for[d] = structured_for.get(d, False) or \
                    t.workload.structured_density
        # kept for mid-run admission: a task admitted later aligns UP to
        # the group's current bucket/density mode (never re-padding the
        # already-compiled incumbents)
        self._pad_for = pad_for
        self._structured_for = structured_for

        infos: List[Tuple] = []
        for task, natural in zip(self.tasks, naturals):
            plat = _platform(task.platform)
            n_pad = pad_for.get(natural[0]) if self.align_signatures \
                else None
            if n_pad == natural[1]:
                n_pad = None        # natural bucket: share the plain entry
            spec, ev = get_evaluator(
                task.workload, plat, n_pad=n_pad,
                structured=structured_for.get(natural[0], False))
            kw = dict(task.method_kw)
            if self.device_rounds > 1 and task.method in SEGMENT_METHODS:
                # scan-foldable engines fold k generations per segment;
                # an explicit per-task device_rounds wins over the fleet's
                kw.setdefault("device_rounds", self.device_rounds)
            infos.append((task, kw, spec, ev))
        return infos

    def start(self) -> None:
        """Build evaluators, queue compile-ahead jobs, and prime every
        task's request generator — the fleet is then live and
        :meth:`step` advances it one driver iteration at a time.
        Idempotent; :meth:`run` is ``start(); while step(): pass;
        finish()`` and is bit-identical to the pre-incremental driver."""
        if self._started:
            return
        self._started = True
        infos = self._task_infos()
        states: List[_TaskState] = []
        for (task, kw, spec, ev), name in zip(infos, self.final_names):
            gen, tracker = make_requests(task.method, spec,
                                         _platform(task.platform),
                                         task.budget, task.seed,
                                         **{**kw, **task.runtime_kw})
            states.append(_TaskState(
                name=name, gen=gen, tracker=tracker, ev=ev,
                natural=(task.workload.ndims,
                         _bucket(max(len(task.workload.prime_factors),
                                     1))),
                method=task.method))

        self._ca0 = jax_cost.compile_ahead_counts()
        self._blocked0 = jax_cost.host_blocked_s()
        if self.compile_ahead:
            # AOT-compile the predicted round-1 + watermark + scan shapes
            # on a background thread NOW — the compile spike overlaps the
            # host-side HSHI/LHS/calibration prologue instead of
            # serializing with the first dispatch of each shape
            jobs = self._compile_ahead_jobs(infos)
            if jobs:
                jax_cost.compile_ahead(jobs)

        # group same-signature tasks so they share warm compilations (and,
        # when stacking, one mega-batch); stable within a signature
        states.sort(key=lambda s: s.signature)
        self._states = states
        self._alive: List[_TaskState] = []
        self._done: List[str] = []
        for st in states:
            try:
                st.req = next(st.gen)
                self._alive.append(st)
            except StopIteration as stop:
                st.extras = stop.value or {}
                self._done.append(st.name)
        self._pad_hwm: Dict[Tuple[int, int, str], int] = {}
        self._pad_recent: Dict[Tuple[int, int, str],
                               List[Tuple[int, int]]] = {}
        self._wm_hist: Dict[Tuple[int, int, str], List[int]] = {}
        self._rounds = 0     # weighted generation clock (k per segment)
        self._host_syncs = 0   # driver loop iterations (host roundtrips)
        self._seg_syncs = 0    # iterations that device-advanced segments
        self._seg_rounds = 0   # generation rounds covered by those
        self._dispatch0 = jax_cost.dispatch_count()

    def admit(self, task, name: Optional[str] = None) -> str:
        """Admit one more task into the RUNNING fleet (the sweep
        server's entry point: one more user query costs rows in an
        already-dispatched mega-batch, not a new fleet).  The newcomer
        aligns UP to its signature group's current prime bucket and
        density mode — incumbents are never re-padded, so their warm
        compilations survive — and joins the group's mega-batch on the
        next :meth:`step`.  Returns the resolved (collision-suffixed)
        task name.  Compile-ahead prediction covers only the starting
        fleet; an admitted task with a novel signature jit-compiles on
        first dispatch."""
        task = self._as_task(task)
        self.start()
        wl = task.workload
        d = wl.ndims
        bucket = _bucket(max(len(wl.prime_factors), 1))
        n_pad = None
        structured = False
        if self.align_signatures:
            self._pad_for[d] = max(self._pad_for.get(d, 0), bucket)
            self._structured_for[d] = \
                self._structured_for.get(d, False) or \
                wl.structured_density
            n_pad = self._pad_for[d]
            structured = self._structured_for[d]
            if n_pad == bucket:
                n_pad = None
        plat = _platform(task.platform)
        spec, ev = get_evaluator(wl, plat, n_pad=n_pad,
                                 structured=structured)
        kw = dict(task.method_kw)
        if self.device_rounds > 1 and task.method in SEGMENT_METHODS:
            kw.setdefault("device_rounds", self.device_rounds)
        base = name or task.resolved_name()
        resolved, k = base, 0
        while resolved in self.final_names:
            resolved = f"{base}#{k}"
            k += 1
        gen, tracker = make_requests(task.method, spec, plat,
                                     task.budget, task.seed,
                                     **{**kw, **task.runtime_kw})
        st = _TaskState(name=resolved, gen=gen, tracker=tracker, ev=ev,
                        natural=(d, bucket), method=task.method)
        self.tasks.append(task)
        self.final_names.append(resolved)
        self._states.append(st)
        try:
            st.req = next(st.gen)
            self._alive.append(st)
        except StopIteration as stop:
            st.extras = stop.value or {}
            self._done.append(st.name)
        return resolved

    @property
    def done(self) -> bool:
        """True once every task (initial + admitted) has retired."""
        return self._started and not self._alive

    def pop_done(self) -> List[Tuple[str, SearchResult]]:
        """Drain the retirement queue: ``(name, result)`` for every task
        that finished since the last call (the server streams these to
        their clients and feeds the warm-start library)."""
        out = [(n, self.result_of(n)) for n in self._done]
        self._done = []
        return out

    def result_of(self, name: str) -> SearchResult:
        """The (possibly in-flight) result of one task by resolved
        name — retired tasks get their final result, live tasks a
        best-so-far snapshot."""
        for st in self._states:
            if st.name == name:
                return self._result_for(st)
        raise KeyError(f"no task named {name!r}; have "
                       f"{self.final_names}")

    def step(self) -> bool:
        """One driver iteration: advance segmented tasks by k
        generations and per-round tasks by 1 (mega-batched per
        signature).  Retired tasks land in the :meth:`pop_done` queue.
        Returns True while any task is still alive.

        The pad floor (mega-batch watermark) grows to the largest padded
        round immediately (shrinking fleets keep hitting the warm
        shape), and decays to the recent maximum after ``decay_rounds``
        consecutive rounds each needing at most ``decay_ratio`` of the
        current shape — one extra XLA trace instead of paying
        mostly-padding kernel compute every round after a one-off spike
        (e.g. round-1 calibration probes + random_mapper's 512-row
        chunks).  The grow/decay constants are a per-TOPOLOGY
        :class:`PadPolicy`; the per-round watermark trajectory lands in
        ``stats["pad_watermarks"]`` for cross-PR tracking.  The
        ``pad_recent`` observations are (target, weight) pairs; weight =
        search rounds the fleet clock advanced at that observation, so
        quiet-round decay scales with device-segment length (one host
        observation per k rounds must count as k quiet rounds, not 1 —
        otherwise a post-spike watermark never decays under segmented
        fleets)."""
        self.start()
        alive = self._alive
        if not alive:
            return False
        pad_hwm = self._pad_hwm
        pad_recent = self._pad_recent
        wm_hist = self._wm_hist
        pending: List[_TaskState] = []
        seg_states = [st for st in alive
                      if isinstance(st.req, DeviceSegment)]
        plain = [st for st in alive
                 if not isinstance(st.req, DeviceSegment)]
        # one iteration advances segmented tasks by k generations and
        # per-round tasks by 1; the fleet's round clock moves by the
        # largest stride taken this iteration
        iter_weight = 0
        if seg_states and self.device_execute:
            seg_groups: Dict[Tuple, List[_TaskState]] = {}
            for st in seg_states:
                key = st.signature + es_ops.segment_shape_key(st.req)
                seg_groups.setdefault(key, []).append(st)
            for key in sorted(seg_groups):
                grp = seg_groups[key]
                iter_weight = max(iter_weight, grp[0].req.rounds)
                # with pipeline=True the SegmentResults come back
                # unresolved (defer): the generators stash them, yield
                # the NEXT segment from the device-resident carry, and
                # only then resolve round N — the blocking conversion
                # overlaps round N+1's device execution (COMPAT.md
                # "Pipelined dispatch contract")
                segres = jax_cost.run_segments(
                    [s.ev for s in grp], [s.req for s in grp],
                    mesh=self.mesh, defer=self.pipeline)
                for st, res in zip(grp, segres):
                    if self._advance(st, res):
                        pending.append(st)
        elif seg_states:
            # host-loop reference path: the generator replays the
            # identical pre-drawn plan per-round (its next yield is a
            # plain batch, so the task rejoins the per-round path)
            for st in seg_states:
                if self._advance(st, None):
                    pending.append(st)
        if seg_states and self.device_execute:
            self._seg_syncs += 1
            self._seg_rounds += iter_weight
        if plain:
            iter_weight = max(iter_weight, 1)
        if self.stack_batches:
            groups: Dict[Tuple[int, int, str],
                         List[_TaskState]] = {}
            for st in plain:
                groups.setdefault(st.signature, []).append(st)
            # two-phase round: FIRST enqueue every signature group's
            # mega-batch (with pipeline=True the dispatches return
            # StackedPending handles, so all groups' device work is
            # in flight together), THEN finalize + advance in the
            # same sorted order — round N's host-blocking conversion
            # of group i overlaps groups i+1..n computing.  The
            # watermark bookkeeping is value-independent (row counts
            # are known at dispatch), so it stays in dispatch order
            # and pipeline on/off cannot change any padded shape.
            dispatched: List[Tuple[List[_TaskState], object]] = []
            for sig in sorted(groups):
                grp = groups[sig]
                pol = self._pad_policy(sig[2])
                hwm = pad_hwm.get(sig, 0)
                outs = jax_cost.eval_stacked(
                    [s.ev for s in grp], [s.req for s in grp],
                    pad_floor=hwm, mesh=self.mesh,
                    defer=self.pipeline)
                dispatched.append((grp, outs))
                target = jax_cost._pad_batch(
                    sum(len(s.req) for s in grp))
                hist = pad_recent.setdefault(sig, [])
                hist.append((target, max(iter_weight, 1)))
                wtot = sum(w for _, w in hist)
                while hist and wtot - hist[0][1] >= pol.decay_rounds:
                    wtot -= hist.pop(0)[1]
                if target > hwm:
                    pad_hwm[sig] = target
                    hist.clear()
                elif wtot >= pol.decay_rounds and \
                        all(t <= hwm * pol.decay_ratio
                            for t, _ in hist):
                    pad_hwm[sig] = max(t for t, _ in hist)
                    hist.clear()
                wm_hist.setdefault(sig, []).append(pad_hwm[sig])
            for grp, outs in dispatched:
                if isinstance(outs, jax_cost.StackedPending):
                    outs = outs.finalize()
                for st, out in zip(grp, outs):
                    if self._advance(st, out):
                        pending.append(st)
        else:
            for st in plain:
                if self._advance(st, st.ev(st.req)):
                    pending.append(st)
        live = {id(st) for st in pending}
        for st in alive:
            if id(st) not in live:
                self._done.append(st.name)
        self._alive = pending
        self._rounds += iter_weight
        self._host_syncs += 1
        return bool(self._alive)

    @staticmethod
    def _result_for(st: _TaskState) -> SearchResult:
        extras = dict(st.extras or {})
        extras["signature"] = st.signature
        extras["natural_signature"] = st.natural
        extras.setdefault("method", st.method)
        extras.setdefault("arch", st.ev.arch)
        return SearchResult(
            best_edp=st.tracker.best,
            best_genome=st.tracker.best_genome,
            history=np.asarray(st.tracker.hist),
            evals=st.tracker.evals,
            valid_evals=st.tracker.valid,
            extras=extras)

    def stats_snapshot(self) -> Dict:
        """The fleet stats as of now — same shape as the final
        ``stats``, computable mid-run (the server's ``stats`` op)."""
        self.start()
        # host_syncs_per_round: 1.0 for per-round fleets; for segmented
        # fleets the steady-state metric is over the segment phase (the
        # HSHI/calibration prologue is inherently host-driven, so the
        # whole-run ratio can never reach 1/k) — seg iterations each
        # cover k generations with ONE host sync
        hspr = (self._seg_syncs / self._seg_rounds) if self._seg_rounds \
            else (self._host_syncs / self._rounds if self._rounds
                  else 1.0)
        ca_hits, ca_misses = jax_cost.compile_ahead_counts()
        ca_hits0, ca_misses0 = self._ca0
        return dict(
            rounds=self._rounds,
            host_syncs=self._host_syncs,
            host_syncs_per_round=hspr,
            device_rounds=self.device_rounds,
            device_rounds_source=self.device_rounds_source,
            pipeline=self.pipeline,
            compile_ahead=self.compile_ahead,
            compile_ahead_hits=ca_hits - ca_hits0,
            compile_ahead_misses=ca_misses - ca_misses0,
            host_blocked_s=jax_cost.host_blocked_s() - self._blocked0,
            devices=jax_cost._mesh_ndev(self.mesh),
            dispatches=jax_cost.dispatch_count() - self._dispatch0,
            signatures=sorted({s.signature for s in self._states}),
            natural_signatures=sorted({s.natural
                                       for s in self._states}),
            # per-signature mega-batch watermark trajectory + the policy
            # that produced it, keyed "d{ndims}_p{bucket}_{topology}"
            pad_watermarks={
                f"d{sig[0]}_p{sig[1]}_{sig[2]}": hist
                for sig, hist in self._wm_hist.items()},
            pad_policies={
                sig[2]: dataclasses.asdict(self._pad_policy(sig[2]))
                for sig in self._wm_hist})

    def finish(self) -> Dict[str, SearchResult]:
        """Stop background compile-ahead work, freeze ``stats``, and
        return every task's result keyed by resolved name."""
        # compile-ahead jobs still queued were predicted for dispatches
        # that will never come — stop burning cores on them
        jax_cost.compile_ahead_quiesce()
        self.stats = self.stats_snapshot()
        return {st.name: self._result_for(st) for st in self._states}

    def run(self) -> Dict[str, SearchResult]:
        self.start()
        while self.step():
            pass
        return self.finish()


def run_sweep(workloads: Sequence[Workload],
              platform: PlatformLike = "cloud",
              budget: int = 20_000, seed: int = 0,
              align_signatures: bool = True, stack_batches: bool = False,
              device_rounds: Optional[int] = None, mesh=None,
              pipeline: bool = True, compile_ahead: bool = True,
              config: Optional[FleetConfig] = None,
              **es_kw) -> Dict[str, SearchResult]:
    """Convenience wrapper: one concurrent SparseMap search per workload
    (e.g. the paper's Table III list) on a shared platform.  An explicit
    ``config`` wins over the individual fleet kwargs (which predate
    :class:`FleetConfig` and remain for convenience)."""
    if config is None:
        config = FleetConfig(
            align_signatures=align_signatures,
            stack_batches=stack_batches, device_rounds=device_rounds,
            mesh=mesh, pipeline=pipeline, compile_ahead=compile_ahead)
    ms = MultiSearch(
        [SearchTask(wl, platform, budget=budget, seed=seed,
                    method_kw=dict(es_kw)) for wl in workloads],
        config)
    return ms.run()


def run_method_sweep(methods: Sequence[str],
                     workloads: Sequence[Workload],
                     platform: PlatformLike = "cloud",
                     budget: int = 20_000, seed: int = 0,
                     align_signatures: bool = True,
                     stack_batches: bool = True,
                     method_kw: Optional[Dict[str, Dict]] = None,
                     stats_out: Optional[Dict] = None,
                     device_rounds: Optional[int] = None, mesh=None,
                     device_execute: bool = True, pipeline: bool = True,
                     compile_ahead: bool = True,
                     config: Optional[FleetConfig] = None
                     ) -> Dict[str, Dict[str, SearchResult]]:
    """The full fig17-style grid — every method on every workload — as ONE
    concurrent :class:`MultiSearch` fleet, mega-batched per signature by
    default.  Returns ``{method: {workload_name: SearchResult}}``;
    ``method_kw`` maps method name -> factory kwargs; ``stats_out``, if
    given, receives the fleet's ``MultiSearch.stats``."""
    method_kw = method_kw or {}
    dup_m = [m for m, c in Counter(methods).items() if c > 1]
    dup_w = [n for n, c in Counter(w.name for w in workloads).items()
             if c > 1]
    if dup_m or dup_w:
        # the returned {method: {workload_name: ...}} grid would silently
        # drop one of the colliding searches — refuse instead
        raise ValueError(
            f"run_method_sweep needs unique methods and workload names; "
            f"duplicated methods={dup_m}, workload names={dup_w}")
    tasks = [SearchTask(wl, platform, budget=budget, seed=seed, method=m,
                        method_kw=dict(method_kw.get(m, {})))
             for m in methods for wl in workloads]
    if config is None:
        config = FleetConfig(
            align_signatures=align_signatures,
            stack_batches=stack_batches, device_rounds=device_rounds,
            mesh=mesh, device_execute=device_execute,
            pipeline=pipeline, compile_ahead=compile_ahead)
    ms = MultiSearch(tasks, config)
    flat = ms.run()
    grid: Dict[str, Dict[str, SearchResult]] = {m: {} for m in methods}
    i = 0
    for m in methods:
        for wl in workloads:
            grid[m][wl.name] = flat[ms.final_names[i]]
            i += 1
    if stats_out is not None:
        stats_out.update(ms.stats)
    return grid
