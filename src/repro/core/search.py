"""Public search facade: run any optimization method on (workload,
platform) under an evaluation budget.

    from repro.core import search
    res = search.run("sparsemap", workload, "cloud", budget=20_000, seed=0)
    print(res.best_edp, res.valid_fraction)
    design = search.decode_best(workload, res)

Evaluator instances are cached per (workload, platform) because jit
compilation of the batch cost model dominates small searches.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple, Union

import numpy as np

from . import accel
from .baselines import METHODS
from .cost_model import CostReport, Design, evaluate
from .encoding import GenomeSpec
from .evolution import SearchResult
from .jax_cost import JaxCostModel
from .workload import Workload

_CACHE: Dict[Tuple[int, str], Tuple[GenomeSpec, JaxCostModel]] = {}


def get_evaluator(workload: Workload, platform: Union[str, accel.Platform]
                  ) -> Tuple[GenomeSpec, JaxCostModel]:
    plat = accel.PLATFORMS[platform] if isinstance(platform, str) else platform
    key = (id(workload), plat.name)
    if key not in _CACHE:
        spec = GenomeSpec(workload)
        _CACHE[key] = (spec, JaxCostModel(spec, plat))
    return _CACHE[key]


def run(method: str, workload: Workload,
        platform: Union[str, accel.Platform], budget: int = 20_000,
        seed: int = 0, **kw) -> SearchResult:
    if method not in METHODS:
        raise KeyError(f"unknown method {method!r}; have {list(METHODS)}")
    plat = accel.PLATFORMS[platform] if isinstance(platform, str) else platform
    spec, ev = get_evaluator(workload, plat)
    return METHODS[method](spec, ev, budget, seed, plat, **kw)


def decode_best(workload: Workload, result: SearchResult) -> Optional[Design]:
    if result.best_genome is None:
        return None
    return GenomeSpec(workload).decode(result.best_genome)


def report_best(workload: Workload, platform: Union[str, accel.Platform],
                result: SearchResult) -> Optional[CostReport]:
    d = decode_best(workload, result)
    if d is None:
        return None
    plat = accel.PLATFORMS[platform] if isinstance(platform, str) else platform
    return evaluate(d, plat)
