"""Public search facade: run any optimization method on (workload,
platform) under an evaluation budget.

    from repro.core import search
    res = search.run("sparsemap", workload, "cloud", budget=20_000, seed=0)
    print(res.best_edp, res.valid_fraction)
    design = search.decode_best(workload, res)

Evaluator instances are cached per (workload, platform) because jit
compilation of the batch cost model dominates small searches.

Multi-workload sweeps use :class:`MultiSearch`, which runs one ES
population per (workload, platform) pair *concurrently*: every pending
population is round-robined through the shared jitted evaluator, ordered
by (ndims, prime-bucket) compilation signature, and — with
``align_signatures=True`` — each workload's prime axis is padded up to the
largest bucket among its same-ndims peers so the whole group shares ONE
XLA compilation instead of tracing per workload:

    results = search.run_sweep([wl_a, wl_b], "cloud", budget=20_000)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from . import accel
from .baselines import METHODS, sparsemap_setup
from .cost_model import CostReport, Design, evaluate
from .encoding import GenomeSpec
from .evolution import SearchResult, _Budget, evolve_requests
from .jax_cost import JaxCostModel, _bucket
from .workload import Workload

_CACHE: Dict[Tuple[int, str, Optional[int]],
             Tuple[GenomeSpec, JaxCostModel]] = {}


def _platform(platform: Union[str, accel.Platform]) -> accel.Platform:
    return accel.PLATFORMS[platform] if isinstance(platform, str) \
        else platform


def get_evaluator(workload: Workload, platform: Union[str, accel.Platform],
                  n_pad: Optional[int] = None
                  ) -> Tuple[GenomeSpec, JaxCostModel]:
    plat = _platform(platform)
    key = (id(workload), plat.name, n_pad)
    if key not in _CACHE:
        spec = GenomeSpec(workload)
        _CACHE[key] = (spec, JaxCostModel(spec, plat, n_pad=n_pad))
    return _CACHE[key]


def clear_cache() -> None:
    """Drop cached evaluators AND the shared jitted kernels (benchmark
    hook for counting compilations from a cold start)."""
    from . import jax_cost
    _CACHE.clear()
    jax_cost.clear_compile_cache()


def run(method: str, workload: Workload,
        platform: Union[str, accel.Platform], budget: int = 20_000,
        seed: int = 0, **kw) -> SearchResult:
    if method not in METHODS:
        raise KeyError(f"unknown method {method!r}; have {list(METHODS)}")
    plat = _platform(platform)
    spec, ev = get_evaluator(workload, plat)
    return METHODS[method](spec, ev, budget, seed, plat, **kw)


def decode_best(workload: Workload, result: SearchResult) -> Optional[Design]:
    if result.best_genome is None:
        return None
    return GenomeSpec(workload).decode(result.best_genome)


def report_best(workload: Workload, platform: Union[str, accel.Platform],
                result: SearchResult) -> Optional[CostReport]:
    d = decode_best(workload, result)
    if d is None:
        return None
    plat = _platform(platform)
    return evaluate(d, plat)


# ---------------------------------------------------------------- multi


@dataclasses.dataclass
class SearchTask:
    """One (workload, platform) search in a :class:`MultiSearch` fleet."""
    workload: Workload
    platform: Union[str, accel.Platform] = "cloud"
    budget: int = 20_000
    seed: int = 0
    name: Optional[str] = None
    es_kw: Dict = dataclasses.field(default_factory=dict)

    def resolved_name(self) -> str:
        if self.name:
            return self.name
        return f"{self.workload.name}@{_platform(self.platform).name}"


@dataclasses.dataclass
class _TaskState:
    name: str
    gen: object                      # the evolve_requests generator
    tracker: _Budget
    ev: JaxCostModel
    natural: Tuple[int, int]
    req: Optional[np.ndarray] = None
    extras: Optional[Dict] = None

    @property
    def signature(self) -> Tuple[int, int]:
        return self.ev.signature


class MultiSearch:
    """Run one SparseMap ES population per (workload, platform) pair
    concurrently.

    Each task's engine is an :func:`evolve_requests` generator; every
    round, each pending population's next batch is evaluated and the
    generator advanced, with tasks ordered by compilation signature so
    same-signature populations hit the shared jitted evaluator
    back-to-back.  With ``align_signatures=True`` (default), each
    workload's prime axis is padded up to the largest bucket among its
    same-ndims peers, collapsing the group onto one (ndims, bucket)
    signature — a sweep over the paper's workload table then reuses
    compilations instead of paying XLA tracing per workload (the padding
    primes are 1.0 and numerically inert).

    After :meth:`run`, ``stats`` holds the round count plus the aligned
    and natural signature sets.
    """

    def __init__(self, tasks: Iterable, align_signatures: bool = True):
        norm: List[SearchTask] = []
        for t in tasks:
            if isinstance(t, SearchTask):
                norm.append(t)
            elif isinstance(t, Workload):
                norm.append(SearchTask(t))
            else:
                norm.append(SearchTask(*t))
        if not norm:
            raise ValueError("MultiSearch needs at least one task")
        self.tasks = norm
        self.align_signatures = align_signatures
        self.stats: Dict = {}

    def run(self) -> Dict[str, SearchResult]:
        naturals = [(t.workload.ndims,
                     _bucket(max(len(t.workload.prime_factors), 1)))
                    for t in self.tasks]
        pad_for: Dict[int, int] = {}
        if self.align_signatures:
            for d, bucket in naturals:
                pad_for[d] = max(pad_for.get(d, 0), bucket)

        states: List[_TaskState] = []
        seen_names: Dict[str, int] = {}
        for task, natural in zip(self.tasks, naturals):
            plat = _platform(task.platform)
            n_pad = pad_for.get(natural[0]) if self.align_signatures \
                else None
            if n_pad == natural[1]:
                n_pad = None        # natural bucket: share the plain entry
            spec, ev = get_evaluator(task.workload, plat, n_pad=n_pad)
            cfg, seeds = sparsemap_setup(spec, plat, task.budget,
                                         task.seed, **task.es_kw)
            tracker = _Budget(cfg.budget)
            gen = evolve_requests(spec, cfg, tracker, seeds=seeds)
            name = task.resolved_name()
            if name in seen_names:
                seen_names[name] += 1
                name = f"{name}#{seen_names[name]}"
            else:
                seen_names[name] = 0
            states.append(_TaskState(name=name, gen=gen, tracker=tracker,
                                     ev=ev, natural=natural))

        # group same-signature populations so they share warm compilations
        states.sort(key=lambda s: s.signature)

        alive: List[_TaskState] = []
        for st in states:
            try:
                st.req = next(st.gen)
                alive.append(st)
            except StopIteration as stop:
                st.extras = stop.value or {}

        rounds = 0
        while alive:
            pending: List[_TaskState] = []
            for st in alive:
                out = st.ev(st.req)
                try:
                    st.req = st.gen.send(out)
                    pending.append(st)
                except StopIteration as stop:
                    st.extras = stop.value or {}
            alive = pending
            rounds += 1

        results: Dict[str, SearchResult] = {}
        for st in states:
            extras = dict(st.extras or {})
            extras["signature"] = st.signature
            extras["natural_signature"] = st.natural
            results[st.name] = SearchResult(
                best_edp=st.tracker.best,
                best_genome=st.tracker.best_genome,
                history=np.asarray(st.tracker.hist),
                evals=st.tracker.evals,
                valid_evals=st.tracker.valid,
                extras=extras)
        self.stats = dict(
            rounds=rounds,
            signatures=sorted({s.signature for s in states}),
            natural_signatures=sorted({s.natural for s in states}))
        return results


def run_sweep(workloads: Sequence[Workload],
              platform: Union[str, accel.Platform] = "cloud",
              budget: int = 20_000, seed: int = 0,
              align_signatures: bool = True, **es_kw
              ) -> Dict[str, SearchResult]:
    """Convenience wrapper: one concurrent SparseMap search per workload
    (e.g. the paper's Table III list) on a shared platform."""
    ms = MultiSearch(
        [SearchTask(wl, platform, budget=budget, seed=seed,
                    es_kw=dict(es_kw)) for wl in workloads],
        align_signatures=align_signatures)
    return ms.run()
