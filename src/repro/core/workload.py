"""Sparse tensor algebra workloads (SparseMap §II, Table III).

A workload is an einsum ``Z[m,n] += P[m,k] * Q[k,n]`` (SpMM) or a sparse
convolution lowered to implicit GEMM (SpConv).  SparseMap treats both as a
D-dimensional projective einsum: each tensor is indexed by a subset of the
iteration dimensions, and each operand carries a density.

Dimensions are named; the canonical GEMM order is ("M", "K", "N").  A batched
workload (§IV.G, Fig. 15) adds "B" and the genome widens automatically — the
encoding only ever sees ``dims`` / ``prime_factors`` / relevance sets.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

WORD_BYTES = 2  # 16-bit operands throughout (paper uses 16-bit, DSTC 12nm)


def prime_factorize(n: int) -> List[int]:
    """Prime factors of ``n`` in non-decreasing order (1 -> [])."""
    if n < 1:
        raise ValueError(f"dimension must be >= 1, got {n}")
    out: List[int] = []
    d = 2
    while d * d <= n:
        while n % d == 0:
            out.append(d)
            n //= d
        d += 1
    if n > 1:
        out.append(n)
    return out


def pad_to_composite(n: int, max_prime: int = 7) -> int:
    """Replace a dimension whose largest prime factor exceeds ``max_prime``
    with the nearest larger integer that factorizes into small primes
    (paper §IV.B: "if a dimension size is a large prime number, we replace it
    with the nearest larger composite number")."""
    m = n
    while max(prime_factorize(m), default=1) > max_prime:
        m += 1
    return m


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    """One tensor of the einsum."""

    name: str                 # "P" | "Q" | "Z"
    dims: Tuple[str, ...]     # iteration dims this tensor is indexed by
    density: float            # fraction of nonzero elements, in (0, 1]
    is_output: bool = False

    def size(self, dim_sizes: Dict[str, int]) -> int:
        s = 1
        for d in self.dims:
            s *= dim_sizes[d]
        return s


@dataclasses.dataclass(frozen=True)
class Workload:
    """A sparse projective einsum plus densities.

    ``dim_sizes`` are the *padded* sizes actually searched over;
    ``orig_dim_sizes`` keeps the user-specified sizes for reporting.
    """

    name: str
    dim_order: Tuple[str, ...]            # canonical order, e.g. ("M","K","N")
    dim_sizes: Dict[str, int]
    tensors: Tuple[TensorSpec, TensorSpec, TensorSpec]
    orig_dim_sizes: Dict[str, int] = dataclasses.field(default_factory=dict)

    # ---- derived -----------------------------------------------------
    def cache_key(self) -> Tuple:
        """Hashable content key.  Evaluator caches must key on this, NOT
        on ``id(workload)``: two content-equal workloads then share one
        cached evaluator/compilation, and — critically — a recycled object
        id can never alias a *different* workload after the original is
        garbage-collected."""
        return (self.name, self.dim_order,
                tuple(sorted(self.dim_sizes.items())),
                self.tensors,
                tuple(sorted(self.orig_dim_sizes.items())))

    @property
    def ndims(self) -> int:
        return len(self.dim_order)

    @property
    def inputs(self) -> Tuple[TensorSpec, TensorSpec]:
        return tuple(t for t in self.tensors if not t.is_output)  # type: ignore

    @property
    def output(self) -> TensorSpec:
        return next(t for t in self.tensors if t.is_output)

    def tensor(self, name: str) -> TensorSpec:
        return next(t for t in self.tensors if t.name == name)

    @property
    def prime_factors(self) -> List[Tuple[str, int]]:
        """Flat list of (dim_name, prime) pairs — the tiling genome slots."""
        out: List[Tuple[str, int]] = []
        for d in self.dim_order:
            for p in prime_factorize(self.dim_sizes[d]):
                out.append((d, p))
        return out

    @property
    def macs(self) -> int:
        """Dense MAC count = product of all iteration dims."""
        s = 1
        for d in self.dim_order:
            s *= self.dim_sizes[d]
        return s

    def output_density(self) -> float:
        """P(z != 0) under uniform-random nonzero placement: an output element
        is nonzero iff any of the K (contraction) products is nonzero."""
        contraction = [d for d in self.dim_order
                       if d not in self.output.dims]
        k = 1
        for d in contraction:
            k *= self.dim_sizes[d]
        dp = 1.0
        for t in self.inputs:
            dp *= t.density
        return float(1.0 - (1.0 - dp) ** k) if dp < 1.0 else 1.0

    def density_of(self, name: str) -> float:
        if name == self.output.name:
            return self.output_density()
        return self.tensor(name).density


def spmm(name: str, m: int, k: int, n: int,
         density_p: float, density_q: float) -> Workload:
    """SpMM workload  P[M,K] x Q[K,N] = Z[M,N]  (paper Table III mm*)."""
    sizes = {"M": pad_to_composite(m), "K": pad_to_composite(k),
             "N": pad_to_composite(n)}
    return Workload(
        name=name,
        dim_order=("M", "K", "N"),
        dim_sizes=sizes,
        orig_dim_sizes={"M": m, "K": k, "N": n},
        tensors=(
            TensorSpec("P", ("M", "K"), density_p),
            TensorSpec("Q", ("K", "N"), density_q),
            TensorSpec("Z", ("M", "N"), 1.0, is_output=True),
        ),
    )


def batched_spmm(name: str, b: int, m: int, k: int, n: int,
                 density_p: float, density_q: float) -> Workload:
    """4-dim workload (paper Fig. 15): adds batch dim B shared by all
    tensors.  Exercises the multi-dimensional genome path (perm range A_4^4)."""
    sizes = {"B": pad_to_composite(b), "M": pad_to_composite(m),
             "K": pad_to_composite(k), "N": pad_to_composite(n)}
    return Workload(
        name=name,
        dim_order=("B", "M", "K", "N"),
        dim_sizes=sizes,
        orig_dim_sizes={"B": b, "M": m, "K": k, "N": n},
        tensors=(
            TensorSpec("P", ("B", "M", "K"), density_p),
            TensorSpec("Q", ("B", "K", "N"), density_q),
            TensorSpec("Z", ("B", "M", "N"), 1.0, is_output=True),
        ),
    )


def spconv(name: str, c: int, h: int, w: int, kout: int, r: int, s: int,
           density_i: float, density_w: float,
           stride: int = 1, pad: int | None = None) -> Workload:
    """SpConv lowered to implicit GEMM (paper Table III conv*).

    Input  I[C,H,W] (density_i), weights W[Kout,C,R,S] (density_w),
    output O[Kout,P,Q'].  im2col:  M=Kout, K=C*R*S, N=P*Q'.
    Operand1 of Table III is the input fmap, operand2 the weights.
    """
    if pad is None:
        pad = r // 2
    p_out = (h + 2 * pad - r) // stride + 1
    q_out = (w + 2 * pad - s) // stride + 1
    m = kout
    kk = c * r * s
    n = p_out * q_out
    wl = spmm(name, m, kk, n, density_w, density_i)
    # P holds weights (density_w), Q holds the im2col'd input (density_i).
    return wl


def from_gemm_shape(name: str, m: int, k: int, n: int,
                    density_p: float = 1.0, density_q: float = 1.0
                    ) -> Workload:
    return spmm(name, m, k, n, density_p, density_q)
