"""Sparse tensor algebra workloads (SparseMap §II, Table III).

A workload is an einsum ``Z[m,n] += P[m,k] * Q[k,n]`` (SpMM) or a sparse
convolution lowered to implicit GEMM (SpConv).  SparseMap treats both as a
D-dimensional projective einsum: each tensor is indexed by a subset of the
iteration dimensions, and each operand carries a *density model*
(:mod:`repro.core.density`): a plain float means uniform-random nonzeros
(the seed semantics), while :class:`~repro.core.density.Banded` and
:class:`~repro.core.density.BlockNM` describe clustered and
structured-pruned operands whose byte/intersection statistics differ.

Dimensions are named; the canonical GEMM order is ("M", "K", "N").  A batched
workload (§IV.G, Fig. 15) adds "B" and the genome widens automatically — the
encoding only ever sees ``dims`` / ``prime_factors`` / relevance sets.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from .density import (DensityLike, DensityModel, Uniform, as_density,
                      density_from_dict, density_to_dict)

WORD_BYTES = 2  # 16-bit operands throughout (paper uses 16-bit, DSTC 12nm)


def prime_factorize(n: int) -> List[int]:
    """Prime factors of ``n`` in non-decreasing order (1 -> [])."""
    if n < 1:
        raise ValueError(f"dimension must be >= 1, got {n}")
    out: List[int] = []
    d = 2
    while d * d <= n:
        while n % d == 0:
            out.append(d)
            n //= d
        d += 1
    if n > 1:
        out.append(n)
    return out


def pad_to_composite(n: int, max_prime: int = 7) -> int:
    """Replace a dimension whose largest prime factor exceeds ``max_prime``
    with the nearest larger integer that factorizes into small primes
    (paper §IV.B: "if a dimension size is a large prime number, we replace it
    with the nearest larger composite number")."""
    m = n
    while max(prime_factorize(m), default=1) > max_prime:
        m += 1
    return m


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    """One tensor of the einsum.

    ``density`` accepts a plain float (fraction of nonzero elements in
    (0, 1], meaning uniform-random placement) or any
    :class:`~repro.core.density.DensityModel`; ``density_model`` is the
    normalized view and ``mean_density`` the scalar mean."""

    name: str                 # "P" | "Q" | "Z"
    dims: Tuple[str, ...]     # iteration dims this tensor is indexed by
    density: DensityLike      # float (= Uniform) or a DensityModel
    is_output: bool = False

    @property
    def density_model(self) -> DensityModel:
        return as_density(self.density)

    @property
    def mean_density(self) -> float:
        return self.density_model.density

    def size(self, dim_sizes: Dict[str, int]) -> int:
        s = 1
        for d in self.dims:
            s *= dim_sizes[d]
        return s


@dataclasses.dataclass(frozen=True)
class Workload:
    """A sparse projective einsum plus densities.

    ``dim_sizes`` are the *padded* sizes actually searched over;
    ``orig_dim_sizes`` keeps the user-specified sizes for reporting.
    """

    name: str
    dim_order: Tuple[str, ...]            # canonical order, e.g. ("M","K","N")
    dim_sizes: Dict[str, int]
    tensors: Tuple[TensorSpec, TensorSpec, TensorSpec]
    orig_dim_sizes: Dict[str, int] = dataclasses.field(default_factory=dict)

    # ---- derived -----------------------------------------------------
    def cache_key(self) -> Tuple:
        """Hashable content key.  Evaluator caches must key on this, NOT
        on ``id(workload)``: two content-equal workloads then share one
        cached evaluator/compilation, and — critically — a recycled object
        id can never alias a *different* workload after the original is
        garbage-collected."""
        return (self.name, self.dim_order,
                tuple(sorted(self.dim_sizes.items())),
                tuple((t.name, t.dims, t.density_model, t.is_output)
                      for t in self.tensors),
                tuple(sorted(self.orig_dim_sizes.items())))

    @property
    def ndims(self) -> int:
        return len(self.dim_order)

    @property
    def inputs(self) -> Tuple[TensorSpec, TensorSpec]:
        return tuple(t for t in self.tensors if not t.is_output)  # type: ignore

    @property
    def output(self) -> TensorSpec:
        return next(t for t in self.tensors if t.is_output)

    def tensor(self, name: str) -> TensorSpec:
        return next(t for t in self.tensors if t.name == name)

    @property
    def prime_factors(self) -> List[Tuple[str, int]]:
        """Flat list of (dim_name, prime) pairs — the tiling genome slots."""
        out: List[Tuple[str, int]] = []
        for d in self.dim_order:
            for p in prime_factorize(self.dim_sizes[d]):
                out.append((d, p))
        return out

    @property
    def macs(self) -> int:
        """Dense MAC count = product of all iteration dims."""
        s = 1
        for d in self.dim_order:
            s *= self.dim_sizes[d]
        return s

    def output_density(self) -> float:
        """P(z != 0) under independent nonzero placement: an output element
        is nonzero iff any of the K (contraction) products is nonzero.
        Mean-field over the input models (their mean densities); input
        structure correlating the products is not modeled here."""
        contraction = [d for d in self.dim_order
                       if d not in self.output.dims]
        k = 1
        for d in contraction:
            k *= self.dim_sizes[d]
        dp = 1.0
        for t in self.inputs:
            dp *= t.mean_density
        return float(1.0 - (1.0 - dp) ** k) if dp < 1.0 else 1.0

    def density_of(self, name: str) -> float:
        """Mean density of a tensor (the output's is derived)."""
        return self.density_model_of(name).density

    def density_model_of(self, name: str) -> DensityModel:
        """The tensor's density model.  The output keeps the seed
        semantics — its density is *derived* from the inputs
        (:meth:`output_density`, uniform placement) — unless a
        structured model was declared on it explicitly."""
        t = self.tensor(name)
        if t.is_output:
            m = t.density_model
            if m.family == "uniform":
                return Uniform(self.output_density())
            return m
        return t.density_model

    @property
    def structured_density(self) -> bool:
        """True when any tensor declares a non-uniform density model
        (selects the structured JAX kernel variant)."""
        return any(t.density_model.family != "uniform"
                   for t in self.tensors)


def workload_to_dict(wl: Workload) -> Dict:
    """JSON-able wire form of a workload — exactly the
    :meth:`Workload.cache_key` fields, with density models serialized by
    registered family (:func:`~repro.core.density.density_to_dict`).
    Round-trips through :func:`workload_from_dict` to a content-equal
    workload (same ``cache_key()``), so a deserialized server query
    shares the sender's evaluator cache entry and warm-start library
    key."""
    return {
        "name": wl.name,
        "dim_order": list(wl.dim_order),
        "dim_sizes": {d: int(v) for d, v in wl.dim_sizes.items()},
        "orig_dim_sizes": {d: int(v)
                           for d, v in wl.orig_dim_sizes.items()},
        "tensors": [
            {"name": t.name, "dims": list(t.dims),
             "density": density_to_dict(t.density),
             "is_output": bool(t.is_output)} for t in wl.tensors],
    }


def workload_from_dict(d: Dict) -> Workload:
    """Inverse of :func:`workload_to_dict`."""
    tensors = tuple(
        TensorSpec(name=t["name"], dims=tuple(t["dims"]),
                   density=density_from_dict(t["density"]),
                   is_output=bool(t.get("is_output", False)))
        for t in d["tensors"])
    if len(tensors) != 3:
        raise ValueError(f"workload needs exactly 3 tensors, "
                         f"got {len(tensors)}")
    return Workload(
        name=d["name"], dim_order=tuple(d["dim_order"]),
        dim_sizes={k: int(v) for k, v in d["dim_sizes"].items()},
        tensors=tensors,  # type: ignore[arg-type]
        orig_dim_sizes={k: int(v)
                        for k, v in d.get("orig_dim_sizes", {}).items()})


def spmm(name: str, m: int, k: int, n: int,
         density_p: DensityLike, density_q: DensityLike) -> Workload:
    """SpMM workload  P[M,K] x Q[K,N] = Z[M,N]  (paper Table III mm*)."""
    sizes = {"M": pad_to_composite(m), "K": pad_to_composite(k),
             "N": pad_to_composite(n)}
    return Workload(
        name=name,
        dim_order=("M", "K", "N"),
        dim_sizes=sizes,
        orig_dim_sizes={"M": m, "K": k, "N": n},
        tensors=(
            TensorSpec("P", ("M", "K"), density_p),
            TensorSpec("Q", ("K", "N"), density_q),
            TensorSpec("Z", ("M", "N"), 1.0, is_output=True),
        ),
    )


def batched_spmm(name: str, b: int, m: int, k: int, n: int,
                 density_p: DensityLike, density_q: DensityLike
                 ) -> Workload:
    """4-dim workload (paper Fig. 15): adds batch dim B shared by all
    tensors.  Exercises the multi-dimensional genome path (perm range A_4^4)."""
    sizes = {"B": pad_to_composite(b), "M": pad_to_composite(m),
             "K": pad_to_composite(k), "N": pad_to_composite(n)}
    return Workload(
        name=name,
        dim_order=("B", "M", "K", "N"),
        dim_sizes=sizes,
        orig_dim_sizes={"B": b, "M": m, "K": k, "N": n},
        tensors=(
            TensorSpec("P", ("B", "M", "K"), density_p),
            TensorSpec("Q", ("B", "K", "N"), density_q),
            TensorSpec("Z", ("B", "M", "N"), 1.0, is_output=True),
        ),
    )


def spconv(name: str, c: int, h: int, w: int, kout: int, r: int, s: int,
           density_i: DensityLike, density_w: DensityLike,
           stride: int = 1, pad: int | None = None) -> Workload:
    """SpConv lowered to implicit GEMM (paper Table III conv*).

    Input  I[C,H,W] (density_i), weights W[Kout,C,R,S] (density_w),
    output O[Kout,P,Q'].  im2col:  M=Kout, K=C*R*S, N=P*Q'.
    Operand1 of Table III is the input fmap, operand2 the weights.
    """
    if pad is None:
        pad = r // 2
    p_out = (h + 2 * pad - r) // stride + 1
    q_out = (w + 2 * pad - s) // stride + 1
    m = kout
    kk = c * r * s
    n = p_out * q_out
    wl = spmm(name, m, kk, n, density_w, density_i)
    # P holds weights (density_w), Q holds the im2col'd input (density_i).
    return wl


def from_gemm_shape(name: str, m: int, k: int, n: int,
                    density_p: DensityLike = 1.0, density_q: DensityLike = 1.0
                    ) -> Workload:
    return spmm(name, m, k, n, density_p, density_q)
