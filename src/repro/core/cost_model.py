"""Sparseloop-class analytical cost model (SparseMap §IV.I "Evaluation
Environment"; Sparseloop/TimeloopV2 methodology), generalized over a
declared :class:`repro.core.arch.ArchSpec`.

Given (Workload, Mapping, SparseStrategy, arch-or-platform) it returns
energy (pJ), latency (cycles), EDP (cycles * pJ) and a validity verdict.
The paper uses the TimeloopV2 binary; this is a faithful
re-implementation of its published accounting (per-level access counts
from loop-nest reuse analysis, density-scaled by the sparse strategy,
per-access energy tables) — see DESIGN.md §5 for the assumptions.

Traffic edges are derived from the arch: one per storage level below the
backing store, each filtered by the S/G site of its SOURCE store (the
backing store has none).  For the default paper topology:

    DRAM -> GLB       : compression only (no S/G)
    GLB  -> PE buffer : "L2" S/G site
    PEbuf-> MAC regs  : "L3" S/G site
    MAC ops           : "C"  S/G site

Skip scales energy AND cycles; Gate scales energy only (Fig. 6).  A skip
anywhere whose leader is tensor T multiplies the effectual compute-cycle
fraction by density(T) (the paper's Fig. 14: skipping empty P rows at the
GLB skips the whole corresponding compute iterations).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple, Union

from .accel import Platform
from .arch import ArchSpec, as_arch
from .mapping import Mapping
from .sparse import (FMT_U, SparseStrategy, TensorFormat, effective_bytes,
                     followers, is_gate, is_skip, leaders)


@dataclasses.dataclass(frozen=True)
class Design:
    mapping: Mapping
    strategy: SparseStrategy


@dataclasses.dataclass
class CostReport:
    valid: bool
    reason: str = ""
    energy_pj: float = 0.0
    cycles: float = 0.0
    edp: float = float("inf")
    # --- breakdowns for analysis/benchmarks ---
    energy_breakdown: Dict[str, float] = dataclasses.field(default_factory=dict)
    traffic_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    compute_cycles: float = 0.0
    dram_cycles: float = 0.0
    # per-store occupancies for every capacity-checked store of the arch
    occupancy_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def fitness(self) -> float:
        return 0.0 if not self.valid else 1.0 / max(self.edp, 1e-30)

    # legacy accessors (paper-topology store names)
    @property
    def glb_occupancy_bytes(self) -> float:
        return self.occupancy_bytes.get("glb", 0.0)

    @property
    def pebuf_occupancy_bytes(self) -> float:
        return self.occupancy_bytes.get("pebuf", 0.0)


def tiled_subdims(mapping: Mapping, tensor_name: str
                  ) -> Tuple[Tuple[int, str, int], ...]:
    """Tiled sub-dimensions of a tensor, outer->inner: (level, dim, size),
    keeping only factors > 1 (paper Fig. 13: formats are specified for the
    sub-dimensions that actually exist)."""
    t = mapping.workload.tensor(tensor_name)
    out = []
    for lvl in range(mapping.arch.n_levels):
        for d in mapping.perms[lvl]:
            if d in t.dims:
                f = mapping.factors[lvl].get(d, 1)
                if f > 1:
                    out.append((lvl, d, f))
    return tuple(out)


def spatial_subdim_indices(mapping: Mapping, tensor_name: str
                           ) -> Tuple[int, ...]:
    subs = tiled_subdims(mapping, tensor_name)
    spatial = set(mapping.arch.spatial_levels)
    return tuple(i for i, (lvl, _, _) in enumerate(subs)
                 if lvl in spatial)


def make_tensor_format(mapping: Mapping, tensor_name: str,
                       fmt_genes: Tuple[int, ...]) -> TensorFormat:
    """Apply the paper's gene->format rule: the sub-segment has
    ``MAX_FMT_GENES`` genes; the LAST k genes map to the k tiled
    sub-dimensions; sub-dimensions beyond the first 5 stay uncompressed."""
    subs = tiled_subdims(mapping, tensor_name)
    k = len(subs)
    ng = len(fmt_genes)
    if k <= ng:
        fmts = tuple(fmt_genes[ng - k:])
    else:
        fmts = tuple(fmt_genes) + tuple([FMT_U] * (k - ng))
    return TensorFormat(tensor=tensor_name, formats=fmts,
                        fiber_lens=tuple(s for _, _, s in subs))


# --------------------------------------------------------------------------


def evaluate(design: Design, platform: Union[str, Platform, ArchSpec]
             ) -> CostReport:
    mp = design.mapping
    st = design.strategy
    wl = mp.workload
    arch = as_arch(platform)
    if arch.topology != mp.arch.topology:
        raise ValueError(
            f"mapping was built for arch {mp.arch.name!r} "
            f"(topology {mp.arch.topology.fingerprint}) but is evaluated "
            f"on {arch.name!r} ({arch.topology.fingerprint})")

    # ---------- validity: spatial fanout ----------
    caps = arch.spatial_caps()
    for lvl, cap, store_k in zip(arch.spatial_levels, caps,
                                 arch.spatial_store):
        fan = mp.spatial_fanout(lvl)
        if fan > cap:
            return CostReport(
                False, f"{arch.level_names[lvl]} fanout {fan} > {cap} "
                       f"{arch.store_names[store_k]} instances")

    # ---------- validity: sparse strategy ----------
    spatial_subs = {t.name: spatial_subdim_indices(mp, t.name)
                    for t in wl.tensors}
    ok, why = st.valid(spatial_subs)
    if not ok:
        return CostReport(False, why)

    # per-tensor density models: byte accounting consumes the full model
    # (fiber-fill statistics), S/G intersections its element-granularity
    # hit rate (== mean density for every built-in model)
    dmodel = {t.name: wl.density_model_of(t.name) for t in wl.tensors}
    hit = {n: m.hit_rate() for n, m in dmodel.items()}

    def tile_bytes(store: str, tname: str) -> float:
        # occupancy is accounted at the STORE's word width (per-level
        # datawidths: a quantized level holds narrower words)
        n = mp.tensor_tile_elems(store, tname)
        return effective_bytes(st.formats[tname], dmodel[tname], n,
                               arch.word_bytes_of(store))

    # ---------- validity: buffer capacities ----------
    occ: Dict[str, float] = {}
    for _, sname, cap in arch.capacity_stores:
        o = sum(tile_bytes(sname, t.name) for t in wl.tensors)
        occ[sname] = o
        if o > cap:
            return CostReport(
                False, f"{sname.upper()} overflow {o:.0f}B > {cap:.0f}B",
                occupancy_bytes=occ)

    # ---------- per-tensor average bytes per dense position ----------
    # the compression ratio depends on the word width (metadata bits do
    # not scale with it), so it is computed per distinct edge width
    def comp_ratio(tname: str, wb: float) -> float:
        full = wl.tensor(tname).size(wl.dim_sizes)
        return effective_bytes(st.formats[tname], dmodel[tname], full,
                               wb) / max(full * wb, 1)

    ratio = {(t.name, wb): comp_ratio(t.name, wb)
             for t in wl.tensors
             for wb in set(arch.edge_word_bytes)}

    # ---------- S/G filter fractions per edge ----------
    # a follower's surviving fraction is the product of its leaders'
    # intersection hit rates (DensityModel.hit_rate — the mean density
    # for uniform/banded/N:M leaders; N:M is deterministic at n/m)
    def edge_fraction(site: str, tname: str, energy: bool) -> float:
        sg = st.sg[site]
        if tname not in followers(sg):
            return 1.0
        if is_skip(sg) or (energy and is_gate(sg)):
            f = 1.0
            for ld in leaders(sg):
                if ld != tname:
                    f *= hit[ld]
            return f
        return 1.0

    # ---------- traffic ----------
    z_name = wl.output.name
    traffic_e: Dict[str, float] = {}     # energy-relevant bytes
    traffic_t: Dict[str, float] = {}     # time-relevant bytes
    # one edge per store below the backing store, filtered by the S/G
    # site of its source store (None for the backing store's edge)
    store_sites = tuple(s for s in arch.sg_sites[:-1])
    edges = tuple(
        (arch.store_names[k + 1],
         None if arch.edge_site[k] is None
         else store_sites[arch.edge_site[k]],
         arch.edge_word_bytes[k])
        for k in range(arch.n_edges))
    for store, site, wb in edges:
        for t in wl.tensors:
            fills = mp.fills(store, t.name)
            if t.name == z_name:
                total = wl.output.size(wl.dim_sizes)
                # read-modify-write; write-once when fully accumulated
                fills = max(2.0 * fills - total, float(total))
            bytes_dense = fills * wb * ratio[(t.name, wb)]
            fe = ft = 1.0
            if site is not None:
                fe = edge_fraction(site, t.name, energy=True)
                ft = edge_fraction(site, t.name, energy=False)
            traffic_e[f"{store}:{t.name}"] = bytes_dense * fe
            traffic_t[f"{store}:{t.name}"] = bytes_dense * ft

    # ---------- compute ----------
    macs_dense = float(wl.macs)
    cycle_leaders = set()
    energy_leaders = set()
    for site in arch.sg_sites:
        sg = st.sg[site]
        if is_skip(sg):
            cycle_leaders.update(leaders(sg))
            energy_leaders.update(leaders(sg))
        elif is_gate(sg):
            energy_leaders.update(leaders(sg))
    cyc_frac = 1.0
    for ld in cycle_leaders:
        cyc_frac *= hit[ld]
    e_frac = 1.0
    for ld in energy_leaders:
        e_frac *= hit[ld]

    compute_cycles = float(mp.temporal_iterations()) * cyc_frac

    # ---------- energy ----------
    br: Dict[str, float] = {}
    for k in range(arch.n_edges):
        store = arch.store_names[k + 1]
        edge_bytes = sum(v for key, v in traffic_e.items()
                         if key.startswith(f"{store}:"))
        for gname, comps in arch.edge_energy[k]:
            # accumulate: two edges may share a group name (e.g. "noc")
            br[gname] = br.get(gname, 0.0) + edge_bytes * sum(comps)
    br["mac"] = macs_dense * e_frac * arch.e_mac
    energy = sum(br.values())

    # ---------- latency ----------
    cycles = compute_cycles
    dram_cycles = 0.0
    for k, bpc in arch.bw_edges:
        store = arch.store_names[k + 1]
        edge_bytes_t = sum(v for key, v in traffic_t.items()
                           if key.startswith(f"{store}:"))
        edge_cycles = edge_bytes_t / bpc
        if k == 0:
            dram_cycles = edge_cycles
        cycles = max(cycles, edge_cycles)
    edp = cycles * energy

    return CostReport(
        valid=True, energy_pj=energy, cycles=cycles, edp=edp,
        energy_breakdown=br, traffic_bytes=traffic_e,
        compute_cycles=compute_cycles, dram_cycles=dram_cycles,
        occupancy_bytes=occ,
    )
