"""Sparseloop-class analytical cost model (SparseMap §IV.I "Evaluation
Environment"; Sparseloop/TimeloopV2 methodology).

Given (Workload, Mapping, SparseStrategy, Platform) it returns energy (pJ),
latency (cycles), EDP (cycles * pJ) and a validity verdict.  The paper uses
the TimeloopV2 binary; this is a faithful re-implementation of its published
accounting (per-level access counts from loop-nest reuse analysis, density-
scaled by the sparse strategy, per-access energy tables) — see DESIGN.md §5
for the assumptions.

Traffic edges and the S/G site that filters each edge:

    DRAM -> GLB       : compression only (no S/G)
    GLB  -> PE buffer : "L2" S/G site
    PEbuf-> MAC regs  : "L3" S/G site
    MAC ops           : "C"  S/G site

Skip scales energy AND cycles; Gate scales energy only (Fig. 6).  A skip
anywhere whose leader is tensor T multiplies the effectual compute-cycle
fraction by density(T) (the paper's Fig. 14: skipping empty P rows at the
GLB skips the whole corresponding compute iterations).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from .accel import Platform
from .mapping import Mapping, N_LEVELS, SPATIAL_LEVELS
from .sparse import (FMT_U, SparseStrategy, TensorFormat, effective_bytes,
                     followers, is_gate, is_skip, leaders)
from .workload import WORD_BYTES, Workload


@dataclasses.dataclass(frozen=True)
class Design:
    mapping: Mapping
    strategy: SparseStrategy


@dataclasses.dataclass
class CostReport:
    valid: bool
    reason: str = ""
    energy_pj: float = 0.0
    cycles: float = 0.0
    edp: float = float("inf")
    # --- breakdowns for analysis/benchmarks ---
    energy_breakdown: Dict[str, float] = dataclasses.field(default_factory=dict)
    traffic_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    compute_cycles: float = 0.0
    dram_cycles: float = 0.0
    glb_occupancy_bytes: float = 0.0
    pebuf_occupancy_bytes: float = 0.0

    @property
    def fitness(self) -> float:
        return 0.0 if not self.valid else 1.0 / max(self.edp, 1e-30)


def tiled_subdims(mapping: Mapping, tensor_name: str
                  ) -> Tuple[Tuple[int, str, int], ...]:
    """Tiled sub-dimensions of a tensor, outer->inner: (level, dim, size),
    keeping only factors > 1 (paper Fig. 13: formats are specified for the
    sub-dimensions that actually exist)."""
    t = mapping.workload.tensor(tensor_name)
    out = []
    for lvl in range(N_LEVELS):
        for d in mapping.perms[lvl]:
            if d in t.dims:
                f = mapping.factors[lvl].get(d, 1)
                if f > 1:
                    out.append((lvl, d, f))
    return tuple(out)


def spatial_subdim_indices(mapping: Mapping, tensor_name: str
                           ) -> Tuple[int, ...]:
    subs = tiled_subdims(mapping, tensor_name)
    return tuple(i for i, (lvl, _, _) in enumerate(subs)
                 if lvl in SPATIAL_LEVELS)


def make_tensor_format(mapping: Mapping, tensor_name: str,
                       fmt_genes: Tuple[int, ...]) -> TensorFormat:
    """Apply the paper's gene->format rule: the sub-segment has
    ``MAX_FMT_GENES`` genes; the LAST k genes map to the k tiled
    sub-dimensions; sub-dimensions beyond the first 5 stay uncompressed."""
    subs = tiled_subdims(mapping, tensor_name)
    k = len(subs)
    ng = len(fmt_genes)
    if k <= ng:
        fmts = tuple(fmt_genes[ng - k:])
    else:
        fmts = tuple(fmt_genes) + tuple([FMT_U] * (k - ng))
    return TensorFormat(tensor=tensor_name, formats=fmts,
                        fiber_lens=tuple(s for _, _, s in subs))


# --------------------------------------------------------------------------


def evaluate(design: Design, platform: Platform) -> CostReport:
    mp = design.mapping
    st = design.strategy
    wl = mp.workload

    # ---------- validity: spatial fanout ----------
    if mp.spatial_fanout(2) > platform.n_pe:
        return CostReport(False, f"L2_S fanout {mp.spatial_fanout(2)} "
                                 f"> {platform.n_pe} PEs")
    if mp.spatial_fanout(4) > platform.macs_per_pe:
        return CostReport(False, f"L3_S fanout {mp.spatial_fanout(4)} "
                                 f"> {platform.macs_per_pe} MACs/PE")

    # ---------- validity: sparse strategy ----------
    spatial_subs = {t.name: spatial_subdim_indices(mp, t.name)
                    for t in wl.tensors}
    ok, why = st.valid(spatial_subs)
    if not ok:
        return CostReport(False, why)

    dens = {t.name: wl.density_of(t.name) for t in wl.tensors}

    def tile_bytes(store: str, tname: str) -> float:
        n = mp.tensor_tile_elems(store, tname)
        return effective_bytes(st.formats[tname], dens[tname], n, WORD_BYTES)

    # ---------- validity: buffer capacities ----------
    glb_occ = sum(tile_bytes("glb", t.name) for t in wl.tensors)
    if glb_occ > platform.glb_bytes:
        return CostReport(False, f"GLB overflow {glb_occ:.0f}B "
                                 f"> {platform.glb_bytes}B")
    pe_occ = sum(tile_bytes("pebuf", t.name) for t in wl.tensors)
    if pe_occ > platform.pe_buffer_bytes:
        return CostReport(False, f"PE buffer overflow {pe_occ:.0f}B "
                                 f"> {platform.pe_buffer_bytes}B")

    # ---------- per-tensor average bytes per dense position ----------
    def comp_ratio(tname: str) -> float:
        full = wl.tensor(tname).size(wl.dim_sizes)
        return effective_bytes(st.formats[tname], dens[tname], full,
                               WORD_BYTES) / max(full * WORD_BYTES, 1)

    ratio = {t.name: comp_ratio(t.name) for t in wl.tensors}

    # ---------- S/G filter fractions per edge ----------
    # edge "glb" (DRAM->GLB): no S/G.  edge "pebuf": site L2.
    # edge "reg": site L3.  compute: site C.
    def edge_fraction(site: str, tname: str, energy: bool) -> float:
        sg = st.sg[site]
        if tname not in followers(sg):
            return 1.0
        if is_skip(sg) or (energy and is_gate(sg)):
            f = 1.0
            for ld in leaders(sg):
                if ld != tname:
                    f *= dens[ld]
            return f
        return 1.0

    # ---------- traffic ----------
    z_name = wl.output.name
    traffic_e: Dict[str, float] = {}     # energy-relevant bytes
    traffic_t: Dict[str, float] = {}     # time-relevant bytes (DRAM only)
    edges = (("glb", None), ("pebuf", "L2"), ("reg", "L3"))
    for store, site in edges:
        for t in wl.tensors:
            fills = mp.fills(store, t.name)
            if t.name == z_name:
                total = wl.output.size(wl.dim_sizes)
                # read-modify-write; write-once when fully accumulated
                fills = max(2.0 * fills - total, float(total))
            bytes_dense = fills * WORD_BYTES * ratio[t.name]
            fe = ft = 1.0
            if site is not None:
                fe = edge_fraction(site, t.name, energy=True)
                ft = edge_fraction(site, t.name, energy=False)
            traffic_e[f"{store}:{t.name}"] = bytes_dense * fe
            traffic_t[f"{store}:{t.name}"] = bytes_dense * ft

    # ---------- compute ----------
    macs_dense = float(wl.macs)
    cycle_leaders = set()
    energy_leaders = set()
    for site in ("L2", "L3", "C"):
        sg = st.sg[site]
        if is_skip(sg):
            cycle_leaders.update(leaders(sg))
            energy_leaders.update(leaders(sg))
        elif is_gate(sg):
            energy_leaders.update(leaders(sg))
    cyc_frac = 1.0
    for ld in cycle_leaders:
        cyc_frac *= dens[ld]
    e_frac = 1.0
    for ld in energy_leaders:
        e_frac *= dens[ld]

    compute_cycles = float(mp.temporal_iterations()) * cyc_frac

    # ---------- energy ----------
    e_glb = platform.scaled_glb_energy()
    e_pe = platform.scaled_pebuf_energy()
    br: Dict[str, float] = {}
    br["dram"] = sum(v for k, v in traffic_e.items()
                     if k.startswith("glb:")) * platform.e_dram_per_byte
    br["glb"] = sum(v for k, v in traffic_e.items()
                    if k.startswith("pebuf:")) * (e_glb + platform.e_noc_per_byte)
    br["pebuf"] = sum(v for k, v in traffic_e.items()
                      if k.startswith("reg:")) * e_pe
    br["reg"] = sum(v for k, v in traffic_e.items()
                    if k.startswith("reg:")) * platform.e_reg_per_byte
    br["mac"] = macs_dense * e_frac * platform.e_mac
    energy = sum(br.values())

    # ---------- latency ----------
    dram_bytes_t = sum(v for k, v in traffic_t.items() if k.startswith("glb:"))
    dram_cycles = dram_bytes_t / platform.dram_bytes_per_cycle
    cycles = max(compute_cycles, dram_cycles)
    edp = cycles * energy

    return CostReport(
        valid=True, energy_pj=energy, cycles=cycles, edp=edp,
        energy_breakdown=br, traffic_bytes=traffic_e,
        compute_cycles=compute_cycles, dram_cycles=dram_cycles,
        glb_occupancy_bytes=glb_occ, pebuf_occupancy_bytes=pe_occ,
    )
