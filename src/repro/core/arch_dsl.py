"""Declarative accelerator descriptions -> :class:`ArchSpec`.

A TeAAL-flavored frontend: an accelerator is a plain dict (every value a
JSON/TOML type — strings, numbers, lists, dicts, booleans) naming its
storage levels outermost-first, and :func:`compile_arch` lowers it to the
:class:`repro.core.arch.ArchSpec` the whole mapping/cost/search stack
runs on.  Nothing here adds modeling power — the DSL is sugar over
``ArchSpec``/``StorageLevel``/``NoCSpec`` — but it makes a new zoo entry
a few declarative lines instead of hand-assembled Python:

    EYERISS = compile_arch({
        "name": "eyeriss_like",
        "levels": [
            {"name": "dram"},
            {"name": "glb", "capacity": "108KB", "bandwidth": "1GB/s",
             "energy": [["dram", [100.0]]], "sg_site": "L2"},
            {"name": "spad", "capacity": "512B",
             "energy": [["glb", [6.0, 0.3]]],
             "fanout": [12, 14],                    # 2-D PE mesh
             "noc": {"multicast": "row",            # X-bus per row
                     "reduction": "col"},           # psums down columns
             "sg_site": "L3"},
            {"name": "reg", "energy": [["spad", [0.6]], ["reg", [0.05]]]},
        ],
    })

Spelling conventions (each mirrors an ``ArchSpec`` field; see COMPAT.md
"Declarative arch frontend" for the contract):

* ``capacity`` — bytes as a number, or a BINARY-unit string:
  ``"512B"``, ``"256KB"`` (= 256*1024), ``"64MB"``, ``"2GB"``.
* ``bandwidth`` — bytes/cycle as a number, or a DECIMAL-unit rate
  string divided by the chip clock: ``"16MB/s"`` = 16e6 bytes/s ->
  ``16e6 / clock_hz`` bytes/cycle (matching Table II's convention).
* ``energy`` — ordered ``[group, [component, ...]]`` pairs, pJ/byte
  into this level (the ``EnergyGroups`` shape, as nested lists).
* ``fanout`` — an instance count, or a 2-item ``[rows, cols]`` mesh.
  A mesh is the same ``rows * cols`` instances structurally, but lets
  ``noc`` schemes resolve their fanout geometrically.
* ``noc`` — ``{"multicast": ..., "reduction": ...}``.  Each scheme is
  ``true``/``"all"`` (one copy serves everyone), ``false``/``"none"``
  (one copy per instance), ``"row"``/``"col"`` (fractional; the
  discount fanout is read off the level's mesh: a row-wise bus serves
  ``cols`` instances per copy, a column-wise one ``rows``), or an
  explicit ``[label, fanout]`` pair (e.g. ``["cluster", 8]``).
* ``word``  — datawidth of one element in this level, in BYTES
  (``1.0`` for an 8-bit store); omitted = the global 16-bit default.
* ``clock`` (top level) — Hz as a number or ``"1GHz"``/``"200MHz"``
  style string; ``mac_energy`` — pJ/MAC.

The compiled ArchSpec is indistinguishable from a hand-built one:
:func:`sparsemap_desc` re-derives the paper topology and compiles
bit-identical to ``ARCH_SPARSEMAP`` (pinned against
``tests/golden/arch_sparsemap_golden.npz``).  Register the result with
:func:`repro.core.arch.register_arch` to make it a named, searchable
topology (``repro.configs.archs`` defines the zoo this way).
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple, Union

from .accel import Platform
from .arch import ArchSpec, NoCSpec, StorageLevel

Desc = Dict[str, Any]

# Capacities are storage sizes -> binary units; bandwidth strings are
# link rates -> decimal units (vendor convention, and exactly how the
# existing configs spell "16 MB/s DRAM" as ``16e6 / 1.0e9``).
_CAP_UNITS = {"B": 1.0, "KB": 1024.0, "MB": 1024.0 ** 2,
              "GB": 1024.0 ** 3}
_RATE_UNITS = {"B/S": 1e0, "KB/S": 1e3, "MB/S": 1e6, "GB/S": 1e9,
               "TB/S": 1e12}
_FREQ_UNITS = {"HZ": 1e0, "KHZ": 1e3, "MHZ": 1e6, "GHZ": 1e9}

_NUM_UNIT = re.compile(r"^\s*([0-9]+(?:\.[0-9]+)?)\s*([A-Za-z/]+)\s*$")


def _parse_unit(value: Union[str, float, int], units: Dict[str, float],
                what: str) -> float:
    """A number passes through; a string must be ``<number><unit>``."""
    if isinstance(value, (int, float)) and not isinstance(value, bool):
        return float(value)
    if isinstance(value, str):
        m = _NUM_UNIT.match(value)
        if m and m.group(2).upper() in units:
            return float(m.group(1)) * units[m.group(2).upper()]
    raise ValueError(
        f"cannot parse {what} {value!r}; give a number or a "
        f"'<number><unit>' string with unit in {sorted(units)}")


def parse_capacity(value: Union[str, float, int]) -> float:
    """Bytes.  String units are BINARY: ``"256KB"`` = 256 * 1024."""
    return _parse_unit(value, _CAP_UNITS, "capacity")


def parse_frequency(value: Union[str, float, int]) -> float:
    """Hz.  ``"1GHz"`` = 1e9."""
    return _parse_unit(value, _FREQ_UNITS, "clock")


def parse_bandwidth(value: Union[str, float, int],
                    clock_hz: float) -> float:
    """Bytes per CYCLE.  A bare number is already per-cycle; a rate
    string is DECIMAL bytes/s divided by the clock: ``"16MB/s"`` at 1 GHz
    -> ``0.016`` bytes/cycle."""
    if isinstance(value, str):
        return _parse_unit(value, _RATE_UNITS, "bandwidth") / clock_hz
    return _parse_unit(value, _RATE_UNITS, "bandwidth")


def _parse_energy(value: Any, level: str) -> Tuple:
    """``[[group, [comp, ...]], ...]`` -> the EnergyGroups tuple shape."""
    try:
        groups = tuple(
            (str(group), tuple(float(c) for c in comps))
            for group, comps in value)
    except (TypeError, ValueError) as e:
        raise ValueError(
            f"level {level!r}: energy must be ordered [group, "
            f"[component, ...]] pairs (pJ/byte), e.g. "
            f'[["glb", [3.5, 0.3]], ["reg", [0.05]]]; got {value!r}') \
            from e
    for group, comps in groups:
        if not comps:
            raise ValueError(
                f"level {level!r}: energy group {group!r} has no "
                f"components")
    return groups


def _parse_fanout(value: Any, level: str) \
        -> Tuple[int, Optional[Tuple[int, int]]]:
    """An int instance count, or a ``[rows, cols]`` mesh.  Returns
    ``(total_fanout, mesh_dims_or_None)``."""
    if isinstance(value, (list, tuple)):
        if len(value) != 2 or not all(
                isinstance(v, int) and v > 0 for v in value):
            raise ValueError(
                f"level {level!r}: a mesh fanout is [rows, cols] with "
                f"positive ints, got {value!r}")
        rows, cols = value
        return rows * cols, (rows, cols)
    if isinstance(value, int) and not isinstance(value, bool) \
            and value > 0:
        return value, None
    raise ValueError(
        f"level {level!r}: fanout must be a positive int or a "
        f"[rows, cols] mesh, got {value!r}")


def _parse_scheme(value: Any, mesh: Optional[Tuple[int, int]],
                  level: str, kind: str) \
        -> Tuple[Union[bool, str], Optional[float]]:
    """One NoC scheme declaration -> ``(scheme, fanout)`` NoCSpec args.

    ``true``/``"all"`` and ``false``/``"none"`` normalize to the plain
    booleans (so a desc-built arch compares equal to a hand-built one).
    ``"row"``/``"col"`` read their discount fanout off the level's mesh;
    any other fractional scheme spells it explicitly: ``[label, fanout]``.
    """
    if value is True or value == "all":
        return True, None
    if value is False or value == "none":
        return False, None
    if isinstance(value, (list, tuple)):
        if len(value) != 2 or not isinstance(value[0], str):
            raise ValueError(
                f"level {level!r}: noc {kind} pair must be "
                f"[scheme, fanout], got {value!r}")
        label, fan = value
        if label in ("all", "none"):
            raise ValueError(
                f"level {level!r}: noc {kind}={label!r} takes no fanout "
                f"(only fractional schemes carry a discount)")
        return label, float(fan)
    if value in ("row", "col"):
        if mesh is None:
            raise ValueError(
                f"level {level!r}: noc {kind}={value!r} needs a "
                f"[rows, cols] mesh fanout to resolve its discount "
                f"(or spell it explicitly as [{value!r}, fanout])")
        rows, cols = mesh
        # a row-wise bus puts one copy on each row's bus; it serves the
        # `cols` instances along that row (and vice versa)
        return value, float(cols if value == "row" else rows)
    if isinstance(value, str) and value:
        raise ValueError(
            f"level {level!r}: fractional noc {kind}={value!r} needs an "
            f"explicit discount — use [{value!r}, fanout] (only "
            f"'row'/'col' auto-resolve from a mesh)")
    raise ValueError(
        f"level {level!r}: noc {kind} must be true/'all', false/'none', "
        f"'row'/'col' (with a mesh), or [scheme, fanout]; got {value!r}")


def _parse_noc(value: Any, mesh: Optional[Tuple[int, int]],
               level: str) -> NoCSpec:
    if not isinstance(value, dict):
        raise ValueError(
            f"level {level!r}: noc must be a dict with 'multicast' / "
            f"'reduction' keys, got {value!r}")
    unknown = set(value) - {"multicast", "reduction"}
    if unknown:
        raise ValueError(
            f"level {level!r}: unknown noc keys {sorted(unknown)} "
            f"(allowed: multicast, reduction)")
    mc, mc_fan = _parse_scheme(value.get("multicast", True), mesh,
                               level, "multicast")
    red, red_fan = _parse_scheme(value.get("reduction", True), mesh,
                                 level, "reduction")
    return NoCSpec(multicast=mc, reduction=red,
                   multicast_fanout=mc_fan, reduction_fanout=red_fan)


_LEVEL_KEYS = {"name", "capacity", "energy", "fanout", "sg_site",
               "bandwidth", "word", "noc", "spatial"}
_TOP_KEYS = {"name", "levels", "mac_energy", "clock"}


def _parse_level(d: Any, clock_hz: float, outermost: bool) \
        -> StorageLevel:
    if not isinstance(d, dict) or "name" not in d:
        raise ValueError(f"each level is a dict with at least a 'name'; "
                         f"got {d!r}")
    name = d["name"]
    unknown = set(d) - _LEVEL_KEYS
    if unknown:
        raise ValueError(
            f"level {name!r}: unknown keys {sorted(unknown)} "
            f"(allowed: {sorted(_LEVEL_KEYS)})")
    if outermost:
        extra = set(d) - {"name"}
        if extra:
            raise ValueError(
                f"the outermost (backing) level {name!r} has no fill "
                f"edge; it takes only 'name', got extra keys "
                f"{sorted(extra)}")
        return StorageLevel(name)
    kw: Dict[str, Any] = {}
    if "capacity" in d:
        kw["capacity_bytes"] = parse_capacity(d["capacity"])
    if "energy" in d:
        kw["fill_energy"] = _parse_energy(d["energy"], name)
    mesh: Optional[Tuple[int, int]] = None
    if "fanout" in d:
        kw["fanout"], mesh = _parse_fanout(d["fanout"], name)
    if "sg_site" in d:
        kw["sg_site"] = str(d["sg_site"])
    if "bandwidth" in d:
        kw["fill_bandwidth_bytes_per_cycle"] = parse_bandwidth(
            d["bandwidth"], clock_hz)
    if "word" in d:
        kw["word_bytes"] = float(d["word"])
    if "noc" in d:
        kw["noc"] = _parse_noc(d["noc"], mesh, name)
    if "spatial" in d:
        kw["spatial"] = bool(d["spatial"])
    return StorageLevel(name, **kw)


def compile_arch(desc: Desc) -> ArchSpec:
    """Lower a declarative accelerator description (module docstring has
    the schema) to an :class:`ArchSpec`.  Purely structural — nothing is
    registered; pass the result to :func:`repro.core.arch.register_arch`
    to make it name-resolvable."""
    if not isinstance(desc, dict):
        raise ValueError(f"an arch description is a dict, got "
                         f"{type(desc).__name__}")
    unknown = set(desc) - _TOP_KEYS
    if unknown:
        raise ValueError(f"unknown description keys {sorted(unknown)} "
                         f"(allowed: {sorted(_TOP_KEYS)})")
    for key in ("name", "levels"):
        if key not in desc:
            raise ValueError(f"description needs a {key!r} key")
    clock_hz = parse_frequency(desc.get("clock", 1.0e9))
    levels = tuple(
        _parse_level(d, clock_hz, outermost=(i == 0))
        for i, d in enumerate(desc["levels"]))
    return ArchSpec(
        name=str(desc["name"]), levels=levels,
        e_mac=float(desc.get("mac_energy", 0.8)), clock_hz=clock_hz)


def sparsemap_desc(platform: Union[str, Platform] = "cloud",
                   name: Optional[str] = None) -> Desc:
    """The paper topology (Fig. 3a: DRAM -> GLB -> PE array -> MACs) as
    a declarative description, populated with a platform's Table II
    numbers.  ``compile_arch(sparsemap_desc("cloud", "sparsemap"))`` is
    bit-identical to the hand-built ``ARCH_SPARSEMAP`` (test-pinned
    against ``tests/golden/arch_sparsemap_golden.npz``)."""
    from .accel import PLATFORMS
    p = PLATFORMS[platform] if isinstance(platform, str) else platform
    return {
        "name": p.name if name is None else name,
        "clock": p.clock_hz,
        "mac_energy": p.e_mac,
        "levels": [
            {"name": "dram"},
            {"name": "glb",
             "capacity": p.glb_bytes,
             "energy": [["dram", [p.e_dram_per_byte]]],
             "sg_site": "L2",
             "bandwidth": p.dram_bytes_per_cycle},
            {"name": "pebuf",
             "capacity": p.pe_buffer_bytes,
             "energy": [["glb", [p.scaled_glb_energy(),
                                 p.e_noc_per_byte]]],
             "fanout": p.n_pe,
             "sg_site": "L3",
             "spatial": True},
            {"name": "reg",
             "energy": [["pebuf", [p.scaled_pebuf_energy()]],
                        ["reg", [p.e_reg_per_byte]]],
             "fanout": p.macs_per_pe,
             "spatial": True},
        ],
    }
