"""Backend-shared ES operator core: one definition of SparseMap's
evolutionary operators usable from both the numpy host loop and the
device-resident ``lax.scan`` round program (``jax_cost.run_segments``).

Every operator is split into a *draw plan* and a pure *apply*:

* ``plan_crossover`` / ``plan_mutation`` reproduce the numpy
  ``Generator`` call sequence of the legacy ``evolution.crossover`` /
  ``evolution.mutate`` exactly (same calls, same order, same shapes), so
  the host loop and a device segment fed the same plan make bit-identical
  operator choices.  The numpy implementations remain the oracle.
* ``apply_crossover`` / ``apply_mutation`` consume a plan and work on
  either numpy or ``jax.numpy`` arrays — the numpy path is byte-identical
  to the legacy in-place formulation (duplicate gene draws within a row
  overwrite in draw order: the apply walks the ``genes_per`` columns
  sequentially, which XLA scatters preserve because each column's row
  indices are unique).
* ``threefry_plan_generation`` is the device-RNG alternative: the same
  plan arrays drawn with ``jax.random`` (threefry) keyed by
  ``(seed, generation)``.  It is a different stream from the numpy
  oracle by construction — the RNG seam is test-pinned — but it is
  deterministic across drivers and platforms.

The module also defines the **device-segment protocol** types
(:class:`DeviceSegment`, :class:`SegmentResult`) that request generators
yield when ``ESConfig.device_rounds > 1``, and :class:`PaddedLayout`,
the genome-column padding that lets same-signature workloads with
different prime counts share one compiled scan program (pad columns are
numerically inert: value 0, upper bound 1).

This module is the ONE sanctioned home for raw RNG in ``repro.core``:
contract rule R2 (``python -m repro.analysis``, COMPAT.md
"Machine-checked contracts") forbids ``np.random.*`` / stdlib
``random`` everywhere else in the core so that every draw reaches the
kernels as a pre-planned array.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

# --------------------------------------------------------------- plans


@dataclasses.dataclass
class GenDraws:
    """All randomness of ONE generation of the ES main loop, in canonical
    (unpadded) genome coordinates: crossover parent pairs + cut positions,
    then mutation activity/gene/value draws."""

    ab: np.ndarray          # (C, 2) parent indices into the sorted top-P
    cuts: np.ndarray        # (C,) absolute single-point cut positions
    active: np.ndarray      # (C,) bool: row is mutated
    gene: np.ndarray        # (C, genes_per) gene indices
    vals: np.ndarray        # (C, genes_per) replacement values


def crossover_cut_points(L: int, sens=None) -> np.ndarray:
    """Allowed single-point cut positions.  With ``sens``: restricted to
    high-sensitivity segment boundaries (never splitting a run), exactly
    as ``evolution.crossover``."""
    if sens is not None:
        pts = {0, L}
        for a, b in sens.high_segments():
            pts.add(a)
            pts.add(b)
        cut_points = sorted(pts - {0, L}) or [L // 2]
    else:
        cut_points = list(range(1, L))
    return np.asarray(cut_points, dtype=np.int64)


def plan_crossover(rng: np.random.Generator, n_children: int,
                   n_parents: int, cut_arr: np.ndarray
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """The two crossover draws, in the legacy call order: parent pairs,
    then cut-point indices."""
    ab = rng.integers(0, n_parents, size=(n_children, 2))
    cuts = cut_arr[rng.integers(0, len(cut_arr), size=n_children)]
    return ab, cuts


def mutation_index_tables(L: int, sens) -> Tuple[Optional[np.ndarray],
                                                 Optional[np.ndarray]]:
    """(hi, lo) gene-index tables for annealing mutation; (None, None)
    for uniform mutation.  Empty tables fall back to all genes, exactly
    as ``evolution.mutate``."""
    if sens is None:
        return None, None
    all_idx = np.arange(L)
    hi = sens.high_indices
    lo = sens.low_indices
    if len(hi) == 0:
        hi = all_idx
    if len(lo) == 0:
        lo = all_idx
    return hi, lo


def plan_mutation(rng: np.random.Generator, n: int, gene_ub: np.ndarray,
                  genes_per: int, p_mut: float, p_high: float = 0.0,
                  hi: Optional[np.ndarray] = None,
                  lo: Optional[np.ndarray] = None
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The mutation draws in the legacy call order: activity, gene
    indices (annealed high/low split when ``hi``/``lo`` are given,
    uniform otherwise), replacement values."""
    L = len(gene_ub)
    active = rng.random(n) < p_mut
    if hi is not None:
        use_high = rng.random(n) < p_high
        u = rng.random((n, genes_per))
        gene = np.where(use_high[:, None],
                        hi[(u * len(hi)).astype(np.int64)],
                        lo[(u * len(lo)).astype(np.int64)])
    else:
        gene = rng.integers(0, L, size=(n, genes_per))
    vals = rng.integers(0, gene_ub[gene])
    return active, gene, vals


def plan_generation(rng: np.random.Generator, *, n_children: int,
                    n_parents: int, cut_arr: np.ndarray,
                    gene_ub: np.ndarray, genes_per: int, p_mut: float,
                    p_high: float, hi: Optional[np.ndarray],
                    lo: Optional[np.ndarray]) -> GenDraws:
    """One generation's full plan, matching the legacy per-generation
    draw order (crossover first, then mutation)."""
    ab, cuts = plan_crossover(rng, n_children, n_parents, cut_arr)
    active, gene, vals = plan_mutation(rng, n_children, gene_ub, genes_per,
                                       p_mut, p_high, hi, lo)
    return GenDraws(ab=ab, cuts=cuts, active=active, gene=gene, vals=vals)


def threefry_plan_generation(seed: int, gen: int, *, n_children: int,
                             n_parents: int, cut_arr: np.ndarray,
                             gene_ub: np.ndarray, genes_per: int,
                             p_mut: float, p_high: float,
                             hi: Optional[np.ndarray],
                             lo: Optional[np.ndarray]) -> GenDraws:
    """The threefry-keyed variant of :func:`plan_generation`: the same
    plan arrays drawn with ``jax.random`` from ``fold_in(PRNGKey(seed),
    gen)``.  Deterministic across drivers and devices; a *different*
    stream from the numpy oracle (the seam is test-pinned)."""
    import jax.random as jr
    L = len(gene_ub)
    key = jr.fold_in(jr.PRNGKey(seed), gen)
    k_ab, k_cut, k_act, k_gene, k_u, k_val = jr.split(key, 6)
    ab = np.asarray(jr.randint(k_ab, (n_children, 2), 0, n_parents),
                    dtype=np.int64)
    cuts = cut_arr[np.asarray(
        jr.randint(k_cut, (n_children,), 0, len(cut_arr)), dtype=np.int64)]
    active = np.asarray(jr.uniform(k_act, (n_children,))) < p_mut
    if hi is not None:
        use_high = np.asarray(jr.uniform(k_gene, (n_children,))) < p_high
        u = np.asarray(jr.uniform(k_u, (n_children, genes_per)))
        gene = np.where(use_high[:, None],
                        hi[(u * len(hi)).astype(np.int64)],
                        lo[(u * len(lo)).astype(np.int64)])
    else:
        gene = np.asarray(jr.randint(k_gene, (n_children, genes_per), 0, L),
                          dtype=np.int64)
    vals = (np.asarray(jr.uniform(k_val, (n_children, genes_per)))
            * gene_ub[gene]).astype(np.int64)
    return GenDraws(ab=ab, cuts=cuts, active=active, gene=gene, vals=vals)


def stack_draws(draws: Sequence[GenDraws]) -> Dict[str, np.ndarray]:
    """Stack k per-generation plans into the (k, ...) arrays a
    ``lax.scan`` consumes as its xs."""
    return dict(
        ab=np.stack([d.ab for d in draws]).astype(np.int32),
        cuts=np.stack([d.cuts for d in draws]).astype(np.int32),
        active=np.stack([d.active for d in draws]),
        gene=np.stack([d.gene for d in draws]).astype(np.int32),
        vals=np.stack([d.vals for d in draws]).astype(np.int32))


# --------------------------------------------------------------- applies


def apply_crossover(parents, ab, cuts):
    """Assemble all children from a crossover plan.  Works on numpy and
    jax.numpy arrays (the index grid + ``where`` formulation is shared)."""
    if isinstance(parents, np.ndarray):
        xp = np
    else:
        import jax.numpy as xp
    L = parents.shape[1]
    col = xp.arange(L)[None, :]
    return xp.where(col < cuts[:, None], parents[ab[:, 0]],
                    parents[ab[:, 1]])


def apply_mutation(genomes, active, gene, vals):
    """Apply a mutation plan.  Duplicate gene draws within a row
    overwrite in draw order — the apply walks the ``genes_per`` columns
    sequentially (each column's row indices are unique, so the order is
    deterministic under XLA scatters too).  Returns a new array; the
    input is not modified."""
    n, genes_per = gene.shape
    if isinstance(genomes, np.ndarray):
        out = genomes.copy()
        rows = np.arange(n)
        for j in range(genes_per):
            g = gene[:, j]
            out[rows, g] = np.where(active, vals[:, j], out[rows, g])
        return out
    import jax.numpy as jnp
    out = genomes
    rows = jnp.arange(n)
    for j in range(genes_per):
        g = gene[:, j]
        out = out.at[rows, g].set(
            jnp.where(active, vals[:, j], out[rows, g]))
    return out


def stable_order(edp):
    """Stable fitness order, shared by the device scan and the host
    fallback so a segment's trajectory is driver-invariant.  (The legacy
    per-round host loop keeps ``np.argsort``'s default introsort; the two
    differ only in tie order.)"""
    if isinstance(edp, np.ndarray):
        return np.argsort(edp, kind="stable")
    import jax.numpy as jnp
    return jnp.argsort(edp)


def select(pop, edp, n_parents: int, n_elite: int):
    """Elitist truncation selection: (parents, elites, elite_edp)."""
    order = stable_order(edp)
    return (pop[order[:n_parents]], pop[order[:n_elite]],
            edp[order[:n_elite]])


def best_so_far(edp):
    """Running best-so-far curve over a fitness sequence (jnp or np)."""
    if isinstance(edp, np.ndarray):
        return np.minimum.accumulate(edp)
    import jax.numpy as jnp
    import jax
    return jax.lax.associative_scan(jnp.minimum, edp)


# ------------------------------------------------------ padded layout


class PaddedLayout:
    """Column padding that maps a spec's canonical genome layout
    ``[perm | tiling(n_primes) | fmt | sg]`` onto the scan program's
    shared layout ``[perm | tiling(n_pad) | fmt | sg]``.  Pad columns are
    inert (value 0, upper bound 1); gene indices and cut positions at or
    beyond the tiling boundary shift by ``delta = n_pad - n_primes``."""

    def __init__(self, spec, n_pad: int):
        self.n_levels = spec.arch.n_levels
        self.n_primes = spec.n_primes
        self.n_pad = int(n_pad)
        if self.n_pad < self.n_primes:
            raise ValueError(f"n_pad {n_pad} < n_primes {self.n_primes}")
        self.boundary = self.n_levels + self.n_primes
        self.delta = self.n_pad - self.n_primes
        self.L = spec.length
        self.Lp = spec.length + self.delta
        self.cols = np.concatenate([
            np.arange(self.boundary),
            np.arange(self.boundary + self.delta, self.Lp)])

    def pad_rows(self, g: np.ndarray) -> np.ndarray:
        out = np.zeros(g.shape[:-1] + (self.Lp,), dtype=g.dtype)
        out[..., self.cols] = g
        return out

    def unpad_rows(self, gp: np.ndarray) -> np.ndarray:
        return np.ascontiguousarray(gp[..., self.cols])

    def pad_index(self, idx: np.ndarray) -> np.ndarray:
        """Gene indices: positions at/after the boundary shift up."""
        return np.where(idx >= self.boundary, idx + self.delta, idx)

    def pad_cut(self, c: np.ndarray) -> np.ndarray:
        """Cut positions: a cut strictly after the boundary shifts up (a
        cut AT the boundary keeps the same prefix; the pad columns it
        hands to the other parent are inert)."""
        return np.where(c > self.boundary, c + self.delta, c)

    def pad_vector(self, v: np.ndarray, fill) -> np.ndarray:
        out = np.full(self.Lp, fill, dtype=np.asarray(v).dtype)
        out[self.cols] = v
        return out


# ------------------------------------------------- segment protocol


@dataclasses.dataclass
class DeviceSegment:
    """A request for k device-resident ES generations.  Yielded by
    ``evolution.evolve_requests`` when ``ESConfig.device_rounds > 1``;
    drivers that can execute it send back a :class:`SegmentResult`
    (``jax_cost.run_segments``), drivers that cannot send ``None`` and
    the generator replays the same plan on the host — either way the
    trajectory is identical because all randomness is in ``draws``."""

    spec: object                    # GenomeSpec
    pop: np.ndarray                 # (B, L) current population, int64
    edp: np.ndarray                 # (B,) selection fitness, float32
    rounds: int                     # k generations in this segment
    gen0: int                       # index of the first generation
    n_parents: int
    n_elite: int
    genes_per: int
    draws: Dict[str, np.ndarray]    # stacked (k, ...) plan arrays
    fixed_genes: Optional[Dict[int, int]] = None
    rng_backend: str = "numpy"
    # pipelined dispatch (COMPAT.md "Pipelined dispatch contract"):
    # ``carry`` holds the previous segment's device-resident PADDED
    # (pop, edp) pair — when set, drivers feed the scan from it directly
    # and ``pop``/``edp`` are only the host-side fallback of record.
    carry: Optional[Tuple] = None
    # segment flavor: "es" runs in canonical genome coordinates;
    # "direct" carries direct-value genomes plus the translation tables
    # in ``aux`` (scramble, dim_sizes) and translates rows in-scan.
    kind: str = "es"
    aux: Optional[Dict[str, np.ndarray]] = None
    # stagnation restart folded into the scan: re-init the non-elite
    # population after ``restart`` generations without improvement of the
    # carried float32 best (0 = off).  ``state`` is the (best, since)
    # carry across segments; ``draws["fresh"]`` holds the pre-drawn
    # replacement populations.
    restart: int = 0
    state: Optional[Tuple[float, int]] = None


@dataclasses.dataclass
class SegmentResult:
    """What a driver sends back for a :class:`DeviceSegment`: the per-
    generation (kids, canonical output dict) pairs for `_Budget`
    accounting, plus the device's final carry state.

    With deferred harvesting (``jax_cost.run_segments(..., defer=True)``)
    ``gens``/``final_pop``/``final_edp`` start empty and ``harvest`` is a
    thunk that converts the device outputs to numpy on first call —
    request generators call :meth:`resolve` one round late, so the
    blocking conversion overlaps the next segment's device execution.
    ``carry`` always holds the device-resident PADDED (pop, edp) pair for
    the follow-up segment, and ``state`` the device (best, since) restart
    carry when the segment folded stagnation restarts."""

    gens: List[Tuple[np.ndarray, Dict[str, np.ndarray]]]
    final_pop: Optional[np.ndarray]  # (B, L) int64, unpadded
    final_edp: Optional[np.ndarray]  # (B,) float32
    carry: Optional[Tuple] = None    # device-resident padded (pop, edp)
    state: Optional[Tuple] = None    # device (best, since) restart carry
    harvest: Optional[Callable] = None

    def resolve(self) -> "SegmentResult":
        """Run the deferred numpy conversion (idempotent)."""
        if self.harvest is not None:
            self.gens, self.final_pop, self.final_edp = self.harvest()
            self.harvest = None
        return self


def segment_shape_key(seg: DeviceSegment) -> Tuple:
    """Tasks whose segments share this key (plus the evaluator
    compilation signature) can stack into one scan dispatch."""
    return (len(seg.pop), seg.rounds, seg.n_parents, seg.n_elite,
            seg.genes_per, getattr(seg, "kind", "es"),
            getattr(seg, "restart", 0))
