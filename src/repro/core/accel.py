"""Accelerator platform models (SparseMap §V.A, Table II).

A 3-level storage architecture: off-chip DRAM -> Global Buffer (GLB) ->
PE array (each PE with a local buffer and several MACs), Fig. 3(a).

Energy constants are 12 nm-class per-access numbers in pJ (the paper uses the
DSTC 12 nm process; absolute pJ values are config constants, not claims — see
DESIGN.md §5).  Latency model: 1 GHz clock; DRAM bandwidth from Table II.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class Platform:
    name: str
    n_pe: int                  # number of PEs (spatial fanout at L2_S)
    macs_per_pe: int           # MACs per PE (spatial fanout at L3_S)
    pe_buffer_bytes: int       # per-PE local buffer
    glb_bytes: int             # global buffer
    dram_bw_bytes_per_s: float
    clock_hz: float = 1.0e9

    # --- per-access energies, pJ per byte unless noted -----------------
    e_dram_per_byte: float = 100.0      # off-chip DRAM access
    e_glb_per_byte: float = 3.0         # large on-chip SRAM
    e_pebuf_per_byte: float = 0.6       # small local SRAM
    e_reg_per_byte: float = 0.05        # register/file forwarding
    e_mac: float = 0.8                  # one 16-bit MAC op, pJ
    e_noc_per_byte: float = 0.3         # GLB <-> PE network hop

    @property
    def dram_bytes_per_cycle(self) -> float:
        return self.dram_bw_bytes_per_s / self.clock_hz

    def scaled_glb_energy(self) -> float:
        """SRAM energy grows ~sqrt(capacity); normalize to 128 KB."""
        return self.e_glb_per_byte * math.sqrt(self.glb_bytes / (128 * 1024))

    def scaled_pebuf_energy(self) -> float:
        return self.e_pebuf_per_byte * math.sqrt(self.pe_buffer_bytes / 1024)


# Table II ---------------------------------------------------------------
EDGE = Platform(
    name="edge",
    n_pe=16 * 16, macs_per_pe=1,
    pe_buffer_bytes=1 * 1024, glb_bytes=128 * 1024,
    dram_bw_bytes_per_s=16e6,
)

MOBILE = Platform(
    name="mobile",
    n_pe=16 * 16, macs_per_pe=64,
    pe_buffer_bytes=32 * 1024, glb_bytes=16 * 1024 * 1024,
    dram_bw_bytes_per_s=32e9,
)

CLOUD = Platform(
    name="cloud",
    n_pe=32 * 32, macs_per_pe=64,
    pe_buffer_bytes=128 * 1024, glb_bytes=64 * 1024 * 1024,
    dram_bw_bytes_per_s=128e9,
)

PLATFORMS = {p.name: p for p in (EDGE, MOBILE, CLOUD)}


# TPU v5e roofline constants (assignment; used by core.autoshard + roofline
# benchmarks, NOT by the faithful paper cost model above).
TPU_V5E = dict(
    peak_bf16_flops=197e12,        # per chip
    hbm_bw_bytes_per_s=819e9,      # per chip
    ici_link_bw_bytes_per_s=50e9,  # per link per direction
)
