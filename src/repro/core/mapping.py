"""Mapping scheme (SparseMap §II.B, §III.A.1, Fig. 4).

A mapping on the 3-level storage architecture has five mapping levels,
outer to inner:

    idx  name   kind      hardware meaning
    0    L1_T   temporal  DRAM -> GLB tile schedule
    1    L2_T   temporal  GLB -> PE-array tile schedule
    2    L2_S   spatial   parallelism across PEs
    3    L3_T   temporal  PE-buffer -> MAC schedule
    4    L3_S   spatial   parallelism across MACs inside a PE

Each level carries one loop per iteration dimension; its bound is the tiling
factor of that dimension at that level (``prod_l factor[l][d] == size(d)``),
and a permutation orders the loops within the level (outermost first).

``LoopNest`` flattens a mapping to a single outer->inner loop list and
implements the classical Timeloop-style reuse analysis used by the cost
model: the number of fills of a tensor tile into a storage level is

    fills = footprint * prod(bounds of loops in the outer nest)
                      / prod(bounds of the innermost contiguous run of
                             loops irrelevant to the tensor)
    (bound-1 loops are transparent; irrelevant *spatial* loops multicast
     and never multiply traffic.)
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, List, Sequence, Tuple

from .workload import Workload

LEVEL_NAMES = ("L1_T", "L2_T", "L2_S", "L3_T", "L3_S")
N_LEVELS = 5
SPATIAL_LEVELS = (2, 4)          # indices of L2_S, L3_S
TEMPORAL_LEVELS = (0, 1, 3)

# Storage points between mapping levels.  Fills *into* a storage level see
# the loops strictly above it as the outer nest:
#   GLB       <- loops of L1_T                       (levels [0])
#   PE buffer <- loops of L1_T, L2_T, L2_S           (levels [0..2])
#   MAC regs  <- loops of L1_T .. L3_S               (levels [0..4])
OUTER_LEVELS_FOR = {
    "glb": (0,),
    "pebuf": (0, 1, 2),
    "reg": (0, 1, 2, 3, 4),
}
# Tile held *inside* a storage level spans the mapping levels below it:
INNER_LEVELS_FOR = {
    "glb": (1, 2, 3, 4),
    "pebuf": (3, 4),
    "reg": (),
}


@dataclasses.dataclass(frozen=True)
class Mapping:
    """Fully decoded mapping for a given workload."""

    workload: Workload
    # factors[level][dim_name] -> tiling factor (int >= 1)
    factors: Tuple[Dict[str, int], ...]
    # perms[level] -> tuple of dim names, outermost first
    perms: Tuple[Tuple[str, ...], ...]

    def __post_init__(self):
        for d in self.workload.dim_order:
            prod = 1
            for lvl in range(N_LEVELS):
                prod *= self.factors[lvl].get(d, 1)
            if prod != self.workload.dim_sizes[d]:
                raise ValueError(
                    f"tiling of {d}: prod {prod} != size "
                    f"{self.workload.dim_sizes[d]}")

    # ---- tiles --------------------------------------------------------
    def tile_sizes(self, store: str) -> Dict[str, int]:
        """Per-dimension extent of the tile resident in ``store``."""
        dims = {d: 1 for d in self.workload.dim_order}
        for lvl in INNER_LEVELS_FOR[store]:
            for d in dims:
                dims[d] *= self.factors[lvl].get(d, 1)
        return dims

    def tensor_tile_elems(self, store: str, tensor_name: str) -> int:
        t = self.workload.tensor(tensor_name)
        tiles = self.tile_sizes(store)
        n = 1
        for d in t.dims:
            n *= tiles[d]
        return n

    def spatial_fanout(self, level: int) -> int:
        assert level in SPATIAL_LEVELS
        n = 1
        for d in self.workload.dim_order:
            n *= self.factors[level].get(d, 1)
        return n

    # ---- flattened nest ----------------------------------------------
    def loops(self) -> List[Tuple[int, str, int, bool]]:
        """Flattened loop list, outer->inner:
        (level_idx, dim_name, bound, is_spatial)."""
        out = []
        for lvl in range(N_LEVELS):
            for d in self.perms[lvl]:
                out.append((lvl, d, self.factors[lvl].get(d, 1),
                            lvl in SPATIAL_LEVELS))
        return out

    def fills(self, store: str, tensor_name: str) -> float:
        """Number of element-fills of tensor ``tensor_name`` into ``store``
        across the whole computation (dense; sparsity scaling is applied by
        the cost model).  See module docstring for the reuse rule."""
        t = self.workload.tensor(tensor_name)
        relevant_dims = set(t.dims)
        outer = [l for l in self.loops() if l[0] in OUTER_LEVELS_FOR[store]]
        # drop transparent loops
        outer = [l for l in outer if l[2] > 1]
        # innermost contiguous run of irrelevant loops -> temporal reuse
        suffix = 0
        for lvl, d, bound, is_spatial in reversed(outer):
            if d in relevant_dims:
                break
            suffix += 1
        body = outer[: len(outer) - suffix] if suffix else outer
        mult = 1.0
        for lvl, d, bound, is_spatial in body:
            if d in relevant_dims:
                mult *= bound
            elif not is_spatial:
                mult *= bound          # temporal thrash: refetch
            # irrelevant spatial loop: multicast, no extra upstream traffic
        return self.tensor_tile_elems(store, tensor_name) * mult

    def temporal_iterations(self) -> int:
        """Total compute cycles for the dense workload = product of all
        temporal loop bounds (each cycle issues the full spatial fanout)."""
        n = 1
        for lvl in TEMPORAL_LEVELS:
            for d in self.workload.dim_order:
                n *= self.factors[lvl].get(d, 1)
        return n

    # ---- pretty print --------------------------------------------------
    def describe(self) -> str:
        rows = []
        for lvl in range(N_LEVELS):
            parts = []
            for d in self.perms[lvl]:
                b = self.factors[lvl].get(d, 1)
                kw = "par-for" if lvl in SPATIAL_LEVELS else "for"
                parts.append(f"{kw} {d.lower()}{lvl+1} in [0,{b})")
            rows.append(f"{LEVEL_NAMES[lvl]:5s}: " + " ".join(parts))
        return "\n".join(rows)


def balanced_mapping(workload: Workload, n_pe: int, macs_per_pe: int
                     ) -> Mapping:
    """A sane hand-built output-stationary mapping, used as the SAGE-like
    fixed mapping and as a fallback individual.

    Greedily fills L3_S up to ``macs_per_pe`` with K-factors, L2_S up to
    ``n_pe`` with M/N-factors, splits the rest between L2_T and L1_T.
    """
    factors: List[Dict[str, int]] = [dict() for _ in range(N_LEVELS)]
    remaining = dict(workload.dim_sizes)

    def take(level: int, dim: str, f: int):
        factors[level][dim] = factors[level].get(dim, 1) * f
        remaining[dim] //= f

    contraction = [d for d in workload.dim_order
                   if d not in workload.output.dims]
    outs = [d for d in workload.dim_order if d in workload.output.dims]

    # L3_S: contraction-dim parallelism across MACs (cap: leave some K
    # temporal so per-PE tiles exist)
    budget = min(macs_per_pe, 16)
    for d in contraction:
        for p in _prime_iter(remaining[d]):
            if p <= budget:
                take(4, d, p)
                budget //= p
            if budget <= 1:
                break
    # L2_S: output-dim parallelism across PEs, capped at 16 per dim so the
    # mapping keeps temporal sub-dimensions (realistic Eyeriss-class PE use)
    budget = n_pe
    for d in outs:
        per_dim = 1
        for p in _prime_iter(remaining[d]):
            if p <= budget and per_dim * p <= 16:
                take(2, d, p)
                budget //= p
                per_dim *= p
            if budget <= 1:
                break
    # L3_T: keep a modest PE-local tile
    for d in workload.dim_order:
        for p in _prime_iter(remaining[d]):
            if factors[3].get(d, 1) * p <= 8:
                take(3, d, p)
    # L2_T: grow GLB tile up to 64 per dim
    for d in workload.dim_order:
        for p in _prime_iter(remaining[d]):
            if factors[1].get(d, 1) * p <= 64:
                take(1, d, p)
    # L1_T: everything left
    for d in workload.dim_order:
        if remaining[d] > 1:
            take(0, d, remaining[d])

    # output-stationary order: contraction dims innermost at L1/L2
    def os_perm():
        return tuple(outs + contraction)

    perms = tuple(os_perm() for _ in range(N_LEVELS))
    return Mapping(workload=workload, factors=tuple(factors), perms=perms)


def _prime_iter(n: int):
    from .workload import prime_factorize
    return list(prime_factorize(n))
