"""Mapping scheme (SparseMap §II.B, §III.A.1, Fig. 4), parameterized by an
:class:`repro.core.arch.ArchSpec`.

For the default paper topology (``ARCH_SPARSEMAP``: DRAM -> GLB -> PE
array -> MACs) a mapping has five mapping levels, outer to inner:

    idx  name   kind      hardware meaning
    0    L1_T   temporal  DRAM -> GLB tile schedule
    1    L2_T   temporal  GLB -> PE-array tile schedule
    2    L2_S   spatial   parallelism across PEs
    3    L3_T   temporal  PE-buffer -> MAC schedule
    4    L3_S   spatial   parallelism across MACs inside a PE

but the level structure is *derived from the arch*: each store below the
backing store owns a temporal level, plus a spatial level when it is
replicated (``StorageLevel.fanout > 1``).  Each level carries one loop per
iteration dimension; its bound is the tiling factor of that dimension at
that level (``prod_l factor[l][d] == size(d)``), and a permutation orders
the loops within the level (outermost first).

``Mapping.fills`` implements the classical Timeloop-style reuse analysis
used by the cost model: the number of fills of a tensor tile into a
storage level is

    fills = footprint * prod(bounds of loops in the outer nest)
                      / prod(bounds of the innermost contiguous run of
                             loops irrelevant to the tensor)
    (bound-1 loops are transparent; irrelevant *spatial* loops multicast
     and never multiply traffic — unless the edge's NoC descriptor
     (``StorageLevel.noc``) turns the discount off: with
     ``multicast=False`` every spatial instance's read copy crosses the
     edge, and with ``reduction=False`` every instance's partial output
     sums cross, so irrelevant spatial loops then multiply traffic by
     their bound wherever they sit in the nest.  Fractional schemes —
     ``multicast="row"``, ``reduction="cluster"``, ... with a numeric
     ``*_fanout`` — sit in between: the S spatial instances group into
     domains of ``fanout``, and ``max(S / fanout, 1)`` copies cross.)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from .arch import ARCH_SPARSEMAP, ArchSpec
from .workload import Workload

# Legacy module constants: the default (paper) topology's structure.
# Prefer reading these off an ArchSpec; they are kept for callers that
# only ever deal with the default arch.
LEVEL_NAMES = ARCH_SPARSEMAP.level_names
N_LEVELS = ARCH_SPARSEMAP.n_levels
SPATIAL_LEVELS = ARCH_SPARSEMAP.spatial_levels
TEMPORAL_LEVELS = ARCH_SPARSEMAP.temporal_levels
OUTER_LEVELS_FOR = dict(ARCH_SPARSEMAP.outer_levels_for)
INNER_LEVELS_FOR = dict(ARCH_SPARSEMAP.inner_levels_for)


@dataclasses.dataclass(frozen=True)
class Mapping:
    """Fully decoded mapping for a given workload on a given arch."""

    workload: Workload
    # factors[level][dim_name] -> tiling factor (int >= 1)
    factors: Tuple[Dict[str, int], ...]
    # perms[level] -> tuple of dim names, outermost first
    perms: Tuple[Tuple[str, ...], ...]
    arch: ArchSpec = ARCH_SPARSEMAP

    def __post_init__(self):
        if len(self.factors) != self.arch.n_levels:
            raise ValueError(
                f"{len(self.factors)} factor levels != arch "
                f"{self.arch.name}'s {self.arch.n_levels}")
        for d in self.workload.dim_order:
            prod = 1
            for lvl in range(self.arch.n_levels):
                prod *= self.factors[lvl].get(d, 1)
            if prod != self.workload.dim_sizes[d]:
                raise ValueError(
                    f"tiling of {d}: prod {prod} != size "
                    f"{self.workload.dim_sizes[d]}")

    # ---- tiles --------------------------------------------------------
    def tile_sizes(self, store: str) -> Dict[str, int]:
        """Per-dimension extent of the tile resident in ``store``."""
        dims = {d: 1 for d in self.workload.dim_order}
        for lvl in self.arch.inner_levels_for[store]:
            for d in dims:
                dims[d] *= self.factors[lvl].get(d, 1)
        return dims

    def tensor_tile_elems(self, store: str, tensor_name: str) -> int:
        t = self.workload.tensor(tensor_name)
        tiles = self.tile_sizes(store)
        n = 1
        for d in t.dims:
            n *= tiles[d]
        return n

    def spatial_fanout(self, level: int) -> int:
        assert level in self.arch.spatial_levels
        n = 1
        for d in self.workload.dim_order:
            n *= self.factors[level].get(d, 1)
        return n

    # ---- flattened nest ----------------------------------------------
    def loops(self) -> List[Tuple[int, str, int, bool]]:
        """Flattened loop list, outer->inner:
        (level_idx, dim_name, bound, is_spatial)."""
        out = []
        for lvl in range(self.arch.n_levels):
            for d in self.perms[lvl]:
                out.append((lvl, d, self.factors[lvl].get(d, 1),
                            self.arch.is_spatial[lvl]))
        return out

    def fills(self, store: str, tensor_name: str) -> float:
        """Number of element-fills of tensor ``tensor_name`` into ``store``
        across the whole computation (dense; sparsity scaling is applied by
        the cost model).  See module docstring for the reuse rule."""
        t = self.workload.tensor(tensor_name)
        relevant_dims = set(t.dims)
        outer_set = self.arch.outer_levels_for[store]
        outer = [l for l in self.loops() if l[0] in outer_set]
        # drop transparent loops
        outer = [l for l in outer if l[2] > 1]
        # NoC of the edge INTO this store: does an irrelevant spatial
        # loop's traffic collapse to one copy (reads: multicast; output:
        # in-network reduction of partials), cross per instance, or —
        # fractional schemes — cross once per multicast/reduction domain
        # of ``fanout`` instances?
        noc = self.arch.edge_noc[self.arch.store_index[store] - 1]
        scheme = (noc.reduction_scheme if t.is_output
                  else noc.multicast_scheme)
        discount = scheme != "none"
        # innermost contiguous run of irrelevant loops -> temporal reuse
        suffix = 0
        for lvl, d, bound, is_spatial in reversed(outer):
            if d in relevant_dims:
                break
            suffix += 1
        body = outer[: len(outer) - suffix] if suffix else outer
        mult = 1.0
        for lvl, d, bound, is_spatial in body:
            if d in relevant_dims:
                mult *= bound
            elif not is_spatial:
                mult *= bound          # temporal thrash: refetch
            elif not discount:
                mult *= bound          # unicast NoC: one copy per instance
            # irrelevant spatial loop: multicast, no extra upstream traffic
        if not discount:
            # replication is physical, not temporal reuse: irrelevant
            # spatial loops multiply traffic even inside the reuse suffix
            for lvl, d, bound, is_spatial in outer[len(outer) - suffix:]:
                if is_spatial:
                    mult *= bound
        elif scheme == "frac":
            # fractional scheme ("row"/"col"/"cluster"): the S spatial
            # instances needing the tile group into multicast/reduction
            # domains of size ``fanout``, so max(S / fanout, 1) copies
            # cross the edge — applied once over ALL irrelevant spatial
            # loops (suffix included: replication is physical), with
            # "all" the fanout->inf limit and "none" fanout=1
            fan = (noc.reduction_fanout if t.is_output
                   else noc.multicast_fanout)
            s_irrel = 1.0
            for lvl, d, bound, is_spatial in outer:
                if is_spatial and d not in relevant_dims:
                    s_irrel *= bound
            mult *= max(s_irrel / fan, 1.0)
        return self.tensor_tile_elems(store, tensor_name) * mult

    def temporal_iterations(self) -> int:
        """Total compute cycles for the dense workload = product of all
        temporal loop bounds (each cycle issues the full spatial fanout)."""
        n = 1
        for lvl in self.arch.temporal_levels:
            for d in self.workload.dim_order:
                n *= self.factors[lvl].get(d, 1)
        return n

    # ---- pretty print --------------------------------------------------
    def describe(self) -> str:
        rows = []
        for lvl in range(self.arch.n_levels):
            parts = []
            for d in self.perms[lvl]:
                b = self.factors[lvl].get(d, 1)
                kw = "par-for" if self.arch.is_spatial[lvl] else "for"
                parts.append(f"{kw} {d.lower()}{lvl+1} in [0,{b})")
            rows.append(f"{self.arch.level_names[lvl]:5s}: "
                        + " ".join(parts))
        return "\n".join(rows)


def balanced_mapping_for_arch(workload: Workload, arch: ArchSpec,
                              spatial_caps: Optional[Sequence[int]] = None
                              ) -> Mapping:
    """A sane hand-built output-stationary mapping on ``arch``, used as
    the SAGE-like fixed mapping and as a fallback individual.

    Greedy placement, generalizing the paper-topology heuristic exactly:
    the innermost spatial level takes contraction-dim parallelism (capped
    at 16; dot-product style, only when the arch has >= 2 spatial levels),
    every other spatial level takes output-dim parallelism (<= 16 per
    dim), then temporal levels inner-to-outer keep small local tiles
    (8 per dim), medium staging tiles (64 per dim), and the outermost
    temporal level absorbs the rest.  ``spatial_caps`` overrides the
    arch's declared per-spatial-level fanouts (level order).

    Every placement is additionally *capacity-aware*: a prime is only
    taken at a level if the resulting uncompressed tile still fits every
    capacity-checked store holding that level in its inner nest (at the
    store's word width); rejected primes flow outward, ultimately to the
    outermost temporal level, which no capacity-checked store holds — so
    the fallback mapping is ``evaluate``-valid on deep or small-buffer
    hierarchies where the fixed per-dim caps alone would overflow.
    """
    nl = arch.n_levels
    factors: List[Dict[str, int]] = [dict() for _ in range(nl)]
    remaining = dict(workload.dim_sizes)

    def take(level: int, dim: str, f: int):
        factors[level][dim] = factors[level].get(dim, 1) * f
        remaining[dim] //= f

    # capacity guard: (inner level set, capacity, word width) per
    # capacity-checked store of the arch
    cap_stores = [(set(arch.inner_levels_for[sname]), cap,
                   arch.store_word_bytes[k])
                  for k, sname, cap in arch.capacity_stores]

    def fits(level: int, dim: str, f: int) -> bool:
        """Would factor ``f`` of ``dim`` at ``level`` keep every
        capacity-checked store's uncompressed occupancy within budget?"""
        for inner, cap, wb in cap_stores:
            if level not in inner:
                continue
            occ = 0.0
            for t in workload.tensors:
                n = 1
                for d in t.dims:
                    for l in inner:
                        n *= factors[l].get(d, 1)
                if dim in t.dims:
                    n *= f
                occ += n * wb
            if occ > cap:
                return False
        return True

    contraction = [d for d in workload.dim_order
                   if d not in workload.output.dims]
    outs = [d for d in workload.dim_order if d in workload.output.dims]

    caps = list(spatial_caps if spatial_caps is not None
                else arch.spatial_caps())
    spatial = list(arch.spatial_levels)
    assert len(caps) == len(spatial)

    # innermost spatial level: contraction-dim parallelism (cap: leave
    # some contraction temporal so per-instance tiles exist)
    inner_spatial: List[int] = []
    if len(spatial) >= 2:
        lvl = spatial[-1]
        inner_spatial = [lvl]
        budget = min(caps[-1], 16)
        for d in contraction:
            for p in _prime_iter(remaining[d]):
                if p <= budget and fits(lvl, d, p):
                    take(lvl, d, p)
                    budget //= p
                if budget <= 1:
                    break
    # remaining spatial levels, innermost first: output-dim parallelism,
    # capped at 16 per dim so the mapping keeps temporal sub-dimensions
    for lvl, cap in reversed(list(zip(spatial, caps))):
        if lvl in inner_spatial:
            continue
        budget = cap
        for d in outs:
            per_dim = 1
            for p in _prime_iter(remaining[d]):
                if p <= budget and per_dim * p <= 16 and fits(lvl, d, p):
                    take(lvl, d, p)
                    budget //= p
                    per_dim *= p
                if budget <= 1:
                    break
    # temporal levels, inner to outer: modest local tile (8/dim), then
    # staging tiles (64/dim); the outermost absorbs whatever is left
    temporal = list(arch.temporal_levels)
    for pos, lvl in enumerate(reversed(temporal[1:])):
        cap = 8 if pos == 0 else 64
        for d in workload.dim_order:
            for p in _prime_iter(remaining[d]):
                if factors[lvl].get(d, 1) * p <= cap and fits(lvl, d, p):
                    take(lvl, d, p)
    top = temporal[0]
    for d in workload.dim_order:
        if remaining[d] > 1:
            take(top, d, remaining[d])

    # output-stationary order: contraction dims innermost at every level
    perms = tuple(tuple(outs + contraction) for _ in range(nl))
    return Mapping(workload=workload, factors=tuple(factors), perms=perms,
                   arch=arch)


def balanced_mapping(workload: Workload, n_pe: int, macs_per_pe: int
                     ) -> Mapping:
    """Paper-topology convenience wrapper around
    :func:`balanced_mapping_for_arch` (DRAM/GLB/PEs/MACs; ``n_pe`` PEs,
    ``macs_per_pe`` MACs per PE)."""
    return balanced_mapping_for_arch(workload, ARCH_SPARSEMAP,
                                     spatial_caps=(n_pe, macs_per_pe))


def _prime_iter(n: int):
    from .workload import prime_factorize
    return list(prime_factorize(n))
