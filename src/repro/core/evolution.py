"""SparseMap's evolution strategy (§IV.D, §IV.E, §IV.H, Fig. 16).

Components:
* **High-Sensitivity Hypercube Initialization (HSHI)** — the design space is
  partitioned into ~pop_size hypercubes along the high-sensitivity genes; a
  small random-search budget per cube finds one valid individual, with
  low-sensitivity genes seeded from the valid combinations collected during
  sensitivity calibration.
* **Annealing mutation** — Eq. (6)/(7): P_h(g) = 0.8*exp(-phi)*(1-phi),
  phi = g/G, shifting mutation mass from high- to low-sensitivity segments.
* **Sensitivity-aware crossover** — single-point crossover whose cut points
  are restricted to the natural boundaries of high-sensitivity segments, so
  high-sensitivity gene runs are never fragmented.
* **Evaluation & selection** — population fitness from the batch cost model
  (invalid individuals have fitness 0); elitist truncation selection.

`evolve` also implements the ablation variants of Fig. 18: standard ES with
LHS init, uniform crossover/mutation (``use_hshi=False, use_custom_ops=False``).

Every operator is array-at-once: mutation draws its gene indices and
replacement values as (pop, genes_per) matrices, crossover assembles all
children with one ``np.where`` over an index grid, HSHI samples one
(n_cubes, L) candidate matrix per round, and best-so-far tracking uses
``np.minimum.accumulate``.  The engine itself is a *generator*
(:func:`evolve_requests`): it yields genome batches and receives evaluation
dicts, so a driver — :func:`evolve` for a single search, or
``repro.core.search.MultiSearch`` for a fleet — decides when and on which
evaluator each batch runs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Generator, List, Optional

import numpy as np

from . import es_ops
from .encoding import GenomeSpec
from .es_ops import DeviceSegment
from .sensitivity import SensitivityResult, build_probes, score_probes


@dataclasses.dataclass
class ESConfig:
    pop_size: int = 100
    budget: int = 20_000            # total cost-model evaluations
    parent_frac: float = 0.4
    elite_frac: float = 0.1
    p_mutation: float = 0.9
    genes_per_mutation: int = 2
    # ablation switches (Fig. 18)
    use_hshi: bool = True
    use_custom_ops: bool = True     # annealing mutation + SA crossover
    # HSHI parameters (§IV.D: ~100 cubes, budget 20 random tries each)
    n_cubes: Optional[int] = None   # default: pop_size
    cube_budget: int = 20
    # sensitivity calibration
    calib_contexts: int = 6
    calib_samples: int = 12
    # beyond-paper: restart on stagnation
    stagnation_restart: int = 0     # 0 = off; else #gens with no improvement
    seed: int = 0
    # device-resident rounds (COMPAT.md "Device-resident round protocol"):
    # with device_rounds=k>1 the main loop yields DeviceSegment requests
    # covering k generations each instead of per-generation batches; a
    # driver that can't execute segments sends None and the generator
    # replays the identical plan on the host.  rng_backend picks where
    # the per-generation randomness comes from: "numpy" (the legacy
    # Generator stream, so k>1 makes the same operator choices as k=1)
    # or "threefry" (jax.random keyed by (seed, generation) — a
    # different, device-native stream).  stagnation_restart > 0 no
    # longer forces the per-round path: restart segments pre-draw one
    # fresh LHS block per generation and the scan adopts it via a
    # re-init branch on the carried (best-so-far, stagnant-gens) state
    # — a different rng consumption order than the host-adaptive
    # device_rounds=1 restart, by design (fixed shapes need the draws
    # up front), but identical between the device segment and its host
    # replay (test-pinned).
    device_rounds: int = 1
    rng_backend: str = "numpy"


@dataclasses.dataclass
class SearchResult:
    best_edp: float
    best_genome: Optional[np.ndarray]
    history: np.ndarray             # best-so-far EDP after each evaluation
    evals: int
    valid_evals: int
    extras: Dict = dataclasses.field(default_factory=dict)

    @property
    def valid_fraction(self) -> float:
        return self.valid_evals / max(self.evals, 1)


class _Budget:
    """Tracks best-so-far vs evaluation count across batched evals."""

    def __init__(self, budget: int):
        self.budget = budget
        self.evals = 0
        self.valid = 0
        self.best = np.inf
        self.best_genome: Optional[np.ndarray] = None
        self.hist: List[float] = []
        self.last_n = 0                 # rows counted by the last register

    def register(self, genomes: np.ndarray, out: Dict) -> np.ndarray:
        """Record a batch; returns a full-length EDP array: ``inf`` where
        a row was evaluated and invalid, ``NaN`` where the batch was
        truncated by the budget and the row was NOT counted.  The NaN tail
        is deliberate — selection code must not mistake budget truncation
        for "evaluated and invalid" (both compare False and sort last, but
        only NaN rows may be dropped from learning updates).  The number
        of counted rows is also exposed as ``last_n``."""
        n = min(len(genomes), self.budget - self.evals)
        self.last_n = n
        valid = np.asarray(out["valid"])[:n]
        edp = np.asarray(out["edp"], dtype=np.float64)[:n].copy()
        edp[~valid] = np.inf
        if n > 0:
            # best-so-far curve over the batch, continuing self.best
            curve = np.minimum(np.minimum.accumulate(edp), self.best)
            if curve[-1] < self.best:
                i = int(np.argmin(edp))     # first index achieving the min
                self.best = float(edp[i])
                self.best_genome = genomes[i].copy()
            self.hist.extend(curve.tolist())
            self.evals += n
            self.valid += int(valid.sum())
        full = np.full(len(genomes), np.nan)
        full[:n] = edp
        return full

    @property
    def exhausted(self) -> bool:
        return self.evals >= self.budget


# The generator engine yields (B, L) genome batches and is sent back the
# evaluator's output dict for that batch.
Requests = Generator[np.ndarray, Dict, Dict]


def _drive(gen: Requests, batch_eval):
    """Run a request generator to completion against one evaluator and
    return its StopIteration value verbatim.  DeviceSegment requests are
    routed to the evaluator's ``run_segment`` method when it has one
    (``JaxCostModel``); evaluators without one are sent ``None`` and the
    generator replays the segment on the host — same trajectory either
    way (all randomness rides in the segment's plan)."""
    try:
        req = next(gen)
        while True:
            if isinstance(req, DeviceSegment):
                runner = getattr(batch_eval, "run_segment", None)
                out = runner(req) if runner is not None else None
            else:
                out = batch_eval(req)
            req = gen.send(out)
    except StopIteration as stop:
        return stop.value


# ---------------------------------------------------------------- HSHI


def _hshi_requests(spec: GenomeSpec, sens: SensitivityResult,
                   rng: np.random.Generator, pop_size: int,
                   n_cubes: Optional[int], cube_budget: int,
                   tracker: _Budget) -> Requests:
    """High-sensitivity hypercube initialization (Fig. 11), vectorized:
    each round draws ONE (n_cubes, L) candidate matrix — low-sensitivity
    genes seeded from the calibration valid pool with a single masked
    gather, cube constraints applied as per-cube [low, high) windows on
    the high-sensitivity columns."""
    L = spec.length
    ub = spec.gene_ub
    n_cubes = n_cubes or pop_size
    hi = sens.high_indices
    H = len(hi)

    # per-gene bin counts whose product ~ n_cubes
    bins = np.ones(L, dtype=np.int64)
    if H > 0:
        per = max(1, int(round(n_cubes ** (1.0 / H))))
        bins[hi] = np.minimum(per, ub[hi])

    n_list = max(n_cubes, pop_size)
    # mixed-radix cube coordinates for every cube: (n_list, H)
    total = int(np.prod(bins[hi])) if H else 1
    cc = np.arange(n_list, dtype=np.int64) % max(total, 1)
    coords = np.empty((n_list, H), dtype=np.int64)
    for j, g in enumerate(hi):
        coords[:, j] = cc % bins[g]
        cc //= bins[g]
    if H:
        lowv = (ub[hi][None, :] * coords) // bins[hi][None, :]
        highv = np.maximum(
            lowv + 1, (ub[hi][None, :] * (coords + 1)) // bins[hi][None, :])

    low_mask = np.zeros(L, dtype=bool)
    low_mask[sens.low_indices] = True
    pool = sens.valid_pool

    found = np.zeros((n_list, L), dtype=np.int64)
    found_edp = np.full(n_list, np.inf)
    has_found = np.zeros(n_list, dtype=bool)
    fallback: Optional[np.ndarray] = None

    for _ in range(cube_budget):
        if has_found.all() or tracker.exhausted:
            break
        g = spec.random_genomes(rng, n_list)
        # low-sensitivity genes: seed from the calibration valid pool
        if len(pool) > 0:
            take = rng.random(n_list) < 0.5
            rows = rng.integers(0, len(pool), n_list)
            g = np.where(take[:, None] & low_mask[None, :],
                         pool[rows], g)
        if H:
            g[:, hi] = lowv + (rng.random((n_list, H)) *
                               (highv - lowv)).astype(np.int64)
        cands = spec.clip(g)
        out = yield cands
        edp = tracker.register(cands, out)[:n_list]
        fallback = cands
        better = np.isfinite(edp) & (edp < found_edp)
        found_edp = np.where(better, edp, found_edp)
        found = np.where(better[:, None], cands, found)
        has_found |= better

    pop = np.where(has_found[:, None], found,
                   fallback if fallback is not None
                   else spec.random_genomes(rng, n_list))
    if len(pop) < pop_size:     # unreachable (n_list >= pop_size); safety
        pop = np.concatenate(
            [pop, spec.random_genomes(rng, pop_size - len(pop))], axis=0)
    return pop[:pop_size]


def hshi_init(spec: GenomeSpec, batch_eval, sens: SensitivityResult,
              rng: np.random.Generator, pop_size: int,
              n_cubes: Optional[int], cube_budget: int,
              tracker: _Budget) -> np.ndarray:
    """Drive :func:`_hshi_requests` against a single evaluator."""
    return _drive(_hshi_requests(spec, sens, rng, pop_size, n_cubes,
                                 cube_budget, tracker), batch_eval)


def lhs_init(spec: GenomeSpec, rng: np.random.Generator,
             pop_size: int) -> np.ndarray:
    """Latin hypercube sampling over all genes (standard-ES baseline).
    One permuted strata matrix; every column is an independent shuffle."""
    L = spec.length
    strata = np.broadcast_to(
        np.arange(pop_size, dtype=np.float64)[:, None],
        (pop_size, L)).copy()
    strata = rng.permuted(strata, axis=0)
    strata = (strata + rng.random((pop_size, L))) / pop_size
    g = (strata * spec.gene_ub[None, :].astype(np.float64)
         ).astype(np.int64)
    return spec.clip(g)


# ---------------------------------------------------------------- operators


def annealing_p_high(gen: int, total_gens: int) -> float:
    """Eq. (6): P_h(g) = 0.8 * exp(-phi) * (1 - phi), phi = g/G."""
    phi = gen / max(total_gens, 1)
    return 0.8 * math.exp(-phi) * (1.0 - phi)


def mutate(genomes: np.ndarray, spec: GenomeSpec, rng: np.random.Generator,
           p_mut: float, genes_per: int,
           sens: Optional[SensitivityResult], p_high: float) -> np.ndarray:
    """Annealing mutation (sens given) or uniform mutation (sens=None).

    Fully batched: gene indices are drawn as an (n, genes_per) matrix —
    one shared uniform draw mapped into the high- or low-sensitivity
    segment per row — and the replacement values come from a single
    element-wise ``rng.integers(0, ub[gene])`` call.  Duplicate draws
    within a row overwrite in draw order, exactly like the sequential
    formulation."""
    n = len(genomes)
    if n == 0 or genes_per <= 0:
        return genomes.copy()
    hi, lo = es_ops.mutation_index_tables(spec.length, sens)
    active, gene, vals = es_ops.plan_mutation(
        rng, n, spec.gene_ub, genes_per, p_mut, p_high, hi, lo)
    return es_ops.apply_mutation(genomes, active, gene, vals)


def crossover(parents: np.ndarray, n_children: int, spec: GenomeSpec,
              rng: np.random.Generator,
              sens: Optional[SensitivityResult]) -> np.ndarray:
    """Single-point crossover.  With ``sens``: sensitivity-aware — cut
    points restricted to high-sensitivity segment boundaries (plus genome
    ends), never splitting a high-sensitivity run.

    Batched: parent pairs and cut points are drawn as vectors and all
    children are assembled with one ``np.where`` over the gene index
    grid."""
    cut_arr = es_ops.crossover_cut_points(spec.length, sens)
    ab, cuts = es_ops.plan_crossover(rng, n_children, len(parents), cut_arr)
    kids = es_ops.apply_crossover(parents, ab, cuts)
    return np.ascontiguousarray(kids, dtype=parents.dtype)


# ---------------------------------------------------------------- main loop


def calib_plan(length: int, cfg: ESConfig) -> tuple:
    """The (n_contexts, n_samples) the sensitivity calibration actually
    uses after shrinking to keep init+calibration under ~10% of the
    budget.  Shared with the compile-ahead shape predictors: the probe
    batch the generator's FIRST yield carries has exactly
    ``n_ctx * n_smp * length`` rows."""
    calib_target = max(int(0.10 * cfg.budget), 2 * length)
    n_ctx = cfg.calib_contexts
    n_smp = cfg.calib_samples
    while n_ctx * n_smp * length > calib_target and n_ctx > 2:
        n_ctx -= 1
    while n_ctx * n_smp * length > calib_target and n_smp > 4:
        n_smp -= 1
    return n_ctx, n_smp


def evolve_requests(spec: GenomeSpec, cfg: ESConfig, tracker: _Budget,
                    sens: Optional[SensitivityResult] = None,
                    fixed_genes: Optional[Dict[int, int]] = None,
                    seeds: Optional[np.ndarray] = None,
                    resume: Optional[Dict] = None,
                    state_out: Optional[Dict] = None) -> Requests:
    """The ES as a request generator: ``yield``s every genome batch that
    needs evaluating and is ``send``-ed the evaluator's output dict.

    This is the primitive both :func:`evolve` (single search) and
    ``search.MultiSearch`` (many concurrent searches round-robined over
    shared jitted evaluators) are built on.  Returns the extras dict via
    ``StopIteration.value``; all bookkeeping lives in ``tracker``.

    Checkpoint/resume (the sweep server's durability contract): pass a
    dict as ``state_out`` and the generator refreshes
    ``state_out["resume"]`` at the TOP of every main-loop generation —
    *before* that generation's rng draws — so a checkpoint taken while
    the generator is suspended at ``yield kids`` re-draws the in-flight
    generation identically on restore.  Passing such a captured dict
    back as ``resume=`` (with ``resume["tracker"]["hist"]`` filled in —
    the capture records only ``hist_len`` to keep the per-generation
    cost O(pop), see :func:`snapshot_tracker_hist`) skips calibration /
    init entirely and restores rng, population, and tracker bit-exactly:
    the resumed trajectory equals the uninterrupted one at fixed seeds.
    No ``state_out["resume"]`` exists until the first main-loop
    generation (the HSHI/calibration prologue is cheap to replay from
    scratch).  Resume requires ``device_rounds == 1`` — pipelined scan
    segments keep populations device-resident and are not cleanly
    checkpointable at a generation boundary.
    """
    rng = np.random.default_rng(cfg.seed)

    def apply_fixed(g: np.ndarray) -> np.ndarray:
        if fixed_genes:
            for k, v in fixed_genes.items():
                g[..., k] = v
        return g

    if resume is not None:
        if cfg.device_rounds > 1:
            raise ValueError(
                "resume requires device_rounds == 1: scan segments keep "
                "populations device-resident with no generation-boundary "
                "checkpoint (COMPAT.md 'Sweep server protocol')")
        rng.bit_generator.state = resume["rng_state"]
        sens = resume["sens"]
        pop = np.asarray(resume["pop"], dtype=np.int64).copy()
        edp = np.asarray(resume["edp"], dtype=np.float64).copy()
        gen = int(resume["gen"])
        since_improve = int(resume["since_improve"])
        last_best = float(resume["last_best"])
        total_gens = int(resume["total_gens"])
        t = resume["tracker"]
        tracker.evals = int(t["evals"])
        tracker.valid = int(t["valid"])
        tracker.best = float(t["best"])
        tracker.best_genome = None if t.get("best_genome") is None \
            else np.asarray(t["best_genome"]).copy()
        tracker.hist = list(t["hist"])
    else:
        # -- sensitivity calibration (needed by HSHI + custom operators)
        # The paper keeps init+calibration under ~10% of total search
        # time; we shrink the per-gene sampling to respect that at small
        # CI budgets.
        if (cfg.use_hshi or cfg.use_custom_ops) and sens is None:
            n_ctx, n_smp = calib_plan(spec.length, cfg)
            probes, gene_idx, sampled_vals = build_probes(
                spec, rng, n_contexts=n_ctx, n_samples=n_smp)
            out = yield probes
            sens = score_probes(spec, probes, gene_idx, sampled_vals,
                                out, rng, n_contexts=n_ctx, n_samples=n_smp)
            tracker.evals += sens.evals_used        # calibration counts
            tracker.hist.extend([tracker.best] * sens.evals_used)

        # ---- initialization ----
        if cfg.use_hshi and sens is not None:
            n_cubes = cfg.n_cubes or cfg.pop_size
            cube_budget = min(
                cfg.cube_budget,
                max(2, int(0.15 * cfg.budget) // max(n_cubes, 1)))
            pop = yield from _hshi_requests(spec, sens, rng, cfg.pop_size,
                                            n_cubes, cube_budget, tracker)
        else:
            pop = lhs_init(spec, rng, cfg.pop_size)
        if seeds is not None and len(seeds):
            pop[: len(seeds)] = seeds[: len(pop)]
        pop = apply_fixed(pop)
        out = yield pop
        edp = tracker.register(pop, out)
        gen = 0
        since_improve = 0
        last_best = tracker.best
        total_gens = max(1, (cfg.budget - tracker.evals) // cfg.pop_size)

    op_sens = sens if cfg.use_custom_ops else None
    n_parents = max(2, int(cfg.pop_size * cfg.parent_frac))
    n_elite = max(1, int(cfg.pop_size * cfg.elite_frac))

    if cfg.device_rounds > 1:
        if cfg.stagnation_restart:
            extras = yield from _restart_segment_requests(
                spec, cfg, tracker, rng, op_sens, fixed_genes, pop, edp,
                n_parents, n_elite, total_gens)
        else:
            extras = yield from _segment_requests(
                spec, cfg, tracker, rng, op_sens, fixed_genes, pop, edp,
                n_parents, n_elite, total_gens)
        extras["sensitivity"] = None if sens is None else sens.scores
        return extras

    while not tracker.exhausted:
        if state_out is not None:
            # pre-draw capture: restoring this state replays the
            # CURRENT generation's draws identically (the suspended
            # ``yield kids`` batch is re-derived, never stored)
            state_out["resume"] = dict(
                rng_state=rng.bit_generator.state,
                pop=pop.copy(), edp=edp.copy(), gen=gen,
                since_improve=since_improve, last_best=last_best,
                total_gens=total_gens, sens=sens,
                tracker=dict(
                    evals=tracker.evals, valid=tracker.valid,
                    best=tracker.best,
                    best_genome=None if tracker.best_genome is None
                    else tracker.best_genome.copy(),
                    hist_len=len(tracker.hist)))
        order = np.argsort(edp)
        parents = pop[order[:n_parents]]
        elites = pop[order[:n_elite]].copy()
        elite_edp = edp[order[:n_elite]].copy()

        p_high = annealing_p_high(gen, total_gens)
        kids = crossover(parents, cfg.pop_size - n_elite, spec, rng, op_sens)
        kids = mutate(kids, spec, rng, cfg.p_mutation,
                      cfg.genes_per_mutation, op_sens, p_high)
        kids = apply_fixed(spec.clip(kids))
        kout = yield kids
        kedp = tracker.register(kids, kout)

        pop = np.concatenate([elites, kids], axis=0)
        edp = np.concatenate([elite_edp, kedp])
        gen += 1

        if tracker.best < last_best:
            last_best = tracker.best
            since_improve = 0
        else:
            since_improve += 1
        if cfg.stagnation_restart and since_improve >= cfg.stagnation_restart:
            # beyond-paper: re-seed the non-elite population
            fresh = lhs_init(spec, rng, cfg.pop_size - n_elite)
            fresh = apply_fixed(fresh)
            fout = yield fresh
            fedp = tracker.register(fresh, fout)
            pop = np.concatenate([elites, fresh], axis=0)
            edp = np.concatenate([elite_edp, fedp])
            since_improve = 0

    return dict(generations=gen,
                sensitivity=None if sens is None else sens.scores)


def snapshot_tracker_hist(tracker: _Budget, captured: Dict) -> Dict:
    """Complete a ``state_out["resume"]`` capture into a self-contained
    resume dict.  The per-generation capture records only ``hist_len``
    (copying the full best-so-far history every generation would be
    O(budget) per round); this copies the matching history prefix out of
    the still-live tracker — call it at checkpoint-save time, before the
    process can die."""
    out = dict(captured)
    t = dict(captured["tracker"])
    t["hist"] = list(tracker.hist[: t.pop("hist_len")])
    out["tracker"] = t
    return out


def _segment_requests(spec: GenomeSpec, cfg: ESConfig, tracker: _Budget,
                      rng: np.random.Generator,
                      op_sens: Optional[SensitivityResult],
                      fixed_genes: Optional[Dict[int, int]],
                      pop: np.ndarray, edp: np.ndarray,
                      n_parents: int, n_elite: int,
                      total_gens: int) -> Requests:
    """The device-resident main loop: yields :class:`DeviceSegment`
    requests covering ``cfg.device_rounds`` generations each.  All
    per-generation randomness is planned up front (numpy Generator
    stream, or threefry keyed by (seed, generation)), so a driver that
    executes the segment on-device (``jax_cost.run_segments``) and a
    driver that sends back ``None`` — making this generator replay the
    plan as ordinary per-generation batch requests — produce the same
    operator choices.  Selection uses the shared *stable* fitness order
    (``es_ops.stable_order``) in both paths; the legacy per-round loop's
    unstable ``np.argsort`` can differ on ties, which is one of the two
    test-pinned parity seams (the other: in-scan float32 EDP vs the
    host-recomputed canonical EDP).

    PIPELINED DISPATCH (COMPAT.md "Pipelined dispatch contract"): this
    generator never blocks on the segment it just received.  The
    response for segment N is stashed unresolved; segment N+1 is planned
    from the ``planned`` evaluation counter (which replicates
    ``_Budget.register``'s value-independent truncation arithmetic, so
    budget exhaustion is known without harvesting) and yielded carrying
    ``resp.carry`` — the device-resident padded (pop, edp) — and only
    THEN is segment N resolved and registered.  With an async driver
    (``run_segments(..., defer=True)``) the host's blocking conversion
    of round N overlaps the device executing round N+1; with a
    synchronous driver the very same code runs, merely blocking earlier
    — registration order and values are identical by construction, which
    is the ``pipeline=False`` escape hatch's bit-identity guarantee."""
    cut_arr = es_ops.crossover_cut_points(spec.length, op_sens)
    hi, lo = es_ops.mutation_index_tables(spec.length, op_sens)
    k = cfg.device_rounds
    n_children = cfg.pop_size - n_elite
    edp_sel = np.asarray(edp, dtype=np.float32)
    gen = 0

    def make_plans(g0):
        if cfg.rng_backend == "threefry":
            return [es_ops.threefry_plan_generation(
                cfg.seed, g0 + i, n_children=n_children,
                n_parents=n_parents, cut_arr=cut_arr,
                gene_ub=spec.gene_ub, genes_per=cfg.genes_per_mutation,
                p_mut=cfg.p_mutation,
                p_high=annealing_p_high(g0 + i, total_gens),
                hi=hi, lo=lo) for i in range(k)]
        return [es_ops.plan_generation(
            rng, n_children=n_children, n_parents=n_parents,
            cut_arr=cut_arr, gene_ub=spec.gene_ub,
            genes_per=cfg.genes_per_mutation, p_mut=cfg.p_mutation,
            p_high=annealing_p_high(g0 + i, total_gens),
            hi=hi, lo=lo) for i in range(k)]

    def absorb(resp):
        nonlocal pop, edp_sel, gen
        resp.resolve()
        for kids, kout in resp.gens:
            tracker.register(kids, kout)
            gen += 1
        pop = resp.final_pop
        edp_sel = np.asarray(resp.final_edp, dtype=np.float32)

    planned = tracker.evals
    gen_planned = 0
    pending = None
    carry = None
    while planned < cfg.budget:
        plans = make_plans(gen_planned)
        for _ in range(k):
            planned += min(n_children, cfg.budget - planned)
        gen_planned += k
        resp = yield DeviceSegment(
            spec=spec, pop=pop, edp=edp_sel, rounds=k,
            gen0=gen_planned - k, n_parents=n_parents, n_elite=n_elite,
            genes_per=cfg.genes_per_mutation,
            draws=es_ops.stack_draws(plans), fixed_genes=fixed_genes,
            rng_backend=cfg.rng_backend, carry=carry)
        if resp is None:
            # host replay of the identical plan, one generation per yield
            for d in plans:
                parents, elites, elite_edp = es_ops.select(
                    pop, edp_sel, n_parents, n_elite)
                kids = np.ascontiguousarray(
                    es_ops.apply_crossover(parents, d.ab, d.cuts),
                    dtype=pop.dtype)
                kids = es_ops.apply_mutation(kids, d.active, d.gene,
                                             d.vals)
                kids = spec.clip(kids)
                if fixed_genes:
                    for idx, v in fixed_genes.items():
                        kids[..., idx] = v
                kout = yield kids
                tracker.register(kids, kout)
                kedp = np.where(
                    np.asarray(kout["valid"]),
                    np.asarray(kout["edp"], dtype=np.float32),
                    np.float32(np.inf)).astype(np.float32)
                pop = np.concatenate([elites, kids], axis=0)
                edp_sel = np.concatenate(
                    [np.asarray(elite_edp, np.float32), kedp])
                gen += 1
                if tracker.exhausted:
                    break
            continue
        if pending is not None:
            absorb(pending)
        pending = resp
        carry = resp.carry
    if pending is not None:
        absorb(pending)
    return dict(generations=gen)


def _restart_segment_requests(spec: GenomeSpec, cfg: ESConfig,
                              tracker: _Budget,
                              rng: np.random.Generator,
                              op_sens: Optional[SensitivityResult],
                              fixed_genes: Optional[Dict[int, int]],
                              pop: np.ndarray, edp: np.ndarray,
                              n_parents: int, n_elite: int,
                              total_gens: int) -> Requests:
    """Device-resident rounds WITH stagnation restart: each segment
    additionally pre-draws one fresh LHS block per generation (fixed
    shapes — the scan always evaluates it but only ADOPTS it when the
    carried stagnation counter trips; only adopted blocks are
    registered, so the eval budget is spent exactly like an adaptive
    restart).  The carried (best-so-far f32, stagnant-generations)
    state crosses segments via ``DeviceSegment.state`` /
    ``SegmentResult.state``.

    Because whether a restart fired — and therefore how many evaluations
    were registered — is DATA-dependent, this generator harvests eagerly
    (``resp.resolve()`` on receipt) instead of one round late; a
    pipelined fleet driver still overlaps it with the other tasks'
    deferred segments in the same round."""
    cut_arr = es_ops.crossover_cut_points(spec.length, op_sens)
    hi, lo = es_ops.mutation_index_tables(spec.length, op_sens)
    k = cfg.device_rounds
    R = int(cfg.stagnation_restart)
    n_children = cfg.pop_size - n_elite
    edp_sel = np.asarray(edp, dtype=np.float32)
    best = np.float32(np.min(edp_sel)) if len(edp_sel) else \
        np.float32(np.inf)
    since = 0
    gen = 0

    def apply_fixed(g: np.ndarray) -> np.ndarray:
        if fixed_genes:
            for idx, v in fixed_genes.items():
                g[..., idx] = v
        return g

    while not tracker.exhausted:
        if cfg.rng_backend == "threefry":
            plans = [es_ops.threefry_plan_generation(
                cfg.seed, gen + i, n_children=n_children,
                n_parents=n_parents, cut_arr=cut_arr,
                gene_ub=spec.gene_ub, genes_per=cfg.genes_per_mutation,
                p_mut=cfg.p_mutation,
                p_high=annealing_p_high(gen + i, total_gens),
                hi=hi, lo=lo) for i in range(k)]
        else:
            plans = [es_ops.plan_generation(
                rng, n_children=n_children, n_parents=n_parents,
                cut_arr=cut_arr, gene_ub=spec.gene_ub,
                genes_per=cfg.genes_per_mutation, p_mut=cfg.p_mutation,
                p_high=annealing_p_high(gen + i, total_gens),
                hi=hi, lo=lo) for i in range(k)]
        # fresh re-init blocks, one per generation, drawn AFTER the
        # generation plans (deterministic stream order either backend)
        fresh = np.stack([apply_fixed(lhs_init(spec, rng, n_children))
                          for _ in range(k)])
        draws = es_ops.stack_draws(plans)
        draws["fresh"] = fresh
        resp = yield DeviceSegment(
            spec=spec, pop=pop, edp=edp_sel, rounds=k, gen0=gen,
            n_parents=n_parents, n_elite=n_elite,
            genes_per=cfg.genes_per_mutation, draws=draws,
            fixed_genes=fixed_genes, rng_backend=cfg.rng_backend,
            restart=R, state=(float(best), int(since)))
        if resp is None:
            # host replay mirroring step_restart's f32 state machine
            for i, d in enumerate(plans):
                parents, elites, elite_edp = es_ops.select(
                    pop, edp_sel, n_parents, n_elite)
                kids = np.ascontiguousarray(
                    es_ops.apply_crossover(parents, d.ab, d.cuts),
                    dtype=pop.dtype)
                kids = es_ops.apply_mutation(kids, d.active, d.gene,
                                             d.vals)
                kids = apply_fixed(spec.clip(kids))
                kout = yield kids
                tracker.register(kids, kout)
                kedp = np.where(
                    np.asarray(kout["valid"]),
                    np.asarray(kout["edp"], dtype=np.float32),
                    np.float32(np.inf)).astype(np.float32)
                kbest = np.float32(min(best, kedp.min()))
                since = 0 if kbest < best else since + 1
                best = kbest
                gen += 1
                if since >= R:
                    fr = fresh[i].astype(pop.dtype)
                    fout = yield fr
                    tracker.register(fr, fout)
                    fedp = np.where(
                        np.asarray(fout["valid"]),
                        np.asarray(fout["edp"], dtype=np.float32),
                        np.float32(np.inf)).astype(np.float32)
                    pop = np.concatenate([elites, fr], axis=0)
                    edp_sel = np.concatenate(
                        [np.asarray(elite_edp, np.float32), fedp])
                    best = np.float32(min(best, fedp.min()))
                    since = 0
                else:
                    pop = np.concatenate([elites, kids], axis=0)
                    edp_sel = np.concatenate(
                        [np.asarray(elite_edp, np.float32), kedp])
                if tracker.exhausted:
                    break
        else:
            resp.resolve()      # eager: restart consumption is adaptive
            for i, (kids, kout) in enumerate(resp.gens):
                tracker.register(kids, kout)
                gen += 1
                if kout.get("restarted"):
                    fr = draws["fresh"][i].astype(np.int64)
                    tracker.register(fr, kout["fresh"])
                if tracker.exhausted:
                    break
            pop = resp.final_pop
            edp_sel = np.asarray(resp.final_edp, dtype=np.float32)
            best = np.float32(resp.state[0])
            since = int(resp.state[1])
    return dict(generations=gen)


def evolve(spec: GenomeSpec, batch_eval, cfg: ESConfig,
           sens: Optional[SensitivityResult] = None,
           fixed_genes: Optional[Dict[int, int]] = None,
           seeds: Optional[np.ndarray] = None) -> SearchResult:
    """Run SparseMap's ES (or an ablation variant) under an eval budget.

    ``fixed_genes`` pins gene indices to values (used by the SAGE-like
    baseline to freeze the mapping segment).  ``seeds`` (n, L) are injected
    into the initial population verbatim.
    """
    tracker = _Budget(cfg.budget)
    extras = _drive(
        evolve_requests(spec, cfg, tracker, sens=sens,
                        fixed_genes=fixed_genes, seeds=seeds),
        batch_eval) or {}
    return SearchResult(
        best_edp=tracker.best, best_genome=tracker.best_genome,
        history=np.asarray(tracker.hist), evals=tracker.evals,
        valid_evals=tracker.valid, extras=extras)
