"""SparseMap's evolution strategy (§IV.D, §IV.E, §IV.H, Fig. 16).

Components:
* **High-Sensitivity Hypercube Initialization (HSHI)** — the design space is
  partitioned into ~pop_size hypercubes along the high-sensitivity genes; a
  small random-search budget per cube finds one valid individual, with
  low-sensitivity genes seeded from the valid combinations collected during
  sensitivity calibration.
* **Annealing mutation** — Eq. (6)/(7): P_h(g) = 0.8*exp(-phi)*(1-phi),
  phi = g/G, shifting mutation mass from high- to low-sensitivity segments.
* **Sensitivity-aware crossover** — single-point crossover whose cut points
  are restricted to the natural boundaries of high-sensitivity segments, so
  high-sensitivity gene runs are never fragmented.
* **Evaluation & selection** — population fitness from the batch cost model
  (invalid individuals have fitness 0); elitist truncation selection.

`evolve` also implements the ablation variants of Fig. 18: standard ES with
LHS init, uniform crossover/mutation (``use_hshi=False, use_custom_ops=False``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .encoding import GenomeSpec
from .sensitivity import SensitivityResult, calibrate


@dataclasses.dataclass
class ESConfig:
    pop_size: int = 100
    budget: int = 20_000            # total cost-model evaluations
    parent_frac: float = 0.4
    elite_frac: float = 0.1
    p_mutation: float = 0.9
    genes_per_mutation: int = 2
    # ablation switches (Fig. 18)
    use_hshi: bool = True
    use_custom_ops: bool = True     # annealing mutation + SA crossover
    # HSHI parameters (§IV.D: ~100 cubes, budget 20 random tries each)
    n_cubes: Optional[int] = None   # default: pop_size
    cube_budget: int = 20
    # sensitivity calibration
    calib_contexts: int = 6
    calib_samples: int = 12
    # beyond-paper: restart on stagnation
    stagnation_restart: int = 0     # 0 = off; else #gens with no improvement
    seed: int = 0


@dataclasses.dataclass
class SearchResult:
    best_edp: float
    best_genome: Optional[np.ndarray]
    history: np.ndarray             # best-so-far EDP after each evaluation
    evals: int
    valid_evals: int
    extras: Dict = dataclasses.field(default_factory=dict)

    @property
    def valid_fraction(self) -> float:
        return self.valid_evals / max(self.evals, 1)


class _Budget:
    """Tracks best-so-far vs evaluation count across batched evals."""

    def __init__(self, budget: int):
        self.budget = budget
        self.evals = 0
        self.valid = 0
        self.best = np.inf
        self.best_genome: Optional[np.ndarray] = None
        self.hist: List[float] = []

    def register(self, genomes: np.ndarray, out: Dict) -> np.ndarray:
        """Record a batch; returns EDP array (inf where invalid).
        Truncates the batch if it would exceed the budget."""
        n = min(len(genomes), self.budget - self.evals)
        valid = np.asarray(out["valid"])[:n]
        edp = np.asarray(out["edp"], dtype=np.float64)[:n].copy()
        edp[~valid] = np.inf
        for i in range(n):
            if edp[i] < self.best:
                self.best = float(edp[i])
                self.best_genome = genomes[i].copy()
            self.hist.append(self.best)
        self.evals += n
        self.valid += int(valid.sum())
        full = np.full(len(genomes), np.inf)
        full[:n] = edp
        return full

    @property
    def exhausted(self) -> bool:
        return self.evals >= self.budget


# ---------------------------------------------------------------- HSHI


def hshi_init(spec: GenomeSpec, batch_eval, sens: SensitivityResult,
              rng: np.random.Generator, pop_size: int,
              n_cubes: Optional[int], cube_budget: int,
              tracker: _Budget) -> np.ndarray:
    """High-sensitivity hypercube initialization (Fig. 11)."""
    L = spec.length
    ub = spec.gene_ub
    n_cubes = n_cubes or pop_size
    hi = sens.high_indices
    H = len(hi)

    # per-gene bin counts whose product ~ n_cubes
    bins = np.ones(L, dtype=np.int64)
    if H > 0:
        per = max(1, int(round(n_cubes ** (1.0 / H))))
        for g in hi:
            bins[g] = min(per, ub[g])

    def sample_in_cube(cube_coords: Dict[int, int], n: int) -> np.ndarray:
        g = spec.random_genomes(rng, n)
        # low-sensitivity genes: seed from the calibration valid pool
        if len(sens.valid_pool) > 0:
            take = rng.random(n) < 0.5
            rows = rng.integers(0, len(sens.valid_pool), n)
            low = sens.low_indices
            for i in range(n):
                if take[i]:
                    g[i, low] = sens.valid_pool[rows[i], low]
        for gene, b in cube_coords.items():
            lowv = (ub[gene] * b) // bins[gene]
            highv = max(lowv + 1, (ub[gene] * (b + 1)) // bins[gene])
            g[:, gene] = lowv + (rng.random(n) *
                                 (highv - lowv)).astype(np.int64)
        return spec.clip(g)

    # enumerate cube coordinates (mixed radix over high-sens genes)
    pop: List[np.ndarray] = []
    cube_list: List[Dict[int, int]] = []
    total = int(np.prod([bins[g] for g in hi])) if H else 1
    for c in range(max(n_cubes, pop_size)):
        coords = {}
        cc = c % max(total, 1)
        for g in hi:
            coords[g] = cc % bins[g]
            cc //= bins[g]
        cube_list.append(coords)

    # batched cube search: each round evaluates one candidate per cube
    # (constant batch size, so jit compiles a single shape)
    found: Dict[int, np.ndarray] = {}
    found_edp: Dict[int, float] = {}
    fallback: Dict[int, np.ndarray] = {}
    for _ in range(cube_budget):
        if len(found) == len(cube_list) or tracker.exhausted:
            break
        cands = np.concatenate(
            [sample_in_cube(c, 1) for c in cube_list], axis=0)
        out = batch_eval(cands)
        edp = tracker.register(cands, out)
        for j in range(len(cube_list)):
            fallback[j] = cands[j]
            if np.isfinite(edp[j]) and edp[j] < found_edp.get(j, np.inf):
                found[j] = cands[j]
                found_edp[j] = float(edp[j])

    for c in range(len(cube_list)):
        pop.append(found.get(c, fallback.get(
            c, spec.random_genomes(rng, 1)[0])))
        if len(pop) >= pop_size:
            break
    while len(pop) < pop_size:
        pop.append(spec.random_genomes(rng, 1)[0])
    return np.stack(pop[:pop_size])


def lhs_init(spec: GenomeSpec, rng: np.random.Generator,
             pop_size: int) -> np.ndarray:
    """Latin hypercube sampling over all genes (standard-ES baseline)."""
    L = spec.length
    g = np.empty((pop_size, L), dtype=np.int64)
    for j in range(L):
        strata = (np.arange(pop_size) + rng.random(pop_size)) / pop_size
        rng.shuffle(strata)
        g[:, j] = (strata * spec.gene_ub[j]).astype(np.int64)
    return spec.clip(g)


# ---------------------------------------------------------------- operators


def annealing_p_high(gen: int, total_gens: int) -> float:
    """Eq. (6): P_h(g) = 0.8 * exp(-phi) * (1 - phi), phi = g/G."""
    phi = gen / max(total_gens, 1)
    return 0.8 * math.exp(-phi) * (1.0 - phi)


def mutate(genomes: np.ndarray, spec: GenomeSpec, rng: np.random.Generator,
           p_mut: float, genes_per: int,
           sens: Optional[SensitivityResult], p_high: float) -> np.ndarray:
    """Annealing mutation (sens given) or uniform mutation (sens=None)."""
    out = genomes.copy()
    L = spec.length
    for i in range(len(out)):
        if rng.random() >= p_mut:
            continue
        if sens is not None:
            seg = sens.high_indices if rng.random() < p_high \
                else sens.low_indices
            if len(seg) == 0:
                seg = np.arange(L)
        else:
            seg = np.arange(L)
        for _ in range(genes_per):
            g = int(seg[rng.integers(0, len(seg))])
            out[i, g] = rng.integers(0, spec.gene_ub[g])
    return out


def crossover(parents: np.ndarray, n_children: int, spec: GenomeSpec,
              rng: np.random.Generator,
              sens: Optional[SensitivityResult]) -> np.ndarray:
    """Single-point crossover.  With ``sens``: sensitivity-aware — cut
    points restricted to high-sensitivity segment boundaries (plus genome
    ends), never splitting a high-sensitivity run."""
    L = spec.length
    if sens is not None:
        pts = {0, L}
        for a, b in sens.high_segments():
            pts.add(a)
            pts.add(b)
        cut_points = sorted(pts - {0, L}) or [L // 2]
    else:
        cut_points = list(range(1, L))
    kids = np.empty((n_children, L), dtype=parents.dtype)
    for i in range(n_children):
        a, b = rng.integers(0, len(parents), 2)
        cut = cut_points[rng.integers(0, len(cut_points))]
        kids[i, :cut] = parents[a, :cut]
        kids[i, cut:] = parents[b, cut:]
    return kids


# ---------------------------------------------------------------- main loop


def evolve(spec: GenomeSpec, batch_eval, cfg: ESConfig,
           sens: Optional[SensitivityResult] = None,
           fixed_genes: Optional[Dict[int, int]] = None,
           seeds: Optional[np.ndarray] = None) -> SearchResult:
    """Run SparseMap's ES (or an ablation variant) under an eval budget.

    ``fixed_genes`` pins gene indices to values (used by the SAGE-like
    baseline to freeze the mapping segment).  ``seeds`` (n, L) are injected
    into the initial population verbatim.
    """
    rng = np.random.default_rng(cfg.seed)
    tracker = _Budget(cfg.budget)

    def apply_fixed(g: np.ndarray) -> np.ndarray:
        if fixed_genes:
            for k, v in fixed_genes.items():
                g[..., k] = v
        return g

    # ---- sensitivity calibration (needed by HSHI + custom operators) ----
    # The paper keeps init+calibration under ~10% of total search time; we
    # shrink the per-gene sampling to respect that at small CI budgets.
    if (cfg.use_hshi or cfg.use_custom_ops) and sens is None:
        calib_target = max(int(0.10 * cfg.budget), 2 * spec.length)
        n_ctx = cfg.calib_contexts
        n_smp = cfg.calib_samples
        while n_ctx * n_smp * spec.length > calib_target and n_ctx > 2:
            n_ctx -= 1
        while n_ctx * n_smp * spec.length > calib_target and n_smp > 4:
            n_smp -= 1
        sens = calibrate(spec, batch_eval, rng,
                         n_contexts=n_ctx, n_samples=n_smp)
        tracker.evals += sens.evals_used        # calibration counts
        tracker.hist.extend([tracker.best] * sens.evals_used)

    # ---- initialization ----
    if cfg.use_hshi and sens is not None:
        n_cubes = cfg.n_cubes or cfg.pop_size
        cube_budget = min(cfg.cube_budget,
                          max(2, int(0.15 * cfg.budget) // max(n_cubes, 1)))
        pop = hshi_init(spec, batch_eval, sens, rng, cfg.pop_size,
                        n_cubes, cube_budget, tracker)
    else:
        pop = lhs_init(spec, rng, cfg.pop_size)
    if seeds is not None and len(seeds):
        pop[: len(seeds)] = seeds[: len(pop)]
    pop = apply_fixed(pop)
    out = batch_eval(pop)
    edp = tracker.register(pop, out)

    op_sens = sens if cfg.use_custom_ops else None
    n_parents = max(2, int(cfg.pop_size * cfg.parent_frac))
    n_elite = max(1, int(cfg.pop_size * cfg.elite_frac))
    total_gens = max(1, (cfg.budget - tracker.evals) // cfg.pop_size)

    gen = 0
    since_improve = 0
    last_best = tracker.best
    while not tracker.exhausted:
        order = np.argsort(edp)
        parents = pop[order[:n_parents]]
        elites = pop[order[:n_elite]].copy()
        elite_edp = edp[order[:n_elite]].copy()

        p_high = annealing_p_high(gen, total_gens)
        kids = crossover(parents, cfg.pop_size - n_elite, spec, rng, op_sens)
        kids = mutate(kids, spec, rng, cfg.p_mutation,
                      cfg.genes_per_mutation, op_sens, p_high)
        kids = apply_fixed(spec.clip(kids))
        kout = batch_eval(kids)
        kedp = tracker.register(kids, kout)

        pop = np.concatenate([elites, kids], axis=0)
        edp = np.concatenate([elite_edp, kedp])
        gen += 1

        if tracker.best < last_best:
            last_best = tracker.best
            since_improve = 0
        else:
            since_improve += 1
        if cfg.stagnation_restart and since_improve >= cfg.stagnation_restart:
            # beyond-paper: re-seed the non-elite population
            fresh = lhs_init(spec, rng, cfg.pop_size - n_elite)
            fresh = apply_fixed(fresh)
            fout = batch_eval(fresh)
            fedp = tracker.register(fresh, fout)
            pop = np.concatenate([elites, fresh], axis=0)
            edp = np.concatenate([elite_edp, fedp])
            since_improve = 0

    return SearchResult(
        best_edp=tracker.best, best_genome=tracker.best_genome,
        history=np.asarray(tracker.hist), evals=tracker.evals,
        valid_evals=tracker.valid,
        extras=dict(generations=gen,
                    sensitivity=None if sens is None else sens.scores))
