"""autoshard — SparseMap's joint-space ES applied to THIS framework's
distributed mapping space (beyond-paper; DESIGN.md §6).

The paper's core insight is that *mapping* and *sparse strategy* must be
co-optimized because each constrains the other.  The distributed-training
analogue: sharding axis assignments (the mapping) and layout/recompute/
microbatching choices (the strategy) interact the same way — e.g. vocab-
sharded logits only pay off if the loss is computed shard-local, FSDP
weights only pay off if the gather overlaps the previous layer.  So we
reuse the SAME evolution engine (`repro.core.evolution.evolve` — HSHI,
annealing mutation, sensitivity-aware crossover) over a decision genome,
with a closed-form TPU-v5e roofline estimator as the evaluation
environment (validated against the compiled dry-run on the hill-climbed
cells — EXPERIMENTS.md §Perf).

Decision genome (one gene per decision):

    0 remat          {none, dots, full}
    1 microbatches   {1, 2, 4, 8}
    2 logits         {vocab-sharded, replicated-gather}
    3 embed shard    {vocab, d_model}
    4 attn chunk     {0, 1024, 2048, 4096}
    5 mlp shard      {megatron (ff on model), fsdp (weights on data)}
    6 zero1          {off, on}
    7 moe expert ff  {ff on data, ff replicated}   (MoE archs only)
    8 seq shard kv   {model, data+model}           (decode only)
    9 moment dtype   {fp32, bf16, int8}  (int8 = quantized Adam moments —
                       the trick that makes trillion-parameter training
                       fit at all; see EXPERIMENTS.md §Perf)
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.core.accel import TPU_V5E

REMAT_OPTS = ("none", "dots", "full")
MICRO_OPTS = (1, 2, 4, 8)
CHUNK_OPTS = (0, 1024, 2048, 4096)

GENE_NAMES = ("remat", "microbatches", "logits", "embed", "attn_chunk",
              "mlp_shard", "zero1", "moe_ff", "kv_seq", "moments")
GENE_UB = (3, 4, 2, 2, 4, 2, 2, 2, 2, 3)
MOMENT_OPTS = ("fp32", "bf16", "int8")
MOMENT_BYTES = {"fp32": 12.0, "bf16": 4.0, "int8": 2.0}


class DecisionSpec:
    """Minimal GenomeSpec-compatible adapter for the decision genome."""

    def __init__(self):
        self.length = len(GENE_UB)
        self.gene_ub = np.asarray(GENE_UB, np.int64)
        self.segments = {}          # no segment structure needed

    def random_genomes(self, rng: np.random.Generator, n: int
                       ) -> np.ndarray:
        return (rng.random((n, self.length)) *
                self.gene_ub[None, :]).astype(np.int64)

    def clip(self, g: np.ndarray) -> np.ndarray:
        return np.clip(g, 0, self.gene_ub[None, :] - 1)


def decode_decisions(genome: np.ndarray) -> Dict[str, object]:
    return dict(
        remat=REMAT_OPTS[int(genome[0])],
        microbatches=MICRO_OPTS[int(genome[1])],
        logits="vocab" if genome[2] == 0 else "gather",
        embed="vocab" if genome[3] == 0 else "dmodel",
        attn_chunk=CHUNK_OPTS[int(genome[4])],
        mlp_shard="megatron" if genome[5] == 0 else "fsdp",
        zero1=bool(genome[6]),
        moe_ff="data" if genome[7] == 0 else "replicated",
        kv_seq="model" if genome[8] == 0 else "data_model",
        moments=MOMENT_OPTS[int(genome[9])],
    )


# ---------------------------------------------------------------- model


@dataclasses.dataclass
class RooflineEstimate:
    t_compute: float
    t_memory: float
    t_collective: float
    hbm_bytes_per_device: float
    valid: bool = True
    reason: str = ""

    @property
    def t_total(self) -> float:
        # compute overlaps memory on TPU; collectives partially overlap
        return max(self.t_compute, self.t_memory) + 0.5 * self.t_collective

    @property
    def bottleneck(self) -> str:
        terms = dict(compute=self.t_compute, memory=self.t_memory,
                     collective=self.t_collective)
        return max(terms, key=terms.get)


def estimate(cfg, seq_len: int, global_batch: int, mesh_shape: Dict[str, int],
             decisions: Dict[str, object], kind: str = "train"
             ) -> RooflineEstimate:
    """Closed-form three-term roofline for one step (per device)."""
    peak = TPU_V5E["peak_bf16_flops"]
    hbm = TPU_V5E["hbm_bw_bytes_per_s"]
    ici = TPU_V5E["ici_link_bw_bytes_per_s"]
    tp = mesh_shape.get("model", 1)
    dp = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    chips = tp * dp
    d = cfg.d_model
    L = cfg.n_layers
    V = cfg.vocab_size
    n_active = cfg.active_param_count()
    n_total = cfg.param_count()
    tokens = global_batch * (seq_len if kind != "decode" else 1)
    wb = 2.0                                   # bf16

    remat_mult = {"none": 1.0, "dots": 1.15, "full": 4.0 / 3.0}[
        decisions["remat"]]
    fwdbwd = 3.0 if kind == "train" else 1.0

    # ---- compute ----
    flops = 2.0 * n_active * tokens * fwdbwd * \
        (remat_mult if kind == "train" else 1.0)
    # attention quadratic term: 4*B*S^2*H*hd per attn layer (fwd),
    # x3 for training; attn_chunk doesn't change flops, only memory
    attn_layers = sum(b.repeat for b in cfg.pattern
                      if "attn" in b.kind or b.kind == "moe") * cfg.n_super
    if kind != "decode":
        flops += 4.0 * attn_layers * global_batch * seq_len * seq_len * \
            cfg.n_heads * cfg.hd * fwdbwd
    t_compute = flops / (chips * peak)

    # ---- memory ----
    micro = decisions["microbatches"]
    act_bytes = tokens * d * wb * L * (4.0 if decisions["remat"] == "none"
                                       else 1.5)
    # MoE experts shard over BOTH axes (E on model, ff on data);
    # dense params shard over the model axis only
    param_shard = chips if cfg.n_experts else tp
    mom_b = MOMENT_BYTES[decisions["moments"]]
    param_traffic = n_total * wb * (2.0 if kind == "train" else 1.0)
    opt_traffic = n_total * mom_b if kind == "train" else 0.0
    logits_traffic = tokens * V * wb / (tp if decisions["logits"] == "vocab"
                                        else 1)
    if kind == "train":
        logits_traffic *= 3.0
    hbm_bytes = (act_bytes / chips + param_traffic / tp / micro * micro +
                 opt_traffic / chips + logits_traffic / dp)
    t_memory = hbm_bytes / hbm

    # ---- collectives ----
    # Megatron TP: 2 all-reduces (fwd) + 2 (bwd) of activations per layer
    act_per_layer = tokens / dp * d * wb
    tp_coll = (4.0 if kind == "train" else 2.0) * L * act_per_layer * \
        2.0 * (tp - 1) / tp
    if decisions["mlp_shard"] == "fsdp":
        # all-gather weights per layer instead of activation reductions
        tp_coll = L * (n_total / max(L, 1)) * wb / dp * 2.0
    dp_coll = (2.0 * n_total * wb / tp / micro) * (min(micro, 2)) \
        if kind == "train" else 0.0        # grad reduce-scatter+AG
    logits_coll = 0.0
    if decisions["logits"] == "gather":
        logits_coll = tokens / dp * V * wb      # gather full logits
    moe_coll = 0.0
    if cfg.n_experts:
        # token dispatch all-to-all, both directions, fwd+bwd
        moe_coll = (4.0 if kind == "train" else 1.0) * \
            sum(b.repeat for b in cfg.pattern if b.kind == "moe") * \
            cfg.n_super / max(L, 1) * L * tokens / chips * d * wb * 2.0
        if decisions["moe_ff"] == "replicated":
            moe_coll *= 1.5                     # extra gather of outputs
    coll_bytes = tp_coll / chips * tp + dp_coll / chips * dp + \
        logits_coll / chips + moe_coll
    t_collective = coll_bytes / ici

    # ---- validity: HBM capacity (16 GB v5e) ----
    opt_shard = chips if decisions["zero1"] else param_shard
    state = n_total * wb / param_shard + n_total * mom_b / opt_shard
    if kind != "train":
        state = n_total * wb / param_shard
    act_resident = act_bytes / chips / micro
    hbm_cap = 16e9
    valid = state + act_resident < hbm_cap
    reason = "" if valid else (
        f"HBM overflow: {(state + act_resident) / 1e9:.1f} GB > 16 GB")

    return RooflineEstimate(t_compute=t_compute, t_memory=t_memory,
                            t_collective=t_collective,
                            hbm_bytes_per_device=state + act_resident,
                            valid=valid, reason=reason)


# ---------------------------------------------------------------- search


def make_batch_eval(cfg, seq_len: int, global_batch: int,
                    mesh_shape: Dict[str, int], kind: str = "train"):
    """Batch evaluator with the SearchResult contract of the core ES."""

    def _eval(genomes: np.ndarray) -> Dict[str, np.ndarray]:
        n = len(genomes)
        valid = np.zeros(n, bool)
        edp = np.full(n, np.inf)
        for i, g in enumerate(genomes):
            dec = decode_decisions(g)
            est = estimate(cfg, seq_len, global_batch, mesh_shape, dec,
                           kind)
            valid[i] = est.valid
            if est.valid:
                edp[i] = est.t_total
        return dict(valid=valid, edp=edp,
                    log10_edp=np.log10(np.maximum(edp, 1e-30)))

    return _eval


def search(cfg, seq_len: int, global_batch: int,
           mesh_shape: Dict[str, int], kind: str = "train",
           budget: int = 2000, seed: int = 0):
    """Run the paper's ES over the decision genome; returns
    (best decisions, RooflineEstimate, SearchResult)."""
    from repro.core.evolution import ESConfig, evolve

    spec = DecisionSpec()
    ev = make_batch_eval(cfg, seq_len, global_batch, mesh_shape, kind)
    res = evolve(spec, ev, ESConfig(budget=budget, seed=seed, pop_size=32,
                                    cube_budget=4))
    if res.best_genome is None:
        return None, None, res
    dec = decode_decisions(res.best_genome)
    est = estimate(cfg, seq_len, global_batch, mesh_shape, dec, kind)
    return dec, est, res


def exhaustive_best(cfg, seq_len, global_batch, mesh_shape, kind="train"):
    """Tiny genome -> exhaustive reference (the space is ~6k points);
    lets tests verify the ES finds the true optimum."""
    best, best_t = None, np.inf
    ranges = [range(u) for u in GENE_UB]
    import itertools
    for combo in itertools.product(*ranges):
        dec = decode_decisions(np.asarray(combo))
        est = estimate(cfg, seq_len, global_batch, mesh_shape, dec, kind)
        if est.valid and est.t_total < best_t:
            best, best_t = dec, est.t_total
    return best, best_t
