"""JAX batch evaluator for the SparseMap cost model, generalized over a
declared :class:`repro.core.arch.ArchSpec`.

A jit-compiled, vmap-vectorized re-implementation of
:mod:`repro.core.cost_model` that evaluates a whole *population* of genomes
in one XLA call.  The numpy implementation is the exact oracle; this one is
float32 and property-tested against it (tests/test_cost_agreement.py).

Compilation strategy: all workload- and platform-specific quantities
(primes, densities, tensor sizes, energy/capacity/fanout constants) are
*traced arguments*, and the prime list is padded to a bucket size — so a
single compilation is shared by every workload with the same
(ndims, bucket, topology) signature and every same-topology platform.
The arch's *structure* (loop-slot count, store tables, S/G site wiring,
NoC multicast/reduction shape, which parameters exist) is baked into the
kernel as closure constants; its *numbers* — including per-edge word
widths when any level departs from the global default — ride in the
traced parameter vector (``ArchSpec.param_vector``).  Per-tensor density
models follow the same split: the *mode* is structural — all-uniform
workloads bake the literal pre-density-model occupancy code
(bit-identical to the goldens) while any structured operand selects the
structured kernel variant — and within the structured variant the family
codes and numeric parameters (N:M's n/m, a band's coverage) are traced
rows, so a family of N:M workloads, or a whole mixed
uniform/banded/N:M fleet, shares ONE compilation.
``JaxCostModel.signature`` is therefore
``(ndims, prime_bucket, topology_fingerprint, density_key)``, and
``eval_stacked``/``MultiSearch`` mega-batching keeps sharing compilations
*within* a (topology, density-mode) pair.

The decode is fully tensorized: tiling factors via masked products over the
prime list, permutations via a (d!, d) lookup table, loop-nest reuse via
reverse cumulative products over the fixed n_levels*d loop-slot axis, and
the fiber-tree byte accounting via a lax.scan over the loop slots.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from functools import lru_cache
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from . import density as density_lib
from .accel import Platform
from .arch import ARCH_SPARSEMAP, ArchSpec, Topology, as_arch
from .encoding import GenomeSpec, all_permutations
from .es_ops import (DeviceSegment, PaddedLayout, SegmentResult,
                     segment_shape_key)
from .sparse import MAX_FMT_GENES
from .workload import WORD_BYTES

# Legacy constants: the default (paper) topology's store tables, kept for
# reference/backcompat.  The kernel derives its own per-topology tables.
GLB, PEBUF, REG = 0, 1, 2
STORE_OUTER = np.stack([
    np.isin(np.arange(ARCH_SPARSEMAP.n_levels),
            ARCH_SPARSEMAP.outer_levels_for[s])
    for s in ("glb", "pebuf", "reg")])
STORE_INNER = np.stack([
    np.isin(np.arange(ARCH_SPARSEMAP.n_levels),
            ARCH_SPARSEMAP.inner_levels_for[s])
    for s in ("glb", "pebuf", "reg")])
IS_SPATIAL_LEVEL = np.asarray(ARCH_SPARSEMAP.is_spatial)

# S/G lookup tables over gene value 0..6
_V = np.arange(7)
SG_LEADER_P = np.isin(_V, [2, 3, 5, 6])
SG_LEADER_Q = np.isin(_V, [1, 3, 4, 6])
SG_FOLLOW_P = np.isin(_V, [1, 3, 4, 6])
SG_FOLLOW_Q = np.isin(_V, [2, 3, 5, 6])
SG_IS_SKIP = _V >= 4
SG_IS_GATE = (_V >= 1) & (_V <= 3)

FMT_U, FMT_B, FMT_RLE, FMT_CP, FMT_UOP = range(5)


def _bucket(n: int, size: int = 16) -> int:
    return ((n + size - 1) // size) * size


# Registry of live jitted evaluators, keyed by compilation signature
# (ndims, padded prime count, topology fingerprint, density key, kind)
# where kind is "bcast" (workload constants broadcast over the batch) or
# "stacked" (per-row constants, the mega-batch kernel) — used to count
# actual XLA compilations (one per distinct traced argument-shape set per
# signature).  The density key is "u" for all-uniform workloads (the
# literal pre-density-model kernel, bit-identical to the goldens) or
# "s:<registered families>" for the structured variant, in which the
# per-tensor family code and its numeric parameters are TRACED — a whole
# family of N:M workloads, or a mixed uniform/banded/N:M fleet, shares
# one compilation.
_JIT_FNS: Dict[Tuple[int, int, str, str, str], object] = {}

# Device dispatches issued through JaxCostModel / eval_stacked since the
# last reset — the per-round dispatch-count benchmark hook.
_DISPATCHES = 0

# One reentrant lock guards every module-level counter and registry
# (_JIT_FNS/_SHARD_FNS/_STACK_CONSTS/_AOT_*): the compile-ahead worker
# mutates them from its background thread while the search thread
# dispatches, so bare ``+= 1`` increments are no longer safe.
_LOCK = threading.RLock()

# Wall-clock seconds the host spent BLOCKED converting device results to
# numpy (np.asarray on a jax Array waits for the computation) since the
# last reset.  The pipelined drivers exist to shrink this number; the
# benchmark suite records it per fleet.
_HOST_BLOCKED_S = 0.0


def _count_dispatch() -> None:
    global _DISPATCHES
    with _LOCK:
        _DISPATCHES += 1


def _time_block(fn: Callable):
    """Run a blocking device->host conversion thunk, charging its wall
    clock to the host-blocked accumulator."""
    global _HOST_BLOCKED_S
    t0 = time.perf_counter()
    out = fn()
    dt = time.perf_counter() - t0
    with _LOCK:
        _HOST_BLOCKED_S += dt
    return out


def host_blocked_s() -> float:
    """Seconds the host spent blocked on device->numpy conversions since
    the last reset."""
    with _LOCK:
        return _HOST_BLOCKED_S


def reset_host_blocked_s() -> None:
    global _HOST_BLOCKED_S
    with _LOCK:
        _HOST_BLOCKED_S = 0.0


def compilation_count() -> int:
    """Total XLA compilations held by the shared evaluator cache: the sum
    of per-signature jit cache sizes (each distinct batch shape traced on
    a signature is one compilation), plus the AOT executables the
    compile-ahead worker built (shapes served from the AOT registry never
    enter a jit cache)."""
    total = 0
    with _LOCK:
        fns = list(_JIT_FNS.values())
        total += len(_AOT_FNS)
    for fn in fns:
        try:
            total += fn._cache_size()
        except Exception:       # private API; degrade to signature count
            total += 1
    return total


def compile_signatures() -> Tuple[Tuple[int, int, str, str], ...]:
    """The (ndims, prime-bucket, topology, density-key) signatures built
    so far."""
    with _LOCK:
        return tuple(sorted({(k[0], k[1], k[2], k[3]) for k in _JIT_FNS}))


def dispatch_count() -> int:
    """Device dispatches issued since the last reset (each batched
    evaluator call — per-task or mega-batch — is one dispatch)."""
    with _LOCK:
        return _DISPATCHES


def reset_dispatch_count() -> None:
    global _DISPATCHES
    with _LOCK:
        _DISPATCHES = 0


# ------------------------------------------------- AOT compile-ahead
#
# ``compile_ahead`` lowers and compiles predicted dispatch shapes on a
# background thread (jit(...).lower(shapes).compile()) while the host
# runs the HSHI/LHS prologue.  A ``.lower().compile()`` does NOT populate
# the jit function's own call cache, so the finished executables live in
# their own registry, keyed (jit-fn key, shape fingerprint), and the
# dispatch paths consult it first.  ``_AOT_PENDING`` holds an Event per
# in-flight background compile so a dispatch that races the worker WAITS
# for the executable instead of duplicate-tracing.

_AOT_FNS: Dict[Tuple, object] = {}
_AOT_PENDING: Dict[Tuple, threading.Event] = {}
_CA_ACTIVE = False              # a compile-ahead pass ran this epoch
_CA_PREFIXES: set = set()       # (sig..., tag) families compile-ahead built
_CA_HITS = 0                    # dispatches served by an AOT executable
_CA_MISSES = 0                  # fresh XLA traces while compile-ahead on
_CA_CANCEL = None               # cancel event of the latest worker


def compile_ahead_counts() -> Tuple[int, int]:
    """(hits, misses) of the AOT compile-ahead registry: a hit is a
    dispatch served by a pre-built executable, a miss a dispatch that had
    to trace a fresh XLA program even though compile-ahead ran."""
    with _LOCK:
        return _CA_HITS, _CA_MISSES


def reset_compile_ahead_counts() -> None:
    global _CA_HITS, _CA_MISSES
    with _LOCK:
        _CA_HITS = _CA_MISSES = 0


def _aot_lookup(key: Tuple):
    """The AOT executable for ``key``, waiting out an in-flight
    background compile of the same key first; None when absent."""
    with _LOCK:
        fn = _AOT_FNS.get(key)
        ev = _AOT_PENDING.get(key)
    if fn is not None or ev is None:
        return fn
    ev.wait(timeout=600.0)
    with _LOCK:
        return _AOT_FNS.get(key)


def _aot_call(key: Tuple, jit_fn, args: Tuple):
    """Dispatch through the AOT registry when it covers ``key``; fall
    back to the ordinary jit call.  A compile-ahead MISS is a dispatch
    that had to trace a fresh XLA program even though compile-ahead
    claimed its (signature, kernel-tag) family — shapes in families the
    worker never touched (e.g. prologue probe batches when only
    scan/stacked shapes were predicted) don't count."""
    global _CA_HITS, _CA_MISSES
    cfn = _aot_lookup(key)
    if cfn is not None:
        try:
            out = cfn(*args)
        except Exception:       # shape/dtype drift vs the predicted job
            with _LOCK:
                _CA_MISSES += 1
            return jit_fn(*args)
        with _LOCK:
            _CA_HITS += 1
        return out
    with _LOCK:
        armed = _CA_ACTIVE and key[:5] in _CA_PREFIXES
    if not armed:
        return jit_fn(*args)
    try:
        before = jit_fn._cache_size()
    except Exception:
        before = None
    out = jit_fn(*args)
    try:
        traced = before is None or jit_fn._cache_size() > before
    except Exception:
        traced = True
    if traced:
        with _LOCK:
            _CA_MISSES += 1
    return out


def compile_ahead(jobs: Sequence[Tuple[Tuple, object, Tuple]],
                  wait: bool = False) -> Optional[threading.Thread]:
    """Compile the given (key, jit_fn, arg_structs) jobs on a background
    thread.  Returns the thread (already started); ``wait=True`` joins it
    before returning (tests).  Marks compile-ahead active for the epoch,
    which arms the miss counter on every later dispatch.

    Every queued key is claimed in ``_AOT_PENDING`` *before* the worker
    starts: a dispatch that races the worker finds its key pending and
    waits for the executable (``_aot_lookup``) instead of tracing a
    duplicate program inline — a queued shape can never count as a miss,
    only a shape the predictor failed to enumerate.

    The worker is a NON-daemon thread with a cooperative cancel
    (:func:`compile_ahead_quiesce`): a daemon thread killed mid-XLA
    -compile at interpreter exit aborts the process from C++
    (``terminate called without an active exception``), so instead the
    fleet cancels leftover queue work when its run ends and interpreter
    shutdown joins at most the one in-flight compile."""
    global _CA_ACTIVE, _CA_CANCEL
    cancel = threading.Event()
    with _LOCK:
        _CA_ACTIVE = True
        _CA_PREFIXES.update(key[:5] for key, _, _ in jobs)
        queued = []
        for key, jit_fn, arg_structs in jobs:
            if key in _AOT_FNS or key in _AOT_PENDING:
                continue
            ev = threading.Event()
            _AOT_PENDING[key] = ev
            queued.append((key, jit_fn, arg_structs, ev))
        _CA_CANCEL = cancel
    if not queued:
        return None

    def work():
        for key, jit_fn, arg_structs, ev in queued:
            try:
                if not cancel.is_set():
                    compiled = jit_fn.lower(*arg_structs).compile()
                    with _LOCK:
                        _AOT_FNS[key] = compiled
            except Exception:   # dispatch path falls back to tracing
                pass
            finally:
                ev.set()
                with _LOCK:
                    _AOT_PENDING.pop(key, None)

    # daemon=False EXPLICITLY: daemon-ness is inherited from the creating
    # thread, and the sweep server runs fleets on a daemon worker — the
    # non-daemon guarantee above must not silently vanish there
    th = threading.Thread(target=work, name="compile-ahead", daemon=False)
    th.start()
    if wait:
        th.join()
    return th


def compile_ahead_quiesce() -> None:
    """Cancel any compile-ahead work still queued (the in-flight compile
    finishes; skipped jobs release their pending events so no waiter
    hangs).  Called by the fleet when its run ends — whatever is still
    queued was predicted for dispatches that will never come — and at
    interpreter shutdown, so exit joins at most one in-flight compile."""
    with _LOCK:
        cancel = _CA_CANCEL
    if cancel is not None:
        cancel.set()


# threading._register_atexit callbacks fire BEFORE the interpreter joins
# non-daemon threads (plain atexit fires after, too late) — the same
# hook concurrent.futures uses to wind down its workers
try:
    threading._register_atexit(compile_ahead_quiesce)
except Exception:               # pragma: no cover - future-proofing
    import atexit
    atexit.register(compile_ahead_quiesce)


def clear_compile_cache() -> None:
    """Drop all shared jitted evaluators (benchmarking hook)."""
    global _CA_ACTIVE
    _jitted_eval.cache_clear()
    _build_eval_one.cache_clear()
    _scan_task_fn.cache_clear()
    _scan_fn.cache_clear()
    _direct_scan_task_fn.cache_clear()
    _direct_scan_fn.cache_clear()
    with _LOCK:
        _JIT_FNS.clear()
        _SHARD_FNS.clear()
        _STACK_CONSTS.clear()
        _AOT_FNS.clear()
        _AOT_PENDING.clear()
        _CA_PREFIXES.clear()
        _CA_ACTIVE = False
    reset_stack_prep_counts()
    reset_dispatch_count()
    reset_compile_ahead_counts()
    reset_host_blocked_s()


# ------------------------------------------------------- topology tables


@dataclasses.dataclass(frozen=True)
class _TopoTables:
    """Structural constants the kernel builder derives from a Topology."""

    n_levels: int
    n_edges: int
    is_spatial: Tuple[bool, ...]            # per mapping level
    spatial_levels: Tuple[int, ...]
    store_outer: Tuple[Tuple[bool, ...], ...]   # (n_edges, n_levels)
    store_inner: Tuple[Tuple[bool, ...], ...]
    edge_site: Tuple[Optional[int], ...]    # per edge
    n_sites: int
    # param-vector layout (indices into the traced vector)
    fanout_idx: Tuple[int, ...]             # per spatial level
    cap_checks: Tuple[Tuple[int, int], ...]  # (edge idx, param idx)
    energy_idx: Tuple[Tuple[int, ...], ...]  # per edge: component indices
    bw_checks: Tuple[Tuple[int, int], ...]  # (edge idx, param idx)
    mac_idx: int
    # NoC scheme per edge (True/False/"frac") + the word-width
    # parameterization: with uniform_words the kernel bakes WORD_BYTES as
    # a constant (the pre-width code path); otherwise per-edge widths are
    # read from the param vector at word_idx, so same-topology
    # custom-width specs still share one compilation.  Fractional NoC
    # schemes read their discount fanout from the param-vector tail at
    # noc_mc_idx / noc_red_idx (None on all/none edges) — same split, so
    # a same-scheme family with different fanouts shares one compilation.
    noc_multicast: Tuple[Union[bool, str], ...] = ()
    noc_reduction: Tuple[Union[bool, str], ...] = ()
    uniform_words: bool = True
    word_idx: Tuple[int, ...] = ()          # per edge: param idx
    noc_mc_idx: Tuple[Optional[int], ...] = ()   # per edge: param idx|None
    noc_red_idx: Tuple[Optional[int], ...] = ()


@lru_cache(maxsize=32)
def _topo_tables(topo: Topology) -> _TopoTables:
    n_edges = len(topo.has_spatial)
    level_edge: List[int] = []
    is_spatial: List[bool] = []
    for e in range(n_edges):
        level_edge.append(e)
        is_spatial.append(False)
        if topo.has_spatial[e]:
            level_edge.append(e)
            is_spatial.append(True)
    nl = len(level_edge)
    spatial_levels = tuple(i for i, s in enumerate(is_spatial) if s)
    store_outer = tuple(
        tuple(level_edge[i] <= e for i in range(nl))
        for e in range(n_edges))
    store_inner = tuple(
        tuple(level_edge[i] > e for i in range(nl))
        for e in range(n_edges))

    # param vector layout mirrors ArchSpec.param_vector
    pos = 0
    fanout_idx = tuple(range(pos, pos + len(spatial_levels)))
    pos += len(spatial_levels)
    cap_checks = []
    for k in range(1, n_edges + 1):
        if topo.has_capacity[k]:
            cap_checks.append((k - 1, pos))
            pos += 1
    energy_idx = []
    for e in range(n_edges):
        energy_idx.append(tuple(range(pos, pos + topo.n_energy_comps[e])))
        pos += topo.n_energy_comps[e]
    bw_checks = []
    for e in range(n_edges):
        if topo.has_bandwidth[e]:
            bw_checks.append((e, pos))
            pos += 1
    mac_idx = pos
    word_idx = tuple(range(pos + 1, pos + 1 + n_edges))
    # fractional NoC fanouts trail the word widths (mirrors
    # ArchSpec.param_vector: edge order, multicast before reduction)
    noc_mc = topo.noc_multicast or (True,) * n_edges
    noc_red = topo.noc_reduction or (True,) * n_edges
    pos = word_idx[-1] + 1 if word_idx else mac_idx + 1
    noc_mc_idx: List[Optional[int]] = []
    noc_red_idx: List[Optional[int]] = []
    for e in range(n_edges):
        if noc_mc[e] == "frac":
            noc_mc_idx.append(pos)
            pos += 1
        else:
            noc_mc_idx.append(None)
        if noc_red[e] == "frac":
            noc_red_idx.append(pos)
            pos += 1
        else:
            noc_red_idx.append(None)

    return _TopoTables(
        n_levels=nl, n_edges=n_edges, is_spatial=tuple(is_spatial),
        spatial_levels=spatial_levels, store_outer=store_outer,
        store_inner=store_inner, edge_site=topo.edge_site,
        n_sites=len(topo.sg_sites), fanout_idx=fanout_idx,
        cap_checks=tuple(cap_checks), energy_idx=tuple(energy_idx),
        bw_checks=tuple(bw_checks), mac_idx=mac_idx,
        noc_multicast=noc_mc,
        noc_reduction=noc_red,
        uniform_words=topo.uniform_word_bytes,
        word_idx=word_idx,
        noc_mc_idx=tuple(noc_mc_idx), noc_red_idx=tuple(noc_red_idx))


# ------------------------------------------- density occupancy builders
#
# JAX counterparts of DensityModel.block_nonempty, keyed by family name.
# Each takes (params_row, elems) where params_row is the traced
# [code, hit_rate, family params...] row (density.param_row) and elems
# the (possibly fractional) tile extents, and returns P(block nonempty).
# Custom families register with :func:`register_density_occ` BEFORE
# building evaluators (the structured kernel bakes the registered set at
# trace time; the registry fingerprint is part of the signature).


def _occ_uniform(pr, e):
    return 1.0 - jnp.power(1.0 - pr[2], jnp.maximum(e, 1.0))


def _occ_banded(pr, e):
    cov = jnp.maximum(pr[3], 1e-30)
    d_in = jnp.clip(pr[2] / cov, 0.0, 1.0)
    return cov * (1.0 - jnp.power(1.0 - d_in, jnp.maximum(e, 1.0)))


def _occ_block_nm(pr, e):
    # hypergeometric miss: C(m-n, e) / C(m, e) via log-gamma (fractional
    # e supported); any window wider than the zero budget m-n must hit
    from jax.scipy.special import gammaln
    n_, m_ = pr[2], pr[3]
    free = m_ - n_
    e_ = jnp.maximum(e, 1.0)
    ec = jnp.minimum(e_, free)
    lg = (gammaln(free + 1.0) + gammaln(m_ - ec + 1.0)
          - gammaln(free - ec + 1.0) - gammaln(m_ + 1.0))
    return jnp.where(e_ > free, 1.0, 1.0 - jnp.exp(lg))


_JAX_OCC = {"uniform": _occ_uniform, "banded": _occ_banded,
            "block_nm": _occ_block_nm}


def register_density_occ(family: str, fn) -> None:
    """Register the JAX occupancy builder of a custom density family
    (numpy side: ``density.register_density_model``).  Must happen before
    any structured evaluator is built."""
    if family in _JAX_OCC and _JAX_OCC[family] is not fn:
        raise ValueError(f"density family {family!r} already has a JAX "
                         f"occupancy builder")
    _JAX_OCC[family] = fn


def _occ_structured(pr, e):
    """Trace-time dispatch over the registered families: every family's
    occupancy is computed and the traced per-tensor code selects one —
    the family assignment rides in the traced params, so it never splits
    compilations."""
    fams = density_lib.registered_families()
    missing = [f for f in fams if f not in _JAX_OCC]
    if missing:
        raise KeyError(
            f"density families {missing} have no JAX occupancy builder; "
            f"call jax_cost.register_density_occ (COMPAT.md)")
    out = _JAX_OCC[fams[0]](pr, e)
    for fam in fams[1:]:
        out = jnp.where(pr[0] == float(density_lib.family_code(fam)),
                        _JAX_OCC[fam](pr, e), out)
    return out


# ---------------------------------------------------------------- kernel


@lru_cache(maxsize=64)
def _build_eval_one(d: int, n_primes_pad: int, topo: Topology,
                    dens_key: str = "u"):
    """Build the un-vmapped per-row kernel closure for (ndims=d, padded
    prime count, topology, density mode).  Every dispatch path — the
    broadcast and stacked batch evaluators, the sharded mega-batch, and
    the device-resident ``run_segments`` scan — vmaps this ONE closure,
    so per-row results are identical across all of them.

    ``dens_key == "u"`` bakes the uniform-random occupancy model exactly
    as the pre-density-model code did (bit-identical to the goldens);
    any other value builds the structured variant, in which each
    tensor's density-model family code and numeric parameters are read
    from the traced ``dens_params`` rows (see ``_occ_structured``)."""
    tt = _topo_tables(topo)
    structured = dens_key != "u"
    NL = tt.n_levels
    NE = tt.n_edges
    perm_table = jnp.asarray(all_permutations(d), jnp.int32)
    store_outer_lv = jnp.asarray(np.asarray(tt.store_outer))  # (NE, NL)
    store_inner_lv = jnp.asarray(np.asarray(tt.store_inner))
    spatial_lv = jnp.asarray(np.asarray(tt.is_spatial))
    lvl_of = jnp.repeat(jnp.arange(NL), d)          # (nl,)
    wb = float(WORD_BYTES)

    def eval_one(perm_genes, assign, fmt_genes, sg,
                 primes, prime_dim, relevance, densities, full_elems,
                 total_macs, z_onehot, plat, dens_params):
        # ---- tiling factors (NL, d) ----
        lvl_eq = assign[None, :] == jnp.arange(NL,
                                               dtype=jnp.int32)[:, None]
        dim_eq = prime_dim[None, :] == jnp.arange(d, dtype=jnp.int32)[:, None]
        mask = lvl_eq[:, None, :] & dim_eq[None, :, :]     # (NL, d, np)
        factors = jnp.prod(jnp.where(mask, primes[None, None, :], 1.0),
                           axis=-1)                        # (NL, d) float32

        # ---- flattened loops ----
        loop_dims = perm_table[perm_genes]                 # (NL, d)
        dims_flat = loop_dims.reshape(-1)                  # (nl,)
        bounds = factors[lvl_of, dims_flat]
        spatial_flat = spatial_lv[lvl_of]

        fanouts = [jnp.prod(factors[lvl]) for lvl in tt.spatial_levels]
        rel_flat = relevance[:, dims_flat]                 # (3, nl)
        transparent = bounds <= 1.0

        store_outer = store_outer_lv[:, lvl_of]            # (NE, nl)

        def fills_for(s, t):
            active = store_outer[s]
            irrel = ~rel_flat[t]
            passthru = jnp.where(active, irrel | transparent, True)
            in_suffix = jnp.flip(jnp.cumprod(
                jnp.flip(passthru.astype(jnp.float32)))) > 0.5
            contrib = jnp.where(rel_flat[t], bounds,
                                jnp.where(~spatial_flat, bounds, 1.0))
            mult = jnp.prod(jnp.where(active & ~in_suffix, contrib, 1.0))
            # NoC scheme of edge s: without multicast (reads) /
            # in-network reduction (the output, tensor 2), every spatial
            # instance's copy crosses the edge — irrelevant spatial loops
            # multiply traffic wherever they sit in the nest (suffix
            # included).  Fractional schemes carry max(S / fanout, 1)
            # copies over the same loop set, the fanout traced from the
            # param-vector tail (same-scheme families share compilation).
            scheme = (tt.noc_reduction[s] if t == 2
                      else tt.noc_multicast[s])
            if scheme == "frac":
                fi = tt.noc_red_idx[s] if t == 2 else tt.noc_mc_idx[s]
                s_irrel = jnp.prod(jnp.where(
                    active & irrel & spatial_flat, bounds, 1.0))
                mult = mult * jnp.maximum(s_irrel / plat[fi], 1.0)
            elif not scheme:
                mult = mult * jnp.prod(jnp.where(
                    active & irrel & spatial_flat, bounds, 1.0))
            tile = jnp.prod(jnp.where(
                store_inner_lv[s][:, None] & relevance[t][None, :],
                factors, 1.0))
            return tile * mult

        fills = jnp.stack([jnp.stack([fills_for(s, t) for t in range(3)])
                           for s in range(NE)])            # (NE, 3)

        # ---- fiber-tree format accounting per tensor ----
        def clog2(x):
            return jnp.maximum(1.0, jnp.ceil(jnp.log2(jnp.maximum(x, 2.0))))

        def tensor_format(t):
            genes = fmt_genes[t]
            is_sub = rel_flat[t] & (bounds > 1.0)
            k = jnp.sum(is_sub.astype(jnp.int32))
            rank = jnp.cumsum(is_sub.astype(jnp.int32)) - 1
            gidx = rank + jnp.maximum(MAX_FMT_GENES - k, 0)
            fmt = jnp.where(is_sub & (gidx < MAX_FMT_GENES) & (gidx >= 0),
                            genes[jnp.clip(gidx, 0, MAX_FMT_GENES - 1)],
                            FMT_U)
            dens = densities[t]
            sub_bounds = jnp.where(is_sub, bounds, 1.0)
            suffix_prod = jnp.flip(jnp.cumprod(jnp.flip(sub_bounds)))
            elems_below = suffix_prod / sub_bounds
            if structured:
                occ = _occ_structured(dens_params[t], elems_below)
            else:
                # all-uniform: the literal pre-density-model expression
                occ = 1.0 - jnp.power(1.0 - dens,
                                      jnp.maximum(elems_below, 1.0))
            kept = sub_bounds * occ
            full = full_elems[t]

            def body(carry, xs):
                n_fibers, meta_bits = carry
                L, f, kp, sub = xs
                mb = jnp.select(
                    [f == FMT_B, f == FMT_RLE, f == FMT_CP, f == FMT_UOP],
                    [n_fibers * L,
                     n_fibers * kp * clog2(L),
                     n_fibers * kp * clog2(L),
                     n_fibers * (L + 1.0) * clog2(jnp.maximum(full, 2.0))],
                    0.0)
                meta_bits = meta_bits + jnp.where(sub > 0.5, mb, 0.0)
                nf_next = jnp.where(f == FMT_U, n_fibers * L, n_fibers * kp)
                n_fibers = jnp.where(sub > 0.5, nf_next, n_fibers)
                return (n_fibers, meta_bits), None

            (_, meta_bits), _ = jax.lax.scan(
                body, (jnp.float32(1.0), jnp.float32(0.0)),
                (sub_bounds, fmt, kept, is_sub.astype(jnp.float32)))
            compressed = jnp.any(jnp.where(is_sub, fmt != FMT_U, False))
            data_b = jnp.where(compressed, full * dens * wb, full * wb)
            ratio = (data_b + meta_bits / 8.0) / jnp.maximum(full * wb, 1.0)

            comp_here = jnp.where(is_sub, (fmt != FMT_U).astype(jnp.float32),
                                  0.0)
            comp_after = jnp.flip(jnp.cumsum(jnp.flip(comp_here))) - comp_here
            uop_bad = jnp.any(is_sub & (fmt == FMT_UOP) & (comp_after < 0.5))
            spat_bad = jnp.any(is_sub & spatial_flat & (fmt != FMT_U))
            return ratio, compressed, uop_bad | spat_bad, meta_bits

        rs, comps, bads, metas = zip(*[tensor_format(t) for t in range(3)])
        ratios = jnp.stack(rs)
        fmt_invalid = bads[0] | bads[1] | bads[2]
        p_comp, q_comp = comps[0], comps[1]

        # ---- S/G (sg has one gene per site; compute site "C" last) ----
        lead_p = jnp.asarray(SG_LEADER_P)[sg]
        lead_q = jnp.asarray(SG_LEADER_Q)[sg]
        fol_p = jnp.asarray(SG_FOLLOW_P)[sg]
        fol_q = jnp.asarray(SG_FOLLOW_Q)[sg]
        skips = jnp.asarray(SG_IS_SKIP)[sg]
        gates = jnp.asarray(SG_IS_GATE)[sg]
        if structured:
            # element-granularity intersection hit rates of the input
            # leaders (DensityModel.hit_rate, traced per tensor)
            d_p, d_q = dens_params[0, 1], dens_params[1, 1]
        else:
            d_p, d_q = densities[0], densities[1]
        sg_invalid = jnp.any(skips & ((lead_p & ~p_comp) |
                                      (lead_q & ~q_comp)))
        frac_e_p = jnp.where(fol_p & (skips | gates), d_q, 1.0)
        frac_e_q = jnp.where(fol_q & (skips | gates), d_p, 1.0)
        frac_t_p = jnp.where(fol_p & skips, d_q, 1.0)
        frac_t_q = jnp.where(fol_q & skips, d_p, 1.0)
        cyc_frac = jnp.where(jnp.any(skips & lead_p), d_p, 1.0) * \
            jnp.where(jnp.any(skips & lead_q), d_q, 1.0)
        e_frac = jnp.where(jnp.any((skips | gates) & lead_p), d_p, 1.0) * \
            jnp.where(jnp.any((skips | gates) & lead_q), d_q, 1.0)

        # ---- traffic ----
        total_z = jnp.sum(full_elems * z_onehot)
        is_z = z_onehot                                     # (3,)
        one = jnp.float32(1.0)
        fe_rows, ft_rows = [], []
        for e in range(NE):
            si = tt.edge_site[e]
            if si is None:
                fe_rows.append(jnp.stack([one, one, one]))
                ft_rows.append(jnp.stack([one, one, one]))
            else:
                fe_rows.append(jnp.stack([frac_e_p[si], frac_e_q[si], one]))
                ft_rows.append(jnp.stack([frac_t_p[si], frac_t_q[si], one]))
        fe = jnp.stack(fe_rows)                             # (NE, 3)
        ft = jnp.stack(ft_rows)
        f_rmw = jnp.maximum(2.0 * fills - total_z, total_z)
        fills_adj = jnp.where(is_z[None, :] > 0.5, f_rmw, fills)

        def _tile_elems(s):
            return jnp.stack([
                jnp.prod(jnp.where(
                    store_inner_lv[s][:, None] & relevance[t][None, :],
                    factors, 1.0)) for t in range(3)])

        if tt.uniform_words:
            # default-width topology: the pre-word-width code, the global
            # width baked as a constant (bit-identical to the goldens)
            byt = fills_adj * wb * ratios[None, :]          # (NE edges, 3 t)

            def tile_bytes(s):
                return jnp.sum(_tile_elems(s) * wb * ratios)
        else:
            # per-edge widths from the param vector: data bytes scale
            # with the width, metadata bits do not, so the compression
            # ratio is recomputed per edge (edge s fills store s+1, whose
            # width also prices that store's occupancy)
            wbs = jnp.stack([plat[i] for i in tt.word_idx])  # (NE,)
            full_wb = full_elems[None, :] * wbs[:, None]     # (NE, 3)
            data_b = jnp.where(
                jnp.stack(comps)[None, :],
                full_elems[None, :] * densities[None, :] * wbs[:, None],
                full_wb)
            ratios_e = (data_b + jnp.stack(metas)[None, :] / 8.0) / \
                jnp.maximum(full_wb, 1.0)                    # (NE, 3)
            byt = fills_adj * wbs[:, None] * ratios_e

            def tile_bytes(s):
                return jnp.sum(_tile_elems(s) * wbs[s] * ratios_e[s])
        tr_e = byt * fe
        tr_t = byt * ft

        # ---- validity, energy, latency (param-vector driven) ----
        invalid = jnp.bool_(False)
        for fan, pi in zip(fanouts, tt.fanout_idx):
            invalid = invalid | (fan > plat[pi])
        invalid = invalid | fmt_invalid | sg_invalid
        for e, pi in tt.cap_checks:
            invalid = invalid | (tile_bytes(e) > plat[pi])

        # left-associated sums/products, matching the legacy kernel's
        # float32 evaluation order exactly
        edge_energies = []
        for e in range(NE):
            comps_e = [plat[i] for i in tt.energy_idx[e]]
            e_edge = comps_e[0]
            for c in comps_e[1:]:
                e_edge = e_edge + c
            edge_energies.append(jnp.sum(tr_e[e]) * e_edge)
        energy = edge_energies[0]
        for term in edge_energies[1:]:
            energy = energy + term
        energy = energy + total_macs * e_frac * plat[tt.mac_idx]
        fan_prod = fanouts[0] if fanouts else one
        for fan in fanouts[1:]:
            fan_prod = fan_prod * fan
        compute_cycles = (total_macs / fan_prod) * cyc_frac
        cycles = compute_cycles
        for e, pi in tt.bw_checks:
            cycles = jnp.maximum(cycles, jnp.sum(tr_t[e]) / plat[pi])
        edp = cycles * energy
        log10_edp = jnp.log10(jnp.maximum(cycles, 1e-30)) + \
            jnp.log10(jnp.maximum(energy, 1e-30))
        valid = ~invalid
        big = jnp.float32(jnp.inf)
        return dict(valid=valid,
                    energy_pj=jnp.where(valid, energy, big),
                    cycles=jnp.where(valid, cycles, big),
                    edp=jnp.where(valid, edp, big),
                    log10_edp=jnp.where(valid, log10_edp, big))

    return eval_one


@lru_cache(maxsize=32)
def _jitted_eval(d: int, n_primes_pad: int, topo: Topology,
                 dens_key: str = "u", stacked: bool = False):
    """The jitted batch evaluator for (ndims=d, padded prime count,
    topology, density mode): :func:`_build_eval_one` vmapped over the
    batch axis.

    With ``stacked=False`` the workload/platform quantities are broadcast
    over the batch (one workload per call); with ``stacked=True`` they are
    batched per row, so rows belonging to *different* workloads and
    platforms can be concatenated into one mega-batch and evaluated in a
    single device dispatch (``eval_stacked``)."""
    eval_one = _build_eval_one(d, n_primes_pad, topo, dens_key)
    in_axes = (0,) * 13 if stacked else (0, 0, 0, 0) + (None,) * 9
    fn = jax.jit(jax.vmap(eval_one, in_axes=in_axes))
    with _LOCK:
        _JIT_FNS[(d, n_primes_pad, topo.fingerprint, dens_key,
                  "stacked" if stacked else "bcast")] = fn
    return fn


# -------------------------------------------------- device-resident scan

# Mesh-sharded jitted variants, keyed by (signature..., kind, mesh key).
# Kept out of the lru_caches because a Mesh is identified by its device
# set + axis names, not object identity.
_SHARD_FNS: Dict[Tuple, object] = {}


def _mesh_key(mesh) -> Tuple:
    devs = np.asarray(mesh.devices).reshape(-1)
    return (tuple(mesh.axis_names), tuple(int(d.id) for d in devs))


def _mesh_ndev(mesh) -> int:
    return 1 if mesh is None else int(np.asarray(mesh.devices).size)


@lru_cache(maxsize=32)
def _scan_task_fn(d: int, n_pad: int, topo: Topology, dens_key: str,
                  n_parents: int, n_elite: int, genes_per: int,
                  restart: int = 0):
    """The un-jitted scan program for ONE fleet of same-shape tasks:
    vmap over the task axis of a ``lax.scan`` over generations, each
    step folding {stable-sort elitist selection -> crossover -> mutation
    -> clip/fixed-genes -> batched cost eval} into the carry.

    All randomness arrives pre-drawn in the ``draws`` xs (plan arrays in
    PADDED genome coordinates — see ``es_ops.PaddedLayout``), so the
    program is a pure function of its inputs; the carry fitness for
    selection is the explicit ``cycles * energy`` product of the emitted
    outputs, the same multiply ``_canonical`` performs on the host.

    ``restart > 0`` extends the carry with the float32 best-so-far and a
    no-improvement counter: after ``restart`` stagnant generations the
    non-elite population is replaced by the pre-drawn fresh block of
    ``draws["fresh"]`` (always evaluated — fixed shapes — and adopted
    via a where-select on the carry, the ``lax.cond`` re-init branch in
    its vmap-compatible form).  ``restart == 0`` builds EXACTLY the
    pre-restart program."""
    eval_one = _build_eval_one(d, n_pad, topo, dens_key)
    tt = _topo_tables(topo)
    NL = tt.n_levels
    F3 = 3 * MAX_FMT_GENES
    veval = jax.vmap(eval_one, in_axes=(0, 0, 0, 0) + (None,) * 9)

    def eval_rows(kids, consts):
        C = kids.shape[0]
        perm = kids[:, :NL]
        til = kids[:, NL:NL + n_pad]
        fmt = kids[:, NL + n_pad:NL + n_pad + F3].reshape(
            C, 3, MAX_FMT_GENES)
        sg = kids[:, NL + n_pad + F3:]
        return veval(perm, til, fmt, sg, *consts)

    def one_task(pop, edp, gene_ub, fixed_mask, fixed_vals, draws, consts):
        def make_kids(pop, order, dr):
            parents = pop[order[:n_parents]]
            Lp = pop.shape[1]
            col = jnp.arange(Lp)[None, :]
            kids = jnp.where(col < dr["cuts"][:, None],
                             parents[dr["ab"][:, 0]],
                             parents[dr["ab"][:, 1]])
            C = kids.shape[0]
            rows = jnp.arange(C)
            # draw-order duplicate overwrite: one column at a time (row
            # indices are unique per column, so the order is defined)
            for j in range(genes_per):
                g = dr["gene"][:, j]
                kids = kids.at[rows, g].set(
                    jnp.where(dr["active"], dr["vals"][:, j],
                              kids[rows, g]))
            kids = jnp.clip(kids, 0, gene_ub[None, :] - 1)
            kids = jnp.where(fixed_mask[None, :], fixed_vals[None, :],
                             kids)
            return kids

        def step(carry, dr):
            pop, edp = carry
            order = jnp.argsort(edp)            # stable sort
            elites = pop[order[:n_elite]]
            elite_edp = edp[order[:n_elite]]
            kids = make_kids(pop, order, dr)
            out = eval_rows(kids, consts)
            kedp = out["cycles"] * out["energy_pj"]
            new_pop = jnp.concatenate([elites, kids], axis=0)
            new_edp = jnp.concatenate([elite_edp, kedp], axis=0)
            ys = dict(kids=kids, valid=out["valid"],
                      energy_pj=out["energy_pj"], cycles=out["cycles"])
            return (new_pop, new_edp), ys

        def step_restart(carry, dr):
            pop, edp, best, since = carry
            order = jnp.argsort(edp)
            elites = pop[order[:n_elite]]
            elite_edp = edp[order[:n_elite]]
            kids = make_kids(pop, order, dr)
            out = eval_rows(kids, consts)
            kedp = out["cycles"] * out["energy_pj"]
            kbest = jnp.minimum(best, jnp.min(kedp))
            since1 = jnp.where(kbest < best, 0, since + 1)
            # the fresh block is always evaluated (fixed shapes) and only
            # ADOPTED when the stagnation threshold trips
            fresh = dr["fresh"]
            fout = eval_rows(fresh, consts)
            fedp = fout["cycles"] * fout["energy_pj"]
            do_r = since1 >= restart
            new_pop = jnp.where(
                do_r, jnp.concatenate([elites, fresh], axis=0),
                jnp.concatenate([elites, kids], axis=0))
            new_edp = jnp.where(
                do_r, jnp.concatenate([elite_edp, fedp], axis=0),
                jnp.concatenate([elite_edp, kedp], axis=0))
            best2 = jnp.where(do_r, jnp.minimum(kbest, jnp.min(fedp)),
                              kbest)
            since2 = jnp.where(do_r, 0, since1)
            ys = dict(kids=kids, valid=out["valid"],
                      energy_pj=out["energy_pj"], cycles=out["cycles"],
                      f_valid=fout["valid"],
                      f_energy_pj=fout["energy_pj"],
                      f_cycles=fout["cycles"], restarted=do_r)
            return (new_pop, new_edp, best2, since2), ys

        if restart > 0:
            best0 = draws["best0"][0]
            since0 = draws["since0"][0]
            dr_xs = {kk: v for kk, v in draws.items()
                     if kk not in ("best0", "since0")}
            (pop, edp, best, since), ys = jax.lax.scan(
                step_restart, (pop, edp, best0, since0), dr_xs)
            ys = dict(ys, best=best[None], since=since[None])
            return pop, edp, ys
        (pop, edp), ys = jax.lax.scan(step, (pop, edp), draws)
        return pop, edp, ys

    return jax.vmap(one_task, in_axes=(0, 0, 0, 0, 0, 0, 0))


def _donate_args() -> Tuple[int, ...]:
    """Donate the scan carry buffers (pop, edp) on accelerators so a
    pipelined fleet's device-resident populations update in place;
    donation on CPU only produces warnings, so it stays gated."""
    return (0, 1) if jax.default_backend() in ("gpu", "tpu") else ()


@lru_cache(maxsize=32)
def _scan_fn(d: int, n_pad: int, topo: Topology, dens_key: str,
             n_parents: int, n_elite: int, genes_per: int,
             restart: int = 0):
    fn = jax.jit(_scan_task_fn(d, n_pad, topo, dens_key, n_parents,
                               n_elite, genes_per, restart),
                 donate_argnums=_donate_args())
    tag = f"scan:p{n_parents}e{n_elite}g{genes_per}" + (
        f"r{restart}" if restart else "")
    with _LOCK:
        _JIT_FNS[(d, n_pad, topo.fingerprint, dens_key, tag)] = fn
    return fn


@lru_cache(maxsize=32)
def _direct_scan_task_fn(d: int, n_pad: int, topo: Topology,
                         dens_key: str, n_parents: int, n_elite: int,
                         genes_per: int):
    """The scan program for ``standard_es`` segments: the same
    {select -> single-point crossover -> gated mutation -> cost} fold,
    but the carry population lives in DIRECT value coordinates
    (``direct_encoding.DirectValueSpec`` layout: [perm codes | factor
    values d x n_levels | fmt/sg tail]) and every generation's children
    are translated to canonical rows IN-SCAN — the jnp twin of
    ``DirectValueSpec.to_canonical``'s greedy prime placement, vectorized
    over rows and unrolled over the padded prime axis with the prime
    value/dimension TRACED (from the shared consts), so same-signature
    workloads share one compilation.  The scrambled permutation table and
    dim sizes are traced per-task aux inputs for the same reason.

    Numerics note: factor products and remainders stay well inside
    float32's exact-integer range, so the divisibility/validity decisions
    are exact — the translation equals the numpy oracle row-for-row
    (test-pinned)."""
    eval_one = _build_eval_one(d, n_pad, topo, dens_key)
    tt = _topo_tables(topo)
    NL = tt.n_levels
    F3 = 3 * MAX_FMT_GENES
    tail_len = F3 + tt.n_sites
    Ld = NL + d * NL + tail_len
    veval = jax.vmap(eval_one, in_axes=(0, 0, 0, 0) + (None,) * 9)

    def one_task(pop, edp, scramble, dim_sizes, draws, consts):
        primes_f, prime_dim = consts[0], consts[1]

        def translate(kids):
            C = kids.shape[0]
            perm = scramble[kids[:, :NL]].astype(jnp.int32)
            factors = kids[:, NL:NL + d * NL].reshape(
                C, d, NL).astype(jnp.float32)
            prod = jnp.prod(factors, axis=2)                # (C, d)
            ok = jnp.all(prod == dim_sizes[None, :], axis=1)
            remaining = factors
            til = jnp.zeros((C, n_pad), dtype=jnp.int32)
            for kk in range(n_pad):
                p = primes_f[kk]
                di = prime_dim[kk]
                is_real = p > 1.5       # pad primes are 1.0
                rem = jax.lax.dynamic_index_in_dim(
                    remaining, di, axis=1, keepdims=False)  # (C, NL)
                can = (jnp.mod(rem, p) == 0) & (rem > 1.0)
                lvl = jnp.argmax(can, axis=1).astype(jnp.int32)
                hasl = jnp.any(can, axis=1)
                ok = ok & (hasl | ~is_real)
                upd = ((jnp.arange(NL)[None, :] == lvl[:, None]) &
                       hasl[:, None] & is_real)
                remaining = jax.lax.dynamic_update_index_in_dim(
                    remaining, jnp.where(upd, rem / p, rem), di, axis=1)
                til = til.at[:, kk].set(
                    jnp.where(is_real & hasl, lvl, 0))
            return perm, til, ok

        def step(carry, dr):
            pop, edp = carry
            order = jnp.argsort(edp)            # stable sort
            parents = pop[order[:n_parents]]
            elites = pop[order[:n_elite]]
            elite_edp = edp[order[:n_elite]]
            col = jnp.arange(Ld)[None, :]
            kids = jnp.where(col < dr["cuts"][:, None],
                             parents[dr["ab"][:, 0]],
                             parents[dr["ab"][:, 1]])
            C = kids.shape[0]
            rows = jnp.arange(C)
            for j in range(genes_per):
                g = dr["gene"][:, j]
                kids = kids.at[rows, g].set(
                    jnp.where(dr["active"], dr["vals"][:, j],
                              kids[rows, g]))
            # direct mutation draws are valid values by construction —
            # no clip, no fixed genes (matches the host loop exactly)
            perm, til, ok = translate(kids)
            tail = kids[:, NL + d * NL:]
            fmt = tail[:, :F3].reshape(C, 3, MAX_FMT_GENES)
            sg = tail[:, F3:]
            out = veval(perm, til, fmt, sg, *consts)
            big = jnp.float32(jnp.inf)
            kedp = jnp.where(ok, out["cycles"] * out["energy_pj"], big)
            canon = jnp.concatenate([perm, til, tail], axis=1)
            canon = jnp.where(ok[:, None], canon, 0)
            new_pop = jnp.concatenate([elites, kids], axis=0)
            new_edp = jnp.concatenate([elite_edp, kedp], axis=0)
            ys = dict(canon=canon, valid=ok & out["valid"],
                      energy_pj=jnp.where(ok, out["energy_pj"], big),
                      cycles=jnp.where(ok, out["cycles"], big))
            return (new_pop, new_edp), ys

        (pop, edp), ys = jax.lax.scan(step, (pop, edp), draws)
        return pop, edp, ys

    return jax.vmap(one_task, in_axes=(0, 0, 0, 0, 0, 0))


@lru_cache(maxsize=32)
def _direct_scan_fn(d: int, n_pad: int, topo: Topology, dens_key: str,
                    n_parents: int, n_elite: int, genes_per: int):
    fn = jax.jit(_direct_scan_task_fn(d, n_pad, topo, dens_key,
                                      n_parents, n_elite, genes_per),
                 donate_argnums=_donate_args())
    with _LOCK:
        _JIT_FNS[(d, n_pad, topo.fingerprint, dens_key,
                  f"dscan:p{n_parents}e{n_elite}g{genes_per}")] = fn
    return fn


def _sharded_scan_fn(d: int, n_pad: int, topo: Topology, dens_key: str,
                     n_parents: int, n_elite: int, genes_per: int, mesh):
    """The scan program shard_map-ed over the task axis of ``mesh``'s
    first axis (task count must divide the device count's multiple —
    checked by the caller)."""
    key = (d, n_pad, topo.fingerprint, dens_key,
           f"scan:p{n_parents}e{n_elite}g{genes_per}", _mesh_key(mesh))
    fn = _SHARD_FNS.get(key)
    if fn is None:
        from jax.sharding import PartitionSpec as P
        from ..distributed.compat import shard_map
        vfn = _scan_task_fn(d, n_pad, topo, dens_key, n_parents, n_elite,
                            genes_per)
        ax = mesh.axis_names[0]
        fn = jax.jit(shard_map(vfn, mesh=mesh, in_specs=(P(ax),) * 7,
                               out_specs=P(ax)))
        with _LOCK:
            _SHARD_FNS[key] = fn
            _JIT_FNS[(d, n_pad, topo.fingerprint, dens_key,
                      f"scan:p{n_parents}e{n_elite}g{genes_per}"
                      f"@{_mesh_ndev(mesh)}")] = fn
    return fn


def _sharded_stacked_fn(d: int, n_pad: int, topo: Topology,
                        dens_key: str, mesh):
    """The stacked mega-batch kernel shard_map-ed over batch rows."""
    key = (d, n_pad, topo.fingerprint, dens_key, "stacked",
           _mesh_key(mesh))
    fn = _SHARD_FNS.get(key)
    if fn is None:
        from jax.sharding import PartitionSpec as P
        from ..distributed.compat import shard_map
        eval_one = _build_eval_one(d, n_pad, topo, dens_key)
        vfn = jax.vmap(eval_one, in_axes=(0,) * 13)
        ax = mesh.axis_names[0]
        fn = jax.jit(shard_map(vfn, mesh=mesh, in_specs=(P(ax),) * 13,
                               out_specs=P(ax)))
        with _LOCK:
            _SHARD_FNS[key] = fn
            _JIT_FNS[(d, n_pad, topo.fingerprint, dens_key,
                      f"stacked@{_mesh_ndev(mesh)}")] = fn
    return fn


def _padded_layout(model: "JaxCostModel") -> PaddedLayout:
    lay = getattr(model, "_pad_layout", None)
    if lay is None:
        lay = PaddedLayout(model.spec, model.n_pad)
        model._pad_layout = lay
    return lay


def run_segments(models: Sequence["JaxCostModel"],
                 segs: Sequence[DeviceSegment],
                 mesh=None, defer: bool = False) -> List[SegmentResult]:
    """Execute one DeviceSegment per model as a SINGLE device dispatch:
    all segments (which must share the models' compilation signature and
    the segment shape key) stack along a task axis, and a jitted
    vmap-of-lax.scan advances every task ``k`` generations on-device.

    Host work per call is limited to padding genomes/plan arrays into
    the shared scan layout and, afterwards, slicing the per-generation
    outputs back per task (``_canonical``-recomputed like every other
    dispatch path).  With ``mesh`` given and the task count divisible by
    the device count, tasks shard across devices via the
    ``distributed.compat.shard_map`` shim; otherwise the single-device
    program runs unchanged.

    Pipelining hooks: a segment carrying ``carry`` (the device-resident
    padded (pop, edp) of its previous SegmentResult) skips the host-side
    genome padding entirely — the population never leaves the device
    between rounds.  With ``defer=True`` the returned results hold a
    ``harvest`` thunk instead of materialized numpy gens; the device is
    already computing when this function returns, and the caller
    converts (``SegmentResult.resolve``) one round late.  ``carry`` and
    the device handles are valid either way, so the next segment can
    dispatch before the previous one is harvested.

    ``kind == "direct"`` segments (``standard_es``) route to the
    direct-genome scan; ``restart > 0`` runs the stagnation-restart
    kernel variant and needs ``seg.state`` (best-so-far, stagnant-gens)
    plus per-generation ``draws["fresh"]`` re-init blocks."""
    if len(models) != len(segs):
        raise ValueError("models and segments must pair up")
    sig = models[0].signature
    if any(m.signature != sig for m in models):
        raise ValueError(
            f"run_segments needs one shared signature, got "
            f"{sorted({m.signature for m in models})}")
    shape_key = segment_shape_key(segs[0])
    if any(segment_shape_key(s) != shape_key for s in segs):
        raise ValueError("run_segments needs one shared segment shape")
    B, k, n_parents, n_elite, genes_per, kind, restart = shape_key
    if kind == "direct":
        return _run_direct_segments(models, segs, defer=defer)

    pops, edps, ubs, fmasks, fvals, draw_list = [], [], [], [], [], []
    n_children = 0
    for m, s in zip(models, segs):
        lay = _padded_layout(m)
        if s.carry is not None:
            pops.append(jnp.asarray(s.carry[0]))
            edps.append(jnp.asarray(s.carry[1]))
        else:
            pops.append(jnp.asarray(
                lay.pad_rows(np.asarray(s.pop, dtype=np.int32))))
            edps.append(jnp.asarray(np.asarray(s.edp, dtype=np.float32)))
        ubs.append(lay.pad_vector(m.spec.gene_ub.astype(np.int32), 1))
        fm = np.zeros(lay.Lp, dtype=bool)
        fv = np.zeros(lay.Lp, dtype=np.int32)
        if s.fixed_genes:
            idx = lay.pad_index(
                np.asarray(list(s.fixed_genes), dtype=np.int64))
            fm[idx] = True
            fv[idx] = np.asarray(list(s.fixed_genes.values()),
                                 dtype=np.int32)
        fmasks.append(fm)
        fvals.append(fv)
        dr = dict(s.draws)
        dr["gene"] = lay.pad_index(dr["gene"]).astype(np.int32)
        dr["cuts"] = lay.pad_cut(dr["cuts"]).astype(np.int32)
        if restart:
            fr = np.asarray(dr["fresh"], dtype=np.int32)
            gk, gc = fr.shape[0], fr.shape[1]
            dr["fresh"] = lay.pad_rows(
                fr.reshape(gk * gc, -1)).reshape(gk, gc, -1)
            dr["best0"] = np.asarray([s.state[0]], dtype=np.float32)
            dr["since0"] = np.asarray([s.state[1]], dtype=np.int32)
        n_children = dr["ab"].shape[1]
        draw_list.append(dr)
    draws = {kk: jnp.asarray(np.stack([d[kk] for d in draw_list]))
             for kk in draw_list[0]}
    consts = tuple(
        jnp.asarray(np.stack([np.asarray(m._np_consts[j])
                              for m in models]))
        for j in range(len(models[0]._np_consts)))

    T = len(segs)
    topo = models[0].arch.topology
    args = (jnp.stack(pops), jnp.stack(edps),
            jnp.asarray(np.stack(ubs)), jnp.asarray(np.stack(fmasks)),
            jnp.asarray(np.stack(fvals)), draws, consts)
    _count_dispatch()
    if mesh is not None and _mesh_ndev(mesh) > 1 and \
            T % _mesh_ndev(mesh) == 0 and not restart:
        fn = _sharded_scan_fn(sig[0], sig[1], topo, sig[3], n_parents,
                              n_elite, genes_per, mesh)
        pop_f, edp_f, ys = fn(*args)
    else:
        fn = _scan_fn(sig[0], sig[1], topo, sig[3], n_parents, n_elite,
                      genes_per, restart)
        tag = f"scan:p{n_parents}e{n_elite}g{genes_per}" + (
            f"r{restart}" if restart else "")
        key = sig + (tag, T, B, k, n_children)
        pop_f, edp_f, ys = _aot_call(key, fn, args)

    host = {}

    def materialize():
        if "ys" not in host:
            def conv():
                return (np.asarray(pop_f), np.asarray(edp_f),
                        {kk: np.asarray(v) for kk, v in ys.items()})
            host["pf"], host["ef"], host["ys"] = _time_block(conv)
        return host["pf"], host["ef"], host["ys"]

    def make_harvest(t, m):
        def harvest():
            pf, ef, ys_h = materialize()
            lay = _padded_layout(m)
            gens = []
            for g in range(k):
                kids = lay.unpad_rows(ys_h["kids"][t, g]).astype(np.int64)
                out = _canonical(dict(valid=ys_h["valid"][t, g],
                                      energy_pj=ys_h["energy_pj"][t, g],
                                      cycles=ys_h["cycles"][t, g]))
                if restart:
                    out["fresh"] = _canonical(dict(
                        valid=ys_h["f_valid"][t, g],
                        energy_pj=ys_h["f_energy_pj"][t, g],
                        cycles=ys_h["f_cycles"][t, g]))
                    out["restarted"] = bool(ys_h["restarted"][t, g])
                gens.append((kids, out))
            return (gens, lay.unpad_rows(pf[t]).astype(np.int64), ef[t])
        return harvest

    results: List[SegmentResult] = []
    for t, m in enumerate(models):
        r = SegmentResult(gens=None, final_pop=None, final_edp=None,
                          carry=(pop_f[t], edp_f[t]),
                          harvest=make_harvest(t, m))
        if not defer:
            r.resolve()
        if restart:
            _, _, ys_h = materialize()
            r.state = (float(ys_h["best"][t, 0]),
                       int(ys_h["since"][t, 0]))
        results.append(r)
    return results


def _run_direct_segments(models: Sequence["JaxCostModel"],
                         segs: Sequence[DeviceSegment],
                         defer: bool = False) -> List[SegmentResult]:
    """:func:`run_segments` for ``kind == "direct"`` segments: the carry
    population lives in DIRECT value coordinates and the in-scan
    translation (see ``_direct_scan_task_fn``) produces the canonical
    rows each generation's ``gens`` report.  ``final_pop`` is returned
    in direct coordinates (the generator's mirror), while ``gens`` kid
    rows are canonical genomes with untranslatable rows zeroed — exactly
    the legacy ``direct_requests`` registration rows."""
    sig = models[0].signature
    shape_key = segment_shape_key(segs[0])
    B, k, n_parents, n_elite, genes_per, kind, restart = shape_key
    if restart:
        raise ValueError("direct segments do not support in-scan restart")

    pops, edps, scrs, dims, draw_list = [], [], [], [], []
    n_children = 0
    for m, s in zip(models, segs):
        if s.carry is not None:
            pops.append(jnp.asarray(s.carry[0]))
            edps.append(jnp.asarray(s.carry[1]))
        else:
            pops.append(jnp.asarray(np.asarray(s.pop, dtype=np.int32)))
            edps.append(jnp.asarray(np.asarray(s.edp, dtype=np.float32)))
        scrs.append(np.asarray(s.aux["scramble"], dtype=np.int32))
        dims.append(np.asarray(s.aux["dim_sizes"], dtype=np.float32))
        dr = {kk: np.asarray(v) for kk, v in s.draws.items()}
        n_children = dr["ab"].shape[1]
        draw_list.append(dr)
    draws = {kk: jnp.asarray(np.stack([d[kk] for d in draw_list]))
             for kk in draw_list[0]}
    consts = tuple(
        jnp.asarray(np.stack([np.asarray(m._np_consts[j])
                              for m in models]))
        for j in range(len(models[0]._np_consts)))

    T = len(segs)
    topo = models[0].arch.topology
    fn = _direct_scan_fn(sig[0], sig[1], topo, sig[3], n_parents,
                         n_elite, genes_per)
    key = sig + (f"dscan:p{n_parents}e{n_elite}g{genes_per}",
                 T, B, k, n_children)
    _count_dispatch()
    pop_f, edp_f, ys = _aot_call(
        key, fn, (jnp.stack(pops), jnp.stack(edps),
                  jnp.asarray(np.stack(scrs)), jnp.asarray(np.stack(dims)),
                  draws, consts))

    host = {}

    def materialize():
        if "ys" not in host:
            def conv():
                return (np.asarray(pop_f), np.asarray(edp_f),
                        {kk: np.asarray(v) for kk, v in ys.items()})
            host["pf"], host["ef"], host["ys"] = _time_block(conv)
        return host["pf"], host["ef"], host["ys"]

    def make_harvest(t, m):
        def harvest():
            pf, ef, ys_h = materialize()
            lay = _padded_layout(m)
            gens = []
            for g in range(k):
                kids = lay.unpad_rows(
                    ys_h["canon"][t, g]).astype(np.int64)
                out = _canonical(dict(valid=ys_h["valid"][t, g],
                                      energy_pj=ys_h["energy_pj"][t, g],
                                      cycles=ys_h["cycles"][t, g]))
                gens.append((kids, out))
            return gens, pf[t].astype(np.int64), ef[t]
        return harvest

    results: List[SegmentResult] = []
    for t, m in enumerate(models):
        r = SegmentResult(gens=None, final_pop=None, final_edp=None,
                          carry=(pop_f[t], edp_f[t]),
                          harvest=make_harvest(t, m))
        if not defer:
            r.resolve()
        results.append(r)
    return results


# ---------------------------------------------------------------- wrapper


class JaxCostModel:
    """Batch evaluator bound to one (workload, arch/platform) pair.
    Instances with the same (ndims, prime bucket, topology) share a
    single XLA compilation — same-topology platforms (e.g. the paper's
    edge/mobile/cloud) differ only in the traced parameter vector.

    ``n_pad`` widens the prime axis beyond the workload's natural bucket so
    a group of concurrent searches over different workloads can be forced
    onto ONE compilation signature (``search.MultiSearch``); the padding
    primes are 1.0 and are numerically inert.

    ``structured`` likewise promotes an all-uniform workload onto the
    structured-density kernel variant (its Uniform models become traced
    family rows) so a mixed uniform/banded/N:M fleet shares one
    signature; ``None`` picks the workload's natural mode — all-uniform
    workloads then compile the literal pre-density-model kernel,
    bit-identical to the goldens."""

    def __init__(self, spec: GenomeSpec,
                 platform: Union[str, Platform, ArchSpec],
                 n_pad: Optional[int] = None,
                 structured: Optional[bool] = None):
        self.spec = spec
        self.arch = as_arch(platform)
        self.platform = self.arch          # legacy alias
        if self.arch.topology != spec.arch.topology:
            raise ValueError(
                f"GenomeSpec was built for arch {spec.arch.name!r} but "
                f"the evaluator targets {self.arch.name!r} with a "
                f"different topology")
        wl = spec.workload
        d = wl.ndims
        self.d = d
        self.n_primes = spec.n_primes
        self.n_pad = _bucket(max(self.n_primes, 1, int(n_pad or 0)))
        natural_structured = wl.structured_density
        if structured is None:
            structured = natural_structured
        elif not structured and natural_structured:
            raise ValueError(
                f"workload {wl.name!r} declares structured density "
                f"models; it cannot run on the uniform kernel")
        self.structured = bool(structured)
        self.dens_key = "u" if not self.structured else \
            "s:" + density_lib.registry_fingerprint()

        primes = np.ones(self.n_pad, dtype=np.float32)
        prime_dim = np.zeros(self.n_pad, dtype=np.int32)
        dim_idx = {dim: i for i, dim in enumerate(wl.dim_order)}
        for i, (dd, p) in enumerate(spec.primes):
            primes[i] = p
            prime_dim[i] = dim_idx[dd]
        # numpy copies kept for eval_stacked (per-row tiling across a
        # heterogeneous mega-batch); jnp copies feed the broadcast kernel
        self._np_consts = (
            primes,
            prime_dim,
            np.asarray([[dim in t.dims for dim in wl.dim_order]
                        for t in wl.tensors], bool),
            np.asarray([wl.density_of(t.name) for t in wl.tensors],
                       np.float32),
            np.asarray([t.size(wl.dim_sizes) for t in wl.tensors],
                       np.float32),
            np.float32(wl.macs),
            np.asarray([1.0 if t.is_output else 0.0 for t in wl.tensors],
                       np.float32),
            self.arch.param_vector(),
            # per-tensor traced density rows [code, hit, family params..]
            np.asarray([density_lib.param_row(wl.density_model_of(t.name))
                        for t in wl.tensors], np.float32))
        (self._primes, self._prime_dim, self._relevance, self._densities,
         self._full_elems, self._total_macs, self._z_onehot, self._plat,
         self._dens_params) = [jnp.asarray(c) for c in self._np_consts]

        self._fn = _jitted_eval(d, self.n_pad, self.arch.topology,
                                self.dens_key)
        s = spec.segments
        self._sl_perm = (s["perm"].start, s["perm"].stop)
        self._sl_til = (s["tiling"].start, s["tiling"].stop)
        self._sl_fmt = [(s[f"fmt_{t.name}"].start, s[f"fmt_{t.name}"].stop)
                        for t in wl.tensors]
        self._sl_sg = (s["sg"].start, s["sg"].stop)

    @property
    def signature(self) -> Tuple[int, int, str, str]:
        """The (ndims, prime-bucket, topology, density-key) compilation
        signature."""
        return (self.d, self.n_pad, self.arch.topology.fingerprint,
                self.dens_key)

    def _prepare(self, genomes: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Slice a (B, L) genome batch into the kernel's (perm, tiling,
        fmt, sg) inputs, padding the prime axis to its bucket.  For one
        compilation signature these arrays have identical trailing shapes
        across workloads — the property mega-batch stacking relies on."""
        genomes = np.asarray(genomes, dtype=np.int32)
        n = len(genomes)
        perm = genomes[:, self._sl_perm[0]:self._sl_perm[1]]
        til = genomes[:, self._sl_til[0]:self._sl_til[1]]
        if self.n_pad != self.n_primes:
            til = np.concatenate(
                [til, np.zeros((n, self.n_pad - self.n_primes),
                               dtype=np.int32)], axis=1)
        fmt = np.stack([genomes[:, a:b] for a, b in self._sl_fmt], axis=1)
        sg = genomes[:, self._sl_sg[0]:self._sl_sg[1]]
        return perm, til, fmt, sg

    def __call__(self, genomes) -> Dict[str, np.ndarray]:
        """genomes: (B, L) ints -> dict of (B,) arrays.  Pads the batch to
        the next power of two and the prime axis to its bucket."""
        n = len(genomes)
        padded = _pad_batch(n)
        perm, til, fmt, sg = self._prepare(genomes)
        if padded != n:
            perm, til, fmt, sg = (
                np.concatenate(
                    [a, np.zeros((padded - n,) + a.shape[1:], np.int32)],
                    axis=0) for a in (perm, til, fmt, sg))
        _count_dispatch()
        out = _aot_call(
            self.signature + ("bcast", padded), self._fn,
            (jnp.asarray(perm), jnp.asarray(til),
             jnp.asarray(fmt), jnp.asarray(sg),
             self._primes, self._prime_dim, self._relevance,
             self._densities, self._full_elems, self._total_macs,
             self._z_onehot, self._plat, self._dens_params))
        return _canonical(_time_block(
            lambda: {k: np.asarray(v)[:n] for k, v in out.items()}))

    def run_segment(self, seg: DeviceSegment) -> SegmentResult:
        """Execute one device-resident ES segment against this model
        (the single-task case of :func:`run_segments`).  ``_drive`` and
        other single-evaluator drivers discover this method by name —
        evaluators without it receive ``None`` and the generator replays
        the segment on the host."""
        return run_segments([self], [seg])[0]


def _pad_batch(n: int) -> int:
    """Batch-axis padding shared by every dispatch path: next power of
    two, floor 64 — ES populations and the baselines' odd native batch
    sizes (48, 50, 64) all land on the same few warm shapes."""
    return max(64, 1 << max(0, (n - 1)).bit_length())


def _canonical(out: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Recompute the derived outputs (edp, log10_edp) in numpy from the
    kernel's float32 cycles/energy.  XLA is free to fuse the final
    ``cycles * energy`` differently in the broadcast vs stacked kernel
    (observed: 1-ULP drift), so deriving them outside the jit makes every
    dispatch path bit-identical for the same rows."""
    cycles = out["cycles"]
    energy = out["energy_pj"]
    with np.errstate(over="ignore"):
        out["edp"] = cycles * energy
        out["log10_edp"] = (np.log10(np.maximum(cycles, 1e-30)) +
                            np.log10(np.maximum(energy, 1e-30))
                            ).astype(cycles.dtype)
    return out


# ----------------------------------------------- stacked-constants cache

# eval_stacked used to re-tile every model's workload constants across its
# rows (np.broadcast_to + concat) on EVERY round; for a steady fleet the
# (models, row-counts, padded shape) triple is identical round after
# round, so the concatenated constants are cached per signature (one
# epoch slot each) and rebuilt only when the fleet composition or
# mega-batch shape changes.  Epoch keys are CONTENT (workload cache_key +
# arch per model), never id(), so a recycled object can't alias a stale
# entry and no strong model refs need pinning.
_STACK_CONSTS: Dict[Tuple[int, int, str, str], Tuple[Tuple, List]] = {}
_STACK_PREP_HITS = 0
_STACK_PREP_MISSES = 0


def stack_prep_counts() -> Tuple[int, int]:
    """(cache hits, cache misses) of the stacked-constants prep cache."""
    with _LOCK:
        return _STACK_PREP_HITS, _STACK_PREP_MISSES


def reset_stack_prep_counts() -> None:
    global _STACK_PREP_HITS, _STACK_PREP_MISSES
    with _LOCK:
        _STACK_PREP_HITS = _STACK_PREP_MISSES = 0


def _stacked_consts(models: Sequence["JaxCostModel"],
                    sizes: Sequence[int], padded: int) -> List[np.ndarray]:
    global _STACK_PREP_HITS, _STACK_PREP_MISSES
    sig = models[0].signature
    key = (tuple((m.spec.workload.cache_key(), m.arch) for m in models),
           tuple(sizes), padded)
    with _LOCK:
        hit = _STACK_CONSTS.get(sig)
    if hit is not None and hit[0] == key:
        with _LOCK:
            _STACK_PREP_HITS += 1
        return hit[1]
    with _LOCK:
        _STACK_PREP_MISSES += 1
    consts: List[np.ndarray] = []
    for j in range(len(models[0]._np_consts)):
        rows = [np.broadcast_to(m._np_consts[j],
                                (n,) + np.shape(m._np_consts[j]))
                for m, n in zip(models, sizes)]
        total = sum(sizes)
        if padded != total:
            rows.append(np.broadcast_to(
                models[0]._np_consts[j],
                (padded - total,) + np.shape(models[0]._np_consts[j])))
        consts.append(np.ascontiguousarray(np.concatenate(rows, axis=0)))
    with _LOCK:
        _STACK_CONSTS[sig] = (key, consts)
    return consts


class StackedPending:
    """Handle to an in-flight ``eval_stacked(..., defer=True)`` dispatch:
    the device is computing when this is constructed, and ``finalize()``
    blocks (charged to :func:`host_blocked_s`), canonicalizes, and slices
    the mega-batch back per task.  ``finalize`` is idempotent."""

    def __init__(self, out, sizes: Sequence[int]):
        self._out = out
        self._sizes = list(sizes)
        self._sliced: Optional[List[Dict[str, np.ndarray]]] = None

    def finalize(self) -> List[Dict[str, np.ndarray]]:
        if self._sliced is None:
            out = self._out
            flat = _canonical(_time_block(
                lambda: {k: np.asarray(v) for k, v in out.items()}))
            sliced: List[Dict[str, np.ndarray]] = []
            off = 0
            for n in self._sizes:
                sliced.append({k: v[off:off + n] for k, v in flat.items()})
                off += n
            self._sliced = sliced
            self._out = None
        return self._sliced


def eval_stacked(models: Sequence["JaxCostModel"],
                 batches: Sequence[np.ndarray],
                 pad_floor: int = 0,
                 mesh=None, defer: bool = False):
    """Evaluate several (model, genome-batch) pairs sharing one
    compilation signature in a SINGLE device dispatch.

    The batches are concatenated along the batch axis, each model's
    workload/platform constants are tiled across its rows, and the
    stacked-constants kernel variant runs once on the padded mega-batch;
    the output dict is then sliced back per input pair.  Rows are
    evaluated by exactly the same per-row computation as the broadcast
    kernel, so results are bit-identical to per-model calls.  The tiled
    constants are cached per (fleet, signature) epoch — see
    :func:`stack_prep_counts` — so a steady fleet pays the
    broadcast+concat prep only when its composition changes.

    ``pad_floor`` raises the batch padding beyond the power-of-two rule —
    drivers pass the watermark of earlier rounds so a shrinking fleet
    keeps hitting an already-compiled mega-batch shape instead of tracing
    a new one (padding rows are zero genomes, sliced off).

    ``mesh`` shards the padded rows across the mesh's devices via the
    ``distributed.compat.shard_map`` shim (rows are further padded to a
    device-count multiple — a no-op for the usual power-of-two shapes);
    with ``mesh=None`` (or one device) the single-device path runs
    unchanged, and per-row results are identical either way because both
    wrap the same per-row kernel.

    ``defer=True`` returns a :class:`StackedPending` instead of the
    sliced list: the dispatch has been issued (JAX async dispatch keeps
    the device busy) but no host-blocking conversion happens until
    ``finalize()`` — the pipelined driver finalizes round N while round
    N+1 computes.  Results are bit-identical to ``defer=False`` because
    finalize performs exactly the conversion this function otherwise
    does inline."""
    if len(models) != len(batches):
        raise ValueError("models and batches must pair up")
    sig = models[0].signature
    if any(m.signature != sig for m in models):
        raise ValueError(
            f"eval_stacked needs one shared signature, got "
            f"{sorted({m.signature for m in models})}")
    sizes = [len(b) for b in batches]
    total = sum(sizes)
    padded = max(_pad_batch(total), int(pad_floor))
    ndev = _mesh_ndev(mesh) if mesh is not None else 1
    if ndev > 1 and padded % ndev:
        padded = -(-padded // ndev) * ndev
    preps = [m._prepare(b) for m, b in zip(models, batches)]
    ins = []
    for cols in zip(*preps):
        arr = np.concatenate(cols, axis=0)
        if padded != total:
            arr = np.concatenate(
                [arr, np.zeros((padded - total,) + arr.shape[1:],
                               np.int32)], axis=0)
        ins.append(arr)
    consts = _stacked_consts(models, sizes, padded)
    _count_dispatch()
    args = tuple(jnp.asarray(a) for a in ins) + \
        tuple(jnp.asarray(c) for c in consts)
    if ndev > 1:
        fn = _sharded_stacked_fn(sig[0], sig[1],
                                 models[0].arch.topology, sig[3], mesh)
        out = fn(*args)
    else:
        fn = _jitted_eval(sig[0], sig[1], models[0].arch.topology,
                          sig[3], stacked=True)
        out = _aot_call(sig + ("stacked", padded), fn, args)
    pending = StackedPending(out, sizes)
    if defer:
        return pending
    return pending.finalize()


# ----------------------------------------------- compile-ahead job prep
#
# Builders for the (key, jit_fn, arg_structs) triples ``compile_ahead``
# consumes.  Each mirrors EXACTLY the argument pytree its dispatch path
# passes — the AOT registry key doubles as the contract: if the builder
# and the dispatch ever disagree on shapes/dtypes the executable simply
# isn't found (or fails its call and falls back), never a wrong answer.


def _row_structs(model: "JaxCostModel", padded: int) -> Tuple:
    tt = _topo_tables(model.arch.topology)
    S = jax.ShapeDtypeStruct
    return (S((padded, tt.n_levels), np.int32),
            S((padded, model.n_pad), np.int32),
            S((padded, 3, MAX_FMT_GENES), np.int32),
            S((padded, tt.n_sites), np.int32))


def stacked_compile_job(model: "JaxCostModel", padded: int) -> Tuple:
    """AOT job for one ``eval_stacked`` mega-batch shape."""
    sig = model.signature
    fn = _jitted_eval(sig[0], sig[1], model.arch.topology, sig[3],
                      stacked=True)
    S = jax.ShapeDtypeStruct
    consts = tuple(S((padded,) + np.shape(np.asarray(c)),
                     np.asarray(c).dtype) for c in model._np_consts)
    return (sig + ("stacked", padded), fn,
            _row_structs(model, padded) + consts)


def bcast_compile_job(model: "JaxCostModel", padded: int) -> Tuple:
    """AOT job for one broadcast (per-task ``model(genomes)``) shape."""
    sig = model.signature
    S = jax.ShapeDtypeStruct
    consts = tuple(S(np.shape(np.asarray(c)), np.asarray(c).dtype)
                   for c in model._np_consts)
    return (sig + ("bcast", padded), model._fn,
            _row_structs(model, padded) + consts)


def _draw_structs(T: int, k: int, n_children: int, genes_per: int) -> Dict:
    S = jax.ShapeDtypeStruct
    return dict(ab=S((T, k, n_children, 2), np.int32),
                cuts=S((T, k, n_children), np.int32),
                active=S((T, k, n_children), np.bool_),
                gene=S((T, k, n_children, genes_per), np.int32),
                vals=S((T, k, n_children, genes_per), np.int32))


def _seg_consts_structs(model: "JaxCostModel", T: int) -> Tuple:
    S = jax.ShapeDtypeStruct
    return tuple(S((T,) + np.shape(np.asarray(c)), np.asarray(c).dtype)
                 for c in model._np_consts)


def scan_compile_job(model: "JaxCostModel", B: int, k: int,
                     n_parents: int, n_elite: int, genes_per: int,
                     T: int, restart: int = 0) -> Tuple:
    """AOT job for one ``run_segments`` ES-scan shape (``T`` same-shape
    tasks of ``B`` genomes advanced ``k`` generations)."""
    sig = model.signature
    fn = _scan_fn(sig[0], sig[1], model.arch.topology, sig[3],
                  n_parents, n_elite, genes_per, restart)
    lay = _padded_layout(model)
    n_children = B - n_elite
    S = jax.ShapeDtypeStruct
    draws = _draw_structs(T, k, n_children, genes_per)
    if restart:
        draws["fresh"] = S((T, k, n_children, lay.Lp), np.int32)
        draws["best0"] = S((T, 1), np.float32)
        draws["since0"] = S((T, 1), np.int32)
    tag = f"scan:p{n_parents}e{n_elite}g{genes_per}" + (
        f"r{restart}" if restart else "")
    args = (S((T, B, lay.Lp), np.int32), S((T, B), np.float32),
            S((T, lay.Lp), np.int32), S((T, lay.Lp), np.bool_),
            S((T, lay.Lp), np.int32), draws,
            _seg_consts_structs(model, T))
    return sig + (tag, T, B, k, n_children), fn, args


def direct_scan_compile_job(model: "JaxCostModel", B: int, k: int,
                            n_parents: int, n_elite: int, genes_per: int,
                            T: int, direct_len: int,
                            n_perm_codes: int) -> Tuple:
    """AOT job for one ``standard_es`` direct-scan shape.  ``direct_len``
    and ``n_perm_codes`` come from the task's ``DirectValueSpec``."""
    sig = model.signature
    fn = _direct_scan_fn(sig[0], sig[1], model.arch.topology, sig[3],
                         n_parents, n_elite, genes_per)
    n_children = B - n_elite
    S = jax.ShapeDtypeStruct
    args = (S((T, B, direct_len), np.int32), S((T, B), np.float32),
            S((T, n_perm_codes), np.int32), S((T, model.d), np.float32),
            _draw_structs(T, k, n_children, genes_per),
            _seg_consts_structs(model, T))
    return (sig + (f"dscan:p{n_parents}e{n_elite}g{genes_per}",
                   T, B, k, n_children), fn, args)
