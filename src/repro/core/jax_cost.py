"""JAX batch evaluator for the SparseMap cost model, generalized over a
declared :class:`repro.core.arch.ArchSpec`.

A jit-compiled, vmap-vectorized re-implementation of
:mod:`repro.core.cost_model` that evaluates a whole *population* of genomes
in one XLA call.  The numpy implementation is the exact oracle; this one is
float32 and property-tested against it (tests/test_cost_agreement.py).

Compilation strategy: all workload- and platform-specific quantities
(primes, densities, tensor sizes, energy/capacity/fanout constants) are
*traced arguments*, and the prime list is padded to a bucket size — so a
single compilation is shared by every workload with the same
(ndims, bucket, topology) signature and every same-topology platform.
The arch's *structure* (loop-slot count, store tables, S/G site wiring,
NoC multicast/reduction shape, which parameters exist) is baked into the
kernel as closure constants; its *numbers* — including per-edge word
widths when any level departs from the global default — ride in the
traced parameter vector (``ArchSpec.param_vector``).  Per-tensor density
models follow the same split: the *mode* is structural — all-uniform
workloads bake the literal pre-density-model occupancy code
(bit-identical to the goldens) while any structured operand selects the
structured kernel variant — and within the structured variant the family
codes and numeric parameters (N:M's n/m, a band's coverage) are traced
rows, so a family of N:M workloads, or a whole mixed
uniform/banded/N:M fleet, shares ONE compilation.
``JaxCostModel.signature`` is therefore
``(ndims, prime_bucket, topology_fingerprint, density_key)``, and
``eval_stacked``/``MultiSearch`` mega-batching keeps sharing compilations
*within* a (topology, density-mode) pair.

The decode is fully tensorized: tiling factors via masked products over the
prime list, permutations via a (d!, d) lookup table, loop-nest reuse via
reverse cumulative products over the fixed n_levels*d loop-slot axis, and
the fiber-tree byte accounting via a lax.scan over the loop slots.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from . import density as density_lib
from .accel import Platform
from .arch import ARCH_SPARSEMAP, ArchSpec, Topology, as_arch
from .encoding import GenomeSpec, all_permutations
from .es_ops import (DeviceSegment, PaddedLayout, SegmentResult,
                     segment_shape_key)
from .sparse import MAX_FMT_GENES
from .workload import WORD_BYTES

# Legacy constants: the default (paper) topology's store tables, kept for
# reference/backcompat.  The kernel derives its own per-topology tables.
GLB, PEBUF, REG = 0, 1, 2
STORE_OUTER = np.stack([
    np.isin(np.arange(ARCH_SPARSEMAP.n_levels),
            ARCH_SPARSEMAP.outer_levels_for[s])
    for s in ("glb", "pebuf", "reg")])
STORE_INNER = np.stack([
    np.isin(np.arange(ARCH_SPARSEMAP.n_levels),
            ARCH_SPARSEMAP.inner_levels_for[s])
    for s in ("glb", "pebuf", "reg")])
IS_SPATIAL_LEVEL = np.asarray(ARCH_SPARSEMAP.is_spatial)

# S/G lookup tables over gene value 0..6
_V = np.arange(7)
SG_LEADER_P = np.isin(_V, [2, 3, 5, 6])
SG_LEADER_Q = np.isin(_V, [1, 3, 4, 6])
SG_FOLLOW_P = np.isin(_V, [1, 3, 4, 6])
SG_FOLLOW_Q = np.isin(_V, [2, 3, 5, 6])
SG_IS_SKIP = _V >= 4
SG_IS_GATE = (_V >= 1) & (_V <= 3)

FMT_U, FMT_B, FMT_RLE, FMT_CP, FMT_UOP = range(5)


def _bucket(n: int, size: int = 16) -> int:
    return ((n + size - 1) // size) * size


# Registry of live jitted evaluators, keyed by compilation signature
# (ndims, padded prime count, topology fingerprint, density key, kind)
# where kind is "bcast" (workload constants broadcast over the batch) or
# "stacked" (per-row constants, the mega-batch kernel) — used to count
# actual XLA compilations (one per distinct traced argument-shape set per
# signature).  The density key is "u" for all-uniform workloads (the
# literal pre-density-model kernel, bit-identical to the goldens) or
# "s:<registered families>" for the structured variant, in which the
# per-tensor family code and its numeric parameters are TRACED — a whole
# family of N:M workloads, or a mixed uniform/banded/N:M fleet, shares
# one compilation.
_JIT_FNS: Dict[Tuple[int, int, str, str, str], object] = {}

# Device dispatches issued through JaxCostModel / eval_stacked since the
# last reset — the per-round dispatch-count benchmark hook.
_DISPATCHES = 0


def compilation_count() -> int:
    """Total XLA compilations held by the shared evaluator cache: the sum
    of per-signature jit cache sizes (each distinct batch shape traced on
    a signature is one compilation)."""
    total = 0
    for fn in _JIT_FNS.values():
        try:
            total += fn._cache_size()
        except Exception:       # private API; degrade to signature count
            total += 1
    return total


def compile_signatures() -> Tuple[Tuple[int, int, str, str], ...]:
    """The (ndims, prime-bucket, topology, density-key) signatures built
    so far."""
    return tuple(sorted({(k[0], k[1], k[2], k[3]) for k in _JIT_FNS}))


def dispatch_count() -> int:
    """Device dispatches issued since the last reset (each batched
    evaluator call — per-task or mega-batch — is one dispatch)."""
    return _DISPATCHES


def reset_dispatch_count() -> None:
    global _DISPATCHES
    _DISPATCHES = 0


def clear_compile_cache() -> None:
    """Drop all shared jitted evaluators (benchmarking hook)."""
    _jitted_eval.cache_clear()
    _build_eval_one.cache_clear()
    _scan_task_fn.cache_clear()
    _scan_fn.cache_clear()
    _JIT_FNS.clear()
    _SHARD_FNS.clear()
    _STACK_CONSTS.clear()
    reset_stack_prep_counts()
    reset_dispatch_count()


# ------------------------------------------------------- topology tables


@dataclasses.dataclass(frozen=True)
class _TopoTables:
    """Structural constants the kernel builder derives from a Topology."""

    n_levels: int
    n_edges: int
    is_spatial: Tuple[bool, ...]            # per mapping level
    spatial_levels: Tuple[int, ...]
    store_outer: Tuple[Tuple[bool, ...], ...]   # (n_edges, n_levels)
    store_inner: Tuple[Tuple[bool, ...], ...]
    edge_site: Tuple[Optional[int], ...]    # per edge
    n_sites: int
    # param-vector layout (indices into the traced vector)
    fanout_idx: Tuple[int, ...]             # per spatial level
    cap_checks: Tuple[Tuple[int, int], ...]  # (edge idx, param idx)
    energy_idx: Tuple[Tuple[int, ...], ...]  # per edge: component indices
    bw_checks: Tuple[Tuple[int, int], ...]  # (edge idx, param idx)
    mac_idx: int
    # NoC scheme per edge (True/False/"frac") + the word-width
    # parameterization: with uniform_words the kernel bakes WORD_BYTES as
    # a constant (the pre-width code path); otherwise per-edge widths are
    # read from the param vector at word_idx, so same-topology
    # custom-width specs still share one compilation.  Fractional NoC
    # schemes read their discount fanout from the param-vector tail at
    # noc_mc_idx / noc_red_idx (None on all/none edges) — same split, so
    # a same-scheme family with different fanouts shares one compilation.
    noc_multicast: Tuple[Union[bool, str], ...] = ()
    noc_reduction: Tuple[Union[bool, str], ...] = ()
    uniform_words: bool = True
    word_idx: Tuple[int, ...] = ()          # per edge: param idx
    noc_mc_idx: Tuple[Optional[int], ...] = ()   # per edge: param idx|None
    noc_red_idx: Tuple[Optional[int], ...] = ()


@lru_cache(maxsize=32)
def _topo_tables(topo: Topology) -> _TopoTables:
    n_edges = len(topo.has_spatial)
    level_edge: List[int] = []
    is_spatial: List[bool] = []
    for e in range(n_edges):
        level_edge.append(e)
        is_spatial.append(False)
        if topo.has_spatial[e]:
            level_edge.append(e)
            is_spatial.append(True)
    nl = len(level_edge)
    spatial_levels = tuple(i for i, s in enumerate(is_spatial) if s)
    store_outer = tuple(
        tuple(level_edge[i] <= e for i in range(nl))
        for e in range(n_edges))
    store_inner = tuple(
        tuple(level_edge[i] > e for i in range(nl))
        for e in range(n_edges))

    # param vector layout mirrors ArchSpec.param_vector
    pos = 0
    fanout_idx = tuple(range(pos, pos + len(spatial_levels)))
    pos += len(spatial_levels)
    cap_checks = []
    for k in range(1, n_edges + 1):
        if topo.has_capacity[k]:
            cap_checks.append((k - 1, pos))
            pos += 1
    energy_idx = []
    for e in range(n_edges):
        energy_idx.append(tuple(range(pos, pos + topo.n_energy_comps[e])))
        pos += topo.n_energy_comps[e]
    bw_checks = []
    for e in range(n_edges):
        if topo.has_bandwidth[e]:
            bw_checks.append((e, pos))
            pos += 1
    mac_idx = pos
    word_idx = tuple(range(pos + 1, pos + 1 + n_edges))
    # fractional NoC fanouts trail the word widths (mirrors
    # ArchSpec.param_vector: edge order, multicast before reduction)
    noc_mc = topo.noc_multicast or (True,) * n_edges
    noc_red = topo.noc_reduction or (True,) * n_edges
    pos = word_idx[-1] + 1 if word_idx else mac_idx + 1
    noc_mc_idx: List[Optional[int]] = []
    noc_red_idx: List[Optional[int]] = []
    for e in range(n_edges):
        if noc_mc[e] == "frac":
            noc_mc_idx.append(pos)
            pos += 1
        else:
            noc_mc_idx.append(None)
        if noc_red[e] == "frac":
            noc_red_idx.append(pos)
            pos += 1
        else:
            noc_red_idx.append(None)

    return _TopoTables(
        n_levels=nl, n_edges=n_edges, is_spatial=tuple(is_spatial),
        spatial_levels=spatial_levels, store_outer=store_outer,
        store_inner=store_inner, edge_site=topo.edge_site,
        n_sites=len(topo.sg_sites), fanout_idx=fanout_idx,
        cap_checks=tuple(cap_checks), energy_idx=tuple(energy_idx),
        bw_checks=tuple(bw_checks), mac_idx=mac_idx,
        noc_multicast=noc_mc,
        noc_reduction=noc_red,
        uniform_words=topo.uniform_word_bytes,
        word_idx=word_idx,
        noc_mc_idx=tuple(noc_mc_idx), noc_red_idx=tuple(noc_red_idx))


# ------------------------------------------- density occupancy builders
#
# JAX counterparts of DensityModel.block_nonempty, keyed by family name.
# Each takes (params_row, elems) where params_row is the traced
# [code, hit_rate, family params...] row (density.param_row) and elems
# the (possibly fractional) tile extents, and returns P(block nonempty).
# Custom families register with :func:`register_density_occ` BEFORE
# building evaluators (the structured kernel bakes the registered set at
# trace time; the registry fingerprint is part of the signature).


def _occ_uniform(pr, e):
    return 1.0 - jnp.power(1.0 - pr[2], jnp.maximum(e, 1.0))


def _occ_banded(pr, e):
    cov = jnp.maximum(pr[3], 1e-30)
    d_in = jnp.clip(pr[2] / cov, 0.0, 1.0)
    return cov * (1.0 - jnp.power(1.0 - d_in, jnp.maximum(e, 1.0)))


def _occ_block_nm(pr, e):
    # hypergeometric miss: C(m-n, e) / C(m, e) via log-gamma (fractional
    # e supported); any window wider than the zero budget m-n must hit
    from jax.scipy.special import gammaln
    n_, m_ = pr[2], pr[3]
    free = m_ - n_
    e_ = jnp.maximum(e, 1.0)
    ec = jnp.minimum(e_, free)
    lg = (gammaln(free + 1.0) + gammaln(m_ - ec + 1.0)
          - gammaln(free - ec + 1.0) - gammaln(m_ + 1.0))
    return jnp.where(e_ > free, 1.0, 1.0 - jnp.exp(lg))


_JAX_OCC = {"uniform": _occ_uniform, "banded": _occ_banded,
            "block_nm": _occ_block_nm}


def register_density_occ(family: str, fn) -> None:
    """Register the JAX occupancy builder of a custom density family
    (numpy side: ``density.register_density_model``).  Must happen before
    any structured evaluator is built."""
    if family in _JAX_OCC and _JAX_OCC[family] is not fn:
        raise ValueError(f"density family {family!r} already has a JAX "
                         f"occupancy builder")
    _JAX_OCC[family] = fn


def _occ_structured(pr, e):
    """Trace-time dispatch over the registered families: every family's
    occupancy is computed and the traced per-tensor code selects one —
    the family assignment rides in the traced params, so it never splits
    compilations."""
    fams = density_lib.registered_families()
    missing = [f for f in fams if f not in _JAX_OCC]
    if missing:
        raise KeyError(
            f"density families {missing} have no JAX occupancy builder; "
            f"call jax_cost.register_density_occ (COMPAT.md)")
    out = _JAX_OCC[fams[0]](pr, e)
    for fam in fams[1:]:
        out = jnp.where(pr[0] == float(density_lib.family_code(fam)),
                        _JAX_OCC[fam](pr, e), out)
    return out


# ---------------------------------------------------------------- kernel


@lru_cache(maxsize=64)
def _build_eval_one(d: int, n_primes_pad: int, topo: Topology,
                    dens_key: str = "u"):
    """Build the un-vmapped per-row kernel closure for (ndims=d, padded
    prime count, topology, density mode).  Every dispatch path — the
    broadcast and stacked batch evaluators, the sharded mega-batch, and
    the device-resident ``run_segments`` scan — vmaps this ONE closure,
    so per-row results are identical across all of them.

    ``dens_key == "u"`` bakes the uniform-random occupancy model exactly
    as the pre-density-model code did (bit-identical to the goldens);
    any other value builds the structured variant, in which each
    tensor's density-model family code and numeric parameters are read
    from the traced ``dens_params`` rows (see ``_occ_structured``)."""
    tt = _topo_tables(topo)
    structured = dens_key != "u"
    NL = tt.n_levels
    NE = tt.n_edges
    perm_table = jnp.asarray(all_permutations(d), jnp.int32)
    store_outer_lv = jnp.asarray(np.asarray(tt.store_outer))  # (NE, NL)
    store_inner_lv = jnp.asarray(np.asarray(tt.store_inner))
    spatial_lv = jnp.asarray(np.asarray(tt.is_spatial))
    lvl_of = jnp.repeat(jnp.arange(NL), d)          # (nl,)
    wb = float(WORD_BYTES)

    def eval_one(perm_genes, assign, fmt_genes, sg,
                 primes, prime_dim, relevance, densities, full_elems,
                 total_macs, z_onehot, plat, dens_params):
        # ---- tiling factors (NL, d) ----
        lvl_eq = assign[None, :] == jnp.arange(NL,
                                               dtype=jnp.int32)[:, None]
        dim_eq = prime_dim[None, :] == jnp.arange(d, dtype=jnp.int32)[:, None]
        mask = lvl_eq[:, None, :] & dim_eq[None, :, :]     # (NL, d, np)
        factors = jnp.prod(jnp.where(mask, primes[None, None, :], 1.0),
                           axis=-1)                        # (NL, d) float32

        # ---- flattened loops ----
        loop_dims = perm_table[perm_genes]                 # (NL, d)
        dims_flat = loop_dims.reshape(-1)                  # (nl,)
        bounds = factors[lvl_of, dims_flat]
        spatial_flat = spatial_lv[lvl_of]

        fanouts = [jnp.prod(factors[lvl]) for lvl in tt.spatial_levels]
        rel_flat = relevance[:, dims_flat]                 # (3, nl)
        transparent = bounds <= 1.0

        store_outer = store_outer_lv[:, lvl_of]            # (NE, nl)

        def fills_for(s, t):
            active = store_outer[s]
            irrel = ~rel_flat[t]
            passthru = jnp.where(active, irrel | transparent, True)
            in_suffix = jnp.flip(jnp.cumprod(
                jnp.flip(passthru.astype(jnp.float32)))) > 0.5
            contrib = jnp.where(rel_flat[t], bounds,
                                jnp.where(~spatial_flat, bounds, 1.0))
            mult = jnp.prod(jnp.where(active & ~in_suffix, contrib, 1.0))
            # NoC scheme of edge s: without multicast (reads) /
            # in-network reduction (the output, tensor 2), every spatial
            # instance's copy crosses the edge — irrelevant spatial loops
            # multiply traffic wherever they sit in the nest (suffix
            # included).  Fractional schemes carry max(S / fanout, 1)
            # copies over the same loop set, the fanout traced from the
            # param-vector tail (same-scheme families share compilation).
            scheme = (tt.noc_reduction[s] if t == 2
                      else tt.noc_multicast[s])
            if scheme == "frac":
                fi = tt.noc_red_idx[s] if t == 2 else tt.noc_mc_idx[s]
                s_irrel = jnp.prod(jnp.where(
                    active & irrel & spatial_flat, bounds, 1.0))
                mult = mult * jnp.maximum(s_irrel / plat[fi], 1.0)
            elif not scheme:
                mult = mult * jnp.prod(jnp.where(
                    active & irrel & spatial_flat, bounds, 1.0))
            tile = jnp.prod(jnp.where(
                store_inner_lv[s][:, None] & relevance[t][None, :],
                factors, 1.0))
            return tile * mult

        fills = jnp.stack([jnp.stack([fills_for(s, t) for t in range(3)])
                           for s in range(NE)])            # (NE, 3)

        # ---- fiber-tree format accounting per tensor ----
        def clog2(x):
            return jnp.maximum(1.0, jnp.ceil(jnp.log2(jnp.maximum(x, 2.0))))

        def tensor_format(t):
            genes = fmt_genes[t]
            is_sub = rel_flat[t] & (bounds > 1.0)
            k = jnp.sum(is_sub.astype(jnp.int32))
            rank = jnp.cumsum(is_sub.astype(jnp.int32)) - 1
            gidx = rank + jnp.maximum(MAX_FMT_GENES - k, 0)
            fmt = jnp.where(is_sub & (gidx < MAX_FMT_GENES) & (gidx >= 0),
                            genes[jnp.clip(gidx, 0, MAX_FMT_GENES - 1)],
                            FMT_U)
            dens = densities[t]
            sub_bounds = jnp.where(is_sub, bounds, 1.0)
            suffix_prod = jnp.flip(jnp.cumprod(jnp.flip(sub_bounds)))
            elems_below = suffix_prod / sub_bounds
            if structured:
                occ = _occ_structured(dens_params[t], elems_below)
            else:
                # all-uniform: the literal pre-density-model expression
                occ = 1.0 - jnp.power(1.0 - dens,
                                      jnp.maximum(elems_below, 1.0))
            kept = sub_bounds * occ
            full = full_elems[t]

            def body(carry, xs):
                n_fibers, meta_bits = carry
                L, f, kp, sub = xs
                mb = jnp.select(
                    [f == FMT_B, f == FMT_RLE, f == FMT_CP, f == FMT_UOP],
                    [n_fibers * L,
                     n_fibers * kp * clog2(L),
                     n_fibers * kp * clog2(L),
                     n_fibers * (L + 1.0) * clog2(jnp.maximum(full, 2.0))],
                    0.0)
                meta_bits = meta_bits + jnp.where(sub > 0.5, mb, 0.0)
                nf_next = jnp.where(f == FMT_U, n_fibers * L, n_fibers * kp)
                n_fibers = jnp.where(sub > 0.5, nf_next, n_fibers)
                return (n_fibers, meta_bits), None

            (_, meta_bits), _ = jax.lax.scan(
                body, (jnp.float32(1.0), jnp.float32(0.0)),
                (sub_bounds, fmt, kept, is_sub.astype(jnp.float32)))
            compressed = jnp.any(jnp.where(is_sub, fmt != FMT_U, False))
            data_b = jnp.where(compressed, full * dens * wb, full * wb)
            ratio = (data_b + meta_bits / 8.0) / jnp.maximum(full * wb, 1.0)

            comp_here = jnp.where(is_sub, (fmt != FMT_U).astype(jnp.float32),
                                  0.0)
            comp_after = jnp.flip(jnp.cumsum(jnp.flip(comp_here))) - comp_here
            uop_bad = jnp.any(is_sub & (fmt == FMT_UOP) & (comp_after < 0.5))
            spat_bad = jnp.any(is_sub & spatial_flat & (fmt != FMT_U))
            return ratio, compressed, uop_bad | spat_bad, meta_bits

        rs, comps, bads, metas = zip(*[tensor_format(t) for t in range(3)])
        ratios = jnp.stack(rs)
        fmt_invalid = bads[0] | bads[1] | bads[2]
        p_comp, q_comp = comps[0], comps[1]

        # ---- S/G (sg has one gene per site; compute site "C" last) ----
        lead_p = jnp.asarray(SG_LEADER_P)[sg]
        lead_q = jnp.asarray(SG_LEADER_Q)[sg]
        fol_p = jnp.asarray(SG_FOLLOW_P)[sg]
        fol_q = jnp.asarray(SG_FOLLOW_Q)[sg]
        skips = jnp.asarray(SG_IS_SKIP)[sg]
        gates = jnp.asarray(SG_IS_GATE)[sg]
        if structured:
            # element-granularity intersection hit rates of the input
            # leaders (DensityModel.hit_rate, traced per tensor)
            d_p, d_q = dens_params[0, 1], dens_params[1, 1]
        else:
            d_p, d_q = densities[0], densities[1]
        sg_invalid = jnp.any(skips & ((lead_p & ~p_comp) |
                                      (lead_q & ~q_comp)))
        frac_e_p = jnp.where(fol_p & (skips | gates), d_q, 1.0)
        frac_e_q = jnp.where(fol_q & (skips | gates), d_p, 1.0)
        frac_t_p = jnp.where(fol_p & skips, d_q, 1.0)
        frac_t_q = jnp.where(fol_q & skips, d_p, 1.0)
        cyc_frac = jnp.where(jnp.any(skips & lead_p), d_p, 1.0) * \
            jnp.where(jnp.any(skips & lead_q), d_q, 1.0)
        e_frac = jnp.where(jnp.any((skips | gates) & lead_p), d_p, 1.0) * \
            jnp.where(jnp.any((skips | gates) & lead_q), d_q, 1.0)

        # ---- traffic ----
        total_z = jnp.sum(full_elems * z_onehot)
        is_z = z_onehot                                     # (3,)
        one = jnp.float32(1.0)
        fe_rows, ft_rows = [], []
        for e in range(NE):
            si = tt.edge_site[e]
            if si is None:
                fe_rows.append(jnp.stack([one, one, one]))
                ft_rows.append(jnp.stack([one, one, one]))
            else:
                fe_rows.append(jnp.stack([frac_e_p[si], frac_e_q[si], one]))
                ft_rows.append(jnp.stack([frac_t_p[si], frac_t_q[si], one]))
        fe = jnp.stack(fe_rows)                             # (NE, 3)
        ft = jnp.stack(ft_rows)
        f_rmw = jnp.maximum(2.0 * fills - total_z, total_z)
        fills_adj = jnp.where(is_z[None, :] > 0.5, f_rmw, fills)

        def _tile_elems(s):
            return jnp.stack([
                jnp.prod(jnp.where(
                    store_inner_lv[s][:, None] & relevance[t][None, :],
                    factors, 1.0)) for t in range(3)])

        if tt.uniform_words:
            # default-width topology: the pre-word-width code, the global
            # width baked as a constant (bit-identical to the goldens)
            byt = fills_adj * wb * ratios[None, :]          # (NE edges, 3 t)

            def tile_bytes(s):
                return jnp.sum(_tile_elems(s) * wb * ratios)
        else:
            # per-edge widths from the param vector: data bytes scale
            # with the width, metadata bits do not, so the compression
            # ratio is recomputed per edge (edge s fills store s+1, whose
            # width also prices that store's occupancy)
            wbs = jnp.stack([plat[i] for i in tt.word_idx])  # (NE,)
            full_wb = full_elems[None, :] * wbs[:, None]     # (NE, 3)
            data_b = jnp.where(
                jnp.stack(comps)[None, :],
                full_elems[None, :] * densities[None, :] * wbs[:, None],
                full_wb)
            ratios_e = (data_b + jnp.stack(metas)[None, :] / 8.0) / \
                jnp.maximum(full_wb, 1.0)                    # (NE, 3)
            byt = fills_adj * wbs[:, None] * ratios_e

            def tile_bytes(s):
                return jnp.sum(_tile_elems(s) * wbs[s] * ratios_e[s])
        tr_e = byt * fe
        tr_t = byt * ft

        # ---- validity, energy, latency (param-vector driven) ----
        invalid = jnp.bool_(False)
        for fan, pi in zip(fanouts, tt.fanout_idx):
            invalid = invalid | (fan > plat[pi])
        invalid = invalid | fmt_invalid | sg_invalid
        for e, pi in tt.cap_checks:
            invalid = invalid | (tile_bytes(e) > plat[pi])

        # left-associated sums/products, matching the legacy kernel's
        # float32 evaluation order exactly
        edge_energies = []
        for e in range(NE):
            comps_e = [plat[i] for i in tt.energy_idx[e]]
            e_edge = comps_e[0]
            for c in comps_e[1:]:
                e_edge = e_edge + c
            edge_energies.append(jnp.sum(tr_e[e]) * e_edge)
        energy = edge_energies[0]
        for term in edge_energies[1:]:
            energy = energy + term
        energy = energy + total_macs * e_frac * plat[tt.mac_idx]
        fan_prod = fanouts[0] if fanouts else one
        for fan in fanouts[1:]:
            fan_prod = fan_prod * fan
        compute_cycles = (total_macs / fan_prod) * cyc_frac
        cycles = compute_cycles
        for e, pi in tt.bw_checks:
            cycles = jnp.maximum(cycles, jnp.sum(tr_t[e]) / plat[pi])
        edp = cycles * energy
        log10_edp = jnp.log10(jnp.maximum(cycles, 1e-30)) + \
            jnp.log10(jnp.maximum(energy, 1e-30))
        valid = ~invalid
        big = jnp.float32(jnp.inf)
        return dict(valid=valid,
                    energy_pj=jnp.where(valid, energy, big),
                    cycles=jnp.where(valid, cycles, big),
                    edp=jnp.where(valid, edp, big),
                    log10_edp=jnp.where(valid, log10_edp, big))

    return eval_one


@lru_cache(maxsize=32)
def _jitted_eval(d: int, n_primes_pad: int, topo: Topology,
                 dens_key: str = "u", stacked: bool = False):
    """The jitted batch evaluator for (ndims=d, padded prime count,
    topology, density mode): :func:`_build_eval_one` vmapped over the
    batch axis.

    With ``stacked=False`` the workload/platform quantities are broadcast
    over the batch (one workload per call); with ``stacked=True`` they are
    batched per row, so rows belonging to *different* workloads and
    platforms can be concatenated into one mega-batch and evaluated in a
    single device dispatch (``eval_stacked``)."""
    eval_one = _build_eval_one(d, n_primes_pad, topo, dens_key)
    in_axes = (0,) * 13 if stacked else (0, 0, 0, 0) + (None,) * 9
    fn = jax.jit(jax.vmap(eval_one, in_axes=in_axes))
    _JIT_FNS[(d, n_primes_pad, topo.fingerprint, dens_key,
              "stacked" if stacked else "bcast")] = fn
    return fn


# -------------------------------------------------- device-resident scan

# Mesh-sharded jitted variants, keyed by (signature..., kind, mesh key).
# Kept out of the lru_caches because a Mesh is identified by its device
# set + axis names, not object identity.
_SHARD_FNS: Dict[Tuple, object] = {}


def _mesh_key(mesh) -> Tuple:
    devs = np.asarray(mesh.devices).reshape(-1)
    return (tuple(mesh.axis_names), tuple(int(d.id) for d in devs))


def _mesh_ndev(mesh) -> int:
    return 1 if mesh is None else int(np.asarray(mesh.devices).size)


@lru_cache(maxsize=32)
def _scan_task_fn(d: int, n_pad: int, topo: Topology, dens_key: str,
                  n_parents: int, n_elite: int, genes_per: int):
    """The un-jitted scan program for ONE fleet of same-shape tasks:
    vmap over the task axis of a ``lax.scan`` over generations, each
    step folding {stable-sort elitist selection -> crossover -> mutation
    -> clip/fixed-genes -> batched cost eval} into the carry.

    All randomness arrives pre-drawn in the ``draws`` xs (plan arrays in
    PADDED genome coordinates — see ``es_ops.PaddedLayout``), so the
    program is a pure function of its inputs; the carry fitness for
    selection is the explicit ``cycles * energy`` product of the emitted
    outputs, the same multiply ``_canonical`` performs on the host."""
    eval_one = _build_eval_one(d, n_pad, topo, dens_key)
    tt = _topo_tables(topo)
    NL = tt.n_levels
    F3 = 3 * MAX_FMT_GENES
    veval = jax.vmap(eval_one, in_axes=(0, 0, 0, 0) + (None,) * 9)

    def one_task(pop, edp, gene_ub, fixed_mask, fixed_vals, draws, consts):
        def step(carry, dr):
            pop, edp = carry
            order = jnp.argsort(edp)            # stable sort
            parents = pop[order[:n_parents]]
            elites = pop[order[:n_elite]]
            elite_edp = edp[order[:n_elite]]
            Lp = pop.shape[1]
            col = jnp.arange(Lp)[None, :]
            kids = jnp.where(col < dr["cuts"][:, None],
                             parents[dr["ab"][:, 0]],
                             parents[dr["ab"][:, 1]])
            C = kids.shape[0]
            rows = jnp.arange(C)
            # draw-order duplicate overwrite: one column at a time (row
            # indices are unique per column, so the order is defined)
            for j in range(genes_per):
                g = dr["gene"][:, j]
                kids = kids.at[rows, g].set(
                    jnp.where(dr["active"], dr["vals"][:, j],
                              kids[rows, g]))
            kids = jnp.clip(kids, 0, gene_ub[None, :] - 1)
            kids = jnp.where(fixed_mask[None, :], fixed_vals[None, :],
                             kids)
            perm = kids[:, :NL]
            til = kids[:, NL:NL + n_pad]
            fmt = kids[:, NL + n_pad:NL + n_pad + F3].reshape(
                C, 3, MAX_FMT_GENES)
            sg = kids[:, NL + n_pad + F3:]
            out = veval(perm, til, fmt, sg, *consts)
            kedp = out["cycles"] * out["energy_pj"]
            new_pop = jnp.concatenate([elites, kids], axis=0)
            new_edp = jnp.concatenate([elite_edp, kedp], axis=0)
            ys = dict(kids=kids, valid=out["valid"],
                      energy_pj=out["energy_pj"], cycles=out["cycles"])
            return (new_pop, new_edp), ys

        (pop, edp), ys = jax.lax.scan(step, (pop, edp), draws)
        return pop, edp, ys

    return jax.vmap(one_task, in_axes=(0, 0, 0, 0, 0, 0, 0))


@lru_cache(maxsize=32)
def _scan_fn(d: int, n_pad: int, topo: Topology, dens_key: str,
             n_parents: int, n_elite: int, genes_per: int):
    fn = jax.jit(_scan_task_fn(d, n_pad, topo, dens_key, n_parents,
                               n_elite, genes_per))
    _JIT_FNS[(d, n_pad, topo.fingerprint, dens_key,
              f"scan:p{n_parents}e{n_elite}g{genes_per}")] = fn
    return fn


def _sharded_scan_fn(d: int, n_pad: int, topo: Topology, dens_key: str,
                     n_parents: int, n_elite: int, genes_per: int, mesh):
    """The scan program shard_map-ed over the task axis of ``mesh``'s
    first axis (task count must divide the device count's multiple —
    checked by the caller)."""
    key = (d, n_pad, topo.fingerprint, dens_key,
           f"scan:p{n_parents}e{n_elite}g{genes_per}", _mesh_key(mesh))
    fn = _SHARD_FNS.get(key)
    if fn is None:
        from jax.sharding import PartitionSpec as P
        from ..distributed.compat import shard_map
        vfn = _scan_task_fn(d, n_pad, topo, dens_key, n_parents, n_elite,
                            genes_per)
        ax = mesh.axis_names[0]
        fn = jax.jit(shard_map(vfn, mesh=mesh, in_specs=(P(ax),) * 7,
                               out_specs=P(ax)))
        _SHARD_FNS[key] = fn
        _JIT_FNS[(d, n_pad, topo.fingerprint, dens_key,
                  f"scan:p{n_parents}e{n_elite}g{genes_per}"
                  f"@{_mesh_ndev(mesh)}")] = fn
    return fn


def _sharded_stacked_fn(d: int, n_pad: int, topo: Topology,
                        dens_key: str, mesh):
    """The stacked mega-batch kernel shard_map-ed over batch rows."""
    key = (d, n_pad, topo.fingerprint, dens_key, "stacked",
           _mesh_key(mesh))
    fn = _SHARD_FNS.get(key)
    if fn is None:
        from jax.sharding import PartitionSpec as P
        from ..distributed.compat import shard_map
        eval_one = _build_eval_one(d, n_pad, topo, dens_key)
        vfn = jax.vmap(eval_one, in_axes=(0,) * 13)
        ax = mesh.axis_names[0]
        fn = jax.jit(shard_map(vfn, mesh=mesh, in_specs=(P(ax),) * 13,
                               out_specs=P(ax)))
        _SHARD_FNS[key] = fn
        _JIT_FNS[(d, n_pad, topo.fingerprint, dens_key,
                  f"stacked@{_mesh_ndev(mesh)}")] = fn
    return fn


def _padded_layout(model: "JaxCostModel") -> PaddedLayout:
    lay = getattr(model, "_pad_layout", None)
    if lay is None:
        lay = PaddedLayout(model.spec, model.n_pad)
        model._pad_layout = lay
    return lay


def run_segments(models: Sequence["JaxCostModel"],
                 segs: Sequence[DeviceSegment],
                 mesh=None) -> List[SegmentResult]:
    """Execute one DeviceSegment per model as a SINGLE device dispatch:
    all segments (which must share the models' compilation signature and
    the segment shape key) stack along a task axis, and a jitted
    vmap-of-lax.scan advances every task ``k`` generations on-device.

    Host work per call is limited to padding genomes/plan arrays into
    the shared scan layout and, afterwards, slicing the per-generation
    outputs back per task (``_canonical``-recomputed like every other
    dispatch path).  With ``mesh`` given and the task count divisible by
    the device count, tasks shard across devices via the
    ``distributed.compat.shard_map`` shim; otherwise the single-device
    program runs unchanged."""
    global _DISPATCHES
    if len(models) != len(segs):
        raise ValueError("models and segments must pair up")
    sig = models[0].signature
    if any(m.signature != sig for m in models):
        raise ValueError(
            f"run_segments needs one shared signature, got "
            f"{sorted({m.signature for m in models})}")
    shape_key = segment_shape_key(segs[0])
    if any(segment_shape_key(s) != shape_key for s in segs):
        raise ValueError("run_segments needs one shared segment shape")
    _, k, n_parents, n_elite, genes_per = shape_key

    pops, edps, ubs, fmasks, fvals, draw_list = [], [], [], [], [], []
    for m, s in zip(models, segs):
        lay = _padded_layout(m)
        pops.append(lay.pad_rows(np.asarray(s.pop, dtype=np.int32)))
        edps.append(np.asarray(s.edp, dtype=np.float32))
        ubs.append(lay.pad_vector(m.spec.gene_ub.astype(np.int32), 1))
        fm = np.zeros(lay.Lp, dtype=bool)
        fv = np.zeros(lay.Lp, dtype=np.int32)
        if s.fixed_genes:
            idx = lay.pad_index(
                np.asarray(list(s.fixed_genes), dtype=np.int64))
            fm[idx] = True
            fv[idx] = np.asarray(list(s.fixed_genes.values()),
                                 dtype=np.int32)
        fmasks.append(fm)
        fvals.append(fv)
        dr = dict(s.draws)
        dr["gene"] = lay.pad_index(dr["gene"]).astype(np.int32)
        dr["cuts"] = lay.pad_cut(dr["cuts"]).astype(np.int32)
        draw_list.append(dr)
    draws = {kk: jnp.asarray(np.stack([d[kk] for d in draw_list]))
             for kk in draw_list[0]}
    consts = tuple(
        jnp.asarray(np.stack([np.asarray(m._np_consts[j])
                              for m in models]))
        for j in range(len(models[0]._np_consts)))

    T = len(segs)
    topo = models[0].arch.topology
    if mesh is not None and _mesh_ndev(mesh) > 1 and \
            T % _mesh_ndev(mesh) == 0:
        fn = _sharded_scan_fn(sig[0], sig[1], topo, sig[3], n_parents,
                              n_elite, genes_per, mesh)
    else:
        fn = _scan_fn(sig[0], sig[1], topo, sig[3], n_parents, n_elite,
                      genes_per)
    _DISPATCHES += 1
    pop_f, edp_f, ys = fn(jnp.asarray(np.stack(pops)),
                          jnp.asarray(np.stack(edps)),
                          jnp.asarray(np.stack(ubs)),
                          jnp.asarray(np.stack(fmasks)),
                          jnp.asarray(np.stack(fvals)),
                          draws, consts)
    pop_f = np.asarray(pop_f)
    edp_f = np.asarray(edp_f)
    ys = {kk: np.asarray(v) for kk, v in ys.items()}
    results: List[SegmentResult] = []
    for t, m in enumerate(models):
        lay = _padded_layout(m)
        gens = []
        for g in range(k):
            kids = lay.unpad_rows(ys["kids"][t, g]).astype(np.int64)
            out = _canonical(dict(valid=ys["valid"][t, g],
                                  energy_pj=ys["energy_pj"][t, g],
                                  cycles=ys["cycles"][t, g]))
            gens.append((kids, out))
        results.append(SegmentResult(
            gens=gens,
            final_pop=lay.unpad_rows(pop_f[t]).astype(np.int64),
            final_edp=edp_f[t]))
    return results


# ---------------------------------------------------------------- wrapper


class JaxCostModel:
    """Batch evaluator bound to one (workload, arch/platform) pair.
    Instances with the same (ndims, prime bucket, topology) share a
    single XLA compilation — same-topology platforms (e.g. the paper's
    edge/mobile/cloud) differ only in the traced parameter vector.

    ``n_pad`` widens the prime axis beyond the workload's natural bucket so
    a group of concurrent searches over different workloads can be forced
    onto ONE compilation signature (``search.MultiSearch``); the padding
    primes are 1.0 and are numerically inert.

    ``structured`` likewise promotes an all-uniform workload onto the
    structured-density kernel variant (its Uniform models become traced
    family rows) so a mixed uniform/banded/N:M fleet shares one
    signature; ``None`` picks the workload's natural mode — all-uniform
    workloads then compile the literal pre-density-model kernel,
    bit-identical to the goldens."""

    def __init__(self, spec: GenomeSpec,
                 platform: Union[str, Platform, ArchSpec],
                 n_pad: Optional[int] = None,
                 structured: Optional[bool] = None):
        self.spec = spec
        self.arch = as_arch(platform)
        self.platform = self.arch          # legacy alias
        if self.arch.topology != spec.arch.topology:
            raise ValueError(
                f"GenomeSpec was built for arch {spec.arch.name!r} but "
                f"the evaluator targets {self.arch.name!r} with a "
                f"different topology")
        wl = spec.workload
        d = wl.ndims
        self.d = d
        self.n_primes = spec.n_primes
        self.n_pad = _bucket(max(self.n_primes, 1, int(n_pad or 0)))
        natural_structured = wl.structured_density
        if structured is None:
            structured = natural_structured
        elif not structured and natural_structured:
            raise ValueError(
                f"workload {wl.name!r} declares structured density "
                f"models; it cannot run on the uniform kernel")
        self.structured = bool(structured)
        self.dens_key = "u" if not self.structured else \
            "s:" + density_lib.registry_fingerprint()

        primes = np.ones(self.n_pad, dtype=np.float32)
        prime_dim = np.zeros(self.n_pad, dtype=np.int32)
        dim_idx = {dim: i for i, dim in enumerate(wl.dim_order)}
        for i, (dd, p) in enumerate(spec.primes):
            primes[i] = p
            prime_dim[i] = dim_idx[dd]
        # numpy copies kept for eval_stacked (per-row tiling across a
        # heterogeneous mega-batch); jnp copies feed the broadcast kernel
        self._np_consts = (
            primes,
            prime_dim,
            np.asarray([[dim in t.dims for dim in wl.dim_order]
                        for t in wl.tensors], bool),
            np.asarray([wl.density_of(t.name) for t in wl.tensors],
                       np.float32),
            np.asarray([t.size(wl.dim_sizes) for t in wl.tensors],
                       np.float32),
            np.float32(wl.macs),
            np.asarray([1.0 if t.is_output else 0.0 for t in wl.tensors],
                       np.float32),
            self.arch.param_vector(),
            # per-tensor traced density rows [code, hit, family params..]
            np.asarray([density_lib.param_row(wl.density_model_of(t.name))
                        for t in wl.tensors], np.float32))
        (self._primes, self._prime_dim, self._relevance, self._densities,
         self._full_elems, self._total_macs, self._z_onehot, self._plat,
         self._dens_params) = [jnp.asarray(c) for c in self._np_consts]

        self._fn = _jitted_eval(d, self.n_pad, self.arch.topology,
                                self.dens_key)
        s = spec.segments
        self._sl_perm = (s["perm"].start, s["perm"].stop)
        self._sl_til = (s["tiling"].start, s["tiling"].stop)
        self._sl_fmt = [(s[f"fmt_{t.name}"].start, s[f"fmt_{t.name}"].stop)
                        for t in wl.tensors]
        self._sl_sg = (s["sg"].start, s["sg"].stop)

    @property
    def signature(self) -> Tuple[int, int, str, str]:
        """The (ndims, prime-bucket, topology, density-key) compilation
        signature."""
        return (self.d, self.n_pad, self.arch.topology.fingerprint,
                self.dens_key)

    def _prepare(self, genomes: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Slice a (B, L) genome batch into the kernel's (perm, tiling,
        fmt, sg) inputs, padding the prime axis to its bucket.  For one
        compilation signature these arrays have identical trailing shapes
        across workloads — the property mega-batch stacking relies on."""
        genomes = np.asarray(genomes, dtype=np.int32)
        n = len(genomes)
        perm = genomes[:, self._sl_perm[0]:self._sl_perm[1]]
        til = genomes[:, self._sl_til[0]:self._sl_til[1]]
        if self.n_pad != self.n_primes:
            til = np.concatenate(
                [til, np.zeros((n, self.n_pad - self.n_primes),
                               dtype=np.int32)], axis=1)
        fmt = np.stack([genomes[:, a:b] for a, b in self._sl_fmt], axis=1)
        sg = genomes[:, self._sl_sg[0]:self._sl_sg[1]]
        return perm, til, fmt, sg

    def __call__(self, genomes) -> Dict[str, np.ndarray]:
        """genomes: (B, L) ints -> dict of (B,) arrays.  Pads the batch to
        the next power of two and the prime axis to its bucket."""
        global _DISPATCHES
        n = len(genomes)
        padded = _pad_batch(n)
        perm, til, fmt, sg = self._prepare(genomes)
        if padded != n:
            perm, til, fmt, sg = (
                np.concatenate(
                    [a, np.zeros((padded - n,) + a.shape[1:], np.int32)],
                    axis=0) for a in (perm, til, fmt, sg))
        _DISPATCHES += 1
        out = self._fn(jnp.asarray(perm), jnp.asarray(til),
                       jnp.asarray(fmt), jnp.asarray(sg),
                       self._primes, self._prime_dim, self._relevance,
                       self._densities, self._full_elems, self._total_macs,
                       self._z_onehot, self._plat, self._dens_params)
        return _canonical({k: np.asarray(v)[:n] for k, v in out.items()})

    def run_segment(self, seg: DeviceSegment) -> SegmentResult:
        """Execute one device-resident ES segment against this model
        (the single-task case of :func:`run_segments`).  ``_drive`` and
        other single-evaluator drivers discover this method by name —
        evaluators without it receive ``None`` and the generator replays
        the segment on the host."""
        return run_segments([self], [seg])[0]


def _pad_batch(n: int) -> int:
    """Batch-axis padding shared by every dispatch path: next power of
    two, floor 64 — ES populations and the baselines' odd native batch
    sizes (48, 50, 64) all land on the same few warm shapes."""
    return max(64, 1 << max(0, (n - 1)).bit_length())


def _canonical(out: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Recompute the derived outputs (edp, log10_edp) in numpy from the
    kernel's float32 cycles/energy.  XLA is free to fuse the final
    ``cycles * energy`` differently in the broadcast vs stacked kernel
    (observed: 1-ULP drift), so deriving them outside the jit makes every
    dispatch path bit-identical for the same rows."""
    cycles = out["cycles"]
    energy = out["energy_pj"]
    with np.errstate(over="ignore"):
        out["edp"] = cycles * energy
        out["log10_edp"] = (np.log10(np.maximum(cycles, 1e-30)) +
                            np.log10(np.maximum(energy, 1e-30))
                            ).astype(cycles.dtype)
    return out


# ----------------------------------------------- stacked-constants cache

# eval_stacked used to re-tile every model's workload constants across its
# rows (np.broadcast_to + concat) on EVERY round; for a steady fleet the
# (models, row-counts, padded shape) triple is identical round after
# round, so the concatenated constants are cached per signature (one
# epoch slot each) and rebuilt only when the fleet composition or
# mega-batch shape changes.  Epoch keys are CONTENT (workload cache_key +
# arch per model), never id(), so a recycled object can't alias a stale
# entry and no strong model refs need pinning.
_STACK_CONSTS: Dict[Tuple[int, int, str, str], Tuple[Tuple, List]] = {}
_STACK_PREP_HITS = 0
_STACK_PREP_MISSES = 0


def stack_prep_counts() -> Tuple[int, int]:
    """(cache hits, cache misses) of the stacked-constants prep cache."""
    return _STACK_PREP_HITS, _STACK_PREP_MISSES


def reset_stack_prep_counts() -> None:
    global _STACK_PREP_HITS, _STACK_PREP_MISSES
    _STACK_PREP_HITS = _STACK_PREP_MISSES = 0


def _stacked_consts(models: Sequence["JaxCostModel"],
                    sizes: Sequence[int], padded: int) -> List[np.ndarray]:
    global _STACK_PREP_HITS, _STACK_PREP_MISSES
    sig = models[0].signature
    key = (tuple((m.spec.workload.cache_key(), m.arch) for m in models),
           tuple(sizes), padded)
    hit = _STACK_CONSTS.get(sig)
    if hit is not None and hit[0] == key:
        _STACK_PREP_HITS += 1
        return hit[1]
    _STACK_PREP_MISSES += 1
    consts: List[np.ndarray] = []
    for j in range(len(models[0]._np_consts)):
        rows = [np.broadcast_to(m._np_consts[j],
                                (n,) + np.shape(m._np_consts[j]))
                for m, n in zip(models, sizes)]
        total = sum(sizes)
        if padded != total:
            rows.append(np.broadcast_to(
                models[0]._np_consts[j],
                (padded - total,) + np.shape(models[0]._np_consts[j])))
        consts.append(np.ascontiguousarray(np.concatenate(rows, axis=0)))
    _STACK_CONSTS[sig] = (key, consts)
    return consts


def eval_stacked(models: Sequence["JaxCostModel"],
                 batches: Sequence[np.ndarray],
                 pad_floor: int = 0,
                 mesh=None) -> List[Dict[str, np.ndarray]]:
    """Evaluate several (model, genome-batch) pairs sharing one
    compilation signature in a SINGLE device dispatch.

    The batches are concatenated along the batch axis, each model's
    workload/platform constants are tiled across its rows, and the
    stacked-constants kernel variant runs once on the padded mega-batch;
    the output dict is then sliced back per input pair.  Rows are
    evaluated by exactly the same per-row computation as the broadcast
    kernel, so results are bit-identical to per-model calls.  The tiled
    constants are cached per (fleet, signature) epoch — see
    :func:`stack_prep_counts` — so a steady fleet pays the
    broadcast+concat prep only when its composition changes.

    ``pad_floor`` raises the batch padding beyond the power-of-two rule —
    drivers pass the watermark of earlier rounds so a shrinking fleet
    keeps hitting an already-compiled mega-batch shape instead of tracing
    a new one (padding rows are zero genomes, sliced off).

    ``mesh`` shards the padded rows across the mesh's devices via the
    ``distributed.compat.shard_map`` shim (rows are further padded to a
    device-count multiple — a no-op for the usual power-of-two shapes);
    with ``mesh=None`` (or one device) the single-device path runs
    unchanged, and per-row results are identical either way because both
    wrap the same per-row kernel."""
    global _DISPATCHES
    if len(models) != len(batches):
        raise ValueError("models and batches must pair up")
    sig = models[0].signature
    if any(m.signature != sig for m in models):
        raise ValueError(
            f"eval_stacked needs one shared signature, got "
            f"{sorted({m.signature for m in models})}")
    sizes = [len(b) for b in batches]
    total = sum(sizes)
    padded = max(_pad_batch(total), int(pad_floor))
    ndev = _mesh_ndev(mesh) if mesh is not None else 1
    if ndev > 1 and padded % ndev:
        padded = -(-padded // ndev) * ndev
    preps = [m._prepare(b) for m, b in zip(models, batches)]
    ins = []
    for cols in zip(*preps):
        arr = np.concatenate(cols, axis=0)
        if padded != total:
            arr = np.concatenate(
                [arr, np.zeros((padded - total,) + arr.shape[1:],
                               np.int32)], axis=0)
        ins.append(arr)
    consts = _stacked_consts(models, sizes, padded)
    if ndev > 1:
        fn = _sharded_stacked_fn(sig[0], sig[1],
                                 models[0].arch.topology, sig[3], mesh)
    else:
        fn = _jitted_eval(sig[0], sig[1], models[0].arch.topology,
                          sig[3], stacked=True)
    _DISPATCHES += 1
    out = fn(*[jnp.asarray(a) for a in ins],
             *[jnp.asarray(c) for c in consts])
    flat = _canonical({k: np.asarray(v) for k, v in out.items()})
    sliced: List[Dict[str, np.ndarray]] = []
    off = 0
    for n in sizes:
        sliced.append({k: v[off:off + n] for k, v in flat.items()})
        off += n
    return sliced
