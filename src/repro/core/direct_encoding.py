"""Direct value encoding — the ablation counterpoint to prime-factor +
cantor encoding (SparseMap §IV.B, Fig. 10, Fig. 18 curve "ES").

Genome layout:

    [ perm x5 (RANDOM code->permutation table, Fig. 10a)
      | factor values, d dims x 5 levels, each in [1 .. size(dim)]
      | P fmt x5 | Q fmt x5 | Z fmt x5 | SG x3 ]

The dimension-tiling constraint (prod_l factor[d,l] == size(d)) is NOT
guaranteed by the encoding; genomes violating it are invalid — which is the
paper's point: only ~0.000023 % of direct-encoded combinations are valid
tilings.  Sampling and mutation draw factor values from the divisors of the
dimension size (a generous implementation choice; uniform integers would
never produce a single valid point at CI budgets).

Valid direct genomes are translated to the canonical `GenomeSpec` genome
and costed with the same JAX batch evaluator, so the comparison isolates
*encoding*, not the cost model.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

import numpy as np

from .encoding import GenomeSpec, all_permutations, cantor_encode
from .mapping import N_LEVELS
from .sparse import MAX_FMT_GENES, N_SG
from .workload import Workload


def divisors(n: int) -> List[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


class DirectValueSpec:
    """Direct-value genome with a scrambled permutation code table."""

    def __init__(self, canonical: GenomeSpec, seed: int = 1234):
        self.canonical = canonical
        wl = canonical.workload
        self.workload = wl
        self.d = wl.ndims
        rng = np.random.default_rng(seed)
        nperm = math.factorial(self.d)
        # random encoding: code -> arbitrary permutation (Fig. 10a)
        self.scramble = rng.permutation(nperm)
        self._perm_table = all_permutations(self.d)
        self.div: Dict[str, List[int]] = {
            dim: divisors(wl.dim_sizes[dim]) for dim in wl.dim_order}

        self.n_factor_genes = self.d * N_LEVELS
        self.length = (N_LEVELS + self.n_factor_genes +
                       MAX_FMT_GENES * 3 + 3)
        self.perm_sl = slice(0, N_LEVELS)
        self.fact_sl = slice(N_LEVELS, N_LEVELS + self.n_factor_genes)
        self.tail_sl = slice(N_LEVELS + self.n_factor_genes, self.length)
        self.n_perm_codes = nperm

    # -------------------------------------------------------- sampling
    def random_genomes(self, rng: np.random.Generator, n: int) -> np.ndarray:
        g = np.zeros((n, self.length), dtype=np.int64)
        g[:, self.perm_sl] = rng.integers(0, self.n_perm_codes,
                                          (n, N_LEVELS))
        col = self.fact_sl.start
        for dim in self.workload.dim_order:
            dv = np.asarray(self.div[dim])
            for lvl in range(N_LEVELS):
                g[:, col] = dv[rng.integers(0, len(dv), n)]
                col += 1
        tail = self.canonical.length - self.canonical.segments["fmt_P"].start
        tail_ub = self.canonical.gene_ub[-tail:]
        g[:, self.tail_sl] = (rng.random((n, tail)) *
                              tail_ub[None, :]).astype(np.int64)
        return g

    def mutate_gene(self, g: np.ndarray, i: int, j: int,
                    rng: np.random.Generator) -> None:
        if j < self.perm_sl.stop:
            g[i, j] = rng.integers(0, self.n_perm_codes)
        elif j < self.fact_sl.stop:
            rel = j - self.fact_sl.start
            dim = self.workload.dim_order[rel // N_LEVELS]
            dv = self.div[dim]
            g[i, j] = dv[rng.integers(0, len(dv))]
        else:
            rel = j - self.tail_sl.start
            ub = self.canonical.gene_ub[
                self.canonical.segments["fmt_P"].start + rel]
            g[i, j] = rng.integers(0, ub)

    # -------------------------------------------------------- decode
    def to_canonical(self, g: np.ndarray) -> Optional[np.ndarray]:
        """Translate to the canonical genome; None if the tiling constraint
        is violated (invalid individual)."""
        wl = self.workload
        factors = g[self.fact_sl].reshape(self.d, N_LEVELS)
        for i, dim in enumerate(wl.dim_order):
            if int(np.prod(factors[i])) != wl.dim_sizes[dim]:
                return None
        out = np.zeros(self.canonical.length, dtype=np.int64)
        # perms: scrambled code -> permutation -> cantor code
        for lvl in range(N_LEVELS):
            code = int(self.scramble[g[self.perm_sl][lvl]])
            out[self.canonical.segments["perm"].start + lvl] = code
        # tiling: distribute primes of each dim over levels per the factors
        from .workload import prime_factorize
        tpos = self.canonical.segments["tiling"].start
        remaining = {dim: list(factors[i])
                     for i, dim in enumerate(wl.dim_order)}
        for k, (dim, p) in enumerate(self.canonical.primes):
            for lvl in range(N_LEVELS):
                if remaining[dim][lvl] % p == 0 and remaining[dim][lvl] > 1:
                    remaining[dim][lvl] //= p
                    out[tpos + k] = lvl
                    break
            else:
                return None
        out[self.canonical.segments["fmt_P"].start:] = g[self.tail_sl]
        return out

    def make_batch_eval(self, canonical_eval):
        """Wrap the canonical batch evaluator: direct genomes that violate
        the tiling constraint are invalid without costing."""
        def _eval(genomes: np.ndarray) -> Dict[str, np.ndarray]:
            n = len(genomes)
            valid = np.zeros(n, dtype=bool)
            edp = np.full(n, np.inf)
            canon = []
            index = []
            for i in range(n):
                c = self.to_canonical(genomes[i])
                if c is not None:
                    canon.append(c)
                    index.append(i)
            if canon:
                out = canonical_eval(np.stack(canon))
                v = np.asarray(out["valid"])
                e = np.asarray(out["edp"], dtype=np.float64)
                for k, i in enumerate(index):
                    valid[i] = bool(v[k])
                    edp[i] = e[k] if v[k] else np.inf
            return dict(valid=valid, edp=edp,
                        log10_edp=np.log10(np.maximum(edp, 1e-30)))
        return _eval


def direct_standard_es(canonical_spec: GenomeSpec, canonical_eval,
                       budget: int, seed: int, platform=None,
                       pop_size: int = 100, parent_frac: float = 0.4,
                       elite_frac: float = 0.1,
                       p_mut: float = 0.9) -> "SearchResult":
    """Standard ES on the direct encoding (Fig. 18 curve 'ES'): LHS-style
    init, uniform single-point crossover, uniform mutation."""
    from .evolution import SearchResult, _Budget
    rng = np.random.default_rng(seed)
    spec = DirectValueSpec(canonical_spec)
    ev = spec.make_batch_eval(canonical_eval)
    tracker = _Budget(budget)

    pop = spec.random_genomes(rng, pop_size)
    edp = tracker.register(pop, ev(pop))
    n_parents = max(2, int(pop_size * parent_frac))
    n_elite = max(1, int(pop_size * elite_frac))
    while not tracker.exhausted:
        order = np.argsort(edp)
        parents = pop[order[:n_parents]]
        elites = pop[order[:n_elite]].copy()
        elite_edp = edp[order[:n_elite]].copy()
        kids = np.empty((pop_size - n_elite, spec.length), dtype=np.int64)
        for i in range(len(kids)):
            a, b = rng.integers(0, len(parents), 2)
            cut = rng.integers(1, spec.length)
            kids[i, :cut] = parents[a, :cut]
            kids[i, cut:] = parents[b, cut:]
            if rng.random() < p_mut:
                for _ in range(2):
                    spec.mutate_gene(kids, i, rng.integers(0, spec.length),
                                     rng)
        kedp = tracker.register(kids, ev(kids))
        pop = np.concatenate([elites, kids])
        edp = np.concatenate([elite_edp, kedp])
    return SearchResult(best_edp=tracker.best, best_genome=tracker.best_genome,
                        history=np.asarray(tracker.hist),
                        evals=tracker.evals, valid_evals=tracker.valid,
                        extras=dict(method="direct_standard_es"))
