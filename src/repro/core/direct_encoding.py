"""Direct value encoding — the ablation counterpoint to prime-factor +
cantor encoding (SparseMap §IV.B, Fig. 10, Fig. 18 curve "ES").

Genome layout (n_levels/sg-site counts derived from the canonical spec's
arch — word widths, NoC descriptors and per-tensor density models add no
genes, exactly as in the canonical encoding; paper arch shown):

    [ perm x5 (RANDOM code->permutation table, Fig. 10a)
      | factor values, d dims x 5 levels, each in [1 .. size(dim)]
      | P fmt x5 | Q fmt x5 | Z fmt x5 | SG x3 ]

The dimension-tiling constraint (prod_l factor[d,l] == size(d)) is NOT
guaranteed by the encoding; genomes violating it are invalid — which is the
paper's point: only ~0.000023 % of direct-encoded combinations are valid
tilings.  Sampling and mutation draw factor values from the divisors of the
dimension size (a generous implementation choice; uniform integers would
never produce a single valid point at CI budgets).

Valid direct genomes are translated to the canonical `GenomeSpec` genome
and costed with the same JAX batch evaluator, so the comparison isolates
*encoding*, not the cost model.  The engine is exposed both as the
closed-form :func:`direct_standard_es` and as the request generator
:func:`direct_requests` (the ``standard_es`` entry in
``baselines.REQUEST_METHODS``): the generator yields CANONICAL genome
batches for the translatable rows, so a ``search.MultiSearch`` fleet can
evaluate them on the shared jitted evaluator alongside every other
method; untranslatable rows are charged to the budget as invalid without
costing, exactly like the closed-form path.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import es_ops
from .encoding import GenomeSpec, all_permutations


def divisors(n: int) -> List[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


class DirectValueSpec:
    """Direct-value genome with a scrambled permutation code table."""

    def __init__(self, canonical: GenomeSpec, seed: int = 1234):
        self.canonical = canonical
        wl = canonical.workload
        self.workload = wl
        self.d = wl.ndims
        self.n_levels = canonical.arch.n_levels
        rng = np.random.default_rng(seed)
        nperm = math.factorial(self.d)
        # random encoding: code -> arbitrary permutation (Fig. 10a)
        self.scramble = rng.permutation(nperm)
        self._perm_table = all_permutations(self.d)
        self.div: Dict[str, List[int]] = {
            dim: divisors(wl.dim_sizes[dim]) for dim in wl.dim_order}

        nl = self.n_levels
        self.n_factor_genes = self.d * nl
        tail = canonical.length - canonical.segments["fmt_P"].start
        self.length = nl + self.n_factor_genes + tail
        self.perm_sl = slice(0, nl)
        self.fact_sl = slice(nl, nl + self.n_factor_genes)
        self.tail_sl = slice(nl + self.n_factor_genes, self.length)
        self.n_perm_codes = nperm

    # -------------------------------------------------------- sampling
    def random_genomes(self, rng: np.random.Generator, n: int) -> np.ndarray:
        g = np.zeros((n, self.length), dtype=np.int64)
        g[:, self.perm_sl] = rng.integers(0, self.n_perm_codes,
                                          (n, self.n_levels))
        col = self.fact_sl.start
        for dim in self.workload.dim_order:
            dv = np.asarray(self.div[dim])
            for lvl in range(self.n_levels):
                g[:, col] = dv[rng.integers(0, len(dv), n)]
                col += 1
        tail = self.canonical.length - self.canonical.segments["fmt_P"].start
        tail_ub = self.canonical.gene_ub[-tail:]
        g[:, self.tail_sl] = (rng.random((n, tail)) *
                              tail_ub[None, :]).astype(np.int64)
        return g

    def mutate_gene(self, g: np.ndarray, i: int, j: int,
                    rng: np.random.Generator) -> None:
        if j < self.perm_sl.stop:
            g[i, j] = rng.integers(0, self.n_perm_codes)
        elif j < self.fact_sl.stop:
            rel = j - self.fact_sl.start
            dim = self.workload.dim_order[rel // self.n_levels]
            dv = self.div[dim]
            g[i, j] = dv[rng.integers(0, len(dv))]
        else:
            rel = j - self.tail_sl.start
            ub = self.canonical.gene_ub[
                self.canonical.segments["fmt_P"].start + rel]
            g[i, j] = rng.integers(0, ub)

    # -------------------------------------------------------- decode
    def to_canonical(self, g: np.ndarray) -> Optional[np.ndarray]:
        """Translate to the canonical genome; None if the tiling constraint
        is violated (invalid individual)."""
        wl = self.workload
        nl = self.n_levels
        factors = g[self.fact_sl].reshape(self.d, nl)
        for i, dim in enumerate(wl.dim_order):
            if int(np.prod(factors[i])) != wl.dim_sizes[dim]:
                return None
        out = np.zeros(self.canonical.length, dtype=np.int64)
        # perms: scrambled code -> permutation -> cantor code
        for lvl in range(nl):
            code = int(self.scramble[g[self.perm_sl][lvl]])
            out[self.canonical.segments["perm"].start + lvl] = code
        # tiling: distribute primes of each dim over levels per the factors
        tpos = self.canonical.segments["tiling"].start
        remaining = {dim: list(factors[i])
                     for i, dim in enumerate(wl.dim_order)}
        for k, (dim, p) in enumerate(self.canonical.primes):
            for lvl in range(nl):
                if remaining[dim][lvl] % p == 0 and remaining[dim][lvl] > 1:
                    remaining[dim][lvl] //= p
                    out[tpos + k] = lvl
                    break
            else:
                return None
        out[self.canonical.segments["fmt_P"].start:] = g[self.tail_sl]
        return out

    def translate_batch(self, genomes: np.ndarray
                        ) -> Tuple[Optional[np.ndarray], List[int]]:
        """(stacked canonical rows or None, indices of translatable rows)."""
        canon, index = [], []
        for i in range(len(genomes)):
            c = self.to_canonical(genomes[i])
            if c is not None:
                canon.append(c)
                index.append(i)
        return (np.stack(canon) if canon else None), index

    def expand_out(self, n: int, index: List[int],
                   out: Optional[Dict]) -> Dict:
        """Scatter a canonical evaluation of the translatable subset back
        to a full-batch output dict (untranslatable rows: invalid, inf
        EDP)."""
        valid = np.zeros(n, dtype=bool)
        edp = np.full(n, np.inf)
        if out is not None and index:
            v = np.asarray(out["valid"])
            e = np.asarray(out["edp"], dtype=np.float64)
            for k, i in enumerate(index):
                valid[i] = bool(v[k])
                edp[i] = e[k] if v[k] else np.inf
        return dict(valid=valid, edp=edp,
                    log10_edp=np.log10(np.maximum(edp, 1e-30)))

    def make_batch_eval(self, canonical_eval):
        """Wrap the canonical batch evaluator: direct genomes that violate
        the tiling constraint are invalid without costing."""
        def _eval(genomes: np.ndarray) -> Dict[str, np.ndarray]:
            canon, index = self.translate_batch(genomes)
            out = canonical_eval(canon) if canon is not None else None
            return self.expand_out(len(genomes), index, out)
        return _eval


def _direct_value_draw(dspec: DirectValueSpec, j: int,
                       rng: np.random.Generator) -> int:
    """The replacement value :meth:`DirectValueSpec.mutate_gene` would
    write at gene ``j`` — same rng consumption (one ``integers`` draw),
    value independent of the genome, so a plan can pre-draw it."""
    if j < dspec.perm_sl.stop:
        return int(rng.integers(0, dspec.n_perm_codes))
    if j < dspec.fact_sl.stop:
        rel = j - dspec.fact_sl.start
        dim = dspec.workload.dim_order[rel // dspec.n_levels]
        dv = dspec.div[dim]
        return int(dv[rng.integers(0, len(dv))])
    rel = j - dspec.tail_sl.start
    ub = dspec.canonical.gene_ub[
        dspec.canonical.segments["fmt_P"].start + rel]
    return int(rng.integers(0, ub))


def _direct_plan(dspec: DirectValueSpec, rng: np.random.Generator,
                 n_children: int, n_parents: int,
                 p_mut: float) -> es_ops.GenDraws:
    """One generation's randomness for the direct-encoding ES, drawn in
    EXACTLY the legacy per-child order (parent pair, cut, mutation coin,
    then per-mutated-gene index+value) so the plan is a pure
    re-expression of the sequential loop's stream."""
    L = dspec.length
    ab = np.empty((n_children, 2), dtype=np.int64)
    cuts = np.empty(n_children, dtype=np.int64)
    active = np.empty(n_children, dtype=bool)
    gene = np.zeros((n_children, 2), dtype=np.int64)
    vals = np.zeros((n_children, 2), dtype=np.int64)
    for i in range(n_children):
        ab[i] = rng.integers(0, n_parents, 2)
        cuts[i] = rng.integers(1, L)
        active[i] = rng.random() < p_mut
        if active[i]:
            for j in range(2):
                gi = int(rng.integers(0, L))
                gene[i, j] = gi
                vals[i, j] = _direct_value_draw(dspec, gi, rng)
    return es_ops.GenDraws(ab=ab, cuts=cuts, active=active,
                           gene=gene, vals=vals)


def direct_requests(spec: GenomeSpec, tracker: "_Budget", seed: int,
                    platform=None, pop_size: int = 100,
                    parent_frac: float = 0.4, elite_frac: float = 0.1,
                    p_mut: float = 0.9, device_rounds: int = 1,
                    rng_backend: str = "numpy") -> "Requests":
    """Standard ES on the direct encoding (Fig. 18 curve 'ES') as a
    request generator over CANONICAL genome rows: each round the direct
    population is translated, the translatable subset is yielded for
    evaluation on the canonical batch evaluator, and the full population
    (translatable or not) is charged to the budget.  Canonical rows are
    registered with the tracker, so ``best_genome`` decodes with the
    ordinary :class:`GenomeSpec` like every other method's result.

    ``device_rounds=k>1`` switches to the segment protocol: the loop
    yields ``kind="direct"`` :class:`~.es_ops.DeviceSegment` requests
    whose pre-drawn plans cover k generations; ``jax_cost`` runs the
    whole fold — including the direct-to-canonical translation — as one
    scanned dispatch, pipelined one round late exactly like the main
    ES's ``_segment_requests`` (COMPAT.md "standard_es segment
    protocol").  Selection then uses the stable f32 fitness order shared
    with the device kernel (the legacy per-round loop keeps its unstable
    f64 ``np.argsort``, same seam as the canonical ES).
    """
    if rng_backend != "numpy":
        raise ValueError(
            "standard_es segments support only rng_backend='numpy' "
            f"(got {rng_backend!r}); the direct value draws are tied to "
            "the legacy Generator stream")
    rng = np.random.default_rng(seed)
    dspec = DirectValueSpec(spec)

    def charge(pop: np.ndarray):
        """Translate, yield the canonical subset, register the FULL
        population against the budget; returns the full-batch EDP."""
        canon, index = dspec.translate_batch(pop)
        out = None
        if canon is not None:
            out = yield canon
        full = dspec.expand_out(len(pop), index, out)
        # register canonical rows so best_genome is canonical; rows
        # without a translation can never be best (inf EDP)
        reg_rows = np.zeros((len(pop), spec.length), dtype=np.int64)
        if canon is not None:
            reg_rows[index] = canon
        return tracker.register(reg_rows, full)

    pop = dspec.random_genomes(rng, pop_size)
    edp = yield from charge(pop)
    n_parents = max(2, int(pop_size * parent_frac))
    n_elite = max(1, int(pop_size * elite_frac))
    if device_rounds > 1:
        extras = yield from _direct_segment_requests(
            spec, dspec, tracker, rng, pop, edp, pop_size,
            n_parents, n_elite, p_mut, device_rounds)
        return extras
    while not tracker.exhausted:
        order = np.argsort(edp)
        parents = pop[order[:n_parents]]
        elites = pop[order[:n_elite]].copy()
        elite_edp = edp[order[:n_elite]].copy()
        kids = np.empty((pop_size - n_elite, dspec.length), dtype=np.int64)
        for i in range(len(kids)):
            a, b = rng.integers(0, len(parents), 2)
            cut = rng.integers(1, dspec.length)
            kids[i, :cut] = parents[a, :cut]
            kids[i, cut:] = parents[b, cut:]
            if rng.random() < p_mut:
                for _ in range(2):
                    dspec.mutate_gene(kids, i,
                                      rng.integers(0, dspec.length), rng)
        kedp = yield from charge(kids)
        pop = np.concatenate([elites, kids])
        edp = np.concatenate([elite_edp, kedp])
    return dict(method="standard_es", encoding="direct")


def _direct_segment_requests(spec: GenomeSpec, dspec: DirectValueSpec,
                             tracker: "_Budget", rng: np.random.Generator,
                             pop: np.ndarray, edp: np.ndarray,
                             pop_size: int, n_parents: int, n_elite: int,
                             p_mut: float, k: int) -> "Requests":
    """Device-resident rounds for the direct encoding: yields
    ``kind="direct"`` :class:`~.es_ops.DeviceSegment` requests whose
    ``aux`` carries the translation tables (permutation scramble and
    dimension sizes) so ``jax_cost`` can run crossover, mutation,
    direct-to-canonical translation AND evaluation as one scanned
    dispatch.  Pipelined one round late exactly like
    ``evolution._segment_requests`` (COMPAT.md "Pipelined dispatch
    contract"): the response for segment N is stashed unresolved, segment
    N+1 is planned from the ``planned`` counter and yielded carrying the
    device-resident ``resp.carry``, then N is resolved and registered.
    Drivers that answer ``None`` get a host replay of the identical plan
    (translate + canonical-subset yield per generation, same
    registration rows as the device harvest)."""
    n_children = pop_size - n_elite
    edp_sel = np.where(np.isfinite(edp), edp, np.inf).astype(np.float32)
    aux = dict(
        scramble=np.asarray(dspec.scramble, dtype=np.int32),
        dim_sizes=np.asarray(
            [dspec.workload.dim_sizes[d] for d in dspec.workload.dim_order],
            dtype=np.float32))
    gen = 0

    def absorb(resp):
        nonlocal pop, edp_sel, gen
        resp.resolve()
        for kids, kout in resp.gens:
            tracker.register(kids, kout)
            gen += 1
        pop = resp.final_pop
        edp_sel = np.asarray(resp.final_edp, dtype=np.float32)

    planned = tracker.evals
    pending = None
    carry = None
    while planned < tracker.budget:
        plans = [_direct_plan(dspec, rng, n_children, n_parents, p_mut)
                 for _ in range(k)]
        for _ in range(k):
            planned += min(n_children, tracker.budget - planned)
        resp = yield es_ops.DeviceSegment(
            spec=spec, pop=pop, edp=edp_sel, rounds=k, gen0=gen,
            n_parents=n_parents, n_elite=n_elite, genes_per=2,
            draws=es_ops.stack_draws(plans), fixed_genes=None,
            rng_backend="numpy", carry=carry, kind="direct", aux=aux)
        if resp is None:
            # host replay of the identical plan, one generation per yield:
            # the registered rows (canonical where translatable, zeros
            # otherwise) match the device harvest's ``canon`` output
            for d in plans:
                parents, elites, elite_edp = es_ops.select(
                    pop, edp_sel, n_parents, n_elite)
                kids = np.ascontiguousarray(
                    es_ops.apply_crossover(parents, d.ab, d.cuts),
                    dtype=pop.dtype)
                kids = es_ops.apply_mutation(kids, d.active, d.gene,
                                             d.vals)
                canon, index = dspec.translate_batch(kids)
                out = None
                if canon is not None:
                    out = yield canon
                full = dspec.expand_out(len(kids), index, out)
                reg_rows = np.zeros((len(kids), spec.length),
                                    dtype=np.int64)
                if canon is not None:
                    reg_rows[index] = canon
                tracker.register(reg_rows, full)
                kedp = np.where(
                    np.asarray(full["valid"]),
                    np.asarray(full["edp"], dtype=np.float32),
                    np.float32(np.inf)).astype(np.float32)
                pop = np.concatenate([elites, kids], axis=0)
                edp_sel = np.concatenate(
                    [np.asarray(elite_edp, np.float32), kedp])
                gen += 1
                if tracker.exhausted:
                    break
            continue
        if pending is not None:
            absorb(pending)
        pending = resp
        carry = resp.carry
    if pending is not None:
        absorb(pending)
    return dict(method="standard_es", encoding="direct", generations=gen)


def direct_standard_es(canonical_spec: GenomeSpec, canonical_eval,
                       budget: int, seed: int, platform=None,
                       **kw) -> "SearchResult":
    """Drive :func:`direct_requests` against one evaluator (the
    closed-form Fig. 18 'ES' path; identical code to the concurrent
    fleet)."""
    from .evolution import SearchResult, _Budget, _drive
    tracker = _Budget(budget)
    extras = _drive(direct_requests(canonical_spec, tracker, seed,
                                    platform=platform, **kw),
                    canonical_eval) or {}
    extras["method"] = "direct_standard_es"
    return SearchResult(best_edp=tracker.best,
                        best_genome=tracker.best_genome,
                        history=np.asarray(tracker.hist),
                        evals=tracker.evals, valid_evals=tracker.valid,
                        extras=extras)
